//! Quickstart: generate a sparse matrix, compress it with CSR-dtANS,
//! compare sizes against CSR/COO/SELL, run SpMVM on the fly (serial and
//! through the parallel engine), and verify against the plain CSR kernel.
//!
//! Run: `cargo run --release --example quickstart`

use dtans::format::csr_dtans::{CsrDtans, EncodeOptions};
use dtans::matrix::gen::{assign_values, gen_graph_csr, GraphModel, ValueDist};
use dtans::matrix::{Precision, SizeModel};
use dtans::spmv::{spmv_csr, spmv_csr_dtans, DtansOperator, SpmvEngine};
use dtans::util::rng::Xoshiro256;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    // 1. A random graph adjacency matrix with quantized values (think:
    //    pruned+quantized NN layer, one of the paper's motivating cases).
    let mut rng = Xoshiro256::seeded(7);
    let mut a = gen_graph_csr(GraphModel::ErdosRenyi, 20_000, 16.0, &mut rng);
    assign_values(&mut a, ValueDist::Quantized(256), &mut rng);
    println!("matrix: {} x {}, {} nnz", a.nrows, a.ncols, a.nnz());

    // 2. Compress. The encoder delta-encodes column indices, builds the
    //    two dtANS coding tables, entropy-codes every row and interleaves
    //    the streams warp-wise.
    let opts = EncodeOptions::default(); // PAPER params, 64-bit
    let enc = CsrDtans::encode(&a, &opts)?;
    let report = enc.size_report();
    let model = SizeModel { precision: Precision::F64 };
    let (baseline, fmt) = model.best_baseline_bytes(&a);
    println!(
        "size: best classic format ({fmt}) = {} KB, CSR-dtANS = {} KB  ({:.2}x smaller)",
        baseline / 1024,
        report.total / 1024,
        baseline as f64 / report.total as f64
    );
    println!(
        "      breakdown: tables {} + dicts {} + stream {} + row lens {} + escapes {}",
        report.tables, report.dicts, report.stream, report.row_lens, report.escapes
    );

    // 3. SpMVM with on-the-fly decoding, verified against plain CSR.
    let x: Vec<f64> = (0..a.ncols).map(|_| rng.next_f64() - 0.5).collect();
    let mut y = vec![0.0; a.nrows];
    let t0 = std::time::Instant::now();
    spmv_csr_dtans(&enc, &x, &mut y)?;
    let dt = t0.elapsed().as_secs_f64();
    let mut want = vec![0.0; a.nrows];
    spmv_csr(&a, &x, &mut want)?;
    let err = y
        .iter()
        .zip(&want)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f64, f64::max);
    println!(
        "spmv: {:.2} ms ({:.2} GB/s of compressed data), max |err| vs CSR = {err:.2e}",
        dt * 1e3,
        report.total as f64 / dt / 1e9
    );
    assert!(err < 1e-9);

    // 4. The same multiply through the parallel engine (nnz-balanced
    //    blocks across all CPUs) — bit-identical to the serial kernel.
    //    The engine is format-agnostic: it takes any SpmvOperator, and the
    //    dtANS operator owns its decode plan so repeated multiplies skip
    //    the table build.
    let op = DtansOperator::new(enc);
    let engine = SpmvEngine::auto();
    let mut y_par = vec![0.0; a.nrows];
    let t0 = std::time::Instant::now();
    engine.run(&op, &x, &mut y_par)?;
    let dt_par = t0.elapsed().as_secs_f64();
    assert_eq!(y_par, y, "parallel engine must be bit-identical");
    println!(
        "engine: {:.2} ms on {} threads ({:.2}x over serial)",
        dt_par * 1e3,
        engine.nthreads(),
        dt / dt_par
    );
    println!("OK");
    Ok(())
}
