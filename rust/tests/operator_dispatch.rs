//! Property tests for the format-agnostic `dyn SpmvOperator` surface,
//! pinning the redesign's central contract: for **all six built-in
//! formats** (CSR, COO, SELL, BlockedELL, dense, CSR-dtANS) and every
//! partition count in 1..=16, the engine's trait path is **bit-identical**
//! to that format's legacy free-function kernel — not merely numerically
//! close. Also pinned: batched `run_multi` over a contiguous [`DenseMat`]
//! matches repeated single-vector multiplies bitwise, for every format.

use dtans::format::csr_dtans::{CsrDtans, EncodeOptions};
use dtans::matrix::csr::Csr;
use dtans::matrix::gen::structured::{banded, powerlaw_rows, stencil2d5};
use dtans::matrix::gen::{assign_values, gen_graph_csr, GraphModel, ValueDist};
use dtans::matrix::{BlockedEll, Sell};
use dtans::spmv::engine::{ParStrategy, SpmvEngine};
use dtans::spmv::operator::FormatRegistry;
use dtans::spmv::{
    spmv_blocked_ell, spmv_coo, spmv_csr, spmv_csr_dtans, spmv_dense, spmv_sell, DenseMat,
};
use dtans::util::propcheck::{check, Ctx};

/// Random sparse matrix mixing graph and structured families, with value
/// palettes that exercise both the dictionary and escape paths.
fn random_csr(ctx: &mut Ctx) -> Csr {
    let n = 1 + ctx.rng.below_usize(ctx.size.max(1));
    let mut m = match ctx.rng.below(4) {
        0 => gen_graph_csr(GraphModel::ErdosRenyi, n.max(4), 4.0, &mut ctx.rng),
        1 => powerlaw_rows(n.max(4), 5.0, 1.1, &mut ctx.rng),
        2 => banded(n.max(2), 1 + ctx.rng.below_usize(4)),
        _ => {
            let side = 2 + ctx.rng.below_usize((n as f64).sqrt() as usize + 2);
            stencil2d5(side, side)
        }
    };
    let dist = match ctx.rng.below(3) {
        0 => ValueDist::FewDistinct(6),
        1 => ValueDist::Gaussian,
        _ => ValueDist::Quantized(64),
    };
    assign_values(&mut m, dist, &mut ctx.rng);
    m
}

fn random_x(ctx: &mut Ctx, n: usize) -> Vec<f64> {
    (0..n).map(|_| ctx.rng.next_f64() - 0.5).collect()
}

/// The legacy free-function kernel for one format tag, starting from `y0`
/// (the `+=` contract). This is the pre-redesign entry point each
/// operator must reproduce bit-for-bit.
fn legacy_kernel(
    tag: &str,
    m: &Csr,
    opts: &EncodeOptions,
    x: &[f64],
    y0: &[f64],
) -> Result<Vec<f64>, String> {
    let mut y = y0.to_vec();
    match tag {
        "csr" => spmv_csr(m, x, &mut y),
        "coo" => spmv_coo(&m.to_coo(), x, &mut y),
        "sell" => spmv_sell(&Sell::from_csr(m, 32), x, &mut y),
        "blocked_ell" => spmv_blocked_ell(&BlockedEll::from_csr_default(m), x, &mut y),
        "dense" => spmv_dense(&m.to_dense(), m.nrows, m.ncols, x, &mut y),
        "csr_dtans" => {
            let enc = CsrDtans::encode(m, opts).map_err(|e| e.to_string())?;
            spmv_csr_dtans(&enc, x, &mut y)
        }
        other => return Err(format!("no legacy kernel for tag {other}")),
    }
    .map_err(|e| e.to_string())?;
    Ok(y)
}

#[test]
fn prop_dyn_engine_bit_identical_to_legacy_kernels_all_formats() {
    // Engines are reusable; build the 16 partition counts once.
    let engines: Vec<SpmvEngine> =
        (1..=16).map(|p| SpmvEngine::new(ParStrategy::Fixed(p))).collect();
    check("operator-dyn-bitident", 14, 110, |ctx: &mut Ctx| {
        let m = random_csr(ctx);
        let opts = EncodeOptions::default();
        let x = random_x(ctx, m.ncols);
        // Nonzero initial y exercises the += contract.
        let y0: Vec<f64> = (0..m.nrows).map(|i| (i as f64) * 0.0625 - 1.0).collect();
        let built = FormatRegistry::builtin().build_all(&m, &opts);
        if built.len() != 6 {
            return Err(format!("expected 6 builtin formats, got {}", built.len()));
        }
        for (tag, op) in built {
            // Test matrices are small; every builder (dense included)
            // must succeed.
            let op = op.map_err(|e| format!("{tag}: build failed: {e}"))?;
            if op.format_tag() != tag {
                return Err(format!("{tag}: operator reports {}", op.format_tag()));
            }
            let want = legacy_kernel(tag, &m, &opts, &x, &y0)?;
            for (engine, parts) in engines.iter().zip(1usize..) {
                let mut got = y0.clone();
                engine
                    .run(op.as_ref(), &x, &mut got)
                    .map_err(|e| format!("{tag}: {e}"))?;
                if got != want {
                    return Err(format!("{tag} mismatch at parts={parts}"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_run_multi_matches_repeated_serial_spmv() {
    check("operator-spmm-bitident", 10, 90, |ctx: &mut Ctx| {
        let m = random_csr(ctx);
        let opts = EncodeOptions::default();
        let k = 1 + ctx.rng.below_usize(6);
        let cols: Vec<Vec<f64>> = (0..k).map(|_| random_x(ctx, m.ncols)).collect();
        let xs = DenseMat::from_cols(m.ncols, &cols).map_err(|e| e.to_string())?;
        let parts = 1 + ctx.rng.below_usize(16);
        let engine = SpmvEngine::new(ParStrategy::Fixed(parts));
        let zeros = vec![0.0; m.nrows];
        for (tag, op) in FormatRegistry::builtin().build_all(&m, &opts) {
            let op = op.map_err(|e| format!("{tag}: build failed: {e}"))?;
            let ys = engine
                .run_multi(op.as_ref(), &xs)
                .map_err(|e| format!("{tag}: {e}"))?;
            for (j, (x, y)) in cols.iter().zip(ys.into_cols()).enumerate() {
                let want = legacy_kernel(tag, &m, &opts, x, &zeros)?;
                if y != want {
                    return Err(format!("{tag} run_multi rhs {j} mismatch (parts {parts})"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn dyn_engine_handles_degenerate_shapes() {
    // Empty matrix, zero right-hand sides, and a single trailing nonzero:
    // every format through every partition count, no panics, exact
    // results.
    let mut coo_tail = dtans::matrix::coo::Coo::new(65, 65);
    coo_tail.push(64, 64, 2.0);
    let cases = vec![Csr::new(0, 0), Csr::new(40, 40), Csr::from_coo(&coo_tail)];
    let opts = EncodeOptions::default();
    for m in &cases {
        let x = vec![1.0; m.ncols];
        let y0 = vec![0.5; m.nrows];
        for (tag, op) in FormatRegistry::builtin().build_all(m, &opts) {
            let op = op.expect(tag);
            let want = legacy_kernel(tag, m, &opts, &x, &y0).unwrap();
            for parts in [1usize, 3, 16] {
                let engine = SpmvEngine::new(ParStrategy::Fixed(parts));
                let mut got = vec![0.5; m.nrows];
                engine.run(op.as_ref(), &x, &mut got).unwrap();
                assert_eq!(got, want, "{tag} parts={parts}");
                // k = 0 batched call: shape (nrows, 0), no work, no panic.
                let ys = engine.run_multi(op.as_ref(), &DenseMat::zeros(m.ncols, 0)).unwrap();
                assert_eq!(ys.ncols(), 0);
            }
        }
    }
}

#[test]
fn dyn_engine_rejects_dimension_mismatch_for_every_format() {
    let m = banded(30, 2);
    let opts = EncodeOptions::default();
    let x_bad = vec![0.0; m.ncols + 1];
    for (tag, op) in FormatRegistry::builtin().build_all(&m, &opts) {
        let op = op.expect(tag);
        let engine = SpmvEngine::new(ParStrategy::Fixed(4));
        let mut y = vec![0.0; m.nrows];
        assert!(
            engine.run(op.as_ref(), &x_bad, &mut y).is_err(),
            "{tag} accepted a bad x"
        );
        assert!(
            engine.run_multi(op.as_ref(), &DenseMat::zeros(m.ncols + 1, 2)).is_err(),
            "{tag} accepted a bad batch"
        );
    }
}
