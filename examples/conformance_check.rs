//! Run the testkit's differential conformance oracle over the curated
//! pathological fixture zoo and a corpus sample, printing one line per
//! matrix — a quick health check that every registered format agrees
//! with the serial CSR ground truth under every partition strategy.
//!
//! ```sh
//! cargo run --release --example conformance_check
//! ```

use dtans::eval::{build_corpus, CorpusScale};
use dtans::testkit::oracle::{check_matrix, OracleConfig};
use dtans::testkit::zoo;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let cfg = OracleConfig::default();
    println!(
        "{:<28} {:>8} {:>8} {:>9} {:>8} {:>10}",
        "matrix", "rows", "nnz", "formats", "skipped", "mismatches"
    );

    let mut total_mismatches = 0usize;
    let mut checked = 0usize;
    let corpus = build_corpus(&CorpusScale { max_nnz: 3000, steps: 2 }, 17);
    let named: Vec<(String, dtans::matrix::Csr)> = zoo::pathological()
        .into_iter()
        .map(|f| (f.name.to_string(), f.csr))
        .chain(corpus.into_iter().step_by(5).map(|e| (e.name, e.csr)))
        .collect();

    for (name, m) in named {
        let report = check_matrix(&m, &cfg)?;
        println!(
            "{:<28} {:>8} {:>8} {:>9} {:>8} {:>10}",
            name,
            m.nrows,
            m.nnz(),
            report.formats.len(),
            report.skipped.len(),
            report.mismatches.len()
        );
        for mm in &report.mismatches {
            println!("    !! {mm}");
        }
        total_mismatches += report.mismatches.len();
        checked += 1;
    }

    println!("\n{checked} matrices checked, {total_mismatches} mismatch(es)");
    assert_eq!(total_mismatches, 0, "conformance oracle found divergences");
    Ok(())
}
