//! SpMVM kernels (`y = A·x + y`, the paper's §III-A semantics) for every
//! format: dense reference, CSR (scalar and vector variants), COO, SELL,
//! and the fused decode+multiply kernel over CSR-dtANS.
//!
//! The classic-format kernels stand in for cuSPARSE's and feed the GPU
//! simulator's cost models; the CSR-dtANS kernel is the paper's
//! contribution — SpMVM interleaved with on-the-fly entropy decoding.

pub mod coo;
pub mod csr;
pub mod csr_dtans;
pub mod dense;
pub mod sell;
pub mod verify;

pub use coo::spmv_coo;
pub use csr::{spmv_csr, spmv_csr_vector};
pub use csr_dtans::spmv_csr_dtans;
pub use dense::spmv_dense;
pub use sell::spmv_sell;

use crate::util::error::{DtansError, Result};

/// Check `x`/`y` lengths against a matrix shape.
pub(crate) fn check_dims(nrows: usize, ncols: usize, x: &[f64], y: &[f64]) -> Result<()> {
    if x.len() != ncols || y.len() != nrows {
        return Err(DtansError::Dimension(format!(
            "matrix {nrows}x{ncols} with x[{}], y[{}]",
            x.len(),
            y.len()
        )));
    }
    Ok(())
}
