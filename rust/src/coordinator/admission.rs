//! Admission control for the serving core: a bounded, priority-laned
//! request queue with per-tenant token-bucket quotas and cross-request
//! coalescing.
//!
//! The paper's economics — one dtANS decode amortized over many
//! multiplies — only pay off in serving if concurrent requests for the
//! same matrix actually reach the engine as one SpMM batch. The old
//! dispatcher batched only *consecutive* queued requests over an
//! unbounded mpsc channel; this module replaces that front half with an
//! [`AdmissionQueue`]:
//!
//! * **Bounded depth** — [`AdmissionQueue::push`] rejects with a typed
//!   [`DtansError::Overloaded`] once [`AdmissionConfig::queue_depth`]
//!   requests are waiting, instead of growing without bound. Shedding at
//!   submit time is the backpressure contract: the caller knows
//!   immediately, and no shed request ever holds memory or a store pin.
//! * **Priority lanes** — three strict-priority FIFO lanes
//!   ([`Priority::High`]/[`Priority::Normal`]/[`Priority::Low`]).
//!   Dispatch always starts from the oldest request of the highest
//!   non-empty lane; within a lane, order is FIFO.
//! * **Per-tenant quotas** — optional token buckets keyed by
//!   [`SubmitOptions::tenant`]: each admitted request spends one token,
//!   buckets refill at [`QuotaConfig::refill_per_sec`] up to
//!   [`QuotaConfig::burst`]. A tenant with an empty bucket is shed with
//!   [`DtansError::QuotaExceeded`]; tenants without a configured bucket
//!   (and tenant-less requests) are never quota-limited.
//! * **Cross-request coalescing** — [`AdmissionQueue::take_batch`]
//!   gathers **all** queued requests targeting the dispatch target's
//!   matrix, across every lane and regardless of interleaving — not just
//!   a consecutive run. An optional [`AdmissionConfig::gather_window`]
//!   lets the dispatcher linger briefly so a same-matrix burst arriving
//!   over a few microseconds still lands in one decode-amortized SpMM
//!   batch.
//!
//! Deadlines ([`SubmitOptions::deadline`]) are *carried* here but
//! deliberately **not** checked at push: the single expiry point is the
//! dispatcher, immediately before execution, so "expired requests are
//! rejected before execution" is one rule with one clock reading (and a
//! request whose deadline is `Instant::now()` at submit is *guaranteed*
//! to be expired at any later dispatch — the property the deterministic
//! test suite builds on).
//!
//! The queue also exposes a **pause/resume gate**
//! ([`AdmissionQueue::pause`]): while paused, pushes are admitted but
//! `take_batch` blocks, so a test can stage an exact queue state and then
//! release the dispatcher — no sleeps-as-synchronization anywhere.
//! [`AdmissionQueue::close`] overrides the gate: a closing service drains
//! whatever is queued (paused or not) and then `take_batch` returns
//! `None`.

use crate::util::error::{DtansError, Result};
use std::collections::{HashMap, VecDeque};
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// Request priority: strict ordering between lanes, FIFO within a lane.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Default)]
pub enum Priority {
    /// Dispatched before everything else.
    High,
    /// The default lane.
    #[default]
    Normal,
    /// Dispatched only when the other lanes are empty.
    Low,
}

impl Priority {
    /// Lane index (0 = highest).
    fn lane(self) -> usize {
        match self {
            Priority::High => 0,
            Priority::Normal => 1,
            Priority::Low => 2,
        }
    }
}

/// Per-request admission options; `Default` is "no deadline, normal
/// priority, no tenant" — exactly the old `submit` behavior.
#[derive(Debug, Clone, Default)]
pub struct SubmitOptions {
    /// Reject (with [`DtansError::DeadlineExceeded`]) any request whose
    /// deadline has passed when the dispatcher picks it up — checked
    /// once, immediately before execution, never at submit.
    pub deadline: Option<Instant>,
    /// Scheduling lane.
    pub priority: Priority,
    /// Tenant key for quota accounting; `None` bypasses quotas.
    pub tenant: Option<String>,
}

/// Token-bucket quota for one tenant.
#[derive(Debug, Clone, Copy)]
pub struct QuotaConfig {
    /// Bucket capacity: the largest burst admitted at once. Buckets
    /// start full.
    pub burst: f64,
    /// Sustained refill rate, tokens per second. `0.0` makes the bucket
    /// a fixed budget of `burst` admissions — the deterministic setting
    /// the quota tests use.
    pub refill_per_sec: f64,
}

/// Admission-control knobs for the serving core.
#[derive(Debug, Clone)]
pub struct AdmissionConfig {
    /// Maximum queued (admitted, not yet dispatched) requests before
    /// [`AdmissionQueue::push`] sheds with [`DtansError::Overloaded`].
    pub queue_depth: usize,
    /// How long the dispatcher lingers after picking a dispatch target,
    /// gathering late-arriving same-matrix requests into the batch.
    /// `Duration::ZERO` (the default) dispatches immediately; a few
    /// hundred microseconds trades that much added latency for more
    /// coalescing under bursty open-loop load.
    pub gather_window: Duration,
    /// Per-tenant token buckets, keyed by [`SubmitOptions::tenant`].
    /// Tenants not listed here are not quota-limited.
    pub quotas: Vec<(String, QuotaConfig)>,
}

impl Default for AdmissionConfig {
    fn default() -> Self {
        AdmissionConfig {
            queue_depth: 1024,
            gather_window: Duration::ZERO,
            quotas: Vec::new(),
        }
    }
}

/// One admitted request, as handed to the dispatcher.
#[derive(Debug)]
pub struct Admitted<T> {
    /// Target matrix id — the coalescing key.
    pub matrix: u64,
    /// Deadline carried from [`SubmitOptions`]; the dispatcher rejects
    /// the request if `deadline <= now` at dispatch time.
    pub deadline: Option<Instant>,
    /// Scheduling lane the request was admitted into.
    pub priority: Priority,
    /// When the request entered the queue — stamped under the push lock,
    /// so `enqueued.elapsed()` at dispatch is the exact queue wait
    /// (recorded as the `Queued` span stage and the `queue_wait`
    /// histogram; see `docs/OBSERVABILITY.md`).
    pub enqueued: Instant,
    /// The caller's payload (input vector + response channel, for the
    /// service).
    pub payload: T,
}

/// A tenant's bucket: current tokens and the last refill instant.
#[derive(Debug)]
struct Bucket {
    tokens: f64,
    last: Instant,
    cfg: QuotaConfig,
}

impl Bucket {
    /// Spend one token if available, refilling lazily first.
    fn admit(&mut self, now: Instant) -> bool {
        let dt = now.saturating_duration_since(self.last).as_secs_f64();
        self.last = now;
        self.tokens = (self.tokens + dt * self.cfg.refill_per_sec).min(self.cfg.burst);
        if self.tokens >= 1.0 {
            self.tokens -= 1.0;
            true
        } else {
            false
        }
    }
}

/// Everything behind the mutex: the three lanes, the quota buckets, and
/// the gate/lifecycle flags.
#[derive(Debug)]
struct State<T> {
    lanes: [VecDeque<Admitted<T>>; 3],
    len: usize,
    closed: bool,
    paused: bool,
    buckets: HashMap<String, Bucket>,
}

/// The bounded, priority-laned admission queue (see the [module
/// docs](self)). Generic over the payload so the ordering/coalescing
/// logic is directly unit-testable without spinning up a service.
#[derive(Debug)]
pub struct AdmissionQueue<T> {
    queue_depth: usize,
    gather_window: Duration,
    state: Mutex<State<T>>,
    cv: Condvar,
}

impl<T> AdmissionQueue<T> {
    /// Build a queue from `cfg`; quota buckets start full.
    pub fn new(cfg: &AdmissionConfig) -> AdmissionQueue<T> {
        let now = Instant::now();
        let buckets = cfg
            .quotas
            .iter()
            .map(|(tenant, q)| {
                (tenant.clone(), Bucket { tokens: q.burst, last: now, cfg: *q })
            })
            .collect();
        AdmissionQueue {
            queue_depth: cfg.queue_depth,
            gather_window: cfg.gather_window,
            state: Mutex::new(State {
                lanes: [VecDeque::new(), VecDeque::new(), VecDeque::new()],
                len: 0,
                closed: false,
                paused: false,
                buckets,
            }),
            cv: Condvar::new(),
        }
    }

    /// Admit one request, or shed it with a typed error:
    /// [`DtansError::QueueClosed`] after [`AdmissionQueue::close`],
    /// [`DtansError::Overloaded`] at capacity,
    /// [`DtansError::QuotaExceeded`] on an empty tenant bucket (checked
    /// in that order, so a full queue never drains quota tokens).
    /// Returns the queue depth *including* the new request.
    pub fn push(&self, matrix: u64, opts: &SubmitOptions, payload: T) -> Result<usize> {
        let mut s = self.state.lock().unwrap();
        if s.closed {
            return Err(DtansError::QueueClosed);
        }
        if s.len >= self.queue_depth {
            return Err(DtansError::Overloaded { queue_depth: self.queue_depth });
        }
        if let Some(tenant) = &opts.tenant {
            if let Some(b) = s.buckets.get_mut(tenant) {
                if !b.admit(Instant::now()) {
                    return Err(DtansError::QuotaExceeded { tenant: tenant.clone() });
                }
            }
        }
        s.lanes[opts.priority.lane()].push_back(Admitted {
            matrix,
            deadline: opts.deadline,
            priority: opts.priority,
            enqueued: Instant::now(),
            payload,
        });
        s.len += 1;
        let depth = s.len;
        drop(s);
        self.cv.notify_all();
        Ok(depth)
    }

    /// Block until work is available (or the queue closes empty), then
    /// return one coalesced batch: the oldest request of the highest
    /// non-empty lane plus **every** other queued request for the same
    /// matrix, across all lanes, up to `max_batch`. If a gather window
    /// is configured and the batch is not full, lingers up to the window
    /// collecting late same-matrix arrivals. Returns `None` only when
    /// the queue is closed and fully drained.
    ///
    /// While [paused](AdmissionQueue::pause), blocks even if work is
    /// queued — unless the queue has closed, which always drains.
    pub fn take_batch(&self, max_batch: usize) -> Option<Vec<Admitted<T>>> {
        self.take_batch_depth(max_batch).map(|(batch, _)| batch)
    }

    /// [`AdmissionQueue::take_batch`] plus the **residual queue depth**,
    /// read under the same lock that finished the extraction. The pair is
    /// therefore consistent: `depth` is exactly what remained queued the
    /// instant this batch was carved out, with no window for a concurrent
    /// `push` to skew the gauge between dequeue and measurement.
    pub fn take_batch_depth(&self, max_batch: usize) -> Option<(Vec<Admitted<T>>, usize)> {
        let max_batch = max_batch.max(1);
        let mut s = self.state.lock().unwrap();
        loop {
            if s.len > 0 && (!s.paused || s.closed) {
                break;
            }
            if s.closed {
                return None; // closed and drained
            }
            s = self.cv.wait(s).unwrap();
        }
        let target = s
            .lanes
            .iter()
            .find_map(|lane| lane.front().map(|r| r.matrix))
            .expect("len > 0 implies a non-empty lane");
        let mut batch = Vec::new();
        Self::extract(&mut s, target, max_batch, &mut batch);
        if self.gather_window > Duration::ZERO {
            let until = Instant::now() + self.gather_window;
            while !s.closed && batch.len() < max_batch {
                let now = Instant::now();
                if now >= until {
                    break;
                }
                let (guard, _) = self.cv.wait_timeout(s, until - now).unwrap();
                s = guard;
                Self::extract(&mut s, target, max_batch, &mut batch);
            }
        }
        let depth = s.len;
        Some((batch, depth))
    }

    /// Move every queued request for `target` (highest lane first, FIFO
    /// within a lane) into `out`, up to `max_batch` total.
    fn extract(s: &mut State<T>, target: u64, max_batch: usize, out: &mut Vec<Admitted<T>>) {
        let before = out.len();
        for lane in s.lanes.iter_mut() {
            if out.len() >= max_batch {
                break;
            }
            let mut keep = VecDeque::with_capacity(lane.len());
            while let Some(r) = lane.pop_front() {
                if r.matrix == target && out.len() < max_batch {
                    out.push(r);
                } else {
                    keep.push_back(r);
                }
            }
            *lane = keep;
        }
        s.len -= out.len() - before;
    }

    /// Gate the dispatcher: subsequent [`AdmissionQueue::take_batch`]
    /// calls block (submissions are still admitted) until
    /// [`AdmissionQueue::resume`]. The deterministic test hook — stage an
    /// exact queue state, then release it in one step.
    pub fn pause(&self) {
        self.state.lock().unwrap().paused = true;
        self.cv.notify_all();
    }

    /// Release the [`AdmissionQueue::pause`] gate.
    pub fn resume(&self) {
        self.state.lock().unwrap().paused = false;
        self.cv.notify_all();
    }

    /// Close the queue: subsequent pushes fail with
    /// [`DtansError::QueueClosed`]; `take_batch` drains what is queued
    /// (even while paused) and then returns `None`.
    pub fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.cv.notify_all();
    }

    /// Requests currently queued (admitted, not yet dispatched).
    pub fn len(&self) -> usize {
        self.state.lock().unwrap().len
    }

    /// Whether the queue is currently empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(depth: usize) -> AdmissionConfig {
        AdmissionConfig { queue_depth: depth, ..Default::default() }
    }

    fn push_ok(q: &AdmissionQueue<u32>, matrix: u64, opts: &SubmitOptions, payload: u32) {
        q.push(matrix, opts, payload).unwrap();
    }

    #[test]
    fn bounded_depth_sheds_with_typed_overloaded() {
        let q: AdmissionQueue<u32> = AdmissionQueue::new(&cfg(3));
        for i in 0..3 {
            assert_eq!(q.push(7, &SubmitOptions::default(), i).unwrap(), i as usize + 1);
        }
        match q.push(7, &SubmitOptions::default(), 99) {
            Err(DtansError::Overloaded { queue_depth: 3 }) => {}
            other => panic!("expected Overloaded, got {other:?}"),
        }
        assert_eq!(q.len(), 3);
        // Draining frees capacity again.
        let batch = q.take_batch(16).unwrap();
        assert_eq!(batch.len(), 3);
        assert_eq!(q.push(7, &SubmitOptions::default(), 4).unwrap(), 1);
    }

    #[test]
    fn strict_priority_then_fifo_within_lane() {
        let q: AdmissionQueue<u32> = AdmissionQueue::new(&cfg(16));
        let with = |p: Priority| SubmitOptions { priority: p, ..Default::default() };
        // Distinct matrices so every take_batch returns exactly one
        // request and the pop order is fully observable.
        push_ok(&q, 0, &with(Priority::Low), 0);
        push_ok(&q, 1, &with(Priority::High), 1);
        push_ok(&q, 2, &with(Priority::Normal), 2);
        push_ok(&q, 3, &with(Priority::High), 3);
        push_ok(&q, 4, &with(Priority::Low), 4);
        push_ok(&q, 5, &with(Priority::Normal), 5);
        let mut order = Vec::new();
        for _ in 0..6 {
            let batch = q.take_batch(16).unwrap();
            assert_eq!(batch.len(), 1);
            order.push(batch[0].payload);
        }
        assert_eq!(order, vec![1, 3, 2, 5, 0, 4]);
    }

    #[test]
    fn coalesces_same_matrix_across_lanes_and_interleavings() {
        let q: AdmissionQueue<u32> = AdmissionQueue::new(&cfg(16));
        let with = |p: Priority| SubmitOptions { priority: p, ..Default::default() };
        // A and B interleaved, A spread over all three lanes.
        push_ok(&q, 10, &with(Priority::Low), 0);
        push_ok(&q, 20, &with(Priority::Normal), 1);
        push_ok(&q, 10, &with(Priority::Normal), 2);
        push_ok(&q, 20, &with(Priority::Normal), 3);
        push_ok(&q, 10, &with(Priority::High), 4);
        // Highest non-empty lane fronts matrix 10 -> the whole batch is
        // matrix 10, gathered across lanes in priority-then-FIFO order.
        let batch = q.take_batch(16).unwrap();
        assert_eq!(batch.iter().map(|r| r.matrix).collect::<Vec<_>>(), vec![10, 10, 10]);
        assert_eq!(batch.iter().map(|r| r.payload).collect::<Vec<_>>(), vec![4, 2, 0]);
        // The other matrix's requests kept their FIFO order.
        let batch = q.take_batch(16).unwrap();
        assert_eq!(batch.iter().map(|r| r.payload).collect::<Vec<_>>(), vec![1, 3]);
        assert!(q.is_empty());
    }

    #[test]
    fn max_batch_caps_a_coalesced_gather() {
        let q: AdmissionQueue<u32> = AdmissionQueue::new(&cfg(16));
        for i in 0..5 {
            push_ok(&q, 1, &SubmitOptions::default(), i);
        }
        let batch = q.take_batch(3).unwrap();
        assert_eq!(batch.iter().map(|r| r.payload).collect::<Vec<_>>(), vec![0, 1, 2]);
        let batch = q.take_batch(3).unwrap();
        assert_eq!(batch.iter().map(|r| r.payload).collect::<Vec<_>>(), vec![3, 4]);
    }

    #[test]
    fn quota_bucket_is_a_fixed_budget_at_zero_refill() {
        let q: AdmissionQueue<u32> = AdmissionQueue::new(&AdmissionConfig {
            queue_depth: 16,
            quotas: vec![("acme".into(), QuotaConfig { burst: 2.0, refill_per_sec: 0.0 })],
            ..Default::default()
        });
        let acme = SubmitOptions { tenant: Some("acme".into()), ..Default::default() };
        q.push(1, &acme, 0).unwrap();
        q.push(1, &acme, 1).unwrap();
        match q.push(1, &acme, 2) {
            Err(DtansError::QuotaExceeded { tenant }) => assert_eq!(tenant, "acme"),
            other => panic!("expected QuotaExceeded, got {other:?}"),
        }
        // Unconfigured tenants and tenant-less requests are unlimited.
        let other = SubmitOptions { tenant: Some("other".into()), ..Default::default() };
        q.push(1, &other, 3).unwrap();
        q.push(1, &SubmitOptions::default(), 4).unwrap();
        assert_eq!(q.len(), 4);
    }

    #[test]
    fn closed_queue_rejects_pushes_and_drains_batches() {
        let q: AdmissionQueue<u32> = AdmissionQueue::new(&cfg(8));
        push_ok(&q, 1, &SubmitOptions::default(), 0);
        push_ok(&q, 2, &SubmitOptions::default(), 1);
        q.close();
        assert!(matches!(
            q.push(1, &SubmitOptions::default(), 9),
            Err(DtansError::QueueClosed)
        ));
        // Drain continues after close — even under a pause gate.
        q.pause();
        assert_eq!(q.take_batch(8).unwrap().len(), 1);
        assert_eq!(q.take_batch(8).unwrap().len(), 1);
        assert!(q.take_batch(8).is_none());
        assert!(q.take_batch(8).is_none());
    }

    #[test]
    fn pause_gates_take_batch_but_not_push() {
        use std::sync::atomic::{AtomicBool, Ordering};
        use std::sync::Arc;
        let q: Arc<AdmissionQueue<u32>> = Arc::new(AdmissionQueue::new(&cfg(8)));
        q.pause();
        push_ok(&q, 1, &SubmitOptions::default(), 0);
        push_ok(&q, 1, &SubmitOptions::default(), 1);
        assert_eq!(q.len(), 2);
        let took = Arc::new(AtomicBool::new(false));
        let h = {
            let q = Arc::clone(&q);
            let took = Arc::clone(&took);
            std::thread::spawn(move || {
                let batch = q.take_batch(8).unwrap();
                took.store(true, Ordering::SeqCst);
                batch.len()
            })
        };
        // The taker is blocked on the gate; resuming releases exactly
        // the staged state as one coalesced batch. (No sleep needed for
        // correctness: `took` may only flip after resume, which is what
        // we assert via the join result; the gate itself is what makes
        // the batch contents deterministic.)
        assert!(!took.load(Ordering::SeqCst) || q.len() == 0);
        q.resume();
        assert_eq!(h.join().unwrap(), 2);
        assert!(took.load(Ordering::SeqCst));
        assert!(q.is_empty());
    }

    #[test]
    fn gather_window_collects_late_same_matrix_arrivals() {
        use std::sync::Arc;
        let q: Arc<AdmissionQueue<u32>> = Arc::new(AdmissionQueue::new(&AdmissionConfig {
            queue_depth: 16,
            gather_window: Duration::from_millis(200),
            ..Default::default()
        }));
        push_ok(&q, 1, &SubmitOptions::default(), 0);
        let pusher = {
            let q = Arc::clone(&q);
            std::thread::spawn(move || {
                // Lands inside the taker's window; the push itself
                // signals the condvar, so the window picks it up without
                // polling. (This is an upper-bound race only: if the
                // window somehow elapsed first, the assert below catches
                // it by count.)
                q.push(1, &SubmitOptions::default(), 1).unwrap();
            })
        };
        let batch = q.take_batch(16).unwrap();
        pusher.join().unwrap();
        // Either the push beat the gather (2) or — on a pathologically
        // slow machine — missed a 200ms window (1, still correct: the
        // request is simply in the next batch).
        assert!(!batch.is_empty());
        assert_eq!(batch.len() + q.len(), 2);
    }

    #[test]
    fn take_batch_depth_reports_the_residual_under_the_lock() {
        let q: AdmissionQueue<u32> = AdmissionQueue::new(&cfg(16));
        for i in 0..5 {
            push_ok(&q, 1, &SubmitOptions::default(), i);
        }
        // 5 queued, carve 3 -> 2 remain; the depth rides along with the
        // batch instead of being re-read after the lock is dropped.
        let (batch, depth) = q.take_batch_depth(3).unwrap();
        assert_eq!(batch.len(), 3);
        assert_eq!(depth, 2);
        let (batch, depth) = q.take_batch_depth(3).unwrap();
        assert_eq!(batch.len(), 2);
        assert_eq!(depth, 0);
        assert!(q.is_empty());
    }

    #[test]
    fn admitted_requests_carry_an_enqueue_stamp() {
        let q: AdmissionQueue<u32> = AdmissionQueue::new(&cfg(16));
        let before = Instant::now();
        push_ok(&q, 1, &SubmitOptions::default(), 0);
        let batch = q.take_batch(16).unwrap();
        // Stamped inside push: between our `before` and dispatch time,
        // so `enqueued.elapsed()` is a valid queue-wait measurement.
        assert!(batch[0].enqueued >= before);
        assert!(batch[0].enqueued <= Instant::now());
    }
}
