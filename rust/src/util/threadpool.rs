//! A small fixed-size thread pool with a shared work queue.
//!
//! Used by the coordinator's worker pool and by the evaluation harness to
//! parallelize over corpus matrices (tokio/rayon are not available offline).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Fixed-size thread pool; jobs are `FnOnce()` closures.
pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
    pending: Arc<AtomicUsize>,
}

impl ThreadPool {
    /// Spawn `n` worker threads (at least 1).
    pub fn new(n: usize) -> Self {
        let n = n.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let pending = Arc::new(AtomicUsize::new(0));
        let workers = (0..n)
            .map(|_| {
                let rx = Arc::clone(&rx);
                let pending = Arc::clone(&pending);
                std::thread::spawn(move || loop {
                    let job = {
                        let guard = rx.lock().unwrap();
                        guard.recv()
                    };
                    match job {
                        Ok(job) => {
                            job();
                            pending.fetch_sub(1, Ordering::SeqCst);
                        }
                        Err(_) => break,
                    }
                })
            })
            .collect();
        ThreadPool {
            tx: Some(tx),
            workers,
            pending,
        }
    }

    /// Number of logical CPUs (fallback 4).
    pub fn default_parallelism() -> usize {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
    }

    /// Submit a job.
    pub fn execute(&self, job: impl FnOnce() + Send + 'static) {
        self.pending.fetch_add(1, Ordering::SeqCst);
        self.tx.as_ref().unwrap().send(Box::new(job)).unwrap();
    }

    /// Busy-wait (with yields) until all submitted jobs completed.
    pub fn wait_idle(&self) {
        while self.pending.load(Ordering::SeqCst) != 0 {
            std::thread::yield_now();
        }
    }

    /// Parallel map over an indexed range, preserving order.
    pub fn par_map<T, F>(&self, n: usize, f: F) -> Vec<T>
    where
        T: Send + 'static,
        F: Fn(usize) -> T + Send + Sync + 'static,
    {
        let f = Arc::new(f);
        let results: Arc<Mutex<Vec<Option<T>>>> =
            Arc::new(Mutex::new((0..n).map(|_| None).collect()));
        for i in 0..n {
            let f = Arc::clone(&f);
            let results = Arc::clone(&results);
            self.execute(move || {
                let v = f(i);
                results.lock().unwrap()[i] = Some(v);
            });
        }
        self.wait_idle();
        Arc::try_unwrap(results)
            .ok()
            .expect("results still shared")
            .into_inner()
            .unwrap()
            .into_iter()
            .map(|x| x.expect("job did not run"))
            .collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn par_map_order_preserved() {
        let pool = ThreadPool::new(4);
        let out = pool.par_map(100, |i| i * i);
        assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn wait_idle_completes() {
        let pool = ThreadPool::new(2);
        let ctr = Arc::new(AtomicUsize::new(0));
        for _ in 0..50 {
            let c = Arc::clone(&ctr);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(ctr.load(Ordering::SeqCst), 50);
    }
}
