//! dtANS codec parameters and the constraints tying them together
//! (§IV-C/D of the paper).

use crate::util::error::{DtansError, Result};

/// Parameters of a dtANS code.
///
/// * `W = 2^w_bits` — radix of the compressed word stream. The paper uses
///   the GPU word size `W = 2^32`.
/// * `K = 2^k_bits` — number of slots in each coding table. The paper uses
///   `K = 4096` so the tables fit in shared memory.
/// * `M = 2^m_bits` — upper bound on per-symbol multiplicity (new in
///   dtANS vs tANS). Small `M` makes more loads unconditional; the paper
///   uses `M = 256` so returned digits fit 8 bits.
/// * `l` — symbols per segment (decoded in parallel). With value+delta
///   interleaving, a segment covers `l/2` nonzeros.
/// * `o` — words consumed per segment; chosen so `K^l = W^o`.
/// * `f` — conditional checks per segment; chosen so `M^l = W^f`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AnsParams {
    /// log2 of the stream word radix W.
    pub w_bits: u32,
    /// log2 of the table size K.
    pub k_bits: u32,
    /// log2 of the multiplicity cap M.
    pub m_bits: u32,
    /// Symbols per segment.
    pub l: u32,
    /// Words per segment.
    pub o: u32,
    /// Conditional checks per segment.
    pub f: u32,
}

impl AnsParams {
    /// The paper's CSR-dtANS parameters: `W=2^32, K=4096, M=256, l=8, o=3,
    /// f=2` — 4 nonzeros per segment, both constraint inequalities tight.
    pub const PAPER: AnsParams = AnsParams {
        w_bits: 32,
        k_bits: 12,
        m_bits: 8,
        l: 8,
        o: 3,
        f: 2,
    };

    /// Scaled-down parameters for the Pallas kernel (all arithmetic fits
    /// i64, which the TPU/interpret path handles natively): `W=2^16,
    /// K=4096, M=256, l=4, o=3, f=2` — 2 nonzeros per segment, both
    /// constraints again tight.
    pub const KERNEL: AnsParams = AnsParams {
        w_bits: 16,
        k_bits: 12,
        m_bits: 8,
        l: 4,
        o: 3,
        f: 2,
    };

    /// A tiny configuration mirroring the paper's worked example machine
    /// (word size 2 bits, K=8, M=4, l=2, o=3, f=2) — used in tests to stay
    /// close to §IV-D.
    pub const TOY: AnsParams = AnsParams {
        w_bits: 2,
        k_bits: 3,
        m_bits: 2,
        l: 2,
        o: 3,
        f: 2,
    };

    /// Word radix W.
    #[inline]
    pub fn w(&self) -> u64 {
        1u64 << self.w_bits
    }

    /// Table size K.
    #[inline]
    pub fn k(&self) -> u32 {
        1u32 << self.k_bits
    }

    /// Multiplicity cap M.
    #[inline]
    pub fn m(&self) -> u32 {
        1u32 << self.m_bits
    }

    /// Digits per group (`l / f`): each group is accumulated into a single
    /// ≤ W digit/base pair before being pushed onto the state.
    #[inline]
    pub fn group_size(&self) -> u32 {
        self.l / self.f
    }

    /// Validate the constraint system.
    pub fn validate(&self) -> Result<()> {
        let err = |m: String| Err(DtansError::InvalidParams(m));
        if self.w_bits == 0 || self.w_bits > 32 {
            return err(format!("w_bits {} out of range [1,32]", self.w_bits));
        }
        if self.k_bits == 0 || self.k_bits > 16 {
            return err(format!("k_bits {} out of range [1,16]", self.k_bits));
        }
        if self.m_bits == 0 || self.m_bits > self.k_bits || self.m_bits > 8 {
            // m_bits ≤ 8 keeps `base - 1` in one byte (the packed-slot and
            // decremented-radix layout of §IV-F).
            return err(format!("m_bits {} out of range [1, min(k_bits, 8)]", self.m_bits));
        }
        if self.l == 0 || self.f == 0 || self.o == 0 {
            return err("l, o, f must be positive".into());
        }
        if self.f > self.o {
            return err(format!("f={} may not exceed o={}", self.f, self.o));
        }
        if self.l % self.f != 0 {
            return err(format!("l={} must be a multiple of f={}", self.l, self.f));
        }
        // unpack must be a bijection between o words and l slots.
        if self.k_bits * self.l != self.w_bits * self.o {
            return err(format!(
                "K^l must equal W^o (k_bits*l={} vs w_bits*o={})",
                self.k_bits * self.l,
                self.w_bits * self.o
            ));
        }
        // The decoder state must return below W after the f checks.
        if self.m_bits * self.l > self.w_bits * self.f {
            return err(format!(
                "M^l must not exceed W^f (m_bits*l={} vs w_bits*f={})",
                self.m_bits * self.l,
                self.w_bits * self.f
            ));
        }
        // A digit group must fit in one word so the group accumulation is
        // a single multiply-add (the paper's §IV-F "positioning of checks").
        if self.m_bits * self.group_size() > self.w_bits {
            return err(format!(
                "group of {} digits with M=2^{} exceeds one word",
                self.group_size(),
                self.m_bits
            ));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_valid() {
        AnsParams::PAPER.validate().unwrap();
        AnsParams::KERNEL.validate().unwrap();
        AnsParams::TOY.validate().unwrap();
    }

    #[test]
    fn paper_preset_matches_text() {
        let p = AnsParams::PAPER;
        assert_eq!(p.w(), 1 << 32);
        assert_eq!(p.k(), 4096);
        assert_eq!(p.m(), 256);
        assert_eq!((p.l, p.o, p.f), (8, 3, 2));
        assert_eq!(p.group_size(), 4);
    }

    #[test]
    fn rejects_unbalanced_unpack() {
        let mut p = AnsParams::PAPER;
        p.o = 2;
        assert!(p.validate().is_err());
    }

    #[test]
    fn rejects_oversized_m() {
        let mut p = AnsParams::KERNEL;
        p.m_bits = 12; // M^l = 2^48 > W^f = 2^32
        assert!(p.validate().is_err());
    }

    #[test]
    fn rejects_f_gt_o() {
        let mut p = AnsParams::KERNEL;
        p.f = 4;
        assert!(p.validate().is_err());
    }
}
