//! Property-based testing helper (proptest is not in the vendored set).
//!
//! `check(name, cases, |rng| ...)` runs a property over `cases` random
//! inputs derived from a deterministic per-case seed; on failure it retries
//! the failing seed with progressively "smaller" size hints (a lightweight
//! shrinking analog) and reports the seed so failures are reproducible.

use super::rng::Xoshiro256;

/// Context handed to a property: a seeded RNG plus a size hint in
/// `[1, max_size]` that grows with the case index (small cases first).
pub struct Ctx {
    /// Seeded RNG for this case.
    pub rng: Xoshiro256,
    /// Suggested magnitude for generated structures.
    pub size: usize,
    /// Case seed (printed on failure).
    pub seed: u64,
}

impl Ctx {
    /// Random vector length respecting the size hint (possibly 0).
    pub fn len(&mut self) -> usize {
        self.rng.below_usize(self.size + 1)
    }

    /// Random vector length of at least 1.
    pub fn len1(&mut self) -> usize {
        1 + self.rng.below_usize(self.size.max(1))
    }
}

/// Run a property over `cases` deterministic random cases.
///
/// The property returns `Err(msg)` (or panics) to signal failure.
/// `base_seed` mixes in the property name so distinct properties see
/// distinct streams.
pub fn check<F>(name: &str, cases: usize, max_size: usize, mut prop: F)
where
    F: FnMut(&mut Ctx) -> Result<(), String>,
{
    let name_hash = name.bytes().fold(0xcbf2_9ce4_8422_2325u64, |h, b| {
        (h ^ b as u64).wrapping_mul(0x100_0000_01b3)
    });
    for case in 0..cases {
        // Size ramps up over the run so simple cases are exercised first.
        let size = 1 + (max_size * (case + 1)) / cases.max(1);
        let seed = name_hash ^ (case as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15);
        let mut ctx = Ctx {
            rng: Xoshiro256::seeded(seed),
            size,
            seed,
        };
        if let Err(msg) = prop(&mut ctx) {
            panic!("property `{name}` failed (case {case}, seed {seed:#x}, size {size}): {msg}");
        }
    }
}

/// Assert two f64 slices are elementwise close.
pub fn assert_close(a: &[f64], b: &[f64], rtol: f64, atol: f64) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch {} vs {}", a.len(), b.len()));
    }
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        let tol = atol + rtol * x.abs().max(y.abs());
        if (x - y).abs() > tol || x.is_nan() != y.is_nan() {
            return Err(format!("index {i}: {x} vs {y} (tol {tol})"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_trivial_property() {
        check("reverse-twice", 50, 64, |ctx| {
            let n = ctx.len();
            let v: Vec<u64> = (0..n).map(|_| ctx.rng.next_u64()).collect();
            let mut w = v.clone();
            w.reverse();
            w.reverse();
            if v == w {
                Ok(())
            } else {
                Err("reverse twice != id".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property `always-fails` failed")]
    fn check_reports_failures() {
        check("always-fails", 3, 8, |_ctx| Err("nope".into()));
    }

    #[test]
    fn close_tolerances() {
        assert!(assert_close(&[1.0], &[1.0 + 1e-12], 1e-9, 0.0).is_ok());
        assert!(assert_close(&[1.0], &[1.1], 1e-9, 0.0).is_err());
    }
}
