//! Dense row-major matrix-vector product — the ground-truth oracle for all
//! sparse kernels (tests only; never used on large matrices).

use crate::util::error::Result;

/// `y += A·x` for dense row-major `a` of shape `nrows × ncols`.
///
/// ```
/// use dtans::spmv::spmv_dense;
/// let a = [1.0, 2.0, 3.0, 4.0]; // [[1, 2], [3, 4]]
/// let mut y = vec![0.0; 2];
/// spmv_dense(&a, 2, 2, &[1.0, 1.0], &mut y).unwrap();
/// assert_eq!(y, vec![3.0, 7.0]);
/// ```
pub fn spmv_dense(a: &[f64], nrows: usize, ncols: usize, x: &[f64], y: &mut [f64]) -> Result<()> {
    super::check_dims(nrows, ncols, x, y)?;
    assert_eq!(a.len(), nrows * ncols);
    spmv_dense_row_range(a, ncols, 0, nrows, x, y)
}

/// Dense kernel over rows `r0..r1`; `y_seg[i]` accumulates row `r0 + i`.
/// The whole-matrix [`spmv_dense`] is the `0..nrows` case and the dense
/// [`SpmvOperator`](crate::spmv::operator::SpmvOperator) fans out disjoint
/// ranges, so both paths share one loop and bit-identical results hold by
/// construction.
pub(crate) fn spmv_dense_row_range(
    a: &[f64],
    ncols: usize,
    r0: usize,
    r1: usize,
    x: &[f64],
    y_seg: &mut [f64],
) -> Result<()> {
    debug_assert_eq!(y_seg.len(), r1 - r0);
    for (i, r) in (r0..r1).enumerate() {
        let row = &a[r * ncols..(r + 1) * ncols];
        let mut acc = 0.0;
        for (av, xv) in row.iter().zip(x) {
            acc += av * xv;
        }
        y_seg[i] += acc;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_product() {
        let a = vec![1.0, 2.0, 3.0, 4.0];
        let x = vec![1.0, -1.0];
        let mut y = vec![10.0, 0.0];
        spmv_dense(&a, 2, 2, &x, &mut y).unwrap();
        assert_eq!(y, vec![10.0 - 1.0, -1.0]);
    }

    #[test]
    fn dim_mismatch() {
        let a = vec![0.0; 4];
        let x = vec![0.0; 3];
        let mut y = vec![0.0; 2];
        assert!(spmv_dense(&a, 2, 2, &x, &mut y).is_err());
    }
}
