//! Whole-system integration: coordinator service over corpus matrices,
//! routing behavior, experiment drivers, and the simulator's qualitative
//! claims at test scale.

use dtans::coordinator::{FormatChoice, RoutePolicy, ServiceConfig, SpmvService};
use dtans::eval::{build_corpus, fig4, fig6, tab1, CorpusScale};
use dtans::format::csr_dtans::{CsrDtans, EncodeOptions};
use dtans::matrix::Precision;
use dtans::sim::{best_baseline, simulate, GpuModel, KernelKind, SimInput};
use dtans::util::rng::Xoshiro256;

#[test]
fn service_serves_whole_corpus_correctly() {
    let corpus = build_corpus(&CorpusScale { max_nnz: 4000, steps: 2 }, 11);
    let svc = SpmvService::start(ServiceConfig {
        workers: 4,
        ..Default::default()
    });
    let mut rng = Xoshiro256::seeded(1);
    let mut cases = Vec::new();
    for e in corpus.iter().take(12) {
        let id = svc.register(&e.name, e.csr.clone()).unwrap();
        let x: Vec<f64> = (0..e.csr.ncols).map(|_| rng.next_f64() - 0.5).collect();
        let mut want = vec![0.0; e.csr.nrows];
        dtans::spmv::spmv_csr(&e.csr, &x, &mut want).unwrap();
        cases.push((id, x, want, e.name.clone()));
    }
    // Interleave submissions across matrices to exercise batch splitting.
    let pendings: Vec<_> = cases
        .iter()
        .cycle()
        .take(3 * cases.len())
        .map(|(id, x, _, _)| svc.submit(*id, x.clone()).unwrap())
        .collect();
    for (i, p) in pendings.into_iter().enumerate() {
        let (_, _, want, name) = &cases[i % cases.len()];
        let got = p.wait().unwrap();
        dtans::util::propcheck::assert_close(&got, want, 1e-10, 1e-12)
            .unwrap_or_else(|e| panic!("{name}: {e}"));
    }
    let s = svc.metrics.latency_summary();
    assert_eq!(s.count, 3 * cases.len());
}

#[test]
fn routing_policy_follows_paper_rule() {
    // Large+compressible -> dtANS; small or incompressible -> CSR.
    let policy = RoutePolicy {
        min_nnz: 1 << 12,
        max_size_ratio: 0.9,
        ..Default::default()
    };
    let opts = EncodeOptions::default();
    let mut rng = Xoshiro256::seeded(2);

    let big = dtans::matrix::gen::structured::banded(10_000, 2);
    let enc = CsrDtans::encode(&big, &opts).unwrap();
    assert_eq!(policy.choose(&big, &enc, &opts), FormatChoice::CsrDtans);

    let small = dtans::matrix::gen::structured::banded(100, 2);
    let enc = CsrDtans::encode(&small, &opts).unwrap();
    assert_eq!(policy.choose(&small, &enc, &opts), FormatChoice::Csr);

    let mut random = dtans::matrix::gen::structured::random_uniform(3000, 3000, 20_000, &mut rng);
    dtans::matrix::gen::assign_values(
        &mut random,
        dtans::matrix::gen::ValueDist::Random,
        &mut rng,
    );
    let enc = CsrDtans::encode(&random, &opts).unwrap();
    assert_eq!(policy.choose(&random, &enc, &opts), FormatChoice::Csr);
}

#[test]
fn experiments_run_and_match_paper_shape_at_test_scale() {
    let out4 = fig4(1 << 12);
    // Delta encoding reduces entropy in (nearly) all graph points.
    let reduced = out4.tables[0]
        .1
        .rows
        .iter()
        .filter(|r| r[3].parse::<f64>().unwrap() < 1.0)
        .count();
    assert_eq!(reduced, out4.tables[0].1.rows.len());

    // Large enough that the nnz>2^15 & annzpr>10 bucket is populated.
    let scale = CorpusScale { max_nnz: 120_000, steps: 3 };
    let out6 = fig6(&scale);
    assert!(out6.summary.contains("best compression"));
    let out1 = tab1(&scale);
    // The headline cell: large matrices with many nnz/row always compress.
    assert!(out1.summary.contains("= 1.00"), "{}", out1.summary);
}

#[test]
fn simulator_reproduces_crossover_shape() {
    // The paper's central claim, at simulator scale: dtANS loses on a tiny
    // matrix and wins on a large compressible one (cold cache, 64-bit).
    let dev = GpuModel::RTX5090;
    let opts = EncodeOptions::default();

    let small = dtans::matrix::gen::structured::banded(300, 4);
    let enc_s = CsrDtans::encode(&small, &opts).unwrap();
    let sell_s = dtans::matrix::Sell::from_csr(&small, 32);
    let inp = SimInput {
        csr: &small,
        sell: Some(&sell_s),
        enc: Some(&enc_s),
        precision: Precision::F64,
    };
    let (_, base) = best_baseline(&inp, &dev, false);
    let dt = simulate(KernelKind::CsrDtans, &inp, &dev, false);
    assert!(dt.time_us > base.time_us, "small matrix must lose");

    let big = dtans::matrix::gen::structured::banded(400_000, 4);
    let enc_b = CsrDtans::encode(&big, &opts).unwrap();
    let sell_b = dtans::matrix::Sell::from_csr(&big, 32);
    let inp = SimInput {
        csr: &big,
        sell: Some(&sell_b),
        enc: Some(&enc_b),
        precision: Precision::F64,
    };
    let (_, base) = best_baseline(&inp, &dev, false);
    let dt = simulate(KernelKind::CsrDtans, &inp, &dev, false);
    assert!(
        dt.time_us < base.time_us,
        "large compressible matrix must win: dtans {} vs base {}",
        dt.time_us,
        base.time_us
    );
    // And the speedup must not exceed the compression factor (the paper's
    // "practically all points lie above the diagonal").
    let model = dtans::matrix::SizeModel { precision: Precision::F64 };
    let (bbytes, _) = model.best_baseline_bytes(&big);
    let compression = bbytes as f64 / enc_b.size_report().total as f64;
    let speedup = base.time_us / dt.time_us;
    assert!(speedup <= compression * 1.05, "speedup {speedup} vs compression {compression}");
}
