//! dtANS — the paper's decoupled tANS codec (§IV-D/E, Algorithm 3).
//!
//! A row of symbols is processed in *segments* of `l` symbols. The decoder
//! keeps `o` buffered words `w[0..o]` and a state `(d, r)`:
//!
//! * `unpack(w)` yields the `l` slots of the current segment (the base-W
//!   number formed by the words re-read in base K);
//! * the slots' digit/base pairs are folded into `(d, r)` group-wise
//!   (`l/f` digits per group, each group ≤ one word by the `M` cap);
//! * after each group a *check* refills one word for the next segment:
//!   if `r ≥ W` a word is **extracted** from the state (no memory access),
//!   otherwise it is **loaded** from the stream; the last `o − f` words are
//!   always loaded;
//! * the final segment of a row performs no pushes/checks at all (§IV-F
//!   "efficient handling of end of row").
//!
//! Symbols at position `p` within a segment belong to domain
//! `p mod ndomains` (CSR-dtANS interleaves delta/value symbols, so
//! `ndomains = 2`); pass a single table for one-domain streams.
//!
//! The encoder reverses the decoder exactly: a forward **base pass**
//! replays `r` alone — bases depend only on symbols, not slots — recording
//! each check's branch; a backward **digit pass** starts from `d = 0`,
//! re-injects extracted words (`d ← d·W + w`), emits loaded words to the
//! stream (built back-to-front), and picks each slot by `digit = d mod
//! base`. The invariant `d < r(forward)` holds at every point of the
//! backward pass (proved by induction over the three inverse operations),
//! so at stream start where `r = 1` the leftover state is exactly 0 — the
//! decoder may therefore start from `(d, r) = (0, 1)` without any stored
//! state, unlike classic ANS.

use super::params::AnsParams;
use super::tables::CodingTables;
use crate::util::error::{DtansError, Result};

/// Output of [`encode_row`].
#[derive(Debug, Clone, PartialEq)]
pub struct RowEncoding {
    /// Words in the order the decoder consumes them (initial `o` words,
    /// then per non-final segment: conditional loads in check order, then
    /// unconditional loads).
    pub words: Vec<u32>,
    /// Branch per check of each non-final segment (`(nseg-1) * f` entries,
    /// segment-major): `true` = extract (no load), `false` = load.
    pub branches: Vec<bool>,
    /// Number of segments (`nsyms / l`).
    pub nseg: usize,
}

#[inline]
fn unpack(p: &AnsParams, w: &[u32], slots: &mut [u32]) {
    let mut n: u128 = 0;
    for &word in w.iter() {
        n = (n << p.w_bits) | word as u128;
    }
    let mask = (p.k() - 1) as u128;
    for (pos, s) in slots.iter_mut().enumerate() {
        *s = ((n >> (p.k_bits as usize * pos)) & mask) as u32;
    }
}

#[inline]
fn pack(p: &AnsParams, slots: &[u32], w: &mut [u32]) {
    let mut n: u128 = 0;
    for (pos, &s) in slots.iter().enumerate() {
        n |= (s as u128) << (p.k_bits as usize * pos);
    }
    let mask = (p.w() - 1) as u128;
    let o = w.len();
    for (k, word) in w.iter_mut().enumerate() {
        *word = ((n >> (p.w_bits as usize * (o - 1 - k))) & mask) as u32;
    }
}

/// Check that symbols are in range for their domain tables and the length
/// is a whole number of segments.
fn validate_syms(p: &AnsParams, tables: &[&CodingTables], syms: &[u16]) -> Result<()> {
    if tables.is_empty() || p.l as usize % tables.len() != 0 {
        return Err(DtansError::InvalidParams(
            "need 1..=l tables with l % ndomains == 0".into(),
        ));
    }
    if syms.len() % p.l as usize != 0 {
        return Err(DtansError::InvalidParams(format!(
            "symbol count {} not a multiple of l={}",
            syms.len(),
            p.l
        )));
    }
    for (i, &s) in syms.iter().enumerate() {
        let t = tables[i % tables.len()];
        if s as usize >= t.num_symbols() {
            return Err(DtansError::InvalidParams(format!(
                "symbol {s} out of range at position {i}"
            )));
        }
    }
    Ok(())
}

/// Encode one row of symbols (`syms.len()` must be a multiple of `l`;
/// the CSR-dtANS layer pads rows before calling this).
pub fn encode_row(p: &AnsParams, tables: &[&CodingTables], syms: &[u16]) -> Result<RowEncoding> {
    p.validate()?;
    validate_syms(p, tables, syms)?;
    let (l, o, f) = (p.l as usize, p.o as usize, p.f as usize);
    let gsz = p.group_size() as usize;
    let w_radix = p.w();
    let nd = tables.len();
    let nseg = syms.len() / l;
    if nseg == 0 {
        return Ok(RowEncoding {
            words: Vec::new(),
            branches: Vec::new(),
            nseg: 0,
        });
    }

    // ---- Base pass (forward): replay r, record branches. ----
    let mut branches = Vec::with_capacity((nseg - 1) * f);
    let mut r: u64 = 1;
    for t in 0..nseg - 1 {
        for g in 0..f {
            let mut gr: u64 = 1;
            for pos in g * gsz..(g + 1) * gsz {
                gr *= tables[pos % nd].base_of(syms[t * l + pos]);
            }
            r *= gr;
            if r >= w_radix {
                branches.push(true);
                r >>= p.w_bits;
            } else {
                branches.push(false);
            }
        }
    }

    // ---- Digit pass (backward): choose slots, build the stream. ----
    let mut d: u64 = 0;
    let mut rev: Vec<u32> = Vec::new();
    let mut slots = vec![0u32; l];
    let mut req = vec![0u32; o];

    // Final segment: its digits are never pushed by the decoder, so any
    // slot of the right symbol works — use digit 0.
    for pos in 0..l {
        let sym = syms[(nseg - 1) * l + pos];
        slots[pos] = tables[pos % nd].slot_of(sym, 0);
    }
    pack(p, &slots, &mut req);

    for t in (0..nseg - 1).rev() {
        // Forward consumption order in segment t: checks 0..f (loads only
        // on `false` branches), then unconditional words f..o. Backward we
        // undo in reverse: unconditional words first, then check g paired
        // with undoing group g's pushes, for g = f-1 .. 0.
        for k in (f..o).rev() {
            rev.push(req[k]);
        }
        for g in (0..f).rev() {
            if branches[t * f + g] {
                // Forward extracted this word from the state: re-inject.
                debug_assert!(d < w_radix, "inject precondition d < W");
                d = (d << p.w_bits) | req[g] as u64;
            } else {
                rev.push(req[g]);
            }
            for pos in (g * gsz..(g + 1) * gsz).rev() {
                let sym = syms[t * l + pos];
                let b = tables[pos % nd].base_of(sym);
                let digit = d % b;
                slots[pos] = tables[pos % nd].slot_of(sym, digit as u32);
                d /= b;
            }
        }
        pack(p, &slots, &mut req);
    }
    // Initial o words (read before the first segment).
    for k in (0..o).rev() {
        rev.push(req[k]);
    }
    debug_assert_eq!(d, 0, "leftover encoder state must vanish (d < r = 1)");
    rev.reverse();
    Ok(RowEncoding {
        words: rev,
        branches,
        nseg,
    })
}

/// Segment-stepped decoder. The scalar [`decode_row`] drives it directly;
/// the warp-synchronous SpMVM kernel drives 32 of them in lockstep,
/// supplying words from the shared interleaved stream.
#[derive(Debug, Clone)]
pub struct RowDecoder {
    p: AnsParams,
    d: u64,
    r: u64,
    /// Buffered words for the next unpack.
    pub w: Vec<u32>,
    slots: Vec<u32>,
    seg: usize,
    nseg: usize,
}

impl RowDecoder {
    /// New decoder for a row of `nsyms` symbols (multiple of `l`).
    pub fn new(p: AnsParams, nsyms: usize) -> Result<RowDecoder> {
        if nsyms % p.l as usize != 0 {
            return Err(DtansError::InvalidParams(format!(
                "nsyms {nsyms} not a multiple of l={}",
                p.l
            )));
        }
        Ok(RowDecoder {
            p,
            d: 0,
            r: 1,
            w: vec![0; p.o as usize],
            slots: vec![0; p.l as usize],
            seg: 0,
            nseg: nsyms / p.l as usize,
        })
    }

    /// Number of segments.
    #[inline]
    pub fn nseg(&self) -> usize {
        self.nseg
    }

    /// Current segment index.
    #[inline]
    pub fn seg(&self) -> usize {
        self.seg
    }

    /// True while segments remain to decode.
    #[inline]
    pub fn active(&self) -> bool {
        self.seg < self.nseg
    }

    /// True if the current segment must produce words for a successor
    /// (i.e. it is not the final segment).
    #[inline]
    pub fn producing(&self) -> bool {
        self.seg + 1 < self.nseg
    }

    /// Supply the initial `o` words (index `k` in `0..o`).
    #[inline]
    pub fn supply(&mut self, k: usize, word: u32) {
        debug_assert!((word as u64) < self.p.w());
        self.w[k] = word;
    }

    /// Unpack the buffered words into the current segment's slots and write
    /// the decoded symbols (length `l`); `tables` as in [`decode_row`].
    pub fn begin_segment(&mut self, tables: &[&CodingTables], out: &mut [u16]) {
        unpack(&self.p, &self.w, &mut self.slots);
        let nd = tables.len();
        for (pos, &slot) in self.slots.iter().enumerate() {
            out[pos] = tables[pos % nd].slot_sym[slot as usize];
        }
    }

    /// Fold group `g`'s digit/base pairs into the state (call only when
    /// [`Self::producing`]).
    pub fn push_group(&mut self, tables: &[&CodingTables], g: usize) {
        let gsz = self.p.group_size() as usize;
        let nd = tables.len();
        let (mut gd, mut gr) = (0u64, 1u64);
        for pos in g * gsz..(g + 1) * gsz {
            let (_, digit, base) = tables[pos % nd].slot_decode(self.slots[pos]);
            gd = gd * base + digit;
            gr *= base;
        }
        // One multiply-add on the state; on the GPU this is the
        // umul + __umul_hi pair of §IV-F.
        self.d = self.d * gr + gd;
        self.r *= gr;
    }

    /// Check `g`: returns `true` if the word was extracted from the state
    /// (no load needed); on `false` the caller must [`Self::supply`] word
    /// `g` from the stream.
    pub fn check(&mut self, g: usize) -> bool {
        if self.r >= self.p.w() {
            self.w[g] = (self.d & (self.p.w() - 1)) as u32;
            self.d >>= self.p.w_bits;
            self.r >>= self.p.w_bits;
            true
        } else {
            false
        }
    }

    /// Advance to the next segment.
    #[inline]
    pub fn end_segment(&mut self) {
        self.seg += 1;
    }
}

/// Decode a full row of `nsyms` symbols from `words` (scalar driver).
pub fn decode_row(
    p: &AnsParams,
    tables: &[&CodingTables],
    words: &[u32],
    nsyms: usize,
) -> Result<Vec<u16>> {
    p.validate()?;
    if tables.is_empty() || p.l as usize % tables.len() != 0 {
        return Err(DtansError::InvalidParams(
            "need 1..=l tables with l % ndomains == 0".into(),
        ));
    }
    let (l, o, f) = (p.l as usize, p.o as usize, p.f as usize);
    let mut dec = RowDecoder::new(*p, nsyms)?;
    let mut out = vec![0u16; nsyms];
    if dec.nseg() == 0 {
        return Ok(out);
    }
    let mut pos = 0usize;
    let load = |pos: &mut usize| -> Result<u32> {
        let w = *words
            .get(*pos)
            .ok_or_else(|| DtansError::CorruptStream("word stream exhausted".into()))?;
        *pos += 1;
        Ok(w)
    };
    for k in 0..o {
        let w = load(&mut pos)?;
        dec.supply(k, w);
    }
    while dec.active() {
        let t = dec.seg();
        dec.begin_segment(tables, &mut out[t * l..(t + 1) * l]);
        if dec.producing() {
            for g in 0..f {
                dec.push_group(tables, g);
                if !dec.check(g) {
                    let w = load(&mut pos)?;
                    dec.supply(g, w);
                }
            }
            for k in f..o {
                let w = load(&mut pos)?;
                dec.supply(k, w);
            }
        }
        dec.end_segment();
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ans::histogram::normalize_counts;
    use crate::util::rng::Xoshiro256;

    fn toy_tables() -> CodingTables {
        // Fig. 3 tables: (a:1, b:4, c:3) over K=8, reused by the §IV-D
        // dtANS example (M=4 satisfied).
        CodingTables::build(&AnsParams::TOY, &[1, 4, 3]).unwrap()
    }

    #[test]
    fn paper_toy_roundtrip() {
        // The §IV-D example input (10 symbols, l=2 -> pad to 10 stays 10).
        let t = toy_tables();
        let tabs = [&t];
        let syms: Vec<u16> = vec![2, 1, 2, 1, 2, 2, 1, 1, 1, 0];
        let p = AnsParams::TOY;
        let enc = encode_row(&p, &tabs, &syms).unwrap();
        assert_eq!(enc.nseg, 5);
        let dec = decode_row(&p, &tabs, &enc.words, syms.len()).unwrap();
        assert_eq!(dec, syms);
    }

    #[test]
    fn single_segment_row_costs_o_words() {
        // A 1-segment row needs exactly the initial o words — the source of
        // the paper's "~4 words for a 1-nonzero row" observation.
        let t = toy_tables();
        let tabs = [&t];
        let p = AnsParams::TOY;
        let enc = encode_row(&p, &tabs, &[1, 2]).unwrap();
        assert_eq!(enc.words.len(), p.o as usize);
        assert_eq!(decode_row(&p, &tabs, &enc.words, 2).unwrap(), vec![1, 2]);
    }

    #[test]
    fn empty_row() {
        let t = toy_tables();
        let p = AnsParams::TOY;
        let enc = encode_row(&p, &[&t], &[]).unwrap();
        assert!(enc.words.is_empty());
        assert_eq!(decode_row(&p, &[&t], &[], 0).unwrap(), Vec::<u16>::new());
    }

    fn random_tables(p: &AnsParams, nsyms: usize, rng: &mut Xoshiro256) -> CodingTables {
        let counts: Vec<u64> = (0..nsyms).map(|_| 1 + rng.below(1000)).collect();
        let mult = normalize_counts(&counts, p.k(), p.m()).unwrap();
        CodingTables::build(p, &mult).unwrap()
    }

    fn roundtrip_random(p: AnsParams, ndomains: usize, seed: u64, max_len_segments: usize) {
        let mut rng = Xoshiro256::seeded(seed);
        let min_syms = (p.k() as usize).div_ceil(p.m() as usize);
        let t0 = random_tables(&p, min_syms.max(20), &mut rng);
        let t1 = random_tables(&p, min_syms.max(300), &mut rng);
        let tables: Vec<&CodingTables> = match ndomains {
            1 => vec![&t0],
            _ => vec![&t0, &t1],
        };
        for _ in 0..20 {
            let nseg = rng.below_usize(max_len_segments + 1);
            let nsyms = nseg * p.l as usize;
            let syms: Vec<u16> = (0..nsyms)
                .map(|i| {
                    let t = tables[i % tables.len()];
                    // Skew: mostly frequent symbols.
                    if rng.chance(0.8) {
                        // frequent symbol = argmax mult (symbol 0 is fine)
                        (rng.below(4.min(t.num_symbols() as u64))) as u16
                    } else {
                        rng.below(t.num_symbols() as u64) as u16
                    }
                })
                .collect();
            let enc = encode_row(&p, &tables, &syms).unwrap();
            let dec = decode_row(&p, &tables, &enc.words, nsyms).unwrap();
            assert_eq!(dec, syms);
            // The stream is never longer than nseg * o words.
            assert!(enc.words.len() <= nseg.max(1) * p.o as usize || nseg == 0);
        }
    }

    #[test]
    fn roundtrip_paper_params() {
        roundtrip_random(AnsParams::PAPER, 2, 101, 40);
    }

    #[test]
    fn roundtrip_kernel_params() {
        roundtrip_random(AnsParams::KERNEL, 2, 202, 60);
    }

    #[test]
    fn roundtrip_single_domain() {
        roundtrip_random(AnsParams::PAPER, 1, 303, 30);
        roundtrip_random(AnsParams::KERNEL, 1, 304, 30);
    }

    #[test]
    fn frequent_symbols_extract_more() {
        // All-frequent input should extract (branch=true) much more often
        // than all-rare input, i.e. consume fewer stream words.
        let p = AnsParams::KERNEL;
        let mut rng = Xoshiro256::seeded(7);
        let t = random_tables(&p, 300, &mut rng);
        let tabs = [&t];
        // Find most and least frequent symbols.
        let hot = (0..t.num_symbols()).max_by_key(|&s| t.sym_mult[s]).unwrap() as u16;
        let cold = (0..t.num_symbols()).min_by_key(|&s| t.sym_mult[s]).unwrap() as u16;
        let n = 64 * p.l as usize;
        let e_hot = encode_row(&p, &tabs, &vec![hot; n]).unwrap();
        let e_cold = encode_row(&p, &tabs, &vec![cold; n]).unwrap();
        assert!(
            e_hot.words.len() < e_cold.words.len(),
            "hot {} vs cold {}",
            e_hot.words.len(),
            e_cold.words.len()
        );
    }

    #[test]
    fn truncated_stream_is_error_not_panic() {
        let p = AnsParams::KERNEL;
        let mut rng = Xoshiro256::seeded(8);
        let t = random_tables(&p, 300, &mut rng);
        let tabs = [&t];
        let syms: Vec<u16> = (0..8 * p.l as usize)
            .map(|_| rng.below(t.num_symbols() as u64) as u16)
            .collect();
        let enc = encode_row(&p, &tabs, &syms).unwrap();
        let cut = &enc.words[..enc.words.len() - 1];
        assert!(decode_row(&p, &tabs, cut, syms.len()).is_err());
    }

    #[test]
    fn branch_count_matches_loads() {
        let p = AnsParams::KERNEL;
        let mut rng = Xoshiro256::seeded(9);
        let t = random_tables(&p, 100, &mut rng);
        let tabs = [&t];
        let nseg = 17;
        let syms: Vec<u16> = (0..nseg * p.l as usize)
            .map(|_| rng.below(t.num_symbols() as u64) as u16)
            .collect();
        let enc = encode_row(&p, &tabs, &syms).unwrap();
        let loads = enc.branches.iter().filter(|&&b| !b).count();
        let expected =
            p.o as usize + (nseg - 1) * (p.o - p.f) as usize + loads;
        assert_eq!(enc.words.len(), expected);
    }
}
