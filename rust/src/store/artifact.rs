//! Content-addressed on-disk artifact cache for encoded matrices.
//!
//! The paper frames the encoded matrix as a persistent artifact ("the
//! encoded data can be stored in memory or saved in a file for repeated
//! decoding"); this module gives that artifact a home. An
//! [`ArtifactKey`] is a stable 128-bit FNV-1a hash over the *content* of
//! the CSR original plus every field of [`EncodeOptions`] — the full
//! input of the encoder — so two registrations of the same matrix with
//! the same options map to the same on-disk file, and re-registering a
//! known matrix skips encoding entirely (the store loads the artifact via
//! [`crate::format::serialize`] instead).
//!
//! Layout: `<root>/<first-2-hex>/<32-hex>.dtans`, with writes going
//! through a temp file + rename so readers never observe a half-written
//! artifact.
//!
//! Mutable matrices ([`crate::delta`]) stamp a monotonically increasing
//! version per append; [`key_for_versioned`] folds that version into the
//! key (under a distinct schema tag, with version 0 mapping to the
//! original [`key_for`] key space) so compacted artifacts of different
//! versions of one matrix occupy different files.

use crate::format::csr_dtans::{CsrDtans, EncodeOptions};
use crate::format::serialize;
use crate::matrix::csr::Csr;
use crate::matrix::Precision;
use crate::util::error::Result;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

/// 128-bit FNV-1a offset basis.
const FNV_OFFSET: u128 = 0x6c62272e07bb014262b821756295c58d;
/// 128-bit FNV-1a prime.
const FNV_PRIME: u128 = 0x0000000001000000000000000000013b;

/// Incremental 128-bit FNV-1a-style hasher (std's `Hasher` is not stable
/// across releases/platforms; artifact keys must be, since they name
/// files). Folds **8 input bytes per multiply** instead of byte-at-a-time
/// FNV — registration hashes the full matrix content, so the 8x fewer
/// u128 multiplies matter on multi-million-nnz matrices. The output is
/// therefore not standard FNV-128; only stability and dispersion are
/// required here, and the schema tag versions the key space.
#[derive(Debug, Clone)]
struct Fnv128 {
    state: u128,
}

impl Fnv128 {
    fn new() -> Fnv128 {
        Fnv128 { state: FNV_OFFSET }
    }
    #[inline]
    fn absorb(&mut self, word: u64) {
        self.state ^= word as u128;
        self.state = self.state.wrapping_mul(FNV_PRIME);
    }
    fn write(&mut self, bytes: &[u8]) {
        let mut chunks = bytes.chunks_exact(8);
        for c in &mut chunks {
            self.absorb(u64::from_le_bytes(c.try_into().expect("8-byte chunk")));
        }
        let rem = chunks.remainder();
        if !rem.is_empty() {
            // Length-tag the tail word (rem.len() <= 7, so byte 7 is
            // free) to keep short inputs unambiguous.
            let mut buf = [0u8; 8];
            buf[..rem.len()].copy_from_slice(rem);
            buf[7] = rem.len() as u8;
            self.absorb(u64::from_le_bytes(buf));
        }
    }
    fn write_u32(&mut self, x: u32) {
        self.absorb(x as u64);
    }
    fn write_u64(&mut self, x: u64) {
        self.absorb(x);
    }
}

/// Stable content hash identifying one (matrix, encode options) pair.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ArtifactKey(pub u128);

impl std::fmt::Display for ArtifactKey {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{:032x}", self.0)
    }
}

/// Compute the [`ArtifactKey`] for encoding `csr` with `opts`.
///
/// The hash covers shape, sparsity pattern, value bit patterns and every
/// encoder option, prefixed with a schema tag so future key layouts can
/// never collide with this one.
pub fn key_for(csr: &Csr, opts: &EncodeOptions) -> ArtifactKey {
    let mut h = Fnv128::new();
    h.write(b"dtans-artifact-key-v1");
    absorb_content(&mut h, csr, opts);
    ArtifactKey(h.state)
}

/// Version-aware [`ArtifactKey`]: the key for *version* `version` of a
/// mutable matrix whose compacted content is `csr` encoded with `opts`.
///
/// Version 0 (never appended to) delegates to [`key_for`], so every
/// artifact written before versioning existed stays addressable under its
/// original key. Versions > 0 hash under a distinct schema tag
/// (`…-key-v2`) that covers the version number, so cached `.dtans` files
/// from different versions of one matrix can never collide with each other
/// or with any v1 key.
pub fn key_for_versioned(csr: &Csr, opts: &EncodeOptions, version: u64) -> ArtifactKey {
    if version == 0 {
        return key_for(csr, opts);
    }
    let mut h = Fnv128::new();
    h.write(b"dtans-artifact-key-v2");
    h.write_u64(version);
    absorb_content(&mut h, csr, opts);
    ArtifactKey(h.state)
}

/// The shared content-hash body: shape, sparsity pattern, value bit
/// patterns, and every encoder option.
fn absorb_content(h: &mut Fnv128, csr: &Csr, opts: &EncodeOptions) {
    h.write_u64(csr.nrows as u64);
    h.write_u64(csr.ncols as u64);
    h.write_u64(csr.nnz() as u64);
    for &p in &csr.row_ptr {
        h.write_u64(p as u64);
    }
    for &c in &csr.cols {
        h.write_u32(c);
    }
    for &v in &csr.vals {
        h.write_u64(v.to_bits());
    }
    let p = opts.params;
    for x in [p.w_bits, p.k_bits, p.m_bits, p.l, p.o, p.f] {
        h.write_u32(x);
    }
    h.write_u32(match opts.precision {
        Precision::F64 => 64,
        Precision::F32 => 32,
    });
    h.write_u32(opts.delta_encode as u32);
}

/// Distinguishes temp files written concurrently by threads of one process.
static TEMP_SEQ: AtomicU64 = AtomicU64::new(0);

/// A content-addressed directory of serialized [`CsrDtans`] artifacts.
#[derive(Debug)]
pub struct ArtifactCache {
    root: PathBuf,
}

impl ArtifactCache {
    /// Open (creating if needed) a cache rooted at `root`.
    pub fn open(root: &Path) -> Result<ArtifactCache> {
        std::fs::create_dir_all(root)?;
        Ok(ArtifactCache { root: root.to_path_buf() })
    }

    /// The cache's root directory.
    pub fn root(&self) -> &Path {
        &self.root
    }

    /// Canonical path of `key`'s artifact (whether or not it exists).
    pub fn path_for(&self, key: &ArtifactKey) -> PathBuf {
        let hex = key.to_string();
        self.root.join(&hex[..2]).join(format!("{hex}.dtans"))
    }

    /// Does an artifact for `key` exist on disk?
    pub fn contains(&self, key: &ArtifactKey) -> bool {
        self.path_for(key).is_file()
    }

    /// Load the artifact for `key`, if present. Returns `Ok(None)` on a
    /// clean miss; corrupt or unreadable artifacts surface as errors so
    /// the caller can decide to fall back to re-encoding.
    pub fn load(&self, key: &ArtifactKey) -> Result<Option<CsrDtans>> {
        let path = self.path_for(key);
        if !path.is_file() {
            return Ok(None);
        }
        serialize::load(&path).map(Some)
    }

    /// Persist `m` as the artifact for `key` (atomic: temp file + rename).
    /// Returns the canonical artifact path.
    pub fn store(&self, key: &ArtifactKey, m: &CsrDtans) -> Result<PathBuf> {
        let path = self.path_for(key);
        let tmp = path.with_extension(format!(
            "tmp.{}.{}",
            std::process::id(),
            TEMP_SEQ.fetch_add(1, Ordering::Relaxed)
        ));
        serialize::save(m, &tmp)?;
        std::fs::rename(&tmp, &path)?;
        Ok(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::gen::structured::banded;
    use crate::matrix::gen::{assign_values, ValueDist};
    use crate::util::rng::Xoshiro256;

    fn sample(seed: u64) -> Csr {
        let mut m = banded(120, 3);
        assign_values(&mut m, ValueDist::FewDistinct(5), &mut Xoshiro256::seeded(seed));
        m
    }

    fn temp_root(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("dtans_test_artifact_{tag}_{}", std::process::id()))
    }

    #[test]
    fn key_is_stable_and_content_sensitive() {
        let opts = EncodeOptions::default();
        let a = sample(1);
        assert_eq!(key_for(&a, &opts), key_for(&a.clone(), &opts));
        // Different values -> different key.
        let b = sample(2);
        assert_ne!(key_for(&a, &opts), key_for(&b, &opts));
        // Different options -> different key.
        let other = EncodeOptions { delta_encode: false, ..opts };
        assert_ne!(key_for(&a, &opts), key_for(&a, &other));
        let f32_opts = EncodeOptions { precision: Precision::F32, ..opts };
        assert_ne!(key_for(&a, &opts), key_for(&a, &f32_opts));
    }

    #[test]
    fn versioned_keys_never_collide_across_versions() {
        let opts = EncodeOptions::default();
        let m = sample(1);
        // Version 0 is the original key space: on-disk compatibility.
        assert_eq!(key_for_versioned(&m, &opts, 0), key_for(&m, &opts));
        // Distinct versions of the same content get distinct keys, all
        // different from the v0 key.
        let mut seen = vec![key_for(&m, &opts)];
        for v in 1..=8u64 {
            let k = key_for_versioned(&m, &opts, v);
            assert!(!seen.contains(&k), "version {v} collided");
            seen.push(k);
        }
        // Same (content, options, version) stays stable.
        assert_eq!(key_for_versioned(&m, &opts, 3), key_for_versioned(&m, &opts, 3));
        // Content still matters at any version.
        assert_ne!(
            key_for_versioned(&m, &opts, 2),
            key_for_versioned(&sample(2), &opts, 2)
        );
    }

    #[test]
    fn store_then_load_roundtrips() {
        let root = temp_root("roundtrip");
        let cache = ArtifactCache::open(&root).unwrap();
        let m = sample(3);
        let opts = EncodeOptions::default();
        let enc = CsrDtans::encode(&m, &opts).unwrap();
        let key = key_for(&m, &opts);
        assert!(!cache.contains(&key));
        assert!(cache.load(&key).unwrap().is_none());
        let path = cache.store(&key, &enc).unwrap();
        assert_eq!(path, cache.path_for(&key));
        assert!(cache.contains(&key));
        let back = cache.load(&key).unwrap().unwrap();
        assert_eq!(back.stream, enc.stream);
        assert_eq!(back.row_nnz, enc.row_nnz);
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn no_temp_files_left_behind() {
        let root = temp_root("tmpclean");
        let cache = ArtifactCache::open(&root).unwrap();
        let m = sample(4);
        let opts = EncodeOptions::default();
        let enc = CsrDtans::encode(&m, &opts).unwrap();
        cache.store(&key_for(&m, &opts), &enc).unwrap();
        let mut files = Vec::new();
        for dir in std::fs::read_dir(&root).unwrap() {
            for f in std::fs::read_dir(dir.unwrap().path()).unwrap() {
                files.push(f.unwrap().file_name().into_string().unwrap());
            }
        }
        assert_eq!(files.len(), 1);
        assert!(files[0].ends_with(".dtans"), "{files:?}");
        let _ = std::fs::remove_dir_all(&root);
    }
}
