//! Classic tabled ANS (tANS) — Algorithms 1 and 2 of the paper.
//!
//! This is the *reference* entropy coder: sequential, bit-granular, and not
//! GPU-friendly (the paper's §IV-B explains why). It serves three purposes
//! here: (1) a correctness oracle for table construction, (2) the
//! compression-ratio reference dtANS is measured against in the ablation
//! benches, (3) executable documentation of the paper's worked example.

use super::tables::CodingTables;
use crate::util::error::{DtansError, Result};

/// Result of tANS encoding: final state `s0`, bit stream `v` (in decode
/// order), and the number of symbols.
#[derive(Debug, Clone)]
pub struct TansEncoding {
    /// Final state (the decoder's initial state).
    pub s0: u64,
    /// Bit stream in the order the decoder consumes it.
    pub bits: Vec<bool>,
    /// Number of encoded symbols.
    pub n: usize,
}

impl TansEncoding {
    /// Size in bits including the state (log2(2L) bits).
    pub fn total_bits(&self, l_param: u64) -> usize {
        self.bits.len() + (64 - (2 * l_param - 1).leading_zeros() as usize)
    }
}

/// Encode `syms` with tANS over `tables`, state range `L = [l_param,
/// 2*l_param)`; `l_param` must be a multiple of K (we use `l_param = K`).
///
/// Algorithm 1: processes symbols from last to first; for each symbol the
/// digit is `s mod base`, the slot is looked up, and bits are emitted until
/// the successor state `x*K + slot` is back in range.
pub fn tans_encode(tables: &CodingTables, l_param: u64, syms: &[u16]) -> Result<TansEncoding> {
    let k = tables.k as u64;
    if l_param % k != 0 || l_param == 0 {
        return Err(DtansError::InvalidParams("L must be a positive multiple of K".into()));
    }
    let m = l_param / k;
    let mut s = l_param;
    // Bits are pushed while walking the input backwards; the decoder reads
    // them forwards, so reverse at the end.
    let mut rev_bits: Vec<bool> = Vec::new();
    for &u in syms.iter().rev() {
        if u as usize >= tables.num_symbols() {
            return Err(DtansError::InvalidParams(format!("symbol {u} out of range")));
        }
        let q = tables.base_of(u);
        // Normalize: emit low bits of s until s is in the symbol's dyadic
        // interval [q*m, 2*q*m) — this is the paper's "rewrite s as
        // x_inf b_2 d_r such that x_inf j_K is in range".
        while s >= 2 * q * m {
            rev_bits.push(s & 1 == 1);
            s >>= 1;
        }
        debug_assert!(s >= q * m, "state fell below range");
        let d = s % q;
        let x = s / q; // in [m, 2m)
        let j = tables.slot_of(u, d as u32) as u64;
        s = x * k + j;
        debug_assert!((l_param..2 * l_param).contains(&s));
    }
    rev_bits.reverse();
    Ok(TansEncoding {
        s0: s,
        bits: rev_bits,
        n: syms.len(),
    })
}

/// Decode Algorithm 2: starting from `s0`, each step reads the slot
/// `s mod K`, emits its symbol, and refills bits until the state is back in
/// `[l_param, 2*l_param)`.
pub fn tans_decode(tables: &CodingTables, l_param: u64, enc: &TansEncoding) -> Result<Vec<u16>> {
    let k = tables.k as u64;
    let mut s = enc.s0;
    let mut pos = 0usize;
    let mut out = Vec::with_capacity(enc.n);
    for _ in 0..enc.n {
        if s < l_param || s >= 2 * l_param {
            return Err(DtansError::CorruptStream(format!("state {s} out of range")));
        }
        let j = (s % k) as u32;
        let (sym, d, q) = tables.slot_decode(j);
        out.push(sym);
        let x = s / k; // in [m, 2m)
        // Reconstruct the pre-normalization state and refill bits.
        let mut sp = x * q + d;
        while sp < l_param {
            if pos >= enc.bits.len() {
                return Err(DtansError::CorruptStream("bit stream exhausted".into()));
            }
            sp = (sp << 1) | enc.bits[pos] as u64;
            pos += 1;
        }
        s = sp;
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ans::params::AnsParams;
    use crate::util::rng::Xoshiro256;

    fn fig3_tables() -> CodingTables {
        CodingTables::build(&AnsParams::TOY, &[1, 4, 3]).unwrap()
    }

    /// The paper's §III-D example: u = (c,b,c,b,c,c,b,b,b,a) with
    /// P' = (a:1/8, b:4/8, c:3/8), K=8, L=16.
    fn paper_input() -> Vec<u16> {
        // a=0, b=1, c=2
        vec![2, 1, 2, 1, 2, 2, 1, 1, 1, 0]
    }

    #[test]
    fn paper_example_roundtrip_and_optimal_size() {
        let t = fig3_tables();
        let enc = tans_encode(&t, 16, &paper_input()).unwrap();
        // The paper reports 14 bits for v (optimal: 10*H' ~ 13.7). The
        // exact count depends on the arbitrary slot ordering of the symbol
        // table; ours lands at 13-14 bits — equally optimal.
        assert!((13..=15).contains(&enc.bits.len()), "bits={}", enc.bits.len());
        assert!((16..32).contains(&enc.s0));
        let dec = tans_decode(&t, 16, &enc).unwrap();
        assert_eq!(dec, paper_input());
    }

    #[test]
    fn frequent_symbols_cost_fewer_bits() {
        let t = fig3_tables();
        let all_b = vec![1u16; 64];
        let all_a = vec![0u16; 64];
        let eb = tans_encode(&t, 16, &all_b).unwrap();
        let ea = tans_encode(&t, 16, &all_a).unwrap();
        // b has 4/8 slots (1 bit each), a has 1/8 (3 bits each).
        assert_eq!(eb.bits.len(), 64);
        assert_eq!(ea.bits.len(), 3 * 64);
    }

    #[test]
    fn empty_input() {
        let t = fig3_tables();
        let enc = tans_encode(&t, 16, &[]).unwrap();
        assert_eq!(enc.bits.len(), 0);
        assert_eq!(tans_decode(&t, 16, &enc).unwrap(), Vec::<u16>::new());
    }

    #[test]
    fn random_roundtrips_and_near_entropy() {
        let t = fig3_tables();
        let mut rng = Xoshiro256::seeded(11);
        // Draw from P' itself: expected bits/symbol == H(P') = 1/8*3 + 4/8*1 + 3/8*log2(8/3)
        let hp = 0.125 * 3.0 + 0.5 * 1.0 + 0.375 * (8.0f64 / 3.0).log2();
        let n = 4000;
        let mut syms = Vec::with_capacity(n);
        for _ in 0..n {
            let x = rng.below(8);
            syms.push(if x < 1 { 0u16 } else if x < 5 { 1 } else { 2 });
        }
        let enc = tans_encode(&t, 16, &syms).unwrap();
        let dec = tans_decode(&t, 16, &enc).unwrap();
        assert_eq!(dec, syms);
        let bits_per_sym = enc.bits.len() as f64 / n as f64;
        assert!(
            (bits_per_sym - hp).abs() < 0.05,
            "bits/sym {bits_per_sym} vs H' {hp}"
        );
    }

    #[test]
    fn corrupt_stream_detected() {
        let t = fig3_tables();
        let mut enc = tans_encode(&t, 16, &paper_input()).unwrap();
        enc.bits.truncate(4);
        assert!(tans_decode(&t, 16, &enc).is_err());
    }

    #[test]
    fn larger_l_improves_precision() {
        // L can be any multiple of K; a larger L loses less precision.
        let t = fig3_tables();
        let syms = paper_input();
        for l in [16u64, 32, 64, 128] {
            let enc = tans_encode(&t, l, &syms).unwrap();
            assert_eq!(tans_decode(&t, l, &enc).unwrap(), syms);
        }
    }
}
