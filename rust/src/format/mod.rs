//! The CSR-dtANS compressed matrix format: symbolization with escapes,
//! per-row dtANS encoding, warp interleaving, container + (de)serialization.

pub mod csr_dtans;
pub mod interleave;
pub mod serialize;
pub mod symbolize;

pub use csr_dtans::{CsrDtans, EncodeOptions, SizeReport, WARP};
pub use symbolize::{Domain, SymbolPicker};
