//! Conversion of an encoded [`CsrDtans`] matrix into the flat argument
//! arrays the AOT-compiled Pallas kernel expects, padded to a bucket's
//! static shapes (mirrors `python/compile/kernels/ref.py::KernelBundle`).

use super::client::Arg;
use super::manifest::Bucket;
use crate::format::csr_dtans::{CsrDtans, WARP};
use crate::matrix::Precision;
use crate::util::error::{DtansError, Result};

/// Requirements an encoded matrix must meet for the PJRT path.
pub fn check_kernel_compatible(m: &CsrDtans) -> Result<()> {
    if m.params != crate::ans::AnsParams::KERNEL {
        return Err(DtansError::Runtime(
            "PJRT path requires AnsParams::KERNEL encoding".into(),
        ));
    }
    if m.precision != Precision::F32 {
        return Err(DtansError::Runtime("PJRT path requires F32 precision".into()));
    }
    if !m.delta_encode {
        return Err(DtansError::Runtime(
            "artifacts are compiled with delta_encode=true".into(),
        ));
    }
    Ok(())
}

/// Maximum segments of any row (the kernel's loop bound requirement).
pub fn max_segments(m: &CsrDtans) -> usize {
    (0..m.nrows).map(|r| m.row_segments(r)).max().unwrap_or(0)
}

fn pad_i32(src: impl Iterator<Item = i32>, n: usize, fill: i32) -> Vec<i32> {
    let mut v: Vec<i32> = src.collect();
    assert!(v.len() <= n, "bucket too small: {} > {n}", v.len());
    v.resize(n, fill);
    v
}

fn pad_f32(src: impl Iterator<Item = f32>, n: usize) -> Vec<f32> {
    let mut v: Vec<f32> = src.collect();
    assert!(v.len() <= n, "bucket too small: {} > {n}", v.len());
    v.resize(n, 0.0);
    v
}

/// Build the 15 kernel arguments (bundle fields, then x, then y_in) padded
/// to `bucket`.
pub fn build_args(m: &CsrDtans, bucket: &Bucket, x: &[f64], y_in: &[f64]) -> Result<Vec<Arg>> {
    check_kernel_compatible(m)?;
    if x.len() != m.ncols || y_in.len() != m.nrows {
        return Err(DtansError::Dimension(format!(
            "x[{}]/y[{}] vs matrix {}x{}",
            x.len(),
            y_in.len(),
            m.nrows,
            m.ncols
        )));
    }
    let k = m.params.k() as usize;
    let nslices_b = bucket.nrows / WARP;

    let per_sym_i32 = |domain: &crate::format::symbolize::Domain| -> (Vec<i32>, Vec<i32>) {
        let mut pay = vec![0i32; k];
        let mut esc = vec![0i32; k];
        for (i, (&p, &e)) in domain.payload.iter().zip(&domain.is_escape).enumerate() {
            pay[i] = if e { 0 } else { p as i32 };
            esc[i] = e as i32;
        }
        (pay, esc)
    };
    let (d_payload, d_isesc) = per_sym_i32(&m.delta_domain);
    let mut v_value = vec![0.0f32; k];
    let mut v_isesc = vec![0i32; k];
    for (i, (&p, &e)) in m
        .value_domain
        .payload
        .iter()
        .zip(&m.value_domain.is_escape)
        .enumerate()
    {
        v_value[i] = if e { 0.0 } else { f32::from_bits(p as u32) };
        v_isesc[i] = e as i32;
    }

    let last_off = *m.slice_offsets.last().unwrap_or(&0) as i32;
    let mut slice_offsets: Vec<i32> = m.slice_offsets.iter().map(|&v| v as i32).collect();
    assert!(slice_offsets.len() <= nslices_b + 1);
    slice_offsets.resize(nslices_b + 1, last_off);

    Ok(vec![
        Arg::I32(m.delta_tables.packed.iter().map(|&v| v as i32).collect()),
        Arg::I32(m.value_tables.packed.iter().map(|&v| v as i32).collect()),
        Arg::I32(d_payload),
        Arg::I32(d_isesc),
        Arg::F32(v_value),
        Arg::I32(v_isesc),
        Arg::I32(pad_i32(m.stream.iter().map(|&v| v as i32), bucket.nw, 0)),
        Arg::I32(slice_offsets),
        Arg::I32(pad_i32(m.row_nnz.iter().map(|&v| v as i32), bucket.nrows, 0)),
        Arg::I32(pad_i32(
            m.delta_esc_offsets[..m.nrows].iter().map(|&v| v as i32),
            bucket.nrows,
            0,
        )),
        Arg::I32(pad_i32(
            m.value_esc_offsets[..m.nrows].iter().map(|&v| v as i32),
            bucket.nrows,
            0,
        )),
        Arg::I32(pad_i32(
            m.delta_escapes.iter().map(|&v| v as i32),
            bucket.ne,
            0,
        )),
        Arg::F32(pad_f32(
            m.value_escapes.iter().map(|&p| f32::from_bits(p as u32)),
            bucket.ne,
        )),
        Arg::F32(pad_f32(x.iter().map(|&v| v as f32), bucket.ncols)),
        Arg::F32(pad_f32(y_in.iter().map(|&v| v as f32), bucket.nrows)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ans::AnsParams;
    use crate::format::csr_dtans::EncodeOptions;
    use crate::matrix::gen::structured::banded;

    fn kernel_encode(n: usize) -> CsrDtans {
        CsrDtans::encode(
            &banded(n, 2),
            &EncodeOptions {
                params: AnsParams::KERNEL,
                precision: Precision::F32,
                delta_encode: true,
            },
        )
        .unwrap()
    }

    #[test]
    fn rejects_paper_params() {
        let m = CsrDtans::encode(&banded(40, 2), &EncodeOptions::default()).unwrap();
        assert!(check_kernel_compatible(&m).is_err());
    }

    #[test]
    fn builds_padded_args() {
        let m = kernel_encode(50);
        let bucket = Bucket {
            nrows: 64,
            ncols: 64,
            nw: 4096,
            ne: 512,
            nnz: 1024,
            max_seg: 32,
        };
        let x = vec![1.0; 50];
        let y = vec![0.0; 50];
        let args = build_args(&m, &bucket, &x, &y).unwrap();
        assert_eq!(args.len(), 15);
        match &args[6] {
            Arg::I32(v) => assert_eq!(v.len(), 4096),
            _ => panic!("stream must be i32"),
        }
        match &args[13] {
            Arg::F32(v) => assert_eq!(v.len(), 64),
            _ => panic!("x must be f32"),
        }
    }

    #[test]
    fn max_segments_counts() {
        let m = kernel_encode(10);
        // banded(10,2): max row len 5, 2 nnz/segment -> 3 segments.
        assert_eq!(max_segments(&m), 3);
    }
}
