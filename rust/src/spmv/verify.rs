//! Cross-format verification helpers used by tests, examples and the
//! coordinator's self-checks.

use crate::format::csr_dtans::{CsrDtans, EncodeOptions};
use crate::matrix::csr::Csr;
use crate::matrix::sell::Sell;
use crate::matrix::Precision;
use crate::util::error::Result;
use crate::util::rng::Xoshiro256;

/// Maximum elementwise |a-b| / max(1, |a|, |b|).
pub fn max_rel_err(a: &[f64], b: &[f64]) -> f64 {
    a.iter()
        .zip(b)
        .map(|(&x, &y)| (x - y).abs() / x.abs().max(y.abs()).max(1.0))
        .fold(0.0, f64::max)
}

/// Run all kernels (CSR, CSR-vector, COO, SELL, CSR-dtANS) on a random
/// vector and return the worst pairwise relative error vs the CSR result.
/// Used as a one-call consistency check on arbitrary matrices.
pub fn cross_check(m: &Csr, opts: &EncodeOptions, seed: u64) -> Result<f64> {
    let mut rng = Xoshiro256::seeded(seed);
    let x: Vec<f64> = (0..m.ncols).map(|_| rng.next_f64() - 0.5).collect();
    let reference = match opts.precision {
        Precision::F64 => m.clone(),
        Precision::F32 => m.round_to_f32(),
    };
    let mut want = vec![0.0; m.nrows];
    super::csr::spmv_csr(&reference, &x, &mut want)?;

    let mut worst: f64 = 0.0;
    let mut y = vec![0.0; m.nrows];
    super::csr::spmv_csr_vector(&reference, &x, &mut y, 32)?;
    worst = worst.max(max_rel_err(&want, &y));

    let coo = reference.to_coo();
    y.iter_mut().for_each(|v| *v = 0.0);
    super::coo::spmv_coo(&coo, &x, &mut y)?;
    worst = worst.max(max_rel_err(&want, &y));

    let sell = Sell::from_csr(&reference, 32);
    y.iter_mut().for_each(|v| *v = 0.0);
    super::sell::spmv_sell(&sell, &x, &mut y)?;
    worst = worst.max(max_rel_err(&want, &y));

    let enc = CsrDtans::encode(m, opts)?;
    y.iter_mut().for_each(|v| *v = 0.0);
    super::csr_dtans::spmv_csr_dtans(&enc, &x, &mut y)?;
    worst = worst.max(max_rel_err(&want, &y));

    // Every registered format once more, through the dyn-operator engine
    // path: the trait surface must agree with the free functions on
    // arbitrary matrices too (builders that refuse — the dense oracle on
    // huge matrices — are skipped, as the registry contract allows).
    let engine = super::engine::SpmvEngine::serial();
    for (_tag, op) in super::operator::FormatRegistry::builtin().build_all(&reference, opts) {
        if let Ok(op) = op {
            y.iter_mut().for_each(|v| *v = 0.0);
            engine.run(op.as_ref(), &x, &mut y)?;
            worst = worst.max(max_rel_err(&want, &y));
        }
    }
    Ok(worst)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::gen::structured::banded;
    use crate::matrix::gen::{assign_values, ValueDist};

    #[test]
    fn cross_check_small() {
        let mut m = banded(120, 2);
        assign_values(&mut m, ValueDist::FewDistinct(5), &mut Xoshiro256::seeded(1));
        let err = cross_check(&m, &EncodeOptions::default(), 7).unwrap();
        assert!(err < 1e-12, "err={err}");
    }

    #[test]
    fn rel_err_metric() {
        assert_eq!(max_rel_err(&[1.0], &[1.0]), 0.0);
        assert!(max_rel_err(&[1.0], &[2.0]) > 0.4);
    }
}
