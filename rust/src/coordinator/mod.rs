//! Layer-3 coordinator: a batching SpMVM service with per-matrix format
//! routing (the production wrapper around the paper's kernel — encode
//! once, decode on every multiply, as in the iterative-solver and
//! ML-inference scenarios the paper motivates). Matrix lifetime and
//! residency live one layer down in the tiered store ([`crate::store`]);
//! iterative solves ([`crate::solver`]) run through
//! [`service::SpmvService::solve`] under a single store pin.

pub mod metrics;
pub mod router;
pub mod service;

pub use metrics::{FormatSummary, LatencySummary, Metrics, SolverSummary};
pub use router::{FormatChoice, RoutePolicy};
pub use service::{LoadedMatrix, Pending, ServiceConfig, SpmvService};
