"""L2 model entries and the AOT lowering path.

Checks that every (entry × bucket) function traces, lowers to HLO text,
and — executed via jax — matches the oracle on a real padded bundle.
"""

import jax
jax.config.update("jax_enable_x64", True)

import numpy as np

from compile import aot, model
from compile.kernels import ref


def bundle_for_bucket(bucket, seed=0):
    rng = np.random.default_rng(seed)
    nrows, ncols = bucket["nrows"], bucket["ncols"]
    rc, rv = ref.random_matrix(rng, nrows - 5, ncols, 4.0, 8)
    b = ref.encode_matrix(rc, rv, ncols)
    return b.pad_to(nrows, bucket["nw"], bucket["ne"]), rng


def test_spmv_dtans_entry_matches_oracle():
    bucket = model.BUCKETS["r64c64"]
    b, rng = bundle_for_bucket(bucket)
    x = rng.standard_normal(bucket["ncols"]).astype(np.float32)
    y_in = rng.standard_normal(bucket["nrows"]).astype(np.float32)
    fn = model.spmv_dtans_entry(bucket)
    (y,) = jax.jit(fn)(
        b.dtab, b.vtab, b.d_payload, b.d_isesc, b.v_value, b.v_isesc,
        b.stream, b.slice_offsets, b.row_nnz, b.d_esc_off, b.v_esc_off,
        b.d_escapes, b.v_escapes, x, y_in,
    )
    want = ref.decode_spmv_ref(b, x) + y_in
    np.testing.assert_allclose(np.asarray(y), want, rtol=1e-6, atol=1e-6)


def test_spmv_csr_jnp_entry():
    bucket = model.BUCKETS["r64c64"]
    rng = np.random.default_rng(1)
    rc, rv = ref.random_matrix(rng, bucket["nrows"], bucket["ncols"], 3.0, 8)
    nnz = bucket["nnz"]
    row_ids = np.full(nnz, bucket["nrows"], dtype=np.int32)  # dead target
    cols = np.zeros(nnz, dtype=np.int32)
    vals = np.zeros(nnz, dtype=np.float32)
    k = 0
    for r, (cs, vs) in enumerate(zip(rc, rv)):
        for c, v in zip(cs, vs):
            row_ids[k], cols[k], vals[k] = r, c, v
            k += 1
    x = rng.standard_normal(bucket["ncols"]).astype(np.float32)
    y_in = np.zeros(bucket["nrows"], dtype=np.float32)
    fn = model.spmv_csr_jnp_entry(bucket)
    (y,) = jax.jit(fn)(row_ids, cols, vals, x, y_in)
    want = ref.spmv_csr_ref(rc, rv, x)
    np.testing.assert_allclose(np.asarray(y), want, rtol=1e-4, atol=1e-4)


def test_dense_matvec_entry():
    bucket = model.BUCKETS["r64c64"]
    rng = np.random.default_rng(2)
    a = rng.standard_normal((bucket["nrows"], bucket["ncols"])).astype(np.float32)
    x = rng.standard_normal(bucket["ncols"]).astype(np.float32)
    y_in = rng.standard_normal(bucket["nrows"]).astype(np.float32)
    fn = model.dense_matvec_entry(bucket)
    (y,) = jax.jit(fn)(a, x, y_in)
    np.testing.assert_allclose(np.asarray(y), a @ x + y_in, rtol=1e-5, atol=1e-5)


def test_all_entries_lower_to_hlo_text():
    bucket = model.BUCKETS["r64c64"]
    for name, (builder, spec_builder) in model.ENTRIES.items():
        fn = builder(bucket)
        lowered = jax.jit(fn).lower(*spec_builder(bucket))
        text = aot.to_hlo_text(lowered)
        assert text.startswith("HloModule"), name
        assert "ENTRY" in text, name


def test_manifest_line_format():
    bucket = model.BUCKETS["r64c64"]
    specs = model.dense_matvec_arg_specs(bucket)
    line = aot.manifest_line("dense_matvec_r64c64", specs, bucket["nrows"])
    assert line.startswith("dense_matvec_r64c64|f32:64x64;f32:64;f32:64|f32:64")
