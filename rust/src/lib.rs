//! # dtans — entropy-coded sparse matrices with on-the-fly decoding SpMVM
//!
//! Reproduction of *"Fast Entropy Decoding for Sparse MVM on GPUs"*
//! (Schätzle, Pegolotti, Püschel, CS.PF 2026).
//!
//! The paper's key idea: apply lossless entropy coding (a GPU-friendly
//! variant of tabled asymmetric numeral systems, called **dtANS**) on top of
//! the CSR sparse-matrix format, and perform sparse matrix-vector
//! multiplication (SpMVM) while decoding the compressed matrix on the fly.
//! Because SpMVM is memory-bound, moving fewer bytes wins even though
//! decoding costs instructions.
//!
//! This crate contains the complete system:
//!
//! * [`ans`] — the dtANS codec (and classic tANS as a reference):
//!   histogram normalization with multiplicity cap `M`, coding tables,
//!   the segment/word decoder of the paper's Algorithm 3, and the
//!   two-pass (base pass + digit pass) encoder.
//! * [`matrix`] — sparse matrix substrates: COO/CSR/SELL plus the
//!   balanced fixed-width block format [`matrix::BlockedEll`],
//!   MatrixMarket IO, random-graph and structured generators, entropy
//!   statistics.
//! * [`format`] — the **CSR-dtANS** container: delta encoding,
//!   symbolization with escapes, per-row encoding, warp interleaving,
//!   byte-accurate size accounting.
//! * [`spmv`] — SpMVM kernels for dense/CSR/COO/SELL/BlockedELL/
//!   CSR-dtANS, including the warp-synchronous on-the-fly-decoding
//!   kernel (the CUDA kernel's semantics executed in lockstep on the
//!   CPU) and the hand-unrolled 4/8-wide [`spmv::engine::KernelVariant`]
//!   kernels in [`spmv::unrolled`] with their documented deterministic
//!   reassociation policy (`docs/KERNELS.md`). On top sits the
//!   format-agnostic [`spmv::operator`] layer — the object-safe
//!   [`spmv::SpmvOperator`] trait every format implements, plus a
//!   [`spmv::FormatRegistry`] — and the parallel [`spmv::engine`]: an
//!   nnz-balanced partitioner + thread-pool executor (bit-identical to
//!   the serial kernels, per variant) with batched multi-RHS entry
//!   points over contiguous [`spmv::densemat`] views.
//! * [`sim`] — a GPU execution-model simulator (coalescing, L2, DRAM
//!   roofline) that stands in for the paper's RTX 5090 when regenerating
//!   the runtime figures/tables.
//! * [`autotune`] — an exhaustive format autotuner standing in for
//!   AlphaSparse in the Fig. 9 comparison.
//! * [`eval`] — corpus + drivers regenerating every table and figure of
//!   the paper's evaluation section.
//! * [`runtime`] — PJRT loader/executor for the AOT-compiled JAX/Pallas
//!   artifacts (`artifacts/*.hlo.txt`).
//! * [`solver`] — iterative solvers (conjugate gradient, BiCGStab, power
//!   iteration / PageRank) written once against the operator trait, with
//!   iterations running over the engine's fused `y = α·A·x + β·y` entry
//!   point (allocation-free for the row-oriented formats) — the
//!   repeated-multiply workload where per-iteration decoding amortizes
//!   the paper's compression.
//! * [`coordinator`] — the admission-controlled SpMVM service: a
//!   bounded priority queue with typed load-shedding, deadlines and
//!   per-tenant quotas, cross-request coalescing into SpMM batches
//!   (see `docs/SERVING.md`), plus router, worker pool and metrics,
//!   built on the native and PJRT execution paths.
//! * [`obs`] — observability: per-request span chains through the
//!   admission pipeline (drainable as structured events or Chrome
//!   trace-event JSON for Perfetto), HDR-style log-bucketed histograms
//!   backing every latency distribution in the coordinator's `Metrics`,
//!   and Prometheus/JSON metric export with per-matrix paper-headline
//!   gauges (compression ratio, decode throughput) — see
//!   `docs/OBSERVABILITY.md`.
//! * [`store`] — the tiered matrix store under the coordinator: a
//!   content-addressed on-disk artifact cache (re-registering a known
//!   matrix skips encoding), memory-budgeted LRU residency with pinning,
//!   and a deduping background loader that faults evicted matrices back
//!   in from disk.
//! * [`delta`] — mutable registered matrices: an append-only COO delta
//!   overlay composed with the immutable base through an
//!   [`delta::OverlayOperator`], versioned artifacts, and background
//!   compaction that re-absorbs the overlay into a fresh dtANS encoding
//!   (see `docs/MUTATION.md`).
//! * [`testkit`] — the verification subsystem behind the integration
//!   tests: a differential conformance oracle (every registered format ×
//!   every kernel variant × every partition strategy vs the serial CSR
//!   ground truth, with structured mismatch reports and reassociation
//!   negative controls), deterministic fault injection for
//!   `.dtans` artifacts plus a failing cache-root shim, a seeded
//!   concurrency-stress driver with serial-replay bit-identity oracles,
//!   and the curated pathological matrix zoo.
//!
//! ## Quickstart
//!
//! (`no_run`: doctest binaries do not inherit the rpath to
//! libxla_extension's bundled libstdc++ in this offline image; the same
//! code runs as `examples/quickstart.rs`.)
//!
//! ```no_run
//! use dtans::matrix::gen::{GraphModel, gen_graph_csr};
//! use dtans::format::CsrDtans;
//! use dtans::spmv::spmv_csr_dtans;
//! use dtans::util::rng::Xoshiro256;
//!
//! let mut rng = Xoshiro256::seeded(7);
//! let a = gen_graph_csr(GraphModel::ErdosRenyi, 1 << 10, 10.0, &mut rng);
//! let enc = CsrDtans::encode(&a, &Default::default()).unwrap();
//! println!("CSR bytes {} -> dtANS bytes {}", a.size_bytes_f64(), enc.size_report().total);
//! let x = vec![1.0; a.ncols];
//! let mut y = vec![0.0; a.nrows];
//! spmv_csr_dtans(&enc, &x, &mut y).unwrap();
//! ```

pub mod ans;
pub mod autotune;
pub mod coordinator;
pub mod delta;
pub mod eval;
pub mod format;
pub mod matrix;
pub mod obs;
pub mod runtime;
pub mod sim;
pub mod solver;
pub mod spmv;
pub mod store;
pub mod testkit;
pub mod util;

pub use util::error::{DtansError, Result};
