//! Tier-1 fault injection: every corruption mode against the serializer
//! maps to a typed `DtansError` (never a panic, never a silently wrong
//! decode), and the store's failure paths — failed background persists,
//! failed cold loads with concurrent deduped waiters — degrade exactly as
//! documented, without poisoning retry paths.

use dtans::coordinator::{Metrics, RoutePolicy};
use dtans::format::csr_dtans::{CsrDtans, EncodeOptions};
use dtans::format::serialize;
use dtans::matrix::gen::structured::banded;
use dtans::matrix::gen::{assign_values, ValueDist};
use dtans::matrix::Csr;
use dtans::store::{MatrixStore, StoreConfig};
use dtans::testkit::faults::{corrupt, FailingDir, FaultMode, ALL_FAULT_MODES};
use dtans::util::rng::Xoshiro256;
use dtans::DtansError;
use std::sync::atomic::Ordering;
use std::sync::{Arc, Barrier};

fn sample_matrix(n: usize, seed: u64) -> Csr {
    let mut m = banded(n, 3);
    assign_values(&mut m, ValueDist::FewDistinct(6), &mut Xoshiro256::seeded(seed));
    m
}

fn store_with(config: StoreConfig) -> MatrixStore {
    MatrixStore::new(
        config,
        EncodeOptions::default(),
        RoutePolicy { min_nnz: 1 << 8, max_size_ratio: 0.98, ..Default::default() },
        Arc::new(Metrics::default()),
    )
    .unwrap()
}

#[test]
fn every_corruption_mode_maps_to_a_typed_error_never_a_panic() {
    let enc = CsrDtans::encode(&sample_matrix(300, 1), &EncodeOptions::default()).unwrap();
    let mut buf = Vec::new();
    serialize::write_to(&enc, &mut buf).unwrap();
    let mut seen_checksum = false;
    let mut seen_truncated = false;
    for mode in ALL_FAULT_MODES {
        for seed in 0..40u64 {
            let bad = corrupt(&buf, mode, seed);
            assert_ne!(bad, buf, "{mode:?} seed {seed}: corruption was a no-op");
            let err = match serialize::read_from(std::io::Cursor::new(&bad)) {
                Err(e) => e,
                Ok(_) => panic!("{mode:?} seed {seed}: corrupted container loaded"),
            };
            match (mode, &err) {
                // Pure tail loss always surfaces as the truncation variant.
                (FaultMode::Truncate, DtansError::Truncated(_)) => seen_truncated = true,
                (FaultMode::Truncate, other) => {
                    panic!("Truncate seed {seed}: expected Truncated, got {other}")
                }
                // Everything else must land in a container-family variant
                // (which one depends on where the damage falls).
                (
                    _,
                    DtansError::BadMagic { .. }
                    | DtansError::UnsupportedVersion { .. }
                    | DtansError::Truncated(_)
                    | DtansError::ChecksumMismatch { .. }
                    | DtansError::Container(_)
                    | DtansError::InvalidParams(_)
                    | DtansError::CorruptStream(_),
                ) => {
                    if matches!(err, DtansError::ChecksumMismatch { .. }) {
                        seen_checksum = true;
                    }
                }
                (_, other) => panic!("{mode:?} seed {seed}: unexpected variant {other}"),
            }
        }
    }
    // The sweep must have exercised both the checksum trailer and the
    // truncation path (otherwise the modes are not doing their jobs).
    assert!(seen_checksum, "no corruption reached the checksum check");
    assert!(seen_truncated);
}

#[test]
fn failed_persist_is_counted_and_matrix_stays_resident() {
    let dir = FailingDir::new("persist").unwrap();
    let store = store_with(StoreConfig {
        cache_dir: Some(dir.root().to_path_buf()),
        budget_bytes: Some(1), // would evict everything evictable
        ..Default::default()
    });
    // Open the write-failure window before anything persists.
    dir.break_writes().unwrap();
    let id = store.register_csr("m", sample_matrix(400, 2)).unwrap();
    store.flush(); // background persist runs -> fails
    let metrics = store.metrics();
    assert_eq!(metrics.persist_failures.load(Ordering::Relaxed), 1);
    // Unpersisted means unevictable: the 1-byte budget must NOT shed it.
    {
        let _ = store.acquire(id).unwrap(); // unpin triggers an enforce pass
    }
    assert!(store.is_resident(id), "unpersisted matrix must stay resident");
    assert!(!store.evict(id), "manual evict must refuse an unpersisted matrix");
    assert_eq!(metrics.evictions.load(Ordering::Relaxed), 0);
    // And it still serves correctly from RAM.
    let pinned = store.acquire(id).unwrap();
    assert_eq!(pinned.nrows, 400);
    drop(pinned);

    // Close the window: a later registration persists fine — the failure
    // did not wedge the store.
    dir.restore_writes().unwrap();
    let id2 = store.register_csr("n", sample_matrix(500, 3)).unwrap();
    store.flush();
    assert_eq!(metrics.persist_failures.load(Ordering::Relaxed), 1, "no new failure");
    {
        let _ = store.acquire(id2).unwrap();
    }
    assert!(!store.is_resident(id2), "persisted matrix is evictable under a 1-byte budget");
}

#[test]
fn failed_cold_load_reaches_all_deduped_waiters_without_poisoning_the_slot() {
    let dir = FailingDir::new("coldload").unwrap();
    let store = Arc::new(store_with(StoreConfig {
        cache_dir: Some(dir.root().to_path_buf()),
        budget_bytes: Some(1),
        drop_csr: true,
        loader_threads: 2,
        ..Default::default()
    }));
    let m = sample_matrix(900, 4);
    let x: Vec<f64> = (0..m.ncols).map(|i| (i as f64 * 0.01).sin()).collect();
    let mut want = vec![0.0; m.nrows];
    dtans::spmv::spmv_csr(&m, &x, &mut want).unwrap();
    let id = store.register_csr("m", m).unwrap();
    store.flush();
    {
        let _ = store.acquire(id).unwrap(); // unpin -> budget evicts
    }
    assert!(!store.is_resident(id));

    // Damage the artifact, then race 6 threads into the cold load.
    let snapshot = dir.snapshot().unwrap();
    assert!(!snapshot.is_empty(), "artifact must exist on disk");
    assert!(dir.corrupt_artifacts(FaultMode::Truncate, 7).unwrap() >= 1);
    let barrier = Arc::new(Barrier::new(6));
    let handles: Vec<_> = (0..6)
        .map(|_| {
            let store = Arc::clone(&store);
            let barrier = Arc::clone(&barrier);
            std::thread::spawn(move || {
                barrier.wait();
                store.acquire(id).err().map(|e| e.to_string())
            })
        })
        .collect();
    for h in handles {
        let err = h.join().unwrap();
        let msg = err.expect("acquire of a corrupt artifact must fail");
        assert!(
            msg.contains("truncated") || msg.contains("load job"),
            "unexpected error: {msg}"
        );
    }
    // No pins may leak from the failed acquires, and no cold load was
    // recorded as successful.
    assert_eq!(store.pin_count(id), 0);
    assert_eq!(store.metrics().cold_loads.load(Ordering::Relaxed), 0);

    // Restore the artifact bytes: the slot was not poisoned — the next
    // acquire cold-loads successfully and answers bit-correctly.
    dir.restore(&snapshot).unwrap();
    let pinned = store.acquire(id).unwrap();
    let mut got = vec![0.0; pinned.nrows];
    dtans::spmv::spmv_csr_dtans(&pinned.enc, &x, &mut got).unwrap();
    dtans::util::propcheck::assert_close(&got, &want, 1e-12, 1e-9).unwrap();
    assert!(store.metrics().cold_loads.load(Ordering::Relaxed) >= 1);
}

#[test]
fn every_fault_mode_on_an_artifact_surfaces_a_typed_cold_load_error() {
    // One eviction + one corrupt artifact per fault mode: the cold load
    // must fail with a typed error every time, and restoring the bytes
    // must always recover.
    let dir = FailingDir::new("modes").unwrap();
    let store = store_with(StoreConfig {
        cache_dir: Some(dir.root().to_path_buf()),
        budget_bytes: Some(1),
        drop_csr: true,
        ..Default::default()
    });
    let id = store.register_csr("m", sample_matrix(600, 5)).unwrap();
    store.flush();
    let snapshot = dir.snapshot().unwrap();
    for (i, mode) in ALL_FAULT_MODES.into_iter().enumerate() {
        {
            let _ = store.acquire(id).unwrap(); // ensure resident, unpin -> evict
        }
        assert!(!store.is_resident(id), "{mode:?}");
        assert!(dir.corrupt_artifacts(mode, 0x40 + i as u64).unwrap() >= 1);
        assert!(store.acquire(id).is_err(), "{mode:?}: corrupt cold load succeeded");
        assert_eq!(store.pin_count(id), 0, "{mode:?}");
        dir.restore(&snapshot).unwrap();
        let pinned = store.acquire(id).unwrap();
        assert_eq!(pinned.nrows, 600, "{mode:?}");
    }
}

#[test]
fn artifact_cache_read_of_corrupt_file_falls_back_to_reencoding() {
    // register_csr consults the cache; a corrupt cached artifact must be
    // treated as a miss (re-encode) rather than an error or a wrong load.
    let dir = FailingDir::new("cachehit").unwrap();
    let config = StoreConfig {
        cache_dir: Some(dir.root().to_path_buf()),
        ..Default::default()
    };
    let m = sample_matrix(500, 6);
    let store = store_with(config.clone());
    store.register_csr("a", m.clone()).unwrap();
    store.flush();
    assert_eq!(store.metrics().store_misses.load(Ordering::Relaxed), 1);
    assert!(dir.corrupt_artifacts(FaultMode::BitFlip, 9).unwrap() >= 1);

    let store2 = store_with(config);
    let id = store2.register_csr("a", m.clone()).unwrap();
    assert_eq!(
        store2.metrics().store_hits.load(Ordering::Relaxed),
        0,
        "corrupt artifact must not count as a cache hit"
    );
    assert_eq!(store2.metrics().store_misses.load(Ordering::Relaxed), 1);
    // The re-encoded registration still answers correctly.
    let x: Vec<f64> = (0..m.ncols).map(|i| (i as f64 * 0.02).cos()).collect();
    let mut want = vec![0.0; m.nrows];
    dtans::spmv::spmv_csr(&m, &x, &mut want).unwrap();
    let pinned = store2.acquire(id).unwrap();
    let mut got = vec![0.0; m.nrows];
    dtans::spmv::spmv_csr_dtans(&pinned.enc, &x, &mut got).unwrap();
    dtans::util::propcheck::assert_close(&got, &want, 1e-12, 1e-9).unwrap();
}
