#!/usr/bin/env python3
"""Smoke-checker for the adaptive-routing bench report.

Validates `results/BENCH_routing.json` (as written by
`cargo bench --bench main_bench -- routing_adaptation`) so the CI
bench-smoke step fails loudly when the report goes stale or the router
stops converging:

  * the file parses as JSON and names the right bench;
  * `acceptance_bar_ratio` is a number > 1 (the served-p50 budget);
  * `regimes` is a non-empty array whose entries each carry a regime
    name, a positive `steps` count, a non-negative bounded `flips`
    count (<= 4: hysteresis must prevent flapping on every canned
    trace), a `converged_at` observation stamp inside the trace, and
    positive p50s;
  * every regime's `p50_ratio` is consistent with its two p50s and
    within the acceptance bar — post-convergence served latency must
    sit within 10% of the best static arm's.

Hermetic (stdlib only, no network) so the CI job never flakes.

Usage: python3 scripts/check_bench_routing.py <BENCH_routing.json>
       python3 scripts/check_bench_routing.py --selftest
Exit code 0 when every check passes, 1 otherwise (one line per error).
"""

import json
import sys
from pathlib import Path

MAX_FLIPS = 4
REGIME_NUMBER_FIELDS = [
    "steps",
    "flips",
    "converged_at",
    "post_convergence_p50_us",
    "best_static_p50_us",
    "p50_ratio",
]


def _num(v):
    return isinstance(v, (int, float)) and not isinstance(v, bool)


def validate(text: str, origin: str = "<input>") -> list:
    errors = []
    try:
        report = json.loads(text)
    except json.JSONDecodeError as e:
        return [f"{origin}: not valid JSON: {e}"]
    if not isinstance(report, dict):
        return [f"{origin}: top level is not an object"]

    if report.get("bench") != "routing_adaptation":
        errors.append(f"{origin}: bench != routing_adaptation: {report.get('bench')!r}")

    bar = report.get("acceptance_bar_ratio")
    if not _num(bar) or bar <= 1.0:
        errors.append(f"{origin}: acceptance_bar_ratio missing or <= 1: {bar!r}")
        bar = None

    regimes = report.get("regimes")
    if not isinstance(regimes, list) or not regimes:
        return errors + [f"{origin}: missing/empty regimes array"]

    for i, entry in enumerate(regimes):
        if not isinstance(entry, dict):
            errors.append(f"{origin}: regimes[{i}] is not an object")
            continue
        name = entry.get("regime")
        tag = f"{origin}: regimes[{i}] ({name!r})"
        if not isinstance(name, str) or not name:
            errors.append(f"{tag}: missing regime name")
        bad = False
        for field in REGIME_NUMBER_FIELDS:
            v = entry.get(field)
            if not _num(v):
                errors.append(f"{tag}: {field} missing or not a number: {v!r}")
                bad = True
        if bad:
            continue
        if entry["steps"] <= 0:
            errors.append(f"{tag}: steps not positive: {entry['steps']}")
        if not 0 <= entry["flips"] <= MAX_FLIPS:
            errors.append(f"{tag}: flips {entry['flips']} outside [0, {MAX_FLIPS}]")
        if not 0 <= entry["converged_at"] <= entry["steps"]:
            errors.append(
                f"{tag}: converged_at {entry['converged_at']} outside the trace "
                f"(steps={entry['steps']})"
            )
        post = entry["post_convergence_p50_us"]
        best = entry["best_static_p50_us"]
        ratio = entry["p50_ratio"]
        if post <= 0 or best <= 0:
            errors.append(f"{tag}: p50s must be positive: post={post} best={best}")
            continue
        if abs(ratio - post / best) > 0.01:
            errors.append(f"{tag}: p50_ratio {ratio} inconsistent with {post}/{best}")
        if bar is not None and ratio > bar:
            errors.append(f"{tag}: p50_ratio {ratio} exceeds acceptance bar {bar}")
    return errors


VALID_FIXTURE = json.dumps(
    {
        "bench": "routing_adaptation",
        "quick": False,
        "acceptance_bar_ratio": 1.10,
        "regimes": [
            {
                "regime": "stationary",
                "steps": 400,
                "flips": 1,
                "converged_at": 31,
                "post_convergence_p50_us": 254.1,
                "best_static_p50_us": 249.8,
                "p50_ratio": 1.0172,
            },
            {
                "regime": "stationary_shift",
                "steps": 400,
                "flips": 2,
                "converged_at": 223,
                "post_convergence_p50_us": 256.3,
                "best_static_p50_us": 250.4,
                "p50_ratio": 1.0236,
            },
        ],
    }
)

INVALID_FIXTURES = {
    "not json": "{ nope",
    "wrong bench": VALID_FIXTURE.replace(
        '"bench": "routing_adaptation"', '"bench": "mystery"'
    ),
    "bad bar": VALID_FIXTURE.replace('"acceptance_bar_ratio": 1.1', '"acceptance_bar_ratio": 0.5'),
    "empty regimes": VALID_FIXTURE.replace(
        VALID_FIXTURE[VALID_FIXTURE.index("[") : VALID_FIXTURE.rindex("]") + 1], "[]"
    ),
    "missing p50": VALID_FIXTURE.replace('"post_convergence_p50_us": 254.1, ', "", 1),
    "flapping": VALID_FIXTURE.replace('"flips": 2', '"flips": 9'),
    "late convergence": VALID_FIXTURE.replace('"converged_at": 223', '"converged_at": 9000'),
    "ratio over bar": VALID_FIXTURE.replace(
        '"post_convergence_p50_us": 256.3', '"post_convergence_p50_us": 756.3'
    ).replace('"p50_ratio": 1.0236', '"p50_ratio": 3.0204'),
    "inconsistent ratio": VALID_FIXTURE.replace('"p50_ratio": 1.0236', '"p50_ratio": 1.08'),
}


def selftest() -> int:
    errs = validate(VALID_FIXTURE, "valid-fixture")
    if errs:
        print("selftest: valid fixture unexpectedly rejected:")
        for e in errs:
            print(f"  {e}")
        return 1
    failed = 0
    for label, fixture in INVALID_FIXTURES.items():
        if not validate(fixture, label):
            print(f"selftest: invalid fixture {label!r} was not caught")
            failed += 1
    print(
        f"selftest: 1 valid + {len(INVALID_FIXTURES)} invalid fixtures: "
        f"{'OK' if not failed else f'{failed} missed'}"
    )
    return 1 if failed else 0


def main() -> int:
    args = sys.argv[1:]
    if not args:
        sys.exit("usage: check_bench_routing.py <BENCH_routing.json> | --selftest")
    if args == ["--selftest"]:
        return selftest()
    errors = []
    for a in args:
        p = Path(a)
        if not p.is_file():
            sys.exit(f"not a file: {a}")
        errors.extend(validate(p.read_text(encoding="utf-8"), str(p)))
    for e in errors:
        print(e)
    print(f"checked {len(args)} report(s): {'OK' if not errors else f'{len(errors)} errors'}")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
