//! The evaluation corpus: a synthetic stand-in for SuiteSparse that spans
//! the axes the paper's evaluation buckets over — total nnz, average
//! nonzeros per row, structural regularity, and value compressibility.

use crate::matrix::csr::Csr;
use crate::matrix::gen::structured::*;
use crate::matrix::gen::{assign_values, gen_graph_csr, GraphModel, ValueDist};
use crate::util::rng::Xoshiro256;

/// One corpus matrix with its provenance.
pub struct CorpusEntry {
    /// Unique name, e.g. `er-d10-n4096-quant256`.
    pub name: String,
    /// Structural family.
    pub family: &'static str,
    /// Value distribution label.
    pub values: String,
    /// The matrix.
    pub csr: Csr,
}

/// Corpus scale knob: `max_nnz` bounds the largest matrices (tests use a
/// small value; the bench harness uses the full default).
#[derive(Debug, Clone, Copy)]
pub struct CorpusScale {
    /// Upper bound on per-matrix nonzeros.
    pub max_nnz: usize,
    /// Log-spaced size steps per family.
    pub steps: usize,
}

impl Default for CorpusScale {
    fn default() -> Self {
        CorpusScale {
            max_nnz: 4 << 20, // ~4.2M nnz ceiling per matrix
            steps: 6,
        }
    }
}

impl CorpusScale {
    /// A small corpus for unit tests.
    pub fn small() -> Self {
        CorpusScale {
            max_nnz: 40_000,
            steps: 3,
        }
    }

    fn sizes(&self, min_nnz: usize) -> Vec<usize> {
        // Log-spaced nnz targets from min_nnz to max_nnz.
        let mut v = Vec::new();
        let lo = (min_nnz as f64).ln();
        let hi = (self.max_nnz as f64).ln();
        for i in 0..self.steps {
            let t = if self.steps == 1 { 0.0 } else { i as f64 / (self.steps - 1) as f64 };
            v.push((lo + t * (hi - lo)).exp() as usize);
        }
        v.dedup();
        v
    }
}

fn vdist_for(idx: usize) -> ValueDist {
    // Rotate value distributions so every family covers the spectrum from
    // pattern matrices to incompressible values.
    match idx % 5 {
        0 => ValueDist::Ones,
        1 => ValueDist::FewDistinct(16),
        2 => ValueDist::Quantized(256),
        3 => ValueDist::SmallInts(8),
        _ => ValueDist::Gaussian,
    }
}

/// Build the corpus. Deterministic for a given seed and scale.
pub fn build_corpus(scale: &CorpusScale, seed: u64) -> Vec<CorpusEntry> {
    let mut rng = Xoshiro256::seeded(seed);
    let mut out: Vec<CorpusEntry> = Vec::new();
    let mut idx = 0usize;
    let mut push = |name: String, family: &'static str, mut csr: Csr, rng: &mut Xoshiro256, idx: &mut usize| {
        let vd = vdist_for(*idx);
        assign_values(&mut csr, vd, rng);
        out.push(CorpusEntry {
            name: format!("{name}-{}", vd.label()),
            family,
            values: vd.label(),
            csr,
        });
        *idx += 1;
    };

    for &nnz in &scale.sizes(256) {
        // Tridiagonal / banded: annzpr ~3 and ~2bw+1.
        let n = (nnz / 3).max(4);
        push(format!("tridiag-n{n}"), "banded", tridiagonal(n), &mut rng, &mut idx);
        let bw = 8;
        let n = (nnz / (2 * bw + 1)).max(4);
        push(format!("banded{bw}-n{n}"), "banded", banded(n, bw), &mut rng, &mut idx);

        // Stencils: 5-point 2D and 27-point 3D.
        let side = ((nnz / 5) as f64).sqrt() as usize;
        if side >= 4 {
            push(
                format!("stencil5-{side}x{side}"),
                "stencil",
                stencil2d5(side, side),
                &mut rng,
                &mut idx,
            );
        }
        let side3 = ((nnz / 27) as f64).cbrt() as usize;
        if side3 >= 3 {
            push(
                format!("stencil27-{side3}^3"),
                "stencil",
                stencil3d27(side3, side3, side3),
                &mut rng,
                &mut idx,
            );
        }

        // Random graphs at the paper's three degrees.
        for &deg in &[5.0, 10.0, 20.0] {
            let n = ((nnz as f64) / deg) as usize;
            if n >= 64 {
                let model = match idx % 3 {
                    0 => GraphModel::ErdosRenyi,
                    1 => GraphModel::WattsStrogatz,
                    _ => GraphModel::BarabasiAlbert,
                };
                let m = gen_graph_csr(model, n, deg, &mut rng);
                push(
                    format!("{}-d{deg}-n{n}", model.label().to_lowercase()),
                    "graph",
                    m,
                    &mut rng,
                    &mut idx,
                );
            }
        }

        // Blocks (FEM-like), power-law rows, sparse-random, diagonal.
        let bs = 8;
        let nb = ((nnz as f64 / (bs * bs) as f64).sqrt() as usize).max(2);
        push(
            format!("block{bs}-n{}", nb * bs),
            "block",
            block_random(nb * bs, bs, 0.3, &mut rng),
            &mut rng,
            &mut idx,
        );
        let n = (nnz / 8).max(32);
        push(
            format!("powerlaw-n{n}"),
            "powerlaw",
            powerlaw_rows(n, 8.0, 1.1, &mut rng),
            &mut rng,
            &mut idx,
        );
        let n = (nnz / 2).max(16);
        push(
            format!("sparse-random-n{n}"),
            "random",
            random_uniform(n, n, nnz, &mut rng),
            &mut rng,
            &mut idx,
        );
        // One-nonzero-per-row permutation: the Fig. 6 "2x line" group.
        let n = nnz.max(16);
        let mut coo = crate::matrix::coo::Coo::new(n, n);
        for i in 0..n {
            coo.push(i as u32, ((i * 2654435761) % n) as u32, 1.0);
        }
        push(
            format!("permutation-n{n}"),
            "diagonal",
            Csr::from_coo(&coo),
            &mut rng,
            &mut idx,
        );
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_corpus_builds_and_is_diverse() {
        let corpus = build_corpus(&CorpusScale::small(), 1);
        assert!(corpus.len() >= 20, "{}", corpus.len());
        for e in &corpus {
            e.csr.validate().unwrap();
            assert!(e.csr.nnz() <= 3 * CorpusScale::small().max_nnz);
        }
        // Several families and several value distributions present.
        let fams: std::collections::HashSet<_> = corpus.iter().map(|e| e.family).collect();
        assert!(fams.len() >= 5);
        let vals: std::collections::HashSet<_> = corpus.iter().map(|e| e.values.clone()).collect();
        assert!(vals.len() >= 4);
    }

    #[test]
    fn deterministic() {
        let a = build_corpus(&CorpusScale::small(), 7);
        let b = build_corpus(&CorpusScale::small(), 7);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.name, y.name);
            assert_eq!(x.csr, y.csr);
        }
    }

    #[test]
    fn spans_annzpr_buckets() {
        let corpus = build_corpus(&CorpusScale::small(), 1);
        assert!(corpus.iter().any(|e| e.csr.annzpr() <= 10.0));
        assert!(corpus.iter().any(|e| e.csr.annzpr() > 10.0));
    }
}
