//! Timing + micro-benchmark statistics (criterion is not available offline).

use std::time::Instant;

/// Run `f` once and return (result, seconds).
pub fn time_once<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let r = f();
    (r, t0.elapsed().as_secs_f64())
}

/// Summary statistics of repeated timed runs (seconds).
#[derive(Debug, Clone)]
pub struct BenchStats {
    /// Median runtime in seconds.
    pub median: f64,
    /// Minimum runtime.
    pub min: f64,
    /// Maximum runtime.
    pub max: f64,
    /// Median absolute deviation.
    pub mad: f64,
    /// Number of measured iterations.
    pub iters: usize,
}

impl BenchStats {
    /// Format as `median ± mad` with human units.
    pub fn display(&self) -> String {
        format!(
            "{} ± {} (n={})",
            humanize_secs(self.median),
            humanize_secs(self.mad),
            self.iters
        )
    }
}

/// Human-readable seconds.
pub fn humanize_secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Benchmark `f`: `warmup` unmeasured runs, then measured runs until both
/// `min_iters` iterations and `min_secs` total measured seconds are reached
/// (mirrors criterion's warmup/measure split, medians for robustness).
pub fn bench<T>(warmup: usize, min_iters: usize, min_secs: f64, mut f: impl FnMut() -> T) -> BenchStats {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples = Vec::new();
    let mut total = 0.0;
    while samples.len() < min_iters || total < min_secs {
        let t0 = Instant::now();
        std::hint::black_box(f());
        let dt = t0.elapsed().as_secs_f64();
        samples.push(dt);
        total += dt;
        if samples.len() > 10_000 {
            break;
        }
    }
    stats_from(&mut samples)
}

/// Compute [`BenchStats`] from raw samples (sorts in place).
pub fn stats_from(samples: &mut [f64]) -> BenchStats {
    assert!(!samples.is_empty());
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = samples[samples.len() / 2];
    let mut devs: Vec<f64> = samples.iter().map(|x| (x - median).abs()).collect();
    devs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    BenchStats {
        median,
        min: samples[0],
        max: samples[samples.len() - 1],
        mad: devs[devs.len() / 2],
        iters: samples.len(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_median() {
        let mut s = vec![3.0, 1.0, 2.0];
        let st = stats_from(&mut s);
        assert_eq!(st.median, 2.0);
        assert_eq!(st.min, 1.0);
        assert_eq!(st.max, 3.0);
    }

    #[test]
    fn bench_runs() {
        let st = bench(1, 3, 0.0, || 1 + 1);
        assert!(st.iters >= 3);
        assert!(st.median >= 0.0);
    }

    #[test]
    fn humanize() {
        assert!(humanize_secs(2.0).contains("s"));
        assert!(humanize_secs(2e-3).contains("ms"));
        assert!(humanize_secs(2e-6).contains("µs"));
        assert!(humanize_secs(2e-9).contains("ns"));
    }
}
