#!/usr/bin/env python3
"""Markdown link checker for the repository's documentation set.

Checks every relative link and in-document anchor in the given markdown
files/directories; external (http/https/mailto) links are skipped — the
job must stay hermetic so CI never flakes on the network.

Usage: python3 scripts/check_links.py README.md docs
Exit code 0 when every link resolves, 1 otherwise (one line per dead
link).
"""

import re
import sys
import unicodedata
from pathlib import Path

# [text](target) — target up to the first closing paren (no nested
# parens in our docs); reference-style links are not used in this repo.
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
HEADING_RE = re.compile(r"^(#{1,6})\s+(.*?)\s*#*\s*$")
CODE_FENCE_RE = re.compile(r"^(```|~~~)")


def github_anchor(heading: str) -> str:
    """GitHub's anchor slug: strip markup, lowercase, drop punctuation,
    spaces to dashes."""
    text = re.sub(r"[*_`]|\[([^\]]*)\]\([^)]*\)", r"\1", heading)
    text = unicodedata.normalize("NFKD", text)
    out = []
    for ch in text.lower():
        if ch.isalnum():
            out.append(ch)
        elif ch in (" ", "-"):
            out.append("-" if ch == " " else ch)
        # other punctuation is dropped
    return "".join(out)


def anchors_of(path: Path) -> set[str]:
    anchors: set[str] = set()
    seen: dict[str, int] = {}
    in_fence = False
    for line in path.read_text(encoding="utf-8").splitlines():
        if CODE_FENCE_RE.match(line):
            in_fence = not in_fence
            continue
        if in_fence:
            continue
        m = HEADING_RE.match(line)
        if not m:
            continue
        slug = github_anchor(m.group(2))
        n = seen.get(slug, 0)
        seen[slug] = n + 1
        anchors.add(slug if n == 0 else f"{slug}-{n}")
    return anchors


def links_of(path: Path):
    in_fence = False
    for lineno, line in enumerate(path.read_text(encoding="utf-8").splitlines(), 1):
        if CODE_FENCE_RE.match(line):
            in_fence = not in_fence
            continue
        if in_fence:
            continue
        # Inline code spans can contain bracket/paren sequences that look
        # like links (e.g. `spmv[_with_plan](…)`): drop them first.
        for m in LINK_RE.finditer(re.sub(r"`[^`]*`", "", line)):
            yield lineno, m.group(1)


def collect_files(args: list[str]) -> list[Path]:
    files: list[Path] = []
    for a in args:
        p = Path(a)
        if p.is_dir():
            files.extend(sorted(p.rglob("*.md")))
        elif p.suffix == ".md":
            files.append(p)
        else:
            sys.exit(f"not a markdown file or directory: {a}")
    return files


def main() -> int:
    args = sys.argv[1:] or ["README.md", "docs"]
    files = collect_files(args)
    errors = []
    checked = 0
    for md in files:
        for lineno, target in links_of(md):
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            checked += 1
            raw_path, _, fragment = target.partition("#")
            dest = md if not raw_path else (md.parent / raw_path).resolve()
            if not dest.exists():
                errors.append(f"{md}:{lineno}: dead link {target!r} ({dest} missing)")
                continue
            if fragment:
                if dest.suffix != ".md":
                    errors.append(f"{md}:{lineno}: anchor on non-markdown target {target!r}")
                elif fragment.lower() not in anchors_of(dest):
                    errors.append(f"{md}:{lineno}: dead anchor {target!r} in {dest.name}")
    for e in errors:
        print(e)
    print(f"checked {checked} relative links across {len(files)} files: "
          f"{'OK' if not errors else f'{len(errors)} dead'}")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
