//! Service metrics: request counters and latency quantiles.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Lock-free counters + a mutexed latency reservoir.
#[derive(Debug, Default)]
pub struct Metrics {
    /// Requests accepted.
    pub submitted: AtomicU64,
    /// Requests completed successfully.
    pub completed: AtomicU64,
    /// Requests failed.
    pub failed: AtomicU64,
    /// Batches executed.
    pub batches: AtomicU64,
    latencies_us: Mutex<Vec<u64>>,
}

/// Quantile summary of request latencies.
#[derive(Debug, Clone, Copy, Default)]
pub struct LatencySummary {
    /// Number of samples.
    pub count: usize,
    /// 50th percentile, microseconds.
    pub p50_us: u64,
    /// 99th percentile, microseconds.
    pub p99_us: u64,
    /// Maximum, microseconds.
    pub max_us: u64,
}

impl Metrics {
    /// Record one completed request's latency.
    pub fn record_latency(&self, micros: u64) {
        self.completed.fetch_add(1, Ordering::Relaxed);
        let mut l = self.latencies_us.lock().unwrap();
        // Bounded reservoir: keep the most recent 64k samples.
        if l.len() >= 65536 {
            l.drain(..32768);
        }
        l.push(micros);
    }

    /// Quantile summary over the recorded reservoir.
    pub fn latency_summary(&self) -> LatencySummary {
        let mut l = self.latencies_us.lock().unwrap().clone();
        if l.is_empty() {
            return LatencySummary::default();
        }
        l.sort_unstable();
        let q = |p: f64| l[((l.len() - 1) as f64 * p) as usize];
        LatencySummary {
            count: l.len(),
            p50_us: q(0.50),
            p99_us: q(0.99),
            max_us: *l.last().unwrap(),
        }
    }

    /// One-line human-readable report.
    pub fn report(&self) -> String {
        let s = self.latency_summary();
        format!(
            "submitted={} completed={} failed={} batches={} p50={}µs p99={}µs max={}µs",
            self.submitted.load(Ordering::Relaxed),
            self.completed.load(Ordering::Relaxed),
            self.failed.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            s.p50_us,
            s.p99_us,
            s.max_us
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantiles() {
        let m = Metrics::default();
        for i in 1..=100 {
            m.record_latency(i);
        }
        let s = m.latency_summary();
        assert_eq!(s.count, 100);
        assert!((49..=51).contains(&s.p50_us));
        assert!(s.p99_us >= 98);
        assert_eq!(s.max_us, 100);
    }

    #[test]
    fn empty_summary() {
        let m = Metrics::default();
        assert_eq!(m.latency_summary().count, 0);
        assert!(m.report().contains("submitted=0"));
    }
}
