//! Deterministic admission-control suite for the serving core: queue-full
//! shedding, deadline expiry, per-tenant quotas, priority ordering, and
//! cross-request coalescing — with no sleeps-as-synchronization anywhere.
//!
//! Determinism comes from two mechanisms instead of timing:
//!
//! * the **pause gate** ([`SpmvService::pause_dispatch`]): requests are
//!   staged behind a paused dispatcher, so the exact queue state at
//!   release is known — N same-matrix requests staged together *must*
//!   dispatch as one coalesced batch;
//! * the **elapsed-deadline guarantee**: a deadline of `Instant::now()`
//!   taken at submit is `<=` any later dispatch-time clock reading on a
//!   monotonic clock, so an injected deadline always expires — no
//!   sleeping until a timer fires.
//!
//! Every service test ends by checking the conservation identity
//! `completed + failed + shed + expired == submitted`.

use dtans::coordinator::admission::{
    AdmissionConfig, AdmissionQueue, Priority, QuotaConfig, SubmitOptions,
};
use dtans::coordinator::{RoutePolicy, ServiceConfig, SpmvService};
use dtans::matrix::gen::structured::banded;
use dtans::matrix::gen::{assign_values, ValueDist};
use dtans::spmv::engine::ParStrategy;
use dtans::spmv::spmv_csr;
use dtans::testkit::{run_stress, seeded_vector, zoo, StressConfig, TestkitScale};
use dtans::util::error::DtansError;
use dtans::util::rng::Xoshiro256;
use std::sync::atomic::Ordering;
use std::time::Instant;

/// Assert `completed + failed + shed + expired == submitted` on a
/// service's metrics (the stress driver's oracle 2, inline).
fn assert_conserved(svc: &SpmvService) {
    let m = &svc.metrics;
    let (submitted, completed, failed, shed, expired) = (
        m.submitted.load(Ordering::Relaxed),
        m.completed.load(Ordering::Relaxed),
        m.failed.load(Ordering::Relaxed),
        m.shed.load(Ordering::Relaxed),
        m.expired.load(Ordering::Relaxed),
    );
    assert_eq!(
        completed + failed + shed + expired,
        submitted,
        "conservation violated: submitted={submitted} completed={completed} \
         failed={failed} shed={shed} expired={expired}"
    );
}

#[test]
fn queue_full_sheds_with_typed_overloaded() {
    let svc = SpmvService::start(ServiceConfig {
        admission: AdmissionConfig { queue_depth: 4, ..Default::default() },
        ..Default::default()
    });
    let m = zoo::mixed_zoo().remove(0); // banded 500x500, compressible
    let id = svc.register("zoo0", m.clone()).unwrap();
    // Stage exactly queue_depth requests behind the pause gate...
    svc.pause_dispatch();
    let pendings: Vec<_> = (0..4)
        .map(|i| svc.submit(id, seeded_vector(m.ncols, i)).unwrap())
        .collect();
    assert_eq!(svc.queue_depth(), 4);
    // ...then the 5th MUST shed, with the typed error and the configured
    // depth in it.
    match svc.submit(id, seeded_vector(m.ncols, 99)) {
        Err(DtansError::Overloaded { queue_depth }) => assert_eq!(queue_depth, 4),
        other => panic!("expected Overloaded, got {:?}", other.map(|_| "pending")),
    }
    assert_eq!(svc.metrics.shed.load(Ordering::Relaxed), 1);
    assert_eq!(svc.metrics.queue_depth_peak.load(Ordering::Relaxed), 4);
    // Releasing the gate serves the admitted four, bit-identical to the
    // CSR ground truth.
    svc.resume_dispatch();
    for (i, p) in pendings.into_iter().enumerate() {
        let got = p.wait().unwrap();
        let mut want = vec![0.0; m.nrows];
        spmv_csr(&m, &seeded_vector(m.ncols, i as u64), &mut want).unwrap();
        assert_eq!(got, want, "request {i} diverged");
    }
    assert_eq!(svc.metrics.completed.load(Ordering::Relaxed), 4);
    assert_conserved(&svc);
}

#[test]
fn deadline_expires_before_execution_not_at_submit() {
    let svc = SpmvService::start(ServiceConfig::default());
    let m = zoo::mixed_zoo().remove(0);
    let id = svc.register("zoo0", m.clone()).unwrap();
    svc.pause_dispatch();
    // An already-elapsed deadline is ADMITTED (deadlines are not checked
    // at submit — one expiry point, at dispatch)...
    let doomed = svc
        .submit_with(
            id,
            seeded_vector(m.ncols, 1),
            SubmitOptions { deadline: Some(Instant::now()), ..Default::default() },
        )
        .unwrap();
    // ...alongside a deadline-free request and one with a far future
    // deadline, which must both survive.
    let fine = svc.submit(id, seeded_vector(m.ncols, 2)).unwrap();
    let roomy = svc
        .submit_with(
            id,
            seeded_vector(m.ncols, 3),
            SubmitOptions {
                deadline: Some(Instant::now() + std::time::Duration::from_secs(3600)),
                ..Default::default()
            },
        )
        .unwrap();
    assert_eq!(svc.queue_depth(), 3);
    svc.resume_dispatch();
    match doomed.wait() {
        Err(DtansError::DeadlineExceeded) => {}
        other => panic!("expected DeadlineExceeded, got {other:?}"),
    }
    assert_eq!(fine.wait().unwrap().len(), m.nrows);
    assert_eq!(roomy.wait().unwrap().len(), m.nrows);
    assert_eq!(svc.metrics.expired.load(Ordering::Relaxed), 1);
    // The expired request never executed: exactly two completions, no
    // failures, and shed stayed zero (expiry is not a shed).
    assert_eq!(svc.metrics.completed.load(Ordering::Relaxed), 2);
    assert_eq!(svc.metrics.failed.load(Ordering::Relaxed), 0);
    assert_eq!(svc.metrics.shed.load(Ordering::Relaxed), 0);
    assert_conserved(&svc);
}

#[test]
fn per_tenant_quota_sheds_with_typed_error() {
    let svc = SpmvService::start(ServiceConfig {
        admission: AdmissionConfig {
            queue_depth: 64,
            // refill 0: the bucket is a fixed budget of 3 admissions —
            // fully deterministic, no clock dependence.
            quotas: vec![("acme".into(), QuotaConfig { burst: 3.0, refill_per_sec: 0.0 })],
            ..Default::default()
        },
        ..Default::default()
    });
    let m = zoo::mixed_zoo().remove(0);
    let id = svc.register("zoo0", m.clone()).unwrap();
    let acme = || SubmitOptions { tenant: Some("acme".into()), ..Default::default() };
    let mut pendings = Vec::new();
    for i in 0..3 {
        pendings.push(svc.submit_with(id, seeded_vector(m.ncols, i), acme()).unwrap());
    }
    match svc.submit_with(id, seeded_vector(m.ncols, 3), acme()) {
        Err(DtansError::QuotaExceeded { tenant }) => assert_eq!(tenant, "acme"),
        other => panic!("expected QuotaExceeded, got {:?}", other.map(|_| "pending")),
    }
    // Other tenants and tenant-less traffic are unaffected.
    let other_tenant = SubmitOptions { tenant: Some("umbrella".into()), ..Default::default() };
    pendings.push(svc.submit_with(id, seeded_vector(m.ncols, 4), other_tenant).unwrap());
    pendings.push(svc.submit(id, seeded_vector(m.ncols, 5)).unwrap());
    for p in pendings {
        assert_eq!(p.wait().unwrap().len(), m.nrows);
    }
    assert_eq!(svc.metrics.shed.load(Ordering::Relaxed), 1);
    assert_eq!(svc.metrics.quota_rejected.load(Ordering::Relaxed), 1);
    assert_conserved(&svc);
}

#[test]
fn strict_priority_with_fifo_within_each_lane() {
    // Ordering is asserted on the AdmissionQueue directly (distinct
    // matrices, so every take_batch pops exactly one request and the
    // full pop sequence is observable without racing a dispatcher).
    let q: AdmissionQueue<usize> = AdmissionQueue::new(&AdmissionConfig {
        queue_depth: 16,
        ..Default::default()
    });
    let with = |p: Priority| SubmitOptions { priority: p, ..Default::default() };
    let plan = [
        (Priority::Low, 0),
        (Priority::Normal, 1),
        (Priority::High, 2),
        (Priority::Low, 3),
        (Priority::High, 4),
        (Priority::Normal, 5),
    ];
    for (prio, tag) in plan {
        q.push(tag as u64, &with(prio), tag).unwrap();
    }
    let mut order = Vec::new();
    while let Some(batch) = (!q.is_empty()).then(|| q.take_batch(16).unwrap()) {
        assert_eq!(batch.len(), 1);
        order.push(batch[0].payload);
    }
    // All High (submit order), then all Normal, then all Low.
    assert_eq!(order, vec![2, 4, 1, 5, 0, 3]);
}

#[test]
fn coalescing_n_concurrent_submits_one_engine_batch() {
    // The headline observability contract: N same-matrix requests staged
    // together reach the engine as exactly ONE SpMM batch. Fixed(2)
    // keeps will_batch_parallel() true regardless of matrix size, so the
    // SpMM decision is deterministic.
    let svc = SpmvService::start(ServiceConfig {
        par: ParStrategy::Fixed(2),
        ..Default::default()
    });
    let m = zoo::mixed_zoo().remove(0);
    let id = svc.register("zoo0", m.clone()).unwrap();
    // Warm-up: the first request also faults nothing (store is RAM-only
    // here) but gives a known baseline for the batch counters.
    svc.spmv(id, seeded_vector(m.ncols, 100)).unwrap();
    let batches0 = svc.metrics.batches.load(Ordering::Relaxed);
    let coalesced0 = svc.metrics.coalesced_batches.load(Ordering::Relaxed);

    svc.pause_dispatch();
    let pendings: Vec<_> = (0..6)
        .map(|i| svc.submit(id, seeded_vector(m.ncols, i)).unwrap())
        .collect();
    svc.resume_dispatch();
    for (i, p) in pendings.into_iter().enumerate() {
        let got = p.wait().unwrap();
        let mut want = vec![0.0; m.nrows];
        spmv_csr(&m, &seeded_vector(m.ncols, i as u64), &mut want).unwrap();
        assert_eq!(got, want, "request {i} diverged under coalescing");
    }
    assert_eq!(
        svc.metrics.batches.load(Ordering::Relaxed) - batches0,
        1,
        "6 staged same-matrix requests must dispatch as one batch"
    );
    assert_eq!(svc.metrics.coalesced_batches.load(Ordering::Relaxed) - coalesced0, 1);
    assert_eq!(svc.metrics.coalesced_requests.load(Ordering::Relaxed), 6);
    assert_conserved(&svc);
}

#[test]
fn coalescing_gathers_across_interleaved_matrices() {
    // A,B,A,B,A,B staged together must dispatch as TWO batches (all of A,
    // then all of B) — the old consecutive-only batcher would have made
    // six. This is the cross-request (not just consecutive) guarantee.
    let svc = SpmvService::start(ServiceConfig {
        par: ParStrategy::Fixed(2),
        ..Default::default()
    });
    let mut zoo_mats = zoo::mixed_zoo();
    let b = zoo_mats.remove(1); // banded 700x700
    let a = zoo_mats.remove(0); // banded 500x500
    let ida = svc.register("a", a.clone()).unwrap();
    let idb = svc.register("b", b.clone()).unwrap();
    let batches0 = svc.metrics.batches.load(Ordering::Relaxed);

    svc.pause_dispatch();
    let mut pendings = Vec::new();
    for i in 0..3u64 {
        pendings.push((ida, i, svc.submit(ida, seeded_vector(a.ncols, i)).unwrap()));
        pendings.push((idb, i, svc.submit(idb, seeded_vector(b.ncols, i)).unwrap()));
    }
    assert_eq!(svc.queue_depth(), 6);
    svc.resume_dispatch();
    for (mid, i, p) in pendings {
        let mref = if mid == ida { &a } else { &b };
        let got = p.wait().unwrap();
        let mut want = vec![0.0; mref.nrows];
        spmv_csr(mref, &seeded_vector(mref.ncols, i), &mut want).unwrap();
        assert_eq!(got, want);
    }
    assert_eq!(
        svc.metrics.batches.load(Ordering::Relaxed) - batches0,
        2,
        "interleaved A/B/A/B/A/B must coalesce into exactly two batches"
    );
    assert_eq!(svc.metrics.coalesced_batches.load(Ordering::Relaxed), 2);
    assert_eq!(svc.metrics.coalesced_requests.load(Ordering::Relaxed), 6);
    assert_conserved(&svc);
}

#[test]
fn coalesced_spmm_is_bit_identical_to_per_request_spmv() {
    // The docs/SERVING.md caveat, tested: a coalesced SpMM batch and N
    // independent SpMV requests produce bit-identical outputs, per
    // format (the PR-3 run_multi guarantee, end to end through
    // admission). Exercise both router outcomes: a compressible banded
    // matrix above the dtANS threshold and a small CSR-routed one.
    let policy = RoutePolicy { min_nnz: 1 << 10, max_size_ratio: 0.95, ..Default::default() };
    let mut big = banded(4000, 2);
    assign_values(&mut big, ValueDist::FewDistinct(6), &mut Xoshiro256::seeded(11));
    // 744 nnz < the policy's 1024 floor -> guaranteed CSR routing.
    let small = banded(150, 2);
    for (name, m) in [("dtans-routed", big), ("csr-routed", small)] {
        // Coalesced run: everything staged, one SpMM batch.
        let svc = SpmvService::start(ServiceConfig {
            par: ParStrategy::Fixed(2),
            policy,
            ..Default::default()
        });
        let id = svc.register(name, m.clone()).unwrap();
        svc.pause_dispatch();
        let pendings: Vec<_> = (0..5)
            .map(|i| svc.submit(id, seeded_vector(m.ncols, 40 + i)).unwrap())
            .collect();
        svc.resume_dispatch();
        let coalesced: Vec<Vec<f64>> = pendings.into_iter().map(|p| p.wait().unwrap()).collect();
        assert_eq!(svc.metrics.coalesced_batches.load(Ordering::Relaxed), 1, "{name}");

        // Per-request run: a serial, unbatched service of the same
        // routing — requests submitted one at a time.
        let serial = SpmvService::start(ServiceConfig {
            workers: 1,
            par: ParStrategy::Serial,
            policy,
            ..Default::default()
        });
        let sid = serial.register(name, m.clone()).unwrap();
        for (i, batched) in coalesced.iter().enumerate() {
            let want = serial.spmv(sid, seeded_vector(m.ncols, 40 + i as u64)).unwrap();
            assert_eq!(batched, &want, "{name}: request {i} not bit-identical");
        }
        assert_conserved(&svc);
    }
}

#[test]
fn open_loop_stress_driver_passes_all_oracles() {
    // The serving lane's stress entry: open-loop arrivals against a
    // small queue, deterministic elapsed-deadline injection, and the
    // extended conservation oracle
    // (completed + failed + shed + expired == submitted), at the scale
    // TESTKIT_SCALE selects (CI: small).
    let cfg = StressConfig::open_loop_for_scale(TestkitScale::from_env());
    let report = run_stress(&cfg).expect("open-loop stress run violated an oracle");
    assert_eq!(report.ops_executed, cfg.ops);
    assert!(
        report.spmv_checked + report.spmm_checked + report.solves_checked > 0,
        "open-loop run compared nothing"
    );
    // The deterministic trace for the default seed injects elapsed
    // deadlines on base-fixture spmv ops (vseed % 16 == 0), and an
    // injected deadline on an *admitted* request always expires; shed
    // requests are also fine — either way the request must not execute,
    // which the conservation + replay oracles inside run_stress enforce.
    println!(
        "open-loop stress: {} spmv / {} spmm / {} solves checked, {} shed, {} expired",
        report.spmv_checked,
        report.spmm_checked,
        report.solves_checked,
        report.shed,
        report.expired
    );
}
