//! Sliced ELLPACK (SELL) format — groups of `slice_height` rows padded to
//! the slice-local maximum row length and stored column-major, the
//! SIMD/GPU-friendly format the paper compares against.

use super::csr::Csr;

/// SELL matrix with fixed slice height (32 matches a warp, as in the
/// paper's setting; cuSPARSE SELL also uses warp-sized slices).
#[derive(Debug, Clone, PartialEq)]
pub struct Sell {
    /// Number of rows / columns of the logical matrix.
    pub nrows: usize,
    /// Number of columns.
    pub ncols: usize,
    /// Rows per slice.
    pub slice_height: usize,
    /// Width (max row length) of each slice.
    pub slice_widths: Vec<u32>,
    /// Start offset of each slice in `cols`/`vals` (length = nslices + 1).
    pub slice_ptr: Vec<usize>,
    /// Column indices, column-major within a slice; padding uses the row's
    /// last valid column (benign duplicate reads, zero value).
    pub cols: Vec<u32>,
    /// Values, column-major within a slice; padding is 0.0.
    pub vals: Vec<f64>,
    /// Per-row actual lengths (needed to ignore padding).
    pub row_lens: Vec<u32>,
}

impl Sell {
    /// Number of slices.
    pub fn nslices(&self) -> usize {
        self.slice_widths.len()
    }

    /// Total padded cells.
    pub fn padded_cells(&self) -> usize {
        self.vals.len()
    }

    /// Build from CSR with the given slice height.
    pub fn from_csr(csr: &Csr, slice_height: usize) -> Sell {
        assert!(slice_height > 0);
        let nslices = csr.nrows.div_ceil(slice_height.max(1)).max(0);
        let mut slice_widths = Vec::with_capacity(nslices);
        let mut slice_ptr = vec![0usize];
        let mut cols = Vec::new();
        let mut vals = Vec::new();
        let row_lens: Vec<u32> = (0..csr.nrows).map(|r| csr.row_len(r) as u32).collect();
        for s in 0..nslices {
            let r0 = s * slice_height;
            let r1 = (r0 + slice_height).min(csr.nrows);
            let width = (r0..r1).map(|r| csr.row_len(r)).max().unwrap_or(0);
            slice_widths.push(width as u32);
            // Column-major: for each position j, all rows of the slice.
            for j in 0..width {
                for rr in 0..slice_height {
                    let r = r0 + rr;
                    if r < r1 && j < csr.row_len(r) {
                        cols.push(csr.row_cols(r)[j]);
                        vals.push(csr.row_vals(r)[j]);
                    } else {
                        // Padding: repeat a valid column (or 0) with value 0.
                        let pad_col = if r < r1 && csr.row_len(r) > 0 {
                            *csr.row_cols(r).last().unwrap()
                        } else {
                            0
                        };
                        cols.push(pad_col);
                        vals.push(0.0);
                    }
                }
            }
            slice_ptr.push(cols.len());
        }
        Sell {
            nrows: csr.nrows,
            ncols: csr.ncols,
            slice_height,
            slice_widths,
            slice_ptr,
            cols,
            vals,
            row_lens,
        }
    }

    /// Convert back to CSR (drops padding) — used by tests.
    pub fn to_csr(&self) -> Csr {
        let mut coo = super::coo::Coo::new(self.nrows, self.ncols);
        for s in 0..self.nslices() {
            let r0 = s * self.slice_height;
            let width = self.slice_widths[s] as usize;
            let base = self.slice_ptr[s];
            for j in 0..width {
                for rr in 0..self.slice_height {
                    let r = r0 + rr;
                    if r < self.nrows && (j as u32) < self.row_lens[r] {
                        let idx = base + j * self.slice_height + rr;
                        coo.push(r as u32, self.cols[idx], self.vals[idx]);
                    }
                }
            }
        }
        Csr::from_coo(&coo)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::coo::Coo;

    fn example() -> Csr {
        let mut coo = Coo::new(5, 6);
        for &(r, c, v) in &[
            (0, 0, 1.0),
            (0, 5, 2.0),
            (1, 2, 3.0),
            (2, 0, 4.0),
            (2, 1, 5.0),
            (2, 3, 6.0),
            (4, 4, 7.0),
        ] {
            coo.push(r, c, v);
        }
        Csr::from_coo(&coo)
    }

    #[test]
    fn roundtrip() {
        let m = example();
        let sell = Sell::from_csr(&m, 2);
        assert_eq!(sell.to_csr(), m);
    }

    #[test]
    fn slice_widths_are_local_maxima() {
        let m = example();
        let sell = Sell::from_csr(&m, 2);
        // slices: rows {0,1} width 2; {2,3} width 3; {4} width 1
        assert_eq!(sell.slice_widths, vec![2, 3, 1]);
        assert_eq!(sell.padded_cells(), 2 * 2 + 3 * 2 + 1 * 2);
    }

    #[test]
    fn warp_sized_slices() {
        let m = example();
        let sell = Sell::from_csr(&m, 32);
        assert_eq!(sell.nslices(), 1);
        assert_eq!(sell.to_csr(), m);
    }

    #[test]
    fn empty_matrix() {
        let m = Csr::new(0, 0);
        let sell = Sell::from_csr(&m, 32);
        assert_eq!(sell.nslices(), 0);
        assert_eq!(sell.padded_cells(), 0);
    }
}
