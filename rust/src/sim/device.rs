//! GPU device models for the execution simulator.

/// Parameters of the modeled GPU. Defaults mirror the paper's testbed, an
/// RTX 5090: 170 SMs, 32 GB GDDR7 at ~1.79 TB/s, 96 MB L2.
#[derive(Debug, Clone, Copy)]
pub struct GpuModel {
    /// Marketing name (reports).
    pub name: &'static str,
    /// Streaming multiprocessors.
    pub sms: u32,
    /// Core clock, GHz.
    pub clock_ghz: f64,
    /// DRAM bandwidth, GB/s.
    pub dram_bw_gbs: f64,
    /// L2 capacity in bytes.
    pub l2_bytes: usize,
    /// L2 line size in bytes (CUDA sector-pair granularity).
    pub l2_line: usize,
    /// L2 associativity.
    pub l2_ways: usize,
    /// Aggregate L2 bandwidth, GB/s (roughly 4-5x DRAM on Ada/Blackwell).
    pub l2_bw_gbs: f64,
    /// Issued instructions per cycle per SM (warp-averaged integer/FMA mix).
    pub ipc_per_sm: f64,
    /// Fixed kernel launch overhead in microseconds.
    pub launch_us: f64,
}

impl GpuModel {
    /// The paper's RTX 5090 testbed.
    pub const RTX5090: GpuModel = GpuModel {
        name: "RTX 5090 (model)",
        sms: 170,
        clock_ghz: 2.4,
        dram_bw_gbs: 1790.0,
        l2_bytes: 96 * 1024 * 1024,
        l2_line: 128,
        l2_ways: 16,
        l2_bw_gbs: 8000.0,
        ipc_per_sm: 2.0,
        launch_us: 3.0,
    };

    /// Peak instruction throughput, instructions/second.
    pub fn instr_rate(&self) -> f64 {
        self.sms as f64 * self.ipc_per_sm * self.clock_ghz * 1e9 * 32.0 // per-lane
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rtx5090_matches_paper_specs() {
        let g = GpuModel::RTX5090;
        assert_eq!(g.sms, 170);
        assert_eq!(g.l2_bytes, 96 * 1024 * 1024);
        assert!(g.instr_rate() > 1e13);
    }
}
