//! Format routing: decide, per registered matrix, whether SpMVM requests
//! run over CSR-dtANS or plain CSR — and hand back the chosen format as
//! an [`SpmvOperator`], the one kernel surface the rest of the
//! coordinator executes against.
//!
//! The policy distills the paper's Tables I–II conclusion: "size is the
//! most important feature to predict whether a matrix is likely to see a
//! speedup; the number of nonzeros per row determines the magnitude" — so
//! dtANS is selected when the matrix is large enough *and* actually
//! compressed (otherwise decode overhead buys nothing).
//!
//! Iterative solves ([`crate::solver`], exposed through
//! [`SpmvService::solve`](crate::coordinator::service::SpmvService::solve))
//! execute against the same per-matrix routing decision: the operator is
//! chosen once at registration and reused for every iteration, so a
//! dtANS route amortizes its one-time plan build across the entire solve
//! while each iteration pays only the (smaller) resident-byte traffic —
//! the repeated-application regime where the paper's compression pays
//! most (see `docs/SOLVERS.md` for when dtANS wins per-iteration).

use crate::format::csr_dtans::{CsrDtans, EncodeOptions};
use crate::matrix::csr::Csr;
use crate::matrix::SizeModel;
use crate::spmv::operator::{DtansOperator, SpmvOperator};
use crate::util::error::{DtansError, Result};
use std::sync::Arc;

/// Routing decision for one matrix.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FormatChoice {
    /// Plain CSR kernel.
    Csr,
    /// Entropy-coded CSR-dtANS kernel.
    CsrDtans,
    /// σ-sorted balanced-block kernel
    /// ([`crate::matrix::BlockedEll`]) — for large matrices whose
    /// row-length skew makes the sort-and-pad layout pay.
    BlockedEll,
}

impl FormatChoice {
    /// The [`SpmvOperator::format_tag`] the choice routes to — the key
    /// used by per-format metrics.
    pub fn tag(&self) -> &'static str {
        match self {
            FormatChoice::Csr => "csr",
            FormatChoice::CsrDtans => "csr_dtans",
            FormatChoice::BlockedEll => "blocked_ell",
        }
    }
}

/// Tunable routing thresholds (defaults follow the paper's findings,
/// scaled down: the paper's crossover is ~2^25 nnz on an RTX 5090; the
/// CPU testbed crossover sits far lower, so the *structure* of the rule is
/// what we reproduce).
#[derive(Debug, Clone, Copy)]
pub struct RoutePolicy {
    /// Minimum nonzeros before compression can pay off.
    pub min_nnz: usize,
    /// Required compressed/baseline size ratio (must be below this).
    pub max_size_ratio: f64,
    /// Row-length coefficient of variation (std/mean) at or above which a
    /// large matrix that would otherwise stay CSR routes to
    /// [`FormatChoice::BlockedEll`] instead — skewed row lengths are where
    /// the σ-sort balancing pays (CMRS / adaptive row-grouped CSR).
    /// Defaults to `f64::INFINITY`: BlockedEll is opt-in and existing
    /// routing behavior is unchanged until a deployment lowers it.
    pub blocked_ell_cv: f64,
}

impl Default for RoutePolicy {
    fn default() -> Self {
        RoutePolicy {
            min_nnz: 1 << 15,
            max_size_ratio: 0.9,
            blocked_ell_cv: f64::INFINITY,
        }
    }
}

/// Coefficient of variation (population std / mean) of `m`'s row lengths;
/// `0.0` for empty or empty-row-only matrices.
fn row_len_cv(m: &Csr) -> f64 {
    if m.nrows == 0 || m.nnz() == 0 {
        return 0.0;
    }
    let mean = m.nnz() as f64 / m.nrows as f64;
    let var = (0..m.nrows)
        .map(|r| {
            let d = m.row_len(r) as f64 - mean;
            d * d
        })
        .sum::<f64>()
        / m.nrows as f64;
    var.sqrt() / mean
}

impl RoutePolicy {
    /// Decide the format for a matrix given its (pre-computed) encoding.
    /// Size rules first (dtANS when large *and* compressed); a large
    /// matrix that stays uncompressed then routes to BlockedEll when its
    /// row-length skew clears [`blocked_ell_cv`](RoutePolicy::blocked_ell_cv).
    pub fn choose(&self, csr: &Csr, enc: &CsrDtans, opts: &EncodeOptions) -> FormatChoice {
        if csr.nnz() < self.min_nnz {
            return FormatChoice::Csr;
        }
        let model = SizeModel {
            precision: opts.precision,
        };
        let (baseline, _) = model.best_baseline_bytes(csr);
        let ratio = enc.size_report().total as f64 / baseline.max(1) as f64;
        if ratio < self.max_size_ratio {
            FormatChoice::CsrDtans
        } else if row_len_cv(csr) >= self.blocked_ell_cv {
            FormatChoice::BlockedEll
        } else {
            FormatChoice::Csr
        }
    }

    /// Decide the format from the encoding alone — for matrices registered
    /// straight from an on-disk artifact
    /// ([`crate::store::MatrixStore::register_path`]) where no CSR
    /// original exists to size up. The baseline is `min(CSR, COO)` from
    /// the dimensions (both computable without the decoded structure;
    /// COO wins whenever `nnz < nrows + 1`, e.g. matrices with many empty
    /// rows). Only SELL is unaccounted for — it beats CSR/COO on size
    /// only for unusually regular matrices, where this rule is then
    /// slightly more permissive than [`RoutePolicy::choose`]. BlockedEll
    /// is never chosen here: the row-length statistics it needs require
    /// the decoded structure, and artifact-registered matrices keep no
    /// CSR original to build it from.
    ///
    /// For the same reason, any *re*-routing layer on top (the adaptive
    /// router, `docs/ROUTING.md`) must not offer such a matrix a
    /// CSR-walk arm at all: use [`RoutePolicy::admissible_for`] to build
    /// the candidate set, and
    /// [`LoadedMatrix::operator_for_choice`](crate::store::LoadedMatrix::operator_for_choice)
    /// turns a violation into the typed
    /// [`DtansError::InadmissibleRoute`] instead of a generic service
    /// error.
    pub fn choose_encoded(&self, enc: &CsrDtans) -> FormatChoice {
        if enc.nnz < self.min_nnz {
            return FormatChoice::Csr;
        }
        let model = SizeModel { precision: enc.precision };
        let baseline = model.csr_bytes(enc.nrows, enc.nnz).min(model.coo_bytes(enc.nnz));
        let ratio = enc.size_report().total as f64 / baseline.max(1) as f64;
        if ratio < self.max_size_ratio {
            FormatChoice::CsrDtans
        } else {
            FormatChoice::Csr
        }
    }

    /// The formats a matrix can be *re*-routed to, given its residency —
    /// the admissible-arm computation of the adaptive router
    /// (`docs/ROUTING.md`). Residency, not policy: the latent gap this
    /// closes is that [`RoutePolicy::choose_encoded`] already knows an
    /// artifact-registered matrix keeps no CSR original, but nothing
    /// stopped a re-routing layer from picking a CSR-requiring choice
    /// later anyway.
    ///
    /// * An **overlaid** (mutated) matrix admits only its registered
    ///   route: the composite overlay operator is the one correct
    ///   execution surface (its base encoding is stale until
    ///   compaction).
    /// * Without a resident CSR original, the CSR-walk formats
    ///   ([`FormatChoice::Csr`], [`FormatChoice::BlockedEll`]) are
    ///   inadmissible; CSR-dtANS always is (the encoding is what the
    ///   store holds).
    /// * With one, every format is admissible.
    pub fn admissible_for(
        registered: FormatChoice,
        csr_resident: bool,
        overlaid: bool,
    ) -> Vec<FormatChoice> {
        if overlaid {
            return vec![registered];
        }
        if csr_resident {
            vec![FormatChoice::Csr, FormatChoice::CsrDtans, FormatChoice::BlockedEll]
        } else {
            vec![FormatChoice::CsrDtans]
        }
    }

    /// Materialize a routing decision as the operator the service will
    /// execute against: the CSR original for [`FormatChoice::Csr`] (an
    /// error if none is held — the store's residency rules guarantee one
    /// exists for CSR-routed matrices), a [`DtansOperator`] (owning its
    /// decode plan) for [`FormatChoice::CsrDtans`], and a freshly built
    /// default-geometry [`BlockedEll`] for [`FormatChoice::BlockedEll`]
    /// (also requiring the CSR original — the store keeps it resident for
    /// every non-dtANS route).
    pub fn operator_for(
        choice: FormatChoice,
        csr: Option<&Arc<Csr>>,
        enc: &Arc<CsrDtans>,
    ) -> Result<Arc<dyn SpmvOperator>> {
        match choice {
            FormatChoice::Csr => match csr {
                Some(csr) => Ok(Arc::clone(csr) as Arc<dyn SpmvOperator>),
                None => Err(DtansError::Service(
                    "CSR-routed matrix has no resident CSR original".into(),
                )),
            },
            FormatChoice::CsrDtans => Ok(Arc::new(DtansOperator::new(Arc::clone(enc)))),
            FormatChoice::BlockedEll => match csr {
                Some(csr) => Ok(Arc::new(crate::matrix::BlockedEll::from_csr_default(csr))),
                None => Err(DtansError::Service(
                    "BlockedEll-routed matrix has no resident CSR original".into(),
                )),
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::gen::structured::banded;
    use crate::matrix::gen::{assign_values, ValueDist};
    use crate::util::rng::Xoshiro256;

    #[test]
    fn small_matrices_stay_csr() {
        let m = banded(100, 2);
        let enc = CsrDtans::encode(&m, &EncodeOptions::default()).unwrap();
        let p = RoutePolicy::default();
        assert_eq!(p.choose(&m, &enc, &EncodeOptions::default()), FormatChoice::Csr);
    }

    #[test]
    fn large_compressible_matrices_route_to_dtans() {
        let mut m = banded(40_000, 2); // ~120k nnz, highly structured
        assign_values(&mut m, ValueDist::Ones, &mut Xoshiro256::seeded(1));
        let opts = EncodeOptions::default();
        let enc = CsrDtans::encode(&m, &opts).unwrap();
        let p = RoutePolicy::default();
        assert_eq!(p.choose(&m, &enc, &opts), FormatChoice::CsrDtans);
    }

    #[test]
    fn encoded_only_route_agrees_on_clear_cases() {
        // Large + compressible routes to dtANS from the encoding alone;
        // small stays CSR — same answers as the CSR-aware rule.
        let mut m = banded(40_000, 2);
        assign_values(&mut m, ValueDist::Ones, &mut Xoshiro256::seeded(3));
        let opts = EncodeOptions::default();
        let enc = CsrDtans::encode(&m, &opts).unwrap();
        let p = RoutePolicy::default();
        assert_eq!(p.choose_encoded(&enc), FormatChoice::CsrDtans);
        assert_eq!(p.choose_encoded(&enc), p.choose(&m, &enc, &opts));
        let small = CsrDtans::encode(&banded(100, 2), &opts).unwrap();
        assert_eq!(p.choose_encoded(&small), FormatChoice::Csr);
    }

    #[test]
    fn operator_for_materializes_the_choice() {
        let m = Arc::new(banded(100, 2));
        let enc = Arc::new(CsrDtans::encode(&m, &EncodeOptions::default()).unwrap());
        let op = RoutePolicy::operator_for(FormatChoice::Csr, Some(&m), &enc).unwrap();
        assert_eq!(op.format_tag(), FormatChoice::Csr.tag());
        assert_eq!(op.dims(), (100, 100));
        let op = RoutePolicy::operator_for(FormatChoice::CsrDtans, None, &enc).unwrap();
        assert_eq!(op.format_tag(), FormatChoice::CsrDtans.tag());
        // A CSR-routed matrix without its original is a service error.
        assert!(RoutePolicy::operator_for(FormatChoice::Csr, None, &enc).is_err());
    }

    #[test]
    fn incompressible_matrices_stay_csr() {
        let mut rng = Xoshiro256::seeded(2);
        let mut m = crate::matrix::gen::structured::random_uniform(8000, 8000, 80_000, &mut rng);
        assign_values(&mut m, ValueDist::Random, &mut rng);
        let opts = EncodeOptions::default();
        let enc = CsrDtans::encode(&m, &opts).unwrap();
        let p = RoutePolicy {
            min_nnz: 1 << 10,
            ..Default::default()
        };
        // Random values + random pattern: dtANS cannot win on size.
        assert_eq!(p.choose(&m, &enc, &opts), FormatChoice::Csr);
    }

    #[test]
    fn skew_threshold_routes_large_uncompressible_matrices_to_blocked_ell() {
        // Same incompressible matrix as above: the size rule rejects
        // dtANS, so the skew rule decides between CSR and BlockedEll.
        let mut rng = Xoshiro256::seeded(2);
        let mut m = crate::matrix::gen::structured::random_uniform(8000, 8000, 80_000, &mut rng);
        assign_values(&mut m, ValueDist::Random, &mut rng);
        let opts = EncodeOptions::default();
        let enc = CsrDtans::encode(&m, &opts).unwrap();
        // Default threshold (infinity): behavior unchanged, stays CSR.
        let p = RoutePolicy { min_nnz: 1 << 10, ..Default::default() };
        assert_eq!(p.choose(&m, &enc, &opts), FormatChoice::Csr);
        // Any finite threshold at/below the matrix's CV flips the route.
        let p = RoutePolicy { min_nnz: 1 << 10, blocked_ell_cv: 0.0, ..Default::default() };
        assert_eq!(p.choose(&m, &enc, &opts), FormatChoice::BlockedEll);
        assert_eq!(FormatChoice::BlockedEll.tag(), "blocked_ell");
        // Small matrices are exempt regardless of skew.
        let small = banded(100, 2);
        let small_enc = CsrDtans::encode(&small, &opts).unwrap();
        assert_eq!(p.choose(&small, &small_enc, &opts), FormatChoice::Csr);
    }

    #[test]
    fn admissible_arms_consult_residency() {
        // Full residency: every format is re-routable.
        let all = RoutePolicy::admissible_for(FormatChoice::Csr, true, false);
        assert_eq!(all.len(), 3);
        // Artifact-registered (no CSR original): dtANS only — the
        // choose_encoded gap, closed. A CSR-walk choice must not appear.
        let enc_only = RoutePolicy::admissible_for(FormatChoice::CsrDtans, false, false);
        assert_eq!(enc_only, vec![FormatChoice::CsrDtans]);
        assert!(!enc_only.contains(&FormatChoice::Csr));
        assert!(!enc_only.contains(&FormatChoice::BlockedEll));
        // Overlaid: only the registered composite route survives.
        let overlaid = RoutePolicy::admissible_for(FormatChoice::Csr, true, true);
        assert_eq!(overlaid, vec![FormatChoice::Csr]);
    }

    #[test]
    fn operator_for_blocked_ell_needs_the_csr_original() {
        let m = Arc::new(banded(100, 2));
        let enc = Arc::new(CsrDtans::encode(&m, &EncodeOptions::default()).unwrap());
        let op = RoutePolicy::operator_for(FormatChoice::BlockedEll, Some(&m), &enc).unwrap();
        assert_eq!(op.format_tag(), "blocked_ell");
        assert_eq!(op.dims(), (100, 100));
        assert!(RoutePolicy::operator_for(FormatChoice::BlockedEll, None, &enc).is_err());
    }
}
