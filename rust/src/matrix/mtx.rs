//! MatrixMarket (.mtx) reader/writer — the paper reads its inputs from
//! `.mtx` files (SuiteSparse distributes them in this format).
//!
//! Supports the `matrix coordinate {real,integer,pattern} {general,
//! symmetric,skew-symmetric}` subset, which covers the matrices the paper
//! evaluates (complex matrices are excluded there too).

use super::coo::Coo;
use super::csr::Csr;
use crate::util::error::{DtansError, Result};
use std::io::{BufRead, BufReader, Write};
use std::path::Path;

/// Symmetry kinds of the coordinate format.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Symmetry {
    General,
    Symmetric,
    SkewSymmetric,
}

/// Parse a MatrixMarket stream into COO.
pub fn read_mtx<R: BufRead>(reader: R) -> Result<Coo> {
    let mut lines = reader.lines().enumerate();

    // Header line.
    let (mut lineno, header) = loop {
        match lines.next() {
            Some((i, line)) => {
                let line = line?;
                if !line.trim().is_empty() {
                    break (i, line);
                }
            }
            None => {
                return Err(DtansError::MtxParse {
                    line: 0,
                    msg: "empty file".into(),
                })
            }
        }
    };
    let h: Vec<String> = header.split_whitespace().map(|s| s.to_ascii_lowercase()).collect();
    if h.len() < 5 || h[0] != "%%matrixmarket" || h[1] != "matrix" {
        return Err(DtansError::MtxParse {
            line: lineno + 1,
            msg: "expected '%%MatrixMarket matrix ...' header".into(),
        });
    }
    if h[2] != "coordinate" {
        return Err(DtansError::MtxParse {
            line: lineno + 1,
            msg: format!("unsupported layout {:?} (only coordinate)", h[2]),
        });
    }
    let pattern = match h[3].as_str() {
        "real" | "integer" => false,
        "pattern" => true,
        other => {
            return Err(DtansError::MtxParse {
                line: lineno + 1,
                msg: format!("unsupported field {other:?} (complex excluded, as in the paper)"),
            })
        }
    };
    let symmetry = match h[4].as_str() {
        "general" => Symmetry::General,
        "symmetric" => Symmetry::Symmetric,
        "skew-symmetric" => Symmetry::SkewSymmetric,
        other => {
            return Err(DtansError::MtxParse {
                line: lineno + 1,
                msg: format!("unsupported symmetry {other:?}"),
            })
        }
    };

    // Size line (skipping comments).
    let (nrows, ncols, nnz) = loop {
        match lines.next() {
            Some((i, line)) => {
                lineno = i;
                let line = line?;
                let t = line.trim();
                if t.is_empty() || t.starts_with('%') {
                    continue;
                }
                let parts: Vec<&str> = t.split_whitespace().collect();
                if parts.len() != 3 {
                    return Err(DtansError::MtxParse {
                        line: lineno + 1,
                        msg: "size line must have 3 fields".into(),
                    });
                }
                let p = |s: &str| -> Result<usize> {
                    s.parse().map_err(|_| DtansError::MtxParse {
                        line: lineno + 1,
                        msg: format!("bad integer {s:?}"),
                    })
                };
                break (p(parts[0])?, p(parts[1])?, p(parts[2])?);
            }
            None => {
                return Err(DtansError::MtxParse {
                    line: lineno + 1,
                    msg: "missing size line".into(),
                })
            }
        }
    };

    let mut coo = Coo::new(nrows, ncols);
    let mut seen = 0usize;
    for (i, line) in lines {
        let line = line?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('%') {
            continue;
        }
        let parts: Vec<&str> = t.split_whitespace().collect();
        let need = if pattern { 2 } else { 3 };
        if parts.len() < need {
            return Err(DtansError::MtxParse {
                line: i + 1,
                msg: format!("entry needs {need} fields"),
            });
        }
        let r: usize = parts[0].parse().map_err(|_| DtansError::MtxParse {
            line: i + 1,
            msg: "bad row".into(),
        })?;
        let c: usize = parts[1].parse().map_err(|_| DtansError::MtxParse {
            line: i + 1,
            msg: "bad col".into(),
        })?;
        if r == 0 || c == 0 || r > nrows || c > ncols {
            return Err(DtansError::MtxParse {
                line: i + 1,
                msg: format!("index ({r},{c}) out of range (1-based)"),
            });
        }
        let v: f64 = if pattern {
            1.0
        } else {
            parts[2].parse().map_err(|_| DtansError::MtxParse {
                line: i + 1,
                msg: "bad value".into(),
            })?
        };
        let (r0, c0) = (r as u32 - 1, c as u32 - 1);
        coo.push(r0, c0, v);
        // Expand symmetric storage to full pattern, as our kernels (like
        // cuSPARSE's) operate on the full matrix; the Fig. 9 experiment
        // handles triangular storage explicitly instead.
        match symmetry {
            Symmetry::General => {}
            Symmetry::Symmetric => {
                if r0 != c0 {
                    coo.push(c0, r0, v);
                }
            }
            Symmetry::SkewSymmetric => {
                if r0 != c0 {
                    coo.push(c0, r0, -v);
                }
            }
        }
        seen += 1;
    }
    if seen != nnz {
        return Err(DtansError::MtxParse {
            line: lineno + 1,
            msg: format!("expected {nnz} entries, found {seen}"),
        });
    }
    Ok(coo)
}

/// Read a `.mtx` file into CSR.
pub fn load_mtx_csr(path: &Path) -> Result<Csr> {
    let f = std::fs::File::open(path)?;
    Ok(Csr::from_coo(&read_mtx(BufReader::new(f))?))
}

/// Write CSR as `matrix coordinate real general`.
pub fn write_mtx<W: Write>(m: &Csr, mut w: W) -> Result<()> {
    writeln!(w, "%%MatrixMarket matrix coordinate real general")?;
    writeln!(w, "{} {} {}", m.nrows, m.ncols, m.nnz())?;
    for r in 0..m.nrows {
        for i in m.row_ptr[r]..m.row_ptr[r + 1] {
            writeln!(w, "{} {} {:e}", r + 1, m.cols[i] + 1, m.vals[i])?;
        }
    }
    Ok(())
}

/// Save CSR to a `.mtx` file.
pub fn save_mtx(m: &Csr, path: &Path) -> Result<()> {
    if let Some(p) = path.parent() {
        std::fs::create_dir_all(p)?;
    }
    let f = std::fs::File::create(path)?;
    write_mtx(m, std::io::BufWriter::new(f))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn parse_general_real() {
        let src = "%%MatrixMarket matrix coordinate real general\n% comment\n3 3 2\n1 1 1.5\n3 2 -2.0\n";
        let coo = read_mtx(Cursor::new(src)).unwrap();
        assert_eq!(coo.nnz(), 2);
        let m = Csr::from_coo(&coo);
        assert_eq!(m.to_dense()[0], 1.5);
        assert_eq!(m.to_dense()[2 * 3 + 1], -2.0);
    }

    #[test]
    fn parse_symmetric_expands() {
        let src = "%%MatrixMarket matrix coordinate real symmetric\n2 2 2\n1 1 1.0\n2 1 3.0\n";
        let m = Csr::from_coo(&read_mtx(Cursor::new(src)).unwrap());
        assert_eq!(m.nnz(), 3);
        assert!(m.is_symmetric());
    }

    #[test]
    fn parse_pattern() {
        let src = "%%MatrixMarket matrix coordinate pattern general\n2 2 2\n1 2\n2 1\n";
        let m = Csr::from_coo(&read_mtx(Cursor::new(src)).unwrap());
        assert_eq!(m.vals, vec![1.0, 1.0]);
    }

    #[test]
    fn rejects_complex() {
        let src = "%%MatrixMarket matrix coordinate complex general\n1 1 1\n1 1 1.0 2.0\n";
        assert!(read_mtx(Cursor::new(src)).is_err());
    }

    #[test]
    fn entry_count_checked() {
        let src = "%%MatrixMarket matrix coordinate real general\n2 2 3\n1 1 1.0\n";
        assert!(read_mtx(Cursor::new(src)).is_err());
    }

    #[test]
    fn write_read_roundtrip() {
        let mut coo = Coo::new(3, 4);
        coo.push(0, 1, 0.5);
        coo.push(2, 3, 1e-9);
        let m = Csr::from_coo(&coo);
        let mut buf = Vec::new();
        write_mtx(&m, &mut buf).unwrap();
        let back = Csr::from_coo(&read_mtx(Cursor::new(buf)).unwrap());
        assert_eq!(m, back);
    }
}
