//! End-to-end driver: the batching SpMVM service (Layer-3 coordinator)
//! serving concurrent requests over compressed matrices, with the PJRT
//! path (AOT JAX/Pallas kernel) verified against the native path when the
//! artifacts are present.
//!
//! This is the repository's full-stack demo: Rust coordinator + warp-
//! synchronous native decode + the Pallas kernel compiled through
//! `make artifacts` and executed via the xla/PJRT runtime — with
//! latency/throughput metrics reported, as for a serving-system paper.
//!
//! Run: `make artifacts && cargo run --release --example spmv_service`

use dtans::ans::AnsParams;
use dtans::coordinator::{RoutePolicy, ServiceConfig, SpmvService};
use dtans::format::csr_dtans::{CsrDtans, EncodeOptions};
use dtans::matrix::gen::structured::banded;
use dtans::matrix::gen::{assign_values, gen_graph_csr, GraphModel, ValueDist};
use dtans::matrix::Precision;
use dtans::runtime::Runtime;
use dtans::store::StoreConfig;
use dtans::util::rng::Xoshiro256;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    // --- 1. Start the service and register a small model zoo. ---
    // The tiered store persists every encoding to a content-addressed
    // artifact cache and caps resident bytes: cold matrices fault back in
    // from disk on demand, and re-running this example skips re-encoding
    // (watch store_hits in the metrics line).
    let cache_dir = std::env::temp_dir().join("dtans_example_store");
    let svc = SpmvService::start(ServiceConfig {
        workers: 4,
        max_batch: 16,
        policy: RoutePolicy {
            min_nnz: 1 << 14,
            max_size_ratio: 0.95,
            ..Default::default()
        },
        store: StoreConfig {
            cache_dir: Some(cache_dir.clone()),
            budget_bytes: Some(8 << 20), // 8 MiB resident cap
            drop_csr: true,
            loader_threads: 2,
        },
        ..Default::default()
    });
    let mut rng = Xoshiro256::seeded(3);
    let mut big = banded(60_000, 4);
    assign_values(&mut big, ValueDist::FewDistinct(32), &mut rng);
    let mut graph = gen_graph_csr(GraphModel::BarabasiAlbert, 8_000, 12.0, &mut rng);
    assign_values(&mut graph, ValueDist::Quantized(64), &mut rng);
    let small = banded(500, 2);

    let ids = [
        ("banded-60k", svc.register("banded-60k", big.clone())?),
        ("ba-graph-8k", svc.register("ba-graph-8k", graph.clone())?),
        ("small-500", svc.register("small-500", small.clone())?),
    ];
    for (name, id) in &ids {
        println!(
            "registered {name:<12} -> routed to {:?}",
            svc.format_of(*id).unwrap()
        );
    }

    // --- 2. Fire concurrent batched requests. ---
    let t0 = std::time::Instant::now();
    let mut pendings = Vec::new();
    let sizes = [big.ncols, graph.ncols, small.ncols];
    for i in 0..120 {
        let (_, id) = ids[i % 3];
        let n = sizes[i % 3];
        let x: Vec<f64> = (0..n).map(|j| ((i + j) as f64 * 0.01).sin()).collect();
        // `submit` now returns a typed admission result: with the default
        // 1024-deep queue this closed burst never sheds, so an error here
        // is a real failure worth surfacing.
        pendings.push((i, svc.submit(id, x)?));
    }
    for (_, p) in pendings {
        p.wait()?;
    }
    let dt = t0.elapsed().as_secs_f64();
    println!("served 120 requests in {:.2}s ({:.0} req/s)", dt, 120.0 / dt);
    println!("metrics: {}", svc.metrics.report());
    {
        use std::sync::atomic::Ordering;
        let cb = svc.metrics.coalesced_batches.load(Ordering::Relaxed);
        let cr = svc.metrics.coalesced_requests.load(Ordering::Relaxed);
        if cb > 0 {
            println!(
                "coalescing: {cr} requests served by {cb} SpMM batch(es) \
                 ({:.1} multiplies per decode)",
                cr as f64 / cb as f64
            );
        }
    }
    let stats = svc.store().stats();
    println!(
        "store: {} registered, {} resident ({} bytes of {:?} budget) in {}",
        stats.registered,
        stats.resident,
        stats.resident_bytes,
        stats.budget_bytes,
        cache_dir.display()
    );

    // --- 2b. Iterative solve through the service: one store pin for the
    // whole solve, recorded in metrics as a single request-level sample
    // with its iteration count (watch the `solver:` section).
    let spd = dtans::matrix::gen::structured::stencil2d5(64, 64);
    let spd_rows = spd.nrows;
    let spd_id = svc.register("poisson-64", spd)?;
    let acquires0 = svc.metrics.acquires.load(std::sync::atomic::Ordering::Relaxed);
    let sol = svc.solve(
        spd_id,
        dtans::solver::SolveMethod::Cg,
        &vec![1.0; spd_rows],
        &dtans::solver::SolverConfig { tol: 1e-8, ..Default::default() },
    )?;
    let acquires1 = svc.metrics.acquires.load(std::sync::atomic::Ordering::Relaxed);
    println!(
        "CG solve on poisson-64: {} in {} iters ({:.2e} residual) — {} store pin(s) held",
        if sol.report.converged() { "converged" } else { "stopped" },
        sol.report.iterations,
        sol.report.final_residual(),
        acquires1 - acquires0,
    );
    println!("metrics after solve: {}", svc.metrics.report());

    // Re-registering a known matrix hits the artifact cache: no encode.
    svc.store().flush(); // make sure the background persists landed
    let hits_before = svc.metrics.store_hits.load(std::sync::atomic::Ordering::Relaxed);
    svc.register("banded-60k-again", big.clone())?;
    let hits_after = svc.metrics.store_hits.load(std::sync::atomic::Ordering::Relaxed);
    println!(
        "re-registration: artifact cache {} (hits {hits_before} -> {hits_after})",
        if hits_after > hits_before { "HIT, encode skipped" } else { "miss" }
    );

    // --- 3. PJRT path: the AOT-compiled Pallas kernel, if artifacts exist. ---
    match Runtime::open(&Runtime::default_dir()) {
        Ok(rt) => {
            println!("\nPJRT path ({}):", rt.platform());
            let opts = EncodeOptions {
                params: AnsParams::KERNEL,
                precision: Precision::F32,
                delta_encode: true,
            };
            let mut m = banded(200, 3);
            assign_values(&mut m, ValueDist::FewDistinct(8), &mut rng);
            let enc = CsrDtans::encode(&m, &opts)?;
            let x: Vec<f64> = (0..m.ncols).map(|j| (j as f64 * 0.05).cos()).collect();
            let y_pjrt = rt.spmv_dtans(&enc, &x, &vec![0.0; m.nrows])?;
            let mut y_native = vec![0.0; m.nrows];
            dtans::spmv::spmv_csr_dtans(&enc, &x, &mut y_native)?;
            let err = y_native
                .iter()
                .zip(&y_pjrt)
                .map(|(a, &b)| (a - b as f64).abs())
                .fold(0.0f64, f64::max);
            println!("  AOT Pallas kernel vs native decode: max |err| = {err:.2e}");
            assert!(err < 1e-3);
        }
        Err(e) => println!("\nPJRT path skipped ({e}); run `make artifacts`"),
    }
    println!("OK");
    Ok(())
}
