//! Layer-3 coordinator: an admission-controlled, batching SpMVM service
//! with per-matrix format routing (the production wrapper around the
//! paper's kernel — encode once, decode on every multiply, as in the
//! iterative-solver and ML-inference scenarios the paper motivates).
//! Requests pass through the bounded [`admission`] queue (backpressure,
//! deadlines, priorities, per-tenant quotas, cross-request coalescing —
//! see `docs/SERVING.md`) before the dispatcher hands them to the worker
//! pool. Matrix lifetime and residency live one layer down in the tiered
//! store ([`crate::store`]); iterative solves ([`crate::solver`]) run
//! through [`service::SpmvService::solve`] under a single store pin.
//! Per-matrix routes are static by default ([`router::RoutePolicy`]) and
//! optionally learned online by the [`adaptive`] bandit router
//! (`docs/ROUTING.md`).

pub mod adaptive;
pub mod admission;
pub mod metrics;
pub mod router;
pub mod service;

pub use adaptive::{
    AdaptiveConfig, AdaptiveRouter, Arm, ArmSeed, ParHint, RouteCounters, RouteDecision,
    RouteFlip, RouteOverride, SeedSource,
};
pub use admission::{AdmissionConfig, AdmissionQueue, Priority, QuotaConfig, SubmitOptions};
pub use metrics::{FormatSummary, LatencySummary, Metrics, SolverSummary};
pub use router::{FormatChoice, RoutePolicy};
pub use service::{LoadedMatrix, Pending, ServiceConfig, SpmvService};
