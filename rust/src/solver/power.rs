//! Power iteration and PageRank over any [`SpmvOperator`] — the repeated-
//! application eigenvalue workloads (one multiply per iteration, the purest
//! case for the paper's decode-every-iteration amortization argument).

use super::{check_square, dot, norm2, Solution, SolveReport, SolverConfig, Termination};
use crate::spmv::engine::SpmvEngine;
use crate::spmv::operator::SpmvOperator;
use crate::util::error::{DtansError, Result};
use std::time::Instant;

/// A power-iteration answer: the dominant eigenvalue estimate, its unit
/// eigenvector, and the usual [`SolveReport`].
#[derive(Debug, Clone)]
pub struct PowerSolution {
    /// Rayleigh-quotient estimate of the dominant eigenvalue.
    pub eigenvalue: f64,
    /// Unit-norm eigenvector iterate.
    pub x: Vec<f64>,
    /// Termination, residual history, phase timings.
    pub report: SolveReport,
}

/// Estimate the dominant eigenpair of a square operator by power
/// iteration, building a fresh engine from [`SolverConfig::par`].
/// Requires the dominant eigenvalue to be separated in modulus; the
/// residual driving termination is `‖A·x − λ·x‖₂ / |λ|` with
/// `λ = x·A·x` the Rayleigh quotient of the unit iterate.
///
/// ```
/// use dtans::matrix::{Coo, Csr};
/// use dtans::solver::{power_iteration, SolverConfig};
///
/// // diag(9, 3, 1): dominant eigenpair (9, e0), big spectral gap.
/// let mut coo = Coo::new(3, 3);
/// for (i, v) in [9.0, 3.0, 1.0].into_iter().enumerate() {
///     coo.push(i as u32, i as u32, v);
/// }
/// let a = Csr::from_coo(&coo);
/// let cfg = SolverConfig { tol: 1e-9, ..Default::default() };
/// let sol = power_iteration(&a, &cfg).unwrap();
/// assert!(sol.report.converged());
/// assert!((sol.eigenvalue - 9.0).abs() < 1e-6);
/// assert!(sol.x[0].abs() > 0.999); // eigenvector concentrates on e0
/// ```
pub fn power_iteration(op: &dyn SpmvOperator, cfg: &SolverConfig) -> Result<PowerSolution> {
    power_iteration_with(&SpmvEngine::new(cfg.par), op, None, cfg)
}

/// [`power_iteration`] on an existing engine, with an optional start
/// vector (the normalized all-ones vector when `None`).
///
/// ```
/// use dtans::matrix::gen::structured::tridiagonal;
/// use dtans::solver::{power_iteration_with, SolverConfig};
/// use dtans::spmv::engine::SpmvEngine;
///
/// let a = tridiagonal(32);
/// let engine = SpmvEngine::serial();
/// let cfg = SolverConfig { tol: 1e-6, max_iters: 5000, ..Default::default() };
/// let sol = power_iteration_with(&engine, &a, None, &cfg).unwrap();
/// // 1D Laplacian spectrum: dominant eigenvalue approaches 4 from below.
/// assert!(sol.eigenvalue > 3.9 && sol.eigenvalue < 4.0);
/// ```
pub fn power_iteration_with(
    engine: &SpmvEngine,
    op: &dyn SpmvOperator,
    x0: Option<&[f64]>,
    cfg: &SolverConfig,
) -> Result<PowerSolution> {
    let n = check_square(op, x0.map_or(op.dims().0, <[f64]>::len))?;
    let t_total = Instant::now();
    let mut spmv_secs = 0.0;
    let mut vector_secs = 0.0;
    let mut residuals = Vec::new();

    let mut x = match x0 {
        Some(v) => {
            let nrm = norm2(v);
            if nrm == 0.0 {
                return Err(DtansError::InvalidParams(
                    "power iteration start vector must be nonzero".into(),
                ));
            }
            v.iter().map(|e| e / nrm).collect()
        }
        None => vec![1.0 / (n.max(1) as f64).sqrt(); n],
    };
    if n == 0 {
        return Ok(PowerSolution {
            eigenvalue: 0.0,
            x,
            report: SolveReport {
                termination: Termination::Converged,
                iterations: 0,
                residuals,
                spmv_secs,
                vector_secs,
                total_secs: t_total.elapsed().as_secs_f64(),
            },
        });
    }

    let mut ax = vec![0.0; n];
    let mut eigenvalue = 0.0;
    let mut termination = Termination::MaxIters;
    let mut iterations = 0;
    for _ in 0..cfg.max_iters {
        let t = Instant::now();
        engine.run_axpby(op, &x, 1.0, 0.0, &mut ax)?; // ax = A·x
        spmv_secs += t.elapsed().as_secs_f64();

        let t = Instant::now();
        eigenvalue = dot(&x, &ax); // Rayleigh quotient (‖x‖ = 1)
        let mut resid2 = 0.0;
        for i in 0..n {
            let d = ax[i] - eigenvalue * x[i];
            resid2 += d * d;
        }
        let rel = resid2.sqrt() / eigenvalue.abs().max(f64::MIN_POSITIVE);
        iterations += 1;
        residuals.push(rel);
        if rel <= cfg.tol {
            termination = Termination::Converged;
            vector_secs += t.elapsed().as_secs_f64();
            break;
        }
        let nrm = norm2(&ax);
        if nrm == 0.0 {
            // The iterate fell into the null space — no direction left.
            termination = Termination::Breakdown;
            vector_secs += t.elapsed().as_secs_f64();
            break;
        }
        for i in 0..n {
            x[i] = ax[i] / nrm;
        }
        vector_secs += t.elapsed().as_secs_f64();
    }
    Ok(PowerSolution {
        eigenvalue,
        x,
        report: SolveReport {
            termination,
            iterations,
            residuals,
            spmv_secs,
            vector_secs,
            total_secs: t_total.elapsed().as_secs_f64(),
        },
    })
}

/// PageRank by power iteration with the teleport fused into the multiply:
/// each step is `x' = d·P·x + (1−d)/n` — exactly one
/// [`run_axpby`](crate::spmv::engine::SpmvEngine::run_axpby) call with
/// `alpha = d` and `beta = 1` over the teleport-filled output. Builds a
/// fresh engine from [`SolverConfig::par`].
///
/// `op` must be the **column-stochastic transition operator** `P`
/// (`P[v][u] = 1/outdegree(u)` for each edge `u → v`, so `y = P·x`
/// redistributes rank mass); `damping` is the usual `d ∈ (0, 1)`.
/// Termination is on the L1 change `‖x' − x‖₁ ≤ tol`; the returned vector
/// sums to 1 when `P` is genuinely column-stochastic (dangling nodes leak
/// mass, as in the classic formulation).
///
/// ```
/// use dtans::matrix::{Coo, Csr};
/// use dtans::solver::{pagerank, SolverConfig};
///
/// // 3-cycle: column-stochastic P has PageRank uniform at 1/3.
/// let mut coo = Coo::new(3, 3);
/// for u in 0..3u32 {
///     coo.push((u + 1) % 3, u, 1.0); // one out-edge each: weight 1
/// }
/// let p = Csr::from_coo(&coo);
/// let cfg = SolverConfig { tol: 1e-12, ..Default::default() };
/// let sol = pagerank(&p, 0.85, &cfg).unwrap();
/// assert!(sol.report.converged());
/// for r in &sol.x {
///     assert!((r - 1.0 / 3.0).abs() < 1e-9);
/// }
/// ```
pub fn pagerank(op: &dyn SpmvOperator, damping: f64, cfg: &SolverConfig) -> Result<Solution> {
    pagerank_with(&SpmvEngine::new(cfg.par), op, damping, cfg)
}

/// [`pagerank`] on an existing engine — the service's shared-engine entry
/// point.
///
/// ```
/// use dtans::matrix::{Coo, Csr};
/// use dtans::solver::{pagerank_with, SolverConfig};
/// use dtans::spmv::engine::SpmvEngine;
///
/// // Two nodes pointing at each other: uniform rank.
/// let mut coo = Coo::new(2, 2);
/// coo.push(0, 1, 1.0);
/// coo.push(1, 0, 1.0);
/// let p = Csr::from_coo(&coo);
/// let engine = SpmvEngine::serial();
/// let sol = pagerank_with(&engine, &p, 0.85, &SolverConfig::default()).unwrap();
/// assert!((sol.x[0] - 0.5).abs() < 1e-9 && (sol.x[1] - 0.5).abs() < 1e-9);
/// ```
pub fn pagerank_with(
    engine: &SpmvEngine,
    op: &dyn SpmvOperator,
    damping: f64,
    cfg: &SolverConfig,
) -> Result<Solution> {
    if !(0.0..1.0).contains(&damping) || damping == 0.0 {
        return Err(DtansError::InvalidParams(format!(
            "pagerank damping must be in (0, 1), got {damping}"
        )));
    }
    let n = check_square(op, op.dims().0)?;
    let t_total = Instant::now();
    let mut spmv_secs = 0.0;
    let mut vector_secs = 0.0;
    let mut residuals = Vec::new();
    let mut termination = Termination::MaxIters;
    let mut iterations = 0;
    let mut x = vec![1.0 / n.max(1) as f64; n];
    if n > 0 {
        let teleport = (1.0 - damping) / n as f64;
        let mut next = vec![0.0; n];
        for _ in 0..cfg.max_iters {
            let t = Instant::now();
            next.fill(teleport);
            vector_secs += t.elapsed().as_secs_f64();

            let t = Instant::now();
            // next = d·P·x + next — the whole PageRank step, fused.
            engine.run_axpby(op, &x, damping, 1.0, &mut next)?;
            spmv_secs += t.elapsed().as_secs_f64();

            let t = Instant::now();
            let mut l1 = 0.0;
            for i in 0..n {
                l1 += (next[i] - x[i]).abs();
            }
            std::mem::swap(&mut x, &mut next);
            iterations += 1;
            residuals.push(l1);
            vector_secs += t.elapsed().as_secs_f64();
            if l1 <= cfg.tol {
                termination = Termination::Converged;
                break;
            }
        }
    } else {
        termination = Termination::Converged;
    }
    Ok(Solution {
        x,
        report: SolveReport {
            termination,
            iterations,
            residuals,
            spmv_secs,
            vector_secs,
            total_secs: t_total.elapsed().as_secs_f64(),
        },
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::coo::Coo;
    use crate::matrix::csr::Csr;

    fn diag(vals: &[f64]) -> Csr {
        let mut coo = Coo::new(vals.len(), vals.len());
        for (i, v) in vals.iter().enumerate() {
            coo.push(i as u32, i as u32, *v);
        }
        Csr::from_coo(&coo)
    }

    #[test]
    fn finds_dominant_eigenpair_of_diagonal() {
        let a = diag(&[10.0, 3.0, 2.0, 1.0, 0.5]);
        let cfg = SolverConfig { tol: 1e-10, max_iters: 500, ..Default::default() };
        let sol = power_iteration(&a, &cfg).unwrap();
        assert!(sol.report.converged());
        assert!((sol.eigenvalue - 10.0).abs() < 1e-6);
        assert!(sol.x[0].abs() > 0.999_999);
        assert!((norm2(&sol.x) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn zero_start_vector_is_rejected() {
        let a = diag(&[1.0, 2.0]);
        let engine = SpmvEngine::serial();
        assert!(power_iteration_with(&engine, &a, Some(&[0.0, 0.0]), &SolverConfig::default())
            .is_err());
    }

    #[test]
    fn null_matrix_breaks_down() {
        let a = Csr::new(4, 4); // all-zero matrix: A·x = 0
        let sol = power_iteration(&a, &SolverConfig::default()).unwrap();
        // Either the zero Rayleigh quotient converges the residual (0/MIN)
        // or normalization breaks down — both are honest; it must not spin.
        assert!(sol.report.iterations <= 1);
    }

    #[test]
    fn pagerank_respects_link_structure() {
        // Star: nodes 1..4 all link to node 0; node 0 links to node 1.
        // Node 0 must end up with the most rank, then node 1.
        let mut coo = Coo::new(5, 5);
        for u in 1..5u32 {
            coo.push(0, u, 1.0); // u -> 0, out-degree 1
        }
        coo.push(1, 0, 1.0); // 0 -> 1
        let p = Csr::from_coo(&coo);
        let cfg = SolverConfig { tol: 1e-12, ..Default::default() };
        let sol = pagerank(&p, 0.85, &cfg).unwrap();
        assert!(sol.report.converged());
        let total: f64 = sol.x.iter().sum();
        assert!((total - 1.0).abs() < 1e-9, "mass conserved, got {total}");
        assert!(sol.x[0] > sol.x[1] && sol.x[1] > sol.x[2]);
        assert!((sol.x[2] - sol.x[4]).abs() < 1e-12, "symmetric leaves tie");
    }

    #[test]
    fn pagerank_rejects_bad_damping() {
        let p = diag(&[1.0]);
        for d in [0.0, 1.0, -0.3, 1.7] {
            assert!(pagerank(&p, d, &SolverConfig::default()).is_err(), "{d}");
        }
    }
}
