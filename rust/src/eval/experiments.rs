//! Experiment drivers regenerating every table and figure of the paper's
//! evaluation (§V). Each driver returns CSV-able tables plus a markdown
//! summary with the headline numbers to compare against the paper.

use super::corpus::{build_corpus, CorpusEntry, CorpusScale};
use crate::ans::AnsParams;
use crate::autotune::{autotune, dtans_time_us, TuneSpace};
use crate::format::csr_dtans::{CsrDtans, EncodeOptions};
use crate::matrix::gen::{gen_graph_csr, GraphModel};
use crate::matrix::stats::MatrixStats;
use crate::matrix::{Precision, SizeModel};
use crate::sim::{best_baseline, simulate, GpuModel, KernelKind, SimInput};
use crate::util::csv::{fnum, Table};
use crate::util::rng::Xoshiro256;

/// Output of one experiment: named tables + a human summary.
pub struct ExperimentOutput {
    /// (file stem, table) pairs to be saved as CSV.
    pub tables: Vec<(String, Table)>,
    /// Markdown summary.
    pub summary: String,
}

// ---------------------------------------------------------------------------
// Fig. 4 — entropy reduction via delta-encoding on random graph models
// ---------------------------------------------------------------------------

/// Fig. 4: relative entropy H(deltas)/H(indices) for ER/WS/BA at average
/// degrees 5/10/20 over growing node counts (median of 3 seeds).
pub fn fig4(max_nodes: usize) -> ExperimentOutput {
    let mut table = Table::new(&["model", "degree", "nodes", "rel_entropy"]);
    let mut reduced_everywhere = true;
    let mut n = 1024usize;
    let mut sizes = Vec::new();
    while n <= max_nodes {
        sizes.push(n);
        n *= 4;
    }
    for model in [GraphModel::ErdosRenyi, GraphModel::WattsStrogatz, GraphModel::BarabasiAlbert] {
        for &deg in &[5.0, 10.0, 20.0] {
            for &n in &sizes {
                let mut samples: Vec<f64> = (0..3)
                    .map(|s| {
                        let mut rng = Xoshiro256::seeded(1000 + s);
                        let m = gen_graph_csr(model, n, deg, &mut rng);
                        MatrixStats::compute(&m).relative_delta_entropy()
                    })
                    .collect();
                samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
                let median = samples[1];
                reduced_everywhere &= median < 1.0;
                table.push(vec![
                    model.label().into(),
                    format!("{deg}"),
                    n.to_string(),
                    fnum(median, 4),
                ]);
            }
        }
    }
    let summary = format!(
        "Fig4: delta-encoding reduced index entropy in {} of {} (model, degree, n) points \
         (paper: reduced in all cases).",
        table.rows.iter().filter(|r| r[3].parse::<f64>().unwrap() < 1.0).count(),
        table.rows.len(),
    );
    let _ = reduced_everywhere;
    ExperimentOutput {
        tables: vec![("fig4_delta_entropy".into(), table)],
        summary,
    }
}

// ---------------------------------------------------------------------------
// Fig. 6 + Table I — compression
// ---------------------------------------------------------------------------

struct SizeRow {
    name: String,
    nnz: usize,
    annzpr: f64,
    baseline: usize,
    baseline_fmt: &'static str,
    dtans: usize,
}

fn size_rows(corpus: &[CorpusEntry], precision: Precision) -> Vec<SizeRow> {
    let model = SizeModel { precision };
    corpus
        .iter()
        .map(|e| {
            let csr = match precision {
                Precision::F64 => e.csr.clone(),
                Precision::F32 => e.csr.round_to_f32(),
            };
            let (baseline, fmt) = model.best_baseline_bytes(&csr);
            let enc = CsrDtans::encode(
                &csr,
                &EncodeOptions {
                    precision,
                    ..Default::default()
                },
            )
            .expect("encode");
            SizeRow {
                name: e.name.clone(),
                nnz: csr.nnz(),
                annzpr: csr.annzpr(),
                baseline,
                baseline_fmt: fmt,
                dtans: enc.size_report().total,
            }
        })
        .collect()
}

/// Fig. 6: per-matrix size scatter (CSR-dtANS vs smallest cuSPARSE format)
/// for both precisions, plus the headline max compression ratios.
pub fn fig6(scale: &CorpusScale) -> ExperimentOutput {
    let corpus = build_corpus(scale, 42);
    let mut tables = Vec::new();
    let mut summary = String::new();
    for precision in [Precision::F64, Precision::F32] {
        let rows = size_rows(&corpus, precision);
        let mut t = Table::new(&[
            "matrix", "nnz", "annzpr", "baseline_fmt", "baseline_bytes", "dtans_bytes", "ratio",
        ]);
        let mut best_ratio: f64 = 0.0;
        let mut success = 0usize;
        for r in &rows {
            let ratio = r.baseline as f64 / r.dtans.max(1) as f64;
            best_ratio = best_ratio.max(ratio);
            success += (r.dtans < r.baseline) as usize;
            t.push(vec![
                r.name.clone(),
                r.nnz.to_string(),
                fnum(r.annzpr, 2),
                r.baseline_fmt.into(),
                r.baseline.to_string(),
                r.dtans.to_string(),
                fnum(ratio, 3),
            ]);
        }
        summary.push_str(&format!(
            "Fig6 {}: compressed {}/{} matrices; best compression {:.2}x (paper: up to {}x).\n",
            precision.label(),
            success,
            rows.len(),
            best_ratio,
            if precision == Precision::F64 { "11.77" } else { "7.86" },
        ));
        tables.push((
            format!("fig6_compression_{}", if precision == Precision::F64 { "64" } else { "32" }),
            t,
        ));
    }
    ExperimentOutput { tables, summary }
}

fn bucket_nnz_tab1(nnz: usize) -> usize {
    if nnz <= 1 << 10 {
        0
    } else if nnz <= 1 << 15 {
        1
    } else {
        2
    }
}

/// Table I: fraction of successfully compressed matrices bucketed by total
/// nnz (≤2^10, ≤2^15, >2^15) × annzpr (≤10, >10), per precision.
pub fn tab1(scale: &CorpusScale) -> ExperimentOutput {
    let corpus = build_corpus(scale, 42);
    let mut tables = Vec::new();
    let mut summary = String::new();
    for precision in [Precision::F64, Precision::F32] {
        let rows = size_rows(&corpus, precision);
        let mut ok = [[0usize; 3]; 2];
        let mut tot = [[0usize; 3]; 2];
        for r in &rows {
            let a = (r.annzpr > 10.0) as usize;
            let b = bucket_nnz_tab1(r.nnz);
            tot[a][b] += 1;
            ok[a][b] += (r.dtans < r.baseline) as usize;
        }
        let mut t = Table::new(&["annzpr", "nnz<=2^10", "nnz<=2^15", "nnz>2^15"]);
        for (a, label) in [(0usize, "<=10"), (1, ">10")] {
            t.push(vec![
                label.into(),
                format!("{}/{}", ok[a][0], tot[a][0]),
                format!("{}/{}", ok[a][1], tot[a][1]),
                format!("{}/{}", ok[a][2], tot[a][2]),
            ]);
        }
        let big = if tot[1][2] > 0 {
            ok[1][2] as f64 / tot[1][2] as f64
        } else {
            f64::NAN
        };
        summary.push_str(&format!(
            "Tab1 {}: success rate for nnz>2^15 & annzpr>10 = {:.2} (paper: ~1.00); \
             small matrices (<=2^10) = {}/{} (paper: 0).\n",
            precision.label(),
            big,
            ok[0][0] + ok[1][0],
            tot[0][0] + tot[1][0],
        ));
        tables.push((
            format!("tab1_success_{}", if precision == Precision::F64 { "64" } else { "32" }),
            t,
        ));
    }
    ExperimentOutput { tables, summary }
}

// ---------------------------------------------------------------------------
// Fig. 7/8 + Table II/III — simulated SpMVM runtime, warm and cold cache
// ---------------------------------------------------------------------------

fn bucket_nnz_tab23(nnz: usize) -> usize {
    if nnz <= 1 << 20 {
        0
    } else if nnz <= 1 << 25 {
        1
    } else {
        2
    }
}

/// Shared driver for Fig. 7 (warm) and Fig. 8 (cold) plus Tables II/III.
pub fn runtime_experiment(scale: &CorpusScale, warm: bool) -> ExperimentOutput {
    let corpus = build_corpus(scale, 42);
    let dev = GpuModel::RTX5090;
    let label = if warm { "warm" } else { "cold" };
    let fig = if warm { "fig7" } else { "fig8" };
    let tabn = if warm { "tab2" } else { "tab3" };
    let mut tables = Vec::new();
    let mut summary = String::new();

    for precision in [Precision::F64, Precision::F32] {
        let plabel = if precision == Precision::F64 { "64" } else { "32" };
        let mut t = Table::new(&[
            "matrix", "nnz", "annzpr", "rel_size", "rel_time", "base_kernel", "base_us", "dtans_us",
        ]);
        let mut ok = [[0usize; 3]; 2];
        let mut tot = [[0usize; 3]; 2];
        let mut best_speedup: f64 = 0.0;
        let model = SizeModel { precision };
        for e in &corpus {
            let csr = match precision {
                Precision::F64 => e.csr.clone(),
                Precision::F32 => e.csr.round_to_f32(),
            };
            let enc = CsrDtans::encode(
                &csr,
                &EncodeOptions {
                    precision,
                    ..Default::default()
                },
            )
            .expect("encode");
            let sell = crate::matrix::sell::Sell::from_csr(&csr, 32);
            let inp = SimInput {
                csr: &csr,
                sell: Some(&sell),
                enc: Some(&enc),
                precision,
            };
            let (bk, base) = best_baseline(&inp, &dev, warm);
            let dt = simulate(KernelKind::CsrDtans, &inp, &dev, warm);
            let (baseline_bytes, _) = model.best_baseline_bytes(&csr);
            let rel_size = enc.size_report().total as f64 / baseline_bytes.max(1) as f64;
            let rel_time = dt.time_us / base.time_us;
            best_speedup = best_speedup.max(1.0 / rel_time);
            let a = (csr.annzpr() > 10.0) as usize;
            let b = bucket_nnz_tab23(csr.nnz());
            tot[a][b] += 1;
            ok[a][b] += (rel_time < 1.0) as usize;
            t.push(vec![
                e.name.clone(),
                csr.nnz().to_string(),
                fnum(csr.annzpr(), 2),
                fnum(rel_size, 3),
                fnum(rel_time, 3),
                bk.label().into(),
                fnum(base.time_us, 2),
                fnum(dt.time_us, 2),
            ]);
        }
        let mut bt = Table::new(&["annzpr", "nnz<=2^20", "nnz<=2^25", "nnz>2^25"]);
        for (a, lab) in [(0usize, "<=10"), (1, ">10")] {
            bt.push(vec![
                lab.into(),
                format!("{}/{}", ok[a][0], tot[a][0]),
                format!("{}/{}", ok[a][1], tot[a][1]),
                format!("{}/{}", ok[a][2], tot[a][2]),
            ]);
        }
        summary.push_str(&format!(
            "{fig}/{tabn} {label} {plabel}-bit: max speedup {:.2}x; small (<=2^20) wins {}/{}; \
             largest bucket wins {}/{}.\n",
            best_speedup,
            ok[0][0] + ok[1][0],
            tot[0][0] + tot[1][0],
            ok[0][2] + ok[1][2],
            tot[0][2] + tot[1][2],
        ));
        tables.push((format!("{fig}_runtime_{label}_{plabel}"), t));
        tables.push((format!("{tabn}_speedup_{label}_{plabel}"), bt));
    }
    ExperimentOutput { tables, summary }
}

// ---------------------------------------------------------------------------
// Fig. 9 — CSR-dtANS vs the autotuner (AlphaSparse stand-in)
// ---------------------------------------------------------------------------

/// Fig. 9: on the "promising" subset (≥10% size and time win over the best
/// fixed baseline, warm cache, 32-bit), compare CSR-dtANS against the
/// autotuner's best kernel, handling symmetric matrices triangularly as
/// AlphaSparse does.
pub fn fig9(scale: &CorpusScale) -> ExperimentOutput {
    let corpus = build_corpus(scale, 42);
    let dev = GpuModel::RTX5090;
    let precision = Precision::F32;
    let opts = EncodeOptions {
        precision,
        ..Default::default()
    };
    let model = SizeModel { precision };
    let space = TuneSpace::default();

    let mut t = Table::new(&[
        "matrix", "nnz", "csr_vs_tuner", "dtans_vs_tuner", "tuner_best", "search_cost_s",
    ]);
    let mut wins = 0usize;
    let mut best_speedup: f64 = 0.0;
    let mut selected = 0usize;
    for e in &corpus {
        let mut csr = e.csr.round_to_f32();
        // Promising-subset filter (as in the paper's selection).
        let enc = CsrDtans::encode(&csr, &opts).expect("encode");
        let sell = crate::matrix::sell::Sell::from_csr(&csr, 32);
        let inp = SimInput {
            csr: &csr,
            sell: Some(&sell),
            enc: Some(&enc),
            precision,
        };
        let (_, base) = best_baseline(&inp, &dev, true);
        let dt = simulate(KernelKind::CsrDtans, &inp, &dev, true);
        let (bbytes, _) = model.best_baseline_bytes(&csr);
        // The paper's subset rule is >=10% size AND time win; our simulated
        // speedups cap near 6% at this corpus scale, so the time threshold
        // is relaxed to "any win" (the size threshold stays at 10%).
        let promising = dt.time_us < base.time_us
            && (enc.size_report().total as f64) < 0.9 * bbytes as f64;
        if !promising {
            continue;
        }
        selected += 1;
        // AlphaSparse's symmetric handling: multiply only the triangle.
        if csr.is_symmetric() {
            csr = csr.lower_triangular();
        }
        let enc = CsrDtans::encode(&csr, &opts).expect("encode");
        let tuned = autotune(&csr, precision, &space, &dev, true);
        let dtans_us = dtans_time_us(&csr, &enc, precision, &dev, true);
        let csr_inp = SimInput {
            csr: &csr,
            sell: None,
            enc: None,
            precision,
        };
        let csr_us = simulate(KernelKind::CsrScalar, &csr_inp, &dev, true).time_us;
        let rel_dtans = dtans_us / tuned.best_us;
        best_speedup = best_speedup.max(1.0 / rel_dtans);
        wins += (rel_dtans < 1.0) as usize;
        t.push(vec![
            e.name.clone(),
            csr.nnz().to_string(),
            fnum(csr_us / tuned.best_us, 3),
            fnum(rel_dtans, 3),
            tuned.best.label(),
            fnum(tuned.search_cost_us / 1e6, 1),
        ]);
    }
    let summary = format!(
        "Fig9: {selected} promising matrices; CSR-dtANS beats the autotuner on {wins} \
         (best {:.2}x; paper: 28 of 229, up to 1.87x) while the tuner costs minutes-to-hours \
         of search per matrix.",
        best_speedup
    );
    ExperimentOutput {
        tables: vec![("fig9_vs_autotuner".into(), t)],
        summary,
    }
}

// ---------------------------------------------------------------------------
// Ablations (ours): design choices called out in DESIGN.md
// ---------------------------------------------------------------------------

/// Ablation: delta-encoding on/off, PAPER vs KERNEL parameters, precision —
/// measured on compressed size over the corpus.
pub fn ablate(scale: &CorpusScale) -> ExperimentOutput {
    let corpus = build_corpus(scale, 42);
    let mut t = Table::new(&["config", "total_dtans_bytes", "total_baseline_bytes", "ratio"]);
    let mut summary = String::new();
    let configs: Vec<(&str, EncodeOptions)> = vec![
        ("paper-delta", EncodeOptions::default()),
        (
            "paper-nodelta",
            EncodeOptions {
                delta_encode: false,
                ..Default::default()
            },
        ),
        (
            "kernel-delta",
            EncodeOptions {
                params: AnsParams::KERNEL,
                ..Default::default()
            },
        ),
        (
            "paper-f32",
            EncodeOptions {
                precision: Precision::F32,
                ..Default::default()
            },
        ),
    ];
    let mut ratios: Vec<(String, f64)> = Vec::new();
    for (name, opts) in configs {
        let model = SizeModel {
            precision: opts.precision,
        };
        let mut total_dt = 0usize;
        let mut total_base = 0usize;
        for e in &corpus {
            let csr = match opts.precision {
                Precision::F64 => e.csr.clone(),
                Precision::F32 => e.csr.round_to_f32(),
            };
            let enc = CsrDtans::encode(&csr, &opts).expect("encode");
            total_dt += enc.size_report().total;
            total_base += model.best_baseline_bytes(&csr).0;
        }
        let ratio = total_dt as f64 / total_base as f64;
        ratios.push((name.to_string(), ratio));
        t.push(vec![
            name.into(),
            total_dt.to_string(),
            total_base.to_string(),
            fnum(ratio, 4),
        ]);
    }
    let delta = ratios.iter().find(|(n, _)| n == "paper-delta").unwrap().1;
    let nodelta = ratios.iter().find(|(n, _)| n == "paper-nodelta").unwrap().1;
    summary.push_str(&format!(
        "Ablate: delta-encoding improves corpus-total ratio {:.4} -> {:.4}.",
        nodelta, delta
    ));
    ExperimentOutput {
        tables: vec![("ablate_configs".into(), t)],
        summary,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig4_small() {
        let out = fig4(4096);
        assert!(!out.tables[0].1.rows.is_empty());
        // Delta encoding must reduce entropy for the clear majority.
        let reduced = out.tables[0]
            .1
            .rows
            .iter()
            .filter(|r| r[3].parse::<f64>().unwrap() < 1.0)
            .count();
        assert!(reduced * 10 >= out.tables[0].1.rows.len() * 9, "{}", out.summary);
    }

    #[test]
    fn fig6_and_tab1_small() {
        let scale = CorpusScale::small();
        let f6 = fig6(&scale);
        assert_eq!(f6.tables.len(), 2);
        assert!(f6.summary.contains("best compression"));
        let t1 = tab1(&scale);
        assert!(t1.summary.contains("success rate"));
    }

    #[test]
    fn runtime_small_warm_and_cold() {
        let scale = CorpusScale::small();
        let warm = runtime_experiment(&scale, true);
        let cold = runtime_experiment(&scale, false);
        assert_eq!(warm.tables.len(), 4);
        assert_eq!(cold.tables.len(), 4);
    }

    #[test]
    fn fig9_small_runs() {
        let out = fig9(&CorpusScale::small());
        assert!(out.summary.contains("promising"));
    }

    #[test]
    fn ablate_small_delta_helps() {
        let out = ablate(&CorpusScale::small());
        assert!(out.summary.contains("delta-encoding improves"));
        let rows = &out.tables[0].1.rows;
        let get = |name: &str| -> f64 {
            rows.iter()
                .find(|r| r[0] == name)
                .unwrap()[3]
                .parse()
                .unwrap()
        };
        assert!(get("paper-delta") <= get("paper-nodelta"));
    }
}
