//! Conjugate gradient through the solver subsystem — the paper's
//! iterative-solver motivation (§I): the matrix is re-read on every
//! iteration, so compression pays on every multiply and the one-time
//! encode + decode-plan build amortizes across the whole solve.
//!
//! The solver is written once against `&dyn SpmvOperator`, so the same
//! `solver::cg` call runs over plain CSR and over CSR-dtANS (and any
//! other registered format) unchanged; `SolveReport` splits the wall time
//! into SpMVM vs vector phases so the per-iteration kernel cost is
//! directly visible.
//!
//! Run: `cargo run --release --example cg_solver`

use dtans::format::csr_dtans::{CsrDtans, EncodeOptions};
use dtans::matrix::gen::structured::stencil2d5;
use dtans::solver::{cg_with, SolverConfig};
use dtans::spmv::engine::SpmvEngine;
use dtans::spmv::operator::{DtansOperator, SpmvOperator};
use dtans::spmv::spmv_csr;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let side = 192;
    let a = stencil2d5(side, side);
    println!(
        "2D Poisson {}x{} grid: {} unknowns, {} nnz",
        side,
        side,
        a.nrows,
        a.nnz()
    );
    let enc = CsrDtans::encode(&a, &EncodeOptions::default())?;
    println!(
        "operator: CSR {} KB -> CSR-dtANS {} KB ({:.2}x)",
        a.size_bytes_f64() / 1024,
        enc.size_report().total / 1024,
        a.size_bytes_f64() as f64 / enc.size_report().total as f64
    );
    let dtans_op = DtansOperator::new(enc); // plan built once, reused per iteration

    let b = vec![1.0; a.nrows];
    let cfg = SolverConfig { tol: 1e-8, max_iters: 4000, ..Default::default() };
    let engine = SpmvEngine::auto(); // shared: nnz-balanced parallel SpMVM
    let ops: [(&str, &dyn SpmvOperator); 2] = [("CSR", &a), ("CSR-dtANS", &dtans_op)];
    for (name, op) in ops {
        let sol = cg_with(&engine, op, &b, None, &cfg)?;
        let r = &sol.report;
        println!(
            "{name:<10} {} in {} iters (residual {:.2e}) in {:.2}s \
             ({:.3} ms/SpMVM, {:.0}% of solve in SpMVM)",
            if r.converged() { "converged" } else { "stopped" },
            r.iterations,
            r.final_residual(),
            r.total_secs,
            r.spmv_secs / r.iterations.max(1) as f64 * 1e3,
            100.0 * r.spmv_secs / r.total_secs.max(1e-12),
        );
        // Sanity: the iterate must satisfy A x ~ b.
        let mut ax = vec![0.0; a.nrows];
        spmv_csr(&a, &sol.x, &mut ax)?;
        let err = ax.iter().zip(&b).map(|(p, q)| (p - q).abs()).fold(0.0f64, f64::max);
        assert!(err < 1e-5, "solution check failed: {err}");
    }
    println!("both operators converge to the same solution — OK");
    Ok(())
}
