//! Tiered-store acceptance tests: a service whose memory budget is far
//! below its working set must answer every request **bit-identically** to
//! an unbudgeted service, under concurrent load, with evictions and cold
//! reloads observable in metrics — and re-registering an already
//! persisted matrix must hit the artifact cache and skip encoding.

use dtans::coordinator::{RoutePolicy, ServiceConfig, SpmvService};
use dtans::matrix::gen::structured::banded;
use dtans::matrix::gen::{assign_values, ValueDist};
use dtans::store::StoreConfig;
// The mixed fixture set lives in the testkit zoo (shared with the stress
// driver) instead of being duplicated inline here.
use dtans::testkit::zoo::mixed_zoo;
use dtans::util::rng::Xoshiro256;
use std::path::PathBuf;
use std::sync::atomic::Ordering;
use std::sync::Arc;

fn temp_dir(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("dtans_it_store_{tag}_{}", std::process::id()))
}

fn request_vector(ncols: usize, seed: usize) -> Vec<f64> {
    (0..ncols).map(|j| ((seed * 31 + j) as f64 * 0.001).sin()).collect()
}

#[test]
fn budgeted_service_is_bit_identical_to_unbudgeted() {
    let dir = temp_dir("bitident");
    let mats = mixed_zoo();
    assert!(mats.len() >= 8);
    let policy = RoutePolicy { min_nnz: 1 << 9, max_size_ratio: 0.95, ..Default::default() };

    // Ground truth: an unbudgeted, serial service (the pre-store path).
    let reference = SpmvService::start(ServiceConfig { policy, ..Default::default() });
    // Subject: a budget far below the working set, CSR originals dropped
    // for dtANS routes, everything persisted to the artifact cache.
    let budgeted = SpmvService::start(ServiceConfig {
        workers: 4,
        policy,
        store: StoreConfig {
            cache_dir: Some(dir.clone()),
            budget_bytes: Some(64 * 1024), // far below ~9 matrices' cost
            drop_csr: true,
            loader_threads: 2,
            ..Default::default()
        },
        ..Default::default()
    });

    let mut ids = Vec::new();
    for (i, m) in mats.iter().enumerate() {
        let a = reference.register(&format!("m{i}"), m.clone()).unwrap();
        let b = budgeted.register(&format!("m{i}"), m.clone()).unwrap();
        // Same policy + same matrix -> same route on both services.
        assert_eq!(reference.format_of(a), budgeted.format_of(b), "matrix {i}");
        ids.push((a, b, m.ncols));
    }
    budgeted.store().flush(); // all artifacts on disk -> evictable

    // Concurrent request stream from 4 threads, each sweeping the whole
    // zoo repeatedly so cold faults and evictions interleave.
    let reference = Arc::new(reference);
    let budgeted = Arc::new(budgeted);
    let ids = Arc::new(ids);
    let handles: Vec<_> = (0..4)
        .map(|t| {
            let reference = Arc::clone(&reference);
            let budgeted = Arc::clone(&budgeted);
            let ids = Arc::clone(&ids);
            std::thread::spawn(move || {
                for round in 0..3 {
                    for (i, &(ref_id, bud_id, ncols)) in ids.iter().enumerate() {
                        let x = request_vector(ncols, t * 1000 + round * 100 + i);
                        let want = reference.spmv(ref_id, x.clone()).unwrap();
                        let got = budgeted.spmv(bud_id, x).unwrap();
                        // Bit-identical, not merely close: eviction and
                        // cold reload must not change a single ULP.
                        assert_eq!(got, want, "thread {t} round {round} matrix {i}");
                    }
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }

    let m = &budgeted.metrics;
    assert!(
        m.evictions.load(Ordering::Relaxed) > 0,
        "budget below working set must evict: {}",
        m.report()
    );
    assert!(
        m.cold_loads.load(Ordering::Relaxed) > 0,
        "evicted matrices must fault back in: {}",
        m.report()
    );
    assert!(m.cold_load_summary().count > 0);
    let stats = budgeted.store().stats();
    assert_eq!(stats.registered, mats.len());
    assert_eq!(stats.budget_bytes, Some(64 * 1024));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn reregistering_persisted_matrix_skips_encoding() {
    let dir = temp_dir("rereg");
    let mut m = banded(1500, 3);
    assign_values(&mut m, ValueDist::FewDistinct(6), &mut Xoshiro256::seeded(9));

    let mk = || {
        SpmvService::start(ServiceConfig {
            store: StoreConfig { cache_dir: Some(dir.clone()), ..Default::default() },
            ..Default::default()
        })
    };

    // First service: cold cache -> one miss (encode), persisted on flush.
    let svc1 = mk();
    let id1 = svc1.register("m", m.clone()).unwrap();
    svc1.store().flush();
    assert_eq!(svc1.metrics.store_misses.load(Ordering::Relaxed), 1);
    assert_eq!(svc1.metrics.store_hits.load(Ordering::Relaxed), 0);
    let want = svc1.spmv(id1, request_vector(m.ncols, 1)).unwrap();
    drop(svc1);

    // Second service over the same cache dir: the artifact survives the
    // process' service, so registration hits and skips the encoder.
    let svc2 = mk();
    let id2 = svc2.register("m", m.clone()).unwrap();
    assert_eq!(
        svc2.metrics.store_hits.load(Ordering::Relaxed),
        1,
        "re-registering a persisted matrix must hit the artifact cache"
    );
    assert_eq!(
        svc2.metrics.store_misses.load(Ordering::Relaxed),
        0,
        "artifact hit must skip encoding"
    );
    // And the loaded-from-disk encoding answers bit-identically.
    let got = svc2.spmv(id2, request_vector(m.ncols, 1)).unwrap();
    assert_eq!(got, want);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn register_path_roundtrip_through_service() {
    let dir = temp_dir("regpath");
    std::fs::create_dir_all(&dir).unwrap();
    let mut m = banded(900, 2);
    assign_values(&mut m, ValueDist::Quantized(8), &mut Xoshiro256::seeded(5));
    let enc = dtans::format::CsrDtans::encode(&m, &Default::default()).unwrap();
    let file = dir.join("m.dtans");
    dtans::format::serialize::save(&enc, &file).unwrap();

    let svc = SpmvService::start(ServiceConfig {
        policy: RoutePolicy { min_nnz: 1 << 9, max_size_ratio: 0.95, ..Default::default() },
        ..Default::default()
    });
    let id = svc.register_path("from-artifact", &file).unwrap();
    let x = request_vector(m.ncols, 7);
    let mut want = vec![0.0; m.nrows];
    dtans::spmv::spmv_csr(&m, &x, &mut want).unwrap();
    let got = svc.spmv(id, x).unwrap();
    dtans::util::propcheck::assert_close(&got, &want, 1e-12, 1e-9).unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}
