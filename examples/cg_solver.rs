//! Conjugate-gradient solver over a CSR-dtANS-compressed operator — the
//! paper's iterative-solver motivation (§I): the matrix is read once per
//! iteration, so compression pays on every multiply and the warm-cache
//! setting applies.
//!
//! Solves the 2D Poisson problem (5-point stencil) to 1e-8 and reports the
//! per-iteration SpMVM cost on CSR vs CSR-dtANS.
//!
//! Run: `cargo run --release --example cg_solver`

use dtans::format::csr_dtans::{CsrDtans, EncodeOptions};
use dtans::matrix::gen::structured::stencil2d5;
use dtans::matrix::Csr;
use dtans::spmv::{spmv_csr, spmv_csr_dtans};

/// y = A x via the chosen operator.
enum Op<'a> {
    Csr(&'a Csr),
    Dtans(&'a CsrDtans),
}

impl Op<'_> {
    fn apply(&self, x: &[f64], y: &mut [f64]) {
        y.iter_mut().for_each(|v| *v = 0.0);
        match self {
            Op::Csr(m) => spmv_csr(m, x, y).unwrap(),
            Op::Dtans(m) => spmv_csr_dtans(m, x, y).unwrap(),
        }
    }
}

fn dot(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

/// Standard CG; returns (iterations, final residual norm, seconds in SpMVM).
fn cg(op: &Op, b: &[f64], x: &mut [f64], tol: f64, max_iter: usize) -> (usize, f64, f64) {
    let n = b.len();
    let mut r = b.to_vec();
    let mut p = r.clone();
    let mut ap = vec![0.0; n];
    let mut rs = dot(&r, &r);
    let mut spmv_secs = 0.0;
    for it in 0..max_iter {
        let t0 = std::time::Instant::now();
        op.apply(&p, &mut ap);
        spmv_secs += t0.elapsed().as_secs_f64();
        let alpha = rs / dot(&p, &ap);
        for i in 0..n {
            x[i] += alpha * p[i];
            r[i] -= alpha * ap[i];
        }
        let rs_new = dot(&r, &r);
        if rs_new.sqrt() < tol {
            return (it + 1, rs_new.sqrt(), spmv_secs);
        }
        let beta = rs_new / rs;
        rs = rs_new;
        for i in 0..n {
            p[i] = r[i] + beta * p[i];
        }
    }
    (max_iter, rs.sqrt(), spmv_secs)
}

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let side = 192;
    let a = stencil2d5(side, side);
    println!(
        "2D Poisson {}x{} grid: {} unknowns, {} nnz",
        side,
        side,
        a.nrows,
        a.nnz()
    );
    let enc = CsrDtans::encode(&a, &EncodeOptions::default())?;
    println!(
        "operator: CSR {} KB -> CSR-dtANS {} KB ({:.2}x)",
        a.size_bytes_f64() / 1024,
        enc.size_report().total / 1024,
        a.size_bytes_f64() as f64 / enc.size_report().total as f64
    );

    let b = vec![1.0; a.nrows];
    for (name, op) in [("CSR", Op::Csr(&a)), ("CSR-dtANS", Op::Dtans(&enc))] {
        let mut x = vec![0.0; a.nrows];
        let t0 = std::time::Instant::now();
        let (iters, res, spmv_secs) = cg(&op, &b, &mut x, 1e-8, 4000);
        println!(
            "{name:<10} converged in {iters} iters (residual {res:.2e}) in {:.2}s \
             ({:.3} ms/SpMVM)",
            t0.elapsed().as_secs_f64(),
            spmv_secs / iters as f64 * 1e3
        );
        // Sanity: solution must satisfy A x ~ b.
        let mut ax = vec![0.0; a.nrows];
        spmv_csr(&a, &x, &mut ax)?;
        let err = ax.iter().zip(&b).map(|(p, q)| (p - q).abs()).fold(0.0f64, f64::max);
        assert!(err < 1e-5, "solution check failed: {err}");
    }
    println!("both operators converge to the same solution — OK");
    Ok(())
}
