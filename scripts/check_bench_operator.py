#!/usr/bin/env python3
"""Smoke-checker for the operator-dispatch bench report.

Validates `results/BENCH_operator.json` (as written by
`cargo bench --bench main_bench -- operator_dispatch`) so the CI
bench-smoke step fails loudly when the report goes stale or a format
drops out of the registry:

  * the file parses as JSON;
  * the `formats` array names all six built-in formats
    (csr, coo, sell, blocked_ell, dense, csr_dtans);
  * every per-kernel timing field is present and a positive number;
  * `best_variant` names one of the vectorized candidates and
    `best_speedup_vs_csr_scalar` is a positive number (the > 1.0
    acceptance assert lives in the bench itself, full mode only —
    quick-mode CI matrices are too small for wide accumulators).

Hermetic (stdlib only, no network) so the CI job never flakes.

Usage: python3 scripts/check_bench_operator.py <BENCH_operator.json>
       python3 scripts/check_bench_operator.py --selftest
Exit code 0 when every check passes, 1 otherwise (one line per error).
"""

import json
import sys
from pathlib import Path

REQUIRED_FORMATS = {"csr", "coo", "sell", "blocked_ell", "dense", "csr_dtans"}
TIMING_FIELDS = [
    "csr_direct_s",
    "csr_dyn_s",
    "csr_dtans_direct_s",
    "csr_dtans_dyn_s",
    "csr_unrolled4_s",
    "csr_unrolled8_s",
    "blocked_ell_s",
    "blocked_ell_unrolled8_s",
]
VARIANT_CANDIDATES = {
    "csr_unrolled4",
    "csr_unrolled8",
    "blocked_ell",
    "blocked_ell_unrolled8",
}


def validate(text: str, origin: str = "<input>") -> list:
    errors = []
    try:
        report = json.loads(text)
    except json.JSONDecodeError as e:
        return [f"{origin}: not valid JSON: {e}"]
    if not isinstance(report, dict):
        return [f"{origin}: top level is not an object"]

    if report.get("bench") != "operator_dispatch":
        errors.append(f"{origin}: bench != operator_dispatch: {report.get('bench')!r}")

    formats = report.get("formats")
    if not isinstance(formats, list):
        errors.append(f"{origin}: missing/invalid formats array")
    else:
        missing = REQUIRED_FORMATS - set(formats)
        if missing:
            errors.append(f"{origin}: formats missing {sorted(missing)}")

    for field in TIMING_FIELDS:
        v = report.get(field)
        if not isinstance(v, (int, float)) or isinstance(v, bool) or v <= 0:
            errors.append(f"{origin}: {field} missing or not a positive number: {v!r}")

    best = report.get("best_variant")
    if best not in VARIANT_CANDIDATES:
        errors.append(f"{origin}: best_variant {best!r} not in {sorted(VARIANT_CANDIDATES)}")
    speedup = report.get("best_speedup_vs_csr_scalar")
    if not isinstance(speedup, (int, float)) or isinstance(speedup, bool) or speedup <= 0:
        errors.append(f"{origin}: best_speedup_vs_csr_scalar missing/invalid: {speedup!r}")
    return errors


VALID_FIXTURE = json.dumps(
    {
        "bench": "operator_dispatch",
        "quick": True,
        "nnz": 2293756,
        "formats": ["csr", "coo", "sell", "blocked_ell", "dense", "csr_dtans"],
        "csr_direct_s": 0.002,
        "csr_dyn_s": 0.00201,
        "csr_overhead_pct": 0.5,
        "csr_dtans_direct_s": 0.004,
        "csr_dtans_dyn_s": 0.00402,
        "csr_dtans_overhead_pct": 0.5,
        "csr_unrolled4_s": 0.0017,
        "csr_unrolled8_s": 0.0016,
        "blocked_ell_s": 0.0019,
        "blocked_ell_unrolled8_s": 0.0015,
        "best_variant": "blocked_ell_unrolled8",
        "best_speedup_vs_csr_scalar": 1.333,
        "acceptance_bar_pct": 5.0,
    }
)

INVALID_FIXTURES = {
    "not json": "{ nope",
    "missing format": VALID_FIXTURE.replace('"blocked_ell", ', ""),
    "missing timing": VALID_FIXTURE.replace('"csr_unrolled8_s": 0.0016, ', ""),
    "zero timing": VALID_FIXTURE.replace('"blocked_ell_s": 0.0019', '"blocked_ell_s": 0.0'),
    "unknown best variant": VALID_FIXTURE.replace(
        '"best_variant": "blocked_ell_unrolled8"', '"best_variant": "mystery"'
    ),
    "bad speedup": VALID_FIXTURE.replace(
        '"best_speedup_vs_csr_scalar": 1.333', '"best_speedup_vs_csr_scalar": "fast"'
    ),
}


def selftest() -> int:
    errs = validate(VALID_FIXTURE, "valid-fixture")
    if errs:
        print("selftest: valid fixture unexpectedly rejected:")
        for e in errs:
            print(f"  {e}")
        return 1
    failed = 0
    for label, fixture in INVALID_FIXTURES.items():
        if not validate(fixture, label):
            print(f"selftest: invalid fixture {label!r} was not caught")
            failed += 1
    print(
        f"selftest: 1 valid + {len(INVALID_FIXTURES)} invalid fixtures: "
        f"{'OK' if not failed else f'{failed} missed'}"
    )
    return 1 if failed else 0


def main() -> int:
    args = sys.argv[1:]
    if not args:
        sys.exit("usage: check_bench_operator.py <BENCH_operator.json> | --selftest")
    if args == ["--selftest"]:
        return selftest()
    errors = []
    for a in args:
        p = Path(a)
        if not p.is_file():
            sys.exit(f"not a file: {a}")
        errors.extend(validate(p.read_text(encoding="utf-8"), str(p)))
    for e in errors:
        print(e)
    print(f"checked {len(args)} report(s): {'OK' if not errors else f'{len(errors)} errors'}")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
