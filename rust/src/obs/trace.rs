//! Fixed-capacity, lock-minimal span collector with per-thread buffers,
//! configurable sampling, and Chrome trace-event export.
//!
//! A [`Tracer`] hands out [`SpanId`]s at [`Tracer::begin`] (where the
//! sampling decision is made, once per request) and collects
//! [`SpanEvent`]s from every instrumented thread. Collection is sharded:
//! each recording thread owns a process-wide *track* id (assigned lazily,
//! one per dispatcher / pool worker / client thread) and writes to the
//! shard `track % NSHARDS`, so threads almost never contend on a lock and
//! never contend with readers draining a different shard. Each shard is a
//! bounded ring — when full it overwrites its oldest event and counts the
//! loss in [`Tracer::dropped`], so a forgotten tracer can never grow
//! without bound (capacity is per shard; total memory is at most
//! `NSHARDS × capacity × sizeof(SpanEvent)`, allocated lazily as threads
//! actually record).
//!
//! Two export surfaces:
//! * [`Tracer::drain`] / [`Tracer::snapshot`] — structured [`SpanEvent`]s
//!   in timestamp order, for oracles and programmatic consumers;
//! * [`Tracer::trace_json`] — Chrome trace-event JSON, loadable in
//!   Perfetto (<https://ui.perfetto.dev>) or `chrome://tracing`, one
//!   track per recording thread (see `docs/OBSERVABILITY.md`).

use crate::obs::span::{SpanEvent, SpanId, Stage};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// Shards in the collector (a small power of two: more than the typical
/// worker count so co-resident threads rarely share a lock).
const NSHARDS: usize = 16;

/// Tracing configuration, set at service construction
/// ([`ServiceConfig::obs`](crate::coordinator::service::ServiceConfig)).
#[derive(Debug, Clone, Copy)]
pub struct ObsConfig {
    /// Sample one request in this many: `1` traces every request (the
    /// default), `N` traces those whose trace id is a multiple of `N`,
    /// `0` disables tracing entirely (every span is [`SpanId::NONE`] and
    /// the hot path pays only the `begin` counter increment).
    pub sample_one_in: u32,
    /// Ring capacity **per shard**, in events. When a shard fills, its
    /// oldest event is overwritten and [`Tracer::dropped`] grows — size
    /// this above the expected event volume when span conservation must
    /// hold (the stress driver scales it from its op count).
    pub capacity: usize,
}

impl Default for ObsConfig {
    fn default() -> Self {
        ObsConfig {
            sample_one_in: 1,
            capacity: 65536,
        }
    }
}

/// Process-wide track allocator: one stable id per OS thread, shared by
/// all tracers (a thread keeps its track for its lifetime).
static NEXT_TRACK: AtomicU32 = AtomicU32::new(1);
thread_local! {
    static TRACK: std::cell::Cell<u32> = const { std::cell::Cell::new(0) };
}

/// The calling thread's track id, assigned on first use.
fn current_track() -> u32 {
    TRACK.with(|t| {
        let v = t.get();
        if v != 0 {
            return v;
        }
        let v = NEXT_TRACK.fetch_add(1, Ordering::Relaxed);
        t.set(v);
        v
    })
}

/// One bounded event ring (see module docs for the sharding scheme).
#[derive(Debug, Default)]
struct Shard {
    buf: Vec<SpanEvent>,
    /// Oldest slot, once full.
    next: usize,
}

/// Span collector: sampling, sharded rings, counters, and exporters.
#[derive(Debug)]
pub struct Tracer {
    sample_one_in: u32,
    capacity: usize,
    epoch: Instant,
    next_trace: AtomicU64,
    next_batch: AtomicU64,
    recorded: AtomicU64,
    dropped: AtomicU64,
    shards: [Mutex<Shard>; NSHARDS],
    /// Human labels for tracks that announced a role ("dispatcher",
    /// "worker", …) — rendered as Chrome-trace thread names.
    labels: Mutex<BTreeMap<u32, &'static str>>,
}

impl Default for Tracer {
    fn default() -> Self {
        Tracer::new(ObsConfig::default())
    }
}

impl Tracer {
    /// A tracer with the given sampling and capacity.
    pub fn new(cfg: ObsConfig) -> Tracer {
        Tracer {
            sample_one_in: cfg.sample_one_in,
            capacity: cfg.capacity.max(1),
            epoch: Instant::now(),
            next_trace: AtomicU64::new(0),
            next_batch: AtomicU64::new(0),
            recorded: AtomicU64::new(0),
            dropped: AtomicU64::new(0),
            shards: std::array::from_fn(|_| Mutex::new(Shard::default())),
            labels: Mutex::new(BTreeMap::new()),
        }
    }

    /// Is tracing disabled outright (`sample_one_in == 0`)?
    pub fn is_off(&self) -> bool {
        self.sample_one_in == 0
    }

    /// Start a span chain: assigns the next trace id and decides sampling
    /// (`id % sample_one_in == 0`). Unsampled requests get
    /// [`SpanId::NONE`], making every later [`Tracer::record`] a no-op —
    /// instrumentation sites never branch on configuration themselves.
    pub fn begin(&self) -> SpanId {
        if self.sample_one_in == 0 {
            return SpanId::NONE;
        }
        let id = self.next_trace.fetch_add(1, Ordering::Relaxed) + 1;
        if id % self.sample_one_in as u64 == 0 {
            SpanId(id)
        } else {
            SpanId::NONE
        }
    }

    /// Next shared batch id, linking coalesced requests
    /// ([`Stage::Coalesced`]) to their SpMM batch.
    pub fn batch_id(&self) -> u64 {
        self.next_batch.fetch_add(1, Ordering::Relaxed) + 1
    }

    /// Record one stage event against a span. No-op for
    /// [`SpanId::NONE`]; otherwise one timestamp read plus one push under
    /// the calling thread's shard lock.
    pub fn record(&self, span: SpanId, stage: Stage) {
        if !span.is_sampled() {
            return;
        }
        let ev = SpanEvent {
            span,
            ts_us: self.epoch.elapsed().as_micros() as u64,
            track: current_track(),
            stage,
        };
        let mut shard = self.shards[ev.track as usize % NSHARDS].lock().unwrap();
        if shard.buf.len() < self.capacity {
            shard.buf.push(ev);
        } else {
            let next = shard.next;
            shard.buf[next] = ev;
            shard.next = (next + 1) % self.capacity;
            self.dropped.fetch_add(1, Ordering::Relaxed);
        }
        drop(shard);
        self.recorded.fetch_add(1, Ordering::Relaxed);
    }

    /// Label the calling thread's track with a role name (idempotent;
    /// last label wins). Shown as the thread name in Chrome traces.
    pub fn label_current_track(&self, name: &'static str) {
        let track = current_track();
        self.labels.lock().unwrap().insert(track, name);
    }

    /// Events recorded (including any later overwritten).
    pub fn recorded(&self) -> u64 {
        self.recorded.load(Ordering::Relaxed)
    }

    /// Events lost to ring overwrites. Span conservation only holds for
    /// a drain observed with `dropped() == 0`.
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// Microseconds since the tracer's construction (the `ts_us` clock).
    pub fn now_us(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }

    fn collect(&self, clear: bool) -> Vec<SpanEvent> {
        let mut out = Vec::new();
        for shard in &self.shards {
            let mut s = shard.lock().unwrap();
            // Ring order: oldest (next..) then (..next).
            out.extend_from_slice(&s.buf[s.next..]);
            out.extend_from_slice(&s.buf[..s.next]);
            if clear {
                s.buf.clear();
                s.next = 0;
            }
        }
        out.sort_by_key(|e| (e.ts_us, e.span.0));
        out
    }

    /// Remove and return all buffered events, oldest first (globally
    /// ordered by timestamp). Counters are cumulative and unaffected.
    pub fn drain(&self) -> Vec<SpanEvent> {
        self.collect(true)
    }

    /// Copy of all buffered events in timestamp order, without clearing.
    pub fn snapshot(&self) -> Vec<SpanEvent> {
        self.collect(false)
    }

    /// Render the buffered events as Chrome trace-event JSON: one
    /// process, one track (`tid`) per recording thread, duration-bearing
    /// stages ([`Stage::duration_us`]) as complete (`"ph":"X"`) events
    /// and the rest as thread-scoped instants (`"ph":"i"`). Load the
    /// string as a `.json` file in Perfetto or `chrome://tracing`.
    pub fn trace_json(&self) -> String {
        let events = self.snapshot();
        let labels = self.labels.lock().unwrap().clone();
        let mut out = String::with_capacity(128 + events.len() * 96);
        out.push_str("{\"displayTimeUnit\":\"ms\",\"traceEvents\":[");
        let mut first = true;
        let mut push = |out: &mut String, s: &str| {
            if !first {
                out.push(',');
            }
            first = false;
            out.push_str(s);
        };
        for (track, name) in &labels {
            push(
                &mut out,
                &format!(
                    "{{\"name\":\"thread_name\",\"ph\":\"M\",\"pid\":1,\"tid\":{track},\
                     \"args\":{{\"name\":\"{name}-{track}\"}}}}"
                ),
            );
        }
        for e in &events {
            let args = stage_args(&e.stage);
            let line = match e.stage.duration_us() {
                Some(dur) => format!(
                    "{{\"name\":\"{}\",\"ph\":\"X\",\"pid\":1,\"tid\":{},\"ts\":{},\
                     \"dur\":{},\"args\":{{{args}}}}}",
                    e.stage.name(),
                    e.track,
                    e.ts_us.saturating_sub(dur),
                    dur
                ),
                None => format!(
                    "{{\"name\":\"{}\",\"ph\":\"i\",\"s\":\"t\",\"pid\":1,\"tid\":{},\
                     \"ts\":{},\"args\":{{{args}}}}}",
                    e.stage.name(),
                    e.track,
                    e.ts_us
                ),
            };
            push(&mut out, &line);
        }
        out.push_str("]}");
        out
    }
}

/// The `args` object body (no braces) for one stage event.
fn stage_args(stage: &Stage) -> String {
    match stage {
        Stage::Submitted { matrix } => format!("\"matrix\":{matrix}"),
        Stage::Queued { wait_us } => format!("\"wait_us\":{wait_us}"),
        Stage::Dispatched | Stage::Pinned | Stage::Failed | Stage::Shed | Stage::Expired => {
            String::new()
        }
        Stage::ColdLoad { matrix, dur_us } => {
            format!("\"matrix\":{matrix},\"dur_us\":{dur_us}")
        }
        Stage::Coalesced { batch, size } => format!("\"batch\":{batch},\"size\":{size}"),
        Stage::Kernel {
            format,
            blocks,
            min_us,
            max_us,
            mean_us,
            dur_us,
        } => format!(
            "\"format\":\"{format}\",\"blocks\":{blocks},\"min_us\":{min_us},\
             \"max_us\":{max_us},\"mean_us\":{mean_us},\"dur_us\":{dur_us}"
        ),
        Stage::Completed { total_us } => format!("\"total_us\":{total_us}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn always_on_samples_every_request() {
        let t = Tracer::new(ObsConfig::default());
        for _ in 0..10 {
            let s = t.begin();
            assert!(s.is_sampled());
            t.record(s, Stage::Dispatched);
        }
        assert_eq!(t.recorded(), 10);
        assert_eq!(t.dropped(), 0);
        assert_eq!(t.drain().len(), 10);
        // Drain empties the buffers but keeps counters cumulative.
        assert_eq!(t.drain().len(), 0);
        assert_eq!(t.recorded(), 10);
    }

    #[test]
    fn sampling_keeps_every_nth_trace_id() {
        let t = Tracer::new(ObsConfig {
            sample_one_in: 4,
            capacity: 1024,
        });
        let spans: Vec<SpanId> = (0..16).map(|_| t.begin()).collect();
        let sampled: Vec<u64> =
            spans.iter().filter(|s| s.is_sampled()).map(|s| s.0).collect();
        assert_eq!(sampled, vec![4, 8, 12, 16]);
        // Records against unsampled spans are dropped silently.
        for s in &spans {
            t.record(*s, Stage::Dispatched);
        }
        assert_eq!(t.recorded(), 4);
    }

    #[test]
    fn off_mode_records_nothing() {
        let t = Tracer::new(ObsConfig {
            sample_one_in: 0,
            capacity: 16,
        });
        assert!(t.is_off());
        let s = t.begin();
        assert!(!s.is_sampled());
        t.record(s, Stage::Failed);
        assert_eq!(t.recorded(), 0);
        assert!(t.drain().is_empty());
    }

    #[test]
    fn ring_overwrites_oldest_and_counts_drops() {
        let t = Tracer::new(ObsConfig {
            sample_one_in: 1,
            capacity: 4,
        });
        // All records land on this thread → one shard of capacity 4.
        for i in 0..10u64 {
            t.record(SpanId(i + 1), Stage::Dispatched);
        }
        assert_eq!(t.recorded(), 10);
        assert_eq!(t.dropped(), 6);
        let events = t.drain();
        assert_eq!(events.len(), 4);
        // Oldest-first: the survivors are the last four records.
        let ids: Vec<u64> = events.iter().map(|e| e.span.0).collect();
        assert_eq!(ids, vec![7, 8, 9, 10]);
    }

    #[test]
    fn events_drain_in_timestamp_order_across_threads() {
        let t = std::sync::Arc::new(Tracer::new(ObsConfig::default()));
        let mut handles = Vec::new();
        for k in 0..4u64 {
            let t = std::sync::Arc::clone(&t);
            handles.push(std::thread::spawn(move || {
                for i in 0..50 {
                    t.record(SpanId(k * 100 + i + 1), Stage::Dispatched);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let events = t.drain();
        assert_eq!(events.len(), 200);
        assert!(events.windows(2).all(|w| w[0].ts_us <= w[1].ts_us));
        // Threads got distinct tracks.
        let tracks: std::collections::BTreeSet<u32> =
            events.iter().map(|e| e.track).collect();
        assert_eq!(tracks.len(), 4);
    }

    #[test]
    fn trace_json_has_events_and_labels() {
        let t = Tracer::default();
        t.label_current_track("tester");
        let s = t.begin();
        t.record(s, Stage::Submitted { matrix: 3 });
        t.record(s, Stage::Queued { wait_us: 12 });
        t.record(s, Stage::Completed { total_us: 99 });
        let json = t.trace_json();
        assert!(json.starts_with("{\"displayTimeUnit\":\"ms\",\"traceEvents\":["));
        assert!(json.ends_with("]}"));
        assert!(json.contains("\"thread_name\""));
        assert!(json.contains("tester-"));
        assert!(json.contains("\"name\":\"submitted\""));
        // Duration-bearing stages render as complete events.
        assert!(json.contains("\"ph\":\"X\""));
        assert!(json.contains("\"wait_us\":12"));
        // snapshot() does not clear: drain still sees the events.
        assert_eq!(t.drain().len(), 3);
    }
}
