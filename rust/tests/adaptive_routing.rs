//! Tier-1 acceptance for online adaptive routing (`docs/ROUTING.md`):
//! the deterministic simulator proves the bandit converges off a
//! hostile static choice, follows a mid-run regime reversal, and never
//! flips inside the hysteresis margin; a real service with exploration
//! disabled is **bit-identical** to static routing; an exploring
//! service conserves its counters while every response stays within
//! the documented ULP contract of the conformance oracle; and an
//! artifact-registered matrix (no CSR original, cold loads through a
//! [`FailingDir`]-managed cache) rejects CSR-requiring pins with the
//! typed routing error across eviction/reload cycles.

use dtans::coordinator::{
    AdaptiveConfig, Arm, FormatChoice, RouteOverride, RoutePolicy, ServiceConfig, SpmvService,
};
use dtans::matrix::gen::structured::banded;
use dtans::matrix::gen::{assign_values, ValueDist};
use dtans::spmv::spmv_csr;
use dtans::store::StoreConfig;
use dtans::testkit::faults::FailingDir;
use dtans::testkit::routing_sim::{run_routing_sim, ArmProfile, Regime, SimConfig};
use dtans::util::propcheck::assert_close;
use dtans::util::rng::Xoshiro256;
use dtans::DtansError;
use std::sync::atomic::Ordering;

fn dtans_arm() -> Arm {
    Arm::format(FormatChoice::CsrDtans)
}

fn csr_arm() -> Arm {
    Arm::format(FormatChoice::Csr)
}

/// dtANS-hostile regime: the static choice is 1.6× slower than the CSR
/// baseline. The router must abandon it within 200 observations, with
/// exactly one committed flip — and when the regime reverses mid-run,
/// it must flip back.
#[test]
fn hostile_regime_flips_to_csr_and_back_on_reversal() {
    let out = run_routing_sim(&SimConfig::regime(Regime::Stationary));
    assert_eq!(out.final_incumbent, csr_arm());
    assert_eq!(out.flips.len(), 1, "{:?}", out.flips);
    assert_eq!((out.flips[0].from, out.flips[0].to), (dtans_arm(), csr_arm()));
    let at = out.converged_at.expect("converged");
    assert!(at <= 200, "flip must land within 200 observations, was {at}");

    let rev = run_routing_sim(&SimConfig::regime(Regime::Stationary).with_reversal(200));
    assert_eq!(rev.final_incumbent, dtans_arm(), "regime reversed, route must follow");
    assert_eq!(rev.flips.len(), 2, "{:?}", rev.flips);
    assert_eq!(rev.flips[1].to, dtans_arm());
    assert!(rev.flips[1].at_observation > 200);
}

/// A challenger 5% faster than the incumbent, against a 10% hysteresis
/// margin: no flip, ever — however long the trace and however much it
/// explores. (The flap bound under real noise lives in the simulator's
/// own bimodal test; this is the margin contract in isolation.)
#[test]
fn challenger_inside_the_hysteresis_margin_never_flips() {
    let mut cfg = SimConfig::regime(Regime::Stationary);
    cfg.profiles =
        vec![ArmProfile::flat(dtans_arm(), 300.0, 0.0), ArmProfile::flat(csr_arm(), 285.0, 0.0)];
    cfg.adaptive.explore_fraction = 0.3;
    cfg.steps = 500;
    let out = run_routing_sim(&cfg);
    assert!(out.flips.is_empty(), "{:?}", out.flips);
    assert_eq!(out.final_incumbent, dtans_arm());
    assert!(out.counters.explored > 0, "the margin held against real challenger data");
}

/// With exploration at zero the adaptive layer is observationally
/// invisible: a learned-routing service answers bit-for-bit what a
/// static-routing service answers, because no challenger ever gets the
/// observations hysteresis demands.
#[test]
fn zero_exploration_service_is_bit_identical_to_static_routing() {
    let mut m = banded(600, 3);
    assign_values(&mut m, ValueDist::FewDistinct(6), &mut Xoshiro256::seeded(11));
    let xs: Vec<Vec<f64>> =
        (0..10).map(|i| dtans::testkit::seeded_vector(600, 100 + i as u64)).collect();
    let run = |adaptive: AdaptiveConfig| -> Vec<Vec<f64>> {
        let svc = SpmvService::start(ServiceConfig { adaptive, ..Default::default() });
        let id = svc.register("m", m.clone()).unwrap();
        xs.iter().map(|x| svc.spmv(id, x.clone()).unwrap()).collect()
    };
    let static_bits = run(AdaptiveConfig::default());
    let adaptive_bits = run(AdaptiveConfig::zero_exploration());
    assert_eq!(static_bits, adaptive_bits);
}

/// An aggressively-exploring service: every response (whichever arm
/// served it) stays within the conformance oracle's ULP contract of
/// the serial CSR ground truth, and when the dust settles
/// `explored + exploited == routed` holds in both the router's own
/// counters and the exported metrics.
#[test]
fn exploring_service_conserves_counters_and_stays_ulp_close() {
    let svc = SpmvService::start(ServiceConfig {
        adaptive: AdaptiveConfig { explore_fraction: 0.5, ..AdaptiveConfig::enabled() },
        ..Default::default()
    });
    let mut m = banded(500, 3);
    assign_values(&mut m, ValueDist::FewDistinct(5), &mut Xoshiro256::seeded(3));
    let id = svc.register("m", m.clone()).unwrap();
    assert_eq!(svc.adaptive().admissible_arms(id).len(), 3, "kept CSR ⇒ all formats admissible");
    for i in 0..80u64 {
        let x = dtans::testkit::seeded_vector(500, i);
        let mut want = vec![0.0; 500];
        spmv_csr(&m, &x, &mut want).unwrap();
        let got = svc.spmv(id, x).unwrap();
        assert_close(&got, &want, 1e-12, 1e-9).unwrap();
    }
    let c = svc.adaptive().counters();
    assert_eq!(c.routed, 80);
    assert_eq!(c.explored + c.exploited, c.routed);
    assert!(c.explored > 0, "ε = 0.5 over 80 requests must explore: {c:?}");
    assert_eq!(svc.metrics.routed_requests.load(Ordering::Relaxed), c.routed);
    assert_eq!(svc.metrics.explore_requests.load(Ordering::Relaxed), c.explored);
    assert_eq!(svc.metrics.route_flips.load(Ordering::Relaxed), c.flips);
}

/// Regression for the residency gap: an artifact-registered matrix has
/// no CSR original (`drop_csr`), so its only admissible arm is its own
/// encoded format — a pin to the CSR arm must fail with the typed
/// [`DtansError::InadmissibleRoute`], and it must *keep* failing across
/// eviction/cold-reload cycles (each reload rebuilds the residency
/// answer from scratch), while clearing the pin restores service.
#[test]
fn artifact_registered_matrix_rejects_csr_pins_across_cold_loads() {
    let dir = FailingDir::new("adaptive_route").unwrap();
    let svc = SpmvService::start(ServiceConfig {
        policy: RoutePolicy { min_nnz: 1 << 8, max_size_ratio: 0.98, ..Default::default() },
        store: StoreConfig {
            cache_dir: Some(dir.root().to_path_buf()),
            budget_bytes: Some(1), // everything persisted is evictable
            drop_csr: true,
            ..Default::default()
        },
        adaptive: AdaptiveConfig::zero_exploration(),
        ..Default::default()
    });
    let mut m = banded(800, 3);
    assign_values(&mut m, ValueDist::FewDistinct(4), &mut Xoshiro256::seeded(9));
    let id = svc.register("cold", m.clone()).unwrap();
    assert_eq!(svc.format_of(id), Some(FormatChoice::CsrDtans));
    assert_eq!(svc.adaptive().admissible_arms(id), vec![dtans_arm()]);

    let x = dtans::testkit::seeded_vector(800, 42);
    let mut want = vec![0.0; 800];
    spmv_csr(&m, &x, &mut want).unwrap();

    svc.pin_route(id, RouteOverride::Pin(csr_arm()));
    for round in 0..3 {
        // Force the next request through the cold-load path.
        svc.store().flush();
        svc.store().evict(id);
        let err = svc.spmv(id, x.clone()).unwrap_err();
        assert!(
            matches!(err, DtansError::InadmissibleRoute { matrix, tag: "csr" } if matrix == id),
            "round {round}: {err}"
        );
    }
    // Clearing the pin restores the (sole admissible) registered route,
    // still through a cold load.
    svc.pin_route(id, RouteOverride::Clear);
    svc.store().flush();
    svc.store().evict(id);
    let got = svc.spmv(id, x).unwrap();
    assert_close(&got, &want, 1e-12, 1e-9).unwrap();
}
