//! Exhaustive search over the format × parameter space via the simulator.

use crate::format::csr_dtans::CsrDtans;
use crate::matrix::csr::Csr;
use crate::matrix::sell::Sell;
use crate::matrix::Precision;
use crate::sim::{simulate, GpuModel, KernelKind, SimInput};

/// One point in the search space.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Candidate {
    /// Kernel family.
    pub kind: KernelKind,
    /// SELL slice height (only for `Sell`).
    pub sell_height: usize,
}

impl Candidate {
    /// Report label.
    pub fn label(&self) -> String {
        match self.kind {
            KernelKind::Sell => format!("SELL-{}", self.sell_height),
            k => k.label().to_string(),
        }
    }
}

/// The search space definition.
#[derive(Debug, Clone)]
pub struct TuneSpace {
    /// SELL slice heights to sweep.
    pub sell_heights: Vec<usize>,
    /// Include the row-split CSR-vector variant.
    pub include_vector: bool,
    /// Per-candidate code-generation overhead in microseconds — models
    /// AlphaSparse's compilation step (the source of its "hours per
    /// matrix" cost).
    pub codegen_overhead_us: f64,
}

impl Default for TuneSpace {
    fn default() -> Self {
        TuneSpace {
            sell_heights: vec![4, 8, 16, 32, 64, 128],
            include_vector: true,
            codegen_overhead_us: 30e6, // ~30 s compile per candidate kernel
        }
    }
}

/// Autotuning outcome.
#[derive(Debug, Clone)]
pub struct TuneResult {
    /// Winning candidate.
    pub best: Candidate,
    /// Simulated runtime of the winner (µs).
    pub best_us: f64,
    /// Total search cost (µs) including per-candidate codegen overhead.
    pub search_cost_us: f64,
    /// All evaluated candidates with their times.
    pub evaluated: Vec<(Candidate, f64)>,
}

/// Exhaustively evaluate the space on a matrix; `warm` selects cache state.
pub fn autotune(
    csr: &Csr,
    precision: Precision,
    space: &TuneSpace,
    dev: &GpuModel,
    warm: bool,
) -> TuneResult {
    let mut evaluated: Vec<(Candidate, f64)> = Vec::new();
    let mut search_cost = 0.0;

    let base_input = SimInput {
        csr,
        sell: None,
        enc: None,
        precision,
    };
    let mut kinds = vec![KernelKind::CsrScalar, KernelKind::Coo];
    if space.include_vector {
        kinds.push(KernelKind::CsrVector);
    }
    for kind in kinds {
        let r = simulate(kind, &base_input, dev, warm);
        evaluated.push((Candidate { kind, sell_height: 0 }, r.time_us));
        search_cost += r.time_us + space.codegen_overhead_us;
    }
    for &h in &space.sell_heights {
        let sell = Sell::from_csr(csr, h);
        let inp = SimInput {
            csr,
            sell: Some(&sell),
            enc: None,
            precision,
        };
        let r = simulate(KernelKind::Sell, &inp, dev, warm);
        evaluated.push((
            Candidate {
                kind: KernelKind::Sell,
                sell_height: h,
            },
            r.time_us,
        ));
        search_cost += r.time_us + space.codegen_overhead_us;
    }

    let (best, best_us) = evaluated
        .iter()
        .cloned()
        .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
        .expect("non-empty space");
    TuneResult {
        best,
        best_us,
        search_cost_us: search_cost,
        evaluated,
    }
}

/// Simulated CSR-dtANS runtime for the same matrix (the fixed-format
/// contender in Fig. 9).
pub fn dtans_time_us(
    csr: &Csr,
    enc: &CsrDtans,
    precision: Precision,
    dev: &GpuModel,
    warm: bool,
) -> f64 {
    let inp = SimInput {
        csr,
        sell: None,
        enc: Some(enc),
        precision,
    };
    simulate(KernelKind::CsrDtans, &inp, dev, warm).time_us
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::gen::structured::{banded, powerlaw_rows};
    use crate::util::rng::Xoshiro256;

    #[test]
    fn finds_a_winner_and_charges_search_cost() {
        let m = banded(5000, 3);
        let space = TuneSpace::default();
        let r = autotune(&m, Precision::F32, &space, &GpuModel::RTX5090, true);
        assert!(!r.evaluated.is_empty());
        assert!(r.best_us > 0.0);
        // Search cost is dominated by codegen overhead — the paper's
        // "extreme computation overhead" of AlphaSparse.
        assert!(r.search_cost_us > 100e6);
        assert!(r.evaluated.iter().all(|(_, t)| *t >= r.best_us));
    }

    #[test]
    fn regular_matrix_prefers_sell_like_kernels() {
        // Banded matrices have uniform rows: SELL should beat COO.
        let m = banded(20_000, 4);
        let r = autotune(&m, Precision::F32, &TuneSpace::default(), &GpuModel::RTX5090, true);
        let coo_time = r
            .evaluated
            .iter()
            .find(|(c, _)| c.kind == KernelKind::Coo)
            .unwrap()
            .1;
        assert!(r.best_us <= coo_time);
    }

    #[test]
    fn irregular_matrix_not_csr_scalar() {
        let mut rng = Xoshiro256::seeded(4);
        let m = powerlaw_rows(20_000, 8.0, 1.2, &mut rng);
        let r = autotune(&m, Precision::F32, &TuneSpace::default(), &GpuModel::RTX5090, true);
        // Scalar CSR pays the warp-max divergence on power-law rows; the
        // tuner must find something better.
        let scalar = r
            .evaluated
            .iter()
            .find(|(c, _)| c.kind == KernelKind::CsrScalar)
            .unwrap()
            .1;
        assert!(r.best_us < scalar);
    }
}
