//! Value distributions assigned to sparsity patterns.
//!
//! The compressibility of the *value* stream varies enormously across
//! domains: pattern matrices (all 1.0) are maximally compressible, FEM
//! matrices have few distinct stiffness values, quantized NN weights have
//! e.g. 256 levels, and random measurement data is incompressible (every
//! value escapes). The corpus sweeps all of these.

use crate::matrix::csr::Csr;
use crate::util::rng::Xoshiro256;

/// Value distribution families.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ValueDist {
    /// All values 1.0 (pattern matrices).
    Ones,
    /// Uniform over `k` distinct values (FEM-style).
    FewDistinct(usize),
    /// Gaussian quantized to `levels` levels (quantized NN weights).
    Quantized(usize),
    /// Small integers in `[-range, range]` (integer matrices).
    SmallInts(u32),
    /// Fully random uniform in [0,1) — incompressible values.
    Random,
    /// Gaussian N(0,1) — incompressible values with sign structure.
    Gaussian,
}

impl ValueDist {
    /// Parse from a CLI label like `ones`, `few16`, `quant256`, `random`.
    pub fn parse(s: &str) -> Option<ValueDist> {
        let s = s.to_ascii_lowercase();
        if s == "ones" {
            Some(ValueDist::Ones)
        } else if s == "random" {
            Some(ValueDist::Random)
        } else if s == "gaussian" {
            Some(ValueDist::Gaussian)
        } else if let Some(k) = s.strip_prefix("few") {
            k.parse().ok().map(ValueDist::FewDistinct)
        } else if let Some(k) = s.strip_prefix("quant") {
            k.parse().ok().map(ValueDist::Quantized)
        } else if let Some(k) = s.strip_prefix("ints") {
            k.parse().ok().map(ValueDist::SmallInts)
        } else {
            None
        }
    }

    /// Label for reports.
    pub fn label(&self) -> String {
        match self {
            ValueDist::Ones => "ones".into(),
            ValueDist::FewDistinct(k) => format!("few{k}"),
            ValueDist::Quantized(k) => format!("quant{k}"),
            ValueDist::SmallInts(k) => format!("ints{k}"),
            ValueDist::Random => "random".into(),
            ValueDist::Gaussian => "gaussian".into(),
        }
    }
}

/// Overwrite the values of `m` in place according to `dist`.
pub fn assign_values(m: &mut Csr, dist: ValueDist, rng: &mut Xoshiro256) {
    match dist {
        ValueDist::Ones => {
            for v in &mut m.vals {
                *v = 1.0;
            }
        }
        ValueDist::FewDistinct(k) => {
            let k = k.max(1);
            let palette: Vec<f64> = (0..k).map(|_| rng.next_gaussian()).collect();
            for v in &mut m.vals {
                *v = palette[rng.below_usize(k)];
            }
        }
        ValueDist::Quantized(levels) => {
            let levels = levels.max(2) as f64;
            for v in &mut m.vals {
                let g = rng.next_gaussian().clamp(-4.0, 4.0);
                // Quantize to `levels` uniform levels over [-4, 4].
                let q = ((g + 4.0) / 8.0 * (levels - 1.0)).round() / (levels - 1.0) * 8.0 - 4.0;
                *v = q;
            }
        }
        ValueDist::SmallInts(range) => {
            let span = (2 * range + 1) as u64;
            for v in &mut m.vals {
                *v = (rng.below(span) as i64 - range as i64) as f64;
            }
        }
        ValueDist::Random => {
            for v in &mut m.vals {
                *v = rng.next_f64();
            }
        }
        ValueDist::Gaussian => {
            for v in &mut m.vals {
                *v = rng.next_gaussian();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::gen::structured::banded;
    use std::collections::HashSet;

    fn distinct(m: &Csr) -> usize {
        m.vals.iter().map(|v| v.to_bits()).collect::<HashSet<_>>().len()
    }

    #[test]
    fn ones_single_value() {
        let mut m = banded(100, 3);
        assign_values(&mut m, ValueDist::Ones, &mut Xoshiro256::seeded(1));
        assert_eq!(distinct(&m), 1);
    }

    #[test]
    fn few_distinct_bounded() {
        let mut m = banded(100, 3);
        assign_values(&mut m, ValueDist::FewDistinct(8), &mut Xoshiro256::seeded(2));
        assert!(distinct(&m) <= 8);
    }

    #[test]
    fn quantized_bounded_levels() {
        let mut m = banded(200, 3);
        assign_values(&mut m, ValueDist::Quantized(16), &mut Xoshiro256::seeded(3));
        assert!(distinct(&m) <= 16);
    }

    #[test]
    fn random_mostly_distinct() {
        let mut m = banded(100, 3);
        assign_values(&mut m, ValueDist::Random, &mut Xoshiro256::seeded(4));
        assert!(distinct(&m) > m.nnz() / 2);
    }

    #[test]
    fn parse_labels() {
        assert_eq!(ValueDist::parse("few16"), Some(ValueDist::FewDistinct(16)));
        assert_eq!(ValueDist::parse("quant256"), Some(ValueDist::Quantized(256)));
        assert_eq!(ValueDist::parse("ones"), Some(ValueDist::Ones));
        assert!(ValueDist::parse("bogus").is_none());
    }
}
