"""Property tests of the pure-numpy dtANS reference codec (hypothesis)."""

import numpy as np
import pytest

# hypothesis is not baked into the offline image; skip (not error) without it.
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from compile.kernels import ref


def make_tables(rng: np.random.Generator, nsyms: int) -> ref.Tables:
    counts = rng.integers(1, 1000, size=max(nsyms, ref.K // ref.M)).astype(np.float64)
    return ref.Tables.build(ref.normalize_counts(counts))


# ---------------------------------------------------------------------------
# Table construction
# ---------------------------------------------------------------------------


@given(st.integers(0, 2**32 - 1), st.integers(16, 512))
@settings(max_examples=25, deadline=None)
def test_normalize_sums_to_k_with_cap(seed, nsyms):
    rng = np.random.default_rng(seed)
    counts = rng.integers(1, 10_000, size=nsyms).astype(np.float64)
    mult = ref.normalize_counts(counts)
    assert mult.sum() == ref.K
    assert mult.min() >= 1 and mult.max() <= ref.M


def test_tables_layout():
    t = ref.Tables.build(ref.normalize_counts(np.array([100.0, 10.0] * 8)))
    # Slots of one symbol are consecutive with digits 0..mult-1.
    for sym in range(t.num_symbols):
        start, q = int(t.sym_start[sym]), int(t.sym_mult[sym])
        entries = t.packed[start : start + q]
        assert ((entries >> 16) == sym).all()
        assert (((entries >> 8) & 0xFF) == np.arange(q)).all()
        assert ((entries & 0xFF) == q - 1).all()


# ---------------------------------------------------------------------------
# Row codec roundtrips
# ---------------------------------------------------------------------------


@given(st.integers(0, 2**32 - 1), st.integers(0, 40), st.booleans())
@settings(max_examples=40, deadline=None)
def test_row_roundtrip(seed, nseg, two_domains):
    rng = np.random.default_rng(seed)
    t0 = make_tables(rng, 50)
    tables = [t0, make_tables(rng, 300)] if two_domains else [t0]
    syms = []
    for i in range(nseg * ref.L_SYMS):
        t = tables[i % len(tables)]
        # Skew towards frequent symbols.
        if rng.random() < 0.7:
            syms.append(int(rng.integers(0, min(4, t.num_symbols))))
        else:
            syms.append(int(rng.integers(0, t.num_symbols)))
    words, branches = ref.encode_row(tables, syms)
    assert ref.decode_row(tables, words, len(syms)) == syms
    loads = sum(1 for b in branches if not b)
    if nseg > 0:
        expected = ref.O_WORDS + (nseg - 1) * (ref.O_WORDS - ref.F_CHECKS) + loads
        assert len(words) == expected


def test_single_segment_costs_o_words():
    rng = np.random.default_rng(7)
    t = make_tables(rng, 64)
    words, _ = ref.encode_row([t], [1, 2, 3, 0])
    assert len(words) == ref.O_WORDS


def test_hot_symbols_cheaper_than_cold():
    rng = np.random.default_rng(8)
    t = make_tables(rng, 200)
    hot = int(np.argmax(t.sym_mult))
    cold = int(np.argmin(t.sym_mult))
    n = 32 * ref.L_SYMS
    w_hot, _ = ref.encode_row([t], [hot] * n)
    w_cold, _ = ref.encode_row([t], [cold] * n)
    assert len(w_hot) < len(w_cold)


# ---------------------------------------------------------------------------
# Matrix-level: encode_matrix + scalar oracle vs plain CSR SpMVM
# ---------------------------------------------------------------------------


@given(
    st.integers(0, 2**32 - 1),
    st.integers(1, 80),
    st.integers(1, 120),
    st.floats(0.0, 12.0),
    st.sampled_from([1, 3, 1000]),
    st.booleans(),
)
@settings(max_examples=25, deadline=None)
def test_bundle_decode_matches_csr(seed, nrows, ncols, avg, distinct, delta):
    rng = np.random.default_rng(seed)
    rc, rv = ref.random_matrix(rng, nrows, ncols, avg, distinct)
    b = ref.encode_matrix(rc, rv, ncols, delta_encode=delta)
    x = rng.standard_normal(ncols).astype(np.float32)
    got = ref.decode_spmv_ref(b, x)
    want = ref.spmv_csr_ref(rc, rv, x)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_bundle_padding_keeps_results():
    rng = np.random.default_rng(3)
    rc, rv = ref.random_matrix(rng, 50, 64, 4.0)
    b = ref.encode_matrix(rc, rv, 64)
    x = rng.standard_normal(64).astype(np.float32)
    y = ref.decode_spmv_ref(b, x)
    padded = b.pad_to(nrows=96, stream_words=4096, escapes=512)
    y2 = ref.decode_spmv_ref(padded, x)
    np.testing.assert_allclose(y2[:50], y, rtol=0, atol=0)
    assert (y2[50:] == 0).all()


def test_empty_matrix():
    b = ref.encode_matrix([], [], 8)
    y = ref.decode_spmv_ref(b, np.zeros(8, dtype=np.float32))
    assert y.shape == (0,)
