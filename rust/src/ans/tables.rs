//! Coding tables shared by tANS and dtANS (Fig. 3 of the paper): per-slot
//! `symbol`, `digit`, `base` plus the per-symbol inverse (`start`, `mult`)
//! used by the encoder.

use super::params::AnsParams;
use crate::util::error::{DtansError, Result};

/// Coding tables for one symbol domain.
///
/// Slot `j` holds symbol `slot_sym[j]`, digit `slot_digit[j]` and base
/// `slot_base[j]` (= the symbol's multiplicity). Equal symbols occupy
/// consecutive slots numbered `0..mult` (the paper notes slots may also be
/// permuted to spread shared-memory bank accesses; consecutive slots keep
/// the encoder's `slot = start + digit` lookup O(1) and the GPU-bank
/// concern is charged in the simulator instead).
///
/// `packed[j]` carries `sym << 16 | digit << 8 | (base-1)` in one u32 — the
/// decode hot path reads a single 4-byte entry per slot. Storing `base-1`
/// is the paper's §IV-F "storing decremented radixes" trick: with `M = 256`
/// the base would need 9 bits, the decrement fits 8.
#[derive(Debug, Clone, PartialEq)]
pub struct CodingTables {
    /// Table size K.
    pub k: u32,
    /// Slot -> symbol id.
    pub slot_sym: Vec<u16>,
    /// Slot -> digit (0..mult of the symbol).
    pub slot_digit: Vec<u8>,
    /// Slot -> base − 1 (base = symbol multiplicity ≤ M = 256).
    pub slot_base_m1: Vec<u8>,
    /// Packed hot-path entry: `sym << 16 | digit << 8 | base_m1`.
    pub packed: Vec<u32>,
    /// Symbol -> first slot.
    pub sym_start: Vec<u32>,
    /// Symbol -> multiplicity (= base).
    pub sym_mult: Vec<u32>,
}

impl CodingTables {
    /// Build tables from per-symbol multiplicities (must sum to K, each in
    /// `[1, M]`); symbol ids are the indices of `mult`.
    pub fn build(params: &AnsParams, mult: &[u32]) -> Result<CodingTables> {
        params.validate()?;
        let k = params.k();
        let m = params.m();
        let sum: u64 = mult.iter().map(|&q| q as u64).sum();
        if sum != k as u64 {
            return Err(DtansError::InvalidParams(format!(
                "multiplicities sum {sum} != K {k}"
            )));
        }
        if mult.len() > u16::MAX as usize + 1 {
            return Err(DtansError::InvalidParams("more than 2^16 symbols".into()));
        }
        if mult.iter().any(|&q| q == 0 || q > m) {
            return Err(DtansError::InvalidParams(format!(
                "multiplicity out of [1, M={m}]"
            )));
        }
        let mut slot_sym = Vec::with_capacity(k as usize);
        let mut slot_digit = Vec::with_capacity(k as usize);
        let mut slot_base_m1 = Vec::with_capacity(k as usize);
        let mut packed = Vec::with_capacity(k as usize);
        let mut sym_start = Vec::with_capacity(mult.len());
        let mut start = 0u32;
        for (sym, &q) in mult.iter().enumerate() {
            sym_start.push(start);
            for digit in 0..q {
                slot_sym.push(sym as u16);
                slot_digit.push(digit as u8);
                slot_base_m1.push((q - 1) as u8);
                packed.push(((sym as u32) << 16) | (digit << 8) | (q - 1));
            }
            start += q;
        }
        Ok(CodingTables {
            k,
            slot_sym,
            slot_digit,
            slot_base_m1,
            packed,
            sym_start,
            sym_mult: mult.to_vec(),
        })
    }

    /// Number of symbols.
    pub fn num_symbols(&self) -> usize {
        self.sym_mult.len()
    }

    /// Slot for (symbol, digit) — the encoder's lookup.
    #[inline]
    pub fn slot_of(&self, sym: u16, digit: u32) -> u32 {
        debug_assert!(digit < self.sym_mult[sym as usize]);
        self.sym_start[sym as usize] + digit
    }

    /// Base (multiplicity) of a symbol.
    #[inline]
    pub fn base_of(&self, sym: u16) -> u64 {
        self.sym_mult[sym as usize] as u64
    }

    /// Decode a slot into (symbol, digit, base) from the packed entry.
    #[inline]
    pub fn slot_decode(&self, slot: u32) -> (u16, u64, u64) {
        let p = self.packed[slot as usize];
        ((p >> 16) as u16, ((p >> 8) & 0xff) as u64, (p & 0xff) as u64 + 1)
    }

    /// Byte size of the slot table itself (4 bytes per slot as stored on
    /// the GPU: the packed entry). Dictionaries are accounted separately.
    pub fn table_bytes(&self) -> usize {
        self.packed.len() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_tables() -> CodingTables {
        // The paper's Fig. 3 example: P' = (a:1, b:4, c:3) over K=8.
        let p = AnsParams::TOY;
        CodingTables::build(&p, &[1, 4, 3]).unwrap()
    }

    #[test]
    fn fig3_layout() {
        let t = toy_tables();
        assert_eq!(t.k, 8);
        assert_eq!(t.slot_sym, vec![0, 1, 1, 1, 1, 2, 2, 2]);
        assert_eq!(t.slot_digit, vec![0, 0, 1, 2, 3, 0, 1, 2]);
        // base per slot = multiplicity of its symbol
        assert_eq!(
            t.slot_base_m1.iter().map(|&b| b as u32 + 1).collect::<Vec<_>>(),
            vec![1, 4, 4, 4, 4, 3, 3, 3]
        );
    }

    #[test]
    fn packed_consistent() {
        let t = toy_tables();
        for j in 0..t.k {
            let (s, d, b) = t.slot_decode(j);
            assert_eq!(s, t.slot_sym[j as usize]);
            assert_eq!(d, t.slot_digit[j as usize] as u64);
            assert_eq!(b, t.slot_base_m1[j as usize] as u64 + 1);
            assert_eq!(t.slot_of(s, d as u32), j);
        }
    }

    #[test]
    fn rejects_bad_sum() {
        let p = AnsParams::TOY;
        assert!(CodingTables::build(&p, &[1, 4, 4]).is_err());
    }

    #[test]
    fn rejects_over_cap() {
        // TOY has M = 2: multiplicity 4 exceeds it only in validate-by-M
        // configs; use KERNEL (M=256) with an oversized entry.
        let p = AnsParams::KERNEL;
        let mut mult = vec![1u32; 3798];
        mult[0] = 299; // sums to 4096 but 299 > M=256 -> rejected
        assert_eq!(mult.iter().sum::<u32>(), 4096);
        assert!(CodingTables::build(&p, &mult).is_err());
    }
}
