//! Span identities and typed stage events for request-flow tracing.
//!
//! Every request admitted into the serving pipeline gets a [`SpanId`] at
//! submit time and stamps a chain of [`Stage`] events as it moves through
//! the stages:
//!
//! ```text
//! Submitted ─► Queued(wait) ─► Dispatched ─► Pinned ─► Kernel ─► Completed
//!     │                            │            │                 Failed
//!     └► Shed                      └► Expired   └► Coalesced ─►┘
//! ```
//!
//! plus standalone [`Stage::ColdLoad`] spans stamped by the store when an
//! evicted matrix faults back in, standalone [`Stage::Compaction`]
//! spans when a background job absorbs a delta overlay into a fresh
//! artifact ([`crate::store::MatrixStore::compact`]), and standalone
//! [`Stage::Routed`] spans when adaptive routing commits a route flip
//! (`docs/ROUTING.md`). Exactly one
//! **terminal** event
//! ([`Stage::is_terminal`]) closes every chain — the invariant the
//! span-conservation oracle (testkit stress oracle 4,
//! `docs/TESTING.md`) checks against the metrics identity
//! `completed + failed + shed + expired == submitted`.
//!
//! Events are collected by [`crate::obs::trace::Tracer`]; the types here
//! are plain data so tests and exporters can pattern-match without
//! touching the collector.

/// Identity of one request's span chain.
///
/// `SpanId::NONE` (id 0) marks an unsampled request: every
/// [`Tracer::record`](crate::obs::trace::Tracer::record) against it is a
/// no-op, so instrumentation sites stamp unconditionally and sampling is
/// decided once, at [`Tracer::begin`](crate::obs::trace::Tracer::begin).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct SpanId(pub u64);

impl SpanId {
    /// The unsampled sentinel: records against it are dropped.
    pub const NONE: SpanId = SpanId(0);

    /// Is this span actually being recorded?
    pub fn is_sampled(&self) -> bool {
        self.0 != 0
    }
}

/// One typed stage event in a request's span chain.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Stage {
    /// Request accepted by `submit` (counted in `submitted`). Stamped
    /// before the admission-queue push, so shed requests carry it too.
    Submitted {
        /// Store id of the target matrix.
        matrix: u64,
    },
    /// Request left the admission queue; `wait_us` is the measured queue
    /// wait (enqueue → dequeue) — the number that was invisible before
    /// this subsystem existed.
    Queued {
        /// Microseconds spent queued.
        wait_us: u64,
    },
    /// Dispatcher handed the request to a pool worker.
    Dispatched,
    /// The target matrix was pinned resident (store acquire succeeded).
    Pinned,
    /// Store cold load: an evicted matrix faulted back in from its
    /// artifact. Standalone span (own trace id), stamped by the store.
    ColdLoad {
        /// Store id of the loaded matrix.
        matrix: u64,
        /// Microseconds the fault-in took.
        dur_us: u64,
    },
    /// Background overlay compaction completed: base+delta re-encoded and
    /// swapped in ([`crate::store::MatrixStore::compact`]). Standalone
    /// span (own trace id, terminal-free — like [`Stage::ColdLoad`]),
    /// stamped by the store's metrics sink.
    Compaction {
        /// Store id of the compacted matrix.
        matrix: u64,
        /// Microseconds the merge + encode + persist + swap took.
        dur_us: u64,
        /// Overlay entries absorbed into the new base.
        nnz_absorbed: u64,
    },
    /// Adaptive routing committed a route flip for a matrix: the
    /// hysteresis-confirmed challenger replaced the incumbent
    /// ([`crate::coordinator::adaptive::AdaptiveRouter`],
    /// `docs/ROUTING.md`). Standalone span (own trace id, terminal-free
    /// and non-terminal — like [`Stage::ColdLoad`]), stamped by the
    /// metrics sink at flip time, not on a request chain.
    Routed {
        /// Store id of the re-routed matrix.
        matrix: u64,
        /// Format tag the matrix was served from before the flip.
        from: &'static str,
        /// Format tag it is served from now.
        to: &'static str,
        /// Why the route flipped (`"hysteresis"` for learned flips).
        reason: &'static str,
    },
    /// Request served through a coalesced same-matrix SpMM batch; all
    /// members share `batch`.
    Coalesced {
        /// Shared batch span id.
        batch: u64,
        /// Requests in the batch.
        size: u32,
    },
    /// Kernel execution (the engine call itself).
    Kernel {
        /// Executing operator's format tag (`"csr"`, `"csr_dtans"`, …).
        format: &'static str,
        /// Partition blocks the engine ran (1 = serial).
        blocks: u32,
        /// Fastest block, microseconds (0 when per-block timing is off).
        min_us: u64,
        /// Slowest block, microseconds.
        max_us: u64,
        /// Mean block, microseconds.
        mean_us: u64,
        /// Whole-call duration, microseconds.
        dur_us: u64,
    },
    /// Terminal: request completed; `total_us` is end-to-end latency.
    Completed {
        /// Submit → response, microseconds.
        total_us: u64,
    },
    /// Terminal: request failed (store or kernel error).
    Failed,
    /// Terminal: shed at admission (queue full, quota, or closed).
    Shed,
    /// Terminal: deadline elapsed before execution.
    Expired,
}

impl Stage {
    /// Does this event close a span chain? Exactly one terminal event per
    /// admitted request is the span-conservation invariant.
    pub fn is_terminal(&self) -> bool {
        matches!(
            self,
            Stage::Completed { .. } | Stage::Failed | Stage::Shed | Stage::Expired
        )
    }

    /// Stable lowercase name, used for Chrome-trace event names and
    /// grouping in tests.
    pub fn name(&self) -> &'static str {
        match self {
            Stage::Submitted { .. } => "submitted",
            Stage::Queued { .. } => "queued",
            Stage::Dispatched => "dispatched",
            Stage::Pinned => "pinned",
            Stage::ColdLoad { .. } => "cold_load",
            Stage::Compaction { .. } => "compaction",
            Stage::Routed { .. } => "routed",
            Stage::Coalesced { .. } => "coalesced",
            Stage::Kernel { .. } => "kernel",
            Stage::Completed { .. } => "completed",
            Stage::Failed => "failed",
            Stage::Shed => "shed",
            Stage::Expired => "expired",
        }
    }

    /// Duration carried by the event, if it represents a timed interval
    /// (rendered as a Chrome-trace complete event; instants otherwise).
    pub fn duration_us(&self) -> Option<u64> {
        match self {
            Stage::Queued { wait_us } => Some(*wait_us),
            Stage::ColdLoad { dur_us, .. } => Some(*dur_us),
            Stage::Compaction { dur_us, .. } => Some(*dur_us),
            Stage::Kernel { dur_us, .. } => Some(*dur_us),
            Stage::Completed { total_us } => Some(*total_us),
            _ => None,
        }
    }
}

/// One collected event: a [`Stage`] plus when and where it happened.
#[derive(Debug, Clone, Copy)]
pub struct SpanEvent {
    /// Span chain this event belongs to.
    pub span: SpanId,
    /// Microseconds since the tracer's epoch.
    pub ts_us: u64,
    /// Track of the recording thread (one per dispatcher / pool worker /
    /// client thread; see [`crate::obs::trace::Tracer`]).
    pub track: u32,
    /// The typed stage payload.
    pub stage: Stage,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exactly_four_terminal_stages() {
        let all = [
            Stage::Submitted { matrix: 1 },
            Stage::Queued { wait_us: 5 },
            Stage::Dispatched,
            Stage::Pinned,
            Stage::ColdLoad { matrix: 1, dur_us: 9 },
            Stage::Compaction { matrix: 1, dur_us: 9, nnz_absorbed: 3 },
            Stage::Routed { matrix: 1, from: "csr_dtans", to: "csr", reason: "hysteresis" },
            Stage::Coalesced { batch: 2, size: 4 },
            Stage::Kernel {
                format: "csr",
                blocks: 4,
                min_us: 1,
                max_us: 3,
                mean_us: 2,
                dur_us: 4,
            },
            Stage::Completed { total_us: 100 },
            Stage::Failed,
            Stage::Shed,
            Stage::Expired,
        ];
        assert_eq!(all.iter().filter(|s| s.is_terminal()).count(), 4);
        // Names are distinct (they key test assertions and trace output).
        let mut names: Vec<_> = all.iter().map(|s| s.name()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), all.len());
    }

    #[test]
    fn none_span_is_unsampled() {
        assert!(!SpanId::NONE.is_sampled());
        assert!(SpanId(1).is_sampled());
    }
}
