//! Service metrics: request counters, store counters, solver counters,
//! and latency quantiles over fixed-size sliding-window reservoirs —
//! aggregate and broken out per kernel format
//! ([`SpmvOperator::format_tag`](crate::spmv::operator::SpmvOperator::format_tag)),
//! so dtANS vs CSR routing is observable in production.
//!
//! A whole iterative solve ([`crate::coordinator::service::SpmvService::solve`])
//! is **one** request-level sample: [`Metrics::record_solve`] pushes a
//! single end-to-end latency into the aggregate and per-format rings, and
//! its iteration count into a separate iterations reservoir. Recording
//! each of a solve's N inner multiplies as its own latency sample would
//! flood the format rings with N correlated sub-millisecond entries and
//! drag p99 toward the solver's inner-loop time — the skew called out in
//! the per-format breakdown work.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Samples retained per reservoir.
const RESERVOIR_CAP: usize = 65536;

/// Fixed-size ring of the most recent [`RESERVOIR_CAP`] samples. Unlike
/// the old grow-then-drain reservoir (which discarded the oldest 32k
/// samples *wholesale* at 64k, so quantiles right after a drain were
/// computed over a recent-burst-only window), the ring retires exactly
/// one oldest sample per new sample — the window slides, it never jumps.
#[derive(Debug, Default)]
struct Ring {
    buf: Vec<u64>,
    /// Oldest slot, once the ring is full.
    next: usize,
}

impl Ring {
    fn push(&mut self, v: u64) {
        if self.buf.len() < RESERVOIR_CAP {
            self.buf.push(v);
        } else {
            self.buf[self.next] = v;
            self.next = (self.next + 1) % RESERVOIR_CAP;
        }
    }
}

/// Lock-free counters + mutexed latency reservoirs.
#[derive(Debug, Default)]
pub struct Metrics {
    /// Requests accepted.
    pub submitted: AtomicU64,
    /// Requests completed successfully.
    pub completed: AtomicU64,
    /// Requests failed.
    pub failed: AtomicU64,
    /// Requests shed at admission (queue full, tenant quota, or closed
    /// queue) — they were `submitted` but never queued, so the
    /// conservation identity is
    /// `completed + failed + shed + expired == submitted`.
    pub shed: AtomicU64,
    /// Subset of `shed`: rejections from a per-tenant token-bucket
    /// quota.
    pub quota_rejected: AtomicU64,
    /// Requests whose deadline elapsed before execution; rejected at
    /// dispatch with `DeadlineExceeded`, never run.
    pub expired: AtomicU64,
    /// Batches executed.
    pub batches: AtomicU64,
    /// Multi-request same-matrix batches that took the coalesced SpMM
    /// fast path (one `run_multi` engine call for the whole batch).
    pub coalesced_batches: AtomicU64,
    /// Requests served through those coalesced batches
    /// (`coalesced_requests / coalesced_batches` = mean amortization
    /// factor).
    pub coalesced_requests: AtomicU64,
    /// Gauge: admission-queue depth after the most recent submit or
    /// dispatch.
    pub queue_depth: AtomicU64,
    /// High-water mark of the admission queue over the service's life.
    pub queue_depth_peak: AtomicU64,
    /// Registrations served from the on-disk artifact cache (encode
    /// skipped).
    pub store_hits: AtomicU64,
    /// Registrations that had to encode.
    pub store_misses: AtomicU64,
    /// Matrices evicted from residency by the byte budget.
    pub evictions: AtomicU64,
    /// Background artifact persists that failed (the matrix stays
    /// resident and unevictable — the budget cannot be enforced for it).
    pub persist_failures: AtomicU64,
    /// Cold loads (evicted matrices faulted back in from disk).
    pub cold_loads: AtomicU64,
    /// Successful store pin acquisitions
    /// ([`crate::store::MatrixStore::acquire`]) — a solve must cost
    /// exactly one of these no matter how many iterations it runs.
    pub acquires: AtomicU64,
    /// Iterative solve attempts through the service (converged, diverged
    /// **or** errored before iterating — so `solves` may exceed
    /// `solves_converged + solves_diverged` when requests fail on
    /// preconditions like a wrong-length right-hand side).
    pub solves: AtomicU64,
    /// Solves that reached their tolerance.
    pub solves_converged: AtomicU64,
    /// Solves that ran but stopped without converging (iteration cap or
    /// breakdown). Precondition/request errors count as `failed`, not
    /// here — divergence is a numerical signal, not an input bug.
    pub solves_diverged: AtomicU64,
    latencies_us: Mutex<Ring>,
    cold_load_us: Mutex<Ring>,
    solve_iters: Mutex<Ring>,
    /// Per-format breakdown, keyed by the executing operator's
    /// `format_tag()` (`BTreeMap` so reports list formats in a stable
    /// order).
    per_format: Mutex<BTreeMap<&'static str, FormatStats>>,
}

/// Per-format counters + latency reservoir.
#[derive(Debug, Default)]
struct FormatStats {
    completed: u64,
    failed: u64,
    ring: Ring,
}

/// Snapshot of one format's request counters and latency quantiles (see
/// [`Metrics::format_summary`]).
#[derive(Debug, Clone, Copy, Default)]
pub struct FormatSummary {
    /// Requests completed successfully on this format's kernel.
    pub completed: u64,
    /// Requests that failed while executing on this format's kernel.
    pub failed: u64,
    /// Latency quantiles over this format's sliding window.
    pub latency: LatencySummary,
}

/// Quantile summary of a latency reservoir.
#[derive(Debug, Clone, Copy, Default)]
pub struct LatencySummary {
    /// Number of samples.
    pub count: usize,
    /// 50th percentile, microseconds.
    pub p50_us: u64,
    /// 99th percentile, microseconds.
    pub p99_us: u64,
    /// Maximum, microseconds.
    pub max_us: u64,
}

impl LatencySummary {
    /// Summarize raw samples (sorts in place).
    fn from_samples(mut l: Vec<u64>) -> LatencySummary {
        if l.is_empty() {
            return LatencySummary::default();
        }
        l.sort_unstable();
        let q = |p: f64| l[((l.len() - 1) as f64 * p) as usize];
        LatencySummary {
            count: l.len(),
            p50_us: q(0.50),
            p99_us: q(0.99),
            max_us: *l.last().unwrap(),
        }
    }
}

/// Snapshot of the solver section (see [`Metrics::solver_summary`]).
#[derive(Debug, Clone, Copy, Default)]
pub struct SolverSummary {
    /// Solves executed.
    pub solves: u64,
    /// Solves that converged.
    pub converged: u64,
    /// Solves that ran but did not converge (iteration cap or breakdown);
    /// errored solve requests appear in `solves` and the `failed`
    /// counter instead.
    pub diverged: u64,
    /// Iteration-count quantiles over the sliding window (`count` solves;
    /// `p50`/`p99`/`max` are iterations, not microseconds).
    pub iters_count: usize,
    /// Median iterations per solve.
    pub iters_p50: u64,
    /// 99th-percentile iterations per solve.
    pub iters_p99: u64,
    /// Maximum iterations per solve in the window.
    pub iters_max: u64,
}

impl Metrics {
    /// Record one request shed at admission. `quota` marks a per-tenant
    /// quota rejection (counted in both `shed` and `quota_rejected`).
    pub fn record_shed(&self, quota: bool) {
        self.shed.fetch_add(1, Ordering::Relaxed);
        if quota {
            self.quota_rejected.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Record one request rejected at dispatch for an elapsed deadline.
    pub fn record_expired(&self) {
        self.expired.fetch_add(1, Ordering::Relaxed);
    }

    /// Update the queue-depth gauge and its high-water mark.
    pub fn note_queue_depth(&self, depth: u64) {
        self.queue_depth.store(depth, Ordering::Relaxed);
        self.queue_depth_peak.fetch_max(depth, Ordering::Relaxed);
    }

    /// Record one completed request's latency.
    pub fn record_latency(&self, micros: u64) {
        self.completed.fetch_add(1, Ordering::Relaxed);
        self.latencies_us.lock().unwrap().push(micros);
    }

    /// Record one completed request's latency against both the aggregate
    /// window and the executing format's own window.
    pub fn record_format_latency(&self, tag: &'static str, micros: u64) {
        self.record_latency(micros);
        let mut per = self.per_format.lock().unwrap();
        let stats = per.entry(tag).or_default();
        stats.completed += 1;
        stats.ring.push(micros);
    }

    /// Record one failed request against both the aggregate `failed`
    /// counter and the executing format's own counter.
    pub fn record_format_failure(&self, tag: &'static str) {
        self.failed.fetch_add(1, Ordering::Relaxed);
        self.per_format.lock().unwrap().entry(tag).or_default().failed += 1;
    }

    /// Snapshot one format's counters and latency quantiles; `None` if no
    /// request has executed on that format.
    pub fn format_summary(&self, tag: &str) -> Option<FormatSummary> {
        let per = self.per_format.lock().unwrap();
        per.get(tag).map(|s| FormatSummary {
            completed: s.completed,
            failed: s.failed,
            latency: LatencySummary::from_samples(s.ring.buf.clone()),
        })
    }

    /// Tags that have recorded at least one request, in stable order.
    pub fn format_tags(&self) -> Vec<&'static str> {
        self.per_format.lock().unwrap().keys().copied().collect()
    }

    /// Record one whole iterative solve: its iteration count, outcome,
    /// and end-to-end latency. The solve is **one** submitted request and
    /// **one** latency sample in the aggregate and per-format rings —
    /// never one per iteration (see the module docs for the p99-skew
    /// rationale).
    pub fn record_solve(&self, tag: &'static str, iterations: u64, converged: bool, micros: u64) {
        self.submitted.fetch_add(1, Ordering::Relaxed);
        self.solves.fetch_add(1, Ordering::Relaxed);
        if converged {
            self.solves_converged.fetch_add(1, Ordering::Relaxed);
        } else {
            self.solves_diverged.fetch_add(1, Ordering::Relaxed);
        }
        self.solve_iters.lock().unwrap().push(iterations);
        self.record_format_latency(tag, micros);
    }

    /// Record one errored solve (the request never produced an iterate —
    /// e.g. a dimension mismatch). Counted as a failed request and a
    /// solve attempt, but **not** as `solves_diverged`: that counter is
    /// reserved for solves that ran and did not converge.
    pub fn record_solve_failure(&self, tag: &'static str) {
        self.submitted.fetch_add(1, Ordering::Relaxed);
        self.solves.fetch_add(1, Ordering::Relaxed);
        self.record_format_failure(tag);
    }

    /// Snapshot the solver section: solve counts by outcome and
    /// iteration-count quantiles.
    pub fn solver_summary(&self) -> SolverSummary {
        let iters = LatencySummary::from_samples(self.solve_iters.lock().unwrap().buf.clone());
        SolverSummary {
            solves: self.solves.load(Ordering::Relaxed),
            converged: self.solves_converged.load(Ordering::Relaxed),
            diverged: self.solves_diverged.load(Ordering::Relaxed),
            iters_count: iters.count,
            iters_p50: iters.p50_us,
            iters_p99: iters.p99_us,
            iters_max: iters.max_us,
        }
    }

    /// Record one cold load (store fault-in) latency.
    pub fn record_cold_load(&self, micros: u64) {
        self.cold_loads.fetch_add(1, Ordering::Relaxed);
        self.cold_load_us.lock().unwrap().push(micros);
    }

    /// Quantile summary over the request-latency window.
    pub fn latency_summary(&self) -> LatencySummary {
        LatencySummary::from_samples(self.latencies_us.lock().unwrap().buf.clone())
    }

    /// Quantile summary over the cold-load-latency window.
    pub fn cold_load_summary(&self) -> LatencySummary {
        LatencySummary::from_samples(self.cold_load_us.lock().unwrap().buf.clone())
    }

    /// One-line human-readable report: the aggregate counters and
    /// quantiles, then a `solver:` section once any solve has run,
    /// followed by one `fmt[tag]` section per format that has served
    /// requests.
    pub fn report(&self) -> String {
        let s = self.latency_summary();
        let c = self.cold_load_summary();
        let mut out = format!(
            "submitted={} completed={} failed={} shed={} expired={} batches={} \
             coalesced_batches={} coalesced_requests={} queue_depth={} queue_peak={} \
             p50={}µs p99={}µs max={}µs \
             store_hits={} store_misses={} evictions={} persist_failures={} cold_loads={} \
             acquires={} cold_p50={}µs cold_p99={}µs",
            self.submitted.load(Ordering::Relaxed),
            self.completed.load(Ordering::Relaxed),
            self.failed.load(Ordering::Relaxed),
            self.shed.load(Ordering::Relaxed),
            self.expired.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.coalesced_batches.load(Ordering::Relaxed),
            self.coalesced_requests.load(Ordering::Relaxed),
            self.queue_depth.load(Ordering::Relaxed),
            self.queue_depth_peak.load(Ordering::Relaxed),
            s.p50_us,
            s.p99_us,
            s.max_us,
            self.store_hits.load(Ordering::Relaxed),
            self.store_misses.load(Ordering::Relaxed),
            self.evictions.load(Ordering::Relaxed),
            self.persist_failures.load(Ordering::Relaxed),
            self.cold_loads.load(Ordering::Relaxed),
            self.acquires.load(Ordering::Relaxed),
            c.p50_us,
            c.p99_us,
        );
        let sv = self.solver_summary();
        if sv.solves > 0 {
            out.push_str(&format!(
                " | solver: solves={} converged={} diverged={} iters_p50={} iters_p99={}",
                sv.solves, sv.converged, sv.diverged, sv.iters_p50, sv.iters_p99
            ));
        }
        let per = self.per_format.lock().unwrap();
        for (tag, stats) in per.iter() {
            let f = LatencySummary::from_samples(stats.ring.buf.clone());
            out.push_str(&format!(
                " | fmt[{tag}]: ok={} fail={} p50={}µs p99={}µs",
                stats.completed, stats.failed, f.p50_us, f.p99_us
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantiles() {
        let m = Metrics::default();
        for i in 1..=100 {
            m.record_latency(i);
        }
        let s = m.latency_summary();
        assert_eq!(s.count, 100);
        assert!((49..=51).contains(&s.p50_us));
        assert!(s.p99_us >= 98);
        assert_eq!(s.max_us, 100);
    }

    #[test]
    fn empty_summary() {
        let m = Metrics::default();
        assert_eq!(m.latency_summary().count, 0);
        assert!(m.report().contains("submitted=0"));
    }

    #[test]
    fn ring_slides_one_sample_at_a_time() {
        let m = Metrics::default();
        let n = RESERVOIR_CAP + 1000;
        for i in 0..n {
            m.record_latency(i as u64);
        }
        let s = m.latency_summary();
        assert_eq!(s.count, RESERVOIR_CAP);
        // Window is exactly the most recent CAP samples: [1000, n).
        assert_eq!(s.max_us, (n - 1) as u64);
        assert!(s.p50_us >= 1000);
        // The median sits mid-window — the old drain-half behavior would
        // have put it deep in the recent half right after a drain.
        let mid = 1000 + RESERVOIR_CAP as u64 / 2;
        assert!(
            (s.p50_us as i64 - mid as i64).abs() <= 1,
            "p50 {} not centered on {mid}",
            s.p50_us
        );
        assert_eq!(m.completed.load(Ordering::Relaxed), n as u64);
    }

    #[test]
    fn per_format_breakdown_is_independent_and_reported() {
        let m = Metrics::default();
        for i in 1..=50 {
            m.record_format_latency("csr", i);
        }
        for i in 100..=120 {
            m.record_format_latency("csr_dtans", i);
        }
        m.record_format_failure("csr_dtans");
        // Aggregate sees everything.
        assert_eq!(m.completed.load(Ordering::Relaxed), 71);
        assert_eq!(m.failed.load(Ordering::Relaxed), 1);
        assert_eq!(m.latency_summary().count, 71);
        // Per-format windows are disjoint.
        let csr = m.format_summary("csr").unwrap();
        assert_eq!((csr.completed, csr.failed), (50, 0));
        assert_eq!(csr.latency.count, 50);
        assert!(csr.latency.max_us <= 50);
        let dt = m.format_summary("csr_dtans").unwrap();
        assert_eq!((dt.completed, dt.failed), (21, 1));
        assert!(dt.latency.p50_us >= 100);
        assert!(m.format_summary("sell").is_none());
        assert_eq!(m.format_tags(), vec!["csr", "csr_dtans"]);
        let report = m.report();
        assert!(report.contains("fmt[csr]: ok=50 fail=0"), "{report}");
        assert!(report.contains("fmt[csr_dtans]: ok=21 fail=1"), "{report}");
    }

    #[test]
    fn solve_is_one_latency_sample_not_n() {
        let m = Metrics::default();
        // A 500-iteration solve on csr, one diverged solve on csr_dtans,
        // one errored solve (counts as failed + a solve attempt, NOT as
        // diverged — divergence is numerical, an error is an input bug).
        m.record_solve("csr", 500, true, 12_000);
        m.record_solve("csr_dtans", 42, false, 3_000);
        m.record_solve_failure("csr_dtans");
        let s = m.solver_summary();
        assert_eq!((s.solves, s.converged, s.diverged), (3, 1, 1));
        assert_eq!(s.iters_count, 2);
        assert_eq!(s.iters_max, 500);
        // The iteration counts must NOT have flooded the latency rings:
        // one completed sample per successful solve, exactly.
        assert_eq!(m.latency_summary().count, 2);
        assert_eq!(m.completed.load(Ordering::Relaxed), 2);
        assert_eq!(m.failed.load(Ordering::Relaxed), 1);
        assert_eq!(m.submitted.load(Ordering::Relaxed), 3);
        let csr = m.format_summary("csr").unwrap();
        assert_eq!((csr.completed, csr.latency.count), (1, 1));
        assert_eq!(csr.latency.max_us, 12_000);
        let report = m.report();
        assert!(report.contains("solver: solves=3 converged=1 diverged=1"), "{report}");
    }

    #[test]
    fn admission_counters_report_and_conserve() {
        let m = Metrics::default();
        // 7 submitted: 4 completed, 1 shed on depth, 1 shed on quota,
        // 1 expired at dispatch.
        for _ in 0..7 {
            m.submitted.fetch_add(1, Ordering::Relaxed);
        }
        for i in 0..4 {
            m.record_latency(10 + i);
        }
        m.record_shed(false);
        m.record_shed(true);
        m.record_expired();
        m.note_queue_depth(5);
        m.note_queue_depth(2);
        let (submitted, completed, failed, shed, expired) = (
            m.submitted.load(Ordering::Relaxed),
            m.completed.load(Ordering::Relaxed),
            m.failed.load(Ordering::Relaxed),
            m.shed.load(Ordering::Relaxed),
            m.expired.load(Ordering::Relaxed),
        );
        assert_eq!(completed + failed + shed + expired, submitted);
        assert_eq!(m.quota_rejected.load(Ordering::Relaxed), 1);
        // Gauge holds the latest value; the peak holds the maximum.
        assert_eq!(m.queue_depth.load(Ordering::Relaxed), 2);
        assert_eq!(m.queue_depth_peak.load(Ordering::Relaxed), 5);
        let report = m.report();
        assert!(report.contains("shed=2 expired=1"), "{report}");
        assert!(report.contains("queue_depth=2 queue_peak=5"), "{report}");
    }

    #[test]
    fn solver_section_absent_until_first_solve() {
        let m = Metrics::default();
        m.record_latency(5);
        assert!(!m.report().contains("solver:"));
        assert_eq!(m.solver_summary().solves, 0);
    }

    #[test]
    fn cold_load_reservoir_is_independent() {
        let m = Metrics::default();
        m.record_latency(10);
        m.record_cold_load(5000);
        m.record_cold_load(7000);
        assert_eq!(m.latency_summary().count, 1);
        let c = m.cold_load_summary();
        assert_eq!(c.count, 2);
        assert_eq!(c.max_us, 7000);
        assert_eq!(m.cold_loads.load(Ordering::Relaxed), 2);
        assert_eq!(m.completed.load(Ordering::Relaxed), 1);
        assert!(m.report().contains("cold_loads=2"));
    }
}
