//! Saving experiment outputs: CSV per table under `results/`, summaries
//! appended to stdout and returned for EXPERIMENTS.md.

use super::experiments::ExperimentOutput;
use crate::util::error::Result;
use std::path::Path;

/// Save all tables of an experiment under `dir` and return the summary.
pub fn save(out: &ExperimentOutput, dir: &Path) -> Result<String> {
    for (stem, table) in &out.tables {
        let path = dir.join(format!("{stem}.csv"));
        table.save_csv(&path)?;
    }
    Ok(out.summary.clone())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::csv::Table;

    #[test]
    fn saves_tables() {
        let mut t = Table::new(&["a"]);
        t.push(vec!["1".into()]);
        let out = ExperimentOutput {
            tables: vec![("unit_test_table".into(), t)],
            summary: "ok".into(),
        };
        let dir = std::env::temp_dir().join("dtans_report_test");
        let s = save(&out, &dir).unwrap();
        assert_eq!(s, "ok");
        assert!(dir.join("unit_test_table.csv").exists());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
