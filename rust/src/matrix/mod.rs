//! Sparse matrix substrates: storage formats (COO/CSR/SELL), conversions,
//! MatrixMarket IO, generators, and entropy/structure statistics.
//!
//! These are the formats the paper compares against (cuSPARSE's CSR, COO and
//! SELL) plus everything needed to build the evaluation corpus. Values are
//! held as `f64` in memory; the 64-/32-bit distinction of the paper enters
//! through size accounting ([`SizeModel`]) and through the value
//! symbolization in [`crate::format`].

pub mod blocked_ell;
pub mod coo;
pub mod csr;
pub mod gen;
pub mod mtx;
pub mod sell;
pub mod stats;

pub use blocked_ell::BlockedEll;
pub use coo::Coo;
pub use csr::Csr;
pub use sell::Sell;

/// Precision used for *size accounting* and symbolization (the paper's
/// 64-bit vs 32-bit settings).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Precision {
    /// 8-byte values (scientific computing gold standard).
    F64,
    /// 4-byte values (ML-style reduced footprint).
    F32,
}

impl Precision {
    /// Bytes per stored value.
    pub fn value_bytes(self) -> usize {
        match self {
            Precision::F64 => 8,
            Precision::F32 => 4,
        }
    }

    /// Short label used in reports.
    pub fn label(self) -> &'static str {
        match self {
            Precision::F64 => "64-bit",
            Precision::F32 => "32-bit",
        }
    }
}

/// Byte-size model for the classic formats with 32-bit indices, matching
/// the paper's accounting (cuSPARSE with 32-bit indices).
#[derive(Debug, Clone, Copy)]
pub struct SizeModel {
    /// Value precision.
    pub precision: Precision,
}

impl SizeModel {
    /// CSR bytes: one u32 column index + value per nonzero, one u32 row
    /// offset per row + 1.
    pub fn csr_bytes(&self, nrows: usize, nnz: usize) -> usize {
        nnz * (4 + self.precision.value_bytes()) + (nrows + 1) * 4
    }

    /// COO bytes: two u32 indices + value per nonzero (empty rows are free).
    pub fn coo_bytes(&self, nnz: usize) -> usize {
        nnz * (8 + self.precision.value_bytes())
    }

    /// SELL bytes from actual padded layout: per slice, `width × height`
    /// padded (index + value) cells plus one u32 slice offset.
    pub fn sell_bytes(&self, sell: &Sell) -> usize {
        let padded: usize = sell
            .slice_widths
            .iter()
            .map(|&w| w as usize * sell.slice_height)
            .sum();
        padded * (4 + self.precision.value_bytes()) + sell.slice_widths.len() * 4
    }

    /// The paper's baseline: smallest of CSR, COO, SELL.
    pub fn best_baseline_bytes(&self, csr: &Csr) -> (usize, &'static str) {
        let sell = Sell::from_csr(csr, 32);
        let c = self.csr_bytes(csr.nrows, csr.nnz());
        let o = self.coo_bytes(csr.nnz());
        let s = self.sell_bytes(&sell);
        let mut best = (c, "CSR");
        if o < best.0 {
            best = (o, "COO");
        }
        if s < best.0 {
            best = (s, "SELL");
        }
        best
    }
}
