"""Layer-1 Pallas kernel: fused dtANS decode + SpMVM for CSR-dtANS.

One grid program per slice of 32 rows. The 32 CUDA lanes of the paper's
warp become a (32,)-shaped vector axis:

* ``__ballot_sync`` + ``popc`` lane ranking  -> ``jnp.cumsum`` over lanes;
* shared-memory coding tables               -> VMEM-resident (K,) arrays;
* coalesced 4-byte stream loads             -> per-event gathers of <= 32
  consecutive words (one lane each);
* ``__umul_hi`` double-word state           -> int64 arithmetic, which the
  KERNEL preset (W=2^16) keeps below 2^34.

The kernel MUST be lowered with ``interpret=True``: real TPU lowering emits
a Mosaic custom-call the CPU PJRT plugin cannot execute. Numerics are
validated against ``ref.decode_spmv_ref`` by pytest; the AOT path exports
the surrounding jitted function as HLO text for the Rust runtime.

Hardware note (DESIGN.md §Hardware-Adaptation): tables + dictionaries are
~112 KB and the per-slice lane state is a few KB — comfortably inside VMEM.
The full stream is read via dynamic gathers here (interpret mode); a Mosaic
production build would double-buffer stream tiles HBM->VMEM instead.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

WARP = ref.WARP
W_BITS = ref.W_BITS
K_BITS = ref.K_BITS
L_SYMS = ref.L_SYMS
O_WORDS = ref.O_WORDS
F_CHECKS = ref.F_CHECKS
GROUP = ref.GROUP
W = ref.W
K = ref.K
NPS = L_SYMS // 2  # nonzeros per segment


def _slice_kernel(
    dtab_ref,
    vtab_ref,
    d_payload_ref,
    d_isesc_ref,
    v_value_ref,
    v_isesc_ref,
    stream_ref,
    so_ref,
    nnz_ref,
    deo_ref,
    veo_ref,
    d_escapes_ref,
    v_escapes_ref,
    x_ref,
    y_ref,
    *,
    max_seg: int,
    delta_encode: bool,
):
    sid = pl.program_id(0)
    i64 = jnp.int64

    dtab = dtab_ref[...].astype(i64)
    vtab = vtab_ref[...].astype(i64)
    d_payload = d_payload_ref[...].astype(i64)
    d_isesc = d_isesc_ref[...].astype(i64)
    v_value = v_value_ref[...]
    v_isesc = v_isesc_ref[...].astype(i64)
    stream = stream_ref[...].astype(i64)
    d_escapes = d_escapes_ref[...].astype(i64)
    v_escapes = v_escapes_ref[...]
    x = x_ref[...]

    # Slice-local row metadata (blocked to (WARP,) by the BlockSpecs).
    nnz = nnz_ref[...].astype(i64)
    esc_d0 = deo_ref[...].astype(i64)
    esc_v0 = veo_ref[...].astype(i64)
    so_pair = pl.load(so_ref, (pl.dslice(sid, 2),)).astype(i64)
    base = so_pair[0]

    nseg = (nnz + (NPS - 1)) // NPS

    def gather_words(pos, mask):
        """One coalesced load event: active lanes read consecutive words."""
        ranks = jnp.cumsum(mask) - mask  # exclusive prefix sum (popc analog)
        idx = base + pos + ranks
        words = jnp.take(stream, idx, mode="clip")
        return jnp.where(mask.astype(bool), words, 0), pos + jnp.sum(mask)

    # Initial o words for non-empty lanes.
    pos = i64(0)
    w = jnp.zeros((WARP, O_WORDS), dtype=i64)
    nonempty = (nseg > 0).astype(i64)
    for k in range(O_WORDS):
        wk, pos = gather_words(pos, nonempty)
        w = w.at[:, k].set(wk)

    def body(t, carry):
        pos, w, d, r, emitted, col, acc, esc_d, esc_v = carry
        active = t < nseg
        producing = (t + 1) < nseg

        # unpack: o words -> l slots (base-W number re-read in base K).
        n = (w[:, 0] << (2 * W_BITS)) | (w[:, 1] << W_BITS) | w[:, 2]
        slots = [(n >> (K_BITS * p)) & (K - 1) for p in range(L_SYMS)]

        # ---- decode + multiply the segment's nonzeros ----
        for i in range(NPS):
            de = jnp.take(dtab, slots[2 * i], mode="clip")
            ve = jnp.take(vtab, slots[2 * i + 1], mode="clip")
            ds = de >> 16
            vs = ve >> 16
            live = active & (emitted < nnz)

            d_esc = jnp.take(d_isesc, ds, mode="clip") == 1
            dlt = jnp.where(
                d_esc,
                jnp.take(d_escapes, esc_d, mode="clip"),
                jnp.take(d_payload, ds, mode="clip"),
            )
            esc_d = esc_d + jnp.where(live & d_esc, 1, 0)

            v_esc = jnp.take(v_isesc, vs, mode="clip") == 1
            val = jnp.where(
                v_esc,
                jnp.take(v_escapes, esc_v, mode="clip"),
                jnp.take(v_value, vs, mode="clip"),
            )
            esc_v = esc_v + jnp.where(live & v_esc, 1, 0)

            first = emitted == 0
            new_col = jnp.where(first | (not delta_encode), dlt, col + dlt)
            col = jnp.where(live, new_col, col)
            xv = jnp.take(x, jnp.clip(col, 0, x.shape[0] - 1), mode="clip")
            acc = acc + jnp.where(live, val * xv, jnp.float32(0.0))
            emitted = emitted + jnp.where(live, 1, 0)

        # ---- produce next-segment words (final segments skip) ----
        prod_i = producing.astype(i64)
        for g in range(F_CHECKS):
            gd = jnp.zeros((WARP,), dtype=i64)
            gr = jnp.ones((WARP,), dtype=i64)
            for ps in range(g * GROUP, (g + 1) * GROUP):
                tab = dtab if ps % 2 == 0 else vtab
                e = jnp.take(tab, slots[ps], mode="clip")
                b = (e & 0xFF) + 1
                gd = gd * b + ((e >> 8) & 0xFF)
                gr = gr * b
            d = jnp.where(producing, d * gr + gd, d)
            r = jnp.where(producing, r * gr, r)
            extract = producing & (r >= W)
            loadm = prod_i * (1 - extract.astype(i64))
            wload, pos = gather_words(pos, loadm)
            wg = jnp.where(extract, d & (W - 1), jnp.where(loadm.astype(bool), wload, w[:, g]))
            w = w.at[:, g].set(wg)
            d = jnp.where(extract, d >> W_BITS, d)
            r = jnp.where(extract, r >> W_BITS, r)
        for k in range(F_CHECKS, O_WORDS):
            wload, pos = gather_words(pos, prod_i)
            w = w.at[:, k].set(jnp.where(producing, wload, w[:, k]))
        return pos, w, d, r, emitted, col, acc, esc_d, esc_v

    carry = (
        pos,
        w,
        jnp.zeros((WARP,), dtype=i64),  # d
        jnp.ones((WARP,), dtype=i64),  # r
        jnp.zeros((WARP,), dtype=i64),  # emitted
        jnp.zeros((WARP,), dtype=i64),  # col
        jnp.zeros((WARP,), dtype=jnp.float32),  # acc
        esc_d0,
        esc_v0,
    )
    carry = jax.lax.fori_loop(0, max_seg, body, carry)
    y_ref[...] = carry[6]


def spmv_dtans(
    dtab,
    vtab,
    d_payload,
    d_isesc,
    v_value,
    v_isesc,
    stream,
    slice_offsets,
    row_nnz,
    d_esc_off,
    v_esc_off,
    d_escapes,
    v_escapes,
    x,
    *,
    max_seg: int,
    delta_encode: bool = True,
    interpret: bool = True,
):
    """Fused decode+SpMVM: returns y = A @ x (float32, shape (nrows,)).

    All array arguments follow :class:`ref.KernelBundle`; shapes are static,
    so one jit/AOT artifact serves one bucket.
    """
    nrows = row_nnz.shape[0]
    assert nrows % WARP == 0, "pad rows to a multiple of 32"
    nslices = nrows // WARP

    kernel = functools.partial(_slice_kernel, max_seg=max_seg, delta_encode=delta_encode)
    full = lambda shape: pl.BlockSpec(shape, lambda i: tuple(0 for _ in shape))
    lane = pl.BlockSpec((WARP,), lambda i: (i,))
    return pl.pallas_call(
        kernel,
        grid=(nslices,),
        in_specs=[
            full(dtab.shape),
            full(vtab.shape),
            full(d_payload.shape),
            full(d_isesc.shape),
            full(v_value.shape),
            full(v_isesc.shape),
            full(stream.shape),
            full(slice_offsets.shape),
            lane,  # row_nnz
            lane,  # d_esc_off
            lane,  # v_esc_off
            full(d_escapes.shape),
            full(v_escapes.shape),
            full(x.shape),
        ],
        out_specs=lane,
        out_shape=jax.ShapeDtypeStruct((nrows,), jnp.float32),
        interpret=interpret,
    )(
        dtab,
        vtab,
        d_payload,
        d_isesc,
        v_value,
        v_isesc,
        stream,
        slice_offsets,
        row_nnz,
        d_esc_off,
        v_esc_off,
        d_escapes,
        v_escapes,
        x,
    )


def spmv_dtans_bundle(b: "ref.KernelBundle", x, interpret: bool = True):
    """Convenience wrapper over a :class:`ref.KernelBundle`. Pads the row
    count to a slice multiple (and the stream to >= 1 word) if needed,
    truncating the result back."""
    nrows = len(b.row_nnz)
    padded_rows = max(-(-nrows // WARP) * WARP, WARP)
    if padded_rows != nrows or len(b.stream) == 0:
        b = b.pad_to(padded_rows, max(len(b.stream), 1), max(len(b.d_escapes), 1))
    y = _spmv_bundle_arrays(b, x, interpret)
    return y[:nrows]


def _spmv_bundle_arrays(b: "ref.KernelBundle", x, interpret: bool):
    return spmv_dtans(
        jnp.asarray(b.dtab),
        jnp.asarray(b.vtab),
        jnp.asarray(b.d_payload),
        jnp.asarray(b.d_isesc),
        jnp.asarray(b.v_value),
        jnp.asarray(b.v_isesc),
        jnp.asarray(b.stream),
        jnp.asarray(b.slice_offsets),
        jnp.asarray(b.row_nnz),
        jnp.asarray(b.d_esc_off),
        jnp.asarray(b.v_esc_off),
        jnp.asarray(b.d_escapes),
        jnp.asarray(b.v_escapes),
        jnp.asarray(x, dtype=jnp.float32),
        max_seg=max(b.max_seg, 1),
        delta_encode=b.delta_encode,
        interpret=interpret,
    )
