//! Hand-unrolled 4/8-wide accumulator variants of the CSR row-range and
//! SELL slice-range kernels — the CPU analog of the paper's wide warp
//! accumulators, with a **documented, deterministic reassociation policy**
//! (see `docs/KERNELS.md`).
//!
//! # Reassociation policy
//!
//! For a fixed lane count `L ∈ {4, 8}`, every row's dot product is
//! computed as:
//!
//! 1. **Lane assignment** — the row's within-row element positions
//!    `p = 0, 1, 2, …` are assigned to lane `p mod L`, in ascending `p`
//!    order. Tail elements (a final partial group of fewer than `L`
//!    elements) follow the *same* rule; lanes past the tail simply keep
//!    their partial sums (a row shorter than `L` leaves the high lanes at
//!    exactly `0.0`).
//! 2. **Combine tree** — the `L` lane sums are reduced by a fixed
//!    stride-halving pairwise tree:
//!    `L = 4`: `(l0 + l2) + (l1 + l3)`;
//!    `L = 8`: `((l0+l4) + (l2+l6)) + ((l1+l5) + (l3+l7))`.
//!
//! Both steps depend only on the row's own element list — never on block
//! boundaries or partition counts — so for a fixed
//! [`KernelVariant`](crate::spmv::engine::KernelVariant) the engine's
//! results stay **bit-identical** across every
//! [`ParStrategy`](crate::spmv::engine::ParStrategy) and partition count
//! (oracle level 2), while differing from the scalar left-to-right kernels
//! only by float reassociation, within the conformance oracle's closeness
//! bound (oracle level 1). The `_axpby` fused forms reuse the identical
//! per-row accumulation and apply `alpha·acc + beta·y` in place of the
//! `y += acc` accumulate, exactly like their scalar counterparts.
//!
//! A software-prefetch helper ([`prefetch_x`]) walks the `x[col]` gather
//! stream [`PREFETCH_AHEAD`] elements ahead of the accumulators; it
//! compiles to `prefetcht0` on x86_64 and to nothing elsewhere, so it can
//! never change results — only the memory schedule.

use crate::matrix::csr::Csr;
use crate::matrix::sell::Sell;
use crate::util::error::Result;

/// How many elements ahead of the accumulator the `x[col]` gather stream
/// is prefetched. One or two cache-line-batches of column indices: far
/// enough to cover DRAM latency at SpMV arithmetic intensity, near enough
/// not to thrash L1.
pub(crate) const PREFETCH_AHEAD: usize = 16;

/// Software prefetch of `x[col]` into L1 — a scheduling hint only, never
/// observable in results. Compiles to `prefetcht0` on x86_64 and to a
/// no-op on every other target (cfg-gated; no `unsafe` reaches other
/// architectures).
#[inline(always)]
pub(crate) fn prefetch_x(x: &[f64], col: usize) {
    #[cfg(target_arch = "x86_64")]
    {
        if col < x.len() {
            // SAFETY: `col` is bounds-checked above; _mm_prefetch has no
            // memory effects beyond cache state.
            unsafe {
                core::arch::x86_64::_mm_prefetch(
                    x.as_ptr().add(col) as *const i8,
                    core::arch::x86_64::_MM_HINT_T0,
                );
            }
        }
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        let _ = (x, col);
    }
}

/// The fixed stride-halving pairwise combine tree over `L` lane sums
/// (`L` must be a power of two — enforced by the only instantiations,
/// `L = 4` and `L = 8`). This is the *only* reduction order the unrolled
/// kernels use, which is what makes a variant's results reproducible.
#[inline(always)]
pub(crate) fn combine_tree<const L: usize>(acc: [f64; L]) -> f64 {
    debug_assert!(L.is_power_of_two());
    let mut tmp = acc;
    let mut width = L;
    while width > 1 {
        width /= 2;
        for i in 0..width {
            tmp[i] += tmp[i + width];
        }
    }
    tmp[0]
}

/// One row's dot product under the unrolled policy: `L`-strided lane
/// accumulation over `(vals, cols)` gathered from `x`, then the fixed
/// combine tree.
#[inline(always)]
fn row_dot_unrolled<const L: usize>(vals: &[f64], cols: &[u32], x: &[f64]) -> f64 {
    debug_assert_eq!(vals.len(), cols.len());
    let n = vals.len();
    let mut acc = [0.0f64; L];
    let mut k = 0;
    while k + L <= n {
        if k + PREFETCH_AHEAD < n {
            prefetch_x(x, cols[k + PREFETCH_AHEAD] as usize);
        }
        for j in 0..L {
            acc[j] += vals[k + j] * x[cols[k + j] as usize];
        }
        k += L;
    }
    // Tail: positions keep the `p mod L` lane rule (j restarts at 0 on a
    // multiple-of-L boundary, so offset == position mod L).
    let mut j = 0;
    while k < n {
        acc[j] += vals[k] * x[cols[k] as usize];
        k += 1;
        j += 1;
    }
    combine_tree::<L>(acc)
}

/// Unrolled CSR kernel over rows `r0..r1`: `y_seg[i] += dot(row r0+i, x)`
/// under the module's reassociation policy. Same range contract as
/// [`spmv_row_range`](crate::spmv::csr::spmv_row_range).
pub(crate) fn spmv_row_range_unrolled<const L: usize>(
    m: &Csr,
    r0: usize,
    r1: usize,
    x: &[f64],
    y_seg: &mut [f64],
) -> Result<()> {
    debug_assert_eq!(y_seg.len(), r1 - r0);
    for (i, r) in (r0..r1).enumerate() {
        let lo = m.row_ptr[r];
        let hi = m.row_ptr[r + 1];
        y_seg[i] += row_dot_unrolled::<L>(&m.vals[lo..hi], &m.cols[lo..hi], x);
    }
    Ok(())
}

/// Fused unrolled CSR kernel: `y_seg[i] = alpha·dot + beta·y_seg[i]`,
/// with the *same* per-row accumulation as
/// [`spmv_row_range_unrolled`] — bit-identical to the unfused compose by
/// the same argument as the scalar `_axpby` kernels.
pub(crate) fn spmv_row_range_axpby_unrolled<const L: usize>(
    m: &Csr,
    r0: usize,
    r1: usize,
    x: &[f64],
    alpha: f64,
    beta: f64,
    y_seg: &mut [f64],
) -> Result<()> {
    debug_assert_eq!(y_seg.len(), r1 - r0);
    for (i, r) in (r0..r1).enumerate() {
        let lo = m.row_ptr[r];
        let hi = m.row_ptr[r + 1];
        let acc = row_dot_unrolled::<L>(&m.vals[lo..hi], &m.cols[lo..hi], x);
        y_seg[i] = alpha * acc + beta * y_seg[i];
    }
    Ok(())
}

/// One SELL row's dot product under the unrolled policy. SELL stores a
/// slice column-major, so row `rr`'s element at within-row position `j`
/// lives at `base + j*h + rr` (stride `h`); the lane rule is still
/// `j mod L` over the slice's padded width — padded cells carry value
/// `0.0` exactly as in the scalar SELL kernels, so they perturb nothing
/// but participate in the (fixed) lane assignment.
#[inline(always)]
fn sell_row_dot_unrolled<const L: usize>(
    m: &Sell,
    base: usize,
    h: usize,
    rr: usize,
    width: usize,
    x: &[f64],
) -> f64 {
    let mut acc = [0.0f64; L];
    let mut j = 0;
    while j + L <= width {
        if j + PREFETCH_AHEAD < width {
            prefetch_x(x, m.cols[base + (j + PREFETCH_AHEAD) * h + rr] as usize);
        }
        for t in 0..L {
            let idx = base + (j + t) * h + rr;
            acc[t] += m.vals[idx] * x[m.cols[idx] as usize];
        }
        j += L;
    }
    let mut t = 0;
    while j < width {
        let idx = base + j * h + rr;
        acc[t] += m.vals[idx] * x[m.cols[idx] as usize];
        j += 1;
        t += 1;
    }
    combine_tree::<L>(acc)
}

/// Unrolled SELL kernel over slices `s0..s1`; same range contract as
/// [`spmv_sell_slice_range`](crate::spmv::sell::spmv_sell_slice_range),
/// but each row accumulates under the module's reassociation policy
/// (row-major walk, `L` lanes over the padded width).
pub(crate) fn spmv_sell_slice_range_unrolled<const L: usize>(
    m: &Sell,
    s0: usize,
    s1: usize,
    x: &[f64],
    y_seg: &mut [f64],
) -> Result<()> {
    let h = m.slice_height;
    let row0 = s0 * h;
    for s in s0..s1 {
        let r_base = s * h;
        let width = m.slice_widths[s] as usize;
        let base = m.slice_ptr[s];
        for rr in 0..h {
            let r = r_base + rr;
            if r >= m.nrows {
                break; // tail slice: rows past nrows do not exist
            }
            y_seg[r - row0] += sell_row_dot_unrolled::<L>(m, base, h, rr, width, x);
        }
    }
    Ok(())
}

/// Fused unrolled SELL kernel — the `_axpby` form of
/// [`spmv_sell_slice_range_unrolled`], same accumulation, scaled update.
pub(crate) fn spmv_sell_slice_range_axpby_unrolled<const L: usize>(
    m: &Sell,
    s0: usize,
    s1: usize,
    x: &[f64],
    alpha: f64,
    beta: f64,
    y_seg: &mut [f64],
) -> Result<()> {
    let h = m.slice_height;
    let row0 = s0 * h;
    for s in s0..s1 {
        let r_base = s * h;
        let width = m.slice_widths[s] as usize;
        let base = m.slice_ptr[s];
        for rr in 0..h {
            let r = r_base + rr;
            if r >= m.nrows {
                break;
            }
            let acc = sell_row_dot_unrolled::<L>(m, base, h, rr, width, x);
            y_seg[r - row0] = alpha * acc + beta * y_seg[r - row0];
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::gen::structured::powerlaw_rows;
    use crate::matrix::gen::{assign_values, ValueDist};
    use crate::spmv::csr::spmv_csr;
    use crate::util::propcheck::assert_close;
    use crate::util::rng::Xoshiro256;

    fn sample(n: usize, seed: u64) -> Csr {
        let mut rng = Xoshiro256::seeded(seed);
        let mut m = powerlaw_rows(n, 6.0, 1.1, &mut rng);
        assign_values(&mut m, ValueDist::Gaussian, &mut rng);
        m
    }

    #[test]
    fn combine_tree_is_the_documented_order() {
        // L = 4: (l0 + l2) + (l1 + l3), checked against a hand expansion
        // on values where association is observable.
        let eps = f64::EPSILON / 2.0; // 2^-53
        let lanes = [1.0, eps, eps, eps];
        let want = (1.0 + eps) + (eps + eps);
        assert_eq!(combine_tree::<4>(lanes).to_bits(), want.to_bits());
        // L = 8 stride-halving: ((l0+l4)+(l2+l6)) + ((l1+l5)+(l3+l7)).
        let lanes8 = [1.0, eps, eps, eps, eps, eps, eps, eps];
        let want8 = ((1.0 + eps) + (eps + eps)) + ((eps + eps) + (eps + eps));
        assert_eq!(combine_tree::<8>(lanes8).to_bits(), want8.to_bits());
    }

    #[test]
    fn unrolled_row_ranges_reassemble_bitwise() {
        // Partition independence: any split of the row range reassembles
        // to the exact bits of the full-range run, for both lane counts.
        let m = sample(120, 1);
        let mut rng = Xoshiro256::seeded(2);
        let x: Vec<f64> = (0..m.ncols).map(|_| rng.next_f64() - 0.5).collect();
        let mut want4 = vec![0.0; m.nrows];
        spmv_row_range_unrolled::<4>(&m, 0, m.nrows, &x, &mut want4).unwrap();
        let mut want8 = vec![0.0; m.nrows];
        spmv_row_range_unrolled::<8>(&m, 0, m.nrows, &x, &mut want8).unwrap();
        for splits in [vec![0, 1, m.nrows], vec![0, 40, 77, m.nrows]] {
            let mut got4 = vec![0.0; m.nrows];
            let mut got8 = vec![0.0; m.nrows];
            for w in splits.windows(2) {
                spmv_row_range_unrolled::<4>(&m, w[0], w[1], &x, &mut got4[w[0]..w[1]]).unwrap();
                spmv_row_range_unrolled::<8>(&m, w[0], w[1], &x, &mut got8[w[0]..w[1]]).unwrap();
            }
            assert_eq!(got4, want4);
            assert_eq!(got8, want8);
        }
    }

    #[test]
    fn unrolled_csr_is_close_to_scalar_including_short_rows() {
        // powerlaw matrices have plenty of rows shorter than the lane
        // width plus empty rows — the closeness bound must hold anyway.
        let m = sample(200, 3);
        let mut rng = Xoshiro256::seeded(4);
        let x: Vec<f64> = (0..m.ncols).map(|_| rng.next_f64() - 0.5).collect();
        let mut want = vec![0.0; m.nrows];
        spmv_csr(&m, &x, &mut want).unwrap();
        let mut got4 = vec![0.0; m.nrows];
        spmv_row_range_unrolled::<4>(&m, 0, m.nrows, &x, &mut got4).unwrap();
        let mut got8 = vec![0.0; m.nrows];
        spmv_row_range_unrolled::<8>(&m, 0, m.nrows, &x, &mut got8).unwrap();
        assert_close(&got4, &want, 1e-12, 1e-15).unwrap();
        assert_close(&got8, &want, 1e-12, 1e-15).unwrap();
    }

    #[test]
    fn unrolled_axpby_matches_unfused_compose_bitwise() {
        let m = sample(90, 5);
        let mut rng = Xoshiro256::seeded(6);
        let x: Vec<f64> = (0..m.ncols).map(|_| rng.next_f64() - 0.5).collect();
        let y0: Vec<f64> = (0..m.nrows).map(|_| rng.next_f64() * 2.0).collect();
        for &(alpha, beta) in &[(1.0, 0.0), (-0.5, 1.0), (2.5, -0.75)] {
            let mut tmp = vec![0.0; m.nrows];
            spmv_row_range_unrolled::<4>(&m, 0, m.nrows, &x, &mut tmp).unwrap();
            let want: Vec<f64> =
                y0.iter().zip(&tmp).map(|(y, t)| alpha * t + beta * y).collect();
            let mut got = y0.clone();
            spmv_row_range_axpby_unrolled::<4>(&m, 0, m.nrows, &x, alpha, beta, &mut got)
                .unwrap();
            assert_eq!(got, want, "alpha={alpha} beta={beta}");
        }
    }

    #[test]
    fn unrolled_sell_matches_scalar_sell_closely_and_partitions_bitwise() {
        let m = sample(150, 7);
        let sell = Sell::from_csr(&m, 32);
        let mut rng = Xoshiro256::seeded(8);
        let x: Vec<f64> = (0..m.ncols).map(|_| rng.next_f64() - 0.5).collect();
        let mut scalar = vec![0.0; m.nrows];
        crate::spmv::sell::spmv_sell(&sell, &x, &mut scalar).unwrap();
        let nsl = sell.nslices();
        let mut full = vec![0.0; m.nrows];
        spmv_sell_slice_range_unrolled::<8>(&sell, 0, nsl, &x, &mut full).unwrap();
        assert_close(&full, &scalar, 1e-12, 1e-15).unwrap();
        // Slice-range splits reassemble bitwise.
        let mut parts = vec![0.0; m.nrows];
        for w in [0usize, 2, 3, nsl].windows(2) {
            let r0 = w[0] * 32;
            let r1 = (w[1] * 32).min(m.nrows);
            spmv_sell_slice_range_unrolled::<8>(&sell, w[0], w[1], &x, &mut parts[r0..r1])
                .unwrap();
        }
        assert_eq!(parts, full);
        // Fused form agrees with its unfused compose.
        let y0: Vec<f64> = (0..m.nrows).map(|_| rng.next_f64()).collect();
        let want: Vec<f64> = y0.iter().zip(&full).map(|(y, t)| 2.0 * t - 0.5 * y).collect();
        let mut got = y0.clone();
        spmv_sell_slice_range_axpby_unrolled::<8>(&sell, 0, nsl, &x, 2.0, -0.5, &mut got)
            .unwrap();
        assert_eq!(got, want);
    }
}
