//! Random graph models used in Fig. 4 of the paper: Erdős–Rényi,
//! Watts–Strogatz and Barabási–Albert, parameterized by average degree.

use crate::matrix::coo::Coo;
use crate::matrix::csr::Csr;
use crate::util::rng::Xoshiro256;

/// The three random graph models of Fig. 4.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GraphModel {
    /// G(n, p): each edge independently with probability p = degree/n.
    ErdosRenyi,
    /// Ring lattice with k neighbors, each edge rewired with prob 0.1.
    WattsStrogatz,
    /// Preferential attachment, m = degree/2 edges per new node.
    BarabasiAlbert,
}

impl GraphModel {
    /// Parse from a CLI label.
    pub fn parse(s: &str) -> Option<GraphModel> {
        match s.to_ascii_lowercase().as_str() {
            "er" | "erdos-renyi" | "erdosrenyi" => Some(GraphModel::ErdosRenyi),
            "ws" | "watts-strogatz" => Some(GraphModel::WattsStrogatz),
            "ba" | "barabasi-albert" => Some(GraphModel::BarabasiAlbert),
            _ => None,
        }
    }

    /// Display label.
    pub fn label(&self) -> &'static str {
        match self {
            GraphModel::ErdosRenyi => "Erdos-Renyi",
            GraphModel::WattsStrogatz => "Watts-Strogatz",
            GraphModel::BarabasiAlbert => "Barabasi-Albert",
        }
    }
}

/// Generate the adjacency matrix (as CSR, all values 1.0) of a random graph
/// with `n` nodes and the given target average degree.
///
/// Matrix parameters are chosen as in the paper's Fig. 4: "model parameters
/// are chosen to keep the average degree at 5, 10, and 20".
pub fn gen_graph_csr(model: GraphModel, n: usize, avg_degree: f64, rng: &mut Xoshiro256) -> Csr {
    let coo = match model {
        GraphModel::ErdosRenyi => erdos_renyi(n, avg_degree, rng),
        GraphModel::WattsStrogatz => watts_strogatz(n, avg_degree, 0.1, rng),
        GraphModel::BarabasiAlbert => barabasi_albert(n, avg_degree, rng),
    };
    Csr::from_coo(&coo)
}

/// Directed G(n, p) with p = degree/n, generated with geometric skipping so
/// the cost is O(nnz) rather than O(n²).
fn erdos_renyi(n: usize, avg_degree: f64, rng: &mut Xoshiro256) -> Coo {
    let p = (avg_degree / n as f64).min(1.0);
    let mut coo = Coo::new(n, n);
    if p <= 0.0 {
        return coo;
    }
    let total = (n as u64) * (n as u64);
    let mut pos: u64 = rng.next_geometric(p);
    while pos < total {
        coo.push((pos / n as u64) as u32, (pos % n as u64) as u32, 1.0);
        pos += 1 + rng.next_geometric(p);
    }
    coo
}

/// Watts–Strogatz small-world: ring lattice with `k = round(degree)`
/// neighbors per node (k/2 on each side), each edge rewired with
/// probability `beta`.
fn watts_strogatz(n: usize, avg_degree: f64, beta: f64, rng: &mut Xoshiro256) -> Coo {
    let k = (avg_degree.round() as usize).max(2) & !1; // even, >= 2
    let mut coo = Coo::new(n, n);
    if n < 2 {
        return coo;
    }
    // BTreeSet keeps iteration deterministic (seeded corpora must be
    // reproducible across processes).
    use std::collections::BTreeSet;
    let mut edges: BTreeSet<(u32, u32)> = BTreeSet::new();
    for i in 0..n {
        for j in 1..=(k / 2) {
            let mut tgt = ((i + j) % n) as u32;
            if beta > 0.0 && rng.chance(beta) {
                // Rewire to a uniform random target (avoid self loops).
                for _ in 0..8 {
                    let cand = rng.below_usize(n) as u32;
                    if cand as usize != i {
                        tgt = cand;
                        break;
                    }
                }
            }
            edges.insert((i as u32, tgt));
            edges.insert((tgt, i as u32));
        }
    }
    for (r, c) in edges {
        coo.push(r, c, 1.0);
    }
    coo
}

/// Barabási–Albert preferential attachment with `m = degree/2` edges per
/// new node, implemented with the standard repeated-nodes target list (an
/// O(nnz) sampler of the degree distribution).
fn barabasi_albert(n: usize, avg_degree: f64, rng: &mut Xoshiro256) -> Coo {
    let m = ((avg_degree / 2.0).round() as usize).max(1);
    let mut coo = Coo::new(n, n);
    if n <= m {
        // Complete graph fallback for tiny n.
        for i in 0..n {
            for j in 0..n {
                if i != j {
                    coo.push(i as u32, j as u32, 1.0);
                }
            }
        }
        return coo;
    }
    // `targets` holds node ids proportionally to their degree.
    let mut targets: Vec<u32> = Vec::with_capacity(2 * m * n);
    // Seed: a small clique of m+1 nodes.
    for i in 0..=m {
        for j in 0..=m {
            if i != j {
                coo.push(i as u32, j as u32, 1.0);
            }
        }
        for _ in 0..m {
            targets.push(i as u32);
        }
    }
    use std::collections::BTreeSet;
    for v in (m + 1)..n {
        let mut chosen: BTreeSet<u32> = BTreeSet::new();
        let mut guard = 0;
        while chosen.len() < m && guard < 50 * m {
            let t = targets[rng.below_usize(targets.len())];
            chosen.insert(t);
            guard += 1;
        }
        for &t in &chosen {
            coo.push(v as u32, t, 1.0);
            coo.push(t, v as u32, 1.0);
            targets.push(t);
            targets.push(v as u32);
        }
    }
    coo
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::stats::MatrixStats;

    #[test]
    fn er_degree_close_to_target() {
        let mut rng = Xoshiro256::seeded(1);
        let m = gen_graph_csr(GraphModel::ErdosRenyi, 2000, 10.0, &mut rng);
        let d = m.annzpr();
        assert!((d - 10.0).abs() < 1.0, "avg degree {d}");
        m.validate().unwrap();
    }

    #[test]
    fn ws_degree_close_to_target() {
        let mut rng = Xoshiro256::seeded(2);
        let m = gen_graph_csr(GraphModel::WattsStrogatz, 2000, 10.0, &mut rng);
        let d = m.annzpr();
        assert!(d > 8.0 && d < 11.0, "avg degree {d}");
        m.validate().unwrap();
    }

    #[test]
    fn ba_degree_close_to_target() {
        let mut rng = Xoshiro256::seeded(3);
        let m = gen_graph_csr(GraphModel::BarabasiAlbert, 2000, 10.0, &mut rng);
        let d = m.annzpr();
        assert!(d > 8.0 && d < 12.0, "avg degree {d}");
        m.validate().unwrap();
    }

    #[test]
    fn ba_has_hubs() {
        // Power-law: max degree should far exceed the average.
        let mut rng = Xoshiro256::seeded(4);
        let m = gen_graph_csr(GraphModel::BarabasiAlbert, 3000, 10.0, &mut rng);
        assert!(m.max_row_len() > 5 * m.annzpr() as usize);
    }

    #[test]
    fn er_delta_encoding_reduces_entropy() {
        // The Fig. 4 claim: delta-encoding reduces index entropy for all
        // three models. ER deltas are geometric, so this is the clearest.
        let mut rng = Xoshiro256::seeded(5);
        let m = gen_graph_csr(GraphModel::ErdosRenyi, 4096, 10.0, &mut rng);
        let s = MatrixStats::compute(&m);
        assert!(
            s.relative_delta_entropy() < 0.95,
            "relative entropy {}",
            s.relative_delta_entropy()
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let a = gen_graph_csr(GraphModel::ErdosRenyi, 500, 5.0, &mut Xoshiro256::seeded(9));
        let b = gen_graph_csr(GraphModel::ErdosRenyi, 500, 5.0, &mut Xoshiro256::seeded(9));
        assert_eq!(a, b);
    }
}
