//! Entropy and structure statistics over sparse matrices.
//!
//! These drive the Fig. 4 experiment (entropy reduction via delta-encoding
//! on random graph models) and the corpus characterization used in the
//! Table I–III bucketing.

use super::csr::Csr;
use std::collections::HashMap;

/// Shannon entropy (bits/symbol) of a count multiset — Eq. (1).
pub fn entropy_of_counts<I: IntoIterator<Item = u64>>(counts: I) -> f64 {
    let counts: Vec<u64> = counts.into_iter().filter(|&c| c > 0).collect();
    let total: u64 = counts.iter().sum();
    if total == 0 {
        return 0.0;
    }
    let total = total as f64;
    counts
        .iter()
        .map(|&c| {
            let p = c as f64 / total;
            -p * p.log2()
        })
        .sum()
}

/// Cross entropy H(P, P') in bits/symbol — Eq. (2). `p` and `q` are
/// parallel per-symbol probability slices; symbols with q=0 must not have
/// p>0 (caller guarantees coverage, e.g. via an escape symbol).
pub fn cross_entropy(p: &[f64], q: &[f64]) -> f64 {
    p.iter()
        .zip(q)
        .filter(|(&pi, _)| pi > 0.0)
        .map(|(&pi, &qi)| -pi * qi.log2())
        .sum()
}

/// Entropy of a u32 symbol sequence.
pub fn entropy_u32(xs: impl IntoIterator<Item = u32>) -> f64 {
    let mut counts: HashMap<u32, u64> = HashMap::new();
    for x in xs {
        *counts.entry(x).or_insert(0) += 1;
    }
    entropy_of_counts(counts.into_values())
}

/// Entropy of a u64 symbol sequence (used for f64 value bit patterns).
pub fn entropy_u64(xs: impl IntoIterator<Item = u64>) -> f64 {
    let mut counts: HashMap<u64, u64> = HashMap::new();
    for x in xs {
        *counts.entry(x).or_insert(0) += 1;
    }
    entropy_of_counts(counts.into_values())
}

/// Delta-encode the column indices of one row: `delta_0 = col_0`,
/// `delta_i = col_i - col_{i-1}` (strictly positive for i > 0 since columns
/// ascend strictly). Matches the paper's tridiagonal example: a row
/// `[k-1, k, k+1]` yields `[k-1, 1, 1]`.
pub fn delta_encode_row(cols: &[u32], out: &mut Vec<u32>) {
    let mut prev = 0u32;
    for (i, &c) in cols.iter().enumerate() {
        if i == 0 {
            out.push(c);
        } else {
            out.push(c - prev);
        }
        prev = c;
    }
}

/// Inverse of [`delta_encode_row`].
pub fn delta_decode_row(deltas: &[u32], out: &mut Vec<u32>) {
    let mut acc = 0u32;
    for (i, &d) in deltas.iter().enumerate() {
        acc = if i == 0 { d } else { acc + d };
        out.push(acc);
    }
}

/// All per-row deltas of a CSR matrix, concatenated.
pub fn all_deltas(m: &Csr) -> Vec<u32> {
    let mut out = Vec::with_capacity(m.nnz());
    for r in 0..m.nrows {
        delta_encode_row(m.row_cols(r), &mut out);
    }
    out
}

/// Summary statistics of a matrix used for bucketing and reports.
#[derive(Debug, Clone)]
pub struct MatrixStats {
    /// Rows.
    pub nrows: usize,
    /// Columns.
    pub ncols: usize,
    /// Nonzeros.
    pub nnz: usize,
    /// Average nonzeros per row.
    pub annzpr: f64,
    /// Maximum row length.
    pub max_row_len: usize,
    /// Entropy of raw column indices (bits/symbol).
    pub h_indices: f64,
    /// Entropy of delta-encoded column indices (bits/symbol).
    pub h_deltas: f64,
    /// Entropy of value bit patterns (bits/symbol, f64 patterns).
    pub h_values: f64,
    /// Number of distinct values.
    pub distinct_values: usize,
}

impl MatrixStats {
    /// Compute all statistics for a matrix.
    pub fn compute(m: &Csr) -> MatrixStats {
        let h_indices = entropy_u32(m.cols.iter().copied());
        let h_deltas = entropy_u32(all_deltas(m));
        let mut vcounts: HashMap<u64, u64> = HashMap::new();
        for &v in &m.vals {
            *vcounts.entry(v.to_bits()).or_insert(0) += 1;
        }
        let distinct_values = vcounts.len();
        let h_values = entropy_of_counts(vcounts.into_values());
        MatrixStats {
            nrows: m.nrows,
            ncols: m.ncols,
            nnz: m.nnz(),
            annzpr: m.annzpr(),
            max_row_len: m.max_row_len(),
            h_indices,
            h_deltas,
            h_values,
            distinct_values,
        }
    }

    /// The Fig. 4 y-axis: relative entropy H(deltas)/H(indices) (1.0 when
    /// index entropy is zero).
    pub fn relative_delta_entropy(&self) -> f64 {
        if self.h_indices <= 0.0 {
            1.0
        } else {
            self.h_deltas / self.h_indices
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::coo::Coo;

    #[test]
    fn entropy_uniform_and_point() {
        assert!((entropy_of_counts(vec![1, 1, 1, 1]) - 2.0).abs() < 1e-12);
        assert_eq!(entropy_of_counts(vec![5]), 0.0);
        assert_eq!(entropy_of_counts(vec![]), 0.0);
    }

    #[test]
    fn entropy_paper_example() {
        // P: (a,0.1),(b,0.5),(c,0.4) -> H ~ 1.361
        let h = entropy_of_counts(vec![1, 5, 4]);
        assert!((h - 1.3609640474436812).abs() < 1e-9, "{h}");
    }

    #[test]
    fn cross_entropy_paper_example() {
        // P' (a,1/8),(b,4/8),(c,3/8) -> H(P,P') ~ 1.366
        let p = [0.1, 0.5, 0.4];
        let q = [0.125, 0.5, 0.375];
        let h = cross_entropy(&p, &q);
        assert!((h - 1.3660149997115376).abs() < 1e-9, "{h}");
        // suboptimal P'' gives 1.5 exactly
        let q2 = [0.25, 0.5, 0.25];
        assert!((cross_entropy(&p, &q2) - 1.5).abs() < 1e-12);
    }

    #[test]
    fn delta_roundtrip() {
        let cols = vec![3, 5, 6, 100, 101];
        let mut d = Vec::new();
        delta_encode_row(&cols, &mut d);
        assert_eq!(d, vec![3, 2, 1, 94, 1]);
        let mut back = Vec::new();
        delta_decode_row(&d, &mut back);
        assert_eq!(back, cols);
    }

    #[test]
    fn tridiagonal_deltas_match_paper() {
        // Row [k-1, k, k+1] -> deltas [k-1, 1, 1]
        let mut d = Vec::new();
        delta_encode_row(&[41, 42, 43], &mut d);
        assert_eq!(d, vec![41, 1, 1]);
    }

    #[test]
    fn tridiag_delta_entropy_much_lower() {
        // Tridiagonal matrix: delta entropy should be far below raw index
        // entropy (the motivating example of §IV-A).
        let n = 256;
        let mut coo = Coo::new(n, n);
        for i in 0..n {
            for j in i.saturating_sub(1)..(i + 2).min(n) {
                coo.push(i as u32, j as u32, 1.0);
            }
        }
        let m = Csr::from_coo(&coo);
        let s = MatrixStats::compute(&m);
        assert!(s.relative_delta_entropy() < 0.5, "rel={}", s.relative_delta_entropy());
    }
}
