//! Observability: request-flow tracing, log-bucketed histograms, and
//! metrics export for the serving pipeline.
//!
//! The paper's headline claims are *measured* claims — compression ratio
//! against the smallest baseline format and per-matrix SpMVM speedup —
//! and ROADMAP item 3 (measurement-driven adaptive routing) needs to know
//! where a request's time actually goes. This module turns the serving
//! core from "p50/p99 of a black box" into attributable stage-level
//! evidence:
//!
//! * [`span`] — typed per-request stage events
//!   (`Submitted → Queued → Dispatched → Pinned/ColdLoad →
//!   Coalesced → Kernel → Completed/Failed/Shed/Expired`) with a
//!   one-terminal-event-per-request conservation invariant;
//! * [`trace`] — the [`Tracer`] collector: sampled, sharded,
//!   fixed-capacity, drainable as structured events or Chrome
//!   trace-event JSON (Perfetto-loadable);
//! * [`hist`] — [`LogHistogram`], HDR-style log-bucketed mergeable
//!   histograms (≤0.78% relative quantile error, exact counts, constant
//!   memory) backing every latency/iteration distribution in
//!   [`Metrics`](crate::coordinator::metrics::Metrics);
//! * [`export`] — Prometheus text exposition and a JSON snapshot of the
//!   full metrics surface (stable names; `format`/`tenant`/`stage`/
//!   `matrix` labels — contract table in `docs/OBSERVABILITY.md`).
//!
//! Instrumentation lives where the stages happen: the coordinator stamps
//! submit/queue/dispatch/coalesce/kernel, the store stamps cold loads,
//! and [`SpmvEngine::run_timed`](crate::spmv::engine::SpmvEngine::run_timed)
//! reports per-block min/max/mean micros — the partition-imbalance
//! signal the SIMD and adaptive-routing roadmap items both need.

pub mod export;
pub mod hist;
pub mod span;
pub mod trace;

pub use hist::LogHistogram;
pub use span::{SpanEvent, SpanId, Stage};
pub use trace::{ObsConfig, Tracer};
