//! Format autotuner — the AlphaSparse stand-in for the Fig. 9 experiment.
//!
//! AlphaSparse [13] searches a large design space of formats and kernel
//! parameters per matrix (taking hours) and emits the fastest kernel it
//! finds. Our substitute exhaustively sweeps the simulator over the same
//! *kind* of space — the four classic kernels times their tile/slice
//! parameters — and returns the best, along with an honest account of the
//! search cost (the sum of all simulated candidate runtimes plus a
//! per-candidate compilation overhead, which is what makes the real
//! AlphaSparse impractical).

pub mod search;

pub use search::{autotune, dtans_time_us, Candidate, TuneResult, TuneSpace};
