//! Seeded concurrency-stress driver for the coordinator stack.
//!
//! [`run_stress`] generates a deterministic mixed trace (single SpMVMs,
//! SpMM bursts, CG solves, mid-trace registrations, forced evictions,
//! and — in closed-loop runs — delta-append bursts on a set of mutable
//! matrices) from a seed, hammers a **budgeted** [`SpmvService`] with it
//! from many threads — so evictions, cold reloads, deduped loader
//! faults, SpMM batch packing, solve pins and background overlay
//! compactions all interleave — and then checks five conservation
//! oracles:
//!
//! 1. **Bit-identical serial replay of the admitted trace** — every
//!    response the stressed service produced is recomputed on a fresh
//!    *unbudgeted, serial* reference service and compared bit for bit;
//!    shed and expired requests (which by contract never executed) are
//!    skipped but tallied. Eviction, cold reload and kernel parallelism
//!    must never change a single ULP (the per-format bit-identity
//!    guarantee of the engine, end to end through the service). The
//!    replay also re-applies every append burst at the same trace point
//!    and compares the version stamps — and because every op touching a
//!    mutable matrix is confined to one thread (see
//!    [`StressConfig::mutate`]), the per-matrix interleaving is a
//!    function of the trace alone, so reads of mutated matrices must be
//!    bit-identical too even though the stressed service compacts
//!    overlays in the background mid-traffic (compaction is bit-neutral
//!    by construction; the reference never compacts).
//! 2. **Metrics conservation** — after the run drains,
//!    `completed + failed + shed + expired == submitted`, no request
//!    failed, and the shed/expired counters agree exactly with the
//!    outcomes the threads recorded.
//! 3. **Zero leaked pins** — every registered matrix's
//!    [`pin_count`](crate::store::MatrixStore::pin_count) is 0 once all
//!    threads join: no code path (including shedding, deadline expiry,
//!    append's pin-and-retry commit, and the compaction swap's
//!    pin-quiesce) leaks an acquisition.
//! 4. **Span conservation** — the stressed service traces every request
//!    ([`ObsConfig`] with `sample_one_in: 1` and a capacity scaled to the
//!    trace, so nothing drops), and after the drain the span chains must
//!    tell exactly the counters' story: one `Submitted` event per
//!    submitted request, exactly one terminal stage per request span
//!    (never zero, never two — a double-send or a silent drop would show
//!    up here), and terminal kinds summing to the `completed` / `failed`
//!    / `shed` / `expired` counters.
//! 5. **Routing conservation** — the stressed service runs the adaptive
//!    router live under stress, but at
//!    [`AdaptiveConfig::zero_exploration`]: by the router's own
//!    contract no challenger ever accumulates observations, so adaptive
//!    routing must be observationally invisible (which is what lets
//!    oracle 1's serial replay stay bit-identical). After the drain:
//!    `explored + exploited == routed` with `explored == 0`, the
//!    `route_flips` counter equals the length of the (empty) flip
//!    trace, the router's counters agree with the exported metrics, and
//!    every format tag that actually executed lies in the union of the
//!    router's admissible arm sets (plus `overlay` for mutated
//!    matrices, which the router retires on their first append).
//!
//! Two arrival modes share the trace and the oracles. **Closed-loop**
//! (default): each thread waits for its op before issuing the next, so
//! offered load self-limits and nothing sheds. **Open-loop**
//! ([`StressConfig::open_loop`], tier presets via
//! [`StressConfig::open_loop_for_scale`]): each thread submits its whole
//! slice up front against a deliberately small
//! [`StressConfig::queue_depth`], then collects — driving real
//! backpressure sheds, and injecting a deterministic subset of requests
//! with already-elapsed deadlines (`vseed % 16 == 0`) that the
//! dispatcher must reject with `DeadlineExceeded` before execution.
//!
//! Scale comes from [`TestkitScale`] (the `TESTKIT_SCALE` env knob): CI
//! runs `small` (4 threads, a few hundred ops, seconds); soak runs set
//! `medium`/`large`.

use crate::coordinator::{
    AdaptiveConfig, AdmissionConfig, Pending, RoutePolicy, ServiceConfig, SpmvService,
    SubmitOptions,
};
use crate::matrix::csr::Csr;
use crate::obs::{ObsConfig, Stage};
use crate::solver::{SolveMethod, SolverConfig};
use crate::spmv::engine::ParStrategy;
use crate::store::StoreConfig;
use crate::testkit::{seeded_vector as request_vector, zoo, TestkitScale};
use crate::util::error::{DtansError, Result};
use crate::util::rng::Xoshiro256;
use std::path::Path;
use std::sync::atomic::Ordering;
use std::sync::{Arc, Mutex};

/// Stress-run knobs. [`StressConfig::for_scale`] maps the `TESTKIT_SCALE`
/// tiers onto sensible values; fields stay public for bespoke runs.
#[derive(Debug, Clone)]
pub struct StressConfig {
    /// Worker threads issuing requests concurrently.
    pub threads: usize,
    /// Total trace operations (split round-robin across threads).
    pub ops: usize,
    /// Trace seed: same seed, same trace, same fixture set.
    pub seed: u64,
    /// Residency budget for the stressed service — far below the working
    /// set, so the trace forces evictions and cold reloads.
    pub budget_bytes: Option<u64>,
    /// Kernel parallelism of the stressed service (the reference replay
    /// is always serial).
    pub par: ParStrategy,
    /// Open-loop arrival: threads submit their whole trace slice before
    /// collecting any response (offered load is not gated on service
    /// capacity), and a deterministic subset of single-SpMVM requests
    /// carries an already-elapsed deadline. `false` is the classic
    /// closed loop.
    pub open_loop: bool,
    /// Admission queue depth of the stressed service. Closed-loop
    /// presets use a depth far above the possible in-flight count (no
    /// sheds); open-loop presets use a small depth so backpressure
    /// actually sheds.
    pub queue_depth: usize,
    /// Inject mutation ops: a deterministic subset of each mutable
    /// matrix's owning thread's trace slots is rewritten into
    /// [`append`](SpmvService::append) bursts and reads of that matrix,
    /// and the stressed service gets a small
    /// [`compact_overlay_nnz`](StoreConfig::compact_overlay_nnz)
    /// threshold so background compactions fire mid-traffic. Every op
    /// touching mutable matrix `j` lands only at trace indices owned by
    /// thread `j % threads` — the closed loop executes a thread's slice
    /// in index order, so the per-matrix op order equals the serial
    /// replay order and oracle 1's bit-identity extends to mutation.
    /// Ignored (off) under [`open_loop`](StressConfig::open_loop)
    /// arrivals, whose fire-and-forget submits would unorder reads
    /// against appends.
    pub mutate: bool,
}

impl StressConfig {
    /// Map a [`TestkitScale`] tier to a config. All tiers satisfy the
    /// acceptance floor (≥ 4 threads, ≥ 200 mixed ops, eviction-forcing
    /// budget).
    pub fn for_scale(scale: TestkitScale) -> StressConfig {
        let (threads, ops) = match scale {
            TestkitScale::Small => (4, 240),
            TestkitScale::Medium => (8, 1500),
            TestkitScale::Large => (16, 6000),
        };
        StressConfig {
            threads,
            ops,
            seed: 0x57E55,
            budget_bytes: Some(192 * 1024),
            par: ParStrategy::Auto,
            open_loop: false,
            queue_depth: 4096,
            mutate: true,
        }
    }

    /// The open-loop variant of [`StressConfig::for_scale`]: same trace
    /// shape, but arrivals are not gated on completions and the queue is
    /// small enough that admission control must shed under the burst.
    /// Mutation ops are off — see [`StressConfig::mutate`].
    pub fn open_loop_for_scale(scale: TestkitScale) -> StressConfig {
        StressConfig {
            open_loop: true,
            queue_depth: 64,
            mutate: false,
            ..StressConfig::for_scale(scale)
        }
    }
}

/// What a completed stress run did — for assertions and logs.
#[derive(Debug)]
pub struct StressReport {
    /// Trace operations executed.
    pub ops_executed: usize,
    /// Single-SpMVM responses compared bit-identically against replay.
    pub spmv_checked: usize,
    /// SpMM-burst responses compared (individual vectors).
    pub spmm_checked: usize,
    /// CG solves compared (iterate + residual history, bitwise).
    pub solves_checked: usize,
    /// Append bursts replayed on the reference with matching version
    /// stamps (0 unless [`StressConfig::mutate`]).
    pub appends_checked: usize,
    /// Background overlay compactions the stressed service completed.
    pub compactions: u64,
    /// Operations skipped because their mid-trace registration had not
    /// landed yet on the issuing thread's timeline.
    pub skipped: usize,
    /// Requests shed at admission (typed `Overloaded`) — nonzero only
    /// under open-loop arrivals with a small queue.
    pub shed: usize,
    /// Requests rejected at dispatch for an elapsed deadline (typed
    /// `DeadlineExceeded`) — only injected in open-loop mode.
    pub expired: usize,
    /// Evictions observed on the stressed service.
    pub evictions: u64,
    /// Cold loads observed on the stressed service.
    pub cold_loads: u64,
    /// Routing decisions the adaptive router handed out (oracle 5).
    pub routed: u64,
    /// Exploration samples among them — must be 0 under the stress
    /// driver's zero-exploration config.
    pub explored: u64,
    /// Hysteresis-confirmed route flips — must be 0 likewise.
    pub route_flips: u64,
    /// The stressed service's final metrics report line.
    pub metrics_report: String,
}

/// One trace operation. `mat` indexes the fixture set (base fixtures
/// first, then mid-trace extras).
#[derive(Debug, Clone, Copy)]
enum TraceOp {
    Spmv { mat: usize, vseed: u64 },
    Spmm { mat: usize, k: usize, vseed: u64 },
    Solve { vseed: u64 },
    Register { extra: usize },
    Evict { mat: usize },
    /// Append a deterministic burst of coefficient updates (expanded
    /// from `batch_seed` by [`mutation_batch`]) to a mutable matrix.
    /// Only injected by [`inject_mutations`], never rolled by
    /// [`gen_trace`], so every `Append` sits at a trace index owned by
    /// the matrix's affinity thread.
    Append { mat: usize, batch_seed: u64 },
}

/// A recorded response, for bitwise comparison with the replay.
enum Response {
    /// One outcome per request of the op (1 for `Spmv`, `k` for `Spmm`).
    Vecs(Vec<VecOutcome>),
    /// CG iterate and residual history.
    Solve(Vec<f64>, Vec<f64>),
    /// The version an `Append` stamped.
    Version(u64),
    /// Op produced nothing to compare (`Register`, `Evict`, skipped).
    None,
}

/// Outcome of one multiply request within an op. Only `Ok` vectors are
/// replayed; `Shed` and `Expired` never executed (by contract) and are
/// tallied instead.
enum VecOutcome {
    /// The request completed; its output vector is replay-compared.
    Ok(Vec<f64>),
    /// Admission shed the request (`Overloaded`/`QueueClosed`).
    Shed,
    /// The dispatcher rejected an injected elapsed deadline
    /// (`DeadlineExceeded`).
    Expired,
}

fn gen_trace(rng: &mut Xoshiro256, ops: usize, n_total: usize, n_extra: usize) -> Vec<TraceOp> {
    let mut trace: Vec<TraceOp> = (0..ops)
        .map(|_| {
            let roll = rng.below(100);
            if roll < 55 {
                TraceOp::Spmv { mat: rng.below_usize(n_total), vseed: rng.next_u64() }
            } else if roll < 70 {
                TraceOp::Spmm {
                    mat: rng.below_usize(n_total),
                    k: 2 + rng.below_usize(4),
                    vseed: rng.next_u64(),
                }
            } else if roll < 80 {
                TraceOp::Solve { vseed: rng.next_u64() }
            } else {
                TraceOp::Evict { mat: rng.below_usize(n_total) }
            }
        })
        .collect();
    // Place each extra's registration once, in the first half of the
    // trace (linear-probing past slots already taken by a registration).
    for extra in 0..n_extra {
        let mut pos = rng.below_usize((ops / 2).max(1));
        while matches!(trace[pos], TraceOp::Register { .. }) {
            pos = (pos + 1) % ops;
        }
        trace[pos] = TraceOp::Register { extra };
    }
    trace
}

/// Expand an `Append` op's seed into its deterministic update burst
/// (1–4 coefficient deltas inside the matrix's dims). Both the stressed
/// run and the serial replay call this, so the burst is identical on
/// each side by construction.
fn mutation_batch(nrows: usize, ncols: usize, batch_seed: u64) -> Vec<(u32, u32, f64)> {
    let mut rng = Xoshiro256::seeded(batch_seed);
    let k = 1 + rng.below_usize(4);
    (0..k)
        .map(|_| {
            let r = rng.below(nrows as u64) as u32;
            let c = rng.below(ncols as u64) as u32;
            (r, c, rng.next_f64() * 4.0 - 2.0)
        })
        .collect()
}

/// Rewrite a deterministic subset of each mutable matrix's owning
/// thread's trace slots into append bursts and reads of that matrix.
///
/// Bit-identical replay of a mutated matrix needs its op order under
/// concurrency to equal the serial trace order. The closed loop gives
/// each thread its ops in index order (thread `t` executes indices
/// `t, t+threads, …`, waiting for each before the next), so confining
/// every op that touches mutable matrix `j` to the indices owned by
/// thread `j % threads` makes the per-matrix interleaving a function of
/// the trace alone — appends and reads replay in exactly that order on
/// the serial reference. [`gen_trace`] never rolls a mutable index
/// (its `n_total` excludes them), so this pass is the only source of
/// ops on them. `Register` slots are left alone (each extra must still
/// register exactly once); at least one `Append` per mutable matrix is
/// guaranteed.
fn inject_mutations(
    trace: &mut [TraceOp],
    rng: &mut Xoshiro256,
    threads: usize,
    n_rand: usize,
    n_mut: usize,
) {
    let threads = threads.max(1);
    for j in 0..n_mut {
        let mat = n_rand + j;
        let t = j % threads;
        let mut appended = false;
        let mut first_free = None;
        for idx in (t..trace.len()).step_by(threads) {
            if matches!(trace[idx], TraceOp::Register { .. }) {
                continue;
            }
            if first_free.is_none() {
                first_free = Some(idx);
            }
            let roll = rng.below(100);
            if roll < 20 {
                trace[idx] = TraceOp::Append { mat, batch_seed: rng.next_u64() };
                appended = true;
            } else if roll < 40 {
                trace[idx] = TraceOp::Spmv { mat, vseed: rng.next_u64() };
            }
        }
        if !appended {
            if let Some(idx) = first_free {
                trace[idx] = TraceOp::Append { mat, batch_seed: rng.next_u64() };
            }
        }
    }
}

fn solver_config() -> SolverConfig {
    SolverConfig { max_iters: 200, tol: 1e-8, par: ParStrategy::Serial }
}

/// The fixture set: the mixed service zoo, a few extras registered
/// mid-trace, two mutable matrices (append targets — placed *after* the
/// extras so [`gen_trace`]'s random indices never reach them; see
/// [`inject_mutations`]), and one SPD matrix for solves. Returns
/// `(fixtures, n_extra, n_mut, spd)`.
fn fixtures(seed: u64) -> (Vec<Csr>, usize, usize, Csr) {
    let mut base = zoo::mixed_zoo();
    let n_extra = 3;
    for i in 0..n_extra as u64 {
        let mut m = crate::matrix::gen::structured::banded(700 + 150 * i as usize, 2);
        crate::matrix::gen::assign_values(
            &mut m,
            crate::matrix::gen::ValueDist::FewDistinct(5),
            &mut Xoshiro256::seeded(seed ^ (0xE0 + i)),
        );
        base.push(m);
    }
    let n_mut = 2;
    for i in 0..n_mut as u64 {
        let mut m = crate::matrix::gen::structured::banded(260 + 90 * i as usize, 3);
        crate::matrix::gen::assign_values(
            &mut m,
            crate::matrix::gen::ValueDist::FewDistinct(4),
            &mut Xoshiro256::seeded(seed ^ (0xF0 + i)),
        );
        base.push(m);
    }
    (base, n_extra, n_mut, zoo::spd(24))
}

/// Run one stress cycle; see the [module docs](self) for the oracles.
/// Returns an error (with a descriptive message) on any violation:
/// a failed request, a replay mismatch, a leaked pin, or a metrics
/// imbalance.
pub fn run_stress(cfg: &StressConfig) -> Result<StressReport> {
    let cache_dir = std::env::temp_dir().join(format!(
        "dtans_testkit_stress_{}_{:x}",
        std::process::id(),
        cfg.seed
    ));
    let result = run_stress_inner(cfg, &cache_dir);
    let _ = std::fs::remove_dir_all(&cache_dir);
    result
}

fn run_stress_inner(cfg: &StressConfig, cache_dir: &Path) -> Result<StressReport> {
    let policy = RoutePolicy { min_nnz: 1 << 9, max_size_ratio: 0.95, ..Default::default() };
    let (all_fixtures, n_extra, n_mut, spd) = fixtures(cfg.seed);
    let n_total = all_fixtures.len();
    // Random trace ops index only the first `n_rand` fixtures; the
    // mutable tail is reached exclusively through [`inject_mutations`].
    let n_rand = n_total - n_mut;
    let n_base = n_rand - n_extra;

    let mut rng = Xoshiro256::seeded(cfg.seed);
    let mut trace = gen_trace(&mut rng, cfg.ops, n_rand, n_extra);
    // Mutation needs the closed loop's per-thread ordering; open-loop
    // fire-and-forget submits would unorder reads against appends.
    let mutate = cfg.mutate && !cfg.open_loop;
    if mutate {
        inject_mutations(&mut trace, &mut rng, cfg.threads, n_rand, n_mut);
    }

    // --- Stressed subject: budgeted, cached, parallel. ---
    let svc = Arc::new(SpmvService::start(ServiceConfig {
        workers: cfg.threads.min(8),
        policy,
        par: cfg.par,
        store: StoreConfig {
            cache_dir: Some(cache_dir.to_path_buf()),
            budget_bytes: cfg.budget_bytes,
            drop_csr: true,
            loader_threads: 2,
            // Low threshold so append bursts actually trigger background
            // compactions mid-traffic (bit-neutral, so oracle 1 holds).
            compact_overlay_nnz: mutate.then_some(8),
        },
        admission: AdmissionConfig { queue_depth: cfg.queue_depth, ..Default::default() },
        // Oracle 5: the adaptive router runs live (decides on every warm
        // singleton request) but with exploration off, so it is provably
        // bit-neutral and oracle 1's replay contract survives.
        adaptive: AdaptiveConfig::zero_exploration(),
        // Oracle 4 needs a lossless trace: sample everything, and size
        // the per-shard ring far above the worst-case event volume (≤ ~8
        // events per request, ≤ ~6 requests per op, one shard per thread).
        obs: ObsConfig { sample_one_in: 1, capacity: cfg.ops.max(8) * 64 },
        ..Default::default()
    }));
    // Base fixtures, the mutable tail and the SPD solve matrix register
    // up front; extras land mid-trace.
    let mut ids: Vec<Option<u64>> = vec![None; n_total];
    for (i, m) in all_fixtures.iter().take(n_base).enumerate() {
        ids[i] = Some(svc.register(&format!("base{i}"), m.clone())?);
    }
    for mat in n_rand..n_total {
        ids[mat] = Some(svc.register(&format!("mut{}", mat - n_rand), all_fixtures[mat].clone())?);
    }
    let spd_id = svc.register("spd", spd.clone())?;
    svc.store().flush(); // artifacts on disk -> base set evictable
    let ids = Arc::new(Mutex::new(ids));

    // --- Concurrent execution. ---
    let responses: Arc<Mutex<Vec<Option<std::result::Result<Response, String>>>>> =
        Arc::new(Mutex::new((0..trace.len()).map(|_| None).collect()));
    let trace = Arc::new(trace);
    let all_fixtures = Arc::new(all_fixtures);
    let spd_dims = (spd.nrows, spd.ncols);
    let stride = cfg.threads.max(1);
    let handles: Vec<_> = (0..cfg.threads)
        .map(|t| {
            let svc = Arc::clone(&svc);
            let trace = Arc::clone(&trace);
            let responses = Arc::clone(&responses);
            let ids = Arc::clone(&ids);
            let all_fixtures = Arc::clone(&all_fixtures);
            let open_loop = cfg.open_loop;
            std::thread::spawn(move || {
                if open_loop {
                    // Phase 1: submit the whole slice without waiting —
                    // offered load is not gated on completions, so the
                    // bounded queue actually backpressures.
                    let mut inflight: Vec<(usize, InFlight)> = Vec::new();
                    for idx in (t..trace.len()).step_by(stride) {
                        let inf = submit_op(
                            &svc,
                            &ids,
                            &all_fixtures,
                            n_base,
                            spd_id,
                            spd_dims,
                            trace[idx],
                        );
                        inflight.push((idx, inf));
                    }
                    // Phase 2: collect, in submission order.
                    for (idx, inf) in inflight {
                        let r = match inf {
                            InFlight::Ready(r) => r,
                            InFlight::Waiting(waits) => resolve_waits(waits),
                        };
                        responses.lock().unwrap()[idx] = Some(r);
                    }
                } else {
                    for idx in (t..trace.len()).step_by(stride) {
                        let r = execute_op(
                            &svc,
                            &ids,
                            &all_fixtures,
                            n_base,
                            spd_id,
                            spd_dims,
                            trace[idx],
                        );
                        responses.lock().unwrap()[idx] = Some(r);
                    }
                }
            })
        })
        .collect();
    for h in handles {
        h.join().map_err(|_| DtansError::Service("stress worker panicked".into()))?;
    }
    svc.store().flush();

    // --- Oracle 3: zero leaked pins. ---
    let final_ids: Vec<u64> = {
        let g = ids.lock().unwrap();
        g.iter().flatten().copied().chain([spd_id]).collect()
    };
    for id in &final_ids {
        let pins = svc.store().pin_count(*id);
        if pins != 0 {
            return Err(DtansError::Service(format!("matrix {id} leaked {pins} pin(s)")));
        }
    }

    // --- Oracle 2: metrics conservation, no failures. Every submitted
    // request must be accounted for by exactly one of completed /
    // failed / shed (admission rejections) / expired (deadline
    // rejections at dispatch).
    let m = &svc.metrics;
    let (submitted, completed, failed, shed, expired) = (
        m.submitted.load(Ordering::Relaxed),
        m.completed.load(Ordering::Relaxed),
        m.failed.load(Ordering::Relaxed),
        m.shed.load(Ordering::Relaxed),
        m.expired.load(Ordering::Relaxed),
    );
    if completed + failed + shed + expired != submitted {
        return Err(DtansError::Service(format!(
            "metrics do not sum: submitted={submitted} completed={completed} \
             failed={failed} shed={shed} expired={expired}"
        )));
    }
    if failed != 0 {
        return Err(DtansError::Service(format!(
            "{failed} request(s) failed under stress: {}",
            m.report()
        )));
    }
    if !cfg.open_loop && (shed != 0 || expired != 0) {
        return Err(DtansError::Service(format!(
            "closed-loop run shed/expired requests (shed={shed} expired={expired}): {}",
            m.report()
        )));
    }

    // --- Oracle 4: span conservation. Every request was traced and the
    // collector was sized to lose nothing, so the drained span chains
    // must reconcile exactly with the counters checked above.
    let tracer = m.tracer();
    if tracer.dropped() != 0 {
        return Err(DtansError::Service(format!(
            "tracer dropped {} event(s); ring capacity is undersized for this trace",
            tracer.dropped()
        )));
    }
    let events = tracer.drain();
    // Per span id: (#Submitted events, #terminal events). Spans with no
    // Submitted event are the store's standalone cold-load spans, which
    // by design never terminate.
    let mut spans: std::collections::BTreeMap<u64, (u64, u64)> = std::collections::BTreeMap::new();
    let (mut t_completed, mut t_failed, mut t_shed, mut t_expired) = (0u64, 0u64, 0u64, 0u64);
    for e in &events {
        let entry = spans.entry(e.span.0).or_insert((0, 0));
        match e.stage {
            Stage::Submitted { .. } => entry.0 += 1,
            Stage::Completed { .. } => {
                entry.1 += 1;
                t_completed += 1;
            }
            Stage::Failed => {
                entry.1 += 1;
                t_failed += 1;
            }
            Stage::Shed => {
                entry.1 += 1;
                t_shed += 1;
            }
            Stage::Expired => {
                entry.1 += 1;
                t_expired += 1;
            }
            _ => {}
        }
    }
    let submitted_events: u64 = spans.values().map(|&(s, _)| s).sum();
    if submitted_events != submitted {
        return Err(DtansError::Service(format!(
            "span conservation: {submitted_events} Submitted event(s) for \
             {submitted} submitted request(s)"
        )));
    }
    for (span, &(subs, terms)) in &spans {
        let want_terms = u64::from(subs == 1);
        if subs > 1 || terms != want_terms {
            return Err(DtansError::Service(format!(
                "span {span}: {subs} Submitted and {terms} terminal event(s) \
                 (every request span must terminate exactly once)"
            )));
        }
    }
    if (t_completed, t_failed, t_shed, t_expired) != (completed, failed, shed, expired) {
        return Err(DtansError::Service(format!(
            "span terminals disagree with counters: spans say \
             completed={t_completed} failed={t_failed} shed={t_shed} expired={t_expired}, \
             counters say completed={completed} failed={failed} shed={shed} expired={expired}"
        )));
    }

    // --- Oracle 5: routing conservation. The adaptive router ran live
    // at zero exploration, so its counters must conserve, nothing may
    // have explored or flipped, the router's view must agree with the
    // exported metrics, and every format tag that actually executed
    // must be accounted for: an admissible arm of a still-routed
    // matrix, the registered format of some matrix (retired matrices
    // keep serving their registered route), or the overlay composite
    // of a mutated matrix.
    let rc = svc.adaptive().counters();
    if rc.explored + rc.exploited != rc.routed {
        return Err(DtansError::Service(format!("routing counters do not conserve: {rc:?}")));
    }
    let flip_trace = svc.adaptive().flips();
    if rc.explored != 0 || !flip_trace.is_empty() {
        return Err(DtansError::Service(format!(
            "zero-exploration run explored or flipped: {rc:?}, flips {flip_trace:?}"
        )));
    }
    let (m_routed, m_explored, m_flips) = (
        m.routed_requests.load(Ordering::Relaxed),
        m.explore_requests.load(Ordering::Relaxed),
        m.route_flips.load(Ordering::Relaxed),
    );
    if (m_routed, m_explored, m_flips) != (rc.routed, rc.explored, rc.flips)
        || m_flips != flip_trace.len() as u64
    {
        return Err(DtansError::Service(format!(
            "router counters disagree with metrics: router {rc:?}, metrics \
             routed={m_routed} explored={m_explored} flips={m_flips}"
        )));
    }
    let mut allowed_tags = svc.adaptive().admissible_tag_union();
    allowed_tags.push("overlay");
    for id in &final_ids {
        if let Some(choice) = svc.format_of(*id) {
            allowed_tags.push(choice.tag());
        }
    }
    for tag in svc.metrics.format_tags() {
        if !allowed_tags.contains(&tag) {
            return Err(DtansError::Service(format!(
                "format '{tag}' executed outside the admissible set {allowed_tags:?}"
            )));
        }
    }

    // --- Oracle 1: bit-identical serial replay on a reference service. ---
    let reference = SpmvService::start(ServiceConfig {
        workers: 1,
        policy,
        par: ParStrategy::Serial,
        ..Default::default()
    });
    let mut ref_ids = Vec::with_capacity(n_total);
    for (i, m) in all_fixtures.iter().enumerate() {
        ref_ids.push(reference.register(&format!("ref{i}"), m.clone())?);
    }
    let ref_spd = reference.register("refspd", spd.clone())?;

    let mut report = StressReport {
        ops_executed: trace.len(),
        spmv_checked: 0,
        spmm_checked: 0,
        solves_checked: 0,
        appends_checked: 0,
        compactions: m.compactions.load(Ordering::Relaxed),
        skipped: 0,
        shed: 0,
        expired: 0,
        evictions: m.evictions.load(Ordering::Relaxed),
        cold_loads: m.cold_loads.load(Ordering::Relaxed),
        routed: rc.routed,
        explored: rc.explored,
        route_flips: rc.flips,
        metrics_report: m.report(),
    };
    let responses = Arc::try_unwrap(responses)
        .map_err(|_| DtansError::Service("response buffer still shared".into()))?
        .into_inner()
        .unwrap();
    for (idx, (op, resp)) in trace.iter().zip(responses).enumerate() {
        let resp = resp
            .ok_or_else(|| DtansError::Service(format!("op {idx} never executed")))?
            .map_err(DtansError::Service)?;
        replay_and_compare(
            &reference,
            &ref_ids,
            ref_spd,
            &all_fixtures,
            spd_dims,
            idx,
            *op,
            resp,
            &mut report,
        )?;
    }
    // Cross-check: the shed/expired outcomes the threads observed must
    // agree exactly with the service's counters — a shed the caller saw
    // but the metrics missed (or vice versa) is an accounting leak.
    if report.shed as u64 != shed || report.expired as u64 != expired {
        return Err(DtansError::Service(format!(
            "observed outcomes disagree with counters: saw shed={} expired={}, \
             metrics say shed={shed} expired={expired}",
            report.shed, report.expired
        )));
    }
    // End-state probe: after the drain (and whatever background
    // compactions the stressed service ran), every mutable matrix must
    // sit at the reference's version and still serve the exact bits of
    // the never-compacted reference overlay.
    for mat in n_rand..n_total {
        let id = ids.lock().unwrap()[mat].expect("mutable fixtures register up front");
        let (got_v, want_v) =
            (svc.store().version_of(id), reference.store().version_of(ref_ids[mat]));
        if got_v != want_v {
            return Err(DtansError::Service(format!(
                "mutable matrix {mat}: stressed version {got_v:?} != reference {want_v:?}"
            )));
        }
        let probe = request_vector(all_fixtures[mat].ncols, cfg.seed ^ mat as u64);
        let got = svc.spmv(id, probe.clone())?;
        let want = reference.spmv(ref_ids[mat], probe)?;
        if got != want {
            return Err(DtansError::Service(format!(
                "mutable matrix {mat}: end-state SpMVM diverged from serial replay"
            )));
        }
    }
    Ok(report)
}

/// A thread's record of one submitted-but-not-yet-collected op.
enum InFlight {
    /// The op resolved at submit time (synchronous op, skip, or error).
    Ready(std::result::Result<Response, String>),
    /// Multiply requests still waiting on their [`Pending`] handles.
    Waiting(Vec<VecWait>),
}

/// One request of an in-flight op.
enum VecWait {
    /// Admitted: wait on the handle. `expect_expired` marks an injected
    /// elapsed deadline, which the dispatcher *must* reject.
    Handle { p: Pending, expect_expired: bool },
    /// Already resolved at submit time (shed).
    Done(VecOutcome),
}

/// Open-loop submit of one op: multiplies are submitted without waiting
/// (sheds recorded inline); solves, registrations and evictions run
/// synchronously exactly as in the closed loop.
fn submit_op(
    svc: &SpmvService,
    ids: &Mutex<Vec<Option<u64>>>,
    fixtures: &[Csr],
    n_base: usize,
    spd_id: u64,
    spd_dims: (usize, usize),
    op: TraceOp,
) -> InFlight {
    let lookup = |mat: usize| ids.lock().unwrap()[mat];
    let shed_or_err = |e: DtansError| match e {
        DtansError::Overloaded { .. } | DtansError::QueueClosed => {
            Ok(VecWait::Done(VecOutcome::Shed))
        }
        other => Err(other.to_string()),
    };
    match op {
        TraceOp::Spmv { mat, vseed } => match lookup(mat) {
            Some(id) => {
                let x = request_vector(fixtures[mat].ncols, vseed);
                // Deterministic deadline injection: a seed-selected
                // subset carries a deadline of "now", which is already
                // elapsed by the time the dispatcher reads its clock —
                // so the expiry path is exercised without any sleeps.
                let expect_expired = vseed % 16 == 0;
                let opts = SubmitOptions {
                    deadline: expect_expired.then(std::time::Instant::now),
                    ..Default::default()
                };
                match svc.submit_with(id, x, opts) {
                    Ok(p) => InFlight::Waiting(vec![VecWait::Handle { p, expect_expired }]),
                    Err(e) => match shed_or_err(e) {
                        Ok(done) => InFlight::Waiting(vec![done]),
                        Err(msg) => InFlight::Ready(Err(msg)),
                    },
                }
            }
            None => InFlight::Ready(Ok(Response::None)),
        },
        TraceOp::Spmm { mat, k, vseed } => match lookup(mat) {
            Some(id) => {
                let mut waits = Vec::with_capacity(k);
                for j in 0..k {
                    let x = request_vector(fixtures[mat].ncols, vseed ^ j as u64);
                    match svc.submit(id, x) {
                        Ok(p) => waits.push(VecWait::Handle { p, expect_expired: false }),
                        Err(e) => match shed_or_err(e) {
                            Ok(done) => waits.push(done),
                            Err(msg) => return InFlight::Ready(Err(msg)),
                        },
                    }
                }
                InFlight::Waiting(waits)
            }
            None => InFlight::Ready(Ok(Response::None)),
        },
        TraceOp::Solve { .. }
        | TraceOp::Register { .. }
        | TraceOp::Evict { .. }
        | TraceOp::Append { .. } => {
            InFlight::Ready(execute_op(svc, ids, fixtures, n_base, spd_id, spd_dims, op))
        }
    }
}

/// Collect an open-loop op's handles into outcomes, enforcing the
/// deadline contract: an injected elapsed deadline must come back as
/// `DeadlineExceeded` — if it executed, the single-expiry-point rule is
/// broken and the run fails.
fn resolve_waits(waits: Vec<VecWait>) -> std::result::Result<Response, String> {
    let mut outs = Vec::with_capacity(waits.len());
    for w in waits {
        match w {
            VecWait::Done(o) => outs.push(o),
            VecWait::Handle { p, expect_expired } => match p.wait() {
                Ok(y) => {
                    if expect_expired {
                        return Err(
                            "deadline contract violated: elapsed-deadline request executed".into()
                        );
                    }
                    outs.push(VecOutcome::Ok(y));
                }
                Err(DtansError::DeadlineExceeded) if expect_expired => {
                    outs.push(VecOutcome::Expired);
                }
                Err(e) => return Err(e.to_string()),
            },
        }
    }
    Ok(Response::Vecs(outs))
}

/// Execute one op on the stressed service. Errors come back as strings
/// (the caller turns any into a run failure).
fn execute_op(
    svc: &SpmvService,
    ids: &Mutex<Vec<Option<u64>>>,
    fixtures: &[Csr],
    n_base: usize,
    spd_id: u64,
    spd_dims: (usize, usize),
    op: TraceOp,
) -> std::result::Result<Response, String> {
    let lookup = |mat: usize| ids.lock().unwrap()[mat];
    let fail = |e: DtansError| e.to_string();
    match op {
        TraceOp::Spmv { mat, vseed } => match lookup(mat) {
            Some(id) => {
                let x = request_vector(fixtures[mat].ncols, vseed);
                let y = svc.spmv(id, x).map_err(fail)?;
                Ok(Response::Vecs(vec![VecOutcome::Ok(y)]))
            }
            None => Ok(Response::None), // extra not registered yet
        },
        TraceOp::Spmm { mat, k, vseed } => match lookup(mat) {
            Some(id) => {
                // Submit the burst together so the dispatcher can pack it
                // into one SpMM batch. Closed-loop runs use a queue depth
                // far above the possible in-flight count, so admission
                // never sheds here — any submit error is a run failure.
                let pendings = (0..k)
                    .map(|j| {
                        let x = request_vector(fixtures[mat].ncols, vseed ^ j as u64);
                        svc.submit(id, x)
                    })
                    .collect::<Result<Vec<_>>>()
                    .map_err(fail)?;
                let mut ys = Vec::with_capacity(k);
                for p in pendings {
                    ys.push(VecOutcome::Ok(p.wait().map_err(fail)?));
                }
                Ok(Response::Vecs(ys))
            }
            None => Ok(Response::None),
        },
        TraceOp::Solve { vseed } => {
            let b = request_vector(spd_dims.0, vseed);
            let sol =
                svc.solve(spd_id, SolveMethod::Cg, &b, &solver_config()).map_err(fail)?;
            Ok(Response::Solve(sol.x, sol.report.residuals))
        }
        TraceOp::Register { extra } => {
            let mat = n_base + extra;
            let mut g = ids.lock().unwrap();
            if g[mat].is_none() {
                drop(g);
                let id = svc
                    .register(&format!("extra{extra}"), fixtures[mat].clone())
                    .map_err(fail)?;
                ids.lock().unwrap()[mat] = Some(id);
            }
            Ok(Response::None)
        }
        TraceOp::Evict { mat } => {
            if let Some(id) = lookup(mat) {
                // May refuse (pinned / not yet persisted) — both fine.
                let _ = svc.store().evict(id);
            }
            Ok(Response::None)
        }
        TraceOp::Append { mat, batch_seed } => match lookup(mat) {
            Some(id) => {
                let updates =
                    mutation_batch(fixtures[mat].nrows, fixtures[mat].ncols, batch_seed);
                let version = svc.append(id, &updates).map_err(fail)?;
                Ok(Response::Version(version))
            }
            // Mutable fixtures register before the threads start.
            None => Err(format!("append target {mat} was never registered")),
        },
    }
}

/// Recompute one op on the serial reference service and compare bitwise.
#[allow(clippy::too_many_arguments)]
fn replay_and_compare(
    reference: &SpmvService,
    ref_ids: &[u64],
    ref_spd: u64,
    fixtures: &[Csr],
    spd_dims: (usize, usize),
    idx: usize,
    op: TraceOp,
    resp: Response,
    report: &mut StressReport,
) -> Result<()> {
    let mismatch = |what: &str| {
        Err(DtansError::Service(format!("op {idx} ({op:?}): {what} diverged from serial replay")))
    };
    match (op, resp) {
        (TraceOp::Spmv { mat, vseed }, Response::Vecs(got)) => {
            if got.len() != 1 {
                return mismatch("spmv response count");
            }
            match &got[0] {
                VecOutcome::Ok(y) => {
                    let x = request_vector(fixtures[mat].ncols, vseed);
                    let want = reference.spmv(ref_ids[mat], x)?;
                    if *y != want {
                        return mismatch("spmv response");
                    }
                    report.spmv_checked += 1;
                }
                // Shed/expired requests never executed; only the
                // admitted trace is replayed.
                VecOutcome::Shed => report.shed += 1,
                VecOutcome::Expired => report.expired += 1,
            }
        }
        (TraceOp::Spmm { mat, k, vseed }, Response::Vecs(got)) => {
            if got.len() != k {
                return mismatch("spmm burst size");
            }
            let mut compared = false;
            for (j, out) in got.iter().enumerate() {
                match out {
                    VecOutcome::Ok(y) => {
                        let x = request_vector(fixtures[mat].ncols, vseed ^ j as u64);
                        let want = reference.spmv(ref_ids[mat], x)?;
                        if *y != want {
                            return mismatch("spmm response");
                        }
                        compared = true;
                    }
                    VecOutcome::Shed => report.shed += 1,
                    // Deadlines are only injected on Spmv ops.
                    VecOutcome::Expired => return mismatch("unexpected spmm expiry"),
                }
            }
            if compared {
                report.spmm_checked += 1;
            }
        }
        (TraceOp::Solve { vseed }, Response::Solve(x, residuals)) => {
            let b = request_vector(spd_dims.0, vseed);
            let want = reference.solve(ref_spd, SolveMethod::Cg, &b, &solver_config())?;
            if x != want.x || residuals != want.report.residuals {
                return mismatch("solve");
            }
            report.solves_checked += 1;
        }
        (TraceOp::Append { mat, batch_seed }, Response::Version(got)) => {
            // Re-apply the burst at the same trace point. Per-matrix
            // thread affinity makes the stressed per-matrix order equal
            // the trace order, so the version stamps must agree — and
            // the reference overlay now carries the exact folded bits
            // every later read of this matrix is compared against.
            let updates =
                mutation_batch(fixtures[mat].nrows, fixtures[mat].ncols, batch_seed);
            let want = reference.append(ref_ids[mat], &updates)?;
            if got != want {
                return mismatch("append version stamp");
            }
            report.appends_checked += 1;
        }
        (TraceOp::Spmv { .. } | TraceOp::Spmm { .. }, Response::None) => report.skipped += 1,
        (TraceOp::Register { .. } | TraceOp::Evict { .. }, _) => {}
        (op, _) => {
            return Err(DtansError::Service(format!(
                "op {idx} ({op:?}) recorded a response of the wrong shape"
            )));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_is_deterministic_and_registers_each_extra_once() {
        let mut a = Xoshiro256::seeded(9);
        let mut b = Xoshiro256::seeded(9);
        let ta = gen_trace(&mut a, 300, 12, 3);
        let tb = gen_trace(&mut b, 300, 12, 3);
        assert_eq!(ta.len(), 300);
        for (x, y) in ta.iter().zip(&tb) {
            assert_eq!(format!("{x:?}"), format!("{y:?}"));
        }
        let mut extras: Vec<usize> = ta
            .iter()
            .filter_map(|op| match op {
                TraceOp::Register { extra } => Some(*extra),
                _ => None,
            })
            .collect();
        extras.sort_unstable();
        assert_eq!(extras, vec![0, 1, 2]);
        // The mix contains every op family.
        assert!(ta.iter().any(|o| matches!(o, TraceOp::Spmv { .. })));
        assert!(ta.iter().any(|o| matches!(o, TraceOp::Spmm { .. })));
        assert!(ta.iter().any(|o| matches!(o, TraceOp::Solve { .. })));
        assert!(ta.iter().any(|o| matches!(o, TraceOp::Evict { .. })));
    }

    #[test]
    fn mutation_injection_is_deterministic_and_thread_affine() {
        let threads = 3;
        let (n_rand, n_mut) = (12, 2);
        let mk = |seed: u64| {
            let mut rng = Xoshiro256::seeded(seed);
            let mut trace = gen_trace(&mut rng, 300, n_rand, 3);
            inject_mutations(&mut trace, &mut rng, threads, n_rand, n_mut);
            trace
        };
        let ta = mk(9);
        let tb = mk(9);
        for (x, y) in ta.iter().zip(&tb) {
            assert_eq!(format!("{x:?}"), format!("{y:?}"));
        }
        // Every op touching a mutable matrix sits at an index owned by
        // that matrix's affinity thread, each mutable matrix gets at
        // least one append, and the extras still register exactly once.
        let mut appends = vec![0usize; n_mut];
        for (idx, op) in ta.iter().enumerate() {
            let mat = match op {
                TraceOp::Spmv { mat, .. } | TraceOp::Spmm { mat, .. } | TraceOp::Evict { mat } => {
                    *mat
                }
                TraceOp::Append { mat, batch_seed } => {
                    assert!(*mat >= n_rand, "appends only target the mutable tail");
                    appends[*mat - n_rand] += 1;
                    assert!(!mutation_batch(40, 40, *batch_seed).is_empty());
                    *mat
                }
                TraceOp::Solve { .. } | TraceOp::Register { .. } => continue,
            };
            if mat >= n_rand {
                assert_eq!(idx % threads, (mat - n_rand) % threads, "op {idx} off-thread");
            }
        }
        assert!(appends.iter().all(|&n| n >= 1), "{appends:?}");
        let mut extras: Vec<usize> = ta
            .iter()
            .filter_map(|op| match op {
                TraceOp::Register { extra } => Some(*extra),
                _ => None,
            })
            .collect();
        extras.sort_unstable();
        assert_eq!(extras, vec![0, 1, 2]);
    }

    #[test]
    fn mutation_batches_stay_in_bounds() {
        for seed in 0..64 {
            let batch = mutation_batch(17, 23, seed);
            assert!((1..=4).contains(&batch.len()));
            for &(r, c, v) in &batch {
                assert!((r as usize) < 17 && (c as usize) < 23);
                assert!(v.is_finite());
            }
            assert_eq!(batch, mutation_batch(17, 23, seed));
        }
    }

    #[test]
    fn scale_configs_meet_the_acceptance_floor() {
        for scale in [TestkitScale::Small, TestkitScale::Medium, TestkitScale::Large] {
            let cfg = StressConfig::for_scale(scale);
            assert!(cfg.threads >= 4, "{scale:?}");
            assert!(cfg.ops >= 200, "{scale:?}");
            assert!(cfg.budget_bytes.is_some(), "{scale:?}");
            assert!(!cfg.open_loop, "{scale:?}");
            assert!(cfg.mutate, "{scale:?}: closed-loop presets exercise mutation");
            // Closed loop must never shed: depth far above the largest
            // possible in-flight count (threads × max SpMM burst).
            assert!(cfg.queue_depth >= cfg.threads * 8, "{scale:?}");
            let ol = StressConfig::open_loop_for_scale(scale);
            assert!(ol.open_loop, "{scale:?}");
            assert!(!ol.mutate, "{scale:?}: open loop cannot order appends");
            // Open loop must be able to shed: depth below the trace's
            // submit count.
            assert!(ol.queue_depth < ol.ops, "{scale:?}");
            assert_eq!((ol.threads, ol.ops, ol.seed), (cfg.threads, cfg.ops, cfg.seed));
        }
    }

    #[test]
    fn tiny_stress_run_passes_all_oracles() {
        // A miniature in-module smoke run; the full small-scale run lives
        // in tests/conformance.rs.
        let cfg = StressConfig {
            threads: 2,
            ops: 24,
            seed: 0xABCD,
            budget_bytes: Some(128 * 1024),
            par: ParStrategy::Auto,
            open_loop: false,
            queue_depth: 4096,
            mutate: true,
        };
        let report = run_stress(&cfg).unwrap();
        assert_eq!(report.ops_executed, 24);
        assert!(report.spmv_checked + report.spmm_checked + report.solves_checked > 0);
        // Injection guarantees at least one append per mutable matrix,
        // and every one must have replayed with a matching version.
        assert!(report.appends_checked >= 2, "{report:?}");
        assert_eq!((report.shed, report.expired), (0, 0));
        // Oracle 5 ran live: decisions were handed out, none explored.
        assert!(report.routed > 0, "{report:?}");
        assert_eq!((report.explored, report.route_flips), (0, 0));
    }

    #[test]
    fn tiny_open_loop_run_passes_all_oracles() {
        // Open-loop arrivals against a small queue: the oracles must
        // hold whether or not this machine's timing actually sheds, and
        // any injected elapsed deadline must come back Expired. The
        // full-size open-loop run lives in tests/admission.rs.
        let cfg = StressConfig {
            threads: 2,
            ops: 32,
            seed: 0xABCD,
            budget_bytes: Some(128 * 1024),
            par: ParStrategy::Auto,
            open_loop: true,
            queue_depth: 8,
            // `mutate: true` must be a no-op under open-loop arrivals.
            mutate: true,
        };
        let report = run_stress(&cfg).unwrap();
        assert_eq!(report.ops_executed, 32);
        assert!(report.spmv_checked + report.spmm_checked + report.solves_checked > 0);
        assert_eq!((report.appends_checked, report.compactions), (0, 0));
    }
}
