//! Test harness subsystem: differential conformance, deterministic fault
//! injection, and concurrency stress — the machinery that *proves* the
//! paper's correctness-critical claim instead of spot-checking it.
//!
//! dtANS is lossless entropy coding: every decode must be bit-exact
//! against the CSR ground truth, under every execution strategy, after
//! every eviction/cold-reload cycle, and in the face of damaged
//! artifacts. Before this module that verification logic was scattered
//! (ad-hoc helpers in `spmv::verify`, per-test corruption code, inline
//! fixtures); `testkit` centralizes it as a library reused by the
//! integration tests (`tests/conformance.rs`, `tests/fault_injection.rs`),
//! benches and examples:
//!
//! * [`oracle`] — the differential conformance engine: for any matrix it
//!   enumerates the [`FormatRegistry`](crate::spmv::FormatRegistry), runs
//!   every operator through the [`SpmvEngine`](crate::spmv::SpmvEngine)
//!   across serial and `Fixed(1..=N)` strategies, and checks two levels of
//!   agreement — **bit-identity** of every partitioned run against the
//!   format's own serial result, and closeness of every format against the
//!   serial CSR free-function ground truth — producing structured
//!   [`Mismatch`](oracle::Mismatch) reports (format tag, partition count,
//!   first divergent row, ULP distance).
//! * [`faults`] — deterministic byte corruption for serialized `.dtans`
//!   containers (bit flips, truncation, length-prefix inflation,
//!   cross-array length swaps, zeroed spans — all at seeded offsets), plus
//!   [`FailingDir`](faults::FailingDir), a cache-root shim that makes
//!   artifact writes/reads fail in controlled windows to drive the
//!   [`store`](crate::store) error paths.
//! * [`stress`] — a seeded concurrency-stress driver that hammers a
//!   budgeted [`SpmvService`](crate::coordinator::SpmvService) with a
//!   mixed trace (spmv, SpMM bursts, CG solves, registrations, evictions,
//!   and delta-append bursts on mutable matrices that trigger background
//!   overlay compactions mid-traffic) from many threads, then checks
//!   conservation oracles: every recorded response bit-identical to a
//!   serial replay on an unbudgeted, never-compacting reference service
//!   (append version stamps included), metrics counters summing, zero
//!   leaked pins.
//! * [`routing_sim`] — the deterministic routing simulator: an
//!   injected-clock, seeded-latency-oracle harness that replays
//!   stationary / drifting / bimodal-noisy latency regimes through the
//!   *real* [`AdaptiveRouter`](crate::coordinator::AdaptiveRouter) (no
//!   kernels, no threads, no sleeps) and reports convergence step, flip
//!   trace, and conservation counters — the stability proof behind
//!   `docs/ROUTING.md`.
//! * [`zoo`] — curated named fixtures: the pathological shapes (empty
//!   rows, a single dense row, 1×N, explicit zero values, duplicate-heavy
//!   COO input, slice-boundary sizes) that previously existed only inline
//!   in individual tests, plus the mixed service zoo shared with the
//!   store residency tests.
//!
//! The stress driver scales with the `TESTKIT_SCALE` environment knob
//! ([`TestkitScale`]): CI runs `small`, release soak runs set `medium` or
//! `large`. See `docs/TESTING.md` for the tier layout and the seed-repro
//! workflow.

pub mod faults;
pub mod oracle;
pub mod routing_sim;
pub mod stress;
pub mod zoo;

pub use oracle::{
    ConformanceReport, MiscombinedOperator, Mismatch, MismatchKind, OracleConfig,
    PerturbedOperator,
};
pub use routing_sim::{run_routing_sim, ArmProfile, LatencyOracle, Regime, SimConfig, SimOutcome};
pub use stress::{run_stress, StressConfig, StressReport};

/// Deterministic request/input vector: `n` values in `[-0.5, 0.5)` from
/// a seeded stream. The one generator both the conformance oracle and
/// the stress driver derive their multiply inputs from (so a recorded
/// stress response and its replay, or an oracle run and its re-run,
/// always see identical bits).
pub fn seeded_vector(n: usize, seed: u64) -> Vec<f64> {
    let mut rng = crate::util::rng::Xoshiro256::seeded(seed);
    (0..n).map(|_| rng.next_f64() - 0.5).collect()
}

/// Size knob for the stress driver (and any future scale-sensitive
/// harness), read from the `TESTKIT_SCALE` environment variable so one
/// test body serves both fast CI lanes and long soak runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum TestkitScale {
    /// CI scale: completes in seconds (the default).
    #[default]
    Small,
    /// Local soak: minutes.
    Medium,
    /// Release soak: tens of minutes.
    Large,
}

impl TestkitScale {
    /// Read `TESTKIT_SCALE` (`small` / `medium` / `large`,
    /// case-insensitive). Unset or unrecognized values fall back to
    /// [`TestkitScale::Small`] so a typo can never silently launch a soak
    /// run in CI.
    pub fn from_env() -> TestkitScale {
        match std::env::var("TESTKIT_SCALE") {
            Ok(v) => TestkitScale::parse(&v).unwrap_or(TestkitScale::Small),
            Err(_) => TestkitScale::Small,
        }
    }

    /// Parse a scale label.
    pub fn parse(s: &str) -> Option<TestkitScale> {
        match s.trim().to_ascii_lowercase().as_str() {
            "small" => Some(TestkitScale::Small),
            "medium" => Some(TestkitScale::Medium),
            "large" => Some(TestkitScale::Large),
            _ => None,
        }
    }

    /// Stable label (the accepted `TESTKIT_SCALE` value).
    pub fn label(&self) -> &'static str {
        match self {
            TestkitScale::Small => "small",
            TestkitScale::Medium => "medium",
            TestkitScale::Large => "large",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scale_parses_known_labels_and_rejects_noise() {
        assert_eq!(TestkitScale::parse("small"), Some(TestkitScale::Small));
        assert_eq!(TestkitScale::parse(" MEDIUM "), Some(TestkitScale::Medium));
        assert_eq!(TestkitScale::parse("large"), Some(TestkitScale::Large));
        assert_eq!(TestkitScale::parse("huge"), None);
        assert_eq!(TestkitScale::parse(""), None);
    }

    #[test]
    fn scale_labels_roundtrip() {
        for s in [TestkitScale::Small, TestkitScale::Medium, TestkitScale::Large] {
            assert_eq!(TestkitScale::parse(s.label()), Some(s));
        }
    }
}
