"""AOT pipeline: lower every (entry × bucket) jax function to HLO *text*
and write ``artifacts/<entry>_<bucket>.hlo.txt`` plus a manifest.

HLO text — NOT ``lowered.compile()`` or a serialized HloModuleProto — is
the interchange format: jax >= 0.5 emits protos with 64-bit instruction
ids that the xla crate's xla_extension 0.5.1 rejects (``proto.id() <=
INT_MAX``); the text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/README.md.

Run once via ``make artifacts``; python never executes on the Rust
request path.
"""

from __future__ import annotations

import argparse
import os

import jax

jax.config.update("jax_enable_x64", True)  # decoder state is int64

from jax._src.lib import xla_client as xc  # noqa: E402

from .model import BUCKETS, ENTRIES  # noqa: E402


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-reassigning path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def manifest_line(name: str, specs, out_shape) -> str:
    """`name|in=dtype:shape;...|out=f32:shape` — parsed by rust/src/runtime."""
    def fmt(s):
        dt = {"int32": "i32", "float32": "f32", "int64": "i64", "float64": "f64"}[str(s.dtype)]
        dims = "x".join(str(d) for d in s.shape)
        return f"{dt}:{dims}"

    ins = ";".join(fmt(s) for s in specs)
    return f"{name}|{ins}|f32:{out_shape}"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--outdir", default="../artifacts")
    ap.add_argument("--buckets", default=",".join(BUCKETS), help="comma-separated bucket names")
    ap.add_argument("--entries", default=",".join(ENTRIES), help="comma-separated entry names")
    args = ap.parse_args()
    os.makedirs(args.outdir, exist_ok=True)

    manifest = []
    for bname in args.buckets.split(","):
        bucket = BUCKETS[bname]
        for ename in args.entries.split(","):
            builder, spec_builder = ENTRIES[ename]
            fn = builder(bucket)
            specs = spec_builder(bucket)
            lowered = jax.jit(fn).lower(*specs)
            text = to_hlo_text(lowered)
            name = f"{ename}_{bname}"
            path = os.path.join(args.outdir, f"{name}.hlo.txt")
            with open(path, "w") as f:
                f.write(text)
            manifest.append(manifest_line(name, specs, bucket["nrows"]))
            print(f"wrote {path} ({len(text)} chars)")

    # Bucket metadata for the Rust runtime's padding logic.
    with open(os.path.join(args.outdir, "manifest.txt"), "w") as f:
        for line in manifest:
            f.write(line + "\n")
        for bname, b in BUCKETS.items():
            f.write(
                f"#bucket {bname} nrows={b['nrows']} ncols={b['ncols']} "
                f"nw={b['nw']} ne={b['ne']} nnz={b['nnz']} max_seg={b['max_seg']}\n"
            )
    print(f"wrote {os.path.join(args.outdir, 'manifest.txt')}")


if __name__ == "__main__":
    main()
