//! Iterative solvers over the format-agnostic [`SpmvOperator`] surface:
//! conjugate gradient ([`cg`]), BiCGStab ([`bicgstab`]), and power
//! iteration / PageRank ([`power_iteration`], [`pagerank`]).
//!
//! Repeated SpMVM inside an iterative solve is the workload where the
//! paper's compression pays twice: the matrix is re-read on **every**
//! iteration, so the encode cost and the
//! [`DecodePlan`](crate::spmv::csr_dtans::DecodePlan) build are amortized
//! across the whole solve, and the per-iteration win is the resident-byte
//! saving itself (SpMVM is bandwidth-bound). Solvers here are written
//! *once* against `&dyn SpmvOperator` and therefore run unchanged over
//! every registered format — CSR, COO, SELL, dense, CSR-dtANS — and over
//! every [`ParStrategy`]: the engine guarantees each format's results are
//! bit-identical across partition counts, so a solve's entire iterate
//! history is too (property-tested in `tests/solver_convergence.rs`).
//!
//! Iteration multiplies go through the fused [`SpmvEngine::run_axpby`]
//! (`y = α·A·x + β·y`), and all solver work vectors are allocated once
//! before the loop. For the row-oriented formats (CSR, SELL, dense) the
//! fused kernels make iterations fully allocation-free — no temporary
//! product vector, no zeroing pass; COO and CSR-dtANS run `run_axpby`
//! through a per-block temporary (the default
//! [`run_range_axpby`](crate::spmv::operator::SpmvOperator::run_range_axpby)),
//! trading one block-sized allocation for arithmetic identical to the
//! unfused compose.
//!
//! # Contracts and termination
//!
//! See `docs/SOLVERS.md` for the full contract table. In brief:
//!
//! * [`cg`] requires a **symmetric positive-definite** matrix; a
//!   non-SPD operator surfaces as [`Termination::Breakdown`]
//!   (`p·Ap ≤ 0`), not as a wrong answer.
//! * [`bicgstab`] requires only a square nonsingular matrix.
//! * [`power_iteration`] requires a dominant eigenvalue separated in
//!   modulus; [`pagerank`] requires a column-stochastic transition
//!   operator.
//! * Linear solves terminate on the **relative residual**
//!   `‖b − A·x‖₂ / ‖b‖₂ ≤ tol`; [`SolveReport::residuals`] records that
//!   quantity after every iteration, so histories are comparable across
//!   formats and partition counts.
//!
//! # Example
//!
//! ```
//! use dtans::matrix::gen::structured::stencil2d5;
//! use dtans::solver::{cg, SolverConfig};
//!
//! let a = stencil2d5(8, 8); // small SPD Poisson matrix
//! let b = vec![1.0; a.nrows];
//! let sol = cg(&a, &b, &SolverConfig::default()).unwrap();
//! assert!(sol.report.converged());
//! assert!(sol.report.final_residual() <= 1e-10);
//! ```
//!
//! [`SpmvOperator`]: crate::spmv::operator::SpmvOperator
//! [`SpmvEngine::run_axpby`]: crate::spmv::engine::SpmvEngine::run_axpby
//! [`ParStrategy`]: crate::spmv::engine::ParStrategy

pub mod bicgstab;
pub mod cg;
pub mod power;

pub use bicgstab::{bicgstab, bicgstab_with};
pub use cg::{cg, cg_with};
pub use power::{pagerank, pagerank_with, power_iteration, power_iteration_with, PowerSolution};

use crate::spmv::engine::ParStrategy;
use crate::spmv::operator::SpmvOperator;
use crate::util::error::{DtansError, Result};

/// Shared solver knobs. One config drives every solver in this module.
///
/// ```
/// use dtans::solver::SolverConfig;
/// use dtans::spmv::engine::ParStrategy;
/// let cfg = SolverConfig { tol: 1e-8, ..Default::default() };
/// assert_eq!(cfg.max_iters, 1000);
/// assert_eq!(cfg.par, ParStrategy::Auto);
/// ```
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SolverConfig {
    /// Iteration cap; reaching it without converging terminates the solve
    /// with [`Termination::MaxIters`].
    pub max_iters: usize,
    /// Convergence tolerance on the relative residual
    /// (`‖r‖₂ / ‖b‖₂` for linear solves; see each solver for its exact
    /// residual definition).
    pub tol: f64,
    /// Kernel-level parallelism of the engine the convenience entry
    /// points ([`cg`], [`bicgstab`], …) build. The `*_with` variants take
    /// an existing engine instead and ignore this field — as does
    /// [`SpmvService::solve`](crate::coordinator::service::SpmvService::solve),
    /// which always executes on the service's shared engine.
    pub par: ParStrategy,
}

impl Default for SolverConfig {
    fn default() -> Self {
        SolverConfig { max_iters: 1000, tol: 1e-10, par: ParStrategy::Auto }
    }
}

/// Which linear solver [`SpmvService::solve`] runs.
///
/// [`SpmvService::solve`]: crate::coordinator::service::SpmvService::solve
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SolveMethod {
    /// Conjugate gradient ([`cg`]) — SPD matrices.
    Cg,
    /// BiCGStab ([`bicgstab`]) — general square matrices.
    BiCgStab,
}

/// Why a solve stopped.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Termination {
    /// The residual reached [`SolverConfig::tol`].
    Converged,
    /// [`SolverConfig::max_iters`] iterations ran without convergence.
    MaxIters,
    /// A denominator the method divides by vanished (CG: `p·Ap ≤ 0`, i.e.
    /// the matrix is not SPD; BiCGStab: `ρ`, `r̂·v` or `t·t` hit zero;
    /// power iteration: the iterate fell into the null space). The
    /// returned iterate is the best one before the breakdown.
    Breakdown,
}

/// What one solve did: how it terminated, its residual trajectory, and
/// wall time split by phase (SpMVM vs vector arithmetic).
#[derive(Debug, Clone)]
pub struct SolveReport {
    /// Why the solve stopped.
    pub termination: Termination,
    /// Iterations executed.
    pub iterations: usize,
    /// Convergence quantity at each residual-update point — the same
    /// number [`SolverConfig::tol`] is compared against. Its definition
    /// is per solver: CG records the relative recurrence residual
    /// `‖r‖₂/‖b‖₂` once per iteration; BiCGStab records it at both the
    /// half and the full step (up to `2·iterations` entries); power
    /// iteration records the eigenpair residual `‖A·x − λ·x‖₂/|λ|`;
    /// PageRank records the **absolute L1 change** `‖x' − x‖₁` per step.
    /// Empty only on a breakdown before the first residual update.
    pub residuals: Vec<f64>,
    /// Seconds spent inside SpMVM (`run_axpby`) calls.
    pub spmv_secs: f64,
    /// Seconds spent in dots, axpys and norms.
    pub vector_secs: f64,
    /// Whole-solve wall seconds.
    pub total_secs: f64,
}

impl SolveReport {
    /// True when the solve terminated with [`Termination::Converged`].
    pub fn converged(&self) -> bool {
        self.termination == Termination::Converged
    }

    /// The last recorded relative residual (`INFINITY` if none was — a
    /// breakdown before the first iteration completed).
    pub fn final_residual(&self) -> f64 {
        self.residuals.last().copied().unwrap_or(f64::INFINITY)
    }
}

/// A linear solve's answer: the iterate and its [`SolveReport`].
#[derive(Debug, Clone)]
pub struct Solution {
    /// The final iterate `x`.
    pub x: Vec<f64>,
    /// Termination, residual history, phase timings.
    pub report: SolveReport,
}

/// Serial dot product — deliberately a plain sequential loop so solver
/// scalar updates are deterministic regardless of the engine's
/// [`ParStrategy`] (the SpMVM side is bit-stable per format already).
pub(crate) fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0;
    for (x, y) in a.iter().zip(b) {
        acc += x * y;
    }
    acc
}

/// Euclidean norm via [`dot`].
pub(crate) fn norm2(a: &[f64]) -> f64 {
    dot(a, a).sqrt()
}

/// Common argument validation for the linear solvers: the operator must be
/// square and `b` must match its dimension. Returns `n`.
pub(crate) fn check_square(op: &dyn SpmvOperator, blen: usize) -> Result<usize> {
    let (nrows, ncols) = op.dims();
    if nrows != ncols {
        return Err(DtansError::Dimension(format!(
            "iterative solver needs a square matrix, got {nrows}x{ncols}"
        )));
    }
    if blen != nrows {
        return Err(DtansError::Dimension(format!(
            "matrix {nrows}x{ncols} with b[{blen}]"
        )));
    }
    Ok(nrows)
}

/// Validate an optional initial guess and materialize the starting
/// iterate (zeros when absent).
pub(crate) fn initial_x(n: usize, x0: Option<&[f64]>) -> Result<Vec<f64>> {
    match x0 {
        None => Ok(vec![0.0; n]),
        Some(v) if v.len() == n => Ok(v.to_vec()),
        Some(v) => Err(DtansError::Dimension(format!(
            "initial guess x0[{}] for dimension {n}",
            v.len()
        ))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::gen::structured::tridiagonal;

    #[test]
    fn check_square_rejects_bad_shapes() {
        let m = crate::matrix::csr::Csr::new(3, 4);
        assert!(check_square(&m, 3).is_err());
        let sq = tridiagonal(5);
        assert!(check_square(&sq, 4).is_err());
        assert_eq!(check_square(&sq, 5).unwrap(), 5);
    }

    #[test]
    fn initial_guess_is_validated() {
        assert_eq!(initial_x(3, None).unwrap(), vec![0.0; 3]);
        assert_eq!(initial_x(2, Some(&[1.0, 2.0])).unwrap(), vec![1.0, 2.0]);
        assert!(initial_x(2, Some(&[1.0])).is_err());
    }

    #[test]
    fn report_helpers() {
        let r = SolveReport {
            termination: Termination::Converged,
            iterations: 3,
            residuals: vec![0.5, 0.1, 1e-12],
            spmv_secs: 0.0,
            vector_secs: 0.0,
            total_secs: 0.0,
        };
        assert!(r.converged());
        assert_eq!(r.final_residual(), 1e-12);
        let empty = SolveReport { residuals: vec![], termination: Termination::Breakdown, ..r };
        assert!(!empty.converged());
        assert!(empty.final_residual().is_infinite());
    }
}
