//! Curated matrix fixtures for the test harness.
//!
//! Two families:
//!
//! * [`pathological`] — named degenerate shapes (empty matrix, empty
//!   rows, a single dense row, 1×N / N×1 vectors, explicit zero values,
//!   duplicate-heavy COO input, slice-boundary sizes). These used to
//!   exist only inline in individual tests; every one of them has broken a
//!   sparse kernel somewhere in the wild, so the conformance oracle sweeps
//!   all of them (`tests/conformance.rs`).
//! * [`mixed_zoo`] — the service-scale mixed workload (banded and
//!   power-law structures, compressible and incompressible values) shared
//!   by the store residency tests and the stress driver, so both router
//!   outcomes (CSR and CSR-dtANS) are exercised under one roof.

use crate::matrix::coo::Coo;
use crate::matrix::csr::Csr;
use crate::matrix::gen::structured::{banded, powerlaw_rows, stencil2d5};
use crate::matrix::gen::{assign_values, ValueDist};
use crate::util::rng::Xoshiro256;

/// One named fixture.
pub struct Fixture {
    /// Stable name for failure messages.
    pub name: &'static str,
    /// The matrix.
    pub csr: Csr,
}

fn fixture(name: &'static str, csr: Csr) -> Fixture {
    Fixture { name, csr }
}

/// The pathological shapes. Deterministic; every entry passes
/// [`Csr::validate`].
///
/// ```
/// let zoo = dtans::testkit::zoo::pathological();
/// assert!(zoo.len() >= 10);
/// for f in &zoo {
///     f.csr.validate().unwrap();
/// }
/// ```
pub fn pathological() -> Vec<Fixture> {
    let mut rng = Xoshiro256::seeded(0x200);

    // Degenerate shapes first.
    let mut out = vec![
        fixture("empty-0x0", Csr::new(0, 0)),
        fixture("all-rows-empty", Csr::new(6, 6)),
    ];

    // Mostly-empty rows: only every 7th row stores anything.
    let mut coo = Coo::new(64, 64);
    for r in (0..64).step_by(7) {
        for j in 0..3u32 {
            coo.push(r as u32, (r as u32 + j * 11) % 64, rng.next_gaussian());
        }
    }
    out.push(fixture("empty-rows", Csr::from_coo(&coo)));

    // One fully dense row in an otherwise empty matrix: the worst case
    // for row-count-based partitioning (all cost in one unit).
    let mut coo = Coo::new(48, 48);
    for c in 0..48 {
        coo.push(20, c, (c as f64 * 0.3).sin());
    }
    out.push(fixture("single-dense-row", Csr::from_coo(&coo)));

    // 1×N and N×1 vectors.
    let mut coo = Coo::new(1, 128);
    for c in (0..128).step_by(3) {
        coo.push(0, c, rng.next_f64() - 0.5);
    }
    out.push(fixture("row-vector-1xN", Csr::from_coo(&coo)));
    let mut coo = Coo::new(128, 1);
    for r in (0..128).step_by(2) {
        coo.push(r, 0, rng.next_f64() - 0.5);
    }
    out.push(fixture("col-vector-Nx1", Csr::from_coo(&coo)));

    // Explicitly stored zero values: nnz > 0 but every product is 0.
    let mut m = banded(40, 2);
    for v in &mut m.vals {
        *v = 0.0;
    }
    out.push(fixture("all-zero-values", m));

    // Duplicate-heavy COO input: every position pushed 4 times (summed by
    // `Csr::from_coo`), including exact-cancellation pairs that leave
    // explicit zeros behind.
    let mut coo = Coo::new(32, 32);
    for i in 0..64u32 {
        let (r, c) = (i % 32, (i * 7) % 32);
        for _ in 0..4 {
            coo.push(r, c, 0.25 * (1 + i % 3) as f64);
        }
    }
    coo.push(5, 9, 1.5);
    coo.push(5, 9, -1.5); // cancels to an explicit stored zero
    out.push(fixture("duplicate-heavy-coo", Csr::from_coo(&coo)));

    // Sizes straddling the 32-row warp-slice boundary.
    out.push(fixture("slice-boundary-31", banded(31, 1)));
    out.push(fixture("slice-boundary-32", banded(32, 1)));
    out.push(fixture("slice-boundary-33", banded(33, 1)));

    // Skewed aspect ratios.
    let mut coo = Coo::new(300, 4);
    for r in 0..300u32 {
        coo.push(r, r % 4, rng.next_gaussian());
    }
    out.push(fixture("tall-thin-300x4", Csr::from_coo(&coo)));
    let mut coo = Coo::new(4, 300);
    for c in 0..300u32 {
        coo.push(c % 4, c, rng.next_gaussian());
    }
    out.push(fixture("wide-flat-4x300", Csr::from_coo(&coo)));

    // One heavy head row over a trailing diagonal: partition skew.
    let mut coo = Coo::new(80, 80);
    for c in 0..64u32 {
        coo.push(0, c, 1.0 + (c % 5) as f64);
    }
    for r in 1..80u32 {
        coo.push(r, r, -1.0);
    }
    out.push(fixture("heavy-head-row", Csr::from_coo(&coo)));

    for f in &out {
        debug_assert!(f.csr.validate().is_ok(), "{} invalid", f.name);
    }
    out
}

/// A mixed zoo of ≥ 8 service-scale matrices: banded and power-law,
/// compressible and not, so both router outcomes (CSR and CSR-dtANS) are
/// exercised. This is the fixture set behind
/// `tests/store_residency.rs` and the [`stress`](crate::testkit::stress)
/// driver.
pub fn mixed_zoo() -> Vec<Csr> {
    let mut out = Vec::new();
    for i in 0..5u64 {
        let mut m = banded(500 + 200 * i as usize, 2 + (i as usize % 3));
        assign_values(&mut m, ValueDist::FewDistinct(4 + i as usize), &mut Xoshiro256::seeded(i));
        out.push(m);
    }
    for i in 0..4u64 {
        let mut rng = Xoshiro256::seeded(100 + i);
        let mut m = powerlaw_rows(400 + 100 * i as usize, 5.0, 1.2, &mut rng);
        // Random values resist compression -> some matrices stay CSR.
        let dist = if i % 2 == 0 { ValueDist::Random } else { ValueDist::Quantized(16) };
        assign_values(&mut m, dist, &mut rng);
        out.push(m);
    }
    out
}

/// A symmetric positive-definite fixture (2D Poisson stencil on a
/// `side × side` grid) for CG-based stress and solver tests.
pub fn spd(side: usize) -> Csr {
    stencil2d5(side, side)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pathological_fixtures_are_valid_and_distinctly_named() {
        let zoo = pathological();
        assert!(zoo.len() >= 10);
        let mut names: Vec<_> = zoo.iter().map(|f| f.name).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), zoo.len(), "duplicate fixture names");
        for f in &zoo {
            f.csr.validate().unwrap_or_else(|e| panic!("{}: {e}", f.name));
        }
    }

    #[test]
    fn pathological_covers_the_advertised_shapes() {
        let zoo = pathological();
        let get = |name: &str| {
            &zoo.iter().find(|f| f.name == name).unwrap_or_else(|| panic!("missing {name}")).csr
        };
        assert_eq!(get("empty-0x0").nrows, 0);
        assert_eq!(get("all-rows-empty").nnz(), 0);
        let dense = get("single-dense-row");
        assert_eq!(dense.max_row_len(), dense.ncols);
        assert_eq!(get("row-vector-1xN").nrows, 1);
        assert_eq!(get("col-vector-Nx1").ncols, 1);
        let zeroes = get("all-zero-values");
        assert!(zeroes.nnz() > 0 && zeroes.vals.iter().all(|&v| v == 0.0));
        // Cancellation left an explicit stored zero behind.
        assert!(get("duplicate-heavy-coo").vals.iter().any(|&v| v == 0.0));
    }

    #[test]
    fn mixed_zoo_is_deterministic_and_sized() {
        let a = mixed_zoo();
        let b = mixed_zoo();
        assert!(a.len() >= 8);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x, y);
        }
    }

    #[test]
    fn spd_fixture_is_symmetric() {
        assert!(spd(8).is_symmetric());
    }
}
