//! Matrix generators for the evaluation corpus and the Fig. 4 experiment:
//! random graph models (Erdős–Rényi, Watts–Strogatz, Barabási–Albert),
//! structured patterns (banded, stencils, blocks, power-law rows), and
//! value distributions.

pub mod graphs;
pub mod structured;
pub mod values;

pub use graphs::{gen_graph_csr, GraphModel};
pub use structured::*;
pub use values::{assign_values, ValueDist};
