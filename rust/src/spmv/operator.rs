//! The format-agnostic kernel surface: one object-safe trait,
//! [`SpmvOperator`], that every sparse format implements — and the only
//! interface the engine, router, store and service compile against.
//!
//! The paper frames entropy-coded CSR (dtANS) as one more *format*
//! competing against CSR/COO/SELL, and its related work (CMRS, adaptive
//! row-grouped CSR) shows the format zoo keeps growing. Before this
//! module, every format was a separate hard-coded path through the engine,
//! router, store and service; adding a format meant editing six modules.
//! Now a format plugs in by implementing this trait (and optionally
//! registering in the [`FormatRegistry`] so eval and benches pick it up).
//!
//! # Trait contract
//!
//! An operator is a matrix in some storage format, viewed as a collection
//! of contiguous *work units* (rows for CSR/dense, 32-row slices for
//! SELL/CSR-dtANS, σ-row sort windows for BlockedEll, one indivisible
//! unit for COO's unordered scatter):
//!
//! * [`cost_prefix`](SpmvOperator::cost_prefix) returns a monotone
//!   non-decreasing prefix over the units (`prefix[i+1] - prefix[i]` =
//!   cost of unit `i`, length = units + 1, always ≥ 1). The engine feeds
//!   it to [`partition_prefix`](crate::spmv::engine::partition_prefix) to
//!   get equal-cost [`Block`]s — the CPU analog of the paper's
//!   equal-nonzeros warp assignment.
//! * [`rows_through`](SpmvOperator::rows_through) maps a unit boundary to
//!   its exclusive end *row*, so the engine can hand each block a disjoint
//!   `&mut` segment of the output vector.
//! * [`run_range`](SpmvOperator::run_range) computes one block with the
//!   serial kernel's per-row arithmetic, accumulating into its segment
//!   (`y_seg[i] += …`). Because every row is computed by exactly one block
//!   and blocks reuse the serial loops, the engine's parallel results are
//!   **bit-identical** to the serial free functions — property-tested for
//!   all six built-in formats in `tests/operator_dispatch.rs`.
//! * [`run_range_multi`](SpmvOperator::run_range_multi) is the batched
//!   (multi-right-hand-side) variant over contiguous
//!   [`DenseMat`]/[`DenseMatMut`] views; the default implementation loops
//!   [`run_range`](SpmvOperator::run_range) over columns, which keeps
//!   bit-identity with repeated single-vector multiplies by construction.
//!
//! # Example
//!
//! ```
//! use dtans::matrix::{Coo, Csr};
//! use dtans::spmv::engine::SpmvEngine;
//! use dtans::spmv::operator::SpmvOperator;
//!
//! let mut coo = Coo::new(2, 2);
//! coo.push(0, 0, 2.0);
//! coo.push(1, 1, 3.0);
//! let m = Csr::from_coo(&coo); // Csr implements SpmvOperator directly
//! assert_eq!((m.format_tag(), SpmvOperator::nnz(&m)), ("csr", 2));
//! let mut y = vec![0.0; 2];
//! SpmvEngine::auto().run(&m, &[1.0, 1.0], &mut y).unwrap();
//! assert_eq!(y, vec![2.0, 3.0]);
//! ```

use crate::format::csr_dtans::{CsrDtans, EncodeOptions, WARP};
use crate::matrix::coo::Coo;
use crate::matrix::csr::Csr;
use crate::matrix::sell::Sell;
use crate::matrix::blocked_ell::BlockedEll;
use crate::spmv::csr_dtans::DecodePlan;
use crate::spmv::densemat::{DenseMat, DenseMatMut};
use crate::spmv::engine::{Block, KernelVariant};
use crate::util::error::{DtansError, Result};
use std::borrow::Cow;
use std::sync::Arc;

/// Object-safe, format-agnostic SpMVM kernel surface. See the
/// [module docs](self) for the work-unit/cost/row contract and
/// `docs/API.md` for the full trait reference and migration table.
///
/// `Send + Sync` is part of the trait: operators are shared across the
/// service's worker threads as `Arc<dyn SpmvOperator>`.
pub trait SpmvOperator: Send + Sync {
    /// Logical shape `(nrows, ncols)`.
    fn dims(&self) -> (usize, usize);

    /// Number of stored nonzeros (for COO this counts stored triplets,
    /// duplicates included).
    fn nnz(&self) -> usize;

    /// Monotone cost prefix over this operator's work units (length =
    /// units + 1, never empty). The engine partitions it into equal-cost
    /// blocks.
    fn cost_prefix(&self) -> Cow<'_, [usize]>;

    /// Total work-cost driving the
    /// [`ParStrategy::Auto`](crate::spmv::engine::ParStrategy::Auto)
    /// serial/parallel decision (compared against
    /// [`MIN_PAR_COST`](crate::spmv::engine::MIN_PAR_COST), which is
    /// calibrated in *nonzeros*). Defaults to the cost-prefix total;
    /// override when the prefix is in different units — CSR-dtANS's
    /// prefix counts compressed stream words, so it reports `nnz` here
    /// to keep the crossover where the uncompressed formats have it.
    fn cost(&self) -> usize {
        let prefix = self.cost_prefix();
        match prefix.len() {
            0 | 1 => 0,
            n => prefix[n - 1] - prefix[0],
        }
    }

    /// Exclusive end row of units `0..unit_end`. Defaults to the identity
    /// (one unit per row); sliced formats map slice counts to rows,
    /// clamped to `nrows` for the final partial slice.
    fn rows_through(&self, unit_end: usize) -> usize {
        unit_end
    }

    /// Compute one block: `y_seg[i] += (A·x)[rows_through(block.start) + i]`
    /// with the serial kernel's arithmetic. `y_seg` spans exactly rows
    /// `rows_through(block.start)..rows_through(block.end)`; `x` is the
    /// full input vector. Callers (the engine) have already checked
    /// `x.len() == ncols`.
    fn run_range(&self, block: Block, x: &[f64], y_seg: &mut [f64]) -> Result<()>;

    /// Fused scaled-update variant of [`run_range`](SpmvOperator::run_range):
    /// `y_seg[i] = alpha·(A·x)[row] + beta·y_seg[i]` over the block's rows —
    /// the per-block primitive behind
    /// [`SpmvEngine::run_axpby`](crate::spmv::engine::SpmvEngine::run_axpby),
    /// which iterative solvers ([`crate::solver`]) call every iteration.
    ///
    /// The default computes the block through
    /// [`run_range`](SpmvOperator::run_range) into a zeroed temporary and
    /// then applies `alpha·tmp + beta·y` elementwise. Overrides must stay
    /// **bit-identical** to that compose: the row-oriented formats
    /// (CSR, SELL, dense) fuse by keeping the per-row accumulator local and
    /// writing `alpha·acc + beta·y` directly — the exact same float
    /// operations, minus the temporary allocation. Formats whose kernels
    /// cannot expose a per-row accumulator (COO's unordered scatter, the
    /// dtANS lockstep decoder) keep the default.
    fn run_range_axpby(
        &self,
        block: Block,
        x: &[f64],
        alpha: f64,
        beta: f64,
        y_seg: &mut [f64],
    ) -> Result<()> {
        let mut tmp = vec![0.0; y_seg.len()];
        self.run_range(block, x, &mut tmp)?;
        for (y, t) in y_seg.iter_mut().zip(&tmp) {
            *y = alpha * t + beta * *y;
        }
        Ok(())
    }

    /// Batched variant of [`run_range`](SpmvOperator::run_range): for each
    /// column `j`, `ys[.., j] += (A·xs[.., j])` over the block's rows.
    /// `ys` spans exactly the block's rows; `xs` the full input columns.
    /// The default loops `run_range` per column — override only with an
    /// implementation that stays bit-identical to that loop.
    fn run_range_multi(&self, block: Block, xs: &DenseMat, ys: &mut DenseMatMut<'_>) -> Result<()> {
        debug_assert_eq!(xs.ncols(), ys.ncols());
        for j in 0..xs.ncols() {
            self.run_range(block, xs.col(j), ys.col_mut(j))?;
        }
        Ok(())
    }

    /// [`run_range`](SpmvOperator::run_range) under a selected
    /// [`KernelVariant`] — the engine's dispatch point for the unrolled
    /// wide-accumulator kernels (`docs/KERNELS.md`).
    ///
    /// The default ignores the variant and runs the scalar kernel, which
    /// is the honest behavior for formats without unrolled kernels (COO's
    /// scatter, the dtANS lockstep decoder, the dense oracle): every
    /// variant then trivially keeps the per-variant bit-identity
    /// contract. Overrides (CSR, SELL, BlockedEll) must dispatch to
    /// kernels whose per-row arithmetic depends only on the row — never
    /// on `block` boundaries — so that for a fixed variant, partitioned
    /// results stay bit-identical to that variant's serial run.
    fn run_range_variant(
        &self,
        block: Block,
        x: &[f64],
        y_seg: &mut [f64],
        variant: KernelVariant,
    ) -> Result<()> {
        let _ = variant;
        self.run_range(block, x, y_seg)
    }

    /// [`run_range_axpby`](SpmvOperator::run_range_axpby) under a selected
    /// [`KernelVariant`]; same default/override rules as
    /// [`run_range_variant`](SpmvOperator::run_range_variant). Overrides
    /// must keep the fused form bit-identical to the unfused compose
    /// *under the same variant*.
    fn run_range_axpby_variant(
        &self,
        block: Block,
        x: &[f64],
        alpha: f64,
        beta: f64,
        y_seg: &mut [f64],
        variant: KernelVariant,
    ) -> Result<()> {
        match variant {
            KernelVariant::Scalar => self.run_range_axpby(block, x, alpha, beta, y_seg),
            _ => {
                // Unfused compose through the variant kernel: bit-identity
                // with a fused override is the same argument as the
                // scalar default's.
                let mut tmp = vec![0.0; y_seg.len()];
                self.run_range_variant(block, x, &mut tmp, variant)?;
                for (y, t) in y_seg.iter_mut().zip(&tmp) {
                    *y = alpha * t + beta * *y;
                }
                Ok(())
            }
        }
    }

    /// [`run_range_multi`](SpmvOperator::run_range_multi) under a selected
    /// [`KernelVariant`]: the default loops
    /// [`run_range_variant`](SpmvOperator::run_range_variant) per column,
    /// keeping batched results bit-identical to repeated single-vector
    /// multiplies *of the same variant* by construction.
    fn run_range_multi_variant(
        &self,
        block: Block,
        xs: &DenseMat,
        ys: &mut DenseMatMut<'_>,
        variant: KernelVariant,
    ) -> Result<()> {
        match variant {
            KernelVariant::Scalar => self.run_range_multi(block, xs, ys),
            _ => {
                debug_assert_eq!(xs.ncols(), ys.ncols());
                for j in 0..xs.ncols() {
                    self.run_range_variant(block, xs.col(j), ys.col_mut(j), variant)?;
                }
                Ok(())
            }
        }
    }

    /// Heap bytes this operator pins while resident — its cost against the
    /// tiered store's memory budget ([`crate::store`]).
    fn resident_bytes(&self) -> usize;

    /// Stable short tag naming the format (`"csr"`, `"coo"`, `"sell"`,
    /// `"blocked_ell"`, `"dense"`, `"csr_dtans"`) — the key used by
    /// per-format metrics
    /// ([`crate::coordinator::metrics::Metrics`]) and the
    /// [`FormatRegistry`].
    fn format_tag(&self) -> &'static str;
}

// ---------------------------------------------------------------------------
// CSR
// ---------------------------------------------------------------------------

impl SpmvOperator for Csr {
    fn dims(&self) -> (usize, usize) {
        (self.nrows, self.ncols)
    }

    fn nnz(&self) -> usize {
        Csr::nnz(self)
    }

    /// Units = rows, cost = per-row nonzeros: `row_ptr` itself.
    fn cost_prefix(&self) -> Cow<'_, [usize]> {
        Cow::Borrowed(&self.row_ptr)
    }

    fn run_range(&self, block: Block, x: &[f64], y_seg: &mut [f64]) -> Result<()> {
        crate::spmv::csr::spmv_row_range(self, block.start, block.end, x, y_seg)
    }

    /// Allocation-free fused path (see the trait docs for the bit-identity
    /// argument).
    fn run_range_axpby(
        &self,
        block: Block,
        x: &[f64],
        alpha: f64,
        beta: f64,
        y_seg: &mut [f64],
    ) -> Result<()> {
        crate::spmv::csr::spmv_row_range_axpby(self, block.start, block.end, x, alpha, beta, y_seg)
    }

    /// Dispatch to the unrolled wide-accumulator row kernels
    /// ([`crate::spmv::unrolled`]); each row's lane assignment and combine
    /// tree depend only on the row's own element list, never on `block`,
    /// so per-variant partition bit-identity holds (`docs/KERNELS.md`).
    fn run_range_variant(
        &self,
        block: Block,
        x: &[f64],
        y_seg: &mut [f64],
        variant: KernelVariant,
    ) -> Result<()> {
        match variant {
            KernelVariant::Scalar => self.run_range(block, x, y_seg),
            KernelVariant::Unrolled4 => crate::spmv::unrolled::spmv_row_range_unrolled::<4>(
                self, block.start, block.end, x, y_seg,
            ),
            KernelVariant::Unrolled8 => crate::spmv::unrolled::spmv_row_range_unrolled::<8>(
                self, block.start, block.end, x, y_seg,
            ),
        }
    }

    /// Fused form of the unrolled kernels: same per-row accumulator and
    /// combine tree, with `alpha·acc + beta·y` written in place of `y += acc`.
    fn run_range_axpby_variant(
        &self,
        block: Block,
        x: &[f64],
        alpha: f64,
        beta: f64,
        y_seg: &mut [f64],
        variant: KernelVariant,
    ) -> Result<()> {
        match variant {
            KernelVariant::Scalar => self.run_range_axpby(block, x, alpha, beta, y_seg),
            KernelVariant::Unrolled4 => crate::spmv::unrolled::spmv_row_range_axpby_unrolled::<4>(
                self, block.start, block.end, x, alpha, beta, y_seg,
            ),
            KernelVariant::Unrolled8 => crate::spmv::unrolled::spmv_row_range_axpby_unrolled::<8>(
                self, block.start, block.end, x, alpha, beta, y_seg,
            ),
        }
    }

    fn resident_bytes(&self) -> usize {
        self.row_ptr.len() * 8 + self.cols.len() * 4 + self.vals.len() * 8
    }

    fn format_tag(&self) -> &'static str {
        "csr"
    }
}

// ---------------------------------------------------------------------------
// SELL
// ---------------------------------------------------------------------------

impl SpmvOperator for Sell {
    fn dims(&self) -> (usize, usize) {
        (self.nrows, self.ncols)
    }

    fn nnz(&self) -> usize {
        self.row_lens.iter().map(|&l| l as usize).sum()
    }

    /// Units = slices, cost = padded cells (`slice_ptr` deltas — padding
    /// is real work in the SELL kernel, so it is what must balance).
    fn cost_prefix(&self) -> Cow<'_, [usize]> {
        Cow::Borrowed(&self.slice_ptr)
    }

    fn rows_through(&self, unit_end: usize) -> usize {
        (unit_end * self.slice_height).min(self.nrows)
    }

    fn run_range(&self, block: Block, x: &[f64], y_seg: &mut [f64]) -> Result<()> {
        crate::spmv::sell::spmv_sell_slice_range(self, block.start, block.end, x, y_seg)
    }

    /// Allocation-free fused path (see the trait docs for the bit-identity
    /// argument).
    fn run_range_axpby(
        &self,
        block: Block,
        x: &[f64],
        alpha: f64,
        beta: f64,
        y_seg: &mut [f64],
    ) -> Result<()> {
        crate::spmv::sell::spmv_sell_slice_range_axpby(
            self, block.start, block.end, x, alpha, beta, y_seg,
        )
    }

    /// Dispatch to the unrolled SELL kernels ([`crate::spmv::unrolled`]):
    /// per-row lane assignment over the slice's padded width, fixed combine
    /// tree — block-independent, so per-variant partition bit-identity
    /// holds (`docs/KERNELS.md`).
    fn run_range_variant(
        &self,
        block: Block,
        x: &[f64],
        y_seg: &mut [f64],
        variant: KernelVariant,
    ) -> Result<()> {
        match variant {
            KernelVariant::Scalar => self.run_range(block, x, y_seg),
            KernelVariant::Unrolled4 => crate::spmv::unrolled::spmv_sell_slice_range_unrolled::<4>(
                self, block.start, block.end, x, y_seg,
            ),
            KernelVariant::Unrolled8 => crate::spmv::unrolled::spmv_sell_slice_range_unrolled::<8>(
                self, block.start, block.end, x, y_seg,
            ),
        }
    }

    /// Fused form of the unrolled SELL kernels; same accumulator order.
    fn run_range_axpby_variant(
        &self,
        block: Block,
        x: &[f64],
        alpha: f64,
        beta: f64,
        y_seg: &mut [f64],
        variant: KernelVariant,
    ) -> Result<()> {
        match variant {
            KernelVariant::Scalar => self.run_range_axpby(block, x, alpha, beta, y_seg),
            KernelVariant::Unrolled4 => {
                crate::spmv::unrolled::spmv_sell_slice_range_axpby_unrolled::<4>(
                    self, block.start, block.end, x, alpha, beta, y_seg,
                )
            }
            KernelVariant::Unrolled8 => {
                crate::spmv::unrolled::spmv_sell_slice_range_axpby_unrolled::<8>(
                    self, block.start, block.end, x, alpha, beta, y_seg,
                )
            }
        }
    }

    fn resident_bytes(&self) -> usize {
        self.slice_widths.len() * 4
            + self.slice_ptr.len() * 8
            + self.cols.len() * 4
            + self.vals.len() * 8
            + self.row_lens.len() * 4
    }

    fn format_tag(&self) -> &'static str {
        "sell"
    }
}

// ---------------------------------------------------------------------------
// BlockedEll
// ---------------------------------------------------------------------------

impl SpmvOperator for BlockedEll {
    fn dims(&self) -> (usize, usize) {
        (self.nrows, self.ncols)
    }

    fn nnz(&self) -> usize {
        self.row_lens.iter().map(|&l| l as usize).sum()
    }

    /// Units = σ-windows, cost = padded cells (`window_ptr` — padding is
    /// real kernel work, as for SELL). Windows, not blocks, are the units
    /// because the length sort permutes rows only *within* a window: a
    /// window range maps to a contiguous original-row range, which is
    /// what lets the engine hand out disjoint `&mut` output segments.
    fn cost_prefix(&self) -> Cow<'_, [usize]> {
        Cow::Borrowed(&self.window_ptr)
    }

    fn rows_through(&self, unit_end: usize) -> usize {
        (unit_end * self.sigma).min(self.nrows)
    }

    fn run_range(&self, block: Block, x: &[f64], y_seg: &mut [f64]) -> Result<()> {
        crate::spmv::blocked_ell::spmv_blocked_ell_window_range(
            self, block.start, block.end, x, y_seg,
        )
    }

    /// Allocation-free fused path (see the trait docs for the bit-identity
    /// argument).
    fn run_range_axpby(
        &self,
        block: Block,
        x: &[f64],
        alpha: f64,
        beta: f64,
        y_seg: &mut [f64],
    ) -> Result<()> {
        crate::spmv::blocked_ell::spmv_blocked_ell_window_range_axpby(
            self, block.start, block.end, x, alpha, beta, y_seg,
        )
    }

    /// Dispatch to the unrolled BlockedEll kernels
    /// ([`crate::spmv::blocked_ell`]): per-row lane assignment over the
    /// block's padded width, fixed combine tree — block-independent, so
    /// per-variant partition bit-identity holds (`docs/KERNELS.md`).
    fn run_range_variant(
        &self,
        block: Block,
        x: &[f64],
        y_seg: &mut [f64],
        variant: KernelVariant,
    ) -> Result<()> {
        match variant {
            KernelVariant::Scalar => self.run_range(block, x, y_seg),
            KernelVariant::Unrolled4 => {
                crate::spmv::blocked_ell::spmv_blocked_ell_window_range_unrolled::<4>(
                    self, block.start, block.end, x, y_seg,
                )
            }
            KernelVariant::Unrolled8 => {
                crate::spmv::blocked_ell::spmv_blocked_ell_window_range_unrolled::<8>(
                    self, block.start, block.end, x, y_seg,
                )
            }
        }
    }

    /// Fused form of the unrolled BlockedEll kernels; same accumulator
    /// order.
    fn run_range_axpby_variant(
        &self,
        block: Block,
        x: &[f64],
        alpha: f64,
        beta: f64,
        y_seg: &mut [f64],
        variant: KernelVariant,
    ) -> Result<()> {
        match variant {
            KernelVariant::Scalar => self.run_range_axpby(block, x, alpha, beta, y_seg),
            KernelVariant::Unrolled4 => {
                crate::spmv::blocked_ell::spmv_blocked_ell_window_range_axpby_unrolled::<4>(
                    self, block.start, block.end, x, alpha, beta, y_seg,
                )
            }
            KernelVariant::Unrolled8 => {
                crate::spmv::blocked_ell::spmv_blocked_ell_window_range_axpby_unrolled::<8>(
                    self, block.start, block.end, x, alpha, beta, y_seg,
                )
            }
        }
    }

    fn resident_bytes(&self) -> usize {
        self.perm.len() * 4
            + self.block_width.len() * 4
            + self.block_ptr.len() * 8
            + self.window_ptr.len() * 8
            + self.cols.len() * 4
            + self.vals.len() * 8
            + self.row_lens.len() * 4
    }

    fn format_tag(&self) -> &'static str {
        "blocked_ell"
    }
}

// ---------------------------------------------------------------------------
// COO
// ---------------------------------------------------------------------------

impl SpmvOperator for Coo {
    fn dims(&self) -> (usize, usize) {
        (self.nrows, self.ncols)
    }

    fn nnz(&self) -> usize {
        Coo::nnz(self)
    }

    /// One indivisible unit: COO triplets are unordered (the GPU kernel
    /// scatters with atomics), so no row range owns a disjoint output
    /// segment and the engine always runs COO serially. Honest rather
    /// than wrong — a row-sorted COO wanting parallelism should convert
    /// to CSR.
    fn cost_prefix(&self) -> Cow<'_, [usize]> {
        Cow::Owned(vec![0, Coo::nnz(self)])
    }

    fn rows_through(&self, unit_end: usize) -> usize {
        if unit_end == 0 {
            0
        } else {
            self.nrows
        }
    }

    fn run_range(&self, block: Block, x: &[f64], y_seg: &mut [f64]) -> Result<()> {
        if block.is_empty() {
            return Ok(());
        }
        debug_assert_eq!((block.start, block.end), (0, 1), "COO has one unit");
        crate::spmv::coo::scatter(self, x, y_seg);
        Ok(())
    }

    fn resident_bytes(&self) -> usize {
        self.rows.len() * 4 + self.cols.len() * 4 + self.vals.len() * 8
    }

    fn format_tag(&self) -> &'static str {
        "coo"
    }
}

// ---------------------------------------------------------------------------
// Dense
// ---------------------------------------------------------------------------

/// Row-major dense matrix as an operator — the ground-truth oracle
/// ([`crate::spmv::spmv_dense`]) behind the trait surface, so cross-format
/// checks can iterate one registry instead of special-casing the oracle.
///
/// Densifying a sparse matrix is quadratic in its dimensions, so
/// [`DenseOperator::from_csr`] refuses matrices above
/// [`DenseOperator::MAX_CELLS`] cells.
pub struct DenseOperator {
    data: Vec<f64>,
    nrows: usize,
    ncols: usize,
    /// Precomputed uniform cost prefix (`prefix[i] = i * ncols`).
    prefix: Vec<usize>,
}

impl DenseOperator {
    /// Refuse to densify past this many cells (32 MiB of f64): dense is
    /// the *oracle*, never the serving path.
    pub const MAX_CELLS: usize = 1 << 22;

    /// Wrap an existing row-major buffer of shape `nrows × ncols`.
    pub fn new(data: Vec<f64>, nrows: usize, ncols: usize) -> Result<DenseOperator> {
        if data.len() != nrows * ncols {
            return Err(DtansError::Dimension(format!(
                "dense buffer {} != {nrows} x {ncols}",
                data.len()
            )));
        }
        let prefix = (0..=nrows).map(|i| i * ncols).collect();
        Ok(DenseOperator { data, nrows, ncols, prefix })
    }

    /// Densify a CSR matrix (refused above [`DenseOperator::MAX_CELLS`]).
    pub fn from_csr(m: &Csr) -> Result<DenseOperator> {
        if m.nrows.saturating_mul(m.ncols) > Self::MAX_CELLS {
            return Err(DtansError::InvalidMatrix(format!(
                "dense oracle refused: {} x {} exceeds {} cells",
                m.nrows,
                m.ncols,
                Self::MAX_CELLS
            )));
        }
        DenseOperator::new(m.to_dense(), m.nrows, m.ncols)
    }
}

impl SpmvOperator for DenseOperator {
    fn dims(&self) -> (usize, usize) {
        (self.nrows, self.ncols)
    }

    fn nnz(&self) -> usize {
        self.data.iter().filter(|v| **v != 0.0).count()
    }

    fn cost_prefix(&self) -> Cow<'_, [usize]> {
        Cow::Borrowed(&self.prefix)
    }

    fn run_range(&self, block: Block, x: &[f64], y_seg: &mut [f64]) -> Result<()> {
        crate::spmv::dense::spmv_dense_row_range(
            &self.data, self.ncols, block.start, block.end, x, y_seg,
        )
    }

    /// Allocation-free fused path (see the trait docs for the bit-identity
    /// argument).
    fn run_range_axpby(
        &self,
        block: Block,
        x: &[f64],
        alpha: f64,
        beta: f64,
        y_seg: &mut [f64],
    ) -> Result<()> {
        crate::spmv::dense::spmv_dense_row_range_axpby(
            &self.data, self.ncols, block.start..block.end, x, alpha, beta, y_seg,
        )
    }

    fn resident_bytes(&self) -> usize {
        self.data.len() * 8 + self.prefix.len() * 8
    }

    fn format_tag(&self) -> &'static str {
        "dense"
    }
}

// ---------------------------------------------------------------------------
// CSR-dtANS
// ---------------------------------------------------------------------------

/// The paper's format as an operator: an encoded matrix *plus* its
/// [`DecodePlan`], built once at construction. Plan reuse used to leak
/// through a separate `spmv_with_plan(…, &plan, …)` entry point that every
/// caller had to thread a plan into; here it is an internal detail —
/// construct the operator once, multiply many times.
pub struct DtansOperator {
    enc: Arc<CsrDtans>,
    plan: DecodePlan,
    /// `slice_offsets` widened to `usize` once, so partitioning never
    /// re-copies the table.
    prefix: Vec<usize>,
}

impl DtansOperator {
    /// Build the operator (and its decode plan) for an encoded matrix.
    pub fn new(enc: impl Into<Arc<CsrDtans>>) -> DtansOperator {
        let enc = enc.into();
        let plan = DecodePlan::new(&enc);
        let prefix = enc.slice_offsets.iter().map(|&w| w as usize).collect();
        DtansOperator { enc, plan, prefix }
    }

    /// The encoded matrix.
    pub fn encoding(&self) -> &Arc<CsrDtans> {
        &self.enc
    }

    /// The prebuilt decode plan.
    pub fn plan(&self) -> &DecodePlan {
        &self.plan
    }
}

impl SpmvOperator for DtansOperator {
    fn dims(&self) -> (usize, usize) {
        (self.enc.nrows, self.enc.ncols)
    }

    fn nnz(&self) -> usize {
        self.enc.nnz
    }

    /// Units = 32-row slices, cost = encoded stream words (the quantity
    /// that bounds decode time — the paper's §IV work assignment).
    fn cost_prefix(&self) -> Cow<'_, [usize]> {
        Cow::Borrowed(&self.prefix)
    }

    /// Decode work scales with nonzeros, and [`MIN_PAR_COST`] is
    /// calibrated in nonzeros — reporting the (compression-ratio smaller)
    /// stream-word total here would silently raise the Auto serial
    /// crossover for exactly the well-compressed matrices dtANS targets,
    /// and would disagree with the service dispatcher's nnz-based
    /// batch-path decision.
    ///
    /// [`MIN_PAR_COST`]: crate::spmv::engine::MIN_PAR_COST
    fn cost(&self) -> usize {
        self.enc.nnz
    }

    fn rows_through(&self, unit_end: usize) -> usize {
        (unit_end * WARP).min(self.enc.nrows)
    }

    fn run_range(&self, block: Block, x: &[f64], y_seg: &mut [f64]) -> Result<()> {
        crate::spmv::csr_dtans::spmv_slice_range(
            &self.enc, &self.plan, block.start, block.end, x, y_seg,
        )
    }

    fn resident_bytes(&self) -> usize {
        self.enc.size_report().total + self.plan.resident_bytes() + self.prefix.len() * 8
    }

    fn format_tag(&self) -> &'static str {
        "csr_dtans"
    }
}

// ---------------------------------------------------------------------------
// Registry
// ---------------------------------------------------------------------------

/// How to build one format's operator from a CSR original.
#[derive(Clone, Copy)]
pub struct FormatEntry {
    /// The format's [`SpmvOperator::format_tag`].
    pub tag: &'static str,
    /// Constructor. May fail (e.g. the dense oracle refuses huge
    /// matrices); iterating callers skip failures.
    pub build: fn(&Csr, &EncodeOptions) -> Result<Arc<dyn SpmvOperator>>,
}

/// Registry of operator constructors, so eval, benches and tests iterate
/// *all* formats instead of hard-coding the list in each caller — adding
/// a format means one [`FormatEntry`], not another copy of the zoo.
///
/// ```
/// use dtans::format::csr_dtans::EncodeOptions;
/// use dtans::matrix::gen::structured::banded;
/// use dtans::spmv::engine::SpmvEngine;
/// use dtans::spmv::operator::FormatRegistry;
///
/// let m = banded(64, 1);
/// let x = vec![1.0; m.ncols];
/// let engine = SpmvEngine::serial();
/// for (tag, op) in FormatRegistry::builtin().build_all(&m, &EncodeOptions::default()) {
///     let op = op.expect(tag); // small matrix: every builder succeeds
///     let mut y = vec![0.0; m.nrows];
///     engine.run(op.as_ref(), &x, &mut y).unwrap();
/// }
/// ```
pub struct FormatRegistry {
    entries: Vec<FormatEntry>,
}

impl FormatRegistry {
    /// The six built-in formats: CSR, COO, SELL (32-row slices),
    /// BlockedEll (8-lane blocks, 64-row sort windows), the dense oracle,
    /// and CSR-dtANS.
    pub fn builtin() -> FormatRegistry {
        FormatRegistry {
            entries: vec![
                FormatEntry { tag: "csr", build: build_csr },
                FormatEntry { tag: "coo", build: build_coo },
                FormatEntry { tag: "sell", build: build_sell },
                FormatEntry { tag: "blocked_ell", build: build_blocked_ell },
                FormatEntry { tag: "dense", build: build_dense },
                FormatEntry { tag: "csr_dtans", build: build_dtans },
            ],
        }
    }

    /// Add (or shadow) a format. Later entries with an existing tag
    /// replace the earlier one.
    pub fn register(&mut self, entry: FormatEntry) {
        if let Some(e) = self.entries.iter_mut().find(|e| e.tag == entry.tag) {
            *e = entry;
        } else {
            self.entries.push(entry);
        }
    }

    /// The registered entries, in registration order.
    pub fn entries(&self) -> &[FormatEntry] {
        &self.entries
    }

    /// Look one format up by tag.
    pub fn get(&self, tag: &str) -> Option<&FormatEntry> {
        self.entries.iter().find(|e| e.tag == tag)
    }

    /// Build every registered operator for `m`. Construction failures are
    /// returned per-tag (not short-circuited) so callers can skip, e.g.,
    /// the dense oracle on matrices too large to densify.
    pub fn build_all(
        &self,
        m: &Csr,
        opts: &EncodeOptions,
    ) -> Vec<(&'static str, Result<Arc<dyn SpmvOperator>>)> {
        self.entries.iter().map(|e| (e.tag, (e.build)(m, opts))).collect()
    }
}

fn build_csr(m: &Csr, _opts: &EncodeOptions) -> Result<Arc<dyn SpmvOperator>> {
    Ok(Arc::new(m.clone()))
}

fn build_coo(m: &Csr, _opts: &EncodeOptions) -> Result<Arc<dyn SpmvOperator>> {
    Ok(Arc::new(m.to_coo()))
}

fn build_sell(m: &Csr, _opts: &EncodeOptions) -> Result<Arc<dyn SpmvOperator>> {
    Ok(Arc::new(Sell::from_csr(m, 32)))
}

fn build_blocked_ell(m: &Csr, _opts: &EncodeOptions) -> Result<Arc<dyn SpmvOperator>> {
    Ok(Arc::new(BlockedEll::from_csr_default(m)))
}

fn build_dense(m: &Csr, _opts: &EncodeOptions) -> Result<Arc<dyn SpmvOperator>> {
    Ok(Arc::new(DenseOperator::from_csr(m)?))
}

fn build_dtans(m: &Csr, opts: &EncodeOptions) -> Result<Arc<dyn SpmvOperator>> {
    Ok(Arc::new(DtansOperator::new(CsrDtans::encode(m, opts)?)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::gen::structured::powerlaw_rows;
    use crate::matrix::gen::{assign_values, ValueDist};
    use crate::util::rng::Xoshiro256;

    fn sample(seed: u64) -> Csr {
        let mut rng = Xoshiro256::seeded(seed);
        let mut m = powerlaw_rows(100, 4.0, 1.1, &mut rng);
        assign_values(&mut m, ValueDist::FewDistinct(5), &mut rng);
        m
    }

    #[test]
    fn all_builtin_operators_agree_with_csr_kernel() {
        let m = sample(1);
        let mut rng = Xoshiro256::seeded(2);
        let x: Vec<f64> = (0..m.ncols).map(|_| rng.next_f64() - 0.5).collect();
        let mut want = vec![0.0; m.nrows];
        crate::spmv::spmv_csr(&m, &x, &mut want).unwrap();
        for (tag, op) in FormatRegistry::builtin().build_all(&m, &EncodeOptions::default()) {
            let op = op.expect(tag);
            assert_eq!(op.format_tag(), tag);
            assert_eq!(op.dims(), (m.nrows, m.ncols));
            let prefix = op.cost_prefix();
            assert!(!prefix.is_empty(), "{tag}: empty prefix");
            assert_eq!(op.rows_through(prefix.len() - 1), m.nrows, "{tag}");
            let mut got = vec![0.0; m.nrows];
            let full = Block {
                start: 0,
                end: prefix.len() - 1,
                cost: prefix[prefix.len() - 1] - prefix[0],
            };
            op.run_range(full, &x, &mut got).unwrap();
            for (a, b) in got.iter().zip(&want) {
                assert!((a - b).abs() < 1e-9, "{tag}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn dense_oracle_refuses_huge_matrices() {
        let m = Csr::new(1 << 12, 1 << 12); // 16M cells > MAX_CELLS
        assert!(DenseOperator::from_csr(&m).is_err());
        assert!(DenseOperator::from_csr(&sample(3)).is_ok());
    }

    #[test]
    fn registry_shadowing_replaces_by_tag() {
        let mut reg = FormatRegistry::builtin();
        let n = reg.entries().len();
        reg.register(FormatEntry { tag: "csr", build: build_csr });
        assert_eq!(reg.entries().len(), n);
        reg.register(FormatEntry { tag: "custom", build: build_csr });
        assert_eq!(reg.entries().len(), n + 1);
        assert!(reg.get("custom").is_some());
        assert!(reg.get("nope").is_none());
    }

    #[test]
    fn auto_cost_is_calibrated_in_nonzeros() {
        // The Auto decision compares cost() against MIN_PAR_COST, which
        // is calibrated in nonzeros: CSR reports nnz (its prefix total),
        // SELL its padded cells (real kernel work), and dtANS must
        // report nnz too — its prefix counts compressed stream words,
        // which would move the serial crossover by the compression ratio.
        let m = sample(5);
        assert_eq!(SpmvOperator::cost(&m), m.nnz());
        let sell = Sell::from_csr(&m, 32);
        assert_eq!(SpmvOperator::cost(&sell), sell.padded_cells());
        let op = DtansOperator::new(CsrDtans::encode(&m, &EncodeOptions::default()).unwrap());
        assert_eq!(op.cost(), m.nnz());
    }

    #[test]
    fn dtans_operator_owns_plan_and_sizes_itself() {
        let m = sample(4);
        let enc = CsrDtans::encode(&m, &EncodeOptions::default()).unwrap();
        let total = enc.size_report().total;
        let op = DtansOperator::new(enc);
        assert_eq!(SpmvOperator::nnz(&op), m.nnz());
        assert!(op.resident_bytes() >= total + op.plan().resident_bytes());
        assert_eq!(op.encoding().nrows, m.nrows);
    }
}
