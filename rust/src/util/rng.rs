//! Deterministic PRNGs: splitmix64 (seeding) and xoshiro256** (bulk).
//!
//! The corpus generators, property tests and benchmarks all need
//! reproducible randomness; the `rand` crate is not in the vendored set, so
//! we implement the two standard small generators directly.

/// splitmix64 step — used to expand a single u64 seed into a full state.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9e37_79b9_7f4a_7c15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// xoshiro256** — fast, high-quality 64-bit generator.
#[derive(Clone, Debug)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    /// Create from a 64-bit seed via splitmix64 expansion.
    pub fn seeded(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Self { s }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Next u32.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, bound)` (Lemire's method; `bound > 0`).
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        // 128-bit multiply rejection-free approximation is fine for our
        // simulation purposes (bias < 2^-64).
        let x = self.next_u64();
        ((x as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform usize in `[0, bound)`.
    #[inline]
    pub fn below_usize(&mut self, bound: usize) -> usize {
        self.below(bound as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Standard normal via Box–Muller (one value; simple, adequate here).
    pub fn next_gaussian(&mut self) -> f64 {
        loop {
            let u = self.next_f64();
            if u > 1e-300 {
                let v = self.next_f64();
                return (-2.0 * u.ln()).sqrt() * (std::f64::consts::TAU * v).cos();
            }
        }
    }

    /// Geometric distribution: number of failures before first success,
    /// success probability `p` in (0, 1].
    pub fn next_geometric(&mut self, p: f64) -> u64 {
        if p >= 1.0 {
            return 0;
        }
        let u = self.next_f64().max(1e-300);
        (u.ln() / (1.0 - p).ln()).floor() as u64
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below_usize(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (Floyd's algorithm).
    pub fn sample_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        use std::collections::HashSet;
        let k = k.min(n);
        let mut chosen: HashSet<usize> = HashSet::with_capacity(k);
        let mut out = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.below_usize(j + 1);
            if chosen.insert(t) {
                out.push(t);
            } else {
                chosen.insert(j);
                out.push(j);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Xoshiro256::seeded(42);
        let mut b = Xoshiro256::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_in_range() {
        let mut r = Xoshiro256::seeded(1);
        for _ in 0..1000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Xoshiro256::seeded(2);
        for _ in 0..1000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn mean_roughly_half() {
        let mut r = Xoshiro256::seeded(3);
        let n = 20_000;
        let s: f64 = (0..n).map(|_| r.next_f64()).sum();
        let mean = s / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn sample_distinct_unique() {
        let mut r = Xoshiro256::seeded(4);
        let mut v = r.sample_distinct(100, 30);
        v.sort_unstable();
        v.dedup();
        assert_eq!(v.len(), 30);
        assert!(v.iter().all(|&x| x < 100));
    }

    #[test]
    fn geometric_small_p_mean() {
        let mut r = Xoshiro256::seeded(5);
        let p = 0.1;
        let n = 50_000;
        let s: u64 = (0..n).map(|_| r.next_geometric(p)).sum();
        let mean = s as f64 / n as f64;
        // E = (1-p)/p = 9
        assert!((mean - 9.0).abs() < 0.3, "mean={mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Xoshiro256::seeded(6);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
