//! Integration: all SpMVM kernels agree on the whole corpus (dense oracle,
//! CSR scalar/vector, COO, SELL at several slice heights, CSR-dtANS native
//! and parallel).

use dtans::eval::{build_corpus, CorpusScale};
use dtans::format::csr_dtans::EncodeOptions;
use dtans::matrix::Precision;
use dtans::spmv::verify::cross_check;
use dtans::util::rng::Xoshiro256;

#[test]
fn all_kernels_agree_on_corpus_f64() {
    let corpus = build_corpus(&CorpusScale { max_nnz: 8000, steps: 3 }, 5);
    for e in &corpus {
        let err = cross_check(&e.csr, &EncodeOptions::default(), 77).unwrap();
        assert!(err < 1e-10, "{}: err {err}", e.name);
    }
}

#[test]
fn all_kernels_agree_on_corpus_f32() {
    let corpus = build_corpus(&CorpusScale { max_nnz: 5000, steps: 2 }, 6);
    for e in &corpus {
        let err = cross_check(
            &e.csr,
            &EncodeOptions {
                precision: Precision::F32,
                ..Default::default()
            },
            78,
        )
        .unwrap();
        assert!(err < 1e-10, "{}: err {err}", e.name);
    }
}

#[test]
fn dense_oracle_on_tiny_matrices() {
    use dtans::spmv::{spmv_csr, spmv_dense};
    let mut rng = Xoshiro256::seeded(8);
    for _ in 0..50 {
        let nr = 1 + rng.below_usize(12);
        let nc = 1 + rng.below_usize(12);
        let nnz = rng.below_usize(nr * nc + 1);
        let m = dtans::matrix::gen::structured::random_uniform(nr, nc, nnz, &mut rng);
        let x: Vec<f64> = (0..nc).map(|_| rng.next_f64() - 0.5).collect();
        let mut y1 = vec![0.1; nr];
        let mut y2 = vec![0.1; nr];
        spmv_csr(&m, &x, &mut y1).unwrap();
        spmv_dense(&m.to_dense(), nr, nc, &x, &mut y2).unwrap();
        for (a, b) in y1.iter().zip(&y2) {
            assert!((a - b).abs() < 1e-12);
        }
    }
}

#[test]
fn dimension_mismatches_error_everywhere() {
    use dtans::format::csr_dtans::CsrDtans;
    use dtans::spmv::{spmv_csr, spmv_csr_dtans};
    let m = dtans::matrix::gen::structured::banded(10, 1);
    let enc = CsrDtans::encode(&m, &EncodeOptions::default()).unwrap();
    let x_bad = vec![0.0; 9];
    let mut y = vec![0.0; 10];
    assert!(spmv_csr(&m, &x_bad, &mut y).is_err());
    assert!(spmv_csr_dtans(&enc, &x_bad, &mut y).is_err());
    let x = vec![0.0; 10];
    let mut y_bad = vec![0.0; 11];
    assert!(spmv_csr_dtans(&enc, &x, &mut y_bad).is_err());
}
