//! Layer-3 coordinator: a batching SpMVM service with per-matrix format
//! routing (the production wrapper around the paper's kernel — encode
//! once, decode on every multiply, as in the iterative-solver and
//! ML-inference scenarios the paper motivates).

pub mod metrics;
pub mod router;
pub mod service;

pub use metrics::{LatencySummary, Metrics};
pub use router::{FormatChoice, RoutePolicy};
pub use service::{Pending, ServiceConfig, SpmvService};
