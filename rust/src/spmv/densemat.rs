//! Contiguous column-major dense matrices for multi-right-hand-side
//! (SpMM-style) multiplies.
//!
//! The engine's batched entry points used to take `&[Vec<f64>]` — one heap
//! allocation per right-hand side, with no locality guarantee between
//! them. [`DenseMat`] packs `k` vectors of length `nrows` into one
//! contiguous buffer, column-major: column `j` (one right-hand side or one
//! output vector) is the slice `data[j*nrows .. (j+1)*nrows]`. Columns
//! being contiguous is what lets the parallel engine hand each
//! (column × row-block) job a disjoint `&mut` segment via `split_at_mut`,
//! so multi-RHS results stay **bit-identical** to repeated single-vector
//! multiplies.
//!
//! [`DenseMatMut`] is the borrowed mutable view the
//! [`SpmvOperator::run_range_multi`](crate::spmv::operator::SpmvOperator::run_range_multi)
//! contract is written against: a kernel receives the view covering
//! exactly its block's rows, for every column.
//!
//! ```
//! use dtans::spmv::densemat::DenseMat;
//! let m = DenseMat::from_cols(2, &[vec![1.0, 2.0], vec![3.0, 4.0]]).unwrap();
//! assert_eq!(m.col(1), &[3.0, 4.0]);
//! assert_eq!(m.as_slice(), &[1.0, 2.0, 3.0, 4.0]); // column-major
//! ```

use crate::util::error::{DtansError, Result};

/// Owned column-major dense matrix: `ncols` columns of `nrows` contiguous
/// values each. In SpMM use, `nrows` is the vector length and `ncols` the
/// number of right-hand sides (`k`).
#[derive(Debug, Clone, PartialEq)]
pub struct DenseMat {
    data: Vec<f64>,
    nrows: usize,
    ncols: usize,
}

impl DenseMat {
    /// All-zero matrix of the given shape.
    pub fn zeros(nrows: usize, ncols: usize) -> DenseMat {
        DenseMat { data: vec![0.0; nrows * ncols], nrows, ncols }
    }

    /// Pack column vectors into one contiguous buffer. Every column must
    /// have length `nrows`; the first mismatch is reported by index (the
    /// same contract the engine's old `&[Vec<f64>]` batch check had).
    pub fn from_cols(nrows: usize, cols: &[Vec<f64>]) -> Result<DenseMat> {
        let mut data = Vec::with_capacity(nrows * cols.len());
        for (j, c) in cols.iter().enumerate() {
            if c.len() != nrows {
                return Err(DtansError::Dimension(format!(
                    "batch rhs {j}: x[{}] for {nrows} rows",
                    c.len()
                )));
            }
            data.extend_from_slice(c);
        }
        Ok(DenseMat { data, nrows, ncols: cols.len() })
    }

    /// Rows per column (the vector length).
    pub fn nrows(&self) -> usize {
        self.nrows
    }

    /// Number of columns (right-hand sides).
    pub fn ncols(&self) -> usize {
        self.ncols
    }

    /// Column `j` as a contiguous slice.
    pub fn col(&self, j: usize) -> &[f64] {
        &self.data[j * self.nrows..(j + 1) * self.nrows]
    }

    /// Column `j` as a contiguous mutable slice.
    pub fn col_mut(&mut self, j: usize) -> &mut [f64] {
        &mut self.data[j * self.nrows..(j + 1) * self.nrows]
    }

    /// The whole column-major buffer.
    pub fn as_slice(&self) -> &[f64] {
        &self.data
    }

    /// Mutable view over the full matrix (all rows, all columns).
    pub fn view_mut(&mut self) -> DenseMatMut<'_> {
        DenseMatMut { data: &mut self.data, nrows: self.nrows, ncols: self.ncols }
    }

    /// Iterate mutably over whole columns (each a disjoint contiguous
    /// slice) — the fan-out axis of the parallel engine. Empty iterator
    /// when `nrows == 0` (there are no row segments to hand out).
    pub fn cols_mut(&mut self) -> impl Iterator<Item = &mut [f64]> {
        // `chunks_mut` instead of `chunks_exact_mut(nrows)` so nrows == 0
        // yields no chunks instead of panicking on a zero chunk size.
        self.data.chunks_mut(self.nrows.max(1)).take(self.ncols)
    }

    /// Unpack into per-column `Vec`s (copies; the inverse of
    /// [`DenseMat::from_cols`]).
    pub fn into_cols(self) -> Vec<Vec<f64>> {
        (0..self.ncols).map(|j| self.col(j).to_vec()).collect()
    }
}

/// Borrowed mutable column-major view: `ncols` columns of `nrows`
/// contiguous values. In the
/// [`run_range_multi`](crate::spmv::operator::SpmvOperator::run_range_multi)
/// contract, `nrows` covers exactly the rows of the block being computed.
#[derive(Debug)]
pub struct DenseMatMut<'a> {
    data: &'a mut [f64],
    nrows: usize,
    ncols: usize,
}

impl<'a> DenseMatMut<'a> {
    /// Wrap a raw column-major buffer (`data.len()` must equal
    /// `nrows * ncols`).
    pub fn new(data: &'a mut [f64], nrows: usize, ncols: usize) -> Result<DenseMatMut<'a>> {
        if data.len() != nrows * ncols {
            return Err(DtansError::Dimension(format!(
                "dense view buffer {} != {nrows} x {ncols}",
                data.len()
            )));
        }
        Ok(DenseMatMut { data, nrows, ncols })
    }

    /// Rows per column.
    pub fn nrows(&self) -> usize {
        self.nrows
    }

    /// Number of columns.
    pub fn ncols(&self) -> usize {
        self.ncols
    }

    /// Column `j` as a contiguous mutable slice.
    pub fn col_mut(&mut self, j: usize) -> &mut [f64] {
        &mut self.data[j * self.nrows..(j + 1) * self.nrows]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrips_columns() {
        let cols = vec![vec![1.0, 2.0, 3.0], vec![-1.0, 0.0, 4.0]];
        let m = DenseMat::from_cols(3, &cols).unwrap();
        assert_eq!((m.nrows(), m.ncols()), (3, 2));
        assert_eq!(m.col(0), &cols[0][..]);
        assert_eq!(m.col(1), &cols[1][..]);
        assert_eq!(m.into_cols(), cols);
    }

    #[test]
    fn mismatched_column_is_reported_by_index() {
        let err = DenseMat::from_cols(3, &[vec![0.0; 3], vec![0.0; 2]]).unwrap_err();
        assert!(err.to_string().contains("rhs 1"), "{err}");
    }

    #[test]
    fn zero_shapes_are_fine() {
        let mut m = DenseMat::zeros(0, 4);
        assert_eq!(m.cols_mut().count(), 0);
        assert_eq!(m.into_cols(), vec![Vec::<f64>::new(); 4]);
        let mut k0 = DenseMat::zeros(5, 0);
        assert_eq!(k0.cols_mut().count(), 0);
        assert!(k0.into_cols().is_empty());
    }

    #[test]
    fn view_and_cols_mut_cover_disjoint_columns() {
        let mut m = DenseMat::zeros(2, 3);
        for (j, col) in m.cols_mut().enumerate() {
            col.fill(j as f64);
        }
        assert_eq!(m.as_slice(), &[0.0, 0.0, 1.0, 1.0, 2.0, 2.0]);
        let mut v = m.view_mut();
        assert_eq!((v.nrows(), v.ncols()), (2, 3));
        v.col_mut(1)[0] = 9.0;
        assert_eq!(m.col(1), &[9.0, 1.0]);
    }

    #[test]
    fn raw_view_checks_length() {
        let mut buf = vec![0.0; 5];
        assert!(DenseMatMut::new(&mut buf, 2, 3).is_err());
        let mut buf = vec![0.0; 6];
        assert!(DenseMatMut::new(&mut buf, 2, 3).is_ok());
    }
}
