//! Binary (de)serialization of [`CsrDtans`] — the on-disk format the paper
//! mentions ("the encoded data can be stored in memory or saved in a file
//! for repeated decoding").
//!
//! Layout: little-endian, a fixed magic/header followed by length-prefixed
//! arrays. The format is self-describing enough to reject foreign or
//! truncated files with a clear error.

use super::csr_dtans::CsrDtans;
use super::symbolize::Domain;
use crate::ans::params::AnsParams;
use crate::ans::tables::CodingTables;
use crate::matrix::Precision;
use crate::util::error::{DtansError, Result};
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 8] = b"CSRDTANS";
const VERSION: u32 = 1;

struct Writer<W: Write> {
    w: W,
}

impl<W: Write> Writer<W> {
    fn u32(&mut self, x: u32) -> Result<()> {
        self.w.write_all(&x.to_le_bytes())?;
        Ok(())
    }
    fn u64(&mut self, x: u64) -> Result<()> {
        self.w.write_all(&x.to_le_bytes())?;
        Ok(())
    }
    fn vec_u32(&mut self, xs: &[u32]) -> Result<()> {
        self.u64(xs.len() as u64)?;
        for &x in xs {
            self.u32(x)?;
        }
        Ok(())
    }
    fn vec_u64(&mut self, xs: &[u64]) -> Result<()> {
        self.u64(xs.len() as u64)?;
        for &x in xs {
            self.u64(x)?;
        }
        Ok(())
    }
    fn vec_bool(&mut self, xs: &[bool]) -> Result<()> {
        self.u64(xs.len() as u64)?;
        for &x in xs {
            self.w.write_all(&[x as u8])?;
        }
        Ok(())
    }
}

struct Reader<R: Read> {
    r: R,
}

impl<R: Read> Reader<R> {
    fn u32(&mut self) -> Result<u32> {
        let mut b = [0u8; 4];
        self.r.read_exact(&mut b)?;
        Ok(u32::from_le_bytes(b))
    }
    fn u64(&mut self) -> Result<u64> {
        let mut b = [0u8; 8];
        self.r.read_exact(&mut b)?;
        Ok(u64::from_le_bytes(b))
    }
    fn len(&mut self) -> Result<usize> {
        let n = self.u64()?;
        if n > (1 << 40) {
            return Err(DtansError::Container(format!("implausible length {n}")));
        }
        Ok(n as usize)
    }
    fn vec_u32(&mut self) -> Result<Vec<u32>> {
        let n = self.len()?;
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            v.push(self.u32()?);
        }
        Ok(v)
    }
    fn vec_u64(&mut self) -> Result<Vec<u64>> {
        let n = self.len()?;
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            v.push(self.u64()?);
        }
        Ok(v)
    }
    fn vec_bool(&mut self) -> Result<Vec<bool>> {
        let n = self.len()?;
        let mut bytes = vec![0u8; n];
        self.r.read_exact(&mut bytes)?;
        Ok(bytes.into_iter().map(|b| b != 0).collect())
    }
}

fn write_domain<W: Write>(w: &mut Writer<W>, d: &Domain) -> Result<()> {
    w.vec_u64(&d.payload)?;
    w.vec_bool(&d.is_escape)?;
    w.vec_u32(&d.mult)?;
    w.u32(d.escape_payload_bits)
}

fn read_domain<R: Read>(r: &mut Reader<R>) -> Result<Domain> {
    let payload = r.vec_u64()?;
    let is_escape = r.vec_bool()?;
    let mult = r.vec_u32()?;
    let bits = r.u32()?;
    Domain::from_parts(payload, is_escape, mult, bits)
}

/// Serialize to any writer.
pub fn write_to<W: Write>(m: &CsrDtans, w: W) -> Result<()> {
    let mut w = Writer { w };
    w.w.write_all(MAGIC)?;
    w.u32(VERSION)?;
    let p = m.params;
    for x in [p.w_bits, p.k_bits, p.m_bits, p.l, p.o, p.f] {
        w.u32(x)?;
    }
    w.u32(match m.precision {
        Precision::F64 => 64,
        Precision::F32 => 32,
    })?;
    w.u32(m.delta_encode as u32)?;
    w.u64(m.nrows as u64)?;
    w.u64(m.ncols as u64)?;
    w.u64(m.nnz as u64)?;
    write_domain(&mut w, &m.delta_domain)?;
    write_domain(&mut w, &m.value_domain)?;
    w.vec_u32(&m.row_nnz)?;
    w.vec_u32(&m.slice_offsets)?;
    w.vec_u32(&m.stream)?;
    w.vec_u32(&m.delta_escapes)?;
    w.vec_u64(&m.value_escapes)?;
    w.vec_u32(&m.delta_esc_offsets)?;
    w.vec_u32(&m.value_esc_offsets)?;
    Ok(())
}

/// Deserialize from any reader.
pub fn read_from<R: Read>(r: R) -> Result<CsrDtans> {
    let mut r = Reader { r };
    let mut magic = [0u8; 8];
    r.r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        return Err(DtansError::Container("bad magic".into()));
    }
    let version = r.u32()?;
    if version != VERSION {
        return Err(DtansError::Container(format!("unsupported version {version}")));
    }
    let params = AnsParams {
        w_bits: r.u32()?,
        k_bits: r.u32()?,
        m_bits: r.u32()?,
        l: r.u32()?,
        o: r.u32()?,
        f: r.u32()?,
    };
    params.validate()?;
    let precision = match r.u32()? {
        64 => Precision::F64,
        32 => Precision::F32,
        x => return Err(DtansError::Container(format!("bad precision {x}"))),
    };
    let delta_encode = r.u32()? != 0;
    let nrows = r.u64()? as usize;
    let ncols = r.u64()? as usize;
    let nnz = r.u64()? as usize;
    let delta_domain = read_domain(&mut r)?;
    let value_domain = read_domain(&mut r)?;
    let delta_tables = CodingTables::build(&params, &delta_domain.mult)?;
    let value_tables = CodingTables::build(&params, &value_domain.mult)?;
    let m = CsrDtans {
        params,
        precision,
        delta_encode,
        nrows,
        ncols,
        nnz,
        delta_domain,
        value_domain,
        delta_tables,
        value_tables,
        row_nnz: r.vec_u32()?,
        slice_offsets: r.vec_u32()?,
        stream: r.vec_u32()?,
        delta_escapes: r.vec_u32()?,
        value_escapes: r.vec_u64()?,
        delta_esc_offsets: r.vec_u32()?,
        value_esc_offsets: r.vec_u32()?,
    };
    if m.row_nnz.len() != m.nrows || m.slice_offsets.len() != m.nslices() + 1 {
        return Err(DtansError::Container("inconsistent array lengths".into()));
    }
    Ok(m)
}

/// Save to a file, creating parent directories.
pub fn save(m: &CsrDtans, path: &Path) -> Result<()> {
    if let Some(p) = path.parent() {
        std::fs::create_dir_all(p)?;
    }
    let f = std::fs::File::create(path)?;
    write_to(m, std::io::BufWriter::new(f))
}

/// Load from a file.
pub fn load(path: &Path) -> Result<CsrDtans> {
    let f = std::fs::File::open(path)?;
    read_from(std::io::BufReader::new(f))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::format::csr_dtans::EncodeOptions;
    use crate::matrix::gen::structured::banded;
    use crate::matrix::gen::{assign_values, ValueDist};
    use crate::util::rng::Xoshiro256;

    fn sample() -> CsrDtans {
        let mut rng = Xoshiro256::seeded(1);
        let mut m = banded(200, 3);
        assign_values(&mut m, ValueDist::Quantized(32), &mut rng);
        CsrDtans::encode(&m, &EncodeOptions::default()).unwrap()
    }

    #[test]
    fn roundtrip_bytes() {
        let enc = sample();
        let mut buf = Vec::new();
        write_to(&enc, &mut buf).unwrap();
        let back = read_from(std::io::Cursor::new(&buf)).unwrap();
        assert_eq!(back.stream, enc.stream);
        assert_eq!(back.row_nnz, enc.row_nnz);
        assert_eq!(back.delta_tables, enc.delta_tables);
        assert_eq!(
            back.decode_to_csr().unwrap(),
            enc.decode_to_csr().unwrap()
        );
    }

    #[test]
    fn rejects_bad_magic() {
        let enc = sample();
        let mut buf = Vec::new();
        write_to(&enc, &mut buf).unwrap();
        buf[0] = b'X';
        assert!(read_from(std::io::Cursor::new(&buf)).is_err());
    }

    #[test]
    fn rejects_truncation() {
        let enc = sample();
        let mut buf = Vec::new();
        write_to(&enc, &mut buf).unwrap();
        buf.truncate(buf.len() / 2);
        assert!(read_from(std::io::Cursor::new(&buf)).is_err());
    }

    #[test]
    fn file_roundtrip() {
        let enc = sample();
        let dir = std::env::temp_dir().join("dtans_test_serialize");
        let path = dir.join("m.dtans");
        save(&enc, &path).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(back.stream, enc.stream);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
