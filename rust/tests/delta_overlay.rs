//! Tier-1 acceptance for mutable matrices (`docs/MUTATION.md`): the
//! overlay operator is bit-identical to a from-scratch rebuild under
//! every partition strategy, versioned artifact keys never collide in a
//! live cache, the compaction swap is a true pin-quiesce (in-flight pins
//! keep the old version, new acquires see the new one), and a crash in
//! the middle of compaction leaves the old version fully servable with
//! zero leaked pins.

use dtans::coordinator::{Metrics, RoutePolicy};
use dtans::delta::{merge, DeltaOverlay, OverlayOperator};
use dtans::format::csr_dtans::EncodeOptions;
use dtans::matrix::gen::structured::banded;
use dtans::matrix::gen::{assign_values, ValueDist};
use dtans::matrix::Csr;
use dtans::spmv::engine::Block;
use dtans::spmv::SpmvOperator;
use dtans::store::{key_for, key_for_versioned, ArtifactCache, MatrixStore, StoreConfig};
use dtans::testkit::faults::FailingDir;
use dtans::testkit::oracle::{check_operator, OracleConfig};
use dtans::util::rng::Xoshiro256;
use std::sync::atomic::Ordering;
use std::sync::Arc;

fn sample_matrix(n: usize, seed: u64) -> Csr {
    let mut m = banded(n, 3);
    assign_values(&mut m, ValueDist::FewDistinct(6), &mut Xoshiro256::seeded(seed));
    m
}

fn store_with(config: StoreConfig) -> MatrixStore {
    MatrixStore::new(
        config,
        EncodeOptions::default(),
        RoutePolicy { min_nnz: 1 << 8, max_size_ratio: 0.98, ..Default::default() },
        Arc::new(Metrics::default()),
    )
    .unwrap()
}

/// A deterministic update burst: `k` coefficient deltas (some targeting
/// existing entries, some fill-in, some repeated coordinates so the
/// arrival-order folding rule is exercised).
fn update_burst(nrows: usize, ncols: usize, k: usize, seed: u64) -> Vec<(u32, u32, f64)> {
    let mut rng = Xoshiro256::seeded(seed);
    (0..k)
        .map(|_| {
            let r = rng.below(nrows as u64) as u32;
            // Half the updates land on the diagonal band (existing
            // entries), half anywhere (mostly fill-in).
            let c = if rng.chance(0.5) {
                r.min(ncols as u32 - 1)
            } else {
                rng.below(ncols as u64) as u32
            };
            (r, c, rng.next_f64() * 4.0 - 2.0)
        })
        .collect()
}

fn run_full(mat: &dtans::store::LoadedMatrix, x: &[f64]) -> Vec<f64> {
    let prefix = mat.op.cost_prefix();
    let units = prefix.len().saturating_sub(1);
    drop(prefix);
    let mut y = vec![0.0; mat.nrows];
    mat.op
        .run_range(Block { start: 0, end: units, cost: 0 }, x, &mut y)
        .unwrap();
    y
}

/// Property: for a sweep of matrices and stacked update bursts, the
/// overlay operator must be **bit-identical** to a CSR rebuilt from
/// scratch out of base+overlay — under the serial kernel and every
/// `Fixed(1..=8)` engine partitioning (the conformance oracle's level-2
/// bit-identity check), with `nnz` agreeing with the rebuild.
#[test]
fn overlay_operator_is_bit_identical_to_rebuilt_csr_across_partitions() {
    for (n, mseed) in [(120usize, 1u64), (257, 2), (600, 3)] {
        let base = Arc::new(sample_matrix(n, mseed));
        let mut overlay = DeltaOverlay::empty(n, n);
        for burst in 0..3u64 {
            let updates = update_burst(n, n, 5 + 3 * burst as usize, 0xB00 + 7 * burst + mseed);
            overlay = overlay.appended(&base, &updates).unwrap();
            let rebuilt = merge(&base, &overlay).unwrap();
            let op =
                OverlayOperator::new(Arc::clone(&base), Arc::new(overlay.clone())).unwrap();
            assert_eq!(
                dtans::spmv::SpmvOperator::nnz(&op),
                rebuilt.nnz(),
                "n={n} burst={burst}"
            );
            // The oracle's partition sweep demands bit-identity of every
            // Fixed(1..=8) run against the operator's own serial result.
            let report = check_operator(&op, &rebuilt, &OracleConfig::default()).unwrap();
            assert!(report.is_conformant(), "n={n} burst={burst}: {report}");
            // The oracle's cross-format level allows a relative
            // tolerance; the overlay claims more — its union walk
            // reproduces the merged CSR kernel operation for operation —
            // so check the serial run against the rebuild bit for bit.
            let x = dtans::testkit::seeded_vector(n, 0xD7A5);
            let mut got = vec![0.0; n];
            dtans::spmv::SpmvEngine::serial().run(&op, &x, &mut got).unwrap();
            let mut want = vec![0.0; n];
            dtans::spmv::spmv_csr(&rebuilt, &x, &mut want).unwrap();
            for (r, (a, b)) in got.iter().zip(&want).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "n={n} burst={burst} row {r}: overlay != rebuilt CSR"
                );
            }
        }
    }
}

/// Version-aware keys: same bytes + same options but different versions
/// must produce distinct keys that coexist in one live cache, and
/// version 0 must stay bit-compatible with the unversioned v1 key (old
/// cache dirs remain valid).
#[test]
fn versioned_artifact_keys_never_collide_in_a_live_cache() {
    let dir = std::env::temp_dir()
        .join(format!("dtans_it_delta_keys_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let cache = ArtifactCache::open(&dir).unwrap();
    let m = sample_matrix(400, 4);
    let opts = EncodeOptions::default();
    let enc = dtans::format::CsrDtans::encode(&m, &opts).unwrap();

    let keys: Vec<_> = (0..4u64).map(|v| key_for_versioned(&m, &opts, v)).collect();
    assert_eq!(keys[0], key_for(&m, &opts), "version 0 keeps the legacy key");
    for (i, a) in keys.iter().enumerate() {
        for b in keys.iter().skip(i + 1) {
            assert_ne!(a, b, "versions must never share an artifact");
        }
    }
    for k in &keys {
        cache.store(k, &enc).unwrap();
    }
    for k in &keys {
        assert!(cache.contains(k));
        assert!(cache.load(k).unwrap().is_some());
    }
    // Distinct paths on disk — no same-file aliasing behind the keys.
    let mut paths: Vec<_> = keys.iter().map(|k| cache.path_for(k)).collect();
    paths.sort();
    paths.dedup();
    assert_eq!(paths.len(), keys.len());
    let _ = std::fs::remove_dir_all(&dir);
}

/// The compaction swap is a pin-quiesce: a pin taken before the swap
/// keeps servicing the overlay version (bit-for-bit), while an acquire
/// after the swap sees the compacted base with the overlay absorbed —
/// and both serve identical bits, so callers cannot observe the swap
/// except through the overlay metadata.
#[test]
fn swap_under_pin_serves_old_version_while_new_acquires_see_new() {
    let dir = std::env::temp_dir()
        .join(format!("dtans_it_delta_swap_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let store = store_with(StoreConfig {
        cache_dir: Some(dir.clone()),
        ..Default::default()
    });
    let m = sample_matrix(500, 5);
    let id = store.register_csr("m", m.clone()).unwrap();
    store.flush();
    let updates = update_burst(m.nrows, m.ncols, 9, 0xCAFE);
    assert_eq!(store.append(id, &updates).unwrap(), 1);

    // Pin the overlay version, then compact underneath it.
    let old_pin = store.acquire(id).unwrap();
    assert!(old_pin.overlay.is_some());
    assert_eq!(old_pin.version, 1);
    assert!(store.compact(id), "compaction must be accepted");
    store.flush(); // wait for the background job

    // New acquires see the compacted matrix: overlay absorbed, same
    // version (compaction changes representation, not content).
    let new_pin = store.acquire(id).unwrap();
    assert!(new_pin.overlay.is_none(), "overlay must be absorbed");
    assert_eq!(new_pin.version, 1);
    assert_eq!(store.overlay_nnz_of(id), Some(0));
    assert_eq!(store.metrics().compactions.load(Ordering::Relaxed), 1);

    // The in-flight pin still runs on the old representation, and both
    // agree bitwise with a from-scratch rebuild.
    let x: Vec<f64> = (0..m.ncols).map(|j| (j as f64 * 0.01).sin()).collect();
    let overlay = DeltaOverlay::empty(m.nrows, m.ncols).appended(&m, &updates).unwrap();
    let rebuilt = merge(&m, &overlay).unwrap();
    let mut want = vec![0.0; m.nrows];
    dtans::spmv::spmv_csr(&rebuilt, &x, &mut want).unwrap();
    assert_eq!(old_pin.op.format_tag(), "overlay");
    assert_eq!(new_pin.op.format_tag(), "csr");
    assert_eq!(run_full(&old_pin, &x), want);
    assert_eq!(run_full(&new_pin, &x), want);

    drop(old_pin);
    drop(new_pin);
    assert_eq!(store.pin_count(id), 0, "quiesce must not leak pins");
    let _ = std::fs::remove_dir_all(&dir);
}

/// Crash-safety: a compaction whose artifact persist fails must leave
/// the overlay version fully servable (same bits, same version, overlay
/// intact), count a `compaction_failure`, leak no pins — and a retry
/// after the fault window closes must succeed cleanly.
#[test]
fn crash_during_compaction_keeps_old_version_servable() {
    let dir = FailingDir::new("delta_compaction").unwrap();
    let store = store_with(StoreConfig {
        cache_dir: Some(dir.root().to_path_buf()),
        ..Default::default()
    });
    let m = sample_matrix(450, 6);
    let id = store.register_csr("m", m.clone()).unwrap();
    store.flush();
    let updates = update_burst(m.nrows, m.ncols, 7, 0xDEAD);
    assert_eq!(store.append(id, &updates).unwrap(), 1);
    let overlay_nnz = store.overlay_nnz_of(id).unwrap();
    assert!(overlay_nnz > 0);

    let overlay = DeltaOverlay::empty(m.nrows, m.ncols).appended(&m, &updates).unwrap();
    let rebuilt = merge(&m, &overlay).unwrap();
    let x: Vec<f64> = (0..m.ncols).map(|j| (j as f64 * 0.02).cos()).collect();
    let mut want = vec![0.0; m.nrows];
    dtans::spmv::spmv_csr(&rebuilt, &x, &mut want).unwrap();

    // Open the write-failure window mid-"traffic", then compact: the
    // merge+encode succeed but the versioned-artifact persist fails, so
    // the job must abort without touching the resident version.
    dir.break_writes().unwrap();
    assert!(store.compact(id), "job must be accepted before it fails");
    store.flush();
    let metrics = Arc::clone(store.metrics());
    assert_eq!(metrics.compaction_failures.load(Ordering::Relaxed), 1);
    assert_eq!(metrics.compactions.load(Ordering::Relaxed), 0);
    assert_eq!(store.version_of(id), Some(1));
    assert_eq!(store.overlay_nnz_of(id), Some(overlay_nnz), "overlay must survive");
    {
        let pin = store.acquire(id).unwrap();
        assert!(pin.overlay.is_some());
        assert_eq!(run_full(&pin, &x), want, "old version must stay servable");
    }
    assert_eq!(store.pin_count(id), 0, "failed compaction must not leak pins");

    // Close the window: the retry must absorb the overlay and keep bits.
    dir.restore_writes().unwrap();
    assert!(store.compact(id));
    store.flush();
    assert_eq!(metrics.compactions.load(Ordering::Relaxed), 1);
    assert_eq!(store.overlay_nnz_of(id), Some(0));
    assert_eq!(store.version_of(id), Some(1));
    {
        let pin = store.acquire(id).unwrap();
        assert!(pin.overlay.is_none());
        assert_eq!(run_full(&pin, &x), want);
    }
    assert_eq!(store.pin_count(id), 0);
}
