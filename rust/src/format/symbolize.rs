//! Symbolization of delta/value payloads: building the per-domain
//! dictionary, the escape policy, and the normalized multiplicities.
//!
//! The paper's §IV-F "escaping rare values": a domain may have more than K
//! distinct payloads, and even when it does not, escaping rare payloads can
//! reduce total size (a table slot for a once-seen f64 costs more than the
//! escape path). Escaped payloads travel in a separate uncompressed side
//! stream (the paper's lower-latency alternative to in-stream escapes).
//! "We approximate the exact distributions such that the expected total
//! size is minimized" — we sweep frequency cutoffs and keep the best.

use crate::ans::histogram::normalize_counts;
use crate::ans::params::AnsParams;
use crate::util::error::Result;
use std::collections::HashMap;

/// A symbol domain: dictionary payloads, escape flags, multiplicities.
///
/// Symbol ids index `payload`/`mult`/`is_escape` in parallel. Duplicated
/// entries (same payload under several ids) appear when fewer than `K/M`
/// distinct symbols exist — the table must still fill all K slots with
/// per-symbol multiplicity ≤ M.
#[derive(Debug, Clone, PartialEq)]
pub struct Domain {
    /// Payload per symbol id (delta as u64, or value bit pattern).
    pub payload: Vec<u64>,
    /// True for escape symbol ids (payload field unused).
    pub is_escape: Vec<bool>,
    /// Multiplicity per symbol id (sums to K).
    pub mult: Vec<u32>,
    /// payload -> symbol ids (several when duplicated).
    map: HashMap<u64, Vec<u16>>,
    /// Ids of the escape symbol(s).
    escape_ids: Vec<u16>,
    /// Most frequent non-escape id — used as the row pad symbol so pads
    /// never touch the side stream.
    pub pad_sym: u16,
    /// Bits of one escaped raw payload in the side stream.
    pub escape_payload_bits: u32,
    /// Estimated encoded bits for the training data (diagnostics).
    pub est_bits: f64,
}

/// Round-robin symbol chooser for encoding (spreads duplicated ids).
#[derive(Debug, Default)]
pub struct SymbolPicker {
    counters: HashMap<u64, usize>,
}

impl Domain {
    /// Number of symbol ids.
    pub fn num_symbols(&self) -> usize {
        self.payload.len()
    }

    /// Is `sym` an escape id?
    #[inline]
    pub fn escaped(&self, sym: u16) -> bool {
        self.is_escape[sym as usize]
    }

    /// Payload of a non-escape symbol.
    #[inline]
    pub fn payload_of(&self, sym: u16) -> u64 {
        self.payload[sym as usize]
    }

    /// Symbol id for a payload: a dictionary id when present (round-robin
    /// across duplicates via `picker`), else an escape id.
    pub fn sym_for(&self, payload: u64, picker: &mut SymbolPicker) -> (u16, bool) {
        match self.map.get(&payload) {
            Some(ids) => {
                if ids.len() == 1 {
                    (ids[0], false)
                } else {
                    let c = picker.counters.entry(payload).or_insert(0);
                    let id = ids[*c % ids.len()];
                    *c += 1;
                    (id, false)
                }
            }
            None => {
                let c = picker.counters.entry(u64::MAX).or_insert(0);
                let id = self.escape_ids[*c % self.escape_ids.len()];
                *c += 1;
                (id, true)
            }
        }
    }

    /// Reconstruct a domain from serialized parts (payloads, escape flags,
    /// multiplicities) — rebuilds the lookup map and pad symbol.
    pub fn from_parts(
        payload: Vec<u64>,
        is_escape: Vec<bool>,
        mult: Vec<u32>,
        escape_payload_bits: u32,
    ) -> Result<Domain> {
        use crate::util::error::DtansError;
        if payload.len() != is_escape.len() || payload.len() != mult.len() {
            return Err(DtansError::Container("domain arrays disagree".into()));
        }
        if !is_escape.iter().any(|&e| e) {
            return Err(DtansError::Container("domain lacks escape symbol".into()));
        }
        let mut map: HashMap<u64, Vec<u16>> = HashMap::new();
        let mut escape_ids = Vec::new();
        for (id, (&p, &e)) in payload.iter().zip(&is_escape).enumerate() {
            if e {
                escape_ids.push(id as u16);
            } else {
                map.entry(p).or_default().push(id as u16);
            }
        }
        let pad_sym = (0..payload.len())
            .filter(|&i| !is_escape[i])
            .max_by_key(|&i| mult[i])
            .unwrap_or(escape_ids[0] as usize) as u16;
        Ok(Domain {
            payload,
            is_escape,
            mult,
            map,
            escape_ids,
            pad_sym,
            escape_payload_bits,
            est_bits: 0.0,
        })
    }

    /// Build a domain from a payload histogram.
    ///
    /// `escape_payload_bits` is the side-stream cost of one escaped payload
    /// (32 for deltas/f32 values, 64 for f64 values).
    pub fn build(
        counts: &HashMap<u64, u64>,
        params: &AnsParams,
        escape_payload_bits: u32,
    ) -> Result<Domain> {
        let k = params.k();
        let m = params.m();
        let total: u64 = counts.values().sum();

        // Sort distinct payloads by descending count.
        let mut items: Vec<(u64, u64)> = counts.iter().map(|(&p, &c)| (p, c)).collect();
        items.sort_unstable_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));

        // Sweep keep-counts, estimating total encoded bits with the ideal
        // (uncapped-by-integrality) slot assignment p' = min(p, M/K).
        let max_keep = items.len().min(k as usize - 1);
        let mut best_keep = max_keep.max(1).min(items.len());
        let mut best_bits = f64::INFINITY;
        let mut prefix: Vec<u64> = Vec::with_capacity(items.len() + 1);
        prefix.push(0);
        for (_, c) in &items {
            prefix.push(prefix.last().unwrap() + c);
        }
        let candidates: Vec<usize> = {
            // Log-spaced keep counts plus the extremes.
            let mut cs = vec![1usize.min(max_keep.max(1))];
            let mut v = 1usize;
            while v < max_keep {
                v = (v * 2).min(max_keep);
                cs.push(v);
            }
            cs.push(max_keep);
            cs.sort_unstable();
            cs.dedup();
            cs.retain(|&c| c >= 1 && c <= items.len());
            if cs.is_empty() {
                vec![items.len().min(1)]
            } else {
                cs
            }
        };
        for &keep in &candidates {
            let esc_count = total - prefix[keep];
            let cap = m as f64 / k as f64;
            // Ideal probabilities, capped and renormalized approximately.
            let mut bits = 0.0;
            let mut mass = 0.0;
            for &(_, c) in items.iter().take(keep) {
                mass += (c as f64 / total as f64).min(cap);
            }
            let esc_p = ((esc_count as f64 / total as f64).min(cap)).max(1.0 / k as f64);
            mass += esc_p;
            for &(_, c) in items.iter().take(keep) {
                let p = c as f64 / total as f64;
                let q = (p.min(cap) / mass).max(1.0 / k as f64);
                bits += c as f64 * (1.0 / q).log2();
            }
            // Each kept symbol also pays its dictionary entry once — the
            // paper's rationale for escaping rare values ("assigning them a
            // slot in the table is more expensive than paying the cost to
            // escape them").
            bits += keep as f64 * escape_payload_bits as f64;
            if esc_count > 0 {
                let q = (esc_p / mass).max(1.0 / k as f64);
                bits += esc_count as f64 * ((1.0 / q).log2() + escape_payload_bits as f64);
            }
            if bits < best_bits {
                best_bits = bits;
                best_keep = keep;
            }
        }
        // Keep at least one real payload when any exist, so row padding
        // never needs the escape path.
        if !items.is_empty() {
            best_keep = best_keep.max(1);
        }

        // Assemble symbol list: kept payloads + one escape id, then
        // duplicate hot ids until K slots are fillable under the M cap.
        let mut payload: Vec<u64> = items.iter().take(best_keep).map(|&(p, _)| p).collect();
        let mut cnt: Vec<u64> = items.iter().take(best_keep).map(|&(_, c)| c).collect();
        let mut is_escape: Vec<bool> = vec![false; payload.len()];
        let esc_count = total - prefix[best_keep.min(items.len())];
        payload.push(0);
        // Escape keeps at least weight 1 so it stays representable: decoders
        // must handle payloads outside the dictionary even if none were in
        // the training data (e.g. after padding rows).
        cnt.push(esc_count.max(1));
        is_escape.push(true);

        while (payload.len() as u64) * (m as u64) < (k as u64) {
            // Duplicate the currently heaviest id, splitting its count.
            let (hot, _) = cnt.iter().enumerate().max_by_key(|&(_, &c)| c).unwrap();
            let half = (cnt[hot] / 2).max(1);
            cnt[hot] = (cnt[hot] - half).max(1);
            payload.push(payload[hot]);
            cnt.push(half);
            is_escape.push(is_escape[hot]);
        }

        let mult = normalize_counts(&cnt, k, m)?;

        let mut map: HashMap<u64, Vec<u16>> = HashMap::new();
        let mut escape_ids = Vec::new();
        for (id, (&p, &e)) in payload.iter().zip(&is_escape).enumerate() {
            if e {
                escape_ids.push(id as u16);
            } else {
                map.entry(p).or_default().push(id as u16);
            }
        }
        // Pad symbol: most multiplicitous non-escape id (falls back to the
        // escape id only for the degenerate "no payloads at all" domain).
        let pad_sym = (0..payload.len())
            .filter(|&i| !is_escape[i])
            .max_by_key(|&i| mult[i])
            .unwrap_or(escape_ids[0] as usize) as u16;

        Ok(Domain {
            payload,
            is_escape,
            mult,
            map,
            escape_ids,
            pad_sym,
            escape_payload_bits,
            est_bits: best_bits,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn counts_of(pairs: &[(u64, u64)]) -> HashMap<u64, u64> {
        pairs.iter().copied().collect()
    }

    #[test]
    fn small_domain_duplicates_to_fill_k() {
        // KERNEL: K=4096, M=256 -> need >= 16 symbol ids.
        let d = Domain::build(
            &counts_of(&[(1, 1000), (2, 500)]),
            &AnsParams::KERNEL,
            32,
        )
        .unwrap();
        assert!(d.num_symbols() >= 16);
        assert_eq!(d.mult.iter().sum::<u32>(), 4096);
        // The hot payload 1 has several ids.
        assert!(d.map.get(&1).unwrap().len() > 1);
        assert!(!d.escaped(d.pad_sym));
    }

    #[test]
    fn rare_values_escape() {
        // One dominant payload plus 5000 singletons: singletons should not
        // all get dictionary slots.
        let mut c = HashMap::new();
        c.insert(7u64, 100_000u64);
        for i in 0..5000u64 {
            c.insert(1_000_000 + i, 1);
        }
        let d = Domain::build(&c, &AnsParams::KERNEL, 64).unwrap();
        let mut picker = SymbolPicker::default();
        let (s7, esc7) = d.sym_for(7, &mut picker);
        assert!(!esc7);
        assert_eq!(d.payload_of(s7), 7);
        let (_, esc_rare) = d.sym_for(1_000_321, &mut picker);
        assert!(esc_rare);
        // Unseen payloads also escape.
        let (_, esc_new) = d.sym_for(9_999_999_999, &mut picker);
        assert!(esc_new);
    }

    #[test]
    fn more_than_k_distinct_forced_to_escape() {
        let mut c = HashMap::new();
        for i in 0..10_000u64 {
            c.insert(i, 10);
        }
        let d = Domain::build(&c, &AnsParams::KERNEL, 32).unwrap();
        assert!(d.num_symbols() <= 4096);
        assert_eq!(d.mult.iter().sum::<u32>(), 4096);
    }

    #[test]
    fn empty_domain_is_escape_only() {
        let d = Domain::build(&HashMap::new(), &AnsParams::KERNEL, 32).unwrap();
        assert!(d.num_symbols() >= 16);
        let mut picker = SymbolPicker::default();
        let (_, esc) = d.sym_for(42, &mut picker);
        assert!(esc);
    }

    #[test]
    fn round_robin_spreads_duplicates() {
        let d = Domain::build(&counts_of(&[(5, 100)]), &AnsParams::KERNEL, 32).unwrap();
        let ids = d.map.get(&5).unwrap().clone();
        assert!(ids.len() > 1);
        let mut picker = SymbolPicker::default();
        let a = d.sym_for(5, &mut picker).0;
        let b = d.sym_for(5, &mut picker).0;
        assert_ne!(a, b);
    }

    #[test]
    fn paper_params_domain() {
        let d = Domain::build(
            &counts_of(&[(1, 800), (2, 150), (3, 50)]),
            &AnsParams::PAPER,
            32,
        )
        .unwrap();
        assert_eq!(d.mult.iter().sum::<u32>(), 4096);
        // Frequent deltas get higher multiplicity than rare ones.
        let mut picker = SymbolPicker::default();
        let s1 = d.sym_for(1, &mut picker).0 as usize;
        let s3 = d.sym_for(3, &mut picker).0 as usize;
        assert!(d.mult[s1] >= d.mult[s3]);
    }
}
