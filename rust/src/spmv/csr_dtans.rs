//! The paper's kernel: SpMVM fused with on-the-fly dtANS decoding
//! (Fig. 1 right, §II-B). This is the warp-synchronous CUDA control flow
//! executed in lockstep on the CPU: 32 lanes per slice, one shared stream
//! cursor, load events resolved by lane rank (the `__ballot_sync`/`popc`
//! prefix sum becomes an explicit scan).
//!
//! The hot path avoids the generic [`crate::ans::dtans::RowDecoder`] in
//! favor of flat per-lane state arrays and precomputed symbol lookup
//! tables (`sym -> f64 value`, `sym -> delta`, `sym -> escape?`), so the
//! inner loop is: unpack, table gather, FMA, group push, check.

use crate::format::csr_dtans::{CsrDtans, WARP};
use crate::util::error::{DtansError, Result};
use crate::util::threadpool::ThreadPool;

/// Precomputed per-symbol lookup tables for one encoded matrix; build once,
/// reuse across SpMVM calls (the coordinator caches this).
#[derive(Debug, Clone)]
pub struct DecodePlan {
    /// Value-domain symbol -> f64 value (0.0 for escapes).
    value_of_sym: Vec<f64>,
    /// Delta-domain symbol -> delta (0 for escapes).
    delta_of_sym: Vec<u32>,
    /// Value-domain symbol -> escape?
    value_escape: Vec<bool>,
    /// Delta-domain symbol -> escape?
    delta_escape: Vec<bool>,
    /// Escaped value payloads pre-decoded to f64.
    value_escapes_f64: Vec<f64>,
}

impl DecodePlan {
    /// Build the plan for an encoded matrix.
    pub fn new(m: &CsrDtans) -> DecodePlan {
        let prec = m.precision;
        let to_f64 = |p: u64| match prec {
            crate::matrix::Precision::F64 => f64::from_bits(p),
            crate::matrix::Precision::F32 => f32::from_bits(p as u32) as f64,
        };
        DecodePlan {
            value_of_sym: m
                .value_domain
                .payload
                .iter()
                .zip(&m.value_domain.is_escape)
                .map(|(&p, &e)| if e { 0.0 } else { to_f64(p) })
                .collect(),
            delta_of_sym: m
                .delta_domain
                .payload
                .iter()
                .zip(&m.delta_domain.is_escape)
                .map(|(&p, &e)| if e { 0 } else { p as u32 })
                .collect(),
            value_escape: m.value_domain.is_escape.clone(),
            delta_escape: m.delta_domain.is_escape.clone(),
            value_escapes_f64: m.value_escapes.iter().map(|&p| to_f64(p)).collect(),
        }
    }

    /// Heap bytes held by this plan's lookup tables — the plan's
    /// contribution to a matrix's resident cost in the tiered store's
    /// memory budget ([`crate::store::residency`]).
    pub fn resident_bytes(&self) -> usize {
        self.value_of_sym.len() * 8
            + self.delta_of_sym.len() * 4
            + self.value_escape.len()
            + self.delta_escape.len()
            + self.value_escapes_f64.len() * 8
    }
}

/// `y += A·x` over a CSR-dtANS matrix (single-threaded).
///
/// Builds a fresh [`DecodePlan`]; use [`spmv_with_plan`] — or better, a
/// [`DtansOperator`](crate::spmv::operator::DtansOperator), which owns its
/// plan — to reuse the plan across multiplies.
///
/// ```
/// use dtans::format::csr_dtans::{CsrDtans, EncodeOptions};
/// use dtans::matrix::gen::structured::banded;
/// use dtans::matrix::gen::{assign_values, ValueDist};
/// use dtans::spmv::{spmv_csr, spmv_csr_dtans};
/// use dtans::util::rng::Xoshiro256;
///
/// let mut m = banded(200, 2);
/// assign_values(&mut m, ValueDist::FewDistinct(4), &mut Xoshiro256::seeded(1));
/// let enc = CsrDtans::encode(&m, &EncodeOptions::default()).unwrap();
/// let x = vec![1.0; m.ncols];
/// let (mut y, mut want) = (vec![0.0; m.nrows], vec![0.0; m.nrows]);
/// spmv_csr_dtans(&enc, &x, &mut y).unwrap();
/// spmv_csr(&m, &x, &mut want).unwrap();
/// assert!(y.iter().zip(&want).all(|(a, b)| (a - b).abs() < 1e-12));
/// ```
pub fn spmv_csr_dtans(m: &CsrDtans, x: &[f64], y: &mut [f64]) -> Result<()> {
    let plan = DecodePlan::new(m);
    spmv_with_plan(m, &plan, x, y)
}

/// `y += A·x` with a prebuilt [`DecodePlan`].
pub fn spmv_with_plan(m: &CsrDtans, plan: &DecodePlan, x: &[f64], y: &mut [f64]) -> Result<()> {
    super::check_dims(m.nrows, m.ncols, x, y)?;
    spmv_slice_range(m, plan, 0, m.nslices(), x, y)
}

/// Decode + multiply the contiguous slice range `s0..s1`; `y_seg` spans
/// rows `s0 * WARP .. min(s1 * WARP, nrows)`. This is the unit the
/// parallel engine fans out: slice ranges touch disjoint row ranges, so
/// each block gets its own `&mut` output segment with no combining pass.
pub(crate) fn spmv_slice_range(
    m: &CsrDtans,
    plan: &DecodePlan,
    s0: usize,
    s1: usize,
    x: &[f64],
    y_seg: &mut [f64],
) -> Result<()> {
    let base = s0 * WARP;
    for s in s0..s1 {
        let a = s * WARP - base;
        let b = ((s + 1) * WARP).min(m.nrows) - base;
        spmv_slice(m, plan, s, x, &mut y_seg[a..b])?;
    }
    Ok(())
}

/// Parallel variant over a caller-provided pool: slices fan out in
/// nnz-balanced blocks (see [`crate::spmv::engine::partition_prefix`],
/// applied to the slice word-offset table), each writing its disjoint `y`
/// range in place — no per-slice copies. Bit-identical to the serial
/// [`spmv_csr_dtans`].
///
/// Prefer [`crate::spmv::engine::SpmvEngine::run`] over a
/// [`DtansOperator`](crate::spmv::operator::DtansOperator), which owns its
/// pool and plan and adds strategy selection plus batched entry points;
/// this free function remains for callers that already manage a
/// [`ThreadPool`].
pub fn spmv_csr_dtans_parallel(
    m: &CsrDtans,
    x: &[f64],
    y: &mut [f64],
    pool: &ThreadPool,
) -> Result<()> {
    super::check_dims(m.nrows, m.ncols, x, y)?;
    let plan = DecodePlan::new(m);
    // The by-projection partitions the u32 slice-offset table directly —
    // no widened copy on this per-call path (the operator API instead
    // widens once at `DtansOperator` construction).
    let blocks =
        super::engine::partition::partition_prefix_by(&m.slice_offsets, |&w| w as usize, pool.size());
    super::engine::run_blocks(
        pool,
        &blocks,
        y,
        |b| (b.end * WARP).min(m.nrows),
        |b, seg| spmv_slice_range(m, &plan, b.start, b.end, x, seg),
    )
}

/// Decode + multiply one slice; `y_slice` covers the slice's rows.
/// Dispatches to a monomorphized kernel for the two presets (perf pass:
/// constant loop bounds let the compiler fully unroll the per-segment
/// inner loops — ~25% over the dynamic version).
fn spmv_slice(
    m: &CsrDtans,
    plan: &DecodePlan,
    slice: usize,
    x: &[f64],
    y_slice: &mut [f64],
) -> Result<()> {
    use crate::ans::AnsParams;
    if m.params == AnsParams::PAPER {
        spmv_slice_impl::<8, 3, 2, 32, 12>(m, plan, slice, x, y_slice)
    } else if m.params == AnsParams::KERNEL {
        spmv_slice_impl::<4, 3, 2, 16, 12>(m, plan, slice, x, y_slice)
    } else {
        spmv_slice_dyn(m, plan, slice, x, y_slice)
    }
}

/// Monomorphized slice kernel: `L` symbols/segment, `O` words, `F` checks,
/// `WB`/`KB` word/table bits.
#[inline(always)]
fn spmv_slice_impl<const L: usize, const O: usize, const F: usize, const WB: usize, const KB: usize>(
    m: &CsrDtans,
    plan: &DecodePlan,
    slice: usize,
    x: &[f64],
    y_slice: &mut [f64],
) -> Result<()> {
    let (l, o, f) = (L, O, F);
    let gsz = L / F;
    let nps = L / 2;
    let (w_bits, k_bits) = (WB, KB);
    let w_radix: u64 = 1 << w_bits;
    let k_mask: u64 = (1u64 << k_bits) - 1;

    let r0 = slice * WARP;
    let lanes = y_slice.len();
    let stream =
        &m.stream[m.slice_offsets[slice] as usize..m.slice_offsets[slice + 1] as usize];
    let dtab = &m.delta_tables.packed[..];
    let vtab = &m.value_tables.packed[..];
    // Invariants for the unchecked gathers below: slots are masked to
    // [0, K), both tables have exactly K entries, and symbol ids inside
    // packed entries are < num_symbols == plan array lengths by table
    // construction (they do not depend on stream contents).
    assert_eq!(dtab.len(), k_mask as usize + 1);
    assert_eq!(vtab.len(), k_mask as usize + 1);
    assert_eq!(plan.delta_of_sym.len(), m.delta_domain.num_symbols());
    assert_eq!(plan.value_of_sym.len(), m.value_domain.num_symbols());

    let mut pos = 0usize;

    // Flat per-lane state. `ent` caches the packed table entries of the
    // current segment's slots so the digit-fold phase does not re-gather
    // them (perf pass: -1 table load per symbol).
    let mut d = [0u64; WARP];
    let mut r = [1u64; WARP];
    let mut w = [[0u32; 8]; WARP]; // o <= 8
    let mut nseg = [0usize; WARP];
    let mut emitted = [0usize; WARP];
    let mut nnz_lane = [0usize; WARP];
    let mut col_acc = [0u32; WARP];
    let mut acc = [0.0f64; WARP];
    let mut esc_d = [0usize; WARP];
    let mut esc_v = [0usize; WARP];
    let mut ent = [[0u32; 16]; WARP]; // l <= 16
    debug_assert!(o <= 8 && l <= 16 && nps <= 8);

    let mut max_seg = 0usize;
    for lane in 0..lanes {
        let row = r0 + lane;
        nnz_lane[lane] = m.row_nnz[row] as usize;
        nseg[lane] = nnz_lane[lane].div_ceil(nps);
        max_seg = max_seg.max(nseg[lane]);
        esc_d[lane] = m.delta_esc_offsets[row] as usize;
        esc_v[lane] = m.value_esc_offsets[row] as usize;
    }

    // Initial o words (one event per word index — coalesced on the GPU).
    for k in 0..o {
        for lane in 0..lanes {
            if nseg[lane] > 0 {
                let word = *stream
                    .get(pos)
                    .ok_or_else(|| DtansError::CorruptStream("stream exhausted".into()))?;
                pos += 1;
                w[lane][k] = word;
            }
        }
    }

    // Perf notes (EXPERIMENTS.md §Perf): the unpack works on two u64
    // halves instead of a u128 (the 96-bit PAPER case), the packed table
    // entries are gathered once per symbol and cached in `ent` for the
    // digit-fold phase, and the per-position span split (low half / both /
    // high half) branches only on the loop counter, so it predicts
    // perfectly.
    for t in 0..max_seg {
        // --- Decode segment t of each active lane and accumulate. ---
        for lane in 0..lanes {
            if t >= nseg[lane] {
                continue;
            }
            // unpack: o words form a (w_bits*o <= 96)-bit number held as
            // (hi, lo) u64 halves; slots are its base-K digits.
            let (mut hi, mut lo) = (0u64, 0u64);
            for k in 0..o {
                hi = (hi << w_bits) | (lo >> (64 - w_bits));
                lo = (lo << w_bits) | w[lane][k] as u64;
            }
            for pos_s in 0..l {
                let b = k_bits * pos_s;
                let raw = if b + k_bits <= 64 {
                    lo >> b
                } else if b >= 64 {
                    hi >> (b - 64)
                } else {
                    (lo >> b) | (hi << (64 - b))
                };
                let slot = (raw & k_mask) as usize;
                // SAFETY: slot < K == table length (asserted above).
                ent[lane][pos_s] = unsafe {
                    if pos_s % 2 == 0 {
                        *dtab.get_unchecked(slot)
                    } else {
                        *vtab.get_unchecked(slot)
                    }
                };
            }
            // Resolve up to nps (column, value) pairs; the x-gathers and
            // FMAs run in a separate batched pass below so the loads of
            // all lanes are independent in the out-of-order window (perf
            // pass: the fused per-lane loop serialized on the x gather).
            let mut em = emitted[lane];
            let nnz_r = nnz_lane[lane];
            let mut col = col_acc[lane];
            let mut cnt = 0usize;
            let (mut a0, mut a1) = (0.0f64, 0.0f64);
            for i in 0..nps {
                if em >= nnz_r {
                    break;
                }
                let ds = (ent[lane][2 * i] >> 16) as usize;
                let vs = (ent[lane][2 * i + 1] >> 16) as usize;
                // SAFETY: symbol ids in packed entries are < num_symbols
                // by table construction (asserted above), independent of
                // stream contents.
                let delta = if unsafe { *plan.delta_escape.get_unchecked(ds) } {
                    let v = *m
                        .delta_escapes
                        .get(esc_d[lane])
                        .ok_or_else(|| DtansError::CorruptStream("delta escapes exhausted".into()))?;
                    esc_d[lane] += 1;
                    v
                } else {
                    unsafe { *plan.delta_of_sym.get_unchecked(ds) }
                };
                let val = if unsafe { *plan.value_escape.get_unchecked(vs) } {
                    let v = *plan
                        .value_escapes_f64
                        .get(esc_v[lane])
                        .ok_or_else(|| DtansError::CorruptStream("value escapes exhausted".into()))?;
                    esc_v[lane] += 1;
                    v
                } else {
                    unsafe { *plan.value_of_sym.get_unchecked(vs) }
                };
                col = if em == 0 || !m.delta_encode { delta } else { col + delta };
                // Checked x access: corrupt streams yield errors, not
                // panics (see proptests::prop_corrupted_streams_never_panic).
                let xv = *x
                    .get(col as usize)
                    .ok_or_else(|| DtansError::CorruptStream("column out of range".into()))?;
                cnt += 1;
                // Alternating accumulators break the addsd dependency
                // chain within a segment.
                if cnt % 2 == 0 {
                    a0 += val * xv;
                } else {
                    a1 += val * xv;
                }
                em += 1;
            }
            emitted[lane] = em;
            col_acc[lane] = col;
            acc[lane] += a0 + a1;
        }
        // --- Produce next-segment words (skipped by final segments). ---
        for g in 0..f {
            for lane in 0..lanes {
                if t + 1 >= nseg[lane] {
                    continue;
                }
                // Group push: fold gsz digit/base pairs into (d, r), using
                // the cached entries (no table re-gather).
                let (mut gd, mut gr) = (0u64, 1u64);
                for ps in g * gsz..(g + 1) * gsz {
                    let e = ent[lane][ps];
                    let base = (e & 0xff) as u64 + 1;
                    let digit = ((e >> 8) & 0xff) as u64;
                    gd = gd * base + digit;
                    gr *= base;
                }
                d[lane] = d[lane] * gr + gd;
                r[lane] *= gr;
                // (Perf pass note: a branchless cmov variant of this check
                // measured *slower* — the branch predicts well on real
                // symbol streams because hot rows extract consistently.)
                if r[lane] >= w_radix {
                    w[lane][g] = (d[lane] & (w_radix - 1)) as u32;
                    d[lane] >>= w_bits;
                    r[lane] >>= w_bits;
                } else {
                    let word = *stream
                        .get(pos)
                        .ok_or_else(|| DtansError::CorruptStream("stream exhausted".into()))?;
                    pos += 1;
                    w[lane][g] = word;
                }
            }
        }
        for k in f..o {
            for lane in 0..lanes {
                if t + 1 >= nseg[lane] {
                    continue;
                }
                let word = *stream
                    .get(pos)
                    .ok_or_else(|| DtansError::CorruptStream("stream exhausted".into()))?;
                pos += 1;
                w[lane][k] = word;
            }
        }
    }
    if pos != stream.len() {
        return Err(DtansError::CorruptStream(format!(
            "slice {slice}: consumed {pos}/{} words",
            stream.len()
        )));
    }
    for lane in 0..lanes {
        y_slice[lane] += acc[lane];
    }
    Ok(())
}

/// Fallback for non-preset parameter sets (identical logic, dynamic bounds).
fn spmv_slice_dyn(
    m: &CsrDtans,
    plan: &DecodePlan,
    slice: usize,
    x: &[f64],
    y_slice: &mut [f64],
) -> Result<()> {
    let p = &m.params;
    let (l, o, f) = (p.l as usize, p.o as usize, p.f as usize);
    let gsz = p.group_size() as usize;
    let nps = l / 2;
    let (w_bits, k_bits) = (p.w_bits as usize, p.k_bits as usize);
    let w_radix: u64 = 1 << w_bits;
    let k_mask: u64 = (p.k() - 1) as u64;

    let r0 = slice * WARP;
    let lanes = y_slice.len();
    let stream =
        &m.stream[m.slice_offsets[slice] as usize..m.slice_offsets[slice + 1] as usize];
    let dtab = &m.delta_tables.packed[..];
    let vtab = &m.value_tables.packed[..];
    // Invariants for the unchecked gathers below: slots are masked to
    // [0, K), both tables have exactly K entries, and symbol ids inside
    // packed entries are < num_symbols == plan array lengths by table
    // construction (they do not depend on stream contents).
    assert_eq!(dtab.len(), k_mask as usize + 1);
    assert_eq!(vtab.len(), k_mask as usize + 1);
    assert_eq!(plan.delta_of_sym.len(), m.delta_domain.num_symbols());
    assert_eq!(plan.value_of_sym.len(), m.value_domain.num_symbols());

    let mut pos = 0usize;

    // Flat per-lane state. `ent` caches the packed table entries of the
    // current segment's slots so the digit-fold phase does not re-gather
    // them (perf pass: -1 table load per symbol).
    let mut d = [0u64; WARP];
    let mut r = [1u64; WARP];
    let mut w = [[0u32; 8]; WARP]; // o <= 8
    let mut nseg = [0usize; WARP];
    let mut emitted = [0usize; WARP];
    let mut nnz_lane = [0usize; WARP];
    let mut col_acc = [0u32; WARP];
    let mut acc = [0.0f64; WARP];
    let mut esc_d = [0usize; WARP];
    let mut esc_v = [0usize; WARP];
    let mut ent = [[0u32; 16]; WARP]; // l <= 16
    debug_assert!(o <= 8 && l <= 16 && nps <= 8);

    let mut max_seg = 0usize;
    for lane in 0..lanes {
        let row = r0 + lane;
        nnz_lane[lane] = m.row_nnz[row] as usize;
        nseg[lane] = nnz_lane[lane].div_ceil(nps);
        max_seg = max_seg.max(nseg[lane]);
        esc_d[lane] = m.delta_esc_offsets[row] as usize;
        esc_v[lane] = m.value_esc_offsets[row] as usize;
    }

    // Initial o words (one event per word index — coalesced on the GPU).
    for k in 0..o {
        for lane in 0..lanes {
            if nseg[lane] > 0 {
                let word = *stream
                    .get(pos)
                    .ok_or_else(|| DtansError::CorruptStream("stream exhausted".into()))?;
                pos += 1;
                w[lane][k] = word;
            }
        }
    }

    // Perf notes (EXPERIMENTS.md §Perf): the unpack works on two u64
    // halves instead of a u128 (the 96-bit PAPER case), the packed table
    // entries are gathered once per symbol and cached in `ent` for the
    // digit-fold phase, and the per-position span split (low half / both /
    // high half) branches only on the loop counter, so it predicts
    // perfectly.
    for t in 0..max_seg {
        // --- Decode segment t of each active lane and accumulate. ---
        for lane in 0..lanes {
            if t >= nseg[lane] {
                continue;
            }
            // unpack: o words form a (w_bits*o <= 96)-bit number held as
            // (hi, lo) u64 halves; slots are its base-K digits.
            let (mut hi, mut lo) = (0u64, 0u64);
            for k in 0..o {
                hi = (hi << w_bits) | (lo >> (64 - w_bits));
                lo = (lo << w_bits) | w[lane][k] as u64;
            }
            for pos_s in 0..l {
                let b = k_bits * pos_s;
                let raw = if b + k_bits <= 64 {
                    lo >> b
                } else if b >= 64 {
                    hi >> (b - 64)
                } else {
                    (lo >> b) | (hi << (64 - b))
                };
                let slot = (raw & k_mask) as usize;
                // SAFETY: slot < K == table length (asserted above).
                ent[lane][pos_s] = unsafe {
                    if pos_s % 2 == 0 {
                        *dtab.get_unchecked(slot)
                    } else {
                        *vtab.get_unchecked(slot)
                    }
                };
            }
            // Resolve up to nps (column, value) pairs; the x-gathers and
            // FMAs run in a separate batched pass below so the loads of
            // all lanes are independent in the out-of-order window (perf
            // pass: the fused per-lane loop serialized on the x gather).
            let mut em = emitted[lane];
            let nnz_r = nnz_lane[lane];
            let mut col = col_acc[lane];
            let mut cnt = 0usize;
            let (mut a0, mut a1) = (0.0f64, 0.0f64);
            for i in 0..nps {
                if em >= nnz_r {
                    break;
                }
                let ds = (ent[lane][2 * i] >> 16) as usize;
                let vs = (ent[lane][2 * i + 1] >> 16) as usize;
                // SAFETY: symbol ids in packed entries are < num_symbols
                // by table construction (asserted above), independent of
                // stream contents.
                let delta = if unsafe { *plan.delta_escape.get_unchecked(ds) } {
                    let v = *m
                        .delta_escapes
                        .get(esc_d[lane])
                        .ok_or_else(|| DtansError::CorruptStream("delta escapes exhausted".into()))?;
                    esc_d[lane] += 1;
                    v
                } else {
                    unsafe { *plan.delta_of_sym.get_unchecked(ds) }
                };
                let val = if unsafe { *plan.value_escape.get_unchecked(vs) } {
                    let v = *plan
                        .value_escapes_f64
                        .get(esc_v[lane])
                        .ok_or_else(|| DtansError::CorruptStream("value escapes exhausted".into()))?;
                    esc_v[lane] += 1;
                    v
                } else {
                    unsafe { *plan.value_of_sym.get_unchecked(vs) }
                };
                col = if em == 0 || !m.delta_encode { delta } else { col + delta };
                // Checked x access: corrupt streams yield errors, not
                // panics (see proptests::prop_corrupted_streams_never_panic).
                let xv = *x
                    .get(col as usize)
                    .ok_or_else(|| DtansError::CorruptStream("column out of range".into()))?;
                cnt += 1;
                // Alternating accumulators break the addsd dependency
                // chain within a segment.
                if cnt % 2 == 0 {
                    a0 += val * xv;
                } else {
                    a1 += val * xv;
                }
                em += 1;
            }
            emitted[lane] = em;
            col_acc[lane] = col;
            acc[lane] += a0 + a1;
        }
        // --- Produce next-segment words (skipped by final segments). ---
        for g in 0..f {
            for lane in 0..lanes {
                if t + 1 >= nseg[lane] {
                    continue;
                }
                // Group push: fold gsz digit/base pairs into (d, r), using
                // the cached entries (no table re-gather).
                let (mut gd, mut gr) = (0u64, 1u64);
                for ps in g * gsz..(g + 1) * gsz {
                    let e = ent[lane][ps];
                    let base = (e & 0xff) as u64 + 1;
                    let digit = ((e >> 8) & 0xff) as u64;
                    gd = gd * base + digit;
                    gr *= base;
                }
                d[lane] = d[lane] * gr + gd;
                r[lane] *= gr;
                // (Perf pass note: a branchless cmov variant of this check
                // measured *slower* — the branch predicts well on real
                // symbol streams because hot rows extract consistently.)
                if r[lane] >= w_radix {
                    w[lane][g] = (d[lane] & (w_radix - 1)) as u32;
                    d[lane] >>= w_bits;
                    r[lane] >>= w_bits;
                } else {
                    let word = *stream
                        .get(pos)
                        .ok_or_else(|| DtansError::CorruptStream("stream exhausted".into()))?;
                    pos += 1;
                    w[lane][g] = word;
                }
            }
        }
        for k in f..o {
            for lane in 0..lanes {
                if t + 1 >= nseg[lane] {
                    continue;
                }
                let word = *stream
                    .get(pos)
                    .ok_or_else(|| DtansError::CorruptStream("stream exhausted".into()))?;
                pos += 1;
                w[lane][k] = word;
            }
        }
    }
    if pos != stream.len() {
        return Err(DtansError::CorruptStream(format!(
            "slice {slice}: consumed {pos}/{} words",
            stream.len()
        )));
    }
    for lane in 0..lanes {
        y_slice[lane] += acc[lane];
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ans::AnsParams;
    use crate::format::csr_dtans::EncodeOptions;
    use crate::matrix::gen::structured::{banded, powerlaw_rows, random_uniform, stencil2d5};
    use crate::matrix::gen::{assign_values, gen_graph_csr, GraphModel, ValueDist};
    use crate::matrix::{Csr, Precision};
    use crate::spmv::csr::spmv_csr;
    use crate::util::propcheck::assert_close;
    use crate::util::rng::Xoshiro256;

    fn check_matches_csr(m: &Csr, opts: &EncodeOptions, seed: u64) {
        let enc = CsrDtans::encode(m, opts).unwrap();
        let mut rng = Xoshiro256::seeded(seed);
        let x: Vec<f64> = (0..m.ncols).map(|_| rng.next_f64() - 0.5).collect();
        let mut want = vec![0.25; m.nrows];
        let reference = match opts.precision {
            Precision::F64 => m.clone(),
            Precision::F32 => m.round_to_f32(),
        };
        spmv_csr(&reference, &x, &mut want).unwrap();
        let mut got = vec![0.25; m.nrows];
        spmv_csr_dtans(&enc, &x, &mut got).unwrap();
        assert_close(&got, &want, 1e-12, 1e-12).unwrap();
        // Parallel variant agrees too.
        let pool = ThreadPool::new(4);
        let mut gp = vec![0.25; m.nrows];
        spmv_csr_dtans_parallel(&enc, &x, &mut gp, &pool).unwrap();
        assert_close(&gp, &want, 1e-12, 1e-12).unwrap();
    }

    #[test]
    fn banded_matches() {
        let mut m = banded(700, 5);
        assign_values(&mut m, ValueDist::FewDistinct(9), &mut Xoshiro256::seeded(1));
        check_matches_csr(&m, &EncodeOptions::default(), 11);
    }

    #[test]
    fn stencil_matches_kernel_params() {
        let m = stencil2d5(25, 25);
        check_matches_csr(
            &m,
            &EncodeOptions {
                params: AnsParams::KERNEL,
                ..Default::default()
            },
            12,
        );
    }

    #[test]
    fn graph_with_random_values_escapes() {
        let mut rng = Xoshiro256::seeded(2);
        let mut m = gen_graph_csr(GraphModel::ErdosRenyi, 500, 7.0, &mut rng);
        assign_values(&mut m, ValueDist::Gaussian, &mut rng);
        check_matches_csr(&m, &EncodeOptions::default(), 13);
    }

    #[test]
    fn f32_precision_matches_rounded_reference() {
        let mut rng = Xoshiro256::seeded(3);
        let mut m = random_uniform(300, 200, 2500, &mut rng);
        assign_values(&mut m, ValueDist::Quantized(128), &mut rng);
        check_matches_csr(
            &m,
            &EncodeOptions {
                precision: Precision::F32,
                ..Default::default()
            },
            14,
        );
    }

    #[test]
    fn irregular_power_law_matches() {
        let mut rng = Xoshiro256::seeded(4);
        let mut m = powerlaw_rows(400, 7.0, 1.1, &mut rng);
        assign_values(&mut m, ValueDist::SmallInts(3), &mut rng);
        check_matches_csr(&m, &EncodeOptions::default(), 15);
        check_matches_csr(
            &m,
            &EncodeOptions {
                params: AnsParams::KERNEL,
                ..Default::default()
            },
            16,
        );
    }

    #[test]
    fn empty_rows_and_empty_matrix() {
        check_matches_csr(&Csr::new(40, 40), &EncodeOptions::default(), 17);
        let mut coo = crate::matrix::coo::Coo::new(65, 65);
        coo.push(64, 64, 2.0); // single nonzero in last slice
        check_matches_csr(&Csr::from_coo(&coo), &EncodeOptions::default(), 18);
    }
}
