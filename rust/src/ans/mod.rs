//! The entropy-coding core: classic tabled ANS (tANS, §III of the paper)
//! as a reference implementation, and **dtANS** (§IV), the decoupled
//! variant designed for fast parallel GPU decoding.
//!
//! dtANS differs from tANS in two ways that matter for GPUs:
//!
//! 1. **Word streams instead of bit streams.** The compressed stream `v`
//!    holds `W`-radix words (4-byte words on the GPU). Threads of a warp
//!    share one interleaved stream; per decoded segment each thread needs
//!    at most `o` words, of which `f` are *conditional* (extracted from the
//!    decoder state when its radix `r ≥ W`, loaded from the stream
//!    otherwise) and `o − f` unconditional.
//! 2. **Segments instead of per-symbol dependencies.** `l` symbols are
//!    decoded at once from an `unpack` of the `o` buffered words, restoring
//!    instruction-level parallelism that the sequential tANS state update
//!    destroys; the returned digit/base pairs are then folded back into the
//!    decoder state group-wise.
//!
//! The encoder is the paper's two-pass scheme: a forward *base pass* that
//! replays only the radix `r` (and therefore the exact branch pattern of
//! the decoder), and a backward *digit pass* that picks slots via
//! `digit = d mod base` — exactly inverse to the decoder.
//!
//! Correctness hinges on an exact invariant we maintain (and property-test):
//! the backward encoder state is always `< r` of the forward replay at the
//! same point; since `r = 1` at stream start, the leftover state is forced
//! to 0 — which is why the decoder can initialize `d = 0, r = 1`.

pub mod dtans;
pub mod histogram;
pub mod params;
pub mod tables;
pub mod tans;

pub use dtans::{decode_row, encode_row, RowDecoder, RowEncoding};
pub use histogram::normalize_counts;
pub use params::AnsParams;
pub use tables::CodingTables;
