//! Tier-1 observability suite: end-to-end span chains through the
//! serving pipeline, histogram accuracy against exact quantiles,
//! sampling, Chrome-trace structural validity, Prometheus exposition,
//! and the span-conservation oracle under a miniature open-loop stress
//! run. (Fast: debug-lane sized matrices throughout.)

use dtans::coordinator::{RoutePolicy, ServiceConfig, SpmvService};
use dtans::matrix::gen::structured::banded;
use dtans::matrix::gen::{assign_values, ValueDist};
use dtans::obs::export::{metrics_json, prometheus_text};
use dtans::obs::{LogHistogram, ObsConfig, Stage};
use dtans::testkit::{run_stress, StressConfig};
use dtans::util::rng::Xoshiro256;

#[test]
fn histogram_quantiles_stay_within_two_percent_of_exact() {
    let mut h = LogHistogram::new();
    let mut rng = Xoshiro256::seeded(0x0B5);
    let mut exact: Vec<u64> = (0..40_000)
        .map(|_| (rng.next_u64() % 1_000) << (rng.next_u64() % 16))
        .collect();
    for &v in &exact {
        h.record(v);
    }
    exact.sort_unstable();
    for p in [0.50, 0.90, 0.99, 0.999] {
        let got = h.quantile(p) as f64;
        let idx = ((p * exact.len() as f64).ceil() as usize).clamp(1, exact.len()) - 1;
        let want = exact[idx] as f64;
        // The bucket scheme guarantees ≤ 2^-7 relative error per sample;
        // 2% is the documented (conservative) contract.
        assert!(
            (got - want).abs() <= 0.02 * want.max(1.0),
            "p{p}: got {got}, exact {want}"
        );
    }
    assert_eq!(h.count(), 40_000);
    assert_eq!(h.max(), *exact.last().unwrap());
}

#[test]
fn sampling_honors_one_in_n_end_to_end() {
    // 16 warm submits through a service sampling one request in four:
    // exactly the spans with trace id divisible by 4 may record events.
    // (No cold loads here — those would consume trace ids of their own.)
    let svc = SpmvService::start(ServiceConfig {
        obs: ObsConfig { sample_one_in: 4, capacity: 4096 },
        ..Default::default()
    });
    let m = banded(96, 2);
    let id = svc.register("m", m).unwrap();
    let pendings: Vec<_> = (0..16).map(|_| svc.submit(id, vec![1.0; 96]).unwrap()).collect();
    for p in pendings {
        p.wait().unwrap();
    }
    let events = svc.metrics.tracer().drain();
    assert!(!events.is_empty());
    let mut sampled: Vec<u64> = events
        .iter()
        .filter(|e| matches!(e.stage, Stage::Submitted { .. }))
        .map(|e| e.span.0)
        .collect();
    sampled.sort_unstable();
    assert_eq!(sampled, vec![4, 8, 12, 16]);
    assert!(events.iter().all(|e| e.span.0 % 4 == 0));
    // Each sampled request still carries a complete chain: exactly one
    // terminal per sampled span.
    for want in [4u64, 8, 12, 16] {
        let terminals = events
            .iter()
            .filter(|e| e.span.0 == want && e.stage.is_terminal())
            .count();
        assert_eq!(terminals, 1, "span {want}");
    }
}

/// Minimal structural JSON validator: tracks string/escape state and
/// brace/bracket depth. Catches unbalanced nesting, naked control
/// characters and trailing garbage without pulling in a JSON parser.
fn assert_structurally_valid_json(s: &str) {
    let (mut depth, mut in_str, mut escaped) = (0i64, false, false);
    let mut stack: Vec<char> = Vec::new();
    for c in s.chars() {
        if in_str {
            if escaped {
                escaped = false;
            } else if c == '\\' {
                escaped = true;
            } else if c == '"' {
                in_str = false;
            } else {
                assert!(!c.is_control(), "raw control character inside string");
            }
            continue;
        }
        match c {
            '"' => in_str = true,
            '{' | '[' => {
                stack.push(c);
                depth += 1;
            }
            '}' => {
                assert_eq!(stack.pop(), Some('{'), "mismatched closing brace");
                depth -= 1;
            }
            ']' => {
                assert_eq!(stack.pop(), Some('['), "mismatched closing bracket");
                depth -= 1;
            }
            _ => {}
        }
        assert!(depth >= 0, "negative nesting depth");
    }
    assert!(!in_str, "unterminated string");
    assert_eq!(depth, 0, "unbalanced braces/brackets");
}

#[test]
fn chrome_trace_export_is_valid_and_carries_the_pipeline() {
    let svc = SpmvService::start(ServiceConfig::default());
    let m = banded(128, 2);
    let id = svc.register("m", m).unwrap();
    for _ in 0..4 {
        svc.spmv(id, vec![1.0; 128]).unwrap();
    }
    let json = svc.metrics.tracer().trace_json();
    assert_structurally_valid_json(&json);
    assert!(json.starts_with("{\"displayTimeUnit\":\"ms\",\"traceEvents\":["));
    // Thread metadata for the labelled tracks, complete events for the
    // duration-bearing stages, instants for the rest.
    assert!(json.contains("\"thread_name\""));
    assert!(json.contains("dispatcher-"));
    assert!(json.contains("worker-"));
    assert!(json.contains("\"ph\":\"X\""));
    assert!(json.contains("\"ph\":\"i\""));
    for stage in ["submitted", "queued", "dispatched", "pinned", "kernel", "completed"] {
        assert!(json.contains(&format!("\"name\":\"{stage}\"")), "missing {stage}");
    }
    // The JSON snapshot re-exports the same surface, also valid.
    let snap = metrics_json(&svc.metrics);
    assert_structurally_valid_json(&snap);
}

#[test]
fn prometheus_exposition_covers_paper_and_pipeline_metrics() {
    // A dtANS-routed matrix (structured values, above the nnz floor) so
    // the paper gauges — compression ratio and decode throughput — are
    // populated, plus enough traffic for queue-wait and block-timing
    // histograms.
    let svc = SpmvService::start(ServiceConfig {
        policy: RoutePolicy { min_nnz: 1 << 10, max_size_ratio: 0.9, ..Default::default() },
        ..Default::default()
    });
    let mut m = banded(4000, 2);
    assign_values(&mut m, ValueDist::Ones, &mut Xoshiro256::seeded(2));
    let id = svc.register("big", m).unwrap();
    assert_eq!(svc.format_of(id).unwrap().tag(), "csr_dtans");
    for _ in 0..3 {
        svc.spmv(id, vec![1.0; 4000]).unwrap();
    }
    let report = svc.metrics.report();
    for needle in ["qwait_p50=", "qwait_p99=", "blk_imb=", "paper[big]:", "ratio=", "decode="] {
        assert!(report.contains(needle), "report missing {needle}: {report}");
    }
    let text = prometheus_text(&svc.metrics);
    for needle in [
        "# TYPE dtans_requests_submitted_total counter",
        "dtans_requests_completed_total 3",
        "dtans_queue_depth ",
        "dtans_stage_duration_microseconds_bucket{stage=\"queue_wait\",le=\"+Inf\"} 3",
        "dtans_kernel_block_microseconds_count{stat=\"mean\"} 3",
        "dtans_block_imbalance_ratio ",
        "dtans_matrix_compression_ratio{matrix=\"big\"} ",
        "dtans_matrix_decode_bytes_per_second{matrix=\"big\"} ",
        "dtans_format_requests_total{format=\"csr_dtans\",outcome=\"completed\"} 3",
        "dtans_trace_events_recorded_total ",
    ] {
        assert!(text.contains(needle), "exposition missing {needle}");
    }
    // Histogram buckets must be cumulative (monotone in le) and close
    // with +Inf == _count, for every series in the exposition.
    let mut last: Option<(String, u64)> = None;
    for line in text.lines() {
        if let Some((name_labels, value)) = line.split_once(' ') {
            if !name_labels.contains("_bucket{") {
                last = None;
                continue;
            }
            // Series key = everything before the `le` label (`le` is
            // always the last label in the exposition).
            let series = match name_labels.find("le=\"") {
                Some(i) => name_labels[..i].to_string(),
                None => continue,
            };
            let v: u64 = value.parse().unwrap();
            if let Some((prev_series, prev_v)) = &last {
                if *prev_series == series {
                    assert!(v >= *prev_v, "non-monotone buckets in {series}");
                }
            }
            last = Some((series, v));
        }
    }
}

#[test]
fn span_conservation_holds_under_open_loop_stress() {
    // A miniature open-loop run: sheds and injected deadline expiries
    // interleave with completions, and the stress driver's Oracle 4
    // reconciles every drained span chain against the service counters.
    let cfg = StressConfig {
        threads: 2,
        ops: 40,
        seed: 0x0B5E7,
        budget_bytes: Some(128 * 1024),
        par: dtans::spmv::engine::ParStrategy::Auto,
        open_loop: true,
        queue_depth: 8,
    };
    let report = run_stress(&cfg).unwrap();
    assert_eq!(report.ops_executed, 40);
    assert!(report.spmv_checked + report.spmm_checked + report.solves_checked > 0);
}
