//! CSR SpMVM kernels: the scalar (one row per thread) and vector (one warp
//! per row) variants of cuSPARSE/Bell-Garland [34]. On the CPU both reduce
//! to the same arithmetic; they differ in the *memory schedule* the GPU
//! simulator charges, so both exist as named kernels.

use crate::matrix::csr::Csr;
use crate::util::error::Result;

/// Scalar CSR kernel: each row's dot product in sequence.
///
/// Accumulates into `y` (`y += A·x`); zero `y` first for a plain product.
///
/// ```
/// use dtans::matrix::{Coo, Csr};
/// use dtans::spmv::spmv_csr;
/// let mut coo = Coo::new(2, 2);
/// coo.push(0, 0, 2.0);
/// coo.push(1, 1, 3.0);
/// let m = Csr::from_coo(&coo);
/// let mut y = vec![1.0, 0.0]; // note the nonzero initial entry
/// spmv_csr(&m, &[10.0, 10.0], &mut y).unwrap();
/// assert_eq!(y, vec![21.0, 30.0]);
/// ```
pub fn spmv_csr(m: &Csr, x: &[f64], y: &mut [f64]) -> Result<()> {
    super::check_dims(m.nrows, m.ncols, x, y)?;
    spmv_row_range(m, 0, m.nrows, x, y)
}

/// Scalar CSR kernel over rows `r0..r1`; `y_seg[i]` accumulates row
/// `r0 + i`. The whole-matrix [`spmv_csr`] is the `0..nrows` case and the
/// parallel engine fans out disjoint ranges, so both paths share one loop
/// and bit-identical results hold by construction.
pub(crate) fn spmv_row_range(
    m: &Csr,
    r0: usize,
    r1: usize,
    x: &[f64],
    y_seg: &mut [f64],
) -> Result<()> {
    debug_assert_eq!(y_seg.len(), r1 - r0);
    for (i, r) in (r0..r1).enumerate() {
        let lo = m.row_ptr[r];
        let hi = m.row_ptr[r + 1];
        let mut acc = 0.0;
        for k in lo..hi {
            acc += m.vals[k] * x[m.cols[k] as usize];
        }
        y_seg[i] += acc;
    }
    Ok(())
}

/// Fused scaled update over rows `r0..r1`:
/// `y_seg[i] = alpha·(A·x)[r0 + i] + beta·y_seg[i]`.
///
/// Shares [`spmv_row_range`]'s per-row accumulation (same terms, same
/// order, same local accumulator starting at `0.0`), then applies the
/// `alpha·acc + beta·y` update in place of the `y += acc` accumulate — the
/// exact float operations the unfused "multiply into a zeroed temporary,
/// then axpby" compose performs, minus the temporary. This is what makes
/// [`SpmvEngine::run_axpby`](crate::spmv::engine::SpmvEngine::run_axpby)
/// bit-identical to the unfused compose on the CSR path.
pub(crate) fn spmv_row_range_axpby(
    m: &Csr,
    r0: usize,
    r1: usize,
    x: &[f64],
    alpha: f64,
    beta: f64,
    y_seg: &mut [f64],
) -> Result<()> {
    debug_assert_eq!(y_seg.len(), r1 - r0);
    for (i, r) in (r0..r1).enumerate() {
        let lo = m.row_ptr[r];
        let hi = m.row_ptr[r + 1];
        let mut acc = 0.0;
        for k in lo..hi {
            acc += m.vals[k] * x[m.cols[k] as usize];
        }
        y_seg[i] = alpha * acc + beta * y_seg[i];
    }
    Ok(())
}

/// Vector CSR kernel: rows processed in warp-sized gangs with a lane-strided
/// inner loop (the GPU schedule; numerically reassociated, which matters
/// only at the f64 ulp level).
///
/// ```
/// use dtans::matrix::{Coo, Csr};
/// use dtans::spmv::{spmv_csr, spmv_csr_vector};
/// let mut coo = Coo::new(1, 4);
/// for c in 0..4 { coo.push(0, c, 1.0 + c as f64); }
/// let m = Csr::from_coo(&coo);
/// let x = [1.0, -1.0, 0.5, 0.25];
/// let (mut y, mut yv) = (vec![0.0], vec![0.0]);
/// spmv_csr(&m, &x, &mut y).unwrap();
/// spmv_csr_vector(&m, &x, &mut yv, 32).unwrap();
/// assert!((y[0] - yv[0]).abs() < 1e-12);
/// ```
pub fn spmv_csr_vector(m: &Csr, x: &[f64], y: &mut [f64], warp: usize) -> Result<()> {
    super::check_dims(m.nrows, m.ncols, x, y)?;
    let warp = warp.max(1);
    for r in 0..m.nrows {
        let lo = m.row_ptr[r];
        let hi = m.row_ptr[r + 1];
        // Lane-strided partial sums, then a tree-style reduction.
        let nlanes = warp.min(hi - lo).max(1);
        let mut partial = vec![0.0f64; nlanes];
        for (k, i) in (lo..hi).enumerate() {
            partial[k % nlanes] += m.vals[i] * x[m.cols[i] as usize];
        }
        y[r] += partial.iter().sum::<f64>();
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::coo::Coo;
    use crate::spmv::dense::spmv_dense;
    use crate::util::propcheck::assert_close;

    fn example() -> Csr {
        let mut coo = Coo::new(4, 4);
        for &(r, c, v) in &[(0, 1, 7.0), (0, 3, 5.0), (1, 0, 3.0), (1, 2, 2.0), (2, 1, 4.0), (3, 3, 1.0)] {
            coo.push(r, c, v);
        }
        Csr::from_coo(&coo)
    }

    #[test]
    fn matches_dense() {
        let m = example();
        let x = vec![1.0, 2.0, 3.0, 4.0];
        let mut y = vec![0.5; 4];
        let mut yd = vec![0.5; 4];
        spmv_csr(&m, &x, &mut y).unwrap();
        spmv_dense(&m.to_dense(), 4, 4, &x, &mut yd).unwrap();
        assert_close(&y, &yd, 1e-12, 0.0).unwrap();
    }

    #[test]
    fn vector_variant_matches() {
        let m = example();
        let x = vec![1.0, -2.0, 0.25, 4.0];
        let mut y1 = vec![0.0; 4];
        let mut y2 = vec![0.0; 4];
        spmv_csr(&m, &x, &mut y1).unwrap();
        spmv_csr_vector(&m, &x, &mut y2, 32).unwrap();
        assert_close(&y1, &y2, 1e-12, 1e-15).unwrap();
    }

    #[test]
    fn row_range_blocks_reassemble_bitwise() {
        let m = example();
        let x = vec![1.0, 2.0, 3.0, 4.0];
        let mut want = vec![0.5; 4];
        spmv_csr(&m, &x, &mut want).unwrap();
        let mut got = vec![0.5; 4];
        for (r0, r1) in [(0usize, 1usize), (1, 3), (3, 4)] {
            spmv_row_range(&m, r0, r1, &x, &mut got[r0..r1]).unwrap();
        }
        assert_eq!(got, want); // bit-identical, not just close
    }

    #[test]
    fn axpby_range_matches_unfused_compose_bitwise() {
        let m = example();
        let x = vec![1.0, -2.0, 3.0, 0.5];
        let y0 = vec![0.25, -1.5, 2.0, 7.0];
        for &(alpha, beta) in &[(1.0, 0.0), (-0.5, 1.0), (2.5, -0.75), (0.0, 0.0)] {
            // Unfused reference: multiply into a zeroed temporary, then axpby.
            let mut tmp = vec![0.0; 4];
            spmv_csr(&m, &x, &mut tmp).unwrap();
            let want: Vec<f64> =
                y0.iter().zip(&tmp).map(|(y, t)| alpha * t + beta * y).collect();
            let mut got = y0.clone();
            spmv_row_range_axpby(&m, 0, 4, &x, alpha, beta, &mut got).unwrap();
            assert_eq!(got, want, "alpha={alpha} beta={beta}");
            // Disjoint ranges reassemble to the same answer.
            let mut parts = y0.clone();
            for (r0, r1) in [(0usize, 2usize), (2, 3), (3, 4)] {
                spmv_row_range_axpby(&m, r0, r1, &x, alpha, beta, &mut parts[r0..r1]).unwrap();
            }
            assert_eq!(parts, want);
        }
    }

    #[test]
    fn accumulates_into_y() {
        let m = example();
        let x = vec![1.0; 4];
        let mut y = vec![100.0; 4];
        spmv_csr(&m, &x, &mut y).unwrap();
        assert_eq!(y[3], 101.0);
    }
}
