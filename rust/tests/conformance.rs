//! Tier-1 conformance: the differential oracle over the pathological zoo
//! and a corpus sample, its negative self-tests (a deliberately perturbed
//! operator must be detected and localized), and the seeded
//! concurrency-stress driver at the `TESTKIT_SCALE` size.

use dtans::format::csr_dtans::EncodeOptions;
use dtans::matrix::gen::structured::banded;
use dtans::matrix::gen::{assign_values, ValueDist};
use dtans::matrix::{Csr, Precision};
use dtans::spmv::engine::KernelVariant;
use dtans::spmv::{FormatEntry, FormatRegistry, SpmvOperator};
use dtans::testkit::oracle::{self, MismatchKind, OracleConfig, PerturbedOperator};
use dtans::testkit::{run_stress, zoo, StressConfig, TestkitScale};
use dtans::util::rng::Xoshiro256;
use std::sync::Arc;

#[test]
fn pathological_zoo_is_conformant_across_formats_variants_and_partitions() {
    // The full cross-product sweep: every builtin format × every kernel
    // variant × serial + every partition count, on every zoo fixture.
    let cfg = OracleConfig::default();
    let registry = FormatRegistry::builtin();
    for f in zoo::pathological() {
        let report = oracle::cross_check_with(&f.csr, &cfg, &registry, &KernelVariant::ALL)
            .unwrap_or_else(|e| panic!("{}: oracle errored: {e}", f.name));
        assert!(report.is_conformant(), "{}: {report}", f.name);
        // Every fixture must actually exercise the zoo — at least the
        // CSR, COO, SELL, BlockedELL and dtANS builders accept all of
        // these shapes.
        assert!(report.formats.len() >= 5, "{}: only {:?}", f.name, report.formats);
        assert!(report.formats.contains(&"blocked_ell"), "{}", f.name);
        assert_eq!(report.strategies, KernelVariant::ALL.len() * (cfg.max_parts + 1));
    }
}

#[test]
fn corpus_sample_is_conformant() {
    use dtans::eval::{build_corpus, CorpusScale};
    let corpus = build_corpus(&CorpusScale { max_nnz: 4000, steps: 2 }, 21);
    let cfg = OracleConfig { max_parts: 6, ..Default::default() };
    for e in corpus.iter().step_by(3) {
        let report = oracle::check_matrix(&e.csr, &cfg)
            .unwrap_or_else(|err| panic!("{}: oracle errored: {err}", e.name));
        assert!(report.is_conformant(), "{}: {report}", e.name);
    }
}

#[test]
fn mixed_zoo_is_conformant_at_f32_precision_too() {
    let cfg = OracleConfig {
        opts: EncodeOptions { precision: Precision::F32, ..Default::default() },
        max_parts: 5,
        ..Default::default()
    };
    for (i, m) in zoo::mixed_zoo().iter().step_by(2).enumerate() {
        let report = oracle::check_matrix(m, &cfg).unwrap();
        assert!(report.is_conformant(), "mixed zoo matrix {i}: {report}");
    }
}

/// Negative self-test 1: a partition-dependent single-ULP output flip
/// must be detected with format tag, partition count and divergent row.
#[test]
fn oracle_detects_partition_dependent_single_ulp_flip() {
    let mut m = banded(220, 3);
    assign_values(&mut m, ValueDist::FewDistinct(7), &mut Xoshiro256::seeded(4));
    let target_row = 133;
    for (label, op) in [
        ("csr", Arc::new(m.clone()) as Arc<dyn SpmvOperator>),
        ("sell", Arc::new(dtans::matrix::Sell::from_csr(&m, 32)) as Arc<dyn SpmvOperator>),
    ] {
        let bad = PerturbedOperator::new(op, target_row);
        let report = oracle::check_operator(&bad, &m, &OracleConfig::default()).unwrap();
        assert!(!report.is_conformant(), "{label}: perturbation went undetected");
        let first = &report.mismatches[0];
        assert_eq!(first.kind, MismatchKind::ParallelDivergence, "{label}");
        assert_eq!(first.format, label);
        assert!(first.parts >= 2, "{label}: detected at parts={}", first.parts);
        assert_eq!(first.row, target_row, "{label}");
        assert_eq!(first.ulps, 1, "{label}");
    }
}

/// Negative self-test 2: one flipped bit in one stored matrix *value*
/// (injected through a shadowed registry builder) must be detected by the
/// cross-format level with the format tag and the divergent row.
#[test]
fn oracle_detects_one_flipped_value_bit_via_registry() {
    fn build_csr_with_flipped_value(
        m: &Csr,
        _opts: &EncodeOptions,
    ) -> dtans::Result<Arc<dyn SpmvOperator>> {
        let mut m = m.clone();
        // Flip an exponent bit of the first stored value: a decisive,
        // single-bit corruption of the operator's data.
        let v = m.vals.first_mut().expect("nonempty fixture");
        *v = f64::from_bits(v.to_bits() ^ (1 << 62));
        Ok(Arc::new(m))
    }

    let mut m = banded(180, 2);
    assign_values(&mut m, ValueDist::FewDistinct(5), &mut Xoshiro256::seeded(8));
    let mut registry = FormatRegistry::builtin();
    registry.register(FormatEntry { tag: "csr", build: build_csr_with_flipped_value });

    let report =
        oracle::check_matrix_with(&m, &OracleConfig::default(), &registry).unwrap();
    assert!(!report.is_conformant(), "flipped value bit went undetected");
    let cross: Vec<_> = report
        .mismatches
        .iter()
        .filter(|mm| mm.kind == MismatchKind::CrossFormat)
        .collect();
    assert!(!cross.is_empty(), "no cross-format mismatch reported: {report}");
    let mm = cross[0];
    assert_eq!(mm.format, "csr");
    assert_eq!(mm.parts, 0, "cross-format checks run serially");
    // vals[0] lives in row 0 of a banded matrix.
    assert_eq!(mm.row, 0);
    assert!(mm.ulps > 0);
    // The healthy formats must NOT be implicated.
    assert!(cross.iter().all(|mm| mm.format == "csr"), "{report}");
}

/// The stress acceptance gate: a seeded multi-threaded mixed trace
/// (≥ 4 threads, ≥ 200 requests, an eviction budget far below the
/// working set) completes with bit-identical serial replay, summed
/// metrics and zero leaked pins. Scale via `TESTKIT_SCALE`
/// (small/medium/large; CI pins small).
#[test]
fn stress_trace_is_bit_identical_with_zero_leaked_pins() {
    let scale = TestkitScale::from_env();
    let cfg = StressConfig::for_scale(scale);
    assert!(cfg.threads >= 4 && cfg.ops >= 200);
    let report = run_stress(&cfg)
        .unwrap_or_else(|e| panic!("stress run ({}) failed: {e}", scale.label()));
    assert_eq!(report.ops_executed, cfg.ops);
    assert!(report.spmv_checked > 0, "{report:?}");
    assert!(report.spmm_checked > 0, "{report:?}");
    assert!(report.solves_checked > 0, "{report:?}");
    // The budget must actually have forced eviction/cold-reload traffic —
    // otherwise the run proved nothing about the store under pressure.
    assert!(report.evictions >= 1, "{}", report.metrics_report);
    assert!(report.cold_loads >= 1, "{}", report.metrics_report);
}
