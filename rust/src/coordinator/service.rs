//! The SpMVM service: store-backed matrix registry + admission-controlled
//! request batcher + worker pool, executing over the parallel SpMV
//! engine.
//!
//! Requests `(matrix_id, x)` enter through the bounded
//! [`AdmissionQueue`] ([`super::admission`]): [`SpmvService::submit`]
//! either admits the request or sheds it *at submit time* with a typed
//! error ([`DtansError::Overloaded`] at capacity,
//! [`DtansError::QuotaExceeded`] on an exhausted tenant bucket,
//! [`DtansError::QueueClosed`] during shutdown). The dispatcher pulls
//! coalesced batches — **all** queued requests for the dispatch target's
//! matrix, across priority lanes and regardless of interleaving, not
//! just consecutive arrivals — rejects any whose
//! [deadline](SubmitOptions::deadline) has elapsed
//! ([`DtansError::DeadlineExceeded`], checked once, immediately before
//! execution), and hands the survivors to the worker pool (amortizing
//! plan lookups and keeping the decode tables hot, the same motivation
//! as GPU batching). See `docs/SERVING.md` for the full admission
//! contract. Singleton batches run as jobs on a worker pool; multi-request batches
//! take the SpMM fast path — the batch packed into one contiguous
//! column-major [`DenseMat`] and run through a single multi-RHS engine
//! call, fanning the (request × row-block) grid across the engine's
//! threads. Either way the kernel work is format-agnostic: every matrix
//! carries its routed
//! [`SpmvOperator`](crate::spmv::operator::SpmvOperator) and the shared
//! [`SpmvEngine`] executes `run`/`run_multi` against that trait object,
//! with the [`ParStrategy`] coming from [`ServiceConfig::par`]
//! (`ParStrategy::Serial` restores the old one-thread-per-request
//! behavior). Responses are delivered over per-request channels; metrics
//! are recorded per executing `format_tag()`. Everything is std-thread
//! based.
//!
//! Routing is static by default (the registration-time [`RoutePolicy`]
//! choice). With [`ServiceConfig::adaptive`] enabled, singleton requests
//! instead consult the [`AdaptiveRouter`] — a per-matrix
//! latency-learning cost model with epsilon-greedy exploration and
//! hysteresis-gated route flips (`docs/ROUTING.md`). The adaptive path
//! times every kernel on the exact arm it routed to and feeds the
//! latency back ([`AdaptiveRouter::observe`]); coalesced SpMM batches
//! and whole solves stay on the registered route, and matrices retire
//! from adaptation on their first [`SpmvService::append`] (the overlaid
//! composite operator is the only correct execution surface).
//!
//! Matrix lifetime is owned by the tiered [`MatrixStore`]
//! ([`crate::store`]): registration goes through the on-disk artifact
//! cache (re-registering a known matrix skips encoding), and residency is
//! governed by [`StoreConfig::budget_bytes`]. Pool workers acquire each
//! matrix through a pin guard — cold matrices fault in from disk
//! transparently (deduped across concurrent requests), and the pin keeps
//! them resident until their batch completes. The dispatcher itself
//! routes on metadata only and never blocks on a cold load, so one cold
//! matrix cannot head-of-line-block warm traffic. Registered matrices are
//! mutable through [`SpmvService::append`] — delta overlays composed with
//! the immutable base, versioned and background-compacted
//! ([`crate::delta`], `docs/MUTATION.md`) — without any change to the
//! request path: the routed operator is swapped atomically under the
//! store's pin-quiesce.
//!
//! Beyond one-shot multiplies, the service runs whole **iterative
//! solves** ([`SpmvService::solve`], [`SpmvService::power`],
//! [`SpmvService::pagerank`]): the matrix is pinned once for the entire
//! solve, every iteration executes on the shared engine against the
//! routed operator, and the solve lands in [`Metrics`] as one
//! request-level sample carrying its iteration count and outcome (see
//! `docs/SOLVERS.md`).

use super::adaptive::{
    sim_seeds, AdaptiveConfig, AdaptiveRouter, ParHint, RouteOverride, SeedSource,
};
use super::admission::{AdmissionConfig, AdmissionQueue, SubmitOptions};
use super::metrics::Metrics;
use super::router::{FormatChoice, RoutePolicy};
use crate::format::csr_dtans::EncodeOptions;
use crate::matrix::csr::Csr;
use crate::obs::{ObsConfig, SpanId, Stage};
use crate::solver::{self, PowerSolution, Solution, SolveMethod, SolverConfig};
use crate::spmv::densemat::DenseMat;
use crate::spmv::engine::{KernelVariant, ParStrategy, SpmvEngine};
use crate::store::{MatrixStore, PinnedMatrix, StoreConfig};
use crate::util::error::{DtansError, Result};
use std::path::Path;
use std::sync::atomic::Ordering;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::time::Instant;

pub use crate::store::LoadedMatrix;

/// The admission queue's payload: everything about a request except the
/// coalescing key and scheduling fields, which live on
/// [`Admitted`](super::admission::Admitted).
struct Job {
    x: Vec<f64>,
    submitted: Instant,
    resp: Sender<Result<Vec<f64>>>,
    /// Trace span opened at submit ([`SpanId::NONE`] when unsampled —
    /// every `record` on it is a no-op, so the pipeline never branches
    /// on the tracing config).
    span: SpanId,
}

/// One dispatched SpMVM request (admission already passed, deadline
/// already checked).
struct Request {
    matrix: u64,
    x: Vec<f64>,
    submitted: Instant,
    resp: Sender<Result<Vec<f64>>>,
    span: SpanId,
}

/// Service configuration.
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    /// Worker threads (request-level parallelism for singleton batches).
    pub workers: usize,
    /// Max requests fused into one batch.
    pub max_batch: usize,
    /// Encoding options for registered matrices.
    pub encode: EncodeOptions,
    /// Routing policy.
    pub policy: RoutePolicy,
    /// Kernel-level parallelism: the [`ParStrategy`] of the shared
    /// [`SpmvEngine`] every request executes on. `Auto` (default) splits
    /// large multiplies across all CPUs and runs small ones serially;
    /// `Serial` restores pre-engine behavior.
    pub par: ParStrategy,
    /// Kernel variant of the shared engine: `Scalar` (default) runs the
    /// classic left-to-right kernels; `Unrolled4`/`Unrolled8` select the
    /// wide-accumulator kernels (reassociation policy in
    /// `docs/KERNELS.md`). Per-variant results stay deterministic across
    /// `par` and partition counts.
    pub kernel_variant: KernelVariant,
    /// Storage tier: artifact cache directory, residency byte budget,
    /// CSR-original dropping, loader threads. The default keeps
    /// everything in RAM with no persistence (the pre-store behavior).
    pub store: StoreConfig,
    /// Admission control: bounded queue depth, coalescing gather window,
    /// per-tenant quotas (see [`AdmissionConfig`] and `docs/SERVING.md`).
    pub admission: AdmissionConfig,
    /// Observability: request-flow span sampling and collector capacity
    /// (see [`ObsConfig`] and `docs/OBSERVABILITY.md`). The default
    /// traces every request; `sample_one_in: 0` turns the tracer off
    /// entirely (kernels run untimed, spans cost nothing).
    pub obs: ObsConfig,
    /// Online adaptive routing ([`AdaptiveConfig`], `docs/ROUTING.md`).
    /// The default is **disabled**: requests execute the registered
    /// operator exactly as static-routing builds did.
    pub adaptive: AdaptiveConfig,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            workers: 2,
            max_batch: 16,
            encode: EncodeOptions::default(),
            policy: RoutePolicy::default(),
            par: ParStrategy::Auto,
            kernel_variant: KernelVariant::default(),
            store: StoreConfig::default(),
            admission: AdmissionConfig::default(),
            obs: ObsConfig::default(),
            adaptive: AdaptiveConfig::default(),
        }
    }
}

/// Handle for a pending response.
pub struct Pending {
    rx: Receiver<Result<Vec<f64>>>,
}

impl Pending {
    /// Block until the result arrives.
    pub fn wait(self) -> Result<Vec<f64>> {
        self.rx
            .recv()
            .map_err(|_| DtansError::Service("worker dropped response".into()))?
    }
}

/// The batching SpMVM service.
pub struct SpmvService {
    store: Arc<MatrixStore>,
    queue: Arc<AdmissionQueue<Job>>,
    /// Service metrics (shared with workers and the store).
    pub metrics: Arc<Metrics>,
    /// One engine for every execution path — dispatcher batches, per-
    /// request jobs, and whole solves — so decode plans stay hot and
    /// kernel parallelism is centralized under [`ServiceConfig::par`].
    engine: Arc<SpmvEngine>,
    /// Pool-free serial engine backing [`ParHint::Serial`] arms (and
    /// nothing else): construction is free, so it exists even when
    /// adaptation is off.
    serial_engine: Arc<SpmvEngine>,
    /// The online routing layer (disabled by default — see
    /// [`ServiceConfig::adaptive`]).
    adaptive: Arc<AdaptiveRouter>,
    dispatcher: Option<std::thread::JoinHandle<()>>,
    config: ServiceConfig,
}

impl SpmvService {
    /// Start the service with `config`. Panics if the artifact cache
    /// directory cannot be created; use [`SpmvService::try_start`] to
    /// handle that error.
    pub fn start(config: ServiceConfig) -> SpmvService {
        SpmvService::try_start(config).expect("service start")
    }

    /// Start the service with `config`.
    pub fn try_start(config: ServiceConfig) -> Result<SpmvService> {
        let metrics = Arc::new(Metrics::with_obs(config.obs));
        let store = Arc::new(MatrixStore::new(
            config.store.clone(),
            config.encode,
            config.policy,
            Arc::clone(&metrics),
        )?);
        let queue = Arc::new(AdmissionQueue::new(&config.admission));
        let engine =
            Arc::new(SpmvEngine::new(config.par).with_kernel_variant(config.kernel_variant));
        let serial_engine =
            Arc::new(SpmvEngine::serial().with_kernel_variant(config.kernel_variant));
        let adaptive = Arc::new(AdaptiveRouter::new(config.adaptive, Arc::clone(&metrics)));

        let dispatcher = {
            let queue = Arc::clone(&queue);
            let store = Arc::clone(&store);
            let metrics = Arc::clone(&metrics);
            let engine = Arc::clone(&engine);
            let serial_engine = Arc::clone(&serial_engine);
            let adaptive = Arc::clone(&adaptive);
            let cfg = config.clone();
            std::thread::spawn(move || {
                dispatcher_loop(queue, store, metrics, engine, serial_engine, adaptive, cfg)
            })
        };

        Ok(SpmvService {
            store,
            queue,
            metrics,
            engine,
            serial_engine,
            adaptive,
            dispatcher: Some(dispatcher),
            config,
        })
    }

    /// Register a matrix: encodes it (or loads its cached artifact),
    /// routes it, returns its id. With adaptation enabled the matrix
    /// also enters the [`AdaptiveRouter`], its arm estimates seeded from
    /// the GPU execution-model simulator when a CSR original is resident
    /// ([`SeedSource::Sim`]; [`SeedSource::Static`] otherwise).
    pub fn register(&self, name: &str, csr: Csr) -> Result<u64> {
        let id = self.store.register_csr(name, csr)?;
        self.seed_routes(id);
        Ok(id)
    }

    /// Register a matrix straight from a serialized `.dtans` artifact.
    /// Enters adaptation like [`SpmvService::register`]; the admissible
    /// arm set is residency-filtered, so a `drop_csr` store keeps such a
    /// matrix on its dtANS route (no CSR original to serve CSR-walk
    /// formats from).
    pub fn register_path(&self, name: &str, path: &Path) -> Result<u64> {
        let id = self.store.register_path(name, path)?;
        self.seed_routes(id);
        Ok(id)
    }

    /// Enter a freshly registered matrix into the adaptive router: the
    /// admissible arms come from what is resident right now
    /// ([`LoadedMatrix::admissible_choices`]), and estimates are seeded
    /// from the analytic GPU model when the CSR original is available.
    /// No-op when adaptation is disabled.
    fn seed_routes(&self, id: u64) {
        if !self.adaptive.is_enabled() {
            return;
        }
        // A failed acquire (raced eviction before the artifact persisted,
        // etc.) just leaves the matrix unadapted: decide() returns None
        // and it serves its registered route, which is always correct.
        let Ok(pinned) = self.store.acquire(id) else { return };
        let admissible = pinned.admissible_choices();
        let (seeds, source) = match &pinned.csr {
            Some(csr) => (sim_seeds(csr, &pinned.enc, &admissible), SeedSource::Sim),
            None => (Vec::new(), SeedSource::Static),
        };
        self.adaptive.register_matrix(
            id,
            pinned.choice,
            &admissible,
            self.config.kernel_variant,
            &seeds,
            source,
        );
    }

    /// Append COO `(row, col, delta)` updates to a registered matrix:
    /// each means `A[row,col] += delta`, folded in arrival order. Stamps
    /// and returns a new monotonically increasing version; every request
    /// submitted after this returns sees the updated matrix, while
    /// requests already executing finish on the version they pinned (see
    /// [`crate::delta`] and `docs/MUTATION.md`). The overlay is absorbed
    /// into a fresh artifact by background compaction once it passes
    /// [`StoreConfig::compact_overlay_nnz`].
    pub fn append(&self, matrix: u64, updates: &[(u32, u32, f64)]) -> Result<u64> {
        let version = self.store.append(matrix, updates)?;
        if !updates.is_empty() {
            // An overlaid matrix serves only its composite operator (the
            // base encoding is stale), so it leaves adaptation: decide()
            // returns None and requests ride the registered route.
            self.adaptive.retire(matrix);
        }
        Ok(version)
    }

    /// The adaptive routing layer (counters, flip trace, incumbents).
    pub fn adaptive(&self) -> &Arc<AdaptiveRouter> {
        &self.adaptive
    }

    /// Pin (or unpin) a matrix's route — the operator escape hatch
    /// ([`RouteOverride`], `docs/ROUTING.md`). A pinned arm serves all
    /// of the matrix's singleton traffic with no exploration and no
    /// flips; pinning a route the matrix cannot materialize makes its
    /// requests fail with the typed
    /// [`DtansError::InadmissibleRoute`](crate::util::error::DtansError)
    /// rather than silently serving another format. No-op when
    /// adaptation is disabled or the matrix is unregistered/retired.
    pub fn pin_route(&self, matrix: u64, ov: RouteOverride) {
        self.adaptive.set_override(matrix, ov);
    }

    /// The service's tiered matrix store (stats, flush, manual evict).
    pub fn store(&self) -> &Arc<MatrixStore> {
        &self.store
    }

    /// Routed format of a registered matrix.
    pub fn format_of(&self, id: u64) -> Option<FormatChoice> {
        self.store.format_of(id)
    }

    /// Submit a request with default admission options (no deadline,
    /// normal priority, no tenant); returns a [`Pending`] handle, or a
    /// typed shed error if admission rejected the request
    /// ([`DtansError::Overloaded`], [`DtansError::QueueClosed`]).
    ///
    /// Every call — admitted or shed — counts toward
    /// [`Metrics::submitted`]; sheds count toward [`Metrics::shed`], so
    /// `completed + failed + shed + expired == submitted` always holds.
    ///
    /// [`Metrics::submitted`]: crate::coordinator::metrics::Metrics::submitted
    /// [`Metrics::shed`]: crate::coordinator::metrics::Metrics::shed
    pub fn submit(&self, matrix: u64, x: Vec<f64>) -> Result<Pending> {
        self.submit_with(matrix, x, SubmitOptions::default())
    }

    /// Submit a request with explicit [`SubmitOptions`] (deadline,
    /// priority, tenant). Sheds with [`DtansError::QuotaExceeded`] when
    /// the tenant's token bucket is empty, in addition to the
    /// [`SpmvService::submit`] shed conditions. A deadline is **not**
    /// checked here: expiry is decided once, by the dispatcher,
    /// immediately before execution — an expired request resolves its
    /// [`Pending`] with [`DtansError::DeadlineExceeded`].
    pub fn submit_with(&self, matrix: u64, x: Vec<f64>, opts: SubmitOptions) -> Result<Pending> {
        let (tx, rx) = channel();
        self.metrics.submitted.fetch_add(1, Ordering::Relaxed);
        let tracer = self.metrics.tracer();
        let span = tracer.begin();
        tracer.record(span, Stage::Submitted { matrix });
        let job = Job { x, submitted: Instant::now(), resp: tx, span };
        match self.queue.push(matrix, &opts, job) {
            Ok(depth) => {
                if let Some(tenant) = &opts.tenant {
                    self.metrics.record_tenant(tenant, true);
                }
                self.metrics.note_queue_depth(depth as u64);
                Ok(Pending { rx })
            }
            Err(e) => {
                if let Some(tenant) = &opts.tenant {
                    self.metrics.record_tenant(tenant, false);
                }
                self.metrics.record_shed(matches!(e, DtansError::QuotaExceeded { .. }));
                tracer.record(span, Stage::Shed);
                Err(e)
            }
        }
    }

    /// Convenience: submit and wait.
    pub fn spmv(&self, matrix: u64, x: Vec<f64>) -> Result<Vec<f64>> {
        self.submit(matrix, x)?.wait()
    }

    /// Gate the dispatcher: requests are still admitted (and shed, and
    /// quota-accounted) but nothing dispatches until
    /// [`SpmvService::resume_dispatch`]. The deterministic test hook —
    /// stage an exact queue state, then release it; also usable as a
    /// maintenance drain valve. Dropping the service while paused still
    /// shuts down cleanly (close overrides the gate).
    pub fn pause_dispatch(&self) {
        self.queue.pause();
    }

    /// Release the [`SpmvService::pause_dispatch`] gate.
    pub fn resume_dispatch(&self) {
        self.queue.resume();
    }

    /// Requests currently admitted and waiting for dispatch.
    pub fn queue_depth(&self) -> usize {
        self.queue.len()
    }

    /// Run an iterative linear solve `A·x = b` against a registered
    /// matrix on the calling thread.
    ///
    /// The matrix is acquired through **one** store pin held for the
    /// whole solve — a cold matrix faults in once, then every iteration
    /// multiplies against the pinned resident operator (no per-iteration
    /// cold-load faults, observable via [`Metrics::acquires`]). Kernel
    /// work runs on the service's shared engine (so
    /// [`ServiceConfig::par`] applies; [`SolverConfig::par`] is ignored
    /// here), against whatever operator the [`RoutePolicy`] chose at
    /// registration. The solve is recorded in [`Metrics`] as a single
    /// request-level sample with its iteration count and outcome
    /// ([`Metrics::solver_summary`]).
    ///
    /// [`Metrics::acquires`]: crate::coordinator::metrics::Metrics::acquires
    /// [`Metrics::solver_summary`]: crate::coordinator::metrics::Metrics::solver_summary
    pub fn solve(
        &self,
        matrix: u64,
        method: SolveMethod,
        b: &[f64],
        cfg: &SolverConfig,
    ) -> Result<Solution> {
        self.run_pinned_solve(
            matrix,
            |engine, op| match method {
                SolveMethod::Cg => solver::cg_with(engine, op, b, None, cfg),
                SolveMethod::BiCgStab => solver::bicgstab_with(engine, op, b, None, cfg),
            },
            |sol| &sol.report,
        )
    }

    /// Power-iterate a registered matrix to its dominant eigenpair, with
    /// the same single-pin and metrics discipline as
    /// [`SpmvService::solve`].
    pub fn power(&self, matrix: u64, cfg: &SolverConfig) -> Result<PowerSolution> {
        self.run_pinned_solve(
            matrix,
            |engine, op| solver::power_iteration_with(engine, op, None, cfg),
            |sol| &sol.report,
        )
    }

    /// PageRank a registered column-stochastic transition matrix, with
    /// the same single-pin and metrics discipline as
    /// [`SpmvService::solve`].
    pub fn pagerank(&self, matrix: u64, damping: f64, cfg: &SolverConfig) -> Result<Solution> {
        self.run_pinned_solve(
            matrix,
            |engine, op| solver::pagerank_with(engine, op, damping, cfg),
            |sol| &sol.report,
        )
    }

    /// Shared solve discipline: one pin for the whole solve, execution on
    /// the shared engine, one request-level metrics sample. `report_of`
    /// projects the solver's return value onto its [`solver::SolveReport`]
    /// (solutions and eigenpairs carry it under different types).
    fn run_pinned_solve<T>(
        &self,
        matrix: u64,
        run: impl FnOnce(&SpmvEngine, &dyn crate::spmv::operator::SpmvOperator) -> Result<T>,
        report_of: impl Fn(&T) -> &solver::SolveReport,
    ) -> Result<T> {
        let t0 = Instant::now();
        // Solves are requests too: they open a span (so the conservation
        // oracle's "one terminal per Submitted" holds across every path
        // that touches the submitted/completed/failed counters).
        let tracer = self.metrics.tracer();
        let span = tracer.begin();
        tracer.record(span, Stage::Submitted { matrix });
        let pinned = match self.store.acquire(matrix) {
            Ok(p) => p, // the solve's one pin, held until this fn returns
            Err(e) => {
                // No operator ever executed, so there is no format to
                // charge — but the request must still be visible, exactly
                // as the spmv path counts an unknown-matrix request.
                self.metrics.submitted.fetch_add(1, Ordering::Relaxed);
                self.metrics.failed.fetch_add(1, Ordering::Relaxed);
                tracer.record(span, Stage::Failed);
                return Err(e);
            }
        };
        tracer.record(span, Stage::Pinned);
        let tag = pinned.op.format_tag();
        let result = run(&self.engine, pinned.op.as_ref());
        match &result {
            Ok(sol) => {
                let r = report_of(sol);
                let total_us = t0.elapsed().as_micros() as u64;
                self.metrics.record_solve(tag, r.iterations as u64, r.converged(), total_us);
                tracer.record(span, Stage::Completed { total_us });
            }
            Err(_) => {
                self.metrics.record_solve_failure(tag);
                tracer.record(span, Stage::Failed);
            }
        }
        result
    }

    /// The service configuration.
    pub fn config(&self) -> &ServiceConfig {
        &self.config
    }
}

impl Drop for SpmvService {
    fn drop(&mut self) {
        // Close the queue: further submits get QueueClosed, the
        // dispatcher drains what was admitted (even mid-pause) and exits.
        self.queue.close();
        if let Some(h) = self.dispatcher.take() {
            let _ = h.join();
        }
    }
}

fn dispatcher_loop(
    queue: Arc<AdmissionQueue<Job>>,
    store: Arc<MatrixStore>,
    metrics: Arc<Metrics>,
    // The service-wide engine (shared with `SpmvService::solve`): decode
    // tables / plans stay hot, kernel parallelism lives in one place.
    engine: Arc<SpmvEngine>,
    serial_engine: Arc<SpmvEngine>,
    adaptive: Arc<AdaptiveRouter>,
    cfg: ServiceConfig,
) {
    let pool = crate::util::threadpool::ThreadPool::new(cfg.workers);
    if !metrics.tracer().is_off() {
        metrics.tracer().label_current_track("dispatcher");
    }
    // Each take_batch returns one coalesced batch: ALL queued requests
    // for the dispatch target's matrix, across priority lanes, up to
    // max_batch — vLLM-style continuous batching, but gathered over the
    // whole queue instead of only consecutive arrivals. The residual
    // depth rides along from under the queue lock, so the gauge reflects
    // the dequeue exactly (no window for a racing submit to skew it).
    while let Some((admitted, depth)) = queue.take_batch_depth(cfg.max_batch) {
        metrics.note_queue_depth(depth as u64);
        // The single expiry point: a request whose deadline elapsed
        // while queued is rejected here, before any kernel work or store
        // pin. (`deadline <= now` — the queue wait is strictly positive
        // on a monotonic clock, so a deadline of "now" at submit always
        // expires.)
        let now = Instant::now();
        let mut batch: Vec<Request> = Vec::with_capacity(admitted.len());
        for a in admitted {
            let span = a.payload.span;
            let wait_us = now.saturating_duration_since(a.enqueued).as_micros() as u64;
            metrics.record_queue_wait(wait_us);
            metrics.tracer().record(span, Stage::Queued { wait_us });
            if a.deadline.is_some_and(|d| d <= now) {
                metrics.record_expired();
                metrics.tracer().record(span, Stage::Expired);
                let _ = a.payload.resp.send(Err(DtansError::DeadlineExceeded));
            } else {
                metrics.tracer().record(span, Stage::Dispatched);
                batch.push(Request {
                    matrix: a.matrix,
                    x: a.payload.x,
                    submitted: a.payload.submitted,
                    resp: a.payload.resp,
                    span,
                });
            }
        }
        if batch.is_empty() {
            continue; // the whole batch expired; nothing dispatched
        }
        metrics.batches.fetch_add(1, Ordering::Relaxed);

        // The dispatcher itself never acquires: a cold matrix would block
        // it on the disk fault (head-of-line for every other matrix's
        // warm traffic). It routes on cheap metadata only; the acquire —
        // warm pin or deduped cold load — happens on pool workers.
        //
        // SpMM fast path only when the engine would actually fan the
        // batch out; otherwise (Serial engine, or Auto below its cost
        // threshold) keep the one-worker-per-request path so
        // request-level parallelism on the service pool is preserved.
        let id = batch[0].matrix;
        let (spmm, resident) = match store.dispatch_meta(id) {
            Some((nnz, resident)) => (
                batch.len() > 1 && engine.will_batch_parallel(nnz, batch.len()),
                resident,
            ),
            None => (false, false), // unknown id: the batch job reports it
        };
        if spmm {
            // The decode-amortization payoff, observable: this batch
            // reaches the engine as ONE run_multi call.
            metrics.coalesced_batches.fetch_add(1, Ordering::Relaxed);
            metrics.coalesced_requests.fetch_add(batch.len() as u64, Ordering::Relaxed);
        }
        if spmm || !resident {
            // One job for the whole batch: it faults the matrix in (or
            // fails every request) and runs the batched kernel.
            let store = Arc::clone(&store);
            let engine = Arc::clone(&engine);
            let serial_engine = Arc::clone(&serial_engine);
            let adaptive = Arc::clone(&adaptive);
            let metrics = Arc::clone(&metrics);
            pool.execute(move || {
                process_batch(&store, &engine, &serial_engine, &adaptive, &metrics, batch)
            });
        } else {
            // Warm per-request path: each job takes its own (cheap) pin.
            for req in batch {
                let store = Arc::clone(&store);
                let engine = Arc::clone(&engine);
                let serial_engine = Arc::clone(&serial_engine);
                let adaptive = Arc::clone(&adaptive);
                let metrics = Arc::clone(&metrics);
                pool.execute(move || {
                    let tracer = metrics.tracer();
                    if !tracer.is_off() {
                        tracer.label_current_track("worker");
                    }
                    match store.acquire(req.matrix) {
                        Err(e) => {
                            metrics.failed.fetch_add(1, Ordering::Relaxed);
                            tracer.record(req.span, Stage::Failed);
                            let _ = req.resp.send(Err(e));
                        }
                        Ok(pinned) => {
                            tracer.record(req.span, Stage::Pinned);
                            let (result, tag) = run_routed(
                                &pinned,
                                &engine,
                                &serial_engine,
                                &adaptive,
                                &req.x,
                                req.span,
                                &metrics,
                            );
                            match &result {
                                Ok(_) => {
                                    let total_us = req.submitted.elapsed().as_micros() as u64;
                                    metrics.record_format_latency(tag, total_us);
                                    tracer.record(req.span, Stage::Completed { total_us });
                                }
                                Err(_) => {
                                    metrics.record_format_failure(tag);
                                    tracer.record(req.span, Stage::Failed);
                                }
                            }
                            let _ = req.resp.send(result);
                        }
                    }
                });
            }
        }
    }
    // `pool` drops here: its Drop joins the workers, so every in-flight
    // job (and its response send) completes before the dispatcher exits.
}

/// Process one whole batch on a pool worker: acquire (faulting a cold
/// matrix in — deduped with any concurrent load of the same id), then run
/// the SpMM fast path or the requests sequentially.
fn process_batch(
    store: &MatrixStore,
    engine: &SpmvEngine,
    serial_engine: &SpmvEngine,
    adaptive: &AdaptiveRouter,
    metrics: &Metrics,
    batch: Vec<Request>,
) {
    let tracer = metrics.tracer();
    if !tracer.is_off() {
        tracer.label_current_track("worker");
    }
    match store.acquire(batch[0].matrix) {
        Err(e) => {
            for req in batch {
                metrics.failed.fetch_add(1, Ordering::Relaxed);
                tracer.record(req.span, Stage::Failed);
                let _ = req.resp.send(Err(e.duplicate()));
            }
        }
        Ok(pinned) if batch.len() > 1 && engine.will_batch_parallel(pinned.nnz, batch.len()) => {
            // Coalesced batches ride the registered route: one SpMM call
            // cannot split across per-request arms, and fragmenting the
            // batch to explore would forfeit the decode amortization the
            // batch exists for (docs/ROUTING.md documents the tradeoff).
            for req in &batch {
                tracer.record(req.span, Stage::Pinned);
            }
            run_spmm_batch(&pinned, batch, engine, metrics);
        }
        Ok(pinned) => {
            // Requests run sequentially on this worker. Deliberate
            // tradeoff: a cold multi-request batch that does NOT take the
            // SpMM path has a small matrix (large ones clear the engine's
            // batch-parallel cost bar), so the disk fault dominates and
            // per-multiply fan-out would buy little — while re-dispatching
            // per-request jobs from inside a pool job would require the
            // pool to own an Arc of itself (a self-join hazard on drop).
            for req in batch {
                tracer.record(req.span, Stage::Pinned);
                let (result, tag) = run_routed(
                    &pinned,
                    engine,
                    serial_engine,
                    adaptive,
                    &req.x,
                    req.span,
                    metrics,
                );
                match &result {
                    Ok(_) => {
                        let total_us = req.submitted.elapsed().as_micros() as u64;
                        metrics.record_format_latency(tag, total_us);
                        tracer.record(req.span, Stage::Completed { total_us });
                    }
                    Err(_) => {
                        metrics.record_format_failure(tag);
                        tracer.record(req.span, Stage::Failed);
                    }
                }
                let _ = req.resp.send(result);
            }
        }
    }
}

/// SpMM fast path for a multi-request batch: dimension-check each request
/// up front (so one malformed vector cannot poison the batch), pack the
/// accepted right-hand sides into one contiguous column-major [`DenseMat`]
/// and run them through a single batched engine call over the matrix's
/// routed operator.
fn run_spmm_batch(
    pinned: &PinnedMatrix,
    batch: Vec<Request>,
    engine: &SpmvEngine,
    metrics: &Metrics,
) {
    let mat: &LoadedMatrix = pinned;
    let tracer = metrics.tracer();
    let tag = mat.op.format_tag();
    let (nrows, ncols) = (mat.nrows, mat.ncols);
    // One batch id shared by every span in this coalesced dispatch — the
    // trace-side witness that these requests rode one engine call.
    let batch_id = tracer.batch_id();
    let size = batch.len() as u32;
    let mut xs = Vec::with_capacity(batch.len());
    let mut accepted = Vec::with_capacity(batch.len());
    for req in batch {
        tracer.record(req.span, Stage::Coalesced { batch: batch_id, size });
        if req.x.len() == ncols {
            xs.push(req.x);
            accepted.push((req.resp, req.submitted, req.span));
        } else {
            metrics.record_format_failure(tag);
            tracer.record(req.span, Stage::Failed);
            // Same message shape as the per-request path (check_dims with
            // the nrows-sized output the run would have used), so clients
            // see one error text regardless of how requests batched.
            let _ = req.resp.send(Err(DtansError::Dimension(format!(
                "matrix {nrows}x{ncols} with x[{}], y[{nrows}]",
                req.x.len()
            ))));
        }
    }
    if accepted.is_empty() {
        return;
    }
    // Lengths were pre-checked against ncols, so packing cannot fail.
    let t0 = Instant::now();
    let result = DenseMat::from_cols(ncols, &xs)
        .and_then(|xs_mat| engine.run_multi(mat.op.as_ref(), &xs_mat));
    let dur_us = t0.elapsed().as_micros() as u64;
    match result {
        Ok(ys) => {
            if !tracer.is_off() {
                let blocks = engine.batch_blocks(mat.nnz, accepted.len()) as u32;
                if tag == "csr_dtans" {
                    // The batched kernel decodes the stream once per
                    // right-hand side; charge all of it to this one call.
                    metrics.record_decode_rate(
                        pinned.id(),
                        mat.enc.size_report().stream as u64 * accepted.len() as u64,
                        dur_us,
                    );
                }
                for (_, _, span) in &accepted {
                    // Per-block spread is not measured on the batched path
                    // (the grid fans over requests × blocks); min/max/mean
                    // are 0 by convention, dur_us is the whole-call time.
                    tracer.record(
                        *span,
                        Stage::Kernel {
                            format: tag,
                            blocks,
                            min_us: 0,
                            max_us: 0,
                            mean_us: 0,
                            dur_us,
                        },
                    );
                }
            }
            for ((resp, submitted, span), y) in accepted.into_iter().zip(ys.into_cols()) {
                let total_us = submitted.elapsed().as_micros() as u64;
                metrics.record_format_latency(tag, total_us);
                tracer.record(span, Stage::Completed { total_us });
                let _ = resp.send(Ok(y));
            }
        }
        Err(e) => {
            // Decode-level failures are a property of the matrix, so every
            // request in the batch sees the same error — with its variant
            // preserved, exactly as the per-request path would report it.
            for (resp, _, span) in accepted {
                metrics.record_format_failure(tag);
                tracer.record(span, Stage::Failed);
                let _ = resp.send(Err(e.duplicate()));
            }
        }
    }
}

/// One SpMV through the adaptive route. Returns the result **and the
/// tag of the operator that actually executed** (exploration may serve
/// a different format than the registered one), so callers charge
/// latency/failure metrics to the right format family.
///
/// When the router declines ([`AdaptiveRouter::decide`] returns `None`:
/// adaptation disabled, or the matrix unregistered/retired) this is
/// exactly [`run_one`] on the registered operator — the static-routing
/// fast path, untimed when the tracer is off. When a decision arrives,
/// the kernel is *always* timed (the observation feeding the cost
/// model) on the exact arm it routed to: the decided format's operator
/// ([`LoadedMatrix::operator_for_choice`]), the decided kernel variant,
/// and the decided engine ([`ParHint`]).
///
/// Inadmissibility: a [`RouteOverride::Pin`] to a route this resident
/// form cannot serve fails with the typed
/// [`DtansError::InadmissibleRoute`] (never silently re-routed); a
/// *learned* decision that residency cannot serve falls back to the
/// registered operator (the arm list is residency-filtered at
/// registration, so this only happens when residency changed underneath
/// — e.g. a cold reload that could not rebuild the CSR original).
fn run_routed(
    pinned: &PinnedMatrix,
    engine: &SpmvEngine,
    serial_engine: &SpmvEngine,
    adaptive: &AdaptiveRouter,
    x: &[f64],
    span: SpanId,
    metrics: &Metrics,
) -> (Result<Vec<f64>>, &'static str) {
    let mat: &LoadedMatrix = pinned;
    let registered_tag = mat.op.format_tag();
    let Some(decision) = adaptive.decide(pinned.id()) else {
        return (run_one(pinned, engine, x, span, metrics), registered_tag);
    };
    let op = match mat.operator_for_choice(pinned.id(), decision.arm.choice) {
        Ok(op) => op,
        Err(e) if decision.pinned => return (Err(e), registered_tag),
        Err(_) => return (run_one(pinned, engine, x, span, metrics), registered_tag),
    };
    let eng = match decision.arm.par {
        ParHint::Engine => engine,
        ParHint::Serial => serial_engine,
    };
    let tag = op.format_tag();
    let mut y = vec![0.0; mat.nrows];
    let tracer = metrics.tracer();
    let t0 = Instant::now();
    let result = if tracer.is_off() {
        // Untraced: whole-call timing only (the router's observation).
        eng.run_variant(op.as_ref(), x, &mut y, decision.arm.variant).map(|_| None)
    } else {
        eng.run_timed_variant(op.as_ref(), x, &mut y, decision.arm.variant).map(Some)
    };
    let dur_us = t0.elapsed().as_micros() as u64;
    match result {
        Ok(timing) => {
            adaptive.observe(pinned.id(), decision.arm, dur_us as f64);
            if let Some(timing) = timing {
                metrics.record_block_timing(timing.min_us, timing.max_us, timing.mean_us);
                if tag == "csr_dtans" {
                    metrics.record_decode_rate(
                        pinned.id(),
                        mat.enc.size_report().stream as u64,
                        dur_us,
                    );
                }
                tracer.record(
                    span,
                    Stage::Kernel {
                        format: tag,
                        blocks: timing.blocks as u32,
                        min_us: timing.min_us,
                        max_us: timing.max_us,
                        mean_us: timing.mean_us,
                        dur_us,
                    },
                );
            }
            (Ok(y), tag)
        }
        Err(e) => (Err(e), tag),
    }
}

/// One SpMV on the engine. With tracing on, runs through the per-block
/// timed entry point: the block spread lands in the imbalance histograms,
/// dtANS-routed matrices get a decode-throughput sample, and the span
/// gets its `Kernel` stage. With the tracer off this is exactly the old
/// untimed `engine.run` — zero observability overhead.
fn run_one(
    pinned: &PinnedMatrix,
    engine: &SpmvEngine,
    x: &[f64],
    span: SpanId,
    metrics: &Metrics,
) -> Result<Vec<f64>> {
    let mat: &LoadedMatrix = pinned;
    let mut y = vec![0.0; mat.nrows];
    let tracer = metrics.tracer();
    if tracer.is_off() {
        engine.run(mat.op.as_ref(), x, &mut y)?;
        return Ok(y);
    }
    let t0 = Instant::now();
    let timing = engine.run_timed(mat.op.as_ref(), x, &mut y)?;
    let dur_us = t0.elapsed().as_micros() as u64;
    metrics.record_block_timing(timing.min_us, timing.max_us, timing.mean_us);
    let tag = mat.op.format_tag();
    if tag == "csr_dtans" {
        metrics.record_decode_rate(pinned.id(), mat.enc.size_report().stream as u64, dur_us);
    }
    tracer.record(
        span,
        Stage::Kernel {
            format: tag,
            blocks: timing.blocks as u32,
            min_us: timing.min_us,
            max_us: timing.max_us,
            mean_us: timing.mean_us,
            dur_us,
        },
    );
    Ok(y)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::gen::structured::banded;
    use crate::matrix::gen::{assign_values, ValueDist};
    use crate::spmv::spmv_csr;
    use crate::util::rng::Xoshiro256;

    #[test]
    fn serves_requests_correctly() {
        let svc = SpmvService::start(ServiceConfig::default());
        let mut m = banded(200, 3);
        assign_values(&mut m, ValueDist::FewDistinct(4), &mut Xoshiro256::seeded(1));
        let id = svc.register("banded", m.clone()).unwrap();
        let x: Vec<f64> = (0..200).map(|i| (i as f64).cos()).collect();
        let mut want = vec![0.0; 200];
        spmv_csr(&m, &x, &mut want).unwrap();
        let got = svc.spmv(id, x).unwrap();
        crate::util::propcheck::assert_close(&got, &want, 1e-12, 1e-12).unwrap();
        assert!(svc.metrics.latency_summary().count >= 1);
        // A 200x200 banded matrix is below the routing threshold: the
        // request must show up under the CSR format's own metrics.
        let tag = svc.format_of(id).unwrap().tag();
        assert_eq!(tag, "csr");
        let fs = svc.metrics.format_summary(tag).unwrap();
        assert!(fs.completed >= 1 && fs.latency.count >= 1);
    }

    #[test]
    fn kernel_variant_knob_serves_close_to_scalar() {
        let mut m = banded(300, 4);
        assign_values(&mut m, ValueDist::FewDistinct(4), &mut Xoshiro256::seeded(7));
        let x: Vec<f64> = (0..300).map(|i| (i as f64).cos()).collect();
        let mut want = vec![0.0; 300];
        spmv_csr(&m, &x, &mut want).unwrap();
        for variant in KernelVariant::ALL {
            let svc = SpmvService::start(ServiceConfig {
                kernel_variant: variant,
                ..Default::default()
            });
            let id = svc.register("banded", m.clone()).unwrap();
            let got = svc.spmv(id, x.clone()).unwrap();
            crate::util::propcheck::assert_close(&got, &want, 1e-12, 1e-12)
                .unwrap_or_else(|e| panic!("{}: {e}", variant.label()));
        }
    }

    #[test]
    fn batches_many_concurrent_requests() {
        let svc = SpmvService::start(ServiceConfig {
            workers: 4,
            max_batch: 8,
            ..Default::default()
        });
        let m = banded(128, 2);
        let id = svc.register("m", m.clone()).unwrap();
        let handles: Vec<Pending> = (0..40)
            .map(|i| {
                let x: Vec<f64> = (0..128).map(|j| ((i * j) as f64 * 0.01).sin()).collect();
                svc.submit(id, x).unwrap()
            })
            .collect();
        for h in handles {
            let y = h.wait().unwrap();
            assert_eq!(y.len(), 128);
        }
        assert_eq!(svc.metrics.completed.load(Ordering::Relaxed), 40);
    }

    #[test]
    fn unknown_matrix_errors() {
        let svc = SpmvService::start(ServiceConfig::default());
        assert!(svc.spmv(999, vec![0.0; 4]).is_err());
        assert_eq!(svc.metrics.failed.load(Ordering::Relaxed), 1);
        // A solve against an unknown matrix is counted like any failed
        // request (submitted + failed), even though no solver ever ran.
        let submitted0 = svc.metrics.submitted.load(Ordering::Relaxed);
        assert!(svc.solve(999, SolveMethod::Cg, &[0.0; 4], &SolverConfig::default()).is_err());
        assert_eq!(svc.metrics.failed.load(Ordering::Relaxed), 2);
        assert_eq!(svc.metrics.submitted.load(Ordering::Relaxed), submitted0 + 1);
        assert_eq!(svc.metrics.solver_summary().solves, 0);
    }

    #[test]
    fn solve_runs_cg_through_the_service() {
        use crate::matrix::gen::structured::stencil2d5;
        let svc = SpmvService::start(ServiceConfig::default());
        let a = stencil2d5(12, 12);
        let id = svc.register("poisson", a.clone()).unwrap();
        let b = vec![1.0; a.nrows];
        let acquires0 = svc.metrics.acquires.load(Ordering::Relaxed);
        let sol = svc.solve(id, SolveMethod::Cg, &b, &SolverConfig::default()).unwrap();
        assert!(sol.report.converged());
        assert!(sol.report.iterations > 1);
        // Exactly one pin for the whole solve, released afterwards.
        assert_eq!(svc.metrics.acquires.load(Ordering::Relaxed) - acquires0, 1);
        assert_eq!(svc.store().pin_count(id), 0);
        let s = svc.metrics.solver_summary();
        assert_eq!((s.solves, s.converged, s.diverged), (1, 1, 0));
        assert_eq!(s.iters_p50, sol.report.iterations as u64);
        // One request-level latency sample — not one per iteration.
        let fs = svc.metrics.format_summary("csr").unwrap();
        assert_eq!((fs.completed, fs.latency.count), (1, 1));
        // Mismatched rhs fails cleanly: a solve attempt and a failed
        // request, but NOT a divergence (no iteration ever ran).
        let failed0 = svc.metrics.failed.load(Ordering::Relaxed);
        assert!(svc.solve(id, SolveMethod::BiCgStab, &[1.0; 3], &SolverConfig::default())
            .is_err());
        let s2 = svc.metrics.solver_summary();
        assert_eq!((s2.solves, s2.diverged), (2, 0));
        assert_eq!(svc.metrics.failed.load(Ordering::Relaxed), failed0 + 1);
    }

    #[test]
    fn parallel_engine_config_matches_serial_service() {
        // Same requests through a Serial-engine service and a Fixed(4)
        // engine service must produce bit-identical responses.
        let mut m = banded(3000, 3);
        assign_values(&mut m, ValueDist::FewDistinct(8), &mut Xoshiro256::seeded(7));
        let xs: Vec<Vec<f64>> = (0..6)
            .map(|i| (0..3000).map(|j| ((i * j) as f64 * 0.001).sin()).collect())
            .collect();
        let mut answers: Vec<Vec<Vec<f64>>> = Vec::new();
        for par in [ParStrategy::Serial, ParStrategy::Fixed(4)] {
            let svc = SpmvService::start(ServiceConfig {
                workers: 2,
                par,
                policy: RoutePolicy { min_nnz: 1 << 10, max_size_ratio: 0.95, ..Default::default() },
                ..Default::default()
            });
            let id = svc.register("m", m.clone()).unwrap();
            // Submit all up front so the dispatcher can exercise the SpMM
            // batch fast path.
            let pendings: Vec<Pending> =
                xs.iter().map(|x| svc.submit(id, x.clone()).unwrap()).collect();
            answers.push(pendings.into_iter().map(|p| p.wait().unwrap()).collect());
        }
        assert_eq!(answers[0], answers[1]);
        // And both match the serial CSR ground truth.
        for (x, y) in xs.iter().zip(&answers[0]) {
            let mut want = vec![0.0; 3000];
            spmv_csr(&m, x, &mut want).unwrap();
            crate::util::propcheck::assert_close(y, &want, 1e-12, 1e-12).unwrap();
        }
    }

    #[test]
    fn spmm_batch_isolates_bad_dimensions() {
        // Fixed strategy keeps will_batch_parallel() true at any size, so
        // whenever these requests do coalesce they exercise the SpMM path.
        let svc = SpmvService::start(ServiceConfig {
            par: ParStrategy::Fixed(2),
            ..Default::default()
        });
        let m = banded(256, 2);
        let id = svc.register("m", m).unwrap();
        // One malformed request among good ones; submitted together so
        // they can batch.
        let good1 = svc.submit(id, vec![1.0; 256]).unwrap();
        let bad = svc.submit(id, vec![1.0; 7]).unwrap();
        let good2 = svc.submit(id, vec![2.0; 256]).unwrap();
        assert_eq!(good1.wait().unwrap().len(), 256);
        assert!(bad.wait().is_err());
        assert_eq!(good2.wait().unwrap().len(), 256);
    }

    #[test]
    fn drop_while_paused_drains_and_answers_everything() {
        // The shutdown/pause interaction: requests staged behind the
        // pause gate must still be served (close overrides the gate and
        // drains), and the drop must not hang on the gated dispatcher.
        let svc = SpmvService::start(ServiceConfig::default());
        let m = banded(64, 2);
        let id = svc.register("m", m).unwrap();
        svc.pause_dispatch();
        let pendings: Vec<Pending> =
            (0..3).map(|_| svc.submit(id, vec![1.0; 64]).unwrap()).collect();
        assert_eq!(svc.queue_depth(), 3);
        let metrics = Arc::clone(&svc.metrics);
        drop(svc); // close + drain, while still paused
        for p in pendings {
            assert_eq!(p.wait().unwrap().len(), 64);
        }
        assert_eq!(metrics.completed.load(Ordering::Relaxed), 3);
    }

    #[test]
    fn queue_depth_gauge_falls_on_dequeue() {
        // Regression guard for the gauge's dequeue side: stage requests
        // behind the pause gate (submit-side pushes the gauge up), then
        // release and drain — the dispatcher's take_batch_depth must pull
        // the gauge back down to the true residual, not leave it stuck at
        // the last submit-side value.
        let svc = SpmvService::start(ServiceConfig::default());
        let m = banded(64, 2);
        let id = svc.register("m", m).unwrap();
        svc.pause_dispatch();
        let pendings: Vec<Pending> =
            (0..3).map(|_| svc.submit(id, vec![1.0; 64]).unwrap()).collect();
        assert_eq!(svc.metrics.queue_depth.load(Ordering::Relaxed), 3);
        assert!(svc.metrics.queue_depth_peak.load(Ordering::Relaxed) >= 3);
        svc.resume_dispatch();
        for p in pendings {
            p.wait().unwrap();
        }
        assert_eq!(svc.metrics.queue_depth.load(Ordering::Relaxed), 0);
        // Every dispatched request left a queue-wait sample (and the
        // waits are real: the gate held them queued until resume).
        assert_eq!(svc.metrics.queue_wait_summary().count, 3);
    }

    #[test]
    fn spans_chain_through_submit_dispatch_and_kernel() {
        let svc = SpmvService::start(ServiceConfig::default());
        let m = banded(100, 2);
        let id = svc.register("m", m).unwrap();
        svc.spmv(id, vec![1.0; 100]).unwrap();
        let events = svc.metrics.tracer().drain();
        // One request end to end: submitted -> queued -> dispatched ->
        // pinned -> kernel -> completed, all on the same span.
        let names: Vec<&str> = events.iter().map(|e| e.stage.name()).collect();
        for want in ["submitted", "queued", "dispatched", "pinned", "kernel", "completed"] {
            assert!(names.contains(&want), "missing {want} in {names:?}");
        }
        let span = events[0].span;
        assert!(events.iter().all(|e| e.span == span));
        assert_eq!(events.iter().filter(|e| e.stage.is_terminal()).count(), 1);
    }

    #[test]
    fn routes_large_structured_to_dtans() {
        let svc = SpmvService::start(ServiceConfig {
            policy: RoutePolicy {
                min_nnz: 1 << 10,
                max_size_ratio: 0.9,
                ..Default::default()
            },
            ..Default::default()
        });
        let mut m = banded(4000, 2);
        assign_values(&mut m, ValueDist::Ones, &mut Xoshiro256::seeded(2));
        let id = svc.register("big", m.clone()).unwrap();
        assert_eq!(svc.format_of(id), Some(FormatChoice::CsrDtans));
        // And results still match CSR.
        let x = vec![1.0; 4000];
        let mut want = vec![0.0; 4000];
        spmv_csr(&m, &x, &mut want).unwrap();
        let got = svc.spmv(id, x).unwrap();
        crate::util::propcheck::assert_close(&got, &want, 1e-12, 1e-9).unwrap();
    }

    #[test]
    fn append_through_the_service_updates_results() {
        let svc = SpmvService::start(ServiceConfig::default());
        let mut m = banded(200, 3);
        assign_values(&mut m, ValueDist::FewDistinct(4), &mut Xoshiro256::seeded(3));
        let id = svc.register("m", m.clone()).unwrap();
        let x: Vec<f64> = (0..200).map(|i| (i as f64 * 0.05).sin()).collect();
        let before = svc.spmv(id, x.clone()).unwrap();
        let updates = [(0u32, 0u32, 2.0f64), (5, 7, -1.5)];
        assert_eq!(svc.append(id, &updates).unwrap(), 1);
        // Served bits must equal the from-scratch rebuild of base+updates.
        let overlay = crate::delta::DeltaOverlay::empty(200, 200)
            .appended(&m, &updates)
            .unwrap();
        let merged = crate::delta::merge(&m, &overlay).unwrap();
        let mut want = vec![0.0; 200];
        spmv_csr(&merged, &x, &mut want).unwrap();
        let after = svc.spmv(id, x).unwrap();
        assert_eq!(after, want);
        assert_ne!(after, before);
        assert_eq!(svc.metrics.deltas_appended.load(Ordering::Relaxed), 2);
        assert_eq!(svc.store().version_of(id), Some(1));
    }

    #[test]
    fn zero_exploration_adaptive_is_bit_identical_to_static() {
        // The invariant the stress driver's replay oracle leans on: with
        // exploration off, every decision is the incumbent — which IS the
        // registered static choice — so responses are bit-identical to a
        // service with adaptation disabled.
        let mut m = banded(500, 3);
        assign_values(&mut m, ValueDist::FewDistinct(5), &mut Xoshiro256::seeded(21));
        let xs: Vec<Vec<f64>> = (0..8)
            .map(|i| (0..500).map(|j| ((i + j) as f64 * 0.01).sin()).collect())
            .collect();
        let run = |adaptive: AdaptiveConfig| -> Vec<Vec<f64>> {
            let svc = SpmvService::start(ServiceConfig { adaptive, ..Default::default() });
            let id = svc.register("m", m.clone()).unwrap();
            xs.iter().map(|x| svc.spmv(id, x.clone()).unwrap()).collect()
        };
        let static_bits = run(AdaptiveConfig::default());
        let adaptive_bits = run(AdaptiveConfig::zero_exploration());
        assert_eq!(static_bits, adaptive_bits);
    }

    #[test]
    fn adaptive_service_explores_and_conserves() {
        let svc = SpmvService::start(ServiceConfig {
            adaptive: AdaptiveConfig {
                explore_fraction: 0.5,
                ..AdaptiveConfig::enabled()
            },
            ..Default::default()
        });
        let mut m = banded(400, 3);
        assign_values(&mut m, ValueDist::FewDistinct(4), &mut Xoshiro256::seeded(5));
        let id = svc.register("m", m.clone()).unwrap();
        // The CSR original is kept, so all three formats are admissible.
        assert_eq!(svc.adaptive().admissible_arms(id).len(), 3);
        let x: Vec<f64> = (0..400).map(|i| (i as f64 * 0.02).cos()).collect();
        let mut want = vec![0.0; 400];
        spmv_csr(&m, &x, &mut want).unwrap();
        for _ in 0..60 {
            let got = svc.spmv(id, x.clone()).unwrap();
            crate::util::propcheck::assert_close(&got, &want, 1e-12, 1e-9).unwrap();
        }
        let c = svc.adaptive().counters();
        assert_eq!(c.routed, 60);
        assert_eq!(c.explored + c.exploited, c.routed);
        assert!(c.explored > 0, "epsilon 0.5 over 60 requests must explore: {c:?}");
        assert_eq!(
            svc.metrics.explore_requests.load(Ordering::Relaxed),
            c.explored
        );
        assert_eq!(svc.metrics.routed_requests.load(Ordering::Relaxed), c.routed);
    }

    #[test]
    fn pinned_inadmissible_route_fails_typed() {
        use super::super::adaptive::Arm;
        // drop_csr + dtANS route: no CSR original resident, so a pin to
        // the CSR arm cannot be served — requests must fail with the
        // typed routing error, not silently ride another format.
        let svc = SpmvService::start(ServiceConfig {
            policy: RoutePolicy { min_nnz: 1 << 10, max_size_ratio: 0.9, ..Default::default() },
            store: StoreConfig { drop_csr: true, ..Default::default() },
            adaptive: AdaptiveConfig::zero_exploration(),
            ..Default::default()
        });
        let mut m = banded(4000, 2);
        assign_values(&mut m, ValueDist::Ones, &mut Xoshiro256::seeded(2));
        let id = svc.register("big", m).unwrap();
        assert_eq!(svc.format_of(id), Some(FormatChoice::CsrDtans));
        assert_eq!(svc.adaptive().admissible_arms(id), vec![Arm::format(FormatChoice::CsrDtans)]);
        svc.pin_route(id, RouteOverride::Pin(Arm::format(FormatChoice::Csr)));
        let err = svc.spmv(id, vec![1.0; 4000]).unwrap_err();
        assert!(
            matches!(err, DtansError::InadmissibleRoute { matrix, tag: "csr" } if matrix == id),
            "{err}"
        );
        // Clearing the pin restores learned (here: incumbent) routing.
        svc.pin_route(id, RouteOverride::Clear);
        assert_eq!(svc.spmv(id, vec![1.0; 4000]).unwrap().len(), 4000);
    }

    #[test]
    fn budgeted_service_faults_cold_matrices_in() {
        // A budget far below the working set: every request may need a
        // cold reload, yet answers stay correct and evictions/cold loads
        // show up in metrics.
        let dir = std::env::temp_dir()
            .join(format!("dtans_test_svc_budget_{}", std::process::id()));
        let svc = SpmvService::start(ServiceConfig {
            policy: RoutePolicy { min_nnz: 1 << 8, max_size_ratio: 0.98, ..Default::default() },
            store: StoreConfig {
                cache_dir: Some(dir.clone()),
                budget_bytes: Some(1),
                drop_csr: true,
                loader_threads: 2,
                ..Default::default()
            },
            ..Default::default()
        });
        let mut mats = Vec::new();
        for i in 0..3 {
            let mut m = banded(600 + 100 * i, 3);
            assign_values(&mut m, ValueDist::FewDistinct(5), &mut Xoshiro256::seeded(i as u64));
            let id = svc.register(&format!("m{i}"), m.clone()).unwrap();
            mats.push((id, m));
        }
        svc.store().flush(); // artifacts on disk -> evictable
        for round in 0..3 {
            for (id, m) in &mats {
                let x: Vec<f64> =
                    (0..m.ncols).map(|j| ((j + round) as f64 * 0.01).cos()).collect();
                let mut want = vec![0.0; m.nrows];
                spmv_csr(m, &x, &mut want).unwrap();
                let got = svc.spmv(*id, x).unwrap();
                crate::util::propcheck::assert_close(&got, &want, 1e-12, 1e-9).unwrap();
            }
        }
        assert!(svc.metrics.evictions.load(Ordering::Relaxed) >= 1);
        assert!(svc.metrics.cold_loads.load(Ordering::Relaxed) >= 1);
        assert!(svc.metrics.cold_load_summary().count >= 1);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
