//! Small self-contained substrates: PRNGs, CLI parsing, timing, CSV/markdown
//! report writers, property-testing helpers, error types.
//!
//! This environment resolves only the vendored crate set (no rand/clap/
//! criterion/proptest), so these are implemented here from scratch.

pub mod cli;
pub mod csv;
pub mod error;
pub mod propcheck;
pub mod rng;
pub mod threadpool;
pub mod timer;
