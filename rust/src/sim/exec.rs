//! Per-kernel execution models: each SpMVM kernel is replayed as a stream
//! of memory accesses (fed through the L2 model) plus an instruction count,
//! then timed with a roofline `max(memory, compute) + launch` model.
//!
//! This is the stand-in for the paper's RTX 5090 measurements. It is not a
//! cycle simulator; it reproduces the *first-order* effects the paper's
//! evaluation turns on:
//!
//! * SpMVM is memory-bound → bytes moved dominate for large matrices,
//!   so compressed formats win there (Fig. 7/8 bottom-right);
//! * decode costs instructions → dtANS loses when compute-bound or when
//!   the matrix is small (launch + table-load overheads, low occupancy);
//! * warm vs cold cache → matrices fitting in 96 MB L2 stop paying DRAM
//!   bandwidth on the second run (Table II vs Table III);
//! * x-vector gathers hit or miss depending on column locality, so
//!   structure matters, not just nnz;
//! * warp-synchronous kernels pay the slice maximum, so irregular row
//!   lengths hurt CSR-scalar and CSR-dtANS but not SELL/COO (upper-left
//!   quadrant of Fig. 7).

use super::cache::Cache;
use super::device::GpuModel;
use crate::format::csr_dtans::{CsrDtans, WARP};
use crate::matrix::csr::Csr;
use crate::matrix::sell::Sell;
use crate::matrix::Precision;

/// Kernels the simulator models.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum KernelKind {
    /// One thread per row over CSR.
    CsrScalar,
    /// One warp per row over CSR.
    CsrVector,
    /// Atomic scatter over COO.
    Coo,
    /// Column-major slice kernel over SELL (slice height 32).
    Sell,
    /// Fused dtANS decode + SpMVM over CSR-dtANS.
    CsrDtans,
}

impl KernelKind {
    /// Report label.
    pub fn label(&self) -> &'static str {
        match self {
            KernelKind::CsrScalar => "CSR",
            KernelKind::CsrVector => "CSR-vector",
            KernelKind::Coo => "COO",
            KernelKind::Sell => "SELL",
            KernelKind::CsrDtans => "CSR-dtANS",
        }
    }
}

/// Simulation result for one kernel launch.
#[derive(Debug, Clone, Copy, Default)]
pub struct SimResult {
    /// Modeled execution time.
    pub time_us: f64,
    /// Bytes served by DRAM.
    pub dram_bytes: u64,
    /// Bytes served by L2.
    pub l2_bytes: u64,
    /// Lane-instructions executed.
    pub instrs: u64,
    /// Memory-model time component (µs).
    pub mem_us: f64,
    /// Compute-model time component (µs).
    pub compute_us: f64,
}

// Disjoint synthetic base addresses per array.
const A_ROWPTR: u64 = 0x01_0000_0000;
const A_COLS: u64 = 0x02_0000_0000;
const A_VALS: u64 = 0x04_0000_0000;
const A_X: u64 = 0x06_0000_0000;
const A_Y: u64 = 0x08_0000_0000;
const A_ROWS: u64 = 0x0a_0000_0000;
const A_STREAM: u64 = 0x0c_0000_0000;
const A_TABLES: u64 = 0x0e_0000_0000;
const A_ROWNNZ: u64 = 0x10_0000_0000;
const A_ESC: u64 = 0x12_0000_0000;
const A_SLICEOFF: u64 = 0x14_0000_0000;

struct Tracer<'a> {
    cache: &'a mut Cache,
    instrs: u64,
}

impl<'a> Tracer<'a> {
    /// Sequential (coalesced) read of `bytes` from `base`.
    fn seq(&mut self, base: u64, bytes: usize) {
        let line = 128;
        let mut off = 0;
        while off < bytes {
            self.cache.access(base + off as u64, line.min(bytes - off));
            off += line;
        }
    }

    /// One gathered element access.
    fn gather(&mut self, base: u64, index: u64, elem: usize) {
        self.cache.access(base + index * elem as u64, elem);
    }
}

/// Inputs to a simulation: the matrix in all relevant formats.
pub struct SimInput<'a> {
    /// CSR form (always required).
    pub csr: &'a Csr,
    /// SELL form (required for `KernelKind::Sell`).
    pub sell: Option<&'a Sell>,
    /// Encoded form (required for `KernelKind::CsrDtans`).
    pub enc: Option<&'a CsrDtans>,
    /// Value precision (element sizes).
    pub precision: Precision,
}

fn trace_kernel(kind: KernelKind, inp: &SimInput, dev: &GpuModel, tr: &mut Tracer) -> u64 {
    let m = inp.csr;
    let vb = inp.precision.value_bytes();
    match kind {
        KernelKind::CsrScalar => {
            tr.seq(A_ROWPTR, (m.nrows + 1) * 4);
            tr.seq(A_COLS, m.nnz() * 4);
            tr.seq(A_VALS, m.nnz() * vb);
            for r in 0..m.nrows {
                for &c in m.row_cols(r) {
                    tr.gather(A_X, c as u64, vb);
                }
            }
            tr.seq(A_Y, m.nrows * vb);
            // Warp-synchronous: each warp pays its longest row.
            let mut instr = 0u64;
            for w0 in (0..m.nrows).step_by(32) {
                let maxlen = (w0..(w0 + 32).min(m.nrows))
                    .map(|r| m.row_len(r))
                    .max()
                    .unwrap_or(0);
                instr += 32 * (8 * maxlen as u64 + 6);
            }
            instr
        }
        KernelKind::CsrVector => {
            tr.seq(A_ROWPTR, (m.nrows + 1) * 4);
            tr.seq(A_COLS, m.nnz() * 4);
            tr.seq(A_VALS, m.nnz() * vb);
            for r in 0..m.nrows {
                for &c in m.row_cols(r) {
                    tr.gather(A_X, c as u64, vb);
                }
            }
            tr.seq(A_Y, m.nrows * vb);
            // One warp per row: ceil(len/32) coalesced strides + reduction.
            (0..m.nrows)
                .map(|r| 32 * (8 * m.row_len(r).div_ceil(32) as u64 + 12))
                .sum()
        }
        KernelKind::Coo => {
            tr.seq(A_ROWS, m.nnz() * 4);
            tr.seq(A_COLS, m.nnz() * 4);
            tr.seq(A_VALS, m.nnz() * vb);
            for r in 0..m.nrows {
                for &c in m.row_cols(r) {
                    tr.gather(A_X, c as u64, vb);
                }
                // Atomic y update per nonzero.
                for _ in 0..m.row_len(r) {
                    tr.gather(A_Y, r as u64, vb);
                }
            }
            m.nnz() as u64 * 14
        }
        KernelKind::Sell => {
            let sell = inp.sell.expect("SELL input required");
            let padded = sell.padded_cells();
            tr.seq(A_SLICEOFF, sell.nslices() * 4);
            tr.seq(A_COLS, padded * 4);
            tr.seq(A_VALS, padded * vb);
            for s in 0..sell.nslices() {
                let base = sell.slice_ptr[s];
                for idx in base..sell.slice_ptr[s + 1] {
                    tr.gather(A_X, sell.cols[idx] as u64, vb);
                }
            }
            tr.seq(A_Y, m.nrows * vb);
            padded as u64 * 7
        }
        KernelKind::CsrDtans => {
            let enc = inp.enc.expect("CSR-dtANS input required");
            // Coding tables + dictionaries: loaded into shared memory by
            // every resident block; repeats hit L2.
            let table_bytes = enc.delta_tables.table_bytes()
                + enc.value_tables.table_bytes()
                + enc.delta_domain.num_symbols() * 4
                + enc.value_domain.num_symbols() * vb;
            let resident = enc.nslices().min(dev.sms as usize * 2).max(1);
            for _ in 0..resident {
                tr.seq(A_TABLES, table_bytes);
            }
            tr.seq(A_ROWNNZ, enc.nrows * 4);
            tr.seq(A_SLICEOFF, (enc.nslices() + 1) * 4);
            tr.seq(A_STREAM, enc.stream.len() * 4);
            if !enc.delta_escapes.is_empty() {
                tr.seq(A_ESC, enc.delta_escapes.len() * 4 + (enc.nrows + 1) * 4);
            }
            if !enc.value_escapes.is_empty() {
                tr.seq(A_ESC + 0x1_0000_0000, enc.value_escapes.len() * vb + (enc.nrows + 1) * 4);
            }
            for r in 0..m.nrows {
                for &c in m.row_cols(r) {
                    tr.gather(A_X, c as u64, vb);
                }
            }
            tr.seq(A_Y, m.nrows * vb);
            // Warp lockstep: a slice pays its maximum segment count.
            let nps = enc.nnz_per_segment() as u64;
            let mut instr = 0u64;
            for s in 0..enc.nslices() {
                let r0 = s * WARP;
                let r1 = (r0 + WARP).min(enc.nrows);
                let max_seg = (r0..r1).map(|r| enc.row_segments(r)).max().unwrap_or(0) as u64;
                // Per segment per lane: unpack (6) + 2 table lookups, digit
                // fold and FMA per nonzero (9 each) + 2 checks (6 each).
                instr += 32 * max_seg * (6 + 9 * nps + 12);
            }
            // Escape handling costs a few extra ops per escaped payload.
            instr += (enc.delta_escapes.len() + enc.value_escapes.len()) as u64 * 4;
            instr
        }
    }
}

/// Occupancy: fraction of the device the kernel can keep busy.
fn occupancy(kind: KernelKind, inp: &SimInput, dev: &GpuModel) -> f64 {
    let warps_needed = match kind {
        KernelKind::CsrScalar | KernelKind::Sell | KernelKind::CsrDtans => {
            inp.csr.nrows.div_ceil(32)
        }
        KernelKind::CsrVector => inp.csr.nrows,
        KernelKind::Coo => inp.csr.nnz().div_ceil(32 * 4),
    } as f64;
    // ~12 resident warps per SM keep bandwidth saturated.
    (warps_needed / (dev.sms as f64 * 12.0)).min(1.0)
}

/// Simulate one kernel on one matrix. `warm`: the kernel ran once already
/// (L2 primed); cold: L2 flushed.
pub fn simulate(kind: KernelKind, inp: &SimInput, dev: &GpuModel, warm: bool) -> SimResult {
    let mut cache = Cache::new(dev.l2_bytes, dev.l2_line, dev.l2_ways);
    let instrs;
    if warm {
        let mut tr = Tracer { cache: &mut cache, instrs: 0 };
        trace_kernel(kind, inp, dev, &mut tr);
        cache.reset_stats();
    } else {
        cache.flush();
    }
    {
        let mut tr = Tracer { cache: &mut cache, instrs: 0 };
        instrs = trace_kernel(kind, inp, dev, &mut tr) + tr.instrs;
    }
    let dram_bytes = cache.miss_bytes;
    let l2_bytes = cache.hit_bytes;
    let occ = occupancy(kind, inp, dev).max(1e-3);
    let mem_us = (dram_bytes as f64 / (dev.dram_bw_gbs * occ * 1e3))
        + (l2_bytes as f64 / (dev.l2_bw_gbs * occ * 1e3));
    let compute_us = instrs as f64 / (dev.instr_rate() * occ) * 1e6;
    SimResult {
        time_us: mem_us.max(compute_us) + dev.launch_us,
        dram_bytes,
        l2_bytes,
        instrs,
        mem_us,
        compute_us,
    }
}

/// Convenience: simulate the best (minimum-time) cuSPARSE-style baseline
/// (CSR scalar/vector, COO, SELL) and return (kind, result).
pub fn best_baseline(inp: &SimInput, dev: &GpuModel, warm: bool) -> (KernelKind, SimResult) {
    [
        KernelKind::CsrScalar,
        KernelKind::CsrVector,
        KernelKind::Coo,
        KernelKind::Sell,
    ]
    .into_iter()
    .map(|k| (k, simulate(k, inp, dev, warm)))
    .min_by(|a, b| a.1.time_us.partial_cmp(&b.1.time_us).unwrap())
    .unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::format::csr_dtans::EncodeOptions;
    use crate::matrix::gen::structured::banded;
    use crate::matrix::gen::{assign_values, ValueDist};
    use crate::util::rng::Xoshiro256;

    fn setup(n: usize, bw: usize, vals: ValueDist) -> (Csr, Sell, CsrDtans) {
        let mut m = banded(n, bw);
        assign_values(&mut m, vals, &mut Xoshiro256::seeded(1));
        let sell = Sell::from_csr(&m, 32);
        let enc = CsrDtans::encode(&m, &EncodeOptions::default()).unwrap();
        (m, sell, enc)
    }

    fn input<'a>(m: &'a Csr, sell: &'a Sell, enc: &'a CsrDtans) -> SimInput<'a> {
        SimInput {
            csr: m,
            sell: Some(sell),
            enc: Some(enc),
            precision: Precision::F64,
        }
    }

    #[test]
    fn warm_is_not_slower_than_cold() {
        let (m, sell, enc) = setup(20_000, 4, ValueDist::Ones);
        let inp = input(&m, &sell, &enc);
        for k in [
            KernelKind::CsrScalar,
            KernelKind::CsrVector,
            KernelKind::Coo,
            KernelKind::Sell,
            KernelKind::CsrDtans,
        ] {
            let cold = simulate(k, &inp, &GpuModel::RTX5090, false);
            let warm = simulate(k, &inp, &GpuModel::RTX5090, true);
            assert!(warm.time_us <= cold.time_us + 1e-9, "{k:?}");
        }
    }

    #[test]
    fn warm_fitting_matrix_has_no_dram_traffic() {
        let (m, sell, enc) = setup(5_000, 2, ValueDist::Ones);
        let inp = input(&m, &sell, &enc);
        let warm = simulate(KernelKind::CsrScalar, &inp, &GpuModel::RTX5090, true);
        assert_eq!(warm.dram_bytes, 0, "fits in 96 MB L2");
    }

    #[test]
    fn dtans_moves_fewer_bytes_on_compressible_matrix() {
        // Highly structured banded matrix with constant values: dtANS
        // traffic must be far below CSR's (the paper's core premise).
        let (m, sell, enc) = setup(200_000, 4, ValueDist::Ones);
        let inp = input(&m, &sell, &enc);
        let base = simulate(KernelKind::CsrScalar, &inp, &GpuModel::RTX5090, false);
        let dt = simulate(KernelKind::CsrDtans, &inp, &GpuModel::RTX5090, false);
        assert!(
            dt.dram_bytes * 2 < base.dram_bytes,
            "dtans {} vs csr {}",
            dt.dram_bytes,
            base.dram_bytes
        );
    }

    #[test]
    fn dtans_costs_more_instructions() {
        let (m, sell, enc) = setup(50_000, 4, ValueDist::Ones);
        let inp = input(&m, &sell, &enc);
        let base = simulate(KernelKind::CsrScalar, &inp, &GpuModel::RTX5090, false);
        let dt = simulate(KernelKind::CsrDtans, &inp, &GpuModel::RTX5090, false);
        assert!(dt.instrs > base.instrs);
    }

    #[test]
    fn small_matrix_dtans_loses_large_compressible_wins() {
        let dev = GpuModel::RTX5090;
        // Small: launch + tables dominate -> dtANS slower.
        let (m, sell, enc) = setup(500, 2, ValueDist::Ones);
        let inp = input(&m, &sell, &enc);
        let (_, base) = best_baseline(&inp, &dev, false);
        let dt = simulate(KernelKind::CsrDtans, &inp, &dev, false);
        assert!(dt.time_us >= base.time_us, "small should not win");
        // Large + compressible: dtANS faster (cold cache).
        let (m2, sell2, enc2) = setup(300_000, 5, ValueDist::Ones);
        let inp2 = input(&m2, &sell2, &enc2);
        let (_, base2) = best_baseline(&inp2, &dev, false);
        let dt2 = simulate(KernelKind::CsrDtans, &inp2, &dev, false);
        assert!(
            dt2.time_us < base2.time_us,
            "dtans {} vs base {}",
            dt2.time_us,
            base2.time_us
        );
    }
}
