//! Observability tour: run a mixed workload through the serving stack
//! with always-on tracing, then export everything the obs layer offers —
//! the human-readable metrics line, a Prometheus text exposition
//! (`results/metrics.prom`), a JSON metrics snapshot, and a Chrome
//! trace-event file (`results/trace.json`) you can drop into
//! <https://ui.perfetto.dev> or `chrome://tracing` to see every request's
//! span chain (submitted → queued → dispatched → pinned → kernel →
//! completed) laid out per dispatcher/worker track.
//!
//! Run: `cargo run --release --example observability`

use dtans::coordinator::{RoutePolicy, ServiceConfig, SpmvService};
use dtans::matrix::gen::structured::{banded, stencil2d5};
use dtans::matrix::gen::{assign_values, ValueDist};
use dtans::obs::export::{metrics_json, prometheus_text};
use dtans::obs::ObsConfig;
use dtans::util::rng::Xoshiro256;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    // Always-on tracing (`sample_one_in: 1`); production deployments
    // would sample (e.g. 1-in-64) or leave it off — see the
    // `obs_overhead` bench for the measured cost of each mode.
    let svc = SpmvService::start(ServiceConfig {
        workers: 2,
        policy: RoutePolicy { min_nnz: 1 << 12, max_size_ratio: 0.95, ..Default::default() },
        obs: ObsConfig { sample_one_in: 1, capacity: 8192 },
        ..Default::default()
    });

    // A compressible banded matrix (routes to csr_dtans, so the paper
    // gauges — compression ratio and decode throughput — populate) and
    // a small one that stays plain CSR.
    let mut rng = Xoshiro256::seeded(42);
    let mut big = banded(20_000, 4);
    assign_values(&mut big, ValueDist::FewDistinct(16), &mut rng);
    let big_id = svc.register("banded-20k", big)?;
    let small_id = svc.register("small-600", banded(600, 2))?;
    println!("banded-20k routed to {:?}", svc.format_of(big_id).unwrap());

    // A burst of concurrent requests (same-matrix ones may coalesce into
    // SpMM batches — watch for `coalesced` stages in the trace)...
    let mut pendings = Vec::new();
    for i in 0..48 {
        let (id, n) = if i % 3 == 0 { (small_id, 600) } else { (big_id, 20_000) };
        let x: Vec<f64> = (0..n).map(|j| ((i + j) as f64 * 0.01).sin()).collect();
        pendings.push(svc.submit(id, x)?);
    }
    for p in pendings {
        p.wait()?;
    }
    // ...and one iterative solve (a single span spanning the whole CG run).
    let spd = stencil2d5(48, 48);
    let nrows = spd.nrows;
    let spd_id = svc.register("poisson-48", spd)?;
    svc.solve(
        spd_id,
        dtans::solver::SolveMethod::Cg,
        &vec![1.0; nrows],
        &dtans::solver::SolverConfig { tol: 1e-8, ..Default::default() },
    )?;

    println!("metrics: {}", svc.metrics.report());

    // Export: Prometheus exposition + JSON snapshot + Chrome trace.
    let outdir = std::path::Path::new("results");
    std::fs::create_dir_all(outdir)?;
    let prom = prometheus_text(&svc.metrics);
    std::fs::write(outdir.join("metrics.prom"), &prom)?;
    let trace = svc.metrics.tracer().trace_json();
    std::fs::write(outdir.join("trace.json"), &trace)?;
    let events = svc.metrics.tracer().snapshot().len();
    println!(
        "wrote results/metrics.prom ({} lines) — scrape-ready Prometheus text",
        prom.lines().count()
    );
    println!(
        "wrote results/trace.json ({events} span events) — open in https://ui.perfetto.dev"
    );
    println!(
        "json snapshot: {} bytes via metrics_json()",
        metrics_json(&svc.metrics).len()
    );
    println!("OK");
    Ok(())
}
