//! BiCGStab over any [`SpmvOperator`] — van der Vorst's stabilized
//! bi-conjugate gradient for general (nonsymmetric) square systems, with
//! two fused [`run_axpby`](crate::spmv::engine::SpmvEngine::run_axpby)
//! multiplies per iteration.

use super::{check_square, dot, initial_x, norm2, Solution, SolveReport, SolverConfig, Termination};
use crate::spmv::engine::SpmvEngine;
use crate::spmv::operator::SpmvOperator;
use crate::util::error::Result;
use std::time::Instant;

/// Solve `A·x = b` by BiCGStab, building a fresh engine from
/// [`SolverConfig::par`]. `A` only needs to be square and nonsingular —
/// this is the service's method of choice for matrices CG's SPD contract
/// rules out. Vanishing method denominators (`ρ`, `r̂·v`, `t·t`) terminate
/// with [`Termination::Breakdown`].
///
/// Convergence is declared when `‖r‖₂ / ‖b‖₂ ≤ tol`, with the relative
/// residual recorded after the half step and the full step of every
/// iteration.
///
/// ```
/// use dtans::matrix::{Coo, Csr};
/// use dtans::solver::{bicgstab, SolverConfig};
///
/// // Diagonally dominant but nonsymmetric: CG's contract excludes it.
/// let n = 24;
/// let mut coo = Coo::new(n, n);
/// for i in 0..n as u32 {
///     coo.push(i, i, 4.0);
///     if i > 0 { coo.push(i, i - 1, -0.8); }
///     if i + 1 < n as u32 { coo.push(i, i + 1, -1.7); }
/// }
/// let a = Csr::from_coo(&coo);
/// let b = vec![1.0; n];
/// let sol = bicgstab(&a, &b, &SolverConfig::default()).unwrap();
/// assert!(sol.report.converged());
/// let mut ax = vec![0.0; n];
/// dtans::spmv::spmv_csr(&a, &sol.x, &mut ax).unwrap();
/// assert!(ax.iter().zip(&b).all(|(l, r)| (l - r).abs() < 1e-8));
/// ```
pub fn bicgstab(op: &dyn SpmvOperator, b: &[f64], cfg: &SolverConfig) -> Result<Solution> {
    bicgstab_with(&SpmvEngine::new(cfg.par), op, b, None, cfg)
}

/// [`bicgstab`] on an existing engine, with an optional initial guess
/// `x0` (zeros when `None`) — the service's shared-engine entry point.
///
/// ```
/// use dtans::matrix::gen::structured::tridiagonal;
/// use dtans::solver::{bicgstab_with, SolverConfig};
/// use dtans::spmv::engine::SpmvEngine;
///
/// let a = tridiagonal(16); // symmetric systems are fine too
/// let b = vec![1.0; 16];
/// let engine = SpmvEngine::serial();
/// let sol = bicgstab_with(&engine, &a, &b, None, &SolverConfig::default()).unwrap();
/// assert!(sol.report.converged());
/// ```
pub fn bicgstab_with(
    engine: &SpmvEngine,
    op: &dyn SpmvOperator,
    b: &[f64],
    x0: Option<&[f64]>,
    cfg: &SolverConfig,
) -> Result<Solution> {
    let n = check_square(op, b.len())?;
    let t_total = Instant::now();
    let mut spmv_secs = 0.0;
    let mut vector_secs = 0.0;

    let mut x = initial_x(n, x0)?;
    let mut r = b.to_vec();
    if x0.is_some() {
        let t = Instant::now();
        engine.run_axpby(op, &x, -1.0, 1.0, &mut r)?; // r = b - A·x0
        spmv_secs += t.elapsed().as_secs_f64();
    }

    let bnorm = norm2(b);
    let mut residuals = Vec::new();
    let finish = |termination,
                  iterations,
                  residuals: Vec<f64>,
                  x,
                  spmv_secs: f64,
                  vector_secs: f64| {
        Ok(Solution {
            x,
            report: SolveReport {
                termination,
                iterations,
                residuals,
                spmv_secs,
                vector_secs,
                total_secs: t_total.elapsed().as_secs_f64(),
            },
        })
    };
    if bnorm == 0.0 {
        return finish(Termination::Converged, 0, residuals, vec![0.0; n], spmv_secs, vector_secs);
    }
    if norm2(&r) <= cfg.tol * bnorm {
        return finish(Termination::Converged, 0, residuals, x, spmv_secs, vector_secs);
    }

    // Shadow residual r̂ is fixed to the initial residual.
    let rhat = r.clone();
    let (mut rho, mut alpha, mut omega) = (1.0f64, 1.0f64, 1.0f64);
    let mut v = vec![0.0; n];
    let mut p = vec![0.0; n];
    let mut t_vec = vec![0.0; n];
    let mut termination = Termination::MaxIters;
    let mut iterations = 0;
    for _ in 0..cfg.max_iters {
        let t = Instant::now();
        let rho_new = dot(&rhat, &r);
        if rho_new == 0.0 {
            termination = Termination::Breakdown;
            vector_secs += t.elapsed().as_secs_f64();
            break;
        }
        let beta = (rho_new / rho) * (alpha / omega);
        for i in 0..n {
            p[i] = r[i] + beta * (p[i] - omega * v[i]);
        }
        vector_secs += t.elapsed().as_secs_f64();

        let t = Instant::now();
        engine.run_axpby(op, &p, 1.0, 0.0, &mut v)?; // v = A·p
        spmv_secs += t.elapsed().as_secs_f64();

        let t = Instant::now();
        let rv = dot(&rhat, &v);
        if rv == 0.0 {
            termination = Termination::Breakdown;
            vector_secs += t.elapsed().as_secs_f64();
            break;
        }
        alpha = rho_new / rv;
        // Half step: r becomes s = r - alpha·v.
        for i in 0..n {
            r[i] -= alpha * v[i];
        }
        iterations += 1;
        let srel = norm2(&r) / bnorm;
        residuals.push(srel);
        if srel <= cfg.tol {
            for i in 0..n {
                x[i] += alpha * p[i];
            }
            termination = Termination::Converged;
            vector_secs += t.elapsed().as_secs_f64();
            break;
        }
        vector_secs += t.elapsed().as_secs_f64();

        let t = Instant::now();
        engine.run_axpby(op, &r, 1.0, 0.0, &mut t_vec)?; // t = A·s
        spmv_secs += t.elapsed().as_secs_f64();

        let t = Instant::now();
        let tt = dot(&t_vec, &t_vec);
        if tt == 0.0 {
            termination = Termination::Breakdown;
            vector_secs += t.elapsed().as_secs_f64();
            break;
        }
        omega = dot(&t_vec, &r) / tt;
        for i in 0..n {
            x[i] += alpha * p[i] + omega * r[i];
        }
        // Full step: r = s - omega·t.
        for i in 0..n {
            r[i] -= omega * t_vec[i];
        }
        let rel = norm2(&r) / bnorm;
        residuals.push(rel);
        if rel <= cfg.tol {
            termination = Termination::Converged;
            vector_secs += t.elapsed().as_secs_f64();
            break;
        }
        if omega == 0.0 {
            termination = Termination::Breakdown;
            vector_secs += t.elapsed().as_secs_f64();
            break;
        }
        rho = rho_new;
        vector_secs += t.elapsed().as_secs_f64();
    }
    finish(termination, iterations, residuals, x, spmv_secs, vector_secs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::coo::Coo;
    use crate::matrix::csr::Csr;
    use crate::matrix::gen::structured::stencil2d5;
    use crate::spmv::spmv_csr;

    /// Diagonally dominant nonsymmetric test system.
    fn nonsym(n: usize) -> Csr {
        let mut coo = Coo::new(n, n);
        for i in 0..n as u32 {
            coo.push(i, i, 4.0);
            if i > 0 {
                coo.push(i, i - 1, -0.6);
            }
            if i + 1 < n as u32 {
                coo.push(i, i + 1, -1.9);
            }
        }
        Csr::from_coo(&coo)
    }

    #[test]
    fn solves_nonsymmetric_system() {
        let a = nonsym(200);
        let b: Vec<f64> = (0..200).map(|i| ((i as f64) * 0.17).cos()).collect();
        let sol = bicgstab(&a, &b, &SolverConfig::default()).unwrap();
        assert!(sol.report.converged(), "{:?}", sol.report.termination);
        let mut ax = vec![0.0; 200];
        spmv_csr(&a, &sol.x, &mut ax).unwrap();
        for (l, r) in ax.iter().zip(&b) {
            assert!((l - r).abs() < 1e-7, "{l} vs {r}");
        }
    }

    #[test]
    fn agrees_with_cg_on_spd_system() {
        let a = stencil2d5(12, 12);
        let b = vec![1.0; a.nrows];
        let cfg = SolverConfig { tol: 1e-12, ..Default::default() };
        let bi = bicgstab(&a, &b, &cfg).unwrap();
        let cg = super::super::cg(&a, &b, &cfg).unwrap();
        assert!(bi.report.converged() && cg.report.converged());
        for (l, r) in bi.x.iter().zip(&cg.x) {
            assert!((l - r).abs() < 1e-8);
        }
    }

    #[test]
    fn zero_rhs_short_circuits() {
        let sol = bicgstab(&nonsym(10), &[0.0; 10], &SolverConfig::default()).unwrap();
        assert!(sol.report.converged());
        assert_eq!(sol.report.iterations, 0);
    }
}
