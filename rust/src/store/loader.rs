//! Background worker for the tiered store: encode-and-persist and
//! cold-load jobs run on a [`ThreadPool`] off the request path, with
//! per-id dedup so N concurrent requests for the same cold matrix trigger
//! exactly one load — the joiners block on the leader's result instead of
//! issuing N disk reads and N plan builds.

use crate::util::error::{DtansError, Result};
use crate::util::threadpool::ThreadPool;
use std::collections::HashMap;
use std::sync::{Arc, Condvar, Mutex};

/// One in-flight deduped job: joiners wait on `done` until the leader's
/// result is published into `state`.
struct Slot<T> {
    state: Mutex<Option<Result<Arc<T>>>>,
    done: Condvar,
}

/// Deduping background job runner, generic over the loaded payload.
pub struct Loader<T> {
    pool: ThreadPool,
    inflight: Arc<Mutex<HashMap<u64, Arc<Slot<T>>>>>,
}

impl<T: Send + Sync + 'static> Loader<T> {
    /// Spawn a loader with `threads` workers (at least 1).
    pub fn new(threads: usize) -> Loader<T> {
        Loader {
            pool: ThreadPool::new(threads.max(1)),
            inflight: Arc::new(Mutex::new(HashMap::new())),
        }
    }

    /// Run `job` for `id` on the pool, deduplicating against concurrent
    /// calls: the first caller becomes the leader and submits the job;
    /// everyone (leader included) blocks until the result is published and
    /// receives a clone of it. A panicking job is reported as a
    /// [`DtansError::Service`] error to every waiter rather than hanging
    /// them.
    pub fn run_dedup<F>(&self, id: u64, job: F) -> Result<Arc<T>>
    where
        F: FnOnce() -> Result<Arc<T>> + Send + 'static,
    {
        let (slot, leader) = {
            let mut g = self.inflight.lock().unwrap();
            match g.get(&id) {
                Some(s) => (Arc::clone(s), false),
                None => {
                    let s = Arc::new(Slot {
                        state: Mutex::new(None),
                        done: Condvar::new(),
                    });
                    g.insert(id, Arc::clone(&s));
                    (s, true)
                }
            }
        };
        if leader {
            let inflight = Arc::clone(&self.inflight);
            let publish = Arc::clone(&slot);
            self.pool.execute(move || {
                let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(job))
                    .unwrap_or_else(|_| Err(DtansError::Service("load job panicked".into())));
                // Retire the slot before publishing: a caller arriving
                // after publication must start a fresh job, not join a
                // finished one.
                inflight.lock().unwrap().remove(&id);
                let mut st = publish.state.lock().unwrap();
                *st = Some(res);
                publish.done.notify_all();
            });
        }
        let mut st = slot.state.lock().unwrap();
        while st.is_none() {
            st = slot.done.wait(st).unwrap();
        }
        match st.as_ref().expect("published above") {
            Ok(v) => Ok(Arc::clone(v)),
            Err(e) => Err(e.duplicate()),
        }
    }

    /// Fire-and-forget background job (used for persist-after-encode).
    pub fn spawn(&self, job: impl FnOnce() + Send + 'static) {
        self.pool.execute(job);
    }

    /// Block until every submitted job has finished (tests and benches
    /// use this to make background persists deterministic).
    pub fn wait_idle(&self) {
        self.pool.wait_idle();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::time::Duration;

    #[test]
    fn concurrent_callers_share_one_execution() {
        let loader: Arc<Loader<u64>> = Arc::new(Loader::new(2));
        let runs = Arc::new(AtomicUsize::new(0));
        // All callers line up at a barrier, then race into run_dedup while
        // the leader's job holds the slot open well past the race window.
        let barrier = Arc::new(std::sync::Barrier::new(8));
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let loader = Arc::clone(&loader);
                let runs = Arc::clone(&runs);
                let barrier = Arc::clone(&barrier);
                std::thread::spawn(move || {
                    barrier.wait();
                    loader
                        .run_dedup(7, move || {
                            runs.fetch_add(1, Ordering::SeqCst);
                            std::thread::sleep(Duration::from_millis(500));
                            Ok(Arc::new(42u64))
                        })
                        .unwrap()
                })
            })
            .collect();
        for h in handles {
            assert_eq!(*h.join().unwrap(), 42);
        }
        assert_eq!(runs.load(Ordering::SeqCst), 1, "job must run exactly once");
    }

    #[test]
    fn distinct_ids_run_independently() {
        let loader: Loader<u64> = Loader::new(2);
        let a = loader.run_dedup(1, || Ok(Arc::new(1))).unwrap();
        let b = loader.run_dedup(2, || Ok(Arc::new(2))).unwrap();
        assert_eq!((*a, *b), (1, 2));
    }

    #[test]
    fn errors_reach_every_waiter() {
        let loader: Loader<u64> = Loader::new(1);
        let err = loader
            .run_dedup(3, || Err(DtansError::Service("no artifact".into())))
            .unwrap_err();
        assert!(err.to_string().contains("no artifact"));
        // The slot was retired: a retry runs a fresh job.
        assert_eq!(*loader.run_dedup(3, || Ok(Arc::new(9))).unwrap(), 9);
    }

    #[test]
    fn panicking_job_fails_cleanly() {
        let loader: Loader<u64> = Loader::new(1);
        let err = loader.run_dedup(4, || panic!("boom")).unwrap_err();
        assert!(err.to_string().contains("panicked"));
        // Pool worker survived.
        assert_eq!(*loader.run_dedup(5, || Ok(Arc::new(5))).unwrap(), 5);
    }
}
