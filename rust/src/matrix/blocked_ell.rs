//! Blocked ELLPACK (BlockedEll) — CMRS / adaptive-row-grouped-CSR-style
//! balanced fixed-width row blocks: rows are sorted by length inside
//! small σ-windows, grouped into fixed-height blocks of `block_rows`
//! lanes, and each block is padded to its local maximum row length with
//! an explicit column-index sentinel ([`BlockedEll::PAD_COL`]).
//!
//! Relative to SELL this trades the per-slice width array's irregular
//! strides for *uniform* lane stride (`block_rows` everywhere) plus a
//! window-local length sort that shrinks padding on skewed row-length
//! distributions — the shape that lets the unrolled wide-accumulator
//! kernels ([`crate::spmv::unrolled`]) run every lane of a block without
//! per-row bounds juggling. The sort permutes rows **only within a
//! σ-window**, so a window still covers a contiguous original-row range
//! and the engine can hand each partition a disjoint `&mut` output
//! segment (the same contract every other format keeps).

use super::coo::Coo;
use super::csr::Csr;

/// Blocked ELLPACK matrix: σ-window length-sorted rows in fixed-height
/// padded blocks. See the [module docs](self) for the layout rationale
/// and `docs/KERNELS.md` for the kernel contract on top of it.
///
/// Layout: block `b` owns row *positions* `b·C .. min((b+1)·C, nrows)`
/// (`C =` [`block_rows`](BlockedEll::block_rows)); position `p` holds
/// original row [`perm`](BlockedEll::perm)`[p]`. The block stores
/// `width[b] · C` cells column-major with **uniform stride `C`**:
/// within-row element `j` of lane `t` lives at
/// `block_ptr[b] + j·C + t`. Absent cells — lanes past `nrows` in the
/// tail block, and positions `j ≥ row_lens[p]` — carry column
/// [`BlockedEll::PAD_COL`] and value `0.0`.
#[derive(Debug, Clone, PartialEq)]
pub struct BlockedEll {
    /// Number of rows of the logical matrix.
    pub nrows: usize,
    /// Number of columns.
    pub ncols: usize,
    /// Lanes (rows) per block, `1..=32` — the fixed accumulator width.
    pub block_rows: usize,
    /// Sort-window size in rows; always a multiple of `block_rows`.
    /// Rows are length-sorted only within a window, so windows map to
    /// contiguous original-row ranges.
    pub sigma: usize,
    /// Position → original row (length `nrows`). Within each σ-window,
    /// rows sorted by descending length, ties by ascending row index.
    pub perm: Vec<u32>,
    /// Per-block padded width (local max row length; length = nblocks).
    pub block_width: Vec<u32>,
    /// Start offset of each block in `cols`/`vals` (length = nblocks + 1).
    pub block_ptr: Vec<usize>,
    /// Padded-cell prefix per σ-window (length = nwindows + 1) — the
    /// engine's cost prefix; windows are the format's work units.
    pub window_ptr: Vec<usize>,
    /// Column indices, column-major within a block; padding is
    /// [`BlockedEll::PAD_COL`].
    pub cols: Vec<u32>,
    /// Values, column-major within a block; padding is `0.0`.
    pub vals: Vec<f64>,
    /// Actual row length at each *position* `p` (i.e. of row `perm[p]`).
    pub row_lens: Vec<u32>,
}

impl BlockedEll {
    /// Sentinel column index marking a padded cell. Kernels must skip it —
    /// unlike SELL's repeat-a-valid-column padding, it is **not** a legal
    /// index into `x`.
    pub const PAD_COL: u32 = u32::MAX;

    /// Largest supported `block_rows` (the kernels keep one stack
    /// accumulator per lane).
    pub const MAX_BLOCK_ROWS: usize = 32;

    /// Default lane count: matches the widest unrolled kernel variant.
    pub const DEFAULT_BLOCK_ROWS: usize = 8;

    /// Default sort window (rows).
    pub const DEFAULT_SIGMA: usize = 64;

    /// Number of blocks.
    pub fn nblocks(&self) -> usize {
        self.block_width.len()
    }

    /// Number of σ-windows (the format's work units).
    pub fn nwindows(&self) -> usize {
        self.window_ptr.len() - 1
    }

    /// Blocks per full window (`sigma / block_rows`).
    pub fn blocks_per_window(&self) -> usize {
        self.sigma / self.block_rows
    }

    /// Total padded cells (real kernel work, like SELL's).
    pub fn padded_cells(&self) -> usize {
        self.vals.len()
    }

    /// Build with the default geometry
    /// ([`DEFAULT_BLOCK_ROWS`](BlockedEll::DEFAULT_BLOCK_ROWS) lanes,
    /// [`DEFAULT_SIGMA`](BlockedEll::DEFAULT_SIGMA)-row windows).
    pub fn from_csr_default(csr: &Csr) -> BlockedEll {
        BlockedEll::from_csr(csr, Self::DEFAULT_BLOCK_ROWS, Self::DEFAULT_SIGMA)
    }

    /// Build from CSR. `block_rows` must be in
    /// `1..=`[`MAX_BLOCK_ROWS`](BlockedEll::MAX_BLOCK_ROWS); `sigma` is
    /// rounded **up** to a multiple of `block_rows` (and at least one
    /// block), so window boundaries always align with block boundaries.
    pub fn from_csr(csr: &Csr, block_rows: usize, sigma: usize) -> BlockedEll {
        assert!(
            block_rows >= 1 && block_rows <= Self::MAX_BLOCK_ROWS,
            "block_rows {block_rows} outside 1..={}",
            Self::MAX_BLOCK_ROWS
        );
        let sigma = sigma.max(block_rows).div_ceil(block_rows) * block_rows;
        let c = block_rows;
        let nblocks = csr.nrows.div_ceil(c);
        let nwindows = csr.nrows.div_ceil(sigma);
        let bpw = sigma / c;

        // Window-local descending-length sort (stable: ties keep ascending
        // row order) — σ bounds how far a row may move, and keeps each
        // window a contiguous original-row range.
        let mut perm: Vec<u32> = (0..csr.nrows as u32).collect();
        for w in 0..nwindows {
            let lo = w * sigma;
            let hi = (lo + sigma).min(csr.nrows);
            perm[lo..hi].sort_by_key(|&r| (usize::MAX - csr.row_len(r as usize), r));
        }
        let row_lens: Vec<u32> = perm.iter().map(|&r| csr.row_len(r as usize) as u32).collect();

        let mut block_width = Vec::with_capacity(nblocks);
        let mut block_ptr = vec![0usize];
        let mut cols = Vec::new();
        let mut vals = Vec::new();
        for b in 0..nblocks {
            let p0 = b * c;
            let p1 = (p0 + c).min(csr.nrows);
            // Sorted descending within the window and block boundaries
            // align to window boundaries, so the first lane is the widest.
            let width = (p0..p1).map(|p| row_lens[p] as usize).max().unwrap_or(0);
            block_width.push(width as u32);
            // Column-major, uniform stride C: element j of every lane.
            for j in 0..width {
                for t in 0..c {
                    let p = p0 + t;
                    if p < p1 && (j as u32) < row_lens[p] {
                        let r = perm[p] as usize;
                        cols.push(csr.row_cols(r)[j]);
                        vals.push(csr.row_vals(r)[j]);
                    } else {
                        cols.push(Self::PAD_COL);
                        vals.push(0.0);
                    }
                }
            }
            block_ptr.push(cols.len());
        }
        let window_ptr: Vec<usize> =
            (0..=nwindows).map(|w| block_ptr[(w * bpw).min(nblocks)]).collect();

        BlockedEll {
            nrows: csr.nrows,
            ncols: csr.ncols,
            block_rows: c,
            sigma,
            perm,
            block_width,
            block_ptr,
            window_ptr,
            cols,
            vals,
            row_lens,
        }
    }

    /// Convert back to CSR (drops padding, undoes the permutation) —
    /// used by tests.
    pub fn to_csr(&self) -> Csr {
        let mut coo = Coo::new(self.nrows, self.ncols);
        let c = self.block_rows;
        for b in 0..self.nblocks() {
            let p0 = b * c;
            let width = self.block_width[b] as usize;
            let base = self.block_ptr[b];
            for t in 0..c {
                let p = p0 + t;
                if p >= self.nrows {
                    break;
                }
                let r = self.perm[p];
                for j in 0..(self.row_lens[p] as usize).min(width) {
                    let idx = base + j * c + t;
                    coo.push(r, self.cols[idx], self.vals[idx]);
                }
            }
        }
        Csr::from_coo(&coo)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn example() -> Csr {
        let mut coo = Coo::new(5, 6);
        for &(r, c, v) in &[
            (0, 0, 1.0),
            (0, 5, 2.0),
            (1, 2, 3.0),
            (2, 0, 4.0),
            (2, 1, 5.0),
            (2, 3, 6.0),
            (4, 4, 7.0),
        ] {
            coo.push(r, c, v);
        }
        Csr::from_coo(&coo)
    }

    #[test]
    fn roundtrip() {
        let m = example();
        for (c, sigma) in [(1, 1), (2, 4), (8, 64), (4, 5)] {
            let be = BlockedEll::from_csr(&m, c, sigma);
            assert_eq!(be.to_csr(), m, "C={c} sigma={sigma}");
        }
    }

    #[test]
    fn sigma_sort_is_window_local_and_descending() {
        // One 4-row window over rows 0..4, tail window {4}. Row lengths
        // are [2, 1, 3, 0, 1] → window 0 sorts to rows [2, 0, 1, 3].
        let m = example();
        let be = BlockedEll::from_csr(&m, 2, 4);
        assert_eq!(be.sigma, 4);
        assert_eq!(be.perm, vec![2, 0, 1, 3, 4]);
        assert_eq!(be.row_lens, vec![3, 2, 1, 0, 1]);
        // Blocks pad to the local max: {2,0} → 3 wide, {1,3} → 1, {4} → 1.
        assert_eq!(be.block_width, vec![3, 1, 1]);
        assert_eq!(be.padded_cells(), 3 * 2 + 1 * 2 + 1 * 2);
        // Sorting shrank padding vs the unsorted grouping (widths 2,3,1).
        assert!(be.padded_cells() < 2 * 2 + 3 * 2 + 1 * 2);
    }

    #[test]
    fn padding_uses_the_sentinel() {
        let m = example();
        let be = BlockedEll::from_csr(&m, 2, 4);
        let pads = be.cols.iter().filter(|&&c| c == BlockedEll::PAD_COL).count();
        assert_eq!(pads, be.padded_cells() - m.nnz());
        for (&c, &v) in be.cols.iter().zip(&be.vals) {
            if c == BlockedEll::PAD_COL {
                assert_eq!(v, 0.0);
            } else {
                assert!((c as usize) < be.ncols);
            }
        }
    }

    #[test]
    fn window_ptr_is_the_padded_cell_prefix() {
        let m = example();
        let be = BlockedEll::from_csr(&m, 2, 4);
        // Windows: {blocks 0,1} and {block 2}.
        assert_eq!(be.nwindows(), 2);
        assert_eq!(be.window_ptr, vec![0, 8, 10]);
        assert_eq!(*be.window_ptr.last().unwrap(), be.padded_cells());
    }

    #[test]
    fn sigma_rounds_up_to_block_multiple() {
        let m = example();
        let be = BlockedEll::from_csr(&m, 4, 5);
        assert_eq!(be.sigma, 8);
        let be = BlockedEll::from_csr(&m, 4, 0);
        assert_eq!(be.sigma, 4);
    }

    #[test]
    fn empty_matrix() {
        let m = Csr::new(0, 0);
        let be = BlockedEll::from_csr_default(&m);
        assert_eq!(be.nblocks(), 0);
        assert_eq!(be.nwindows(), 0);
        assert_eq!(be.window_ptr, vec![0]);
        assert_eq!(be.padded_cells(), 0);
        assert_eq!(be.to_csr(), m);
    }
}
