//! SELL SpMVM kernel: column-major within a slice, one lane per row — the
//! fully coalesced schedule SELL was designed for [20].

use crate::matrix::sell::Sell;
use crate::util::error::Result;

/// `y += A·x` over a SELL matrix (padding contributes 0).
pub fn spmv_sell(m: &Sell, x: &[f64], y: &mut [f64]) -> Result<()> {
    super::check_dims(m.nrows, m.ncols, x, y)?;
    let h = m.slice_height;
    for s in 0..m.nslices() {
        let r0 = s * h;
        let width = m.slice_widths[s] as usize;
        let base = m.slice_ptr[s];
        for j in 0..width {
            let col_base = base + j * h;
            for rr in 0..h {
                let r = r0 + rr;
                if r < m.nrows {
                    let idx = col_base + rr;
                    // Padded cells have value 0.0: the FMA is a no-op, as on
                    // the GPU (no branch).
                    y[r] += m.vals[idx] * x[m.cols[idx] as usize];
                }
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::sell::Sell;
    use crate::spmv::csr::spmv_csr;
    use crate::util::propcheck::assert_close;
    use crate::util::rng::Xoshiro256;

    #[test]
    fn matches_csr_various_slice_heights() {
        let mut rng = Xoshiro256::seeded(4);
        let m = crate::matrix::gen::structured::powerlaw_rows(150, 5.0, 1.0, &mut rng);
        let x: Vec<f64> = (0..150).map(|_| rng.next_f64()).collect();
        let mut want = vec![0.0; 150];
        spmv_csr(&m, &x, &mut want).unwrap();
        for h in [1usize, 2, 7, 32, 64] {
            let sell = Sell::from_csr(&m, h);
            let mut y = vec![0.0; 150];
            spmv_sell(&sell, &x, &mut y).unwrap();
            assert_close(&y, &want, 1e-12, 1e-15).unwrap();
        }
    }
}
