#!/usr/bin/env python3
"""Prometheus text-exposition validator for the obs layer's export.

Validates a text-format exposition file (as written by
`dtans::obs::export::prometheus_text`, e.g. `results/metrics.prom` from
the `observability` example) against the rules a scraper relies on:

  * metric and label names use the legal charset;
  * every sample's family is declared with `# HELP` and `# TYPE` lines
    that appear before its first sample, exactly once;
  * sample values parse as numbers;
  * histogram bucket series are cumulative: `le` thresholds strictly
    increase, counts are monotone non-decreasing, the series closes with
    an `le="+Inf"` bucket, and the family's `_count` sample equals it.

Hermetic (stdlib only, no network) so the CI job never flakes.

Usage: python3 scripts/check_prom.py <exposition.prom> [more files...]
       python3 scripts/check_prom.py --selftest
Exit code 0 when every check passes, 1 otherwise (one line per error).
"""

import math
import re
import sys
from pathlib import Path

METRIC_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
LABEL_RE = re.compile(r'([a-zA-Z_][a-zA-Z0-9_]*)="((?:[^"\\]|\\.)*)"')
LABELS_BODY_RE = re.compile(
    r'^[a-zA-Z_][a-zA-Z0-9_]*="(?:[^"\\]|\\.)*"'
    r'(?:,[a-zA-Z_][a-zA-Z0-9_]*="(?:[^"\\]|\\.)*")*,?$'
)
SAMPLE_RE = re.compile(r"^([a-zA-Z_:][a-zA-Z0-9_:]*)(\{(.*)\})?\s+(\S+)(\s+\d+)?$")
TYPES = {"counter", "gauge", "histogram", "summary", "untyped"}


def parse_value(s: str):
    if s == "+Inf":
        return math.inf
    if s == "-Inf":
        return -math.inf
    try:
        return float(s)
    except ValueError:
        return None


def family_of(name: str, types: dict) -> str:
    """Histogram samples (`_bucket`/`_sum`/`_count`) belong to the base
    family; everything else is its own family."""
    for suffix in ("_bucket", "_sum", "_count"):
        base = name.removesuffix(suffix)
        if base != name and types.get(base) == "histogram":
            return base
    return name


def validate(text: str, origin: str = "<input>") -> list:
    errors = []
    helps: dict = {}
    types: dict = {}
    sampled: set = set()
    # bucket series: (family, sorted non-le labels) -> [(le, count, lineno)]
    buckets: dict = {}
    counts: dict = {}  # same key -> _count value

    for lineno, line in enumerate(text.splitlines(), 1):
        where = f"{origin}:{lineno}"
        if not line.strip():
            continue
        if line.startswith("#"):
            parts = line.split(None, 3)
            if len(parts) >= 3 and parts[1] in ("HELP", "TYPE"):
                kind, name = parts[1], parts[2]
                reg = helps if kind == "HELP" else types
                if not METRIC_NAME_RE.match(name):
                    errors.append(f"{where}: bad metric name {name!r} in {kind}")
                    continue
                if name in reg:
                    errors.append(f"{where}: duplicate # {kind} for {name}")
                if name in sampled:
                    errors.append(f"{where}: # {kind} for {name} after its samples")
                if kind == "TYPE":
                    t = parts[3].strip() if len(parts) > 3 else ""
                    if t not in TYPES:
                        errors.append(f"{where}: unknown TYPE {t!r} for {name}")
                    types[name] = t
                else:
                    helps[name] = parts[3] if len(parts) > 3 else ""
            continue

        m = SAMPLE_RE.match(line)
        if not m:
            errors.append(f"{where}: unparsable sample line {line!r}")
            continue
        name, labels_body, value_s = m.group(1), m.group(3), m.group(4)
        value = parse_value(value_s)
        if value is None:
            errors.append(f"{where}: non-numeric value {value_s!r} for {name}")
            continue
        labels = {}
        if labels_body:
            if not LABELS_BODY_RE.match(labels_body):
                errors.append(f"{where}: malformed labels {{{labels_body}}}")
                continue
            for lm in LABEL_RE.finditer(labels_body):
                labels[lm.group(1)] = lm.group(2)
        fam = family_of(name, types)
        sampled.add(fam)
        if fam not in types:
            errors.append(f"{where}: sample for {name} with no # TYPE {fam}")
        if fam not in helps:
            errors.append(f"{where}: sample for {name} with no # HELP {fam}")

        if types.get(fam) == "histogram":
            key = (fam, tuple(sorted((k, v) for k, v in labels.items() if k != "le")))
            if name == fam + "_bucket":
                if "le" not in labels:
                    errors.append(f"{where}: histogram bucket without le label")
                    continue
                le = parse_value(labels["le"])
                if le is None:
                    errors.append(f"{where}: unparsable le={labels['le']!r}")
                    continue
                buckets.setdefault(key, []).append((le, value, lineno))
            elif name == fam + "_count":
                counts[key] = (value, lineno)

    for (fam, lbls), series in buckets.items():
        tag = fam if not lbls else f"{fam}{{{','.join(f'{k}={v}' for k, v in lbls)}}}"
        les = [le for le, _, _ in series]
        if any(b <= a for a, b in zip(les, les[1:])):
            errors.append(f"{origin}: non-increasing le thresholds in {tag}")
        vals = [v for _, v, _ in series]
        if any(b < a for a, b in zip(vals, vals[1:])):
            errors.append(f"{origin}: non-cumulative bucket counts in {tag}")
        if not les or les[-1] != math.inf:
            errors.append(f"{origin}: histogram {tag} does not close with le=\"+Inf\"")
        elif key_count := counts.get((fam, lbls)):
            if key_count[0] != vals[-1]:
                errors.append(
                    f"{origin}: {tag} _count {key_count[0]:g} != +Inf bucket {vals[-1]:g}"
                )
        else:
            errors.append(f"{origin}: histogram {tag} has no _count sample")
    return errors


VALID_FIXTURE = """\
# HELP dtans_requests_total Requests.
# TYPE dtans_requests_total counter
dtans_requests_total 12
# HELP dtans_queue_depth Depth.
# TYPE dtans_queue_depth gauge
dtans_queue_depth 3
# HELP dtans_latency_us Latency.
# TYPE dtans_latency_us histogram
dtans_latency_us_bucket{stage="queue",le="1"} 0
dtans_latency_us_bucket{stage="queue",le="4"} 2
dtans_latency_us_bucket{stage="queue",le="+Inf"} 5
dtans_latency_us_sum{stage="queue"} 37
dtans_latency_us_count{stage="queue"} 5
"""

INVALID_FIXTURES = {
    "non-cumulative buckets": VALID_FIXTURE.replace('le="4"} 2', 'le="4"} 9'),
    "missing +Inf bucket": VALID_FIXTURE.replace(
        'dtans_latency_us_bucket{stage="queue",le="+Inf"} 5\n', ""
    ),
    "_count mismatch": VALID_FIXTURE.replace(
        'dtans_latency_us_count{stage="queue"} 5',
        'dtans_latency_us_count{stage="queue"} 7',
    ),
    "sample before TYPE": "orphan_metric 1\n",
    "bad metric name": "# HELP 1bad x.\n# TYPE 1bad counter\n1bad 3\n",
    "non-numeric value": VALID_FIXTURE.replace(
        "dtans_queue_depth 3", "dtans_queue_depth three"
    ),
}


def selftest() -> int:
    errs = validate(VALID_FIXTURE, "valid-fixture")
    if errs:
        print("selftest: valid fixture unexpectedly rejected:")
        for e in errs:
            print(f"  {e}")
        return 1
    failed = 0
    for label, fixture in INVALID_FIXTURES.items():
        if not validate(fixture, label):
            print(f"selftest: invalid fixture {label!r} was not caught")
            failed += 1
    print(
        f"selftest: 1 valid + {len(INVALID_FIXTURES)} invalid fixtures: "
        f"{'OK' if not failed else f'{failed} missed'}"
    )
    return 1 if failed else 0


def main() -> int:
    args = sys.argv[1:]
    if not args:
        sys.exit("usage: check_prom.py <exposition.prom> [more...] | --selftest")
    if args == ["--selftest"]:
        return selftest()
    errors = []
    for a in args:
        p = Path(a)
        if not p.is_file():
            sys.exit(f"not a file: {a}")
        errors.extend(validate(p.read_text(encoding="utf-8"), str(p)))
    for e in errors:
        print(e)
    print(f"checked {len(args)} exposition file(s): {'OK' if not errors else f'{len(errors)} errors'}")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
