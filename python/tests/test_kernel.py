"""Pallas kernel vs the numpy oracle — the CORE correctness signal.

The fused decode+SpMVM kernel (interpret=True) must reproduce the scalar
warp-synchronous reference bit-for-bit (identical f32 accumulation order).
hypothesis sweeps matrix shapes, densities, value distributions, and
delta-encoding on/off.
"""

import jax
jax.config.update("jax_enable_x64", True)

import numpy as np
import pytest

# hypothesis is not baked into the offline image; skip (not error) without it.
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.dtans_decode import spmv_dtans_bundle


def run_case(seed, nrows, ncols, avg, distinct, delta):
    rng = np.random.default_rng(seed)
    rc, rv = ref.random_matrix(rng, nrows, ncols, avg, distinct)
    b = ref.encode_matrix(rc, rv, ncols, delta_encode=delta)
    x = rng.standard_normal(ncols).astype(np.float32)
    want = ref.decode_spmv_ref(b, x)
    got = np.asarray(spmv_dtans_bundle(b, x))
    np.testing.assert_array_equal(got, want)  # bit-exact: same f32 op order


@given(
    st.integers(0, 2**32 - 1),
    st.integers(1, 80),
    st.integers(1, 100),
    st.floats(0.0, 10.0),
    st.sampled_from([1, 4, 1000]),
    st.booleans(),
)
@settings(max_examples=20, deadline=None)
def test_kernel_matches_oracle(seed, nrows, ncols, avg, distinct, delta):
    run_case(seed, nrows, ncols, avg, distinct, delta)


def test_kernel_single_full_warp():
    run_case(0, 32, 64, 6.0, 8, True)


def test_kernel_many_slices():
    run_case(1, 160, 64, 5.0, 8, True)


def test_kernel_escape_heavy():
    # Gaussian values: everything escapes through the side stream.
    run_case(2, 64, 64, 6.0, 4096, True)


def test_kernel_empty_rows_interleaved():
    rng = np.random.default_rng(5)
    rc, rv = ref.random_matrix(rng, 64, 64, 2.0)
    for i in range(0, 64, 3):  # punch empty rows
        rc[i] = np.zeros(0, dtype=np.int64)
        rv[i] = np.zeros(0, dtype=np.float32)
    b = ref.encode_matrix(rc, rv, 64)
    x = rng.standard_normal(64).astype(np.float32)
    np.testing.assert_array_equal(
        np.asarray(spmv_dtans_bundle(b, x)), ref.decode_spmv_ref(b, x)
    )


def test_kernel_long_rows():
    # Rows much longer than a segment exercise the extract/load mix.
    rng = np.random.default_rng(6)
    rc = [np.sort(rng.choice(512, size=200, replace=False)) for _ in range(32)]
    rv = [rng.standard_normal(200).astype(np.float32) for _ in range(32)]
    b = ref.encode_matrix(rc, rv, 512, max_dict=64)
    x = rng.standard_normal(512).astype(np.float32)
    want = ref.decode_spmv_ref(b, x)
    got = np.asarray(spmv_dtans_bundle(b, x))
    np.testing.assert_array_equal(got, want)
    want_csr = ref.spmv_csr_ref(rc, rv, x)
    np.testing.assert_allclose(got, want_csr, rtol=1e-4, atol=1e-4)


def test_kernel_padded_bucket_shape():
    rng = np.random.default_rng(7)
    rc, rv = ref.random_matrix(rng, 40, 64, 4.0)
    b = ref.encode_matrix(rc, rv, 64).pad_to(nrows=64, stream_words=4096, escapes=512)
    x = rng.standard_normal(64).astype(np.float32)
    got = np.asarray(spmv_dtans_bundle(b, x))
    np.testing.assert_array_equal(got, ref.decode_spmv_ref(b, x))
