//! Autotuner demo (the Fig. 9 setting in miniature): for a few matrices,
//! sweep the classic-format design space with the GPU simulator (the
//! AlphaSparse stand-in), then compare the winner against the fixed
//! CSR-dtANS format — including the search cost that makes per-matrix
//! autotuning impractical.
//!
//! Run: `cargo run --release --example autotune_demo`

use dtans::autotune::{autotune, dtans_time_us, TuneSpace};
use dtans::format::csr_dtans::{CsrDtans, EncodeOptions};
use dtans::matrix::gen::structured::{banded, powerlaw_rows, random_uniform};
use dtans::matrix::gen::{assign_values, ValueDist};
use dtans::matrix::{Csr, Precision};
use dtans::sim::GpuModel;
use dtans::util::rng::Xoshiro256;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let mut rng = Xoshiro256::seeded(5);
    let cases: Vec<(&str, Csr)> = vec![
        ("banded-200k", {
            let mut m = banded(200_000, 4);
            assign_values(&mut m, ValueDist::FewDistinct(16), &mut rng);
            m
        }),
        ("powerlaw-50k", powerlaw_rows(50_000, 8.0, 1.2, &mut rng)),
        ("random-100k", random_uniform(100_000, 100_000, 500_000, &mut rng)),
    ];
    let dev = GpuModel::RTX5090;
    let space = TuneSpace::default();
    let opts = EncodeOptions {
        precision: Precision::F32,
        ..Default::default()
    };

    println!(
        "{:<14} {:>12} {:>10} {:>12} {:>12} {:>14}",
        "matrix", "tuner best", "best µs", "dtANS µs", "dtANS rel", "search cost"
    );
    for (name, csr) in &cases {
        let tuned = autotune(csr, Precision::F32, &space, &dev, true);
        let enc = CsrDtans::encode(csr, &opts)?;
        let dt = dtans_time_us(csr, &enc, Precision::F32, &dev, true);
        println!(
            "{:<14} {:>12} {:>10.1} {:>12.1} {:>11.2}x {:>12.1}s",
            name,
            tuned.best.label(),
            tuned.best_us,
            dt,
            dt / tuned.best_us,
            tuned.search_cost_us / 1e6,
        );
    }
    println!(
        "\nThe tuner explores ~11 candidates per matrix; its search cost (dominated by \
         per-candidate code generation, as with AlphaSparse) exceeds any single SpMVM by \
         ~6 orders of magnitude — the paper's argument for a fixed format."
    );
    Ok(())
}
