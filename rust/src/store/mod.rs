//! Tiered matrix store: on-disk artifact cache + memory-budgeted
//! residency + background loader — the persistence layer under the
//! coordinator.
//!
//! The paper treats the encoded matrix as a persistent artifact ("the
//! encoded data can be stored in memory or saved in a file for repeated
//! decoding"); at service scale the working set of registered matrices
//! can far exceed RAM, so pinning every CSR original, encoding and decode
//! plan in memory forever (what the coordinator did before this module)
//! caps the service at its heap. The store splits lifetime from
//! residency across three layers:
//!
//! * [`artifact`] — a content-addressed on-disk cache keyed by a stable
//!   hash of the matrix bytes + [`EncodeOptions`]; re-registering a known
//!   matrix loads the persisted encoding instead of re-encoding.
//! * [`residency`] — a byte-budgeted LRU manager deciding which matrices
//!   stay in RAM; pinned (in-flight) matrices are never evicted, and
//!   evicted ones fault back in from their artifact on demand.
//! * [`loader`] — a background worker pool for encode-and-persist and
//!   cold-load jobs, deduped so concurrent requests for one cold matrix
//!   trigger a single load.
//!
//! [`MatrixStore`] composes the three. [`MatrixStore::register_csr`]
//! encodes (or artifact-hits), routes, persists in the background and
//! makes the matrix resident; [`MatrixStore::acquire`] returns a
//! [`PinnedMatrix`] guard, transparently faulting cold matrices in. The
//! coordinator's service is rewired on top ([`crate::coordinator::service`]),
//! and budget/eviction activity is observable through
//! [`crate::coordinator::metrics::Metrics`] (`store_hits`, `store_misses`,
//! `evictions`, cold-load quantiles).
//!
//! Results are bit-identical with and without a budget: eviction drops
//! bytes, never changes them — the reloaded encoding is byte-equal to the
//! persisted one, and a CSR original rebuilt via
//! [`CsrDtans::decode_to_csr`] is exact for f64 encodes (property-tested
//! in `rust/tests/store_residency.rs`).
//!
//! # Mutation
//!
//! Registered matrices are mutable through [`MatrixStore::append`], which
//! composes an append-only [`DeltaOverlay`](crate::delta::DeltaOverlay)
//! with the immutable base and stamps a monotonically increasing
//! **version** per batch. A mutated entry serves through an
//! [`OverlayOperator`](crate::delta::OverlayOperator) (CSR-exact
//! arithmetic) and is pinned unevictable while its overlay is RAM-only;
//! once the overlay passes [`StoreConfig::compact_overlay_nnz`], a
//! background **compaction** job on the [`loader`] merges base+overlay
//! into a fresh CSR, re-encodes it, persists the dtANS artifact under a
//! version-aware key ([`key_for_versioned`]) and atomically swaps the
//! operator under a pin-quiesce: in-flight pins keep servicing the old
//! version (their guards own an `Arc` to it), new acquires see the new
//! one, and the old bytes become evictable garbage once the last pin
//! drops. See `docs/MUTATION.md` for the semantics and the crash-safety
//! argument.

pub mod artifact;
pub mod loader;
pub mod residency;

pub use artifact::{key_for, key_for_versioned, ArtifactCache, ArtifactKey};
pub use residency::{ResidencyManager, ResidencyStats};

use crate::coordinator::metrics::Metrics;
use crate::coordinator::router::{FormatChoice, RoutePolicy};
use crate::delta::{DeltaOverlay, OverlayOperator};
use crate::format::csr_dtans::{CsrDtans, EncodeOptions};
use crate::matrix::csr::Csr;
use crate::matrix::Precision;
use crate::spmv::operator::SpmvOperator;
use crate::util::error::{DtansError, Result};
use loader::Loader;
use std::collections::{BTreeMap, HashMap};
use std::path::{Path, PathBuf};
use std::sync::atomic::Ordering;
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// A registered matrix in its resident (in-RAM) form.
pub struct LoadedMatrix {
    /// Human-readable name.
    pub name: String,
    /// Rows.
    pub nrows: usize,
    /// Columns.
    pub ncols: usize,
    /// Nonzeros.
    pub nnz: usize,
    /// The CSR original — `None` for dtANS-routed matrices registered in a
    /// store with [`StoreConfig::drop_csr`] (rebuilt by decoding if the
    /// matrix ever needs the CSR path again).
    pub csr: Option<Arc<Csr>>,
    /// The encoded form (always kept: it backs persistence and eviction).
    pub enc: Arc<CsrDtans>,
    /// The routed kernel surface the service executes against — the CSR
    /// original, a [`crate::spmv::operator::DtansOperator`] owning its
    /// decode plan, or an [`OverlayOperator`] for appended-to matrices.
    pub op: Arc<dyn SpmvOperator>,
    /// Routed format.
    pub choice: FormatChoice,
    /// RAM-only delta overlay of updates appended since the base this
    /// resident form was built from — `None` once compaction absorbs it.
    pub overlay: Option<Arc<DeltaOverlay>>,
    /// Monotonically increasing mutation version (0 = never appended to).
    pub version: u64,
    /// Lazily materialized operators for *alternate* routes (keyed by
    /// format tag), built the first time the adaptive router
    /// ([`crate::coordinator::adaptive`]) steers a request onto a format
    /// other than [`LoadedMatrix::choice`]. Cached per resident form: an
    /// eviction, cold reload, append, or compaction swaps in a fresh
    /// `LoadedMatrix` and so naturally invalidates the cache.
    alt_ops: Mutex<BTreeMap<&'static str, Arc<dyn SpmvOperator>>>,
}

impl LoadedMatrix {
    /// The kernel surface for serving this resident form through
    /// `choice`: the registered operator when `choice` matches the routed
    /// format, otherwise a lazily built (and cached) alternate operator.
    ///
    /// Residency gates admissibility (see
    /// [`RoutePolicy::admissible_for`] and `docs/ROUTING.md`): a
    /// CSR-walk format (`csr`, `blocked_ell`) needs the resident CSR
    /// original, and an overlaid (mutated) matrix serves **only**
    /// through its composite overlay operator. Violations return the
    /// typed [`DtansError::InadmissibleRoute`] — `matrix_id` is only
    /// used to label that error.
    pub fn operator_for_choice(
        &self,
        matrix_id: u64,
        choice: FormatChoice,
    ) -> Result<Arc<dyn SpmvOperator>> {
        if choice == self.choice {
            // For an overlaid matrix this hands back the composite
            // overlay operator — the one surface that sees the appended
            // updates.
            return Ok(Arc::clone(&self.op));
        }
        let tag = choice.tag();
        if self.overlay.is_some() {
            // Any re-route of a mutated matrix would serve stale bits.
            return Err(DtansError::InadmissibleRoute { matrix: matrix_id, tag });
        }
        if matches!(choice, FormatChoice::Csr | FormatChoice::BlockedEll)
            && self.csr.is_none()
        {
            return Err(DtansError::InadmissibleRoute { matrix: matrix_id, tag });
        }
        let mut cache = self.alt_ops.lock().unwrap();
        if let Some(op) = cache.get(tag) {
            return Ok(Arc::clone(op));
        }
        let op = RoutePolicy::operator_for(choice, self.csr.as_ref(), &self.enc)?;
        cache.insert(tag, Arc::clone(&op));
        Ok(op)
    }

    /// Routes this resident form can actually serve, given what is in
    /// RAM right now (delegates to [`RoutePolicy::admissible_for`]).
    pub fn admissible_choices(&self) -> Vec<FormatChoice> {
        RoutePolicy::admissible_for(self.choice, self.csr.is_some(), self.overlay.is_some())
    }
}

/// Can a matrix registered from a *user-provided* CSR original be evicted
/// without changing future results? Eviction rebuilds the kept CSR via
/// [`CsrDtans::decode_to_csr`], which is exact only for f64 encodes — an
/// F32-precision encode would hand back f32-rounded values after a
/// reload, silently changing CSR-routed answers. Such entries stay
/// resident instead. (A CSR that was itself *derived by decoding* — the
/// [`MatrixStore::register_path`] and cold-reload cases — is rebuildable
/// bit-for-bit at any precision, so this gate does not apply there.)
fn eviction_is_lossless(mat: &LoadedMatrix) -> bool {
    mat.csr.is_none() || mat.enc.precision == Precision::F64
}

/// Bytes this matrix pins in RAM while resident: the routed operator's
/// own footprint ([`SpmvOperator::resident_bytes`] — for dtANS that
/// already includes the encoded container and decode plan) plus whatever
/// side data the operator does not own (the retained encoding under a
/// CSR route; the retained CSR original under a dtANS route).
fn resident_cost(mat: &LoadedMatrix) -> u64 {
    let mut cost = mat.op.resident_bytes() as u64;
    match mat.choice {
        FormatChoice::Csr => cost += mat.enc.size_report().total as u64,
        FormatChoice::CsrDtans => {
            if let Some(csr) = &mat.csr {
                cost += SpmvOperator::resident_bytes(csr.as_ref()) as u64;
            }
        }
        // BlockedEll routes keep both the encoding (for artifacts /
        // cold reload) and the CSR original (the operator is derived,
        // not primary) alongside the padded operator itself.
        FormatChoice::BlockedEll => {
            cost += mat.enc.size_report().total as u64;
            if let Some(csr) = &mat.csr {
                cost += SpmvOperator::resident_bytes(csr.as_ref()) as u64;
            }
        }
    }
    cost
}

/// Storage-tier configuration (the serving-side knobs live in
/// [`crate::coordinator::service::ServiceConfig`]).
#[derive(Debug, Clone, Default)]
pub struct StoreConfig {
    /// Artifact cache directory. `None` disables persistence: every
    /// registration encodes, and nothing is evictable (a budget then has
    /// no effect, since eviction would lose data).
    pub cache_dir: Option<PathBuf>,
    /// Resident-byte budget. `None` means keep everything in RAM.
    pub budget_bytes: Option<u64>,
    /// Drop the CSR original for dtANS-routed matrices (they decode on
    /// the fly; the original is rebuilt by decoding if ever needed).
    pub drop_csr: bool,
    /// Background loader threads (0 is treated as 1). The default of 0
    /// lets `Default::default()` mean "minimal": one worker.
    pub loader_threads: usize,
    /// Overlay size (in stored entries) at which an append triggers
    /// background compaction of that matrix. `None` (the default) never
    /// auto-compacts; [`MatrixStore::compact`] still works manually.
    pub compact_overlay_nnz: Option<usize>,
}

/// Aggregate store numbers (see [`MatrixStore::stats`]).
#[derive(Debug, Clone, Copy)]
pub struct StoreStats {
    /// Registered matrices (resident or cold).
    pub registered: usize,
    /// Currently resident matrices.
    pub resident: usize,
    /// Sum of resident byte costs.
    pub resident_bytes: u64,
    /// Configured budget, if any.
    pub budget_bytes: Option<u64>,
}

/// Static metadata for one registered id — survives eviction.
struct EntryMeta {
    name: String,
    choice: FormatChoice,
    nrows: usize,
    ncols: usize,
    nnz: usize,
    keep_csr: bool,
    /// Path of the persisted artifact, once it exists.
    artifact: Option<PathBuf>,
    /// Current mutation version (bumped by every non-empty append).
    version: u64,
    /// Entries in the RAM-only overlay (0 = base is current).
    overlay_nnz: usize,
    /// A compaction job for this entry is in flight.
    compacting: bool,
}

struct StoreInner {
    next_id: u64,
    entries: HashMap<u64, EntryMeta>,
    residency: ResidencyManager<LoadedMatrix>,
}

/// State shared with background jobs and pin guards.
struct StoreShared {
    config: StoreConfig,
    encode: EncodeOptions,
    policy: RoutePolicy,
    metrics: Arc<Metrics>,
    artifacts: Option<ArtifactCache>,
    inner: Mutex<StoreInner>,
}

impl StoreShared {
    fn note_evictions(&self, evicted: &[u64]) {
        if !evicted.is_empty() {
            self.metrics.evictions.fetch_add(evicted.len() as u64, Ordering::Relaxed);
        }
    }
}

/// The tiered matrix store. See the [module docs](self) for the layer
/// breakdown and `docs/STORE.md` for artifact layout and budget semantics.
pub struct MatrixStore {
    shared: Arc<StoreShared>,
    loader: Loader<LoadedMatrix>,
}

impl MatrixStore {
    /// Open a store. Fails only if the artifact cache directory cannot be
    /// created.
    pub fn new(
        config: StoreConfig,
        encode: EncodeOptions,
        policy: RoutePolicy,
        metrics: Arc<Metrics>,
    ) -> Result<MatrixStore> {
        let artifacts = match &config.cache_dir {
            Some(dir) => Some(ArtifactCache::open(dir)?),
            None => None,
        };
        let budget = config.budget_bytes;
        let loader_threads = config.loader_threads;
        Ok(MatrixStore {
            shared: Arc::new(StoreShared {
                config,
                encode,
                policy,
                metrics,
                artifacts,
                inner: Mutex::new(StoreInner {
                    next_id: 1,
                    entries: HashMap::new(),
                    residency: ResidencyManager::new(budget),
                }),
            }),
            loader: Loader::new(loader_threads),
        })
    }

    /// Register a CSR matrix: artifact-cache hit loads the persisted
    /// encoding (skipping the encoder entirely, counted as a
    /// `store_hits`); a miss encodes and persists in the background. The
    /// matrix becomes resident and routed; returns its id.
    pub fn register_csr(&self, name: &str, csr: Csr) -> Result<u64> {
        let sh = &self.shared;
        // The O(nnz) content hash is only worth computing when there is a
        // cache to consult/populate with it.
        let key = sh.artifacts.as_ref().map(|_| key_for(&csr, &sh.encode));
        // A cached artifact must agree with the matrix on shape; a
        // corrupt or colliding file is treated as a miss and re-encoded.
        let cached = sh.artifacts.as_ref().zip(key).and_then(|(cache, key)| {
            match cache.load(&key) {
                Ok(Some(enc))
                    if enc.nrows == csr.nrows
                        && enc.ncols == csr.ncols
                        && enc.nnz == csr.nnz() =>
                {
                    Some(enc)
                }
                _ => None,
            }
        });
        let from_cache = cached.is_some();
        let enc = match cached {
            Some(enc) => {
                sh.metrics.store_hits.fetch_add(1, Ordering::Relaxed);
                enc
            }
            None => {
                sh.metrics.store_misses.fetch_add(1, Ordering::Relaxed);
                CsrDtans::encode(&csr, &sh.encode)?
            }
        };
        let choice = sh.policy.choose(&csr, &enc, &sh.encode);
        let keep_csr = !(sh.config.drop_csr && choice == FormatChoice::CsrDtans);
        let (nrows, ncols, nnz) = (csr.nrows, csr.ncols, csr.nnz());
        let baseline_bytes = csr.size_bytes_f64() as u64;
        let csr = keep_csr.then(|| Arc::new(csr));
        let enc = Arc::new(enc);
        let op = RoutePolicy::operator_for(choice, csr.as_ref(), &enc)?;
        let mat = Arc::new(LoadedMatrix {
            name: name.to_string(),
            nrows,
            ncols,
            nnz,
            csr,
            enc,
            op,
            choice,
            overlay: None,
            version: 0,
            alt_ops: Mutex::new(BTreeMap::new()),
        });
        let artifact = if from_cache {
            sh.artifacts.as_ref().zip(key).map(|(c, k)| c.path_for(&k))
        } else {
            None
        };
        let persisted = artifact.is_some();
        let id = self.admit(name, &mat, artifact, eviction_is_lossless(&mat));
        if choice == FormatChoice::CsrDtans {
            // Paper-headline gauge: encoded footprint vs what a resident
            // f64 CSR would have cost (the bytes this routing decision
            // saves on every future multiply).
            sh.metrics.record_compression(
                id,
                name,
                baseline_bytes,
                mat.enc.size_report().total as u64,
            );
        }
        // `key` is Some exactly when a cache is configured.
        if let (false, Some(key)) = (persisted, key) {
            // Persist off the request path; the entry becomes evictable
            // once the artifact is safely on disk.
            let sh2 = Arc::clone(sh);
            let mat2 = Arc::clone(&mat);
            self.loader.spawn(move || {
                let cache = sh2.artifacts.as_ref().expect("key exists only with a cache");
                match cache.store(&key, &mat2.enc) {
                    Ok(path) => {
                        let mut inner = sh2.inner.lock().unwrap();
                        if let Some(e) = inner.entries.get_mut(&id) {
                            e.artifact = Some(path);
                        }
                        if eviction_is_lossless(&mat2) {
                            inner.residency.mark_evictable(id);
                        }
                        let evicted = inner.residency.enforce();
                        drop(inner);
                        sh2.note_evictions(&evicted);
                    }
                    Err(_) => {
                        // The matrix stays resident and unevictable; make
                        // the budget gap observable instead of silent.
                        sh2.metrics.persist_failures.fetch_add(1, Ordering::Relaxed);
                    }
                }
            });
        }
        Ok(id)
    }

    /// Register a matrix straight from a serialized `.dtans` artifact —
    /// no CSR original, no encoding (not counted as a `store_hits`: no
    /// cache was consulted). The file itself backs eviction, so the entry
    /// is evictable immediately (f64 encodes, or any encode without a
    /// kept CSR original); routing uses the encoded-only rule
    /// ([`RoutePolicy::choose_encoded`]).
    pub fn register_path(&self, name: &str, path: &Path) -> Result<u64> {
        let sh = &self.shared;
        // Canonicalize up front: the stored path backs cold reloads for
        // the entry's whole lifetime, so it must survive cwd changes. The
        // file itself must outlive the registration — the store reads it
        // in place rather than copying it into the cache.
        let path = std::fs::canonicalize(path)?;
        let enc = crate::format::serialize::load(&path)?;
        let choice = sh.policy.choose_encoded(&enc);
        let keep_csr = !(sh.config.drop_csr && choice == FormatChoice::CsrDtans);
        let csr = if keep_csr { Some(Arc::new(enc.decode_to_csr()?)) } else { None };
        let enc = Arc::new(enc);
        let op = RoutePolicy::operator_for(choice, csr.as_ref(), &enc)?;
        let mat = Arc::new(LoadedMatrix {
            name: name.to_string(),
            nrows: enc.nrows,
            ncols: enc.ncols,
            nnz: enc.nnz,
            csr,
            enc,
            op,
            choice,
            overlay: None,
            version: 0,
            alt_ops: Mutex::new(BTreeMap::new()),
        });
        // The CSR (if kept) was derived by decoding this very artifact, so
        // a cold reload rebuilds it bit-identically at any precision:
        // always safe to evict.
        let id = self.admit(name, &mat, Some(path), true);
        if mat.choice == FormatChoice::CsrDtans {
            // No user CSR exists here; baseline against the size model's
            // CSR at the encode's own precision (the router's rule).
            let model = crate::matrix::SizeModel { precision: mat.enc.precision };
            sh.metrics.record_compression(
                id,
                name,
                model.csr_bytes(mat.nrows, mat.nnz) as u64,
                mat.enc.size_report().total as u64,
            );
        }
        Ok(id)
    }

    /// Insert a freshly built resident matrix: allocate an id, record its
    /// metadata, make it resident, enforce the budget. `lossless_evict`
    /// says whether an evict/reload cycle reproduces this matrix exactly
    /// (see [`eviction_is_lossless`]); entries persist-gate on it.
    fn admit(
        &self,
        name: &str,
        mat: &Arc<LoadedMatrix>,
        artifact: Option<PathBuf>,
        lossless_evict: bool,
    ) -> u64 {
        let sh = &self.shared;
        let cost = resident_cost(mat);
        let mut inner = sh.inner.lock().unwrap();
        let id = inner.next_id;
        inner.next_id += 1;
        let persisted = artifact.is_some();
        inner.entries.insert(
            id,
            EntryMeta {
                name: name.to_string(),
                choice: mat.choice,
                nrows: mat.nrows,
                ncols: mat.ncols,
                nnz: mat.nnz,
                keep_csr: mat.csr.is_some(),
                artifact,
                version: 0,
                overlay_nnz: 0,
                compacting: false,
            },
        );
        inner.residency.track(id);
        if persisted && lossless_evict {
            inner.residency.mark_evictable(id);
        }
        let evicted = inner.residency.insert(id, Arc::clone(mat), cost);
        drop(inner);
        sh.note_evictions(&evicted);
        id
    }

    /// Acquire matrix `id` for use, pinning it against eviction until the
    /// returned guard drops. Cold matrices fault in from their artifact
    /// (deduped: concurrent acquirers share one load). Each successful
    /// acquisition counts once in [`Metrics::acquires`] — which is how
    /// tests assert that an N-iteration solve holds exactly one pin
    /// instead of re-acquiring per iteration.
    pub fn acquire(&self, id: u64) -> Result<PinnedMatrix> {
        let sh = &self.shared;
        {
            let mut inner = sh.inner.lock().unwrap();
            if !inner.residency.is_tracked(id) {
                return Err(DtansError::Service(format!("unknown matrix {id}")));
            }
            // Pin before anything else: from here the matrix (resident
            // now or loaded below) cannot be evicted under us.
            inner.residency.pin(id);
            if let Some(mat) = inner.residency.get(id) {
                sh.metrics.acquires.fetch_add(1, Ordering::Relaxed);
                return Ok(PinnedMatrix { shared: Arc::clone(sh), id, mat });
            }
        }
        let sh2 = Arc::clone(sh);
        match self.loader.run_dedup(id, move || cold_load(&sh2, id)) {
            Ok(mat) => {
                sh.metrics.acquires.fetch_add(1, Ordering::Relaxed);
                Ok(PinnedMatrix { shared: Arc::clone(sh), id, mat })
            }
            Err(e) => {
                let mut inner = sh.inner.lock().unwrap();
                inner.residency.unpin(id);
                Err(e)
            }
        }
    }

    /// Current pin count of `id` (0 if unknown or unpinned) — observable
    /// so callers can assert pin discipline (e.g. "one pin per solve,
    /// released on completion").
    pub fn pin_count(&self, id: u64) -> u32 {
        self.shared.inner.lock().unwrap().residency.pins(id)
    }

    /// Routed format of a registered matrix.
    pub fn format_of(&self, id: u64) -> Option<FormatChoice> {
        self.shared.inner.lock().unwrap().entries.get(&id).map(|e| e.choice)
    }

    /// Name of a registered matrix.
    pub fn name_of(&self, id: u64) -> Option<String> {
        self.shared.inner.lock().unwrap().entries.get(&id).map(|e| e.name.clone())
    }

    /// Nonzeros of a registered matrix (metadata — available even while
    /// the matrix is cold, so dispatchers can plan without faulting it in).
    pub fn nnz_of(&self, id: u64) -> Option<usize> {
        self.shared.inner.lock().unwrap().entries.get(&id).map(|e| e.nnz)
    }

    /// Dispatcher helper: `(nnz, currently_resident)` for `id` under a
    /// single lock acquisition, or `None` if unregistered.
    pub fn dispatch_meta(&self, id: u64) -> Option<(usize, bool)> {
        let inner = self.shared.inner.lock().unwrap();
        let nnz = inner.entries.get(&id)?.nnz;
        Some((nnz, inner.residency.is_resident(id)))
    }

    /// Is `id` currently resident (in RAM)?
    pub fn is_resident(&self, id: u64) -> bool {
        self.shared.inner.lock().unwrap().residency.is_resident(id)
    }

    /// Forcibly evict `id` (refused while pinned or until its artifact is
    /// persisted). Returns whether it was evicted. Benches use this to
    /// measure the cold path deterministically.
    pub fn evict(&self, id: u64) -> bool {
        let mut inner = self.shared.inner.lock().unwrap();
        let evicted = inner.residency.evict(id);
        drop(inner);
        if evicted {
            self.shared.metrics.evictions.fetch_add(1, Ordering::Relaxed);
        }
        evicted
    }

    /// Append a batch of COO `(row, col, delta)` updates to matrix `id`:
    /// each means `A[row,col] += delta`, folded in arrival order (see
    /// [`crate::delta`] for the exact accumulation semantics). Stamps and
    /// returns a new monotonically increasing version; an empty batch
    /// returns the current version without bumping it.
    ///
    /// The mutated entry serves through an [`OverlayOperator`] (CSR-exact
    /// arithmetic — the router's dtANS choice is revoked on first append)
    /// and is marked unevictable until compaction persists a merged
    /// artifact. If the overlay grows past
    /// [`StoreConfig::compact_overlay_nnz`], a background compaction is
    /// triggered.
    pub fn append(&self, id: u64, updates: &[(u32, u32, f64)]) -> Result<u64> {
        let sh = &self.shared;
        // Pin first: keeps the entry resident (faulting it in if cold)
        // for the whole rebuild, and guarantees the pin-quiesce swap
        // below never races an eviction.
        let pinned = self.acquire(id)?;
        if updates.is_empty() {
            return Ok(pinned.version);
        }
        loop {
            // Snapshot the current resident form and version.
            let (mat, version) = {
                let mut inner = sh.inner.lock().unwrap();
                let mat = inner.residency.get(id).expect("pinned entries are resident");
                let version = inner.entries.get(&id).expect("tracked").version;
                (mat, version)
            };
            // Build the successor outside the lock.
            let base = match &mat.csr {
                Some(c) => Arc::clone(c),
                None => Arc::new(mat.enc.decode_to_csr()?),
            };
            let overlay = match &mat.overlay {
                Some(o) => Arc::new(o.appended(&base, updates)?),
                None => {
                    Arc::new(DeltaOverlay::empty(mat.nrows, mat.ncols).appended(&base, updates)?)
                }
            };
            let op = Arc::new(OverlayOperator::new(Arc::clone(&base), Arc::clone(&overlay))?);
            let nnz = SpmvOperator::nnz(op.as_ref());
            let new_mat = Arc::new(LoadedMatrix {
                name: mat.name.clone(),
                nrows: mat.nrows,
                ncols: mat.ncols,
                nnz,
                csr: Some(base),
                enc: Arc::clone(&mat.enc),
                op,
                choice: FormatChoice::Csr,
                overlay: Some(Arc::clone(&overlay)),
                version: version + 1,
                alt_ops: Mutex::new(BTreeMap::new()),
            });
            let cost = resident_cost(&new_mat);
            // Commit, unless a concurrent append bumped the version or a
            // compaction swapped the resident form under us — then fold
            // the batch again against the fresh state.
            let mut inner = sh.inner.lock().unwrap();
            let stale = inner.entries.get(&id).map_or(true, |e| e.version != version)
                || inner.residency.get(id).map_or(true, |cur| !Arc::ptr_eq(&cur, &mat));
            if stale {
                continue;
            }
            let e = inner.entries.get_mut(&id).expect("tracked");
            e.version = version + 1;
            e.choice = FormatChoice::Csr;
            e.keep_csr = true;
            e.nnz = nnz;
            e.overlay_nnz = overlay.nnz();
            let evicted = inner.residency.insert(id, new_mat, cost);
            // The overlay exists only in RAM: evicting would lose it.
            inner.residency.mark_unevictable(id);
            let gauge = overlay_total(&inner);
            drop(inner);
            sh.note_evictions(&evicted);
            sh.metrics.deltas_appended.fetch_add(updates.len() as u64, Ordering::Relaxed);
            sh.metrics.overlay_nnz.store(gauge, Ordering::Relaxed);
            if sh.config.compact_overlay_nnz.is_some_and(|t| overlay.nnz() >= t) {
                self.spawn_compaction(id);
            }
            drop(pinned);
            return Ok(version + 1);
        }
    }

    /// Manually trigger background compaction of `id`'s overlay. Returns
    /// whether a job was scheduled (`false` if the overlay is empty, a
    /// compaction is already in flight, or `id` is unknown); [`Self::flush`]
    /// waits for it. Benches and tests use this for deterministic absorbs.
    pub fn compact(&self, id: u64) -> bool {
        self.spawn_compaction(id)
    }

    fn spawn_compaction(&self, id: u64) -> bool {
        let sh = &self.shared;
        {
            let mut inner = sh.inner.lock().unwrap();
            let Some(e) = inner.entries.get_mut(&id) else { return false };
            if e.compacting || e.overlay_nnz == 0 {
                return false;
            }
            e.compacting = true;
        }
        let sh2 = Arc::clone(sh);
        self.loader.spawn(move || compact_job(&sh2, id));
        true
    }

    /// Current mutation version of `id` (0 = never appended to).
    pub fn version_of(&self, id: u64) -> Option<u64> {
        self.shared.inner.lock().unwrap().entries.get(&id).map(|e| e.version)
    }

    /// Entries currently in `id`'s RAM-only overlay (0 = base is current).
    pub fn overlay_nnz_of(&self, id: u64) -> Option<usize> {
        self.shared.inner.lock().unwrap().entries.get(&id).map(|e| e.overlay_nnz)
    }

    /// Block until background persists/loads submitted so far finished.
    pub fn flush(&self) {
        self.loader.wait_idle();
    }

    /// Aggregate store numbers.
    pub fn stats(&self) -> StoreStats {
        let inner = self.shared.inner.lock().unwrap();
        let r = inner.residency.stats();
        StoreStats {
            registered: inner.entries.len(),
            resident: r.resident,
            resident_bytes: r.resident_bytes,
            budget_bytes: r.budget_bytes,
        }
    }

    /// The store's metrics sink (shared with the owning service, if any).
    pub fn metrics(&self) -> &Arc<Metrics> {
        &self.shared.metrics
    }
}

/// Fault one cold matrix in from its on-disk artifact. Runs on the
/// loader pool; the acquirer already holds a pin, so the freshly inserted
/// resident cannot be evicted before the caller sees it.
fn cold_load(sh: &Arc<StoreShared>, id: u64) -> Result<Arc<LoadedMatrix>> {
    let (path, meta) = {
        let mut inner = sh.inner.lock().unwrap();
        // Raced with another load or an insert: already resident.
        if let Some(mat) = inner.residency.get(id) {
            return Ok(mat);
        }
        let e = inner
            .entries
            .get(&id)
            .ok_or_else(|| DtansError::Service(format!("unknown matrix {id}")))?;
        let path = e.artifact.clone().ok_or_else(|| {
            DtansError::Service(format!("matrix {id} is cold and has no on-disk artifact"))
        })?;
        (path, (e.name.clone(), e.choice, e.keep_csr, e.nrows, e.ncols, e.nnz, e.version))
    };
    let (name, choice, keep_csr, nrows, ncols, nnz, version) = meta;
    let t0 = Instant::now();
    let enc = crate::format::serialize::load(&path)?;
    let csr = if keep_csr { Some(Arc::new(enc.decode_to_csr()?)) } else { None };
    let enc = Arc::new(enc);
    let op = RoutePolicy::operator_for(choice, csr.as_ref(), &enc)?;
    // An entry is only ever evictable with an empty overlay (appends mark
    // it unevictable until compaction persists the merged artifact), so a
    // cold reload always rebuilds from the artifact alone.
    let mat = Arc::new(LoadedMatrix {
        name,
        nrows,
        ncols,
        nnz,
        csr,
        enc,
        op,
        choice,
        overlay: None,
        version,
        alt_ops: Mutex::new(BTreeMap::new()),
    });
    sh.metrics.record_cold_load_for(id, t0.elapsed().as_micros() as u64);
    let cost = resident_cost(&mat);
    let mut inner = sh.inner.lock().unwrap();
    let evicted = inner.residency.insert(id, Arc::clone(&mat), cost);
    drop(inner);
    sh.note_evictions(&evicted);
    Ok(mat)
}

/// Total overlay entries across all registered matrices — the value of
/// the `overlay_nnz` gauge, recomputed under the store lock at every
/// transition so it can never drift from the per-entry truth.
fn overlay_total(inner: &StoreInner) -> u64 {
    inner.entries.values().map(|e| e.overlay_nnz as u64).sum()
}

/// Background compaction: merge `id`'s base+overlay into a fresh CSR,
/// re-encode, persist the artifact under a version-aware key, and swap
/// the resident form under a pin-quiesce. Runs on the loader pool.
///
/// Failure (encode or persist) leaves the old version fully servable —
/// the overlay stays RAM-only and the entry unevictable — and bumps
/// `compaction_failures`. A concurrent append (version moved while we
/// built) discards the stale build; the next over-threshold append
/// re-triggers. Either way the `compacting` flag is cleared.
fn compact_job(sh: &Arc<StoreShared>, id: u64) {
    let clear_flag = |sh: &Arc<StoreShared>| {
        let mut inner = sh.inner.lock().unwrap();
        if let Some(e) = inner.entries.get_mut(&id) {
            e.compacting = false;
        }
    };
    let t0 = Instant::now();
    // Snapshot. The entry is unevictable while its overlay is non-empty,
    // so a scheduled compaction always finds it resident.
    let (mat, version) = {
        let mut inner = sh.inner.lock().unwrap();
        let Some(mat) = inner.residency.get(id) else {
            drop(inner);
            clear_flag(sh);
            return;
        };
        let version = inner.entries.get(&id).map_or(0, |e| e.version);
        (mat, version)
    };
    let Some(overlay) = mat.overlay.clone().filter(|o| !o.is_empty()) else {
        clear_flag(sh);
        return;
    };
    // Merge + encode + persist outside the lock: traffic keeps servicing
    // the old version meanwhile.
    let built: Result<(Arc<Csr>, Arc<CsrDtans>, Option<PathBuf>)> = (|| {
        let base = match &mat.csr {
            Some(c) => Arc::clone(c),
            None => Arc::new(mat.enc.decode_to_csr()?),
        };
        let merged = Arc::new(crate::delta::merge(&base, &overlay)?);
        let enc = Arc::new(CsrDtans::encode(&merged, &sh.encode)?);
        let path = match &sh.artifacts {
            Some(cache) => {
                Some(cache.store(&key_for_versioned(&merged, &sh.encode, version), &enc)?)
            }
            None => None,
        };
        Ok((merged, enc, path))
    })();
    let (merged, enc, path) = match built {
        Ok(b) => b,
        Err(_) => {
            sh.metrics.compaction_failures.fetch_add(1, Ordering::Relaxed);
            clear_flag(sh);
            return;
        }
    };
    let nnz_absorbed = overlay.nnz() as u64;
    let op: Arc<dyn SpmvOperator> = Arc::clone(&merged);
    let new_mat = Arc::new(LoadedMatrix {
        name: mat.name.clone(),
        nrows: mat.nrows,
        ncols: mat.ncols,
        nnz: merged.nnz(),
        csr: Some(merged),
        enc,
        op,
        choice: FormatChoice::Csr,
        overlay: None,
        version,
        alt_ops: Mutex::new(BTreeMap::new()),
    });
    let cost = resident_cost(&new_mat);
    // Re-eviction gate: with a persisted artifact the merged entry is
    // evictable again, unless rebuilding its kept CSR would roundtrip
    // through a lossy f32 decode (same rule as registration).
    let evictable = path.is_some() && eviction_is_lossless(&new_mat);
    let mut inner = sh.inner.lock().unwrap();
    if inner.entries.get(&id).map_or(true, |e| e.version != version) {
        // Lost the race with an append: the build is stale — discard it.
        drop(inner);
        clear_flag(sh);
        return;
    }
    let e = inner.entries.get_mut(&id).expect("checked above");
    e.nnz = new_mat.nnz;
    e.overlay_nnz = 0;
    e.compacting = false;
    e.keep_csr = true;
    e.choice = FormatChoice::Csr;
    if let Some(p) = path {
        e.artifact = Some(p);
    }
    // The atomic swap: in-flight pins keep their own `Arc` to the old
    // version and finish on it; every acquire from here sees the new one.
    let evicted = inner.residency.insert(id, Arc::clone(&new_mat), cost);
    if evictable {
        inner.residency.mark_evictable(id);
    }
    let gauge = overlay_total(&inner);
    drop(inner);
    sh.note_evictions(&evicted);
    sh.metrics.overlay_nnz.store(gauge, Ordering::Relaxed);
    sh.metrics.record_compaction(id, t0.elapsed().as_micros() as u64, nnz_absorbed);
}

/// Guard over an acquired matrix: derefs to [`LoadedMatrix`] and releases
/// its eviction pin on drop (re-enforcing the budget, since the unpinned
/// matrix may now be the eviction candidate that lets the store fit).
pub struct PinnedMatrix {
    shared: Arc<StoreShared>,
    id: u64,
    mat: Arc<LoadedMatrix>,
}

impl PinnedMatrix {
    /// The pinned matrix's id.
    pub fn id(&self) -> u64 {
        self.id
    }

    /// The resident matrix (cloneable; the clone is *not* pinned — it
    /// keeps the data alive via `Arc` but no longer counts toward the
    /// store's residency).
    pub fn matrix(&self) -> &Arc<LoadedMatrix> {
        &self.mat
    }
}

impl std::ops::Deref for PinnedMatrix {
    type Target = LoadedMatrix;
    fn deref(&self) -> &LoadedMatrix {
        &self.mat
    }
}

impl Drop for PinnedMatrix {
    fn drop(&mut self) {
        let mut inner = self.shared.inner.lock().unwrap();
        inner.residency.unpin(self.id);
        let evicted = inner.residency.enforce();
        drop(inner);
        self.shared.note_evictions(&evicted);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::gen::structured::banded;
    use crate::matrix::gen::{assign_values, ValueDist};
    use crate::util::rng::Xoshiro256;

    fn sample(n: usize, seed: u64) -> Csr {
        let mut m = banded(n, 3);
        assign_values(&mut m, ValueDist::FewDistinct(6), &mut Xoshiro256::seeded(seed));
        m
    }

    fn temp_dir(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("dtans_test_store_{tag}_{}", std::process::id()))
    }

    fn store_with(config: StoreConfig) -> MatrixStore {
        MatrixStore::new(
            config,
            EncodeOptions::default(),
            RoutePolicy { min_nnz: 1 << 8, max_size_ratio: 0.98, ..Default::default() },
            Arc::new(Metrics::default()),
        )
        .unwrap()
    }

    #[test]
    fn register_acquire_roundtrip_without_cache() {
        let store = store_with(StoreConfig::default());
        let m = sample(300, 1);
        let id = store.register_csr("m", m.clone()).unwrap();
        let pinned = store.acquire(id).unwrap();
        assert_eq!(pinned.nrows, 300);
        assert_eq!(pinned.csr.as_ref().map(|c| c.nnz()), Some(m.nnz()));
        assert!(store.acquire(999).is_err());
        // Pin accounting: one successful acquire counted, one pin live
        // until the guard drops, failed acquires not counted.
        assert_eq!(store.metrics().acquires.load(Ordering::Relaxed), 1);
        assert_eq!(store.pin_count(id), 1);
        drop(pinned);
        assert_eq!(store.pin_count(id), 0);
    }

    #[test]
    fn artifact_hit_skips_encoding() {
        let dir = temp_dir("hit");
        let config =
            StoreConfig { cache_dir: Some(dir.clone()), ..Default::default() };
        let store = store_with(config.clone());
        let m = sample(400, 2);
        let a = store.register_csr("a", m.clone()).unwrap();
        store.flush(); // wait for the background persist
        assert_eq!(store.metrics().store_misses.load(Ordering::Relaxed), 1);
        assert_eq!(store.metrics().store_hits.load(Ordering::Relaxed), 0);
        // Same content re-registered: artifact hit, no new encode.
        let b = store.register_csr("b", m.clone()).unwrap();
        assert_ne!(a, b);
        assert_eq!(store.metrics().store_misses.load(Ordering::Relaxed), 1);
        assert_eq!(store.metrics().store_hits.load(Ordering::Relaxed), 1);
        // A second store over the same directory hits too (cold start).
        let store2 = store_with(config);
        store2.register_csr("c", m).unwrap();
        assert_eq!(store2.metrics().store_hits.load(Ordering::Relaxed), 1);
        assert_eq!(store2.metrics().store_misses.load(Ordering::Relaxed), 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn eviction_and_cold_reload_preserve_results() {
        let dir = temp_dir("evict");
        let store = store_with(StoreConfig {
            cache_dir: Some(dir.clone()),
            budget_bytes: Some(1), // evict everything unpinned
            drop_csr: true,
            ..Default::default()
        });
        let m = sample(2000, 3);
        let id = store.register_csr("m", m.clone()).unwrap();
        let x: Vec<f64> = (0..m.ncols).map(|i| (i as f64 * 0.01).sin()).collect();
        let mut want = vec![0.0; m.nrows];
        crate::spmv::spmv_csr(&m, &x, &mut want).unwrap();
        // First acquire may be warm; drop the pin, flush the persist and
        // let the budget evict it.
        {
            let p = store.acquire(id).unwrap();
            assert_eq!(p.choice, FormatChoice::CsrDtans);
            assert!(p.csr.is_none(), "drop_csr must shed the original");
        }
        store.flush();
        {
            let _ = store.acquire(id); // unpin triggers enforce
        }
        assert!(!store.is_resident(id), "budget of 1 byte must evict");
        assert!(store.metrics().evictions.load(Ordering::Relaxed) >= 1);
        // Cold acquire faults it back in; results match the CSR truth.
        let p = store.acquire(id).unwrap();
        assert!(store.metrics().cold_loads.load(Ordering::Relaxed) >= 1);
        let mut got = vec![0.0; p.nrows];
        crate::spmv::spmv_csr_dtans(&p.enc, &x, &mut got).unwrap();
        crate::util::propcheck::assert_close(&got, &want, 1e-12, 1e-9).unwrap();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn register_path_serves_without_original() {
        let dir = temp_dir("path");
        std::fs::create_dir_all(&dir).unwrap();
        let m = sample(600, 4);
        let enc = CsrDtans::encode(&m, &EncodeOptions::default()).unwrap();
        let file = dir.join("m.dtans");
        crate::format::serialize::save(&enc, &file).unwrap();
        let store = store_with(StoreConfig { drop_csr: true, ..Default::default() });
        let id = store.register_path("from-disk", &file).unwrap();
        let p = store.acquire(id).unwrap();
        assert_eq!((p.nrows, p.ncols, p.nnz), (m.nrows, m.ncols, m.nnz()));
        assert_eq!(store.name_of(id).as_deref(), Some("from-disk"));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn f32_encodes_with_kept_csr_are_never_evicted() {
        // Evicting would rebuild the CSR original via a lossy f32
        // roundtrip; the store must keep such entries resident instead.
        let dir = temp_dir("f32gate");
        let store = MatrixStore::new(
            StoreConfig {
                cache_dir: Some(dir.clone()),
                budget_bytes: Some(1),
                ..Default::default()
            },
            EncodeOptions { precision: Precision::F32, ..Default::default() },
            RoutePolicy { min_nnz: 1 << 8, max_size_ratio: 0.98, ..Default::default() },
            Arc::new(Metrics::default()),
        )
        .unwrap();
        let id = store.register_csr("m", sample(400, 7)).unwrap();
        store.flush();
        {
            let _ = store.acquire(id); // unpin triggers an enforce pass
        }
        assert!(store.is_resident(id), "lossy-to-rebuild entries must stay resident");
        assert!(!store.evict(id), "manual evict must refuse too");

        // The same F32 encoding registered from its artifact IS evictable:
        // its CSR is decode-derived, so a reload rebuilds it exactly.
        let opts = EncodeOptions { precision: Precision::F32, ..Default::default() };
        let enc = CsrDtans::encode(&sample(400, 7), &opts).unwrap();
        let file = dir.join("f32.dtans");
        crate::format::serialize::save(&enc, &file).unwrap();
        let store2 = MatrixStore::new(
            StoreConfig { budget_bytes: Some(1), ..Default::default() },
            opts,
            RoutePolicy { min_nnz: 1 << 8, max_size_ratio: 0.98, ..Default::default() },
            Arc::new(Metrics::default()),
        )
        .unwrap();
        let id2 = store2.register_path("f32-artifact", &file).unwrap();
        {
            let _ = store2.acquire(id2); // unpin triggers an enforce pass
        }
        assert!(!store2.is_resident(id2), "decode-derived CSR is safe to evict");
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Run `id`'s routed operator serially over every row (exact bits).
    fn run_full(store: &MatrixStore, id: u64, x: &[f64]) -> Vec<f64> {
        let p = store.acquire(id).unwrap();
        let block = crate::spmv::engine::Block { start: 0, end: p.nrows, cost: 0 };
        let mut y = vec![0.0; p.nrows];
        p.op.run_range(block, x, &mut y).unwrap();
        y
    }

    #[test]
    fn append_bumps_version_and_serves_exact_overlay_bits() {
        let store = store_with(StoreConfig::default());
        let m = sample(300, 8);
        let id = store.register_csr("m", m.clone()).unwrap();
        assert_eq!(store.version_of(id), Some(0));
        assert_eq!(store.append(id, &[]).unwrap(), 0, "empty batch keeps the version");
        let updates = [(0u32, 5u32, 1.5f64), (7, 7, -2.0), (0, 5, 0.25)];
        assert_eq!(store.append(id, &updates).unwrap(), 1);
        assert_eq!(store.version_of(id), Some(1));
        assert_eq!(store.format_of(id), Some(FormatChoice::Csr), "append revokes dtANS routing");
        assert_eq!(store.overlay_nnz_of(id), Some(2), "two distinct coordinates");
        assert_eq!(store.metrics().deltas_appended.load(Ordering::Relaxed), 3);
        // Bit-identical to the from-scratch rebuild of base+overlay.
        let p = store.acquire(id).unwrap();
        assert_eq!(p.version, 1);
        assert_eq!(p.op.format_tag(), "overlay");
        let rebuilt = crate::delta::merge(&m, p.overlay.as_ref().unwrap()).unwrap();
        drop(p);
        let x: Vec<f64> = (0..m.ncols).map(|i| (i as f64 * 0.03).cos()).collect();
        let mut want = vec![0.0; m.nrows];
        crate::spmv::spmv_csr(&rebuilt, &x, &mut want).unwrap();
        assert_eq!(run_full(&store, id, &x), want);
    }

    #[test]
    fn append_to_dtans_routed_matrix_decodes_base_and_reroutes() {
        let store = store_with(StoreConfig { drop_csr: true, ..Default::default() });
        let id = store.register_csr("m", sample(2000, 10)).unwrap();
        assert_eq!(store.format_of(id), Some(FormatChoice::CsrDtans));
        {
            let p = store.acquire(id).unwrap();
            assert!(p.csr.is_none(), "drop_csr sheds the original");
        }
        assert_eq!(store.append(id, &[(1, 1, 4.0)]).unwrap(), 1);
        assert_eq!(store.format_of(id), Some(FormatChoice::Csr));
        let p = store.acquire(id).unwrap();
        assert!(p.csr.is_some(), "append rebuilds and keeps the CSR base");
        assert_eq!(p.op.format_tag(), "overlay");
    }

    #[test]
    fn compaction_absorbs_overlay_persists_versioned_artifact_and_restores_eviction() {
        let dir = temp_dir("compact");
        let store = store_with(StoreConfig {
            cache_dir: Some(dir.clone()),
            budget_bytes: Some(1),
            ..Default::default()
        });
        let m = sample(400, 9);
        let id = store.register_csr("m", m.clone()).unwrap();
        store.flush();
        let updates = [(3u32, 3u32, 2.5f64), (10, 0, -1.0)];
        assert_eq!(store.append(id, &updates).unwrap(), 1);
        // Unevictable while the overlay is RAM-only.
        {
            let _ = store.acquire(id); // unpin triggers an enforce pass
        }
        assert!(store.is_resident(id), "overlay entries must resist the budget");
        assert!(!store.evict(id), "manual evict must refuse too");
        let x: Vec<f64> = (0..m.ncols).map(|i| (i as f64 * 0.02).sin()).collect();
        let want = run_full(&store, id, &x);
        // Compact: absorbs the overlay, persists a version-1 artifact.
        assert!(store.compact(id));
        store.flush();
        assert_eq!(store.overlay_nnz_of(id), Some(0));
        assert_eq!(store.version_of(id), Some(1), "compaction keeps the version");
        assert_eq!(store.metrics().compactions.load(Ordering::Relaxed), 1);
        assert!(!store.compact(id), "nothing left to compact");
        assert_eq!(run_full(&store, id, &x), want, "compaction must be bit-neutral");
        // The artifact landed under the version-aware key.
        let overlay =
            DeltaOverlay::empty(m.nrows, m.ncols).appended(&m, &updates).unwrap();
        let merged = crate::delta::merge(&m, &overlay).unwrap();
        let cache = ArtifactCache::open(&dir).unwrap();
        assert!(cache.contains(&key_for_versioned(&merged, &EncodeOptions::default(), 1)));
        // Evictable again now that the merged artifact exists…
        {
            let _ = store.acquire(id); // unpin triggers an enforce pass
        }
        assert!(!store.is_resident(id), "compacted+persisted entries are evictable");
        // …and the cold reload serves the same bits at the same version.
        let p = store.acquire(id).unwrap();
        assert_eq!((p.version, p.overlay.is_none()), (1, true));
        drop(p);
        assert_eq!(run_full(&store, id, &x), want);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn threshold_append_triggers_background_compaction() {
        let dir = temp_dir("autocompact");
        let store = store_with(StoreConfig {
            cache_dir: Some(dir.clone()),
            compact_overlay_nnz: Some(4),
            ..Default::default()
        });
        let id = store.register_csr("m", sample(300, 11)).unwrap();
        store.flush();
        store.append(id, &[(0, 0, 1.0), (1, 1, 1.0)]).unwrap(); // below threshold
        store.flush();
        assert_eq!(store.metrics().compactions.load(Ordering::Relaxed), 0);
        store.append(id, &[(2, 2, 1.0), (3, 3, 1.0)]).unwrap(); // reaches it
        store.flush();
        assert_eq!(store.metrics().compactions.load(Ordering::Relaxed), 1);
        assert_eq!(store.overlay_nnz_of(id), Some(0));
        assert_eq!(store.version_of(id), Some(2));
        assert_eq!(store.metrics().deltas_appended.load(Ordering::Relaxed), 4);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn operator_for_choice_gates_on_residency() {
        // dtANS-routed with a kept CSR original: every route materializes,
        // and the alternate operator is cached per resident form.
        let store = store_with(StoreConfig::default());
        let id = store.register_csr("m", sample(2000, 12)).unwrap();
        let p = store.acquire(id).unwrap();
        assert_eq!(p.choice, FormatChoice::CsrDtans);
        let csr_op = p.operator_for_choice(id, FormatChoice::Csr).unwrap();
        assert_eq!(csr_op.format_tag(), "csr");
        let again = p.operator_for_choice(id, FormatChoice::Csr).unwrap();
        assert!(Arc::ptr_eq(&csr_op, &again), "alternate operators must be cached");
        assert_eq!(
            p.operator_for_choice(id, FormatChoice::BlockedEll).unwrap().format_tag(),
            "blocked_ell"
        );
        drop(p);

        // drop_csr sheds the original: CSR-walk routes become typed errors.
        let store2 = store_with(StoreConfig { drop_csr: true, ..Default::default() });
        let id2 = store2.register_csr("n", sample(2000, 13)).unwrap();
        let p2 = store2.acquire(id2).unwrap();
        assert!(p2.csr.is_none());
        assert!(matches!(
            p2.operator_for_choice(id2, FormatChoice::Csr),
            Err(DtansError::InadmissibleRoute { matrix, tag: "csr" }) if matrix == id2
        ));
        assert_eq!(p2.admissible_choices(), vec![FormatChoice::CsrDtans]);
        drop(p2);

        // Overlaid matrices serve only their composite operator.
        let store3 = store_with(StoreConfig::default());
        let id3 = store3.register_csr("o", sample(300, 14)).unwrap();
        store3.append(id3, &[(0, 0, 1.0)]).unwrap();
        let p3 = store3.acquire(id3).unwrap();
        assert_eq!(
            p3.operator_for_choice(id3, p3.choice).unwrap().format_tag(),
            "overlay"
        );
        assert!(matches!(
            p3.operator_for_choice(id3, FormatChoice::CsrDtans),
            Err(DtansError::InadmissibleRoute { tag: "csr_dtans", .. })
        ));
        assert_eq!(p3.admissible_choices(), vec![p3.choice]);
    }

    #[test]
    fn pinned_matrices_resist_the_budget() {
        let dir = temp_dir("pin");
        let store = store_with(StoreConfig {
            cache_dir: Some(dir.clone()),
            budget_bytes: Some(1),
            ..Default::default()
        });
        let id = store.register_csr("m", sample(500, 5)).unwrap();
        store.flush();
        let p = store.acquire(id).unwrap();
        // Another registration lands while `id` is pinned: `id` survives.
        let other = store.register_csr("n", sample(700, 6)).unwrap();
        store.flush();
        assert!(store.is_resident(id));
        assert!(!store.evict(id), "pinned: manual evict must refuse");
        drop(p);
        {
            let _ = store.acquire(other); // unpin enforce pass
        }
        assert!(!store.is_resident(id), "unpinned under a 1-byte budget");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
