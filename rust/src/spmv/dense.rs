//! Dense row-major matrix-vector product — the ground-truth oracle for all
//! sparse kernels (tests only; never used on large matrices).

use crate::util::error::Result;

/// `y += A·x` for dense row-major `a` of shape `nrows × ncols`.
///
/// ```
/// use dtans::spmv::spmv_dense;
/// let a = [1.0, 2.0, 3.0, 4.0]; // [[1, 2], [3, 4]]
/// let mut y = vec![0.0; 2];
/// spmv_dense(&a, 2, 2, &[1.0, 1.0], &mut y).unwrap();
/// assert_eq!(y, vec![3.0, 7.0]);
/// ```
pub fn spmv_dense(a: &[f64], nrows: usize, ncols: usize, x: &[f64], y: &mut [f64]) -> Result<()> {
    super::check_dims(nrows, ncols, x, y)?;
    assert_eq!(a.len(), nrows * ncols);
    spmv_dense_row_range(a, ncols, 0, nrows, x, y)
}

/// Dense kernel over rows `r0..r1`; `y_seg[i]` accumulates row `r0 + i`.
/// The whole-matrix [`spmv_dense`] is the `0..nrows` case and the dense
/// [`SpmvOperator`](crate::spmv::operator::SpmvOperator) fans out disjoint
/// ranges, so both paths share one loop and bit-identical results hold by
/// construction.
pub(crate) fn spmv_dense_row_range(
    a: &[f64],
    ncols: usize,
    r0: usize,
    r1: usize,
    x: &[f64],
    y_seg: &mut [f64],
) -> Result<()> {
    debug_assert_eq!(y_seg.len(), r1 - r0);
    for (i, r) in (r0..r1).enumerate() {
        let row = &a[r * ncols..(r + 1) * ncols];
        let mut acc = 0.0;
        for (av, xv) in row.iter().zip(x) {
            acc += av * xv;
        }
        y_seg[i] += acc;
    }
    Ok(())
}

/// Fused scaled update over rows `r0..r1`:
/// `y_seg[i] = alpha·(A·x)[r0 + i] + beta·y_seg[i]`, sharing
/// [`spmv_dense_row_range`]'s per-row accumulation so the fused path stays
/// bit-identical to the unfused "multiply into a zeroed temporary, then
/// axpby" compose.
pub(crate) fn spmv_dense_row_range_axpby(
    a: &[f64],
    ncols: usize,
    rows: std::ops::Range<usize>,
    x: &[f64],
    alpha: f64,
    beta: f64,
    y_seg: &mut [f64],
) -> Result<()> {
    debug_assert_eq!(y_seg.len(), rows.len());
    for (i, r) in rows.enumerate() {
        let row = &a[r * ncols..(r + 1) * ncols];
        let mut acc = 0.0;
        for (av, xv) in row.iter().zip(x) {
            acc += av * xv;
        }
        y_seg[i] = alpha * acc + beta * y_seg[i];
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn axpby_range_matches_unfused_compose_bitwise() {
        let a = vec![1.0, 2.0, 3.0, 4.0, -5.0, 0.5];
        let x = vec![0.5, -2.0];
        let y0 = vec![1.0, -3.0, 0.25];
        for &(alpha, beta) in &[(1.0, 0.0), (-0.5, 1.0), (2.0, -1.5)] {
            let mut tmp = vec![0.0; 3];
            spmv_dense(&a, 3, 2, &x, &mut tmp).unwrap();
            let want: Vec<f64> =
                y0.iter().zip(&tmp).map(|(y, t)| alpha * t + beta * y).collect();
            let mut got = y0.clone();
            spmv_dense_row_range_axpby(&a, 2, 0..3, &x, alpha, beta, &mut got).unwrap();
            assert_eq!(got, want, "alpha={alpha} beta={beta}");
        }
    }

    #[test]
    fn small_product() {
        let a = vec![1.0, 2.0, 3.0, 4.0];
        let x = vec![1.0, -1.0];
        let mut y = vec![10.0, 0.0];
        spmv_dense(&a, 2, 2, &x, &mut y).unwrap();
        assert_eq!(y, vec![10.0 - 1.0, -1.0]);
    }

    #[test]
    fn dim_mismatch() {
        let a = vec![0.0; 4];
        let x = vec![0.0; 3];
        let mut y = vec![0.0; 2];
        assert!(spmv_dense(&a, 2, 2, &x, &mut y).is_err());
    }
}
