//! End-to-end integration: AOT artifacts (JAX/Pallas -> HLO text) loaded
//! and executed via PJRT from Rust, validated against the native Rust
//! decode path.
//!
//! These tests need the artifact directory produced by the python AOT
//! pipeline (`make artifacts`), which is not checked in — so they are
//! `#[ignore]`d with an explicit reason. `cargo test -q` reports them as
//! ignored (visible, unlike the old silent early-return green), and
//! `cargo test -- --ignored` runs them for real, failing loudly if the
//! artifacts are missing.

use dtans::ans::AnsParams;
use dtans::format::csr_dtans::{CsrDtans, EncodeOptions};
use dtans::matrix::gen::structured::{banded, powerlaw_rows};
use dtans::matrix::gen::{assign_values, ValueDist};
use dtans::matrix::{Csr, Precision};
use dtans::runtime::Runtime;
use dtans::spmv::spmv_csr_dtans;
use dtans::util::rng::Xoshiro256;
use std::path::Path;

/// Reason shown by `cargo test` next to each ignored test.
const NEEDS_ARTIFACTS: &str =
    "requires PJRT artifacts: run `make artifacts` (python AOT pipeline), \
     then `cargo test --test runtime_artifacts -- --ignored`";

fn runtime() -> Runtime {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    assert!(
        dir.join("manifest.txt").exists(),
        "PJRT artifacts missing at {} — {NEEDS_ARTIFACTS}",
        dir.display()
    );
    Runtime::open(&dir).expect("open runtime")
}

fn kernel_opts() -> EncodeOptions {
    EncodeOptions {
        params: AnsParams::KERNEL,
        precision: Precision::F32,
        delta_encode: true,
    }
}

fn check_pjrt_matches_native(rt: &Runtime, m: &Csr, seed: u64) {
    let enc = CsrDtans::encode(m, &kernel_opts()).unwrap();
    let mut rng = Xoshiro256::seeded(seed);
    let x: Vec<f64> = (0..m.ncols).map(|_| (rng.next_f32() - 0.5) as f64).collect();
    let y_in: Vec<f64> = (0..m.nrows).map(|_| (rng.next_f32()) as f64).collect();
    // Native Rust warp-synchronous decode path.
    let mut want = y_in.clone();
    spmv_csr_dtans(&enc, &x, &mut want).unwrap();
    // PJRT path (f32 accumulation).
    let got = rt.spmv_dtans(&enc, &x, &y_in).unwrap();
    for r in 0..m.nrows {
        let w = want[r];
        let g = got[r] as f64;
        assert!(
            (w - g).abs() <= 1e-4 * w.abs().max(1.0),
            "row {r}: native {w} vs pjrt {g}"
        );
    }
}

#[test]
#[ignore = "requires PJRT artifacts (make artifacts)"]
fn pjrt_spmv_dtans_matches_native_small() {
    let rt = runtime();
    let mut m = banded(60, 2);
    assign_values(&mut m, ValueDist::FewDistinct(7), &mut Xoshiro256::seeded(1));
    check_pjrt_matches_native(&rt, &m, 11);
}

#[test]
#[ignore = "requires PJRT artifacts (make artifacts)"]
fn pjrt_spmv_dtans_matches_native_irregular_larger_bucket() {
    let rt = runtime();
    let mut rng = Xoshiro256::seeded(2);
    let mut m = powerlaw_rows(200, 5.0, 1.0, &mut rng);
    assign_values(&mut m, ValueDist::Quantized(32), &mut rng);
    check_pjrt_matches_native(&rt, &m, 12);
}

#[test]
#[ignore = "requires PJRT artifacts (make artifacts)"]
fn pjrt_csr_jnp_baseline_matches() {
    let rt = runtime();
    let mut m = banded(50, 3);
    assign_values(&mut m, ValueDist::SmallInts(4), &mut Xoshiro256::seeded(3));
    let m = m.round_to_f32();
    let x: Vec<f64> = (0..50).map(|i| (i as f64 * 0.1).sin()).collect();
    let y_in = vec![0.0; 50];
    let mut want = vec![0.0; 50];
    dtans::spmv::spmv_csr(&m, &x, &mut want).unwrap();
    let got = rt.spmv_csr_jnp(&m, &x, &y_in).unwrap();
    for r in 0..50 {
        assert!((want[r] - got[r] as f64).abs() < 1e-3, "row {r}");
    }
}

#[test]
#[ignore = "requires PJRT artifacts (make artifacts)"]
fn pjrt_dense_matvec_matches() {
    let rt = runtime();
    let (nr, nc) = (10usize, 8usize);
    let a: Vec<f32> = (0..nr * nc).map(|i| (i as f32 * 0.37).sin()).collect();
    let x: Vec<f32> = (0..nc).map(|i| i as f32 * 0.5).collect();
    let y_in = vec![1.0f32; nr];
    let got = rt.dense_matvec(&a, nr, nc, &x, &y_in).unwrap();
    for r in 0..nr {
        let want: f32 = (0..nc).map(|c| a[r * nc + c] * x[c]).sum::<f32>() + 1.0;
        assert!((want - got[r]).abs() < 1e-4, "row {r}: {want} vs {}", got[r]);
    }
}

#[test]
#[ignore = "requires PJRT artifacts (make artifacts)"]
fn oversized_matrix_is_clean_error() {
    let rt = runtime();
    let m = banded(5000, 1); // exceeds every bucket
    let enc = CsrDtans::encode(&m, &kernel_opts()).unwrap();
    let x = vec![0.0; 5000];
    let y = vec![0.0; 5000];
    assert!(rt.spmv_dtans(&enc, &x, &y).is_err());
}
