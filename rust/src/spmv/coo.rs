//! COO SpMVM kernel (atomic-scatter style on the GPU; sequential scatter
//! here — the simulator charges the atomic traffic).

use crate::matrix::coo::Coo;
use crate::util::error::Result;

/// `y += A·x` over COO triplets (duplicates accumulate, as with atomics).
///
/// ```
/// use dtans::matrix::Coo;
/// use dtans::spmv::spmv_coo;
/// let mut m = Coo::new(2, 2);
/// m.push(0, 1, 4.0);
/// m.push(0, 1, 1.0); // duplicate entry sums into the same output row
/// let mut y = vec![0.0; 2];
/// spmv_coo(&m, &[1.0, 2.0], &mut y).unwrap();
/// assert_eq!(y, vec![10.0, 0.0]);
/// ```
pub fn spmv_coo(m: &Coo, x: &[f64], y: &mut [f64]) -> Result<()> {
    super::check_dims(m.nrows, m.ncols, x, y)?;
    scatter(m, x, y);
    Ok(())
}

/// The scatter loop shared by [`spmv_coo`] and the COO
/// [`SpmvOperator`](crate::spmv::operator::SpmvOperator) impl, so both
/// paths are bit-identical by construction.
pub(crate) fn scatter(m: &Coo, x: &[f64], y: &mut [f64]) {
    for i in 0..m.nnz() {
        y[m.rows[i] as usize] += m.vals[i] * x[m.cols[i] as usize];
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    
    use crate::spmv::csr::spmv_csr;
    use crate::util::propcheck::assert_close;
    use crate::util::rng::Xoshiro256;

    #[test]
    fn matches_csr_on_random() {
        let mut rng = Xoshiro256::seeded(9);
        let m = crate::matrix::gen::structured::random_uniform(80, 60, 400, &mut rng);
        let coo = m.to_coo();
        let x: Vec<f64> = (0..60).map(|_| rng.next_f64() - 0.5).collect();
        let mut y1 = vec![0.0; 80];
        let mut y2 = vec![0.0; 80];
        spmv_csr(&m, &x, &mut y1).unwrap();
        spmv_coo(&coo, &x, &mut y2).unwrap();
        assert_close(&y1, &y2, 1e-12, 1e-15).unwrap();
    }

    #[test]
    fn duplicates_accumulate() {
        let mut m = Coo::new(1, 1);
        m.push(0, 0, 1.5);
        m.push(0, 0, 2.5);
        let mut y = vec![0.0];
        spmv_coo(&m, &[2.0], &mut y).unwrap();
        assert_eq!(y[0], 8.0);
    }
}
