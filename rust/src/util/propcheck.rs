//! Property-based testing helper (proptest is not in the vendored set).
//!
//! `check(name, cases, |rng| ...)` runs a property over `cases` random
//! inputs derived from a deterministic per-case seed; on failure it
//! reports the seed — and a ready-to-paste repro command — so failures
//! are reproducible.
//!
//! # Seed-repro workflow
//!
//! A failure message ends with a line like
//! `PROPCHECK_SEED=0x1a2b3c4d cargo test <test name>`. Setting that
//! environment variable makes [`check`] replay **exactly that seed**
//! (swept across the property's size ramp, so the original failing
//! `(seed, size)` combination is guaranteed to be hit) instead of running
//! the whole case schedule — the fast inner loop for debugging one
//! counterexample. Unset it to return to full property runs. See
//! `docs/TESTING.md`.

use super::rng::Xoshiro256;

/// Context handed to a property: a seeded RNG plus a size hint in
/// `[1, max_size]` that grows with the case index (small cases first).
pub struct Ctx {
    /// Seeded RNG for this case.
    pub rng: Xoshiro256,
    /// Suggested magnitude for generated structures.
    pub size: usize,
    /// Case seed (printed on failure).
    pub seed: u64,
}

impl Ctx {
    /// Random vector length respecting the size hint (possibly 0).
    pub fn len(&mut self) -> usize {
        self.rng.below_usize(self.size + 1)
    }

    /// Random vector length of at least 1.
    pub fn len1(&mut self) -> usize {
        1 + self.rng.below_usize(self.size.max(1))
    }
}

/// Run a property over `cases` deterministic random cases.
///
/// The property returns `Err(msg)` (or panics) to signal failure; the
/// failure message includes the seed and a repro command. When the
/// `PROPCHECK_SEED` environment variable is set (decimal, or hex with a
/// `0x` prefix), only that seed is replayed — see the
/// [module docs](self) for the workflow. The per-case seed mixes in the
/// property name so distinct properties see distinct streams.
pub fn check<F>(name: &str, cases: usize, max_size: usize, prop: F)
where
    F: FnMut(&mut Ctx) -> Result<(), String>,
{
    let seed_override = std::env::var("PROPCHECK_SEED").ok().and_then(|s| parse_seed(&s));
    check_with(name, cases, max_size, seed_override, prop)
}

/// [`check`] with the seed override passed explicitly — the testable core
/// of the `PROPCHECK_SEED` path. `Some(seed)` replays that one seed
/// across the property's distinct ramp sizes; `None` runs the normal
/// case schedule.
pub fn check_with<F>(
    name: &str,
    cases: usize,
    max_size: usize,
    seed_override: Option<u64>,
    mut prop: F,
) where
    F: FnMut(&mut Ctx) -> Result<(), String>,
{
    if let Some(seed) = seed_override {
        // Replay the one reported seed at every distinct size the normal
        // schedule would have paired it with (the ramp is monotone, so
        // dedup keeps one copy of each size — and the original failing
        // (seed, size) pair is among them).
        let mut sizes: Vec<usize> =
            (0..cases).map(|case| 1 + (max_size * (case + 1)) / cases.max(1)).collect();
        sizes.dedup();
        for size in sizes {
            let mut ctx = Ctx { rng: Xoshiro256::seeded(seed), size, seed };
            if let Err(msg) = prop(&mut ctx) {
                panic!(
                    "property `{name}` failed under PROPCHECK_SEED replay \
                     (seed {seed:#x}, size {size}): {msg}"
                );
            }
        }
        return;
    }
    let name_hash = name.bytes().fold(0xcbf2_9ce4_8422_2325u64, |h, b| {
        (h ^ b as u64).wrapping_mul(0x100_0000_01b3)
    });
    for case in 0..cases {
        // Size ramps up over the run so simple cases are exercised first.
        let size = 1 + (max_size * (case + 1)) / cases.max(1);
        let seed = name_hash ^ (case as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15);
        let mut ctx = Ctx {
            rng: Xoshiro256::seeded(seed),
            size,
            seed,
        };
        if let Err(msg) = prop(&mut ctx) {
            panic!(
                "property `{name}` failed (case {case}, seed {seed:#x}, size {size}): {msg}\n\
                 re-run exactly this case: PROPCHECK_SEED={seed:#x} cargo test"
            );
        }
    }
}

/// Parse a `PROPCHECK_SEED` value: decimal, or hex with a `0x`/`0X`
/// prefix (the format failure messages print). Returns `None` on
/// anything unparseable, which [`check`] treats as "no override" rather
/// than silently replaying seed 0.
pub fn parse_seed(s: &str) -> Option<u64> {
    let s = s.trim();
    match s.strip_prefix("0x").or_else(|| s.strip_prefix("0X")) {
        Some(hex) => u64::from_str_radix(hex, 16).ok(),
        None => s.parse().ok(),
    }
}

/// Assert two f64 slices are elementwise close.
pub fn assert_close(a: &[f64], b: &[f64], rtol: f64, atol: f64) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch {} vs {}", a.len(), b.len()));
    }
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        let tol = atol + rtol * x.abs().max(y.abs());
        if (x - y).abs() > tol || x.is_nan() != y.is_nan() {
            return Err(format!("index {i}: {x} vs {y} (tol {tol})"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_trivial_property() {
        check("reverse-twice", 50, 64, |ctx| {
            let n = ctx.len();
            let v: Vec<u64> = (0..n).map(|_| ctx.rng.next_u64()).collect();
            let mut w = v.clone();
            w.reverse();
            w.reverse();
            if v == w {
                Ok(())
            } else {
                Err("reverse twice != id".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property `always-fails` failed")]
    fn check_reports_failures() {
        check("always-fails", 3, 8, |_ctx| Err("nope".into()));
    }

    #[test]
    fn close_tolerances() {
        assert!(assert_close(&[1.0], &[1.0 + 1e-12], 1e-9, 0.0).is_ok());
        assert!(assert_close(&[1.0], &[1.1], 1e-9, 0.0).is_err());
    }

    #[test]
    fn parse_seed_accepts_decimal_and_hex() {
        assert_eq!(parse_seed("42"), Some(42));
        assert_eq!(parse_seed("0x2a"), Some(42));
        assert_eq!(parse_seed("0X2A"), Some(42));
        assert_eq!(parse_seed(" 0xdead_beef".replace('_', "").as_str()), Some(0xdead_beef));
        assert_eq!(parse_seed("0xffffffffffffffff"), Some(u64::MAX));
        assert_eq!(parse_seed(""), None);
        assert_eq!(parse_seed("bogus"), None);
        assert_eq!(parse_seed("0x"), None);
    }

    #[test]
    fn seed_override_replays_exactly_one_seed_across_the_size_ramp() {
        // 10 cases over max_size 5 yields ramp sizes {1..=6} -> 6 distinct
        // sizes, so the override runs the property 6 times, always with
        // the override seed.
        let mut runs = Vec::new();
        check_with("override-replay", 10, 5, Some(0xFEED), |ctx| {
            runs.push((ctx.seed, ctx.size));
            Ok(())
        });
        assert_eq!(runs.len(), 6);
        assert!(runs.iter().all(|&(s, _)| s == 0xFEED));
        let sizes: Vec<usize> = runs.iter().map(|&(_, z)| z).collect();
        assert_eq!(sizes, vec![1, 2, 3, 4, 5, 6]);
        // Without the override, the same schedule runs all 10 cases with
        // 10 distinct seeds.
        let mut seeds = Vec::new();
        check_with("override-replay", 10, 5, None, |ctx| {
            seeds.push(ctx.seed);
            Ok(())
        });
        seeds.sort_unstable();
        seeds.dedup();
        assert_eq!(seeds.len(), 10);
    }

    #[test]
    #[should_panic(expected = "PROPCHECK_SEED replay")]
    fn seed_override_failures_name_the_replay() {
        check_with("replay-fails", 3, 8, Some(0xBAD), |_ctx| Err("nope".into()));
    }

    #[test]
    fn normal_failures_print_the_repro_command() {
        let caught = std::panic::catch_unwind(|| {
            check_with("with-repro", 3, 8, None, |_ctx| Err("nope".into()))
        })
        .expect_err("property must fail");
        let msg = caught
            .downcast_ref::<String>()
            .cloned()
            .expect("panic message");
        assert!(msg.contains("PROPCHECK_SEED=0x"), "{msg}");
        assert!(msg.contains("cargo test"), "{msg}");
    }
}
