//! Benchmark harness (criterion is not in the vendored set; this is a
//! plain `harness = false` bench binary using util::timer's warmup/median
//! machinery). Covers:
//!
//!  * microbenches: dtANS encode/decode throughput, per-kernel SpMVM
//!    (iterating the `FormatRegistry`, so new formats show up
//!    automatically);
//!  * engine benches: serial-vs-parallel scaling of the nnz-balanced
//!    engine (`engine_scaling`), the batched multi-RHS entry point
//!    (`engine_batched`), and the dyn-dispatch overhead of the
//!    `SpmvOperator` trait path vs the direct kernels
//!    (`operator_dispatch`, reporting to `results/BENCH_operator.json`);
//!  * solver bench: CG per-iteration cost CSR vs CSR-dtANS on a ~2.3M-nnz
//!    SPD system, with the encode-amortization break-even
//!    (`solver_iterations`, reporting to `results/BENCH_solver.json`);
//!  * store benches: artifact-cache registration vs re-encode and
//!    warm-vs-cold SpMV under eviction (`store_coldstart`), with a
//!    machine-readable trajectory report at `results/BENCH_store.json`;
//!  * mutation bench: delta-overlay append throughput, overlay-vs-
//!    compacted SpMV latency and the compaction pause
//!    (`delta_compaction`, reporting to `results/BENCH_delta.json`);
//!  * stress bench: verified serving throughput of the full coordinator
//!    stack under budget pressure via the testkit's seeded mixed trace
//!    with its serial-replay oracle (`stress_driver`, scale via
//!    `TESTKIT_SCALE`);
//!  * serving bench: latency-vs-offered-load curves for the admission-
//!    controlled core under open-loop same-matrix traffic, demonstrating
//!    cross-request coalescing at saturation (`serving_saturation`,
//!    reporting to `results/BENCH_serving.json`);
//!  * observability bench: per-request cost of the tracing/metrics
//!    layer — off vs sampled 1-in-64 vs always-on — on the scaling
//!    matrix (`obs_overhead`, reporting to `results/BENCH_obs.json`);
//!  * routing bench: adaptation quality of the bandit router on the
//!    deterministic simulator's regime traces — including a mid-run
//!    regime shift — asserting the post-convergence served p50 lands
//!    within 10% of the best static arm's p50 (`routing_adaptation`,
//!    reporting to `results/BENCH_routing.json`);
//!  * one end-to-end bench per paper table/figure (regenerating them at
//!    bench scale): fig4, fig6+tab1, fig7/tab2, fig8/tab3, fig9, ablate.
//!
//! Filter with `cargo bench -- <substring>`; `cargo bench -- --quick`
//! shrinks the corpus. Methodology notes live in `docs/BENCHMARKS.md`.

use dtans::ans::AnsParams;
use dtans::eval::{ablate, fig4, fig6, fig9, runtime_experiment, tab1, CorpusScale};
use dtans::format::csr_dtans::{CsrDtans, EncodeOptions};
use dtans::matrix::gen::structured::{banded, stencil2d5};
use dtans::matrix::gen::{assign_values, gen_graph_csr, GraphModel, ValueDist};
use dtans::matrix::{BlockedEll, Csr};
use dtans::spmv::csr_dtans::DecodePlan;
use dtans::spmv::engine::{KernelVariant, ParStrategy, SpmvEngine};
use dtans::spmv::operator::{DtansOperator, FormatRegistry, SpmvOperator};
use dtans::spmv::{spmv_csr, spmv_csr_dtans, DenseMat};
use dtans::util::rng::Xoshiro256;
use dtans::util::threadpool::ThreadPool;
use dtans::util::timer::bench;
use std::path::Path;

fn should_run(filter: &Option<String>, name: &str) -> bool {
    filter.as_deref().is_none_or(|f| name.contains(f))
}

fn bench_codec(filter: &Option<String>, quick: bool) {
    let n = if quick { 50_000 } else { 400_000 };
    let mut rng = Xoshiro256::seeded(1);
    let mut m = gen_graph_csr(GraphModel::ErdosRenyi, n / 10, 10.0, &mut rng);
    assign_values(&mut m, ValueDist::Quantized(256), &mut rng);
    let opts = EncodeOptions::default();

    if should_run(filter, "encode_throughput") {
        let st = bench(1, 3, 0.5, || CsrDtans::encode(&m, &opts).unwrap());
        let mbs = m.nnz() as f64 * 12.0 / st.median / 1e6;
        println!("encode_throughput            {} ({:.1} MB/s of CSR)", st.display(), mbs);
    }
    let enc = CsrDtans::encode(&m, &opts).unwrap();
    let x: Vec<f64> = (0..m.ncols).map(|_| rng.next_f64()).collect();
    if should_run(filter, "decode_spmv_throughput") {
        let mut y = vec![0.0; m.nrows];
        let st = bench(2, 5, 1.0, || {
            y.iter_mut().for_each(|v| *v = 0.0);
            spmv_csr_dtans(&enc, &x, &mut y).unwrap()
        });
        let gbs = enc.size_report().total as f64 / st.median / 1e9;
        let gnnz = m.nnz() as f64 / st.median / 1e9;
        println!(
            "decode_spmv_throughput       {} ({:.2} GB/s decoded, {:.3} Gnnz/s)",
            st.display(),
            gbs,
            gnnz
        );
        let pool = dtans::util::threadpool::ThreadPool::new(
            dtans::util::threadpool::ThreadPool::default_parallelism(),
        );
        let stp = bench(2, 5, 1.0, || {
            y.iter_mut().for_each(|v| *v = 0.0);
            dtans::spmv::csr_dtans::spmv_csr_dtans_parallel(&enc, &x, &mut y, &pool).unwrap()
        });
        println!(
            "decode_spmv_parallel         {} ({:.2} GB/s decoded, {:.1}x over 1 thread)",
            stp.display(),
            enc.size_report().total as f64 / stp.median / 1e9,
            st.median / stp.median
        );
    }
}

fn bench_kernels(filter: &Option<String>, quick: bool) {
    if !should_run(filter, "kernels") {
        return;
    }
    let n = if quick { 300 } else { 900 };
    let mut rng = Xoshiro256::seeded(2);
    let mut m = stencil2d5(n, n);
    assign_values(&mut m, ValueDist::FewDistinct(8), &mut rng);
    let x: Vec<f64> = (0..m.ncols).map(|_| rng.next_f64()).collect();
    let mut y = vec![0.0; m.nrows];
    let engine = SpmvEngine::serial();

    // One loop over the registry: every registered format (the dense
    // oracle refuses matrices this large and is skipped), GB/s from each
    // operator's actual resident bytes.
    for (tag, op) in FormatRegistry::builtin().build_all(&m, &EncodeOptions::default()) {
        let op = match op {
            Ok(op) => op,
            Err(_) => {
                println!("kernels/{tag:<18} skipped (builder refused at this size)");
                continue;
            }
        };
        let st = bench(2, 5, 0.5, || {
            y.iter_mut().for_each(|v| *v = 0.0);
            engine.run(op.as_ref(), &x, &mut y).unwrap();
        });
        println!(
            "kernels/{tag:<18} {} ({:.2} GB/s resident)",
            st.display(),
            op.resident_bytes() as f64 / st.median / 1e9
        );
    }
}

fn bench_tans_vs_dtans(filter: &Option<String>) {
    if !should_run(filter, "tans_ratio") {
        return;
    }
    // Compression-ratio comparison: dtANS (word stream, decoupled) gives up
    // a little ratio vs classic tANS for decode parallelism.
    use dtans::ans::histogram::normalize_counts;
    use dtans::ans::tables::CodingTables;
    use dtans::ans::tans::tans_encode;
    use dtans::ans::dtans::encode_row;
    let p = AnsParams::KERNEL;
    let mut rng = Xoshiro256::seeded(3);
    let counts: Vec<u64> = (0..500).map(|i| 1 + 100_000 / (i as u64 + 1)).collect();
    let t = CodingTables::build(&p, &normalize_counts(&counts, p.k(), p.m()).unwrap()).unwrap();
    let total: u64 = counts.iter().sum();
    let n = 1 << 14;
    let syms: Vec<u16> = (0..n)
        .map(|_| {
            let mut pick = rng.below(total);
            for (s, &c) in counts.iter().enumerate() {
                if pick < c {
                    return s as u16;
                }
                pick -= c;
            }
            0
        })
        .collect();
    let tans_bits = tans_encode(&t, p.k() as u64, &syms).unwrap().bits.len();
    let dtans_words = encode_row(&p, &[&t], &syms).unwrap().words.len();
    println!(
        "tans_ratio                   tANS {:.3} bits/sym vs dtANS {:.3} bits/sym",
        tans_bits as f64 / n as f64,
        dtans_words as f64 * p.w_bits as f64 / n as f64
    );
}

/// Serial-vs-parallel scaling of the nnz-balanced engine on a large
/// structured matrix (full mode: ~2.3M nnz >= 2^20, the acceptance bar for
/// a *measured* multi-thread speedup over serial CSR-dtANS SpMVM).
fn bench_engine_scaling(filter: &Option<String>, quick: bool) {
    if !should_run(filter, "engine_scaling") {
        return;
    }
    let n = if quick { 1 << 15 } else { 1 << 18 };
    let mut m = banded(n, 4); // ~9 nnz/row -> full mode ~2.3M nnz
    let mut rng = Xoshiro256::seeded(6);
    assign_values(&mut m, ValueDist::FewDistinct(16), &mut rng);
    let enc = CsrDtans::encode(&m, &EncodeOptions::default()).unwrap();
    let x: Vec<f64> = (0..m.ncols).map(|_| rng.next_f64()).collect();
    let mut y = vec![0.0; m.nrows];
    println!(
        "engine_scaling               matrix: {} nnz (2^{:.1}), {} stream words",
        m.nnz(),
        (m.nnz() as f64).log2(),
        enc.stream.len()
    );
    let dtans_op = DtansOperator::new(enc); // owns its decode plan

    let mut threads = vec![1usize, 2, 4];
    let ncpu = ThreadPool::default_parallelism();
    if !threads.contains(&ncpu) {
        threads.push(ncpu);
    }
    threads.retain(|&t| t <= ncpu.max(4));

    // CSR-dtANS: fused decode+multiply.
    let serial = SpmvEngine::serial();
    let st0 = bench(1, 3, 0.5, || {
        y.iter_mut().for_each(|v| *v = 0.0);
        serial.run(&dtans_op, &x, &mut y).unwrap();
    });
    println!("engine_scaling/dtans t=1     {} (serial baseline)", st0.display());
    for &t in &threads[1..] {
        let eng = SpmvEngine::new(ParStrategy::Fixed(t));
        let st = bench(1, 3, 0.5, || {
            y.iter_mut().for_each(|v| *v = 0.0);
            eng.run(&dtans_op, &x, &mut y).unwrap();
        });
        println!(
            "engine_scaling/dtans t={t:<2}    {} ({:.2}x speedup over serial)",
            st.display(),
            st0.median / st.median
        );
    }

    // Plain CSR for reference (bandwidth-bound ceiling).
    let sc0 = bench(1, 3, 0.5, || {
        y.iter_mut().for_each(|v| *v = 0.0);
        serial.run(&m, &x, &mut y).unwrap();
    });
    println!("engine_scaling/csr   t=1     {} (serial baseline)", sc0.display());
    for &t in &threads[1..] {
        let eng = SpmvEngine::new(ParStrategy::Fixed(t));
        let sc = bench(1, 3, 0.5, || {
            y.iter_mut().for_each(|v| *v = 0.0);
            eng.run(&m, &x, &mut y).unwrap();
        });
        println!(
            "engine_scaling/csr   t={t:<2}    {} ({:.2}x speedup over serial)",
            sc.display(),
            sc0.median / sc.median
        );
    }
}

/// Batched multi-RHS (SpMM-style) sweep: one matrix against k vectors per
/// call, versus k separate serial multiplies — the serving shape.
fn bench_engine_batched(filter: &Option<String>, quick: bool) {
    if !should_run(filter, "engine_batched") {
        return;
    }
    let n = if quick { 1 << 12 } else { 1 << 15 };
    let mut rng = Xoshiro256::seeded(7);
    let mut m = gen_graph_csr(GraphModel::ErdosRenyi, n, 12.0, &mut rng);
    assign_values(&mut m, ValueDist::Quantized(128), &mut rng);
    let enc = CsrDtans::encode(&m, &EncodeOptions::default()).unwrap();
    let plan = DecodePlan::new(&enc);
    let op = DtansOperator::new(enc.clone());
    let engine = SpmvEngine::auto();
    for k in [1usize, 4, 16] {
        let cols: Vec<Vec<f64>> = (0..k)
            .map(|_| (0..m.ncols).map(|_| rng.next_f64() - 0.5).collect())
            .collect();
        let xs = DenseMat::from_cols(m.ncols, &cols).unwrap();
        let st_serial = bench(1, 3, 0.3, || {
            for x in &cols {
                let mut y = vec![0.0; m.nrows];
                dtans::spmv::csr_dtans::spmv_with_plan(&enc, &plan, x, &mut y).unwrap();
            }
        });
        let st_batch = bench(1, 3, 0.3, || {
            engine.run_multi(&op, &xs).unwrap();
        });
        println!(
            "engine_batched/k={k:<3}        {} vs {} serial ({:.2}x, {:.3} Gnnz/s)",
            st_batch.display(),
            st_serial.display(),
            st_serial.median / st_batch.median,
            (m.nnz() * k) as f64 / st_batch.median / 1e9
        );
    }
}

/// Dyn-dispatch overhead of the `SpmvOperator` trait path vs the direct
/// kernel entry points, on the same ~2.3M-nnz scaling matrix as
/// `engine_scaling` (full mode). Both sides run serially so the only
/// difference is the trait surface: one virtual call per multiply plus
/// the cost-prefix/units bookkeeping — expected (and asserted by the
/// acceptance bar) to sit within 5% of the direct kernels. Also reports
/// per-kernel-variant serial rows (unrolled 4/8 CSR, BlockedELL scalar
/// and unrolled) vs the scalar CSR kernel, asserting in full mode that
/// at least one vectorized variant wins. Emits a machine-readable
/// `results/BENCH_operator.json` naming all six built-in formats.
fn bench_operator_dispatch(filter: &Option<String>, quick: bool) {
    if !should_run(filter, "operator_dispatch") {
        return;
    }
    let n = if quick { 1 << 15 } else { 1 << 18 };
    let mut m = banded(n, 4); // ~9 nnz/row -> full mode ~2.3M nnz
    let mut rng = Xoshiro256::seeded(9);
    assign_values(&mut m, ValueDist::FewDistinct(16), &mut rng);
    let enc = CsrDtans::encode(&m, &EncodeOptions::default()).unwrap();
    let plan = DecodePlan::new(&enc);
    let op_dtans = DtansOperator::new(enc.clone());
    let x: Vec<f64> = (0..m.ncols).map(|_| rng.next_f64()).collect();
    let mut y = vec![0.0; m.nrows];
    let engine = SpmvEngine::serial();
    println!(
        "operator_dispatch            matrix: {} nnz (2^{:.1})",
        m.nnz(),
        (m.nnz() as f64).log2()
    );

    let measure = |f: &mut dyn FnMut()| bench(2, 7, 0.5, f).median;
    let csr_direct = measure(&mut || {
        y.iter_mut().for_each(|v| *v = 0.0);
        spmv_csr(&m, &x, &mut y).unwrap();
    });
    let csr_dyn = measure(&mut || {
        y.iter_mut().for_each(|v| *v = 0.0);
        engine.run(&m, &x, &mut y).unwrap();
    });
    let dtans_direct = measure(&mut || {
        y.iter_mut().for_each(|v| *v = 0.0);
        dtans::spmv::csr_dtans::spmv_with_plan(&enc, &plan, &x, &mut y).unwrap();
    });
    let dtans_dyn = measure(&mut || {
        y.iter_mut().for_each(|v| *v = 0.0);
        engine.run(&op_dtans, &x, &mut y).unwrap();
    });
    let pct = |direct: f64, dynp: f64| (dynp / direct - 1.0) * 100.0;
    let csr_overhead = pct(csr_direct, csr_dyn);
    let dtans_overhead = pct(dtans_direct, dtans_dyn);
    println!(
        "operator_dispatch/csr        direct {csr_direct:.6}s vs dyn {csr_dyn:.6}s ({csr_overhead:+.2}% overhead)"
    );
    println!(
        "operator_dispatch/csr_dtans  direct {dtans_direct:.6}s vs dyn {dtans_dyn:.6}s ({dtans_overhead:+.2}% overhead)"
    );

    // Per-variant serial rows on the same matrix: the unrolled CSR kernels
    // and the balanced-block BlockedELL format (scalar + widest unrolled),
    // each vs the scalar CSR direct kernel above.
    let bell = BlockedEll::from_csr_default(&m);
    let mut variant_row = |label: &str, variant: KernelVariant, op: &dyn SpmvOperator| {
        let engine = SpmvEngine::serial().with_kernel_variant(variant);
        let t = measure(&mut || {
            y.iter_mut().for_each(|v| *v = 0.0);
            engine.run(op, &x, &mut y).unwrap();
        });
        println!(
            "operator_dispatch/{label:<14} {t:.6}s ({:.2}x vs csr_scalar, {:.3} Gnnz/s)",
            csr_direct / t,
            m.nnz() as f64 / t / 1e9
        );
        t
    };
    let csr_unrolled4 = variant_row("csr_unrolled4", KernelVariant::Unrolled4, &m);
    let csr_unrolled8 = variant_row("csr_unrolled8", KernelVariant::Unrolled8, &m);
    let bell_scalar = variant_row("blocked_ell", KernelVariant::Scalar, &bell);
    let bell_unrolled8 = variant_row("bell_unrolled8", KernelVariant::Unrolled8, &bell);

    let candidates = [
        ("csr_unrolled4", csr_unrolled4),
        ("csr_unrolled8", csr_unrolled8),
        ("blocked_ell", bell_scalar),
        ("blocked_ell_unrolled8", bell_unrolled8),
    ];
    let (best_variant, best_t) = candidates
        .iter()
        .min_by(|a, b| a.1.total_cmp(&b.1))
        .copied()
        .unwrap();
    let best_speedup = csr_direct / best_t;
    println!(
        "operator_dispatch/best       {best_variant} {best_speedup:.2}x vs scalar CSR"
    );
    // The acceptance bar: at least one unrolled variant or BlockedELL must
    // beat the scalar CSR kernel on the ~2.3M-nnz matrix. Quick mode's
    // matrix is too small for the wide accumulators to amortize, so the
    // hard assert applies to the full-size run only.
    if !quick {
        assert!(
            best_speedup > 1.0,
            "no vectorized variant beat scalar CSR ({best_variant} best at {best_speedup:.3}x)"
        );
    } else if best_speedup <= 1.0 {
        println!("operator_dispatch/warn       quick mode: no variant beat scalar CSR");
    }

    let formats: Vec<String> = FormatRegistry::builtin()
        .build_all(&banded(64, 1), &EncodeOptions::default())
        .iter()
        .map(|(tag, _)| format!("\"{tag}\""))
        .collect();

    let outdir = Path::new("results");
    let _ = std::fs::create_dir_all(outdir);
    let json = format!(
        "{{\n  \"bench\": \"operator_dispatch\",\n  \"quick\": {},\n  \"nnz\": {},\n  \"formats\": [{}],\n  \"csr_direct_s\": {:.6},\n  \"csr_dyn_s\": {:.6},\n  \"csr_overhead_pct\": {:.3},\n  \"csr_dtans_direct_s\": {:.6},\n  \"csr_dtans_dyn_s\": {:.6},\n  \"csr_dtans_overhead_pct\": {:.3},\n  \"csr_unrolled4_s\": {:.6},\n  \"csr_unrolled8_s\": {:.6},\n  \"blocked_ell_s\": {:.6},\n  \"blocked_ell_unrolled8_s\": {:.6},\n  \"best_variant\": \"{}\",\n  \"best_speedup_vs_csr_scalar\": {:.3},\n  \"acceptance_bar_pct\": 5.0\n}}\n",
        quick,
        m.nnz(),
        formats.join(", "),
        csr_direct,
        csr_dyn,
        csr_overhead,
        dtans_direct,
        dtans_dyn,
        dtans_overhead,
        csr_unrolled4,
        csr_unrolled8,
        bell_scalar,
        bell_unrolled8,
        best_variant,
        best_speedup,
    );
    let path = outdir.join("BENCH_operator.json");
    std::fs::write(&path, json).expect("write BENCH_operator.json");
    println!("operator_dispatch/report     wrote {}", path.display());
}

/// Adaptive-routing quality bench: replay the deterministic simulator's
/// regime traces (stationary dtANS-hostile, drifting incumbent, bimodal
/// noise, and a stationary trace with a mid-run regime *shift*) through
/// the real `AdaptiveRouter` and report convergence step, flip count,
/// and the served post-convergence p50 next to the best static arm's
/// p50. Acceptance: every trace converges and its p50 ratio stays
/// within 1.10 — ε-greedy's exploration tax plus hysteresis lag must
/// not cost more than 10% at the median. Emits
/// `results/BENCH_routing.json`.
fn bench_routing_adaptation(filter: &Option<String>, quick: bool) {
    use dtans::testkit::routing_sim::{run_routing_sim, Regime, SimConfig};

    if !should_run(filter, "routing_adaptation") {
        return;
    }
    // The simulator is pure arithmetic (no kernels, no threads), so the
    // traces run at full length even under --quick: shrinking them would
    // move the drift crossover and change which arm is truly best.
    let bar = 1.10;
    let shift = SimConfig::regime(Regime::Stationary);
    let reversal = shift.steps / 2;
    let traces: Vec<(&str, SimConfig)> = vec![
        ("stationary", SimConfig::regime(Regime::Stationary)),
        ("drifting", SimConfig::regime(Regime::Drifting)),
        ("bimodal_noisy", SimConfig::regime(Regime::BimodalNoisy)),
        // The regime-shift trace: the stationary regime reverses halfway.
        ("stationary_shift", shift.with_reversal(reversal)),
    ];

    let mut rows = Vec::new();
    for (name, cfg) in &traces {
        let out = run_routing_sim(cfg);
        let at = out
            .converged_at
            .unwrap_or_else(|| panic!("routing_adaptation/{name}: never converged: {out:?}"));
        let ratio = out.post_convergence_p50_us / out.best_static_p50_us;
        println!(
            "routing_adaptation/{name:<17} converged@{at:<4} flips={} p50 {:.1}us \
             vs best-static {:.1}us (ratio {ratio:.3})",
            out.flips.len(),
            out.post_convergence_p50_us,
            out.best_static_p50_us,
        );
        assert!(
            ratio <= bar,
            "routing_adaptation/{name}: post-convergence p50 ratio {ratio:.3} exceeds {bar}"
        );
        rows.push(format!(
            "    {{\n      \"regime\": \"{}\",\n      \"steps\": {},\n      \"flips\": {},\n      \"converged_at\": {},\n      \"post_convergence_p50_us\": {:.3},\n      \"best_static_p50_us\": {:.3},\n      \"p50_ratio\": {:.4}\n    }}",
            name,
            cfg.steps,
            out.flips.len(),
            at,
            out.post_convergence_p50_us,
            out.best_static_p50_us,
            ratio,
        ));
    }

    let outdir = Path::new("results");
    let _ = std::fs::create_dir_all(outdir);
    let json = format!(
        "{{\n  \"bench\": \"routing_adaptation\",\n  \"quick\": {},\n  \"acceptance_bar_ratio\": {:.2},\n  \"regimes\": [\n{}\n  ]\n}}\n",
        quick,
        bar,
        rows.join(",\n"),
    );
    let path = outdir.join("BENCH_routing.json");
    std::fs::write(&path, json).expect("write BENCH_routing.json");
    println!("routing_adaptation/report    wrote {}", path.display());
}

/// Tiered-store cold-start bench: (1) register-from-artifact vs
/// re-encode, (2) warm SpMV vs evicted-then-faulted SpMV. Emits a
/// machine-readable `results/BENCH_store.json` so future PRs have a perf
/// trajectory to compare against.
fn bench_store_coldstart(filter: &Option<String>, quick: bool) {
    use dtans::coordinator::metrics::Metrics;
    use dtans::coordinator::RoutePolicy;
    use dtans::store::{MatrixStore, StoreConfig};
    use std::sync::atomic::Ordering;
    use std::sync::Arc;

    if !should_run(filter, "store_coldstart") {
        return;
    }
    let n = if quick { 1 << 13 } else { 1 << 16 };
    let nmats = 8usize;
    let dir = std::env::temp_dir().join(format!("dtans_bench_store_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let mats: Vec<Csr> = (0..nmats)
        .map(|i| {
            let mut m = banded(n + (i << 8), 3);
            let mut rng = Xoshiro256::seeded(40 + i as u64);
            assign_values(&mut m, ValueDist::FewDistinct(12), &mut rng);
            m
        })
        .collect();
    let policy = RoutePolicy { min_nnz: 1 << 10, max_size_ratio: 0.98, ..Default::default() };
    let mk_store = |budget: Option<u64>| {
        MatrixStore::new(
            StoreConfig {
                cache_dir: Some(dir.clone()),
                budget_bytes: budget,
                drop_csr: true,
                loader_threads: 2,
                ..Default::default()
            },
            EncodeOptions::default(),
            policy,
            Arc::new(Metrics::default()),
        )
        .unwrap()
    };

    // --- Registration: encode-and-persist vs artifact hit. ---
    let store = mk_store(None);
    let st_encode = bench(0, 1, 0.0, || {
        for (i, m) in mats.iter().enumerate() {
            store.register_csr(&format!("m{i}"), m.clone()).unwrap();
        }
    });
    store.flush(); // artifacts all persisted
    assert_eq!(store.metrics().store_misses.load(Ordering::Relaxed), nmats as u64);
    drop(store);
    let store = mk_store(None);
    let st_hit = bench(0, 1, 0.0, || {
        for (i, m) in mats.iter().enumerate() {
            store.register_csr(&format!("m{i}"), m.clone()).unwrap();
        }
    });
    assert_eq!(store.metrics().store_hits.load(Ordering::Relaxed), nmats as u64);
    println!(
        "store_coldstart/register     encode {} vs artifact-hit {} ({:.2}x faster)",
        st_encode.display(),
        st_hit.display(),
        st_encode.median / st_hit.median
    );
    drop(store);

    // --- Serving: warm SpMV vs evicted-then-faulted SpMV. ---
    let store = mk_store(None);
    let engine = SpmvEngine::serial();
    let ids: Vec<u64> = mats
        .iter()
        .enumerate()
        .map(|(i, m)| store.register_csr(&format!("m{i}"), m.clone()).unwrap())
        .collect();
    store.flush();
    let x: Vec<f64> = (0..mats[0].ncols).map(|j| (j as f64 * 0.01).sin()).collect();
    let mut y = vec![0.0; mats[0].nrows];
    fn acquire_and_spmv(
        store: &MatrixStore,
        engine: &SpmvEngine,
        id: u64,
        x: &[f64],
        y: &mut [f64],
    ) {
        let p = store.acquire(id).unwrap();
        y.iter_mut().for_each(|v| *v = 0.0);
        engine.run(p.op.as_ref(), x, y).unwrap();
    }
    let st_warm = bench(1, 5, 0.2, || {
        acquire_and_spmv(&store, &engine, ids[0], &x, &mut y)
    });
    let st_cold = bench(1, 5, 0.2, || {
        assert!(store.evict(ids[0]), "evict must succeed between runs");
        acquire_and_spmv(&store, &engine, ids[0], &x, &mut y)
    });
    let m = store.metrics();
    println!(
        "store_coldstart/spmv         warm {} vs evicted+faulted {} (fault adds {:.1}%; cold_loads={})",
        st_warm.display(),
        st_cold.display(),
        (st_cold.median / st_warm.median - 1.0) * 100.0,
        m.cold_loads.load(Ordering::Relaxed)
    );

    // --- Machine-readable trajectory report. ---
    let outdir = Path::new("results");
    let _ = std::fs::create_dir_all(outdir);
    let json = format!(
        "{{\n  \"bench\": \"store_coldstart\",\n  \"quick\": {},\n  \"matrices\": {},\n  \"nnz_each_approx\": {},\n  \"register_encode_s\": {:.6},\n  \"register_artifact_hit_s\": {:.6},\n  \"register_speedup\": {:.3},\n  \"spmv_warm_s\": {:.6},\n  \"spmv_evicted_faulted_s\": {:.6},\n  \"cold_fault_overhead_pct\": {:.2},\n  \"evictions\": {},\n  \"cold_loads\": {},\n  \"cold_load_p50_us\": {},\n  \"cold_load_p99_us\": {}\n}}\n",
        quick,
        nmats,
        mats[0].nnz(),
        st_encode.median,
        st_hit.median,
        st_encode.median / st_hit.median,
        st_warm.median,
        st_cold.median,
        (st_cold.median / st_warm.median - 1.0) * 100.0,
        m.evictions.load(Ordering::Relaxed),
        m.cold_loads.load(Ordering::Relaxed),
        m.cold_load_summary().p50_us,
        m.cold_load_summary().p99_us,
    );
    let path = outdir.join("BENCH_store.json");
    std::fs::write(&path, json).expect("write BENCH_store.json");
    println!("store_coldstart/report       wrote {}", path.display());
    let _ = std::fs::remove_dir_all(&dir);
}

/// Mutable-matrix workload (`docs/MUTATION.md`): append throughput into a
/// growing delta overlay, SpMV latency through the overlay operator vs
/// the compacted base, and the compaction pause itself (merge + re-encode
/// + versioned persist + swap). Emits `results/BENCH_delta.json`.
fn bench_delta_compaction(filter: &Option<String>, quick: bool) {
    use dtans::coordinator::metrics::Metrics;
    use dtans::coordinator::RoutePolicy;
    use dtans::spmv::operator::SpmvOperator;
    use dtans::store::{MatrixStore, StoreConfig};
    use std::sync::atomic::Ordering;
    use std::sync::Arc;
    use std::time::Instant;

    if !should_run(filter, "delta_compaction") {
        return;
    }
    let n = if quick { 20_000 } else { 120_000 };
    let (bursts, burst_len) = if quick { (20usize, 64usize) } else { (50, 128) };
    let mut m = banded(n, 3);
    let mut rng = Xoshiro256::seeded(77);
    assign_values(&mut m, ValueDist::FewDistinct(12), &mut rng);
    let dir = std::env::temp_dir().join(format!("dtans_bench_delta_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let store = MatrixStore::new(
        StoreConfig { cache_dir: Some(dir.clone()), ..Default::default() },
        EncodeOptions::default(),
        RoutePolicy { min_nnz: 1 << 10, max_size_ratio: 0.98, ..Default::default() },
        Arc::new(Metrics::default()),
    )
    .unwrap();
    let id = store.register_csr("m", m.clone()).unwrap();
    store.flush();

    // --- Append throughput: seeded update bursts into a growing overlay.
    // Per-append cost grows with the overlay (each commit rebuilds the
    // sorted runs), so one timed pass over the whole sequence reports the
    // amortized rate at this overlay size.
    let mk_burst = |b: usize| -> Vec<(u32, u32, f64)> {
        let mut rng = Xoshiro256::seeded(0xA55E7 + b as u64);
        (0..burst_len)
            .map(|_| {
                (
                    rng.below(n as u64) as u32,
                    rng.below(n as u64) as u32,
                    rng.next_f64() - 0.5,
                )
            })
            .collect()
    };
    let total_updates = bursts * burst_len;
    let st_append = bench(0, 1, 0.0, || {
        for b in 0..bursts {
            store.append(id, &mk_burst(b)).unwrap();
        }
    });
    let overlay_nnz = store.overlay_nnz_of(id).unwrap();
    println!(
        "delta_compaction/append      {} for {} updates ({:.0} updates/s, overlay {} entries)",
        st_append.display(),
        total_updates,
        total_updates as f64 / st_append.median,
        overlay_nnz
    );

    // --- SpMV latency: overlay operator vs compacted base. ---
    let engine = SpmvEngine::serial();
    let x: Vec<f64> = (0..n).map(|j| (j as f64 * 0.001).sin()).collect();
    let mut y = vec![0.0; n];
    let st_overlay = {
        let p = store.acquire(id).unwrap();
        assert_eq!(p.op.format_tag(), "overlay");
        bench(2, 5, 0.5, || {
            y.iter_mut().for_each(|v| *v = 0.0);
            engine.run(p.op.as_ref(), &x, &mut y).unwrap();
        })
    };

    // --- Compaction pause: merge + re-encode + versioned persist + swap
    // (the whole background job, run to completion via the loader). ---
    let t0 = Instant::now();
    assert!(store.compact(id));
    store.flush();
    let compaction_s = t0.elapsed().as_secs_f64();
    assert_eq!(store.overlay_nnz_of(id), Some(0));

    let st_compacted = {
        let p = store.acquire(id).unwrap();
        bench(2, 5, 0.5, || {
            y.iter_mut().for_each(|v| *v = 0.0);
            engine.run(p.op.as_ref(), &x, &mut y).unwrap();
        })
    };
    println!(
        "delta_compaction/spmv        overlay {} vs compacted {} ({:.2}x overlay cost)",
        st_overlay.display(),
        st_compacted.display(),
        st_overlay.median / st_compacted.median
    );
    let metrics = store.metrics();
    println!(
        "delta_compaction/compact     {:.3}s pause, {} entries absorbed",
        compaction_s, overlay_nnz
    );

    // --- Machine-readable trajectory report. ---
    let outdir = Path::new("results");
    let _ = std::fs::create_dir_all(outdir);
    let json = format!(
        "{{\n  \"bench\": \"delta_compaction\",\n  \"quick\": {},\n  \"nrows\": {},\n  \"base_nnz\": {},\n  \"updates_appended\": {},\n  \"append_total_s\": {:.6},\n  \"append_updates_per_s\": {:.0},\n  \"overlay_nnz\": {},\n  \"spmv_overlay_s\": {:.6},\n  \"spmv_compacted_s\": {:.6},\n  \"overlay_over_compacted\": {:.3},\n  \"compaction_pause_s\": {:.6},\n  \"compactions\": {},\n  \"deltas_appended\": {}\n}}\n",
        quick,
        n,
        m.nnz(),
        total_updates,
        st_append.median,
        total_updates as f64 / st_append.median,
        overlay_nnz,
        st_overlay.median,
        st_compacted.median,
        st_overlay.median / st_compacted.median,
        compaction_s,
        metrics.compactions.load(Ordering::Relaxed),
        metrics.deltas_appended.load(Ordering::Relaxed),
    );
    let path = outdir.join("BENCH_delta.json");
    std::fs::write(&path, json).expect("write BENCH_delta.json");
    println!("delta_compaction/report      wrote {}", path.display());
    let _ = std::fs::remove_dir_all(&dir);
}

/// Iterative-solver workload: CG on a large SPD Poisson system (~2.3M nnz
/// in full mode, the scaling-bench size), CSR vs CSR-dtANS per-iteration
/// cost. This is the repeated-application regime where dtANS's one-time
/// encode + plan build amortizes across every iteration of the solve;
/// the JSON report states how many iterations that amortization needs.
/// Emits machine-readable `results/BENCH_solver.json`.
fn bench_solver_iterations(filter: &Option<String>, quick: bool) {
    use dtans::solver::{cg_with, SolverConfig};
    use std::time::Instant;

    if !should_run(filter, "solver_iterations") {
        return;
    }
    let side = if quick { 240 } else { 680 }; // 680^2 grid -> ~2.31M nnz
    let a = stencil2d5(side, side);
    let b: Vec<f64> = (0..a.nrows).map(|i| ((i as f64) * 0.013).sin() + 1.0).collect();
    println!(
        "solver_iterations            matrix: {}x{} Poisson, {} unknowns, {} nnz (2^{:.1})",
        side,
        side,
        a.nrows,
        a.nnz(),
        (a.nnz() as f64).log2()
    );

    // One-time dtANS cost: encode + decode-plan build (the DtansOperator
    // constructor builds the plan), paid once per solve lifetime.
    let t0 = Instant::now();
    let enc = CsrDtans::encode(&a, &EncodeOptions::default()).unwrap();
    let enc_bytes = enc.size_report().total;
    let dtans_op = DtansOperator::new(enc);
    let encode_secs = t0.elapsed().as_secs_f64();
    println!(
        "solver_iterations/encode     {:.3}s one-time (CSR {} KB -> dtANS {} KB, {:.2}x)",
        encode_secs,
        a.size_bytes_f64() / 1024,
        enc_bytes / 1024,
        a.size_bytes_f64() as f64 / enc_bytes as f64
    );

    // Fixed-iteration CG (tol 0.0 never converges): equal work per
    // format, so per-iteration cost is directly comparable.
    let iters = if quick { 15 } else { 25 };
    let cfg = SolverConfig { max_iters: iters, tol: 0.0, ..Default::default() };
    let engine = SpmvEngine::auto();
    let csr_sol = cg_with(&engine, &a, &b, None, &cfg).unwrap();
    let dt_sol = cg_with(&engine, &dtans_op, &b, None, &cfg).unwrap();
    let per_iter = |r: &dtans::solver::SolveReport| r.total_secs / r.iterations.max(1) as f64;
    let (csr_it, dt_it) = (per_iter(&csr_sol.report), per_iter(&dt_sol.report));
    println!(
        "solver_iterations/csr        {:.3} ms/iter ({:.1}% in SpMVM)",
        csr_it * 1e3,
        100.0 * csr_sol.report.spmv_secs / csr_sol.report.total_secs.max(1e-12)
    );
    println!(
        "solver_iterations/csr_dtans  {:.3} ms/iter ({:.1}% in SpMVM, {:.2}x vs CSR/iter)",
        dt_it * 1e3,
        100.0 * dt_sol.report.spmv_secs / dt_sol.report.total_secs.max(1e-12),
        csr_it / dt_it
    );
    // Iterations needed before the one-time encode pays for itself
    // (only meaningful when dtANS is faster per iteration).
    let amortize = if csr_it > dt_it {
        let n = (encode_secs / (csr_it - dt_it)).ceil();
        println!("solver_iterations/amortize   encode pays for itself after {n:.0} iterations");
        Some(n)
    } else {
        println!("solver_iterations/amortize   n/a (dtANS not faster per iteration here)");
        None
    };

    let outdir = Path::new("results");
    let _ = std::fs::create_dir_all(outdir);
    let json = format!(
        "{{\n  \"bench\": \"solver_iterations\",\n  \"quick\": {},\n  \"grid_side\": {},\n  \"unknowns\": {},\n  \"nnz\": {},\n  \"cg_iterations\": {},\n  \"encode_plus_plan_s\": {:.6},\n  \"csr_per_iter_s\": {:.6},\n  \"csr_dtans_per_iter_s\": {:.6},\n  \"csr_spmv_fraction\": {:.4},\n  \"csr_dtans_spmv_fraction\": {:.4},\n  \"per_iter_speedup_csr_over_dtans\": {:.4},\n  \"amortize_iterations\": {}\n}}\n",
        quick,
        side,
        a.nrows,
        a.nnz(),
        iters,
        encode_secs,
        csr_it,
        dt_it,
        csr_sol.report.spmv_secs / csr_sol.report.total_secs.max(1e-12),
        dt_sol.report.spmv_secs / dt_sol.report.total_secs.max(1e-12),
        csr_it / dt_it,
        amortize.map_or("null".to_string(), |n| format!("{n:.0}")),
    );
    let path = outdir.join("BENCH_solver.json");
    std::fs::write(&path, json).expect("write BENCH_solver.json");
    println!("solver_iterations/report     wrote {}", path.display());
}

fn bench_experiments(filter: &Option<String>, quick: bool) {
    let scale = if quick {
        CorpusScale { max_nnz: 1 << 16, steps: 4 }
    } else {
        CorpusScale { max_nnz: 1 << 21, steps: 6 }
    };
    let outdir = Path::new("results");
    let run = |name: &str, f: &mut dyn FnMut() -> dtans::eval::ExperimentOutput| {
        if !should_run(filter, name) {
            return;
        }
        let t0 = std::time::Instant::now();
        let out = f();
        let summary = dtans::eval::report::save(&out, outdir).expect("save");
        println!("exp/{name:<10} {:>8.2}s  {}", t0.elapsed().as_secs_f64(), summary.trim().replace('\n', "\n                        "));
    };
    run("fig4", &mut || fig4(if quick { 1 << 13 } else { 1 << 16 }));
    run("fig6", &mut || fig6(&scale));
    run("tab1", &mut || tab1(&scale));
    run("fig7", &mut || runtime_experiment(&scale, true));
    run("fig8", &mut || runtime_experiment(&scale, false));
    run("fig9", &mut || fig9(&scale));
    run("ablate", &mut || ablate(&scale));
}

fn bench_large_banded(filter: &Option<String>, quick: bool) {
    if !should_run(filter, "large_banded") || quick {
        return;
    }
    // The headline-style case: large, structured, compressible.
    let mut m = banded(1 << 20, 4);
    let mut rng = Xoshiro256::seeded(4);
    assign_values(&mut m, ValueDist::FewDistinct(16), &mut rng);
    let enc = CsrDtans::encode(&m, &EncodeOptions::default()).unwrap();
    let x: Vec<f64> = (0..m.ncols).map(|_| rng.next_f64()).collect();
    let mut y = vec![0.0; m.nrows];
    let st = bench(1, 3, 1.0, || {
        y.iter_mut().for_each(|v| *v = 0.0);
        spmv_csr_dtans(&enc, &x, &mut y).unwrap()
    });
    let report = enc.size_report();
    println!(
        "large_banded (9.4M nnz)      {} ({:.2} GB/s decoded; {:.2}x smaller than CSR)",
        st.display(),
        report.total as f64 / st.median / 1e9,
        m.size_bytes_f64() as f64 / report.total as f64,
    );
    let _ = Csr::new(0, 0);
}

/// End-to-end serving throughput under budget pressure: one full run of
/// the testkit's seeded stress trace (spmv / SpMM bursts / CG solves /
/// registrations / evictions) *including* its serial-replay and
/// conservation oracles — so the number is "verified ops per second",
/// not just raw dispatch rate. Scale via `TESTKIT_SCALE` (quick pins
/// small).
fn bench_stress_driver(filter: &Option<String>, quick: bool) {
    use dtans::testkit::{run_stress, StressConfig, TestkitScale};

    if !should_run(filter, "stress_driver") {
        return;
    }
    let scale = if quick { TestkitScale::Small } else { TestkitScale::from_env() };
    let cfg = StressConfig::for_scale(scale);
    let st = bench(0, 1, 0.0, || run_stress(&cfg).expect("stress oracles"));
    println!(
        "stress_driver/{:<14} {} ({:.0} verified ops/s incl. replay, {} threads)",
        scale.label(),
        st.display(),
        cfg.ops as f64 / st.median,
        cfg.threads
    );
}

/// Latency-vs-offered-load curves for the admission-controlled serving
/// core under open-loop same-matrix traffic — the coalescing payoff
/// case. A pacer submits requests at a fixed offered rate regardless of
/// completions; at each load level we record completion/shed counts,
/// p50/p99 latency, and the engine batch count. The headline number at
/// saturation is `batches < requests`: concurrent same-matrix requests
/// reaching the engine as coalesced SpMM batches (one decode amortized
/// across the batch). Emits `results/BENCH_serving.json`.
fn bench_serving_saturation(filter: &Option<String>, quick: bool) {
    use dtans::coordinator::admission::AdmissionConfig;
    use dtans::coordinator::{RoutePolicy, ServiceConfig, SpmvService};
    use std::sync::atomic::Ordering;
    use std::time::{Duration, Instant};

    if !should_run(filter, "serving_saturation") {
        return;
    }
    let n = if quick { 2000 } else { 6000 };
    let reqs_per_level = if quick { 120 } else { 400 };
    let mut m = banded(n, 2);
    assign_values(&mut m, ValueDist::FewDistinct(8), &mut Xoshiro256::seeded(77));
    let x: Vec<f64> = (0..m.ncols).map(|j| (j as f64 * 0.01).sin()).collect();

    let mk_service = || {
        SpmvService::start(ServiceConfig {
            workers: 2,
            max_batch: 32,
            // Fixed(2): the SpMM fast path triggers deterministically for
            // any coalesced batch, independent of host core count.
            par: ParStrategy::Fixed(2),
            policy: RoutePolicy { min_nnz: 1 << 10, max_size_ratio: 0.95, ..Default::default() },
            admission: AdmissionConfig {
                queue_depth: 256,
                // Linger briefly so an open-loop burst lands in one
                // decode-amortized batch (see docs/SERVING.md).
                gather_window: Duration::from_micros(200),
                ..Default::default()
            },
            ..Default::default()
        })
    };

    // Calibrate: closed-loop sequential rate = one request's full
    // round-trip cost; offered-load levels are multiples of it.
    let svc = mk_service();
    let id = svc.register("sat", m.clone()).unwrap();
    let cal = 30;
    let t0 = Instant::now();
    for _ in 0..cal {
        svc.spmv(id, x.clone()).unwrap();
    }
    let base_rps = cal as f64 / t0.elapsed().as_secs_f64();
    drop(svc);

    let mut rows = Vec::new();
    for mult in [0.5, 1.0, 2.0, 4.0, 8.0] {
        let offered_rps = base_rps * mult;
        let interval = Duration::from_secs_f64(1.0 / offered_rps);
        let svc = mk_service();
        let id = svc.register("sat", m.clone()).unwrap();
        svc.spmv(id, x.clone()).unwrap(); // warm the operator
        let warm_batches = svc.metrics.batches.load(Ordering::Relaxed);

        // Open-loop pacer: submit on schedule, never wait inline.
        let start = Instant::now();
        let mut pendings = Vec::with_capacity(reqs_per_level);
        for i in 0..reqs_per_level {
            let due = start + interval * i as u32;
            let now = Instant::now();
            if due > now {
                std::thread::sleep(due - now);
            }
            if let Ok(p) = svc.submit(id, x.clone()) {
                pendings.push(p);
            } // Err = shed under overload; counted by the service.
        }
        let admitted = pendings.len();
        for p in pendings {
            p.wait().unwrap();
        }
        let wall = start.elapsed().as_secs_f64();

        let mmetrics = &svc.metrics;
        let shed = mmetrics.shed.load(Ordering::Relaxed);
        let batches = mmetrics.batches.load(Ordering::Relaxed) - warm_batches;
        let coalesced_b = mmetrics.coalesced_batches.load(Ordering::Relaxed);
        let coalesced_r = mmetrics.coalesced_requests.load(Ordering::Relaxed);
        let lat = mmetrics.latency_summary();
        println!(
            "serving_saturation/x{mult:<4} offered {offered_rps:>7.0} req/s: \
             {admitted}/{reqs_per_level} admitted ({shed} shed), \
             {batches} engine batches, p50 {}µs p99 {}µs",
            lat.p50_us, lat.p99_us
        );
        rows.push(format!(
            "    {{\"offered_mult\": {mult}, \"offered_rps\": {offered_rps:.1}, \
             \"requests\": {reqs_per_level}, \"admitted\": {admitted}, \"shed\": {shed}, \
             \"engine_batches\": {batches}, \"coalesced_batches\": {coalesced_b}, \
             \"coalesced_requests\": {coalesced_r}, \
             \"p50_us\": {}, \"p99_us\": {}, \"wall_s\": {wall:.4}}}",
            lat.p50_us, lat.p99_us
        ));
        // The acceptance claim: past saturation, same-matrix requests
        // coalesce — strictly fewer engine batches than admitted
        // requests.
        if mult >= 4.0 && admitted > 1 {
            assert!(
                (batches as usize) < admitted,
                "no coalescing at x{mult}: {batches} batches for {admitted} requests"
            );
        }
    }

    let outdir = Path::new("results");
    let _ = std::fs::create_dir_all(outdir);
    let json = format!(
        "{{\n  \"bench\": \"serving_saturation\",\n  \"quick\": {quick},\n  \
         \"matrix_nnz\": {},\n  \"closed_loop_base_rps\": {base_rps:.1},\n  \
         \"queue_depth\": 256,\n  \"gather_window_us\": 200,\n  \"levels\": [\n{}\n  ]\n}}\n",
        m.nnz(),
        rows.join(",\n"),
    );
    let path = outdir.join("BENCH_serving.json");
    std::fs::write(&path, json).expect("write BENCH_serving.json");
    println!("serving_saturation/report    wrote {}", path.display());
}

/// Observability overhead: the same closed-loop warm SpMVM workload
/// through three identically configured services that differ only in
/// tracing mode — off (`sample_one_in: 0`, the tracer is bypassed
/// entirely), sampled 1-in-64, and always-on. The acceptance bars
/// (always-on < 5%, sampled < 1% on the ~2.3M-nnz scaling matrix) are
/// recorded in `results/BENCH_obs.json` alongside the always-on
/// service's full metrics snapshot, so future PRs have the trajectory.
fn bench_obs_overhead(filter: &Option<String>, quick: bool) {
    use dtans::coordinator::{ServiceConfig, SpmvService};
    use dtans::obs::export::metrics_json;
    use dtans::obs::ObsConfig;

    if !should_run(filter, "obs_overhead") {
        return;
    }
    let n = if quick { 1 << 15 } else { 1 << 18 };
    let reqs = if quick { 30 } else { 80 };
    let mut m = banded(n, 4); // ~9 nnz/row -> full mode ~2.3M nnz
    assign_values(&mut m, ValueDist::FewDistinct(16), &mut Xoshiro256::seeded(11));
    let x: Vec<f64> = (0..m.ncols).map(|j| (j as f64 * 0.01).sin()).collect();
    println!(
        "obs_overhead                 matrix: {} nnz (2^{:.1}), {} closed-loop requests/mode",
        m.nnz(),
        (m.nnz() as f64).log2(),
        reqs
    );

    let measure = |sample_one_in: u32| {
        let svc = SpmvService::start(ServiceConfig {
            obs: ObsConfig { sample_one_in, capacity: 4096 },
            ..Default::default()
        });
        let id = svc.register("obs", m.clone()).unwrap();
        svc.spmv(id, x.clone()).unwrap(); // warm: encode + pin outside timing
        let st = bench(1, 3, 0.3, || {
            for _ in 0..reqs {
                svc.spmv(id, x.clone()).unwrap();
            }
        });
        (st.median / reqs as f64, svc)
    };
    let (off_s, _svc_off) = measure(0);
    let (sampled_s, _svc_sampled) = measure(64);
    let (on_s, svc_on) = measure(1);
    let pct = |t: f64| (t / off_s - 1.0) * 100.0;
    let (sampled_pct, on_pct) = (pct(sampled_s), pct(on_s));
    println!("obs_overhead/off             {:.3} ms/req (baseline)", off_s * 1e3);
    println!(
        "obs_overhead/sampled_1in64   {:.3} ms/req ({sampled_pct:+.2}%, bar 1%)",
        sampled_s * 1e3
    );
    println!(
        "obs_overhead/always_on       {:.3} ms/req ({on_pct:+.2}%, bar 5%)",
        on_s * 1e3
    );

    let outdir = Path::new("results");
    let _ = std::fs::create_dir_all(outdir);
    let json = format!(
        "{{\n  \"bench\": \"obs_overhead\",\n  \"quick\": {},\n  \"nnz\": {},\n  \"requests_per_mode\": {},\n  \"off_per_req_s\": {:.6},\n  \"sampled_1in64_per_req_s\": {:.6},\n  \"always_on_per_req_s\": {:.6},\n  \"sampled_overhead_pct\": {:.3},\n  \"always_on_overhead_pct\": {:.3},\n  \"sampled_bar_pct\": 1.0,\n  \"always_on_bar_pct\": 5.0,\n  \"always_on_metrics\": {}\n}}\n",
        quick,
        m.nnz(),
        reqs,
        off_s,
        sampled_s,
        on_s,
        sampled_pct,
        on_pct,
        metrics_json(&svc_on.metrics),
    );
    let path = outdir.join("BENCH_obs.json");
    std::fs::write(&path, json).expect("write BENCH_obs.json");
    println!("obs_overhead/report          wrote {}", path.display());
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).filter(|a| a != "--bench").collect();
    let quick = args.iter().any(|a| a == "--quick");
    let filter = args.into_iter().find(|a| !a.starts_with("--"));
    println!("dtans bench harness (filter: {filter:?}, quick: {quick})");
    bench_codec(&filter, quick);
    bench_kernels(&filter, quick);
    bench_tans_vs_dtans(&filter);
    bench_engine_scaling(&filter, quick);
    bench_engine_batched(&filter, quick);
    bench_operator_dispatch(&filter, quick);
    bench_solver_iterations(&filter, quick);
    bench_store_coldstart(&filter, quick);
    bench_delta_compaction(&filter, quick);
    bench_stress_driver(&filter, quick);
    bench_serving_saturation(&filter, quick);
    bench_obs_overhead(&filter, quick);
    bench_routing_adaptation(&filter, quick);
    bench_large_banded(&filter, quick);
    bench_experiments(&filter, quick);
    println!("done.");
}
