//! Crate-wide error type.

use thiserror::Error;

/// Errors produced by the dtans library.
#[derive(Error, Debug)]
pub enum DtansError {
    /// Invalid codec parameters (violating the K^l >= W^o / M^l <= W^f
    /// constraints, or out-of-range fields).
    #[error("invalid ANS parameters: {0}")]
    InvalidParams(String),

    /// Malformed or inconsistent matrix data.
    #[error("invalid matrix: {0}")]
    InvalidMatrix(String),

    /// A decoder detected a corrupt or truncated stream.
    #[error("corrupt stream: {0}")]
    CorruptStream(String),

    /// Container (de)serialization failure.
    #[error("container format error: {0}")]
    Container(String),

    /// Mismatched dimensions in an SpMVM call.
    #[error("dimension mismatch: {0}")]
    Dimension(String),

    /// MatrixMarket parse errors.
    #[error("matrix market parse error at line {line}: {msg}")]
    MtxParse { line: usize, msg: String },

    /// IO errors.
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),

    /// PJRT / XLA runtime errors.
    #[error("runtime error: {0}")]
    Runtime(String),

    /// Coordinator/service errors.
    #[error("service error: {0}")]
    Service(String),
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, DtansError>;
