//! Binary (de)serialization of [`CsrDtans`] — the on-disk format the paper
//! mentions ("the encoded data can be stored in memory or saved in a file
//! for repeated decoding").
//!
//! Layout: little-endian, a fixed magic/header followed by length-prefixed
//! arrays and a trailing FNV-1a content checksum over everything before
//! it. The format is self-describing enough to reject foreign, truncated
//! or bit-rotted files with a clear, typed error — see the fault-injection
//! sweep in `tests/fault_injection.rs`.

use super::csr_dtans::CsrDtans;
use super::symbolize::Domain;
use crate::ans::params::AnsParams;
use crate::ans::tables::CodingTables;
use crate::matrix::Precision;
use crate::util::error::{DtansError, Result};
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 8] = b"CSRDTANS";
/// Version 2 appended the trailing content checksum (version 1 files are
/// rejected with [`DtansError::UnsupportedVersion`]; nothing persists
/// them outside test temp dirs).
const VERSION: u32 = 2;

/// 64-bit FNV-1a offset basis (checksum state seed).
const FNV64_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
/// 64-bit FNV-1a prime.
const FNV64_PRIME: u64 = 0x100_0000_01b3;

fn fnv_fold(mut hash: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        hash = (hash ^ b as u64).wrapping_mul(FNV64_PRIME);
    }
    hash
}

struct Writer<W: Write> {
    w: W,
    /// Running FNV-1a over every byte written so far (the trailer's
    /// checksum input).
    hash: u64,
}

impl<W: Write> Writer<W> {
    /// Single chokepoint: every checksummed byte goes through here.
    fn put(&mut self, bytes: &[u8]) -> Result<()> {
        self.hash = fnv_fold(self.hash, bytes);
        self.w.write_all(bytes)?;
        Ok(())
    }
    fn u32(&mut self, x: u32) -> Result<()> {
        self.put(&x.to_le_bytes())
    }
    fn u64(&mut self, x: u64) -> Result<()> {
        self.put(&x.to_le_bytes())
    }
    fn vec_u32(&mut self, xs: &[u32]) -> Result<()> {
        self.u64(xs.len() as u64)?;
        for &x in xs {
            self.u32(x)?;
        }
        Ok(())
    }
    fn vec_u64(&mut self, xs: &[u64]) -> Result<()> {
        self.u64(xs.len() as u64)?;
        for &x in xs {
            self.u64(x)?;
        }
        Ok(())
    }
    fn vec_bool(&mut self, xs: &[bool]) -> Result<()> {
        self.u64(xs.len() as u64)?;
        for &x in xs {
            self.put(&[x as u8])?;
        }
        Ok(())
    }
}

/// Never pre-reserve more than this many elements on the say-so of a
/// length prefix alone: a corrupted length must fail with
/// [`DtansError::Truncated`] when the data runs out, not abort the process
/// trying to allocate terabytes up front. Memory still only grows with
/// bytes actually read.
const PREALLOC_CAP: usize = 1 << 16;

struct Reader<R: Read> {
    r: R,
    /// Running FNV-1a over every byte read so far, compared against the
    /// file's trailing checksum at the end of [`read_from`].
    hash: u64,
}

impl<R: Read> Reader<R> {
    /// `read_exact` with EOF mapped to [`DtansError::Truncated`], so every
    /// short read surfaces as the dedicated truncation variant.
    fn fill(&mut self, buf: &mut [u8]) -> Result<()> {
        self.r.read_exact(buf).map_err(|e| match e.kind() {
            std::io::ErrorKind::UnexpectedEof => {
                DtansError::Truncated(format!("file ends {} byte(s) short of a field", buf.len()))
            }
            _ => DtansError::Io(e),
        })?;
        self.hash = fnv_fold(self.hash, buf);
        Ok(())
    }
    fn u32(&mut self) -> Result<u32> {
        let mut b = [0u8; 4];
        self.fill(&mut b)?;
        Ok(u32::from_le_bytes(b))
    }
    fn u64(&mut self) -> Result<u64> {
        let mut b = [0u8; 8];
        self.fill(&mut b)?;
        Ok(u64::from_le_bytes(b))
    }
    fn len(&mut self) -> Result<usize> {
        let n = self.u64()?;
        if n > (1 << 40) {
            return Err(DtansError::Container(format!("implausible length {n}")));
        }
        Ok(n as usize)
    }
    fn vec_u32(&mut self) -> Result<Vec<u32>> {
        let n = self.len()?;
        let mut v = Vec::with_capacity(n.min(PREALLOC_CAP));
        for _ in 0..n {
            v.push(self.u32()?);
        }
        Ok(v)
    }
    fn vec_u64(&mut self) -> Result<Vec<u64>> {
        let n = self.len()?;
        let mut v = Vec::with_capacity(n.min(PREALLOC_CAP));
        for _ in 0..n {
            v.push(self.u64()?);
        }
        Ok(v)
    }
    fn vec_bool(&mut self) -> Result<Vec<bool>> {
        let n = self.len()?;
        let mut v = Vec::with_capacity(n.min(PREALLOC_CAP));
        let mut chunk = [0u8; 4096];
        let mut remaining = n;
        while remaining > 0 {
            let take = remaining.min(chunk.len());
            self.fill(&mut chunk[..take])?;
            v.extend(chunk[..take].iter().map(|&b| b != 0));
            remaining -= take;
        }
        Ok(v)
    }
}

fn write_domain<W: Write>(w: &mut Writer<W>, d: &Domain) -> Result<()> {
    w.vec_u64(&d.payload)?;
    w.vec_bool(&d.is_escape)?;
    w.vec_u32(&d.mult)?;
    w.u32(d.escape_payload_bits)
}

fn read_domain<R: Read>(r: &mut Reader<R>) -> Result<Domain> {
    let payload = r.vec_u64()?;
    let is_escape = r.vec_bool()?;
    let mult = r.vec_u32()?;
    let bits = r.u32()?;
    Domain::from_parts(payload, is_escape, mult, bits)
}

/// Serialize to any writer.
pub fn write_to<W: Write>(m: &CsrDtans, w: W) -> Result<()> {
    let mut w = Writer { w, hash: FNV64_OFFSET };
    w.put(MAGIC)?;
    w.u32(VERSION)?;
    let p = m.params;
    for x in [p.w_bits, p.k_bits, p.m_bits, p.l, p.o, p.f] {
        w.u32(x)?;
    }
    w.u32(match m.precision {
        Precision::F64 => 64,
        Precision::F32 => 32,
    })?;
    w.u32(m.delta_encode as u32)?;
    w.u64(m.nrows as u64)?;
    w.u64(m.ncols as u64)?;
    w.u64(m.nnz as u64)?;
    write_domain(&mut w, &m.delta_domain)?;
    write_domain(&mut w, &m.value_domain)?;
    w.vec_u32(&m.row_nnz)?;
    w.vec_u32(&m.slice_offsets)?;
    w.vec_u32(&m.stream)?;
    w.vec_u32(&m.delta_escapes)?;
    w.vec_u64(&m.value_escapes)?;
    w.vec_u32(&m.delta_esc_offsets)?;
    w.vec_u32(&m.value_esc_offsets)?;
    // Trailer: the content checksum itself, written raw (it cannot cover
    // its own bytes).
    let checksum = w.hash;
    w.w.write_all(&checksum.to_le_bytes())?;
    Ok(())
}

/// Deserialize from any reader.
///
/// Rejects foreign files ([`DtansError::BadMagic`]), files written by a
/// different format revision ([`DtansError::UnsupportedVersion`]), files
/// that end mid-field ([`DtansError::Truncated`]), files whose bytes were
/// modified after writing ([`DtansError::ChecksumMismatch`] — the trailer
/// covers every preceding byte, so even a single flipped stream bit is
/// detected instead of silently decoding to different values) and files
/// whose arrays are mutually inconsistent ([`DtansError::Container`]) —
/// see the hardening tests at the bottom of this module and the
/// exhaustive fault-mode sweep in `tests/fault_injection.rs`.
pub fn read_from<R: Read>(r: R) -> Result<CsrDtans> {
    let mut r = Reader { r, hash: FNV64_OFFSET };
    let mut magic = [0u8; 8];
    r.fill(&mut magic)?;
    if &magic != MAGIC {
        return Err(DtansError::BadMagic { found: magic });
    }
    let version = r.u32()?;
    if version != VERSION {
        return Err(DtansError::UnsupportedVersion { found: version, supported: VERSION });
    }
    let params = AnsParams {
        w_bits: r.u32()?,
        k_bits: r.u32()?,
        m_bits: r.u32()?,
        l: r.u32()?,
        o: r.u32()?,
        f: r.u32()?,
    };
    params.validate()?;
    let precision = match r.u32()? {
        64 => Precision::F64,
        32 => Precision::F32,
        x => return Err(DtansError::Container(format!("bad precision {x}"))),
    };
    let delta_encode = r.u32()? != 0;
    let nrows = r.u64()? as usize;
    let ncols = r.u64()? as usize;
    let nnz = r.u64()? as usize;
    let delta_domain = read_domain(&mut r)?;
    let value_domain = read_domain(&mut r)?;
    let delta_tables = CodingTables::build(&params, &delta_domain.mult)?;
    let value_tables = CodingTables::build(&params, &value_domain.mult)?;
    let m = CsrDtans {
        params,
        precision,
        delta_encode,
        nrows,
        ncols,
        nnz,
        delta_domain,
        value_domain,
        delta_tables,
        value_tables,
        row_nnz: r.vec_u32()?,
        slice_offsets: r.vec_u32()?,
        stream: r.vec_u32()?,
        delta_escapes: r.vec_u32()?,
        value_escapes: r.vec_u64()?,
        delta_esc_offsets: r.vec_u32()?,
        value_esc_offsets: r.vec_u32()?,
    };
    // Verify the content checksum before the cross-array consistency
    // pass, so corruption reports as corruption (not as inconsistency).
    let computed = r.hash;
    let stored = {
        let mut b = [0u8; 8];
        r.fill(&mut b)?;
        u64::from_le_bytes(b)
    };
    if stored != computed {
        return Err(DtansError::ChecksumMismatch { stored, computed });
    }
    validate_consistency(&m)?;
    Ok(m)
}

/// Cross-array consistency checks on a freshly read container, so decode
/// paths can index offsets without out-of-bounds panics on corrupt input.
fn validate_consistency(m: &CsrDtans) -> Result<()> {
    let fail = |what: &str| Err(DtansError::Container(format!("inconsistent container: {what}")));
    if m.row_nnz.len() != m.nrows {
        return fail("row_nnz length != nrows");
    }
    if m.slice_offsets.len() != m.nslices() + 1 {
        return fail("slice_offsets length != nslices + 1");
    }
    if m.row_nnz.iter().map(|&n| n as u64).sum::<u64>() != m.nnz as u64 {
        return fail("row_nnz sum != nnz");
    }
    if m.slice_offsets.windows(2).any(|w| w[0] > w[1]) {
        return fail("slice_offsets not monotonic");
    }
    if m.slice_offsets.last().map(|&w| w as usize) != Some(m.stream.len()) {
        return fail("slice_offsets end != stream length");
    }
    for (name, offs, len) in [
        ("delta", &m.delta_esc_offsets, m.delta_escapes.len()),
        ("value", &m.value_esc_offsets, m.value_escapes.len()),
    ] {
        if offs.len() != m.nrows + 1 {
            return fail(&format!("{name} escape offsets length != nrows + 1"));
        }
        if offs.windows(2).any(|w| w[0] > w[1]) {
            return fail(&format!("{name} escape offsets not monotonic"));
        }
        if offs.last().map(|&w| w as usize) != Some(len) {
            return fail(&format!("{name} escape offsets end != escape count"));
        }
    }
    Ok(())
}

/// Save to a file, creating parent directories.
pub fn save(m: &CsrDtans, path: &Path) -> Result<()> {
    if let Some(p) = path.parent() {
        std::fs::create_dir_all(p)?;
    }
    let f = std::fs::File::create(path)?;
    write_to(m, std::io::BufWriter::new(f))
}

/// Load from a file.
pub fn load(path: &Path) -> Result<CsrDtans> {
    let f = std::fs::File::open(path)?;
    read_from(std::io::BufReader::new(f))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::format::csr_dtans::EncodeOptions;
    use crate::matrix::gen::structured::banded;
    use crate::matrix::gen::{assign_values, ValueDist};
    use crate::util::rng::Xoshiro256;

    fn sample() -> CsrDtans {
        let mut rng = Xoshiro256::seeded(1);
        let mut m = banded(200, 3);
        assign_values(&mut m, ValueDist::Quantized(32), &mut rng);
        CsrDtans::encode(&m, &EncodeOptions::default()).unwrap()
    }

    #[test]
    fn roundtrip_bytes() {
        let enc = sample();
        let mut buf = Vec::new();
        write_to(&enc, &mut buf).unwrap();
        let back = read_from(std::io::Cursor::new(&buf)).unwrap();
        assert_eq!(back.stream, enc.stream);
        assert_eq!(back.row_nnz, enc.row_nnz);
        assert_eq!(back.delta_tables, enc.delta_tables);
        assert_eq!(
            back.decode_to_csr().unwrap(),
            enc.decode_to_csr().unwrap()
        );
    }

    #[test]
    fn rejects_bad_magic_with_distinct_variant() {
        let enc = sample();
        let mut buf = Vec::new();
        write_to(&enc, &mut buf).unwrap();
        buf[0] = b'X';
        assert!(matches!(
            read_from(std::io::Cursor::new(&buf)),
            Err(DtansError::BadMagic { .. })
        ));
    }

    #[test]
    fn rejects_future_version_with_distinct_variant() {
        let enc = sample();
        let mut buf = Vec::new();
        write_to(&enc, &mut buf).unwrap();
        // Version is the little-endian u32 right after the 8-byte magic.
        buf[8..12].copy_from_slice(&9u32.to_le_bytes());
        assert!(matches!(
            read_from(std::io::Cursor::new(&buf)),
            Err(DtansError::UnsupportedVersion { found: 9, supported: 2 })
        ));
        // Version-1 files (pre-checksum) are rejected the same way.
        buf[8..12].copy_from_slice(&1u32.to_le_bytes());
        assert!(matches!(
            read_from(std::io::Cursor::new(&buf)),
            Err(DtansError::UnsupportedVersion { found: 1, supported: 2 })
        ));
    }

    #[test]
    fn rejects_truncation_with_distinct_variant() {
        let enc = sample();
        let mut buf = Vec::new();
        write_to(&enc, &mut buf).unwrap();
        for cut in [buf.len() / 2, buf.len() - 1, 12, 9] {
            assert!(matches!(
                read_from(std::io::Cursor::new(&buf[..cut])),
                Err(DtansError::Truncated(_))
            ));
        }
    }

    #[test]
    fn every_strict_prefix_is_rejected() {
        // The format is length-prefixed with no trailing slack, so every
        // strict prefix must fail to parse (sampled densely near the ends,
        // sparsely in the middle).
        let enc = sample();
        let mut buf = Vec::new();
        write_to(&enc, &mut buf).unwrap();
        let mut cuts: Vec<usize> = (0..64.min(buf.len())).collect();
        cuts.extend((buf.len().saturating_sub(64)..buf.len()).step_by(1));
        cuts.extend((0..buf.len()).step_by(97));
        for cut in cuts {
            assert!(
                read_from(std::io::Cursor::new(&buf[..cut])).is_err(),
                "prefix of {cut}/{} bytes parsed",
                buf.len()
            );
        }
    }

    #[test]
    fn corrupted_bytes_are_always_detected() {
        // Fuzz-ish: flip one byte at a pseudo-random offset and parse.
        // Since version 2 the trailing content checksum makes *every*
        // byte-level change detectable: the parse must return a typed
        // error — never panic, never silently decode different values.
        let enc = sample();
        let mut buf = Vec::new();
        write_to(&enc, &mut buf).unwrap();
        let mut rng = Xoshiro256::seeded(0xC0FFEE);
        for _ in 0..400 {
            let mut bad = buf.clone();
            let off = rng.below_usize(bad.len());
            bad[off] ^= 1 + rng.below(255) as u8;
            assert!(
                read_from(std::io::Cursor::new(&bad)).is_err(),
                "byte {off} corruption parsed successfully"
            );
        }
    }

    #[test]
    fn every_fault_mode_is_detected_with_a_typed_error() {
        // The testkit corruption engine's modes (bit flips, truncation,
        // length-prefix inflation, cross-array length swaps, zeroed
        // spans) must each map to a typed `DtansError` — this is the
        // unit-level mirror of the sweep in tests/fault_injection.rs.
        use crate::testkit::faults::{corrupt, FaultMode, ALL_FAULT_MODES};
        let enc = sample();
        let mut buf = Vec::new();
        write_to(&enc, &mut buf).unwrap();
        for mode in ALL_FAULT_MODES {
            for seed in 0..25u64 {
                let bad = corrupt(&buf, mode, seed);
                let err = match read_from(std::io::Cursor::new(&bad)) {
                    Err(e) => e,
                    Ok(_) => panic!("{mode:?} seed {seed} parsed successfully"),
                };
                if mode == FaultMode::Truncate {
                    // Pure tail loss is always the dedicated variant.
                    assert!(
                        matches!(err, DtansError::Truncated(_)),
                        "{mode:?} seed {seed}: {err}"
                    );
                }
            }
        }
    }

    #[test]
    fn file_roundtrip() {
        let enc = sample();
        let dir = std::env::temp_dir().join("dtans_test_serialize");
        let path = dir.join("m.dtans");
        save(&enc, &path).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(back.stream, enc.stream);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
