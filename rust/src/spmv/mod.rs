//! SpMVM kernels (`y = A·x + y`, the paper's §III-A semantics) for every
//! format: dense reference, CSR (scalar and vector variants), COO, SELL,
//! BlockedEll (σ-sorted fixed-width blocks), and the fused decode+multiply
//! kernel over CSR-dtANS — plus the hand-unrolled wide-accumulator
//! variants in [`unrolled`], selected per-engine via
//! [`engine::KernelVariant`] (policy in `docs/KERNELS.md`).
//!
//! The classic-format kernels stand in for cuSPARSE's and feed the GPU
//! simulator's cost models; the CSR-dtANS kernel is the paper's
//! contribution — SpMVM interleaved with on-the-fly entropy decoding.
//!
//! The free functions in this module are the *serial* kernels — the
//! ground truth every other execution path is tested against. Above them
//! sits the format-agnostic [`operator`] layer: each format implements the
//! object-safe [`operator::SpmvOperator`] trait (work units, cost prefix,
//! block kernel), and the [`engine`] schedules any operator — serial,
//! nnz-balanced parallel, or batched multi-RHS over contiguous
//! [`densemat::DenseMat`] views — with results bit-identical to the serial
//! kernels (see [`engine::SpmvEngine`] and [`engine::ParStrategy`] for the
//! selection rules).
//!
//! ```
//! use dtans::matrix::{Coo, Csr};
//! use dtans::spmv::engine::SpmvEngine;
//! use dtans::spmv::spmv_csr;
//!
//! let mut coo = Coo::new(2, 3);
//! coo.push(0, 2, 4.0);
//! coo.push(1, 0, -1.0);
//! let m = Csr::from_coo(&coo);
//! let x = [1.0, 1.0, 0.5];
//!
//! let mut y = vec![0.0; 2];
//! spmv_csr(&m, &x, &mut y).unwrap(); // serial kernel
//! let mut y_eng = vec![0.0; 2];
//! SpmvEngine::auto().run(&m, &x, &mut y_eng).unwrap(); // engine, trait path
//! assert_eq!(y, y_eng);
//! ```

pub mod blocked_ell;
pub mod coo;
pub mod csr;
pub mod csr_dtans;
pub mod dense;
pub mod densemat;
pub mod engine;
pub mod operator;
pub mod sell;
pub mod unrolled;
pub mod verify;

pub use blocked_ell::spmv_blocked_ell;
pub use coo::spmv_coo;
pub use csr::{spmv_csr, spmv_csr_vector};
pub use csr_dtans::spmv_csr_dtans;
pub use dense::spmv_dense;
pub use densemat::{DenseMat, DenseMatMut};
pub use engine::{KernelVariant, ParStrategy, SpmvEngine};
pub use operator::{DenseOperator, DtansOperator, FormatEntry, FormatRegistry, SpmvOperator};
pub use sell::spmv_sell;

use crate::util::error::{DtansError, Result};

/// Check `x`/`y` lengths against a matrix shape.
pub(crate) fn check_dims(nrows: usize, ncols: usize, x: &[f64], y: &[f64]) -> Result<()> {
    if x.len() != ncols || y.len() != nrows {
        return Err(DtansError::Dimension(format!(
            "matrix {nrows}x{ncols} with x[{}], y[{}]",
            x.len(),
            y.len()
        )));
    }
    Ok(())
}
