//! Structured sparsity patterns: banded matrices, 2D/3D stencils, random
//! block patterns, power-law row lengths, and uniform random matrices.
//!
//! Together with the graph models these span the corpus axes the paper's
//! Tables I–III bucket over (total nnz × annzpr × regularity).

use crate::matrix::coo::Coo;
use crate::matrix::csr::Csr;
use crate::util::rng::Xoshiro256;

/// Tridiagonal matrix of order `n` (the paper's §IV-A example).
pub fn tridiagonal(n: usize) -> Csr {
    banded(n, 1)
}

/// Banded matrix with half-bandwidth `bw` (full band `2*bw+1`).
pub fn banded(n: usize, bw: usize) -> Csr {
    let mut coo = Coo::new(n, n);
    for i in 0..n {
        let lo = i.saturating_sub(bw);
        let hi = (i + bw + 1).min(n);
        for j in lo..hi {
            coo.push(i as u32, j as u32, if i == j { 2.0 } else { -1.0 });
        }
    }
    Csr::from_coo(&coo)
}

/// 5-point 2D Laplacian stencil on an `nx × ny` grid.
pub fn stencil2d5(nx: usize, ny: usize) -> Csr {
    let n = nx * ny;
    let mut coo = Coo::new(n, n);
    let idx = |x: usize, y: usize| (y * nx + x) as u32;
    for y in 0..ny {
        for x in 0..nx {
            let c = idx(x, y);
            coo.push(c, c, 4.0);
            if x > 0 {
                coo.push(c, idx(x - 1, y), -1.0);
            }
            if x + 1 < nx {
                coo.push(c, idx(x + 1, y), -1.0);
            }
            if y > 0 {
                coo.push(c, idx(x, y - 1), -1.0);
            }
            if y + 1 < ny {
                coo.push(c, idx(x, y + 1), -1.0);
            }
        }
    }
    Csr::from_coo(&coo)
}

/// 9-point 2D stencil on an `nx × ny` grid.
pub fn stencil2d9(nx: usize, ny: usize) -> Csr {
    let n = nx * ny;
    let mut coo = Coo::new(n, n);
    for y in 0..ny as isize {
        for x in 0..nx as isize {
            let c = (y * nx as isize + x) as u32;
            for dy in -1..=1 {
                for dx in -1..=1 {
                    let (xx, yy) = (x + dx, y + dy);
                    if xx >= 0 && yy >= 0 && (xx as usize) < nx && (yy as usize) < ny {
                        let v = if dx == 0 && dy == 0 { 8.0 } else { -1.0 };
                        coo.push(c, (yy * nx as isize + xx) as u32, v);
                    }
                }
            }
        }
    }
    Csr::from_coo(&coo)
}

/// 27-point 3D stencil on an `nx × ny × nz` grid.
pub fn stencil3d27(nx: usize, ny: usize, nz: usize) -> Csr {
    let n = nx * ny * nz;
    let mut coo = Coo::new(n, n);
    for z in 0..nz as isize {
        for y in 0..ny as isize {
            for x in 0..nx as isize {
                let c = ((z * ny as isize + y) * nx as isize + x) as u32;
                for dz in -1..=1 {
                    for dy in -1..=1 {
                        for dx in -1..=1 {
                            let (xx, yy, zz) = (x + dx, y + dy, z + dz);
                            if xx >= 0
                                && yy >= 0
                                && zz >= 0
                                && (xx as usize) < nx
                                && (yy as usize) < ny
                                && (zz as usize) < nz
                            {
                                let v = if dx == 0 && dy == 0 && dz == 0 { 26.0 } else { -1.0 };
                                coo.push(c, ((zz * ny as isize + yy) * nx as isize + xx) as u32, v);
                            }
                        }
                    }
                }
            }
        }
    }
    Csr::from_coo(&coo)
}

/// Uniform random pattern with exactly ~`nnz` entries spread over an
/// `nrows × ncols` matrix (duplicates collapse, so actual nnz ≲ requested).
pub fn random_uniform(nrows: usize, ncols: usize, nnz: usize, rng: &mut Xoshiro256) -> Csr {
    let mut coo = Coo::new(nrows, ncols);
    for _ in 0..nnz {
        coo.push(
            rng.below_usize(nrows) as u32,
            rng.below_usize(ncols) as u32,
            1.0,
        );
    }
    Csr::from_coo(&coo)
}

/// Random block pattern: `nb × nb` dense blocks of size `bs` dropped onto a
/// block grid with the given density — models FEM-style clustered matrices
/// where delta-encoding shines.
pub fn block_random(n: usize, bs: usize, density: f64, rng: &mut Xoshiro256) -> Csr {
    let nb = n / bs;
    let mut coo = Coo::new(n, n);
    for bi in 0..nb {
        for bj in 0..nb {
            // Always keep the diagonal block so no row is empty.
            if bi == bj || rng.chance(density) {
                for i in 0..bs {
                    for j in 0..bs {
                        coo.push((bi * bs + i) as u32, (bj * bs + j) as u32, 1.0);
                    }
                }
            }
        }
    }
    Csr::from_coo(&coo)
}

/// Power-law row lengths: row r gets ~`c / (r+1)^alpha` nonzeros at random
/// columns — models the highly irregular matrices our kernel handles badly
/// (upper-left quadrant of Fig. 7).
pub fn powerlaw_rows(n: usize, avg_nnz_per_row: f64, alpha: f64, rng: &mut Xoshiro256) -> Csr {
    // Normalize so the expected average matches.
    let weight: f64 = (0..n).map(|r| 1.0 / ((r + 1) as f64).powf(alpha)).sum();
    let scale = avg_nnz_per_row * n as f64 / weight;
    let mut coo = Coo::new(n, n);
    let mut order: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut order); // hubs scattered, not sorted by row id
    for (rank, &r) in order.iter().enumerate() {
        let len = ((scale / ((rank + 1) as f64).powf(alpha)).round() as usize).clamp(1, n);
        for &c in rng.sample_distinct(n, len).iter() {
            coo.push(r as u32, c as u32, 1.0);
        }
    }
    Csr::from_coo(&coo)
}

/// Diagonal plus `k` random off-diagonals per row — mildly irregular.
pub fn diag_plus_random(n: usize, k: usize, rng: &mut Xoshiro256) -> Csr {
    let mut coo = Coo::new(n, n);
    for i in 0..n {
        coo.push(i as u32, i as u32, 2.0);
        for &c in rng.sample_distinct(n, k).iter() {
            if c != i {
                coo.push(i as u32, c as u32, -0.1);
            }
        }
    }
    Csr::from_coo(&coo)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tridiagonal_shape() {
        let m = tridiagonal(5);
        assert_eq!(m.nnz(), 13);
        assert_eq!(m.row_len(0), 2);
        assert_eq!(m.row_len(2), 3);
        m.validate().unwrap();
    }

    #[test]
    fn banded_width() {
        let m = banded(10, 2);
        assert_eq!(m.max_row_len(), 5);
        m.validate().unwrap();
    }

    #[test]
    fn stencil5_interior_has_5() {
        let m = stencil2d5(8, 8);
        // Interior point (3,3) -> row 27 has 5 entries.
        assert_eq!(m.row_len(3 * 8 + 3), 5);
        assert_eq!(m.row_len(0), 3); // corner
        m.validate().unwrap();
    }

    #[test]
    fn stencil9_and_27_counts() {
        assert_eq!(stencil2d9(5, 5).row_len(2 * 5 + 2), 9);
        assert_eq!(stencil3d27(4, 4, 4).row_len(1 * 16 + 1 * 4 + 1), 27);
    }

    #[test]
    fn random_uniform_near_target() {
        let mut rng = Xoshiro256::seeded(1);
        let m = random_uniform(200, 200, 2000, &mut rng);
        assert!(m.nnz() > 1800 && m.nnz() <= 2000);
        m.validate().unwrap();
    }

    #[test]
    fn block_random_no_empty_rows() {
        let mut rng = Xoshiro256::seeded(2);
        let m = block_random(64, 8, 0.2, &mut rng);
        for r in 0..m.nrows {
            assert!(m.row_len(r) >= 8);
        }
        m.validate().unwrap();
    }

    #[test]
    fn powerlaw_irregular() {
        let mut rng = Xoshiro256::seeded(3);
        let m = powerlaw_rows(500, 8.0, 1.0, &mut rng);
        assert!(m.max_row_len() > 4 * m.annzpr() as usize);
        m.validate().unwrap();
    }

    #[test]
    fn diag_plus_random_has_diag() {
        let mut rng = Xoshiro256::seeded(4);
        let m = diag_plus_random(50, 3, &mut rng);
        for r in 0..50 {
            assert!(m.row_cols(r).contains(&(r as u32)));
        }
    }
}
