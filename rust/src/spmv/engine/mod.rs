//! Parallel nnz-balanced SpMVM engine.
//!
//! The paper's GPU kernel assigns one warp per 32-row slice and wins
//! because SpMVM is bandwidth-bound; the CPU reproduction was leaving that
//! same parallelism on the table by running every kernel single-threaded.
//! This engine closes the gap: an nnz-balanced partitioner
//! ([`partition_prefix`], binary search over cost prefixes — the CPU
//! analog of the paper's warp work assignment) plus a scoped executor that
//! fans blocks out across a [`ThreadPool`], handing each worker a disjoint
//! `&mut` range of the output vector.
//!
//! Because blocks are contiguous and every row is computed by exactly one
//! block with the serial kernel's per-row arithmetic, parallel results are
//! **bit-identical** to the serial kernels for CSR, SELL and CSR-dtANS —
//! property-tested in `tests/engine_parallel.rs` across partition counts
//! 1..=16.
//!
//! # Strategy selection ([`ParStrategy`])
//!
//! * [`ParStrategy::Serial`] — always run on the calling thread; no pool
//!   is created. Use when the caller manages parallelism itself (e.g. the
//!   evaluation harness that already parallelizes across matrices) or for
//!   exact control in tests.
//! * [`ParStrategy::Fixed(n)`](ParStrategy::Fixed) — always fan out across
//!   `n` blocks on `n` worker threads, even for tiny inputs. Use for
//!   scaling studies and reproducible partition counts; `Fixed(1)` is the
//!   serial path (no pool is spawned).
//! * [`ParStrategy::Auto`] (default) — one block per logical CPU, but fall
//!   back to the serial path whenever the estimated work (nonzeros, times
//!   right-hand sides for the batched entry points) is below
//!   [`MIN_PAR_COST`], where fan-out overhead would dominate. This is the
//!   right default for services.
//!
//! # Example
//!
//! ```
//! use dtans::matrix::gen::structured::banded;
//! use dtans::matrix::gen::{assign_values, ValueDist};
//! use dtans::spmv::engine::{ParStrategy, SpmvEngine};
//! use dtans::spmv::spmv_csr;
//! use dtans::util::rng::Xoshiro256;
//!
//! let mut m = banded(1000, 3);
//! assign_values(&mut m, ValueDist::FewDistinct(8), &mut Xoshiro256::seeded(1));
//! let x = vec![1.0; m.ncols];
//!
//! let engine = SpmvEngine::new(ParStrategy::Fixed(4));
//! let mut y_par = vec![0.0; m.nrows];
//! engine.spmv_csr(&m, &x, &mut y_par).unwrap();
//!
//! let mut y_serial = vec![0.0; m.nrows];
//! spmv_csr(&m, &x, &mut y_serial).unwrap();
//! assert_eq!(y_par, y_serial); // bit-identical, not merely close
//! ```

pub mod partition;

pub use partition::{partition_csr, partition_dtans, partition_prefix, partition_sell, Block};

use crate::format::csr_dtans::{CsrDtans, WARP};
use crate::matrix::csr::Csr;
use crate::matrix::sell::Sell;
use crate::spmv::csr::spmv_row_range;
use crate::spmv::csr_dtans::{spmv_slice_range, spmv_with_plan, DecodePlan};
use crate::spmv::sell::spmv_sell_slice_range;
use crate::util::error::{DtansError, Result};
use crate::util::threadpool::{ScopedJob, ThreadPool};

/// Below this many "cost units" (nonzeros × right-hand sides), the
/// [`ParStrategy::Auto`] strategy runs serially: fanning a multiply this
/// small across threads costs more in wake-ups than the multiply itself.
pub const MIN_PAR_COST: usize = 1 << 14;

/// How the engine maps one multiply onto threads; see the
/// [module docs](self) for selection rules.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ParStrategy {
    /// Always run on the calling thread.
    Serial,
    /// Always fan out across exactly this many nnz-balanced blocks.
    Fixed(usize),
    /// One block per logical CPU; serial below [`MIN_PAR_COST`].
    #[default]
    Auto,
}

/// The parallel SpMVM engine: owns a worker pool and routes every
/// supported format (CSR, SELL, CSR-dtANS) through the nnz-balanced
/// partitioner. See the [module docs](self) for the execution model.
///
/// The engine is `Sync`: one instance can be shared by many request
/// threads (the coordinator does exactly this), with each call waiting
/// only on its own blocks.
pub struct SpmvEngine {
    strategy: ParStrategy,
    nthreads: usize,
    pool: Option<ThreadPool>,
}

impl Default for SpmvEngine {
    fn default() -> Self {
        SpmvEngine::new(ParStrategy::Auto)
    }
}

impl SpmvEngine {
    /// Build an engine with the given strategy (spawns the worker pool
    /// unless the strategy is [`ParStrategy::Serial`]).
    pub fn new(strategy: ParStrategy) -> SpmvEngine {
        let nthreads = match strategy {
            ParStrategy::Serial => 1,
            ParStrategy::Fixed(n) => n.max(1),
            ParStrategy::Auto => ThreadPool::default_parallelism(),
        };
        let pool = match strategy {
            ParStrategy::Serial => None,
            _ if nthreads < 2 => None,
            _ => Some(ThreadPool::new(nthreads)),
        };
        SpmvEngine { strategy, nthreads, pool }
    }

    /// Engine that always runs on the calling thread.
    pub fn serial() -> SpmvEngine {
        SpmvEngine::new(ParStrategy::Serial)
    }

    /// Engine with the [`ParStrategy::Auto`] policy (the default).
    pub fn auto() -> SpmvEngine {
        SpmvEngine::new(ParStrategy::Auto)
    }

    /// The configured strategy.
    pub fn strategy(&self) -> ParStrategy {
        self.strategy
    }

    /// Worker threads available to this engine (1 for serial).
    pub fn nthreads(&self) -> usize {
        self.nthreads
    }

    /// True when this engine owns a worker pool and can fan a multiply
    /// out (false for [`ParStrategy::Serial`] and single-thread configs).
    pub fn is_parallel(&self) -> bool {
        self.pool.is_some()
    }

    /// True when a batched call over a matrix with `nnz` nonzeros and `k`
    /// right-hand sides would actually fan out (callers with their own
    /// request-level parallelism — the coordinator's worker pool — use
    /// this to decide whether handing the whole batch to the engine beats
    /// per-request dispatch).
    pub fn will_batch_parallel(&self, nnz: usize, k: usize) -> bool {
        self.pool.is_some() && self.batch_parts(nnz, k).is_some()
    }

    /// Number of blocks a multiply of the given cost will fan out into;
    /// 1 means the serial path.
    fn parts_for(&self, cost: usize) -> usize {
        match self.strategy {
            ParStrategy::Serial => 1,
            ParStrategy::Fixed(n) => n.max(1),
            ParStrategy::Auto => {
                if cost < MIN_PAR_COST || self.nthreads < 2 {
                    1
                } else {
                    self.nthreads
                }
            }
        }
    }

    /// `y += A·x` over CSR, partitioned by rows into equal-nonzeros
    /// blocks. Bit-identical to [`crate::spmv::spmv_csr`].
    ///
    /// ```
    /// use dtans::matrix::{Coo, Csr};
    /// use dtans::spmv::engine::SpmvEngine;
    /// let mut coo = Coo::new(2, 2);
    /// coo.push(0, 0, 2.0);
    /// coo.push(1, 1, 3.0);
    /// let m = Csr::from_coo(&coo);
    /// let mut y = vec![0.0; 2];
    /// SpmvEngine::auto().spmv_csr(&m, &[1.0, 1.0], &mut y).unwrap();
    /// assert_eq!(y, vec![2.0, 3.0]);
    /// ```
    pub fn spmv_csr(&self, m: &Csr, x: &[f64], y: &mut [f64]) -> Result<()> {
        let parts = self.parts_for(m.nnz());
        match &self.pool {
            Some(pool) if parts > 1 => {
                super::check_dims(m.nrows, m.ncols, x, y)?;
                let blocks = partition_csr(m, parts);
                run_blocks(pool, &blocks, y, |b| b.end, |b, seg| {
                    spmv_row_range(m, b.start, b.end, x, seg)
                })
            }
            _ => super::csr::spmv_csr(m, x, y),
        }
    }

    /// `y += A·x` over SELL, partitioned by slices weighted by padded
    /// cells. Bit-identical to [`crate::spmv::spmv_sell`].
    pub fn spmv_sell(&self, m: &Sell, x: &[f64], y: &mut [f64]) -> Result<()> {
        let parts = self.parts_for(m.padded_cells());
        match &self.pool {
            Some(pool) if parts > 1 => {
                super::check_dims(m.nrows, m.ncols, x, y)?;
                let blocks = partition_sell(m, parts);
                let h = m.slice_height;
                run_blocks(
                    pool,
                    &blocks,
                    y,
                    |b| (b.end * h).min(m.nrows),
                    |b, seg| spmv_sell_slice_range(m, b.start, b.end, x, seg),
                )
            }
            _ => super::sell::spmv_sell(m, x, y),
        }
    }

    /// `y += A·x` over CSR-dtANS (decode fused with multiply), building
    /// the [`DecodePlan`] on the fly. Prefer
    /// [`SpmvEngine::spmv_csr_dtans_with_plan`] when multiplying the same
    /// matrix repeatedly.
    pub fn spmv_csr_dtans(&self, m: &CsrDtans, x: &[f64], y: &mut [f64]) -> Result<()> {
        let plan = DecodePlan::new(m);
        self.spmv_csr_dtans_with_plan(m, &plan, x, y)
    }

    /// `y += A·x` over CSR-dtANS with a prebuilt [`DecodePlan`],
    /// partitioned by 32-row slices weighted by encoded stream words (the
    /// quantity that bounds decode time). Bit-identical to
    /// [`crate::spmv::spmv_csr_dtans`].
    pub fn spmv_csr_dtans_with_plan(
        &self,
        m: &CsrDtans,
        plan: &DecodePlan,
        x: &[f64],
        y: &mut [f64],
    ) -> Result<()> {
        let parts = self.parts_for(m.nnz);
        match &self.pool {
            Some(pool) if parts > 1 => {
                super::check_dims(m.nrows, m.ncols, x, y)?;
                let blocks = partition_dtans(m, parts);
                run_blocks(
                    pool,
                    &blocks,
                    y,
                    |b| (b.end * WARP).min(m.nrows),
                    |b, seg| spmv_slice_range(m, plan, b.start, b.end, x, seg),
                )
            }
            _ => spmv_with_plan(m, plan, x, y),
        }
    }

    /// Batched multi-RHS multiply (SpMM-style): `ys[j] = A·xs[j]` for every
    /// right-hand side, fanning the (right-hand side × row block) grid out
    /// over the pool — the serving shape where one matrix is multiplied
    /// against many vectors per batch. Returns freshly zero-initialized
    /// outputs. Each output is bit-identical to a serial
    /// [`crate::spmv::spmv_csr`] on the same vector.
    ///
    /// ```
    /// use dtans::matrix::{Coo, Csr};
    /// use dtans::spmv::engine::SpmvEngine;
    /// let mut coo = Coo::new(2, 2);
    /// coo.push(0, 1, 5.0);
    /// coo.push(1, 0, 7.0);
    /// let m = Csr::from_coo(&coo);
    /// let xs = vec![vec![1.0, 0.0], vec![0.0, 1.0]];
    /// let ys = SpmvEngine::auto().spmm_csr(&m, &xs).unwrap();
    /// assert_eq!(ys, vec![vec![0.0, 7.0], vec![5.0, 0.0]]);
    /// ```
    pub fn spmm_csr(&self, m: &Csr, xs: &[Vec<f64>]) -> Result<Vec<Vec<f64>>> {
        check_batch_dims(m.ncols, xs)?;
        let mut ys: Vec<Vec<f64>> = xs.iter().map(|_| vec![0.0; m.nrows]).collect();
        match (&self.pool, self.batch_parts(m.nnz(), xs.len())) {
            (Some(pool), Some(parts)) => {
                let blocks = partition_csr(m, parts);
                run_batch_blocks(pool, &blocks, xs, &mut ys, |b| b.end, |b, x, seg| {
                    spmv_row_range(m, b.start, b.end, x, seg)
                })?;
            }
            _ => {
                for (x, y) in xs.iter().zip(ys.iter_mut()) {
                    super::csr::spmv_csr(m, x, y)?;
                }
            }
        }
        Ok(ys)
    }

    /// Batched multi-RHS multiply over CSR-dtANS, building the plan once.
    pub fn spmm_csr_dtans(&self, m: &CsrDtans, xs: &[Vec<f64>]) -> Result<Vec<Vec<f64>>> {
        let plan = DecodePlan::new(m);
        self.spmm_csr_dtans_with_plan(m, &plan, xs)
    }

    /// Batched multi-RHS multiply over CSR-dtANS with a prebuilt plan:
    /// `ys[j] = A·xs[j]`, fanning the (right-hand side × slice block) grid
    /// out over the pool. The matrix is decoded once per right-hand side
    /// (decode is fused into the multiply), but the coding tables and plan
    /// stay hot in cache across the whole batch. Each output is
    /// bit-identical to a serial [`crate::spmv::spmv_csr_dtans`].
    pub fn spmm_csr_dtans_with_plan(
        &self,
        m: &CsrDtans,
        plan: &DecodePlan,
        xs: &[Vec<f64>],
    ) -> Result<Vec<Vec<f64>>> {
        check_batch_dims(m.ncols, xs)?;
        let mut ys: Vec<Vec<f64>> = xs.iter().map(|_| vec![0.0; m.nrows]).collect();
        match (&self.pool, self.batch_parts(m.nnz, xs.len())) {
            (Some(pool), Some(parts)) => {
                let blocks = partition_dtans(m, parts);
                run_batch_blocks(
                    pool,
                    &blocks,
                    xs,
                    &mut ys,
                    |b| (b.end * WARP).min(m.nrows),
                    |b, x, seg| spmv_slice_range(m, plan, b.start, b.end, x, seg),
                )?;
            }
            _ => {
                for (x, y) in xs.iter().zip(ys.iter_mut()) {
                    spmv_with_plan(m, plan, x, y)?;
                }
            }
        }
        Ok(ys)
    }

    /// Blocks *per right-hand side* for a batched call, or `None` for the
    /// serial path. The whole batch's cost decides whether to go parallel
    /// at all; the per-matrix block count then shrinks as the batch itself
    /// provides parallelism (with `k` right-hand sides and `n` threads,
    /// `ceil(n / k)` blocks already yield ≥ `n` independent jobs, so even
    /// one block per right-hand side is a real fan-out when `k > 1`).
    fn batch_parts(&self, nnz: usize, k: usize) -> Option<usize> {
        if k == 0 {
            return None;
        }
        let parts = self.parts_for(nnz.saturating_mul(k));
        match self.strategy {
            ParStrategy::Serial => None,
            // Auto below the cost threshold stays serial even for k > 1.
            ParStrategy::Auto if parts <= 1 => None,
            // Fixed(1) reaches here as Some(1), but its engine has no
            // pool, so every caller still takes the serial path.
            _ => Some(parts.div_ceil(k).max(1)),
        }
    }
}

/// Validate every right-hand side's length against `ncols`.
fn check_batch_dims(ncols: usize, xs: &[Vec<f64>]) -> Result<()> {
    for (j, x) in xs.iter().enumerate() {
        if x.len() != ncols {
            return Err(DtansError::Dimension(format!(
                "batch rhs {j}: x[{}] for {ncols} columns",
                x.len()
            )));
        }
    }
    Ok(())
}

/// Fan one output vector's blocks out over the pool. `row_end` maps a
/// block to its exclusive end *row* (blocks may be in units of slices);
/// `kernel` computes one block into its disjoint output segment.
/// Crate-visible so `spmv_csr_dtans_parallel` shares the same executor.
pub(crate) fn run_blocks(
    pool: &ThreadPool,
    blocks: &[Block],
    y: &mut [f64],
    row_end: impl Fn(&Block) -> usize,
    kernel: impl Fn(Block, &mut [f64]) -> Result<()> + Send + Sync,
) -> Result<()> {
    let mut slots: Vec<Result<()>> = Vec::new();
    slots.resize_with(blocks.len(), || Ok(()));
    let kernel = &kernel;
    {
        let mut jobs: Vec<ScopedJob<'_>> = Vec::with_capacity(blocks.len());
        let mut tail: &mut [f64] = y;
        let mut cursor = 0usize;
        for (b, slot) in blocks.iter().zip(slots.iter_mut()) {
            let b = *b;
            let r1 = row_end(&b);
            let (seg, rest) = tail.split_at_mut(r1 - cursor);
            tail = rest;
            cursor = r1;
            jobs.push(Box::new(move || *slot = kernel(b, seg)));
        }
        pool.scope_run(jobs);
    }
    slots.into_iter().find(|r| r.is_err()).unwrap_or(Ok(()))
}

/// Fan the (right-hand side × block) grid out over the pool; every job
/// writes a disjoint segment of one output vector.
fn run_batch_blocks(
    pool: &ThreadPool,
    blocks: &[Block],
    xs: &[Vec<f64>],
    ys: &mut [Vec<f64>],
    row_end: impl Fn(&Block) -> usize,
    kernel: impl Fn(Block, &[f64], &mut [f64]) -> Result<()> + Send + Sync,
) -> Result<()> {
    let njobs = blocks.len() * xs.len();
    let mut slots: Vec<Result<()>> = Vec::new();
    slots.resize_with(njobs, || Ok(()));
    let kernel = &kernel;
    {
        let mut jobs: Vec<ScopedJob<'_>> = Vec::with_capacity(njobs);
        let mut slot_iter = slots.iter_mut();
        for (x, y) in xs.iter().zip(ys.iter_mut()) {
            let x: &[f64] = x.as_slice();
            let mut tail: &mut [f64] = y;
            let mut cursor = 0usize;
            for b in blocks {
                let b = *b;
                let r1 = row_end(&b);
                let (seg, rest) = tail.split_at_mut(r1 - cursor);
                tail = rest;
                cursor = r1;
                let slot = slot_iter.next().expect("slot per job");
                jobs.push(Box::new(move || *slot = kernel(b, x, seg)));
            }
        }
        pool.scope_run(jobs);
    }
    slots.into_iter().find(|r| r.is_err()).unwrap_or(Ok(()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::format::csr_dtans::EncodeOptions;
    use crate::matrix::gen::structured::{banded, powerlaw_rows};
    use crate::matrix::gen::{assign_values, ValueDist};
    use crate::util::rng::Xoshiro256;

    fn test_matrix(seed: u64) -> Csr {
        let mut rng = Xoshiro256::seeded(seed);
        let mut m = powerlaw_rows(300, 6.0, 1.1, &mut rng);
        assign_values(&mut m, ValueDist::FewDistinct(7), &mut rng);
        m
    }

    #[test]
    fn csr_parallel_matches_serial_bitwise() {
        let m = test_matrix(1);
        let x: Vec<f64> = (0..m.ncols).map(|i| (i as f64 * 0.1).sin()).collect();
        let mut want = vec![0.25; m.nrows];
        super::super::csr::spmv_csr(&m, &x, &mut want).unwrap();
        for strategy in [ParStrategy::Serial, ParStrategy::Fixed(3), ParStrategy::Fixed(16)] {
            let engine = SpmvEngine::new(strategy);
            let mut got = vec![0.25; m.nrows];
            engine.spmv_csr(&m, &x, &mut got).unwrap();
            assert_eq!(got, want, "strategy {strategy:?}");
        }
    }

    #[test]
    fn dtans_parallel_matches_serial_bitwise() {
        let m = test_matrix(2);
        let enc = CsrDtans::encode(&m, &EncodeOptions::default()).unwrap();
        let x: Vec<f64> = (0..m.ncols).map(|i| (i as f64 * 0.07).cos()).collect();
        let mut want = vec![0.0; m.nrows];
        super::super::csr_dtans::spmv_csr_dtans(&enc, &x, &mut want).unwrap();
        let engine = SpmvEngine::new(ParStrategy::Fixed(5));
        let mut got = vec![0.0; m.nrows];
        engine.spmv_csr_dtans(&enc, &x, &mut got).unwrap();
        assert_eq!(got, want);
    }

    #[test]
    fn sell_parallel_matches_serial_bitwise() {
        let m = test_matrix(3);
        let sell = Sell::from_csr(&m, 32);
        let x: Vec<f64> = (0..m.ncols).map(|i| i as f64 * 0.01 - 1.0).collect();
        let mut want = vec![0.0; m.nrows];
        super::super::sell::spmv_sell(&sell, &x, &mut want).unwrap();
        let engine = SpmvEngine::new(ParStrategy::Fixed(4));
        let mut got = vec![0.0; m.nrows];
        engine.spmv_sell(&sell, &x, &mut got).unwrap();
        assert_eq!(got, want);
    }

    #[test]
    fn spmm_matches_repeated_spmv() {
        let m = test_matrix(4);
        let mut rng = Xoshiro256::seeded(5);
        let xs: Vec<Vec<f64>> = (0..5)
            .map(|_| (0..m.ncols).map(|_| rng.next_f64() - 0.5).collect())
            .collect();
        let engine = SpmvEngine::new(ParStrategy::Fixed(4));
        let ys = engine.spmm_csr(&m, &xs).unwrap();
        for (x, y) in xs.iter().zip(&ys) {
            let mut want = vec![0.0; m.nrows];
            super::super::csr::spmv_csr(&m, x, &mut want).unwrap();
            assert_eq!(y, &want);
        }
    }

    #[test]
    fn batch_dim_mismatch_is_error() {
        let m = test_matrix(6);
        let engine = SpmvEngine::serial();
        let xs = vec![vec![0.0; m.ncols], vec![0.0; m.ncols + 1]];
        assert!(engine.spmm_csr(&m, &xs).is_err());
    }

    #[test]
    fn dim_mismatch_is_error_on_parallel_path() {
        let m = test_matrix(7);
        let engine = SpmvEngine::new(ParStrategy::Fixed(4));
        let x = vec![0.0; m.ncols + 1];
        let mut y = vec![0.0; m.nrows];
        assert!(engine.spmv_csr(&m, &x, &mut y).is_err());
    }

    #[test]
    fn empty_matrix_is_fine() {
        let m = Csr::new(0, 0);
        let engine = SpmvEngine::new(ParStrategy::Fixed(4));
        let mut y = Vec::new();
        engine.spmv_csr(&m, &[], &mut y).unwrap();
        assert!(engine.spmm_csr(&m, &[]).unwrap().is_empty());
    }

    #[test]
    fn auto_runs_small_inputs_serially_and_large_in_parallel() {
        // Behavioral check: both paths must give the same (bit-identical)
        // answer regardless of which side of MIN_PAR_COST the input lands.
        let engine = SpmvEngine::auto();
        for n in [100usize, 20_000] {
            let mut m = banded(n, 2);
            assign_values(&mut m, ValueDist::FewDistinct(4), &mut Xoshiro256::seeded(8));
            let x = vec![1.0; m.ncols];
            let mut want = vec![0.0; m.nrows];
            super::super::csr::spmv_csr(&m, &x, &mut want).unwrap();
            let mut got = vec![0.0; m.nrows];
            engine.spmv_csr(&m, &x, &mut got).unwrap();
            assert_eq!(got, want);
        }
    }
}
