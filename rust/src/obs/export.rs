//! Metrics export: Prometheus text exposition and a JSON snapshot.
//!
//! [`prometheus_text`] renders every counter, gauge, and histogram in
//! [`Metrics`] in the Prometheus text exposition format (version 0.0.4):
//! stable `dtans_`-prefixed metric names, `# HELP`/`# TYPE` headers on
//! every family, and `format` / `tenant` / `stage` / `matrix` / `stat`
//! labels where a family breaks out. The name/label contract is
//! documented in `docs/OBSERVABILITY.md` and validated hermetically by
//! `scripts/check_prom.py` (charset, header pairing, monotone cumulative
//! buckets) — run the `observability` example to produce a live
//! exposition to feed it.
//!
//! [`metrics_json`] is the same surface as one JSON object — the benches
//! embed it in their `results/BENCH_*.json` artifacts.
//!
//! Histogram families render the standard cumulative `_bucket{le=...}` /
//! `_sum` / `_count` triplet. The `le` bounds are powers of four: each is
//! a [`LogHistogram`] bucket boundary, so the cumulative counts are exact
//! (`LogHistogram::count_le` is resolution-limited only between
//! boundaries) and monotone by construction.

use crate::coordinator::metrics::Metrics;
use crate::obs::hist::LogHistogram;
use std::fmt::Write as _;
use std::sync::atomic::Ordering;

/// Cumulative-bucket upper bounds (µs for latencies, plain counts for
/// iterations) — powers of four from 1 to ~4.2M, then `+Inf`.
const LE_BOUNDS: [u64; 12] = [
    1,
    4,
    16,
    64,
    256,
    1024,
    4096,
    16384,
    65536,
    262144,
    1_048_576,
    4_194_304,
];

/// Escape a label value per the exposition format (`\`, `"`, newline).
fn escape_label(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            _ => out.push(c),
        }
    }
    out
}

/// One `counter` or `gauge` family with a single unlabeled sample.
fn scalar(out: &mut String, name: &str, kind: &str, help: &str, value: u64) {
    let _ = writeln!(out, "# HELP {name} {help}");
    let _ = writeln!(out, "# TYPE {name} {kind}");
    let _ = writeln!(out, "{name} {value}");
}

/// The bucket/sum/count triplet for one histogram series. `labels` is
/// the rendered label-pair prefix (e.g. `stage="queue_wait"`), empty for
/// unlabeled series.
fn hist_series(out: &mut String, name: &str, labels: &str, h: &LogHistogram) {
    let sep = if labels.is_empty() { "" } else { "," };
    for b in LE_BOUNDS {
        let _ = writeln!(
            out,
            "{name}_bucket{{{labels}{sep}le=\"{b}\"}} {}",
            h.count_le(b)
        );
    }
    let _ = writeln!(out, "{name}_bucket{{{labels}{sep}le=\"+Inf\"}} {}", h.count());
    if labels.is_empty() {
        let _ = writeln!(out, "{name}_sum {}", h.sum());
        let _ = writeln!(out, "{name}_count {}", h.count());
    } else {
        let _ = writeln!(out, "{name}_sum{{{labels}}} {}", h.sum());
        let _ = writeln!(out, "{name}_count{{{labels}}} {}", h.count());
    }
}

/// A histogram family: HELP/TYPE header plus one or more labeled series.
fn hist_family(
    out: &mut String,
    name: &str,
    help: &str,
    series: &[(String, LogHistogram)],
) {
    let _ = writeln!(out, "# HELP {name} {help}");
    let _ = writeln!(out, "# TYPE {name} histogram");
    for (labels, h) in series {
        hist_series(out, name, labels, h);
    }
}

/// Render the full metrics surface in the Prometheus text exposition
/// format. See the module docs for the name/label contract.
pub fn prometheus_text(m: &Metrics) -> String {
    let mut out = String::with_capacity(8192);
    let c = |a: &std::sync::atomic::AtomicU64| a.load(Ordering::Relaxed);

    // Request lifecycle counters (the conservation identity's terms).
    scalar(&mut out, "dtans_requests_submitted_total", "counter",
        "Requests accepted by submit (completed+failed+shed+expired reconciles to this).",
        c(&m.submitted));
    scalar(&mut out, "dtans_requests_completed_total", "counter",
        "Requests completed successfully.", c(&m.completed));
    scalar(&mut out, "dtans_requests_failed_total", "counter",
        "Requests failed in the store or kernel.", c(&m.failed));
    scalar(&mut out, "dtans_requests_shed_total", "counter",
        "Requests shed at admission (queue full, quota, or closed).", c(&m.shed));
    scalar(&mut out, "dtans_requests_quota_rejected_total", "counter",
        "Subset of shed: per-tenant token-bucket rejections.", c(&m.quota_rejected));
    scalar(&mut out, "dtans_requests_expired_total", "counter",
        "Requests whose deadline elapsed before execution.", c(&m.expired));

    // Dispatch / coalescing.
    scalar(&mut out, "dtans_batches_total", "counter",
        "Dispatcher batches executed.", c(&m.batches));
    scalar(&mut out, "dtans_coalesced_batches_total", "counter",
        "Same-matrix batches served by one SpMM engine call.", c(&m.coalesced_batches));
    scalar(&mut out, "dtans_coalesced_requests_total", "counter",
        "Requests served through coalesced batches.", c(&m.coalesced_requests));
    scalar(&mut out, "dtans_queue_depth", "gauge",
        "Admission-queue depth after the most recent submit or dispatch.",
        c(&m.queue_depth));
    scalar(&mut out, "dtans_queue_depth_peak", "gauge",
        "High-water mark of the admission queue.", c(&m.queue_depth_peak));

    // Store counters.
    scalar(&mut out, "dtans_store_hits_total", "counter",
        "Registrations served from the artifact cache.", c(&m.store_hits));
    scalar(&mut out, "dtans_store_misses_total", "counter",
        "Registrations that had to encode.", c(&m.store_misses));
    scalar(&mut out, "dtans_store_evictions_total", "counter",
        "Matrices evicted from residency by the byte budget.", c(&m.evictions));
    scalar(&mut out, "dtans_store_persist_failures_total", "counter",
        "Background artifact persists that failed.", c(&m.persist_failures));
    scalar(&mut out, "dtans_store_cold_loads_total", "counter",
        "Evicted matrices faulted back in from disk.", c(&m.cold_loads));
    scalar(&mut out, "dtans_store_acquires_total", "counter",
        "Successful store pin acquisitions.", c(&m.acquires));

    // Mutation counters (delta overlays + background compaction).
    scalar(&mut out, "dtans_store_deltas_appended_total", "counter",
        "Individual COO update entries appended to mutable matrices.",
        c(&m.deltas_appended));
    scalar(&mut out, "dtans_store_overlay_nnz", "gauge",
        "Entries currently held in RAM-only delta overlays across all matrices.",
        c(&m.overlay_nnz));
    scalar(&mut out, "dtans_store_compactions_total", "counter",
        "Background compactions that swapped in a merged matrix.",
        c(&m.compactions));
    scalar(&mut out, "dtans_store_compaction_failures_total", "counter",
        "Background compactions that failed; the old version stays servable.",
        c(&m.compaction_failures));

    // Solver counters.
    scalar(&mut out, "dtans_solves_total", "counter",
        "Iterative solve attempts through the service.", c(&m.solves));
    scalar(&mut out, "dtans_solves_converged_total", "counter",
        "Solves that reached tolerance.", c(&m.solves_converged));
    scalar(&mut out, "dtans_solves_diverged_total", "counter",
        "Solves that ran but did not converge.", c(&m.solves_diverged));

    // Adaptive routing counters (docs/ROUTING.md).
    scalar(&mut out, "dtans_route_requests_total", "counter",
        "Requests whose route was decided by the adaptive router.",
        c(&m.routed_requests));
    scalar(&mut out, "dtans_route_explore_total", "counter",
        "Subset of routed: requests sent to a non-incumbent arm to gather latency evidence.",
        c(&m.explore_requests));
    scalar(&mut out, "dtans_route_flips_total", "counter",
        "Hysteresis-confirmed incumbent changes committed by the adaptive router.",
        c(&m.route_flips));

    // Tracer health.
    scalar(&mut out, "dtans_trace_events_recorded_total", "counter",
        "Span events recorded by the tracer.", m.tracer().recorded());
    scalar(&mut out, "dtans_trace_events_dropped_total", "counter",
        "Span events lost to ring overwrites.", m.tracer().dropped());

    // Partition imbalance gauge (slowest/mean block of the last timed
    // engine call; 0 before any timed call).
    let _ = writeln!(out,
        "# HELP dtans_block_imbalance_ratio Slowest/mean block micros of the most recent timed engine call.");
    let _ = writeln!(out, "# TYPE dtans_block_imbalance_ratio gauge");
    let _ = writeln!(out, "dtans_block_imbalance_ratio {}", m.block_imbalance());

    // Aggregate latency histogram.
    hist_family(&mut out, "dtans_request_latency_microseconds",
        "End-to-end request latency (submit to response).",
        &[(String::new(), m.latency_histogram())]);

    // Stage durations: queue wait + cold load share one family.
    hist_family(&mut out, "dtans_stage_duration_microseconds",
        "Time spent per pipeline stage.",
        &[
            ("stage=\"queue_wait\"".to_string(), m.queue_wait_histogram()),
            ("stage=\"cold_load\"".to_string(), m.cold_load_histogram()),
        ]);

    // Per-block kernel timing (partition-imbalance evidence).
    hist_family(&mut out, "dtans_kernel_block_microseconds",
        "Per-call block timing from timed engine runs.",
        &[
            ("stat=\"mean\"".to_string(), m.block_mean_histogram()),
            ("stat=\"max\"".to_string(), m.block_max_histogram()),
        ]);

    // Solve iteration counts.
    hist_family(&mut out, "dtans_solve_iterations",
        "Iterations per solve (count units, not micros).",
        &[(String::new(), m.solve_iters_histogram())]);

    // Per-format breakdown: counters + latency histograms.
    let tags = m.format_tags();
    if !tags.is_empty() {
        let _ = writeln!(out,
            "# HELP dtans_format_requests_total Requests by executing kernel format and outcome.");
        let _ = writeln!(out, "# TYPE dtans_format_requests_total counter");
        for tag in &tags {
            if let Some(s) = m.format_summary(tag) {
                let _ = writeln!(out,
                    "dtans_format_requests_total{{format=\"{tag}\",outcome=\"completed\"}} {}",
                    s.completed);
                let _ = writeln!(out,
                    "dtans_format_requests_total{{format=\"{tag}\",outcome=\"failed\"}} {}",
                    s.failed);
            }
        }
        let series: Vec<(String, LogHistogram)> = tags
            .iter()
            .filter_map(|tag| {
                m.format_histogram(tag)
                    .map(|h| (format!("format=\"{tag}\""), h))
            })
            .collect();
        hist_family(&mut out, "dtans_format_latency_microseconds",
            "Request latency by executing kernel format.", &series);
    }

    // Per-tenant admission outcomes.
    let tenants = m.tenant_counts();
    if !tenants.is_empty() {
        let _ = writeln!(out,
            "# HELP dtans_tenant_requests_total Admission outcomes per named tenant.");
        let _ = writeln!(out, "# TYPE dtans_tenant_requests_total counter");
        for (name, admitted, shed) in &tenants {
            let esc = escape_label(name);
            let _ = writeln!(out,
                "dtans_tenant_requests_total{{tenant=\"{esc}\",outcome=\"admitted\"}} {admitted}");
            let _ = writeln!(out,
                "dtans_tenant_requests_total{{tenant=\"{esc}\",outcome=\"shed\"}} {shed}");
        }
    }

    // Paper-headline gauges per dtANS-routed matrix.
    let paper = m.paper_summaries();
    if !paper.is_empty() {
        let _ = writeln!(out,
            "# HELP dtans_matrix_compression_ratio Resident-CSR-equivalent bytes over encoded dtANS bytes.");
        let _ = writeln!(out, "# TYPE dtans_matrix_compression_ratio gauge");
        for p in &paper {
            let _ = writeln!(out,
                "dtans_matrix_compression_ratio{{matrix=\"{}\"}} {:.6}",
                escape_label(&p.name), p.ratio);
        }
        let _ = writeln!(out,
            "# HELP dtans_matrix_decode_bytes_per_second Latest observed dtANS stream decode throughput.");
        let _ = writeln!(out, "# TYPE dtans_matrix_decode_bytes_per_second gauge");
        for p in &paper {
            let _ = writeln!(out,
                "dtans_matrix_decode_bytes_per_second{{matrix=\"{}\"}} {}",
                escape_label(&p.name), p.decode_bps);
        }
    }

    out
}

/// Escape a string for embedding in JSON.
fn escape_json(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out
}

/// One latency-summary object body.
fn summary_json(s: &crate::coordinator::metrics::LatencySummary) -> String {
    format!(
        "{{\"count\":{},\"p50_us\":{},\"p90_us\":{},\"p99_us\":{},\"max_us\":{}}}",
        s.count, s.p50_us, s.p90_us, s.p99_us, s.max_us
    )
}

/// Render the full metrics surface as one JSON object (the benches embed
/// this in their `results/BENCH_*.json` artifacts).
pub fn metrics_json(m: &Metrics) -> String {
    let c = |a: &std::sync::atomic::AtomicU64| a.load(Ordering::Relaxed);
    let mut out = String::with_capacity(2048);
    out.push('{');
    let _ = write!(
        out,
        "\"counters\":{{\"submitted\":{},\"completed\":{},\"failed\":{},\"shed\":{},\
         \"quota_rejected\":{},\"expired\":{},\"batches\":{},\"coalesced_batches\":{},\
         \"coalesced_requests\":{},\"store_hits\":{},\"store_misses\":{},\"evictions\":{},\
         \"persist_failures\":{},\"cold_loads\":{},\"acquires\":{},\
         \"deltas_appended\":{},\"compactions\":{},\"compaction_failures\":{},\
         \"solves\":{},\"solves_converged\":{},\"solves_diverged\":{},\
         \"routed\":{},\"explored\":{},\"route_flips\":{}}}",
        c(&m.submitted), c(&m.completed), c(&m.failed), c(&m.shed),
        c(&m.quota_rejected), c(&m.expired), c(&m.batches), c(&m.coalesced_batches),
        c(&m.coalesced_requests), c(&m.store_hits), c(&m.store_misses), c(&m.evictions),
        c(&m.persist_failures), c(&m.cold_loads), c(&m.acquires),
        c(&m.deltas_appended), c(&m.compactions), c(&m.compaction_failures),
        c(&m.solves), c(&m.solves_converged), c(&m.solves_diverged),
        c(&m.routed_requests), c(&m.explore_requests), c(&m.route_flips),
    );
    let _ = write!(
        out,
        ",\"gauges\":{{\"queue_depth\":{},\"queue_depth_peak\":{},\"overlay_nnz\":{},\
         \"block_imbalance\":{:.3}}}",
        c(&m.queue_depth), c(&m.queue_depth_peak), c(&m.overlay_nnz), m.block_imbalance(),
    );
    let _ = write!(out, ",\"latency_us\":{}", summary_json(&m.latency_summary()));
    let _ = write!(out, ",\"queue_wait_us\":{}", summary_json(&m.queue_wait_summary()));
    let _ = write!(out, ",\"cold_load_us\":{}", summary_json(&m.cold_load_summary()));
    let _ = write!(out, ",\"block_mean_us\":{}", summary_json(&m.block_mean_summary()));
    let _ = write!(out, ",\"block_max_us\":{}", summary_json(&m.block_max_summary()));
    out.push_str(",\"formats\":{");
    for (i, tag) in m.format_tags().iter().enumerate() {
        if let Some(s) = m.format_summary(tag) {
            if i > 0 {
                out.push(',');
            }
            let _ = write!(
                out,
                "\"{tag}\":{{\"completed\":{},\"failed\":{},\"latency\":{}}}",
                s.completed, s.failed, summary_json(&s.latency)
            );
        }
    }
    out.push('}');
    out.push_str(",\"tenants\":{");
    for (i, (name, admitted, shed)) in m.tenant_counts().iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        let _ = write!(
            out,
            "\"{}\":{{\"admitted\":{admitted},\"shed\":{shed}}}",
            escape_json(name)
        );
    }
    out.push('}');
    out.push_str(",\"paper\":[");
    for (i, p) in m.paper_summaries().iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        let _ = write!(
            out,
            "{{\"id\":{},\"name\":\"{}\",\"baseline_bytes\":{},\"encoded_bytes\":{},\
             \"ratio\":{:.4},\"decode_bps\":{},\"decode_samples\":{}}}",
            p.id, escape_json(&p.name), p.baseline_bytes, p.encoded_bytes,
            p.ratio, p.decode_bps, p.decode_samples,
        );
    }
    out.push(']');
    let _ = write!(
        out,
        ",\"trace\":{{\"recorded\":{},\"dropped\":{}}}",
        m.tracer().recorded(), m.tracer().dropped(),
    );
    out.push('}');
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn populated() -> Metrics {
        let m = Metrics::default();
        m.submitted.fetch_add(5, Ordering::Relaxed);
        m.record_format_latency("csr", 120);
        m.record_format_latency("csr_dtans", 480);
        m.record_format_failure("csr");
        m.record_shed(true);
        m.record_expired();
        m.record_queue_wait(30);
        m.record_cold_load_for(2, 9000);
        m.record_block_timing(50, 90, 70);
        m.record_tenant("acme", true);
        m.record_compression(1, "web", 2_000_000, 800_000);
        m.record_decode_rate(1, 1_000_000, 500);
        m
    }

    #[test]
    fn exposition_has_paired_headers_and_stable_names() {
        let m = populated();
        let text = prometheus_text(&m);
        for name in [
            "dtans_requests_submitted_total",
            "dtans_requests_shed_total",
            "dtans_queue_depth",
            "dtans_request_latency_microseconds",
            "dtans_stage_duration_microseconds",
            "dtans_kernel_block_microseconds",
            "dtans_block_imbalance_ratio",
            "dtans_format_requests_total",
            "dtans_tenant_requests_total",
            "dtans_matrix_compression_ratio",
            "dtans_matrix_decode_bytes_per_second",
            "dtans_trace_events_recorded_total",
            "dtans_store_deltas_appended_total",
            "dtans_store_overlay_nnz",
            "dtans_store_compactions_total",
            "dtans_store_compaction_failures_total",
            "dtans_route_requests_total",
            "dtans_route_explore_total",
            "dtans_route_flips_total",
        ] {
            assert!(text.contains(&format!("# HELP {name} ")), "missing HELP {name}");
            assert!(text.contains(&format!("# TYPE {name} ")), "missing TYPE {name}");
        }
        assert!(text.contains("stage=\"queue_wait\""));
        assert!(text.contains("stage=\"cold_load\""));
        assert!(text.contains("format=\"csr_dtans\""));
        assert!(text.contains("tenant=\"acme\",outcome=\"admitted\"} 1"), "{text}");
        assert!(text.contains("matrix=\"web\""));
    }

    #[test]
    fn histogram_buckets_are_cumulative_and_close_with_inf() {
        let m = populated();
        let text = prometheus_text(&m);
        // Pull the aggregate latency buckets and check monotonicity.
        let mut counts = Vec::new();
        for line in text.lines() {
            if let Some(rest) = line.strip_prefix("dtans_request_latency_microseconds_bucket{le=")
            {
                let v: u64 = rest.split_whitespace().last().unwrap().parse().unwrap();
                counts.push(v);
            }
        }
        assert_eq!(counts.len(), LE_BOUNDS.len() + 1);
        assert!(counts.windows(2).all(|w| w[0] <= w[1]), "{counts:?}");
        // +Inf equals _count.
        assert!(text.contains(&format!(
            "dtans_request_latency_microseconds_count {}",
            counts.last().unwrap()
        )));
    }

    #[test]
    fn label_values_are_escaped() {
        let m = Metrics::default();
        m.record_tenant("we\"ird\\name", false);
        let text = prometheus_text(&m);
        assert!(text.contains("tenant=\"we\\\"ird\\\\name\""), "{text}");
    }

    #[test]
    fn json_snapshot_carries_the_same_surface() {
        let m = populated();
        let json = metrics_json(&m);
        assert!(json.starts_with('{') && json.ends_with('}'));
        assert!(json.contains("\"counters\":{\"submitted\":5"));
        assert!(json.contains("\"deltas_appended\":0,\"compactions\":0"));
        assert!(json.contains("\"routed\":0,\"explored\":0,\"route_flips\":0"));
        assert!(json.contains("\"overlay_nnz\":0"));
        assert!(json.contains("\"queue_wait_us\":{\"count\":1"));
        assert!(json.contains("\"csr_dtans\":{\"completed\":1"));
        assert!(json.contains("\"acme\":{\"admitted\":1,\"shed\":0}"));
        assert!(json.contains("\"ratio\":2.5000"));
        assert!(json.contains("\"trace\":{"));
    }
}
