//! The CSR-dtANS compressed matrix format: symbolization with escapes,
//! per-row dtANS encoding, warp interleaving, container + (de)serialization.
//!
//! This is the paper's §IV container. Encoding takes a validated
//! [`crate::matrix::Csr`] through four stages:
//!
//! 1. delta-encode in-row column indices ([`csr_dtans`], §IV-A);
//! 2. symbolize deltas and value bit-patterns against two dictionaries
//!    with escape codes for rare payloads ([`symbolize`], §IV-B);
//! 3. entropy-code each row with dtANS ([`crate::ans::dtans`], Alg. 2);
//! 4. interleave the 32 per-row streams of each warp-sized slice into one
//!    word stream in exact decode order ([`interleave`], §IV-D), so the
//!    lockstep decoder's loads coalesce.
//!
//! [`serialize`] gives the container a stable byte format; the size
//! accounting ([`SizeReport`]) reproduces the paper's Fig. 6 breakdown.
//!
//! Decoding back to CSR ([`CsrDtans::decode_to_csr`]) is exact for f64
//! encodes; SpMVM over the encoded form without decompressing lives in
//! [`crate::spmv`] (serial) and [`crate::spmv::engine`] (parallel).
//!
//! ```
//! use dtans::format::{CsrDtans, EncodeOptions};
//! use dtans::matrix::gen::structured::banded;
//! use dtans::matrix::gen::{assign_values, ValueDist};
//! use dtans::util::rng::Xoshiro256;
//!
//! let mut m = banded(512, 2);
//! assign_values(&mut m, ValueDist::FewDistinct(4), &mut Xoshiro256::seeded(9));
//! let enc = CsrDtans::encode(&m, &EncodeOptions::default()).unwrap();
//! // Lossless roundtrip...
//! assert_eq!(enc.decode_to_csr().unwrap(), m);
//! // ...and the paper's size accounting.
//! let report = enc.size_report();
//! assert_eq!(
//!     report.total,
//!     report.header + report.tables + report.dicts + report.stream
//!         + report.row_lens + report.slice_offsets + report.escapes
//!         + report.escape_offsets
//! );
//! ```

pub mod csr_dtans;
pub mod interleave;
pub mod serialize;
pub mod symbolize;

pub use csr_dtans::{CsrDtans, EncodeOptions, SizeReport, WARP};
pub use symbolize::{Domain, SymbolPicker};
