//! nnz-balanced work partitioning.
//!
//! The paper assigns one warp per 32-row slice; throughput then depends on
//! the *nonzeros* (equivalently, stream words) each warp owns, not the row
//! count — the same observation behind row-grouped CSR (Oberhuber et al.,
//! arXiv:1012.2270) and nmSPARSE's balanced partitions. This module
//! reproduces that assignment on the CPU: given a monotone cost-prefix
//! array (from [`SpmvOperator::cost_prefix`] — CSR's `row_ptr`, a slice
//! word-offset table, SELL's `slice_ptr`), it binary-searches for split
//! points that give every block an equal share of the total cost.
//!
//! Blocks are contiguous, disjoint, and cover every unit exactly once, so
//! a parallel executor can hand each block a disjoint `&mut` range of the
//! output vector and each row is still computed by exactly one serial
//! kernel invocation — which is what makes the parallel results
//! *bit-identical* to the serial ones (see `tests/engine_parallel.rs`).
//!
//! The per-format wrappers (`partition_csr`/`partition_sell`/
//! `partition_dtans`) are gone: formats describe their own costs through
//! [`SpmvOperator::cost_prefix`] and the engine partitions generically.
//!
//! [`SpmvOperator::cost_prefix`]: crate::spmv::operator::SpmvOperator::cost_prefix

/// One contiguous block of work units (rows or slices).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Block {
    /// First unit (inclusive).
    pub start: usize,
    /// Last unit (exclusive).
    pub end: usize,
    /// Total cost of the block (`prefix[end] - prefix[start]`).
    pub cost: usize,
}

impl Block {
    /// Number of units in the block.
    pub fn len(&self) -> usize {
        self.end - self.start
    }

    /// True when the block spans no units (never produced by the
    /// partitioner; useful for callers building blocks by hand).
    pub fn is_empty(&self) -> bool {
        self.start == self.end
    }
}

/// Split `prefix.len() - 1` work units into at most `parts` contiguous
/// blocks of near-equal cost.
///
/// `prefix` is a monotone non-decreasing cost prefix over the units
/// (`prefix[i+1] - prefix[i]` = cost of unit `i`), e.g. CSR's `row_ptr`.
/// For each split `p`, the boundary is the first unit index whose prefix
/// reaches `total * p / parts` — a binary search (`partition_point`),
/// mirroring the paper's equal-nonzeros warp assignment.
///
/// Guarantees (property-tested in `tests/engine_parallel.rs`):
///
/// * blocks are non-empty, contiguous, in ascending order, and cover
///   `0..units` exactly;
/// * block costs sum to `prefix[units] - prefix[0]`;
/// * every block's cost is at most `ceil(total / parts)` plus the largest
///   single-unit cost (a single unit is never split).
///
/// Edge cases are handled here, not by callers (unit-tested below):
///
/// * an **empty matrix** — a prefix with no units (`[x]`) or even a fully
///   empty slice — yields no blocks;
/// * **`parts > units`** yields exactly `units` single-unit blocks, and
///   `parts == 0` is treated as 1;
/// * an **all-zero prefix** (every row empty) still covers every unit, so
///   zero-cost rows keep their well-defined owner block.
///
/// ```
/// use dtans::spmv::engine::partition_prefix;
/// // 4 rows with 2, 8, 1, 1 nonzeros: the two-way split lands right
/// // after the heavy row (first boundary whose prefix reaches the
/// // 6-nonzeros target), not at the midpoint row count.
/// let blocks = partition_prefix(&[0, 2, 10, 11, 12], 2);
/// assert_eq!(blocks.len(), 2);
/// assert_eq!((blocks[0].start, blocks[0].end, blocks[0].cost), (0, 2, 10));
/// assert_eq!((blocks[1].start, blocks[1].end, blocks[1].cost), (2, 4, 2));
/// ```
pub fn partition_prefix(prefix: &[usize], parts: usize) -> Vec<Block> {
    partition_prefix_by(prefix, |&v| v, parts)
}

/// Generic core of [`partition_prefix`]: `cost_of` projects each stored
/// offset to its `usize` cost, so narrower offset tables (e.g. the `u32`
/// slice offsets of CSR-dtANS in `spmv_csr_dtans_parallel`) partition
/// without a widening copy.
pub(crate) fn partition_prefix_by<T>(
    prefix: &[T],
    cost_of: impl Fn(&T) -> usize,
    parts: usize,
) -> Vec<Block> {
    debug_assert!(
        prefix.windows(2).all(|w| cost_of(&w[0]) <= cost_of(&w[1])),
        "prefix not monotone"
    );
    if prefix.len() <= 1 {
        return Vec::new(); // empty matrix (or empty prefix): no work units
    }
    let units = prefix.len() - 1;
    let parts = parts.clamp(1, units);
    let base = cost_of(&prefix[0]);
    let total = cost_of(&prefix[units]) - base;
    let mut blocks = Vec::with_capacity(parts);
    let mut start = 0usize;
    for p in 1..=parts {
        if start == units {
            break;
        }
        let end = if p == parts {
            units
        } else {
            let target = base + ((total as u128 * p as u128) / parts as u128) as usize;
            // First unit boundary at or past the target cost; forced to
            // advance at least one unit so every block is non-empty.
            prefix
                .partition_point(|v| cost_of(v) < target)
                .clamp(start + 1, units)
        };
        blocks.push(Block {
            start,
            end,
            cost: cost_of(&prefix[end]) - cost_of(&prefix[start]),
        });
        start = end;
    }
    blocks
}

#[cfg(test)]
mod tests {
    use super::*;

    fn assert_valid(blocks: &[Block], prefix: &[usize], parts: usize) {
        let units = prefix.len().saturating_sub(1);
        if units == 0 {
            assert!(blocks.is_empty());
            return;
        }
        let total = prefix[units] - prefix[0];
        assert!(!blocks.is_empty());
        assert!(blocks.len() <= parts.clamp(1, units));
        assert_eq!(blocks[0].start, 0);
        assert_eq!(blocks.last().unwrap().end, units);
        let max_unit = prefix.windows(2).map(|w| w[1] - w[0]).max().unwrap();
        let mut expect_start = 0;
        let mut cost_sum = 0;
        for b in blocks {
            assert_eq!(b.start, expect_start, "blocks not contiguous");
            assert!(b.end > b.start, "empty block");
            assert_eq!(b.cost, prefix[b.end] - prefix[b.start]);
            assert!(
                b.cost <= total.div_ceil(parts.clamp(1, units)) + max_unit,
                "unbalanced block {b:?} (total {total}, parts {parts})"
            );
            expect_start = b.end;
            cost_sum += b.cost;
        }
        assert_eq!(cost_sum, total);
    }

    #[test]
    fn uniform_costs_split_evenly() {
        let prefix: Vec<usize> = (0..=100).map(|i| i * 5).collect();
        for parts in [1, 2, 3, 4, 7, 16, 100] {
            let blocks = partition_prefix(&prefix, parts);
            assert_eq!(blocks.len(), parts.min(100));
            assert_valid(&blocks, &prefix, parts);
        }
    }

    #[test]
    fn skewed_costs_balance_by_cost_not_rows() {
        // One huge row at the front: it must sit alone in the first block.
        let prefix = vec![0, 1000, 1001, 1002, 1003, 1004];
        let blocks = partition_prefix(&prefix, 2);
        assert_valid(&blocks, &prefix, 2);
        assert_eq!(blocks[0], Block { start: 0, end: 1, cost: 1000 });
        assert_eq!(blocks[1], Block { start: 1, end: 5, cost: 4 });
    }

    #[test]
    fn all_zero_prefix_still_covers_every_unit() {
        // All-empty rows: every unit must land in some block even though
        // every split target is 0.
        let prefix = vec![0usize; 9]; // 8 rows, 0 nnz
        for parts in 1..=16 {
            let blocks = partition_prefix(&prefix, parts);
            assert_valid(&blocks, &prefix, parts);
            assert_eq!(blocks.last().unwrap().end, 8);
        }
        // Nonzero base with zero total (offset slice of a larger prefix).
        let offset = vec![7usize; 4];
        assert_valid(&partition_prefix(&offset, 2), &offset, 2);
    }

    #[test]
    fn more_parts_than_units_yields_one_block_per_unit() {
        let prefix = vec![0, 3, 7];
        let blocks = partition_prefix(&prefix, 16);
        assert_valid(&blocks, &prefix, 16);
        assert_eq!(blocks.len(), 2);
        assert!(blocks.iter().all(|b| b.len() == 1));
    }

    #[test]
    fn zero_parts_is_treated_as_one() {
        let prefix = vec![0, 3, 7];
        let blocks = partition_prefix(&prefix, 0);
        assert_eq!(blocks.len(), 1);
        assert_eq!(blocks[0], Block { start: 0, end: 2, cost: 7 });
    }

    #[test]
    fn empty_matrix_yields_no_blocks() {
        // No units (the empty-matrix prefix `[0]`), a bare offset, and
        // even a fully empty prefix: all explicitly legal, all empty.
        assert!(partition_prefix(&[0], 4).is_empty());
        assert!(partition_prefix(&[42], 1).is_empty());
        assert!(partition_prefix(&[], 3).is_empty());
    }

    #[test]
    fn row_ptr_prefix_conserves_nnz() {
        use crate::matrix::coo::Coo;
        use crate::matrix::csr::Csr;
        let mut coo = Coo::new(4, 4);
        for &(r, c) in &[(0, 0), (0, 1), (1, 0), (1, 1), (1, 2), (2, 0), (3, 3)] {
            coo.push(r, c, 1.0);
        }
        let m = Csr::from_coo(&coo);
        let blocks = partition_prefix(&m.row_ptr, 2);
        assert_valid(&blocks, &m.row_ptr, 2);
        assert_eq!(blocks.iter().map(|b| b.cost).sum::<usize>(), m.nnz());
    }
}
