//! Minimal command-line argument parser (clap is not in the vendored set).
//!
//! Supports `program subcommand --flag --key value positional ...` with
//! typed accessors and generated usage text.

use std::collections::BTreeMap;

/// Parsed arguments: a subcommand, `--key value` options, `--flag`
/// booleans, and positional arguments.
#[derive(Debug, Default, Clone)]
pub struct Args {
    /// First non-flag token (if declared as a subcommand grammar).
    pub subcommand: Option<String>,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    /// Remaining positional arguments.
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an explicit token list. Tokens beginning with `--` become
    /// flags or key/value options depending on whether the next token also
    /// begins with `--` (or is absent).
    pub fn parse_from<I: IntoIterator<Item = String>>(tokens: I, expect_subcommand: bool) -> Args {
        let mut a = Args::default();
        let toks: Vec<String> = tokens.into_iter().collect();
        let mut i = 0;
        while i < toks.len() {
            let t = &toks[i];
            if let Some(name) = t.strip_prefix("--") {
                // `--key=value` is unambiguous; `--name tok` treats `tok` as
                // the value unless it starts with `--`.
                if let Some((k, v)) = name.split_once('=') {
                    a.opts.insert(k.to_string(), v.to_string());
                    i += 1;
                    continue;
                }
                let is_kv = i + 1 < toks.len() && !toks[i + 1].starts_with("--");
                if is_kv {
                    a.opts.insert(name.to_string(), toks[i + 1].clone());
                    i += 2;
                } else {
                    a.flags.push(name.to_string());
                    i += 1;
                }
            } else if expect_subcommand && a.subcommand.is_none() {
                a.subcommand = Some(t.clone());
                i += 1;
            } else {
                a.positional.push(t.clone());
                i += 1;
            }
        }
        a
    }

    /// Parse from `std::env::args()` (skipping argv[0]).
    pub fn from_env(expect_subcommand: bool) -> Args {
        Args::parse_from(std::env::args().skip(1), expect_subcommand)
    }

    /// True if `--name` was given as a bare flag.
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// String option `--name value`.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    /// String option with default.
    pub fn get_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    /// Typed option parse with default; panics with a helpful message on
    /// malformed input (CLI boundary, so panic is the right UX).
    pub fn get_parse_or<T: std::str::FromStr>(&self, name: &str, default: T) -> T {
        match self.get(name) {
            None => default,
            Some(s) => s
                .parse::<T>()
                .unwrap_or_else(|_| panic!("--{name}: cannot parse {s:?}")),
        }
    }

    /// usize option.
    pub fn usize_or(&self, name: &str, default: usize) -> usize {
        self.get_parse_or(name, default)
    }

    /// u64 option.
    pub fn u64_or(&self, name: &str, default: u64) -> u64 {
        self.get_parse_or(name, default)
    }

    /// f64 option.
    pub fn f64_or(&self, name: &str, default: f64) -> f64 {
        self.get_parse_or(name, default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(s: &str) -> Vec<String> {
        s.split_whitespace().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_opts_flags_positional() {
        let a = Args::parse_from(toks("encode input.mtx --k=4096 --verbose"), true);
        assert_eq!(a.subcommand.as_deref(), Some("encode"));
        assert_eq!(a.get("k"), Some("4096"));
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["input.mtx"]);
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = Args::parse_from(toks("--a --b v --c"), false);
        assert!(a.flag("a"));
        assert_eq!(a.get("b"), Some("v"));
        assert!(a.flag("c"));
    }

    #[test]
    fn typed_defaults() {
        let a = Args::parse_from(toks("run --n 128"), true);
        assert_eq!(a.usize_or("n", 1), 128);
        assert_eq!(a.usize_or("m", 7), 7);
        assert_eq!(a.f64_or("p", 0.5), 0.5);
    }
}
