//! Log-bucketed mergeable histograms (HDR-style) for latency and
//! iteration-count distributions.
//!
//! Replaces the fixed-size sliding sample rings that [`crate::coordinator::metrics::Metrics`]
//! used through PR 6. A ring caps memory but *windows* the data: quantiles
//! were computed over the most recent 64k samples only, so a long-running
//! service forgot its warm-up tail and a burst could evict the whole
//! history it was supposed to be compared against. The histogram keeps
//! **every** sample (exact `count`, `sum`, `min`, `max` — no reservoir
//! bias) in constant memory by bucketing values logarithmically:
//!
//! * values below [`LINEAR_MAX`] (= 2^([`MANTISSA_BITS`]+1) = 128) land in
//!   exact unit-width buckets — small values (iteration counts, µs-scale
//!   latencies) lose nothing;
//! * larger values keep their exponent plus the top [`MANTISSA_BITS`]
//!   mantissa bits, i.e. each power-of-two octave is split into 2^6 = 64
//!   linear sub-buckets, bounding the worst-case relative quantile error
//!   at 2^-(MANTISSA_BITS+1) ≈ 0.78% — comfortably inside the 2% budget
//!   the observability tests enforce.
//!
//! Histograms are mergeable (`merge` is bucket-wise addition), so
//! per-thread or per-shard instances can be combined without resorting
//! raw samples.

/// Mantissa bits kept per sample above the linear range. 6 bits → 64
/// sub-buckets per octave → ≤0.78% relative error.
pub const MANTISSA_BITS: u32 = 6;

/// Values below this are bucketed exactly (unit-width buckets).
pub const LINEAR_MAX: u64 = 1 << (MANTISSA_BITS + 1);

/// Sub-buckets per power-of-two octave above the linear range.
const SUB: usize = 1 << MANTISSA_BITS;

/// Octaves above the linear range (`u64` exponents 7..=63).
const OCTAVES: usize = 64 - (MANTISSA_BITS as usize + 1);

/// Total bucket count: 128 exact + 57 octaves × 64 sub-buckets.
pub const NBUCKETS: usize = LINEAR_MAX as usize + OCTAVES * SUB;

/// A log-bucketed histogram over `u64` samples with exact count/sum/
/// min/max and ≤0.78% relative quantile error. Memory is a fixed
/// `NBUCKETS × 8` bytes (~30 KiB) regardless of sample count.
#[derive(Debug, Clone)]
pub struct LogHistogram {
    counts: Box<[u64; NBUCKETS]>,
    count: u64,
    sum: u128,
    min: u64,
    max: u64,
}

impl Default for LogHistogram {
    fn default() -> Self {
        LogHistogram::new()
    }
}

/// Bucket index for a value.
fn index_of(v: u64) -> usize {
    if v < LINEAR_MAX {
        v as usize
    } else {
        let h = 63 - v.leading_zeros(); // exponent, >= MANTISSA_BITS + 1
        let sub = ((v >> (h - MANTISSA_BITS)) as usize) & (SUB - 1);
        LINEAR_MAX as usize + (h as usize - (MANTISSA_BITS as usize + 1)) * SUB + sub
    }
}

/// Lowest value mapping to bucket `i`.
fn bucket_low(i: usize) -> u64 {
    if i < LINEAR_MAX as usize {
        i as u64
    } else {
        let oct = (i - LINEAR_MAX as usize) / SUB;
        let sub = ((i - LINEAR_MAX as usize) % SUB) as u64;
        let h = oct as u32 + MANTISSA_BITS + 1;
        (1u64 << h) + (sub << (h - MANTISSA_BITS))
    }
}

/// Representative value reported for bucket `i` (its midpoint; exact for
/// the unit-width linear buckets).
fn representative(i: usize) -> u64 {
    if i < LINEAR_MAX as usize {
        i as u64
    } else {
        let oct = (i - LINEAR_MAX as usize) / SUB;
        let h = oct as u32 + MANTISSA_BITS + 1;
        let width = 1u64 << (h - MANTISSA_BITS);
        bucket_low(i) + width / 2
    }
}

impl LogHistogram {
    /// An empty histogram.
    pub fn new() -> LogHistogram {
        LogHistogram {
            counts: Box::new([0u64; NBUCKETS]),
            count: 0,
            sum: 0,
            min: u64::MAX,
            max: 0,
        }
    }

    /// Record one sample.
    pub fn record(&mut self, v: u64) {
        self.counts[index_of(v)] += 1;
        self.count += 1;
        self.sum += v as u128;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    /// Total samples recorded (exact — no window, no reservoir).
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Sum of all samples (exact).
    pub fn sum(&self) -> u128 {
        self.sum
    }

    /// Smallest sample, or 0 if empty.
    pub fn min(&self) -> u64 {
        if self.count == 0 {
            0
        } else {
            self.min
        }
    }

    /// Largest sample, or 0 if empty.
    pub fn max(&self) -> u64 {
        self.max
    }

    /// Mean sample, or 0.0 if empty.
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Quantile `p` in `[0, 1]`: the smallest bucket representative `r`
    /// such that at least `ceil(p · count)` samples fell in buckets at or
    /// below `r`'s. Clamped into `[min, max]`; 0 when empty. Relative
    /// error vs the exact sorted quantile is bounded by the bucket
    /// half-width (≤0.78%).
    pub fn quantile(&self, p: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = ((p * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            cum += c;
            if cum >= target {
                return representative(i).clamp(self.min, self.max);
            }
        }
        self.max
    }

    /// Number of samples with value ≤ `bound`, at bucket resolution: a
    /// sample counts iff its bucket's representative is ≤ `bound`. Exact
    /// whenever `bound` is a bucket boundary (e.g. a power of two ≥ 128,
    /// or any value < 128). Monotone in `bound` by construction — the
    /// property Prometheus cumulative buckets need.
    pub fn count_le(&self, bound: u64) -> u64 {
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            if c > 0 && representative(i) <= bound {
                cum += c;
            }
        }
        cum
    }

    /// Bucket-wise merge of `other` into `self` (exact: merging then
    /// querying equals querying the concatenated sample streams).
    pub fn merge(&mut self, other: &LogHistogram) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
        self.count += other.count;
        self.sum += other.sum;
        if other.count > 0 {
            self.min = self.min.min(other.min);
            self.max = self.max.max(other.max);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;

    #[test]
    fn small_values_are_exact() {
        let mut h = LogHistogram::new();
        for v in 0..LINEAR_MAX {
            h.record(v);
        }
        assert_eq!(h.count(), LINEAR_MAX);
        for v in 0..LINEAR_MAX {
            assert_eq!(index_of(v), v as usize);
            assert_eq!(representative(index_of(v)), v);
        }
        assert_eq!(h.min(), 0);
        assert_eq!(h.max(), LINEAR_MAX - 1);
    }

    #[test]
    fn bucket_low_inverts_index_of() {
        // bucket_low(i) must itself map to bucket i, and the next bucket's
        // low must be strictly greater — the buckets tile the range.
        for i in 0..NBUCKETS {
            assert_eq!(index_of(bucket_low(i)), i, "bucket {i}");
            if i + 1 < NBUCKETS {
                assert!(bucket_low(i + 1) > bucket_low(i));
            }
        }
        // Spot-check boundaries around the linear/log transition.
        assert_eq!(index_of(LINEAR_MAX - 1), LINEAR_MAX as usize - 1);
        assert_eq!(index_of(LINEAR_MAX), LINEAR_MAX as usize);
        assert_eq!(index_of(u64::MAX), NBUCKETS - 1);
    }

    #[test]
    fn quantiles_match_exact_sorted_within_bound() {
        let mut rng = Xoshiro256::seeded(7);
        let mut h = LogHistogram::new();
        let mut exact: Vec<u64> = Vec::new();
        for _ in 0..10_000 {
            // Log-uniform-ish spread over ~6 decades.
            let e = rng.next_u64() % 20;
            let v = (rng.next_u64() % 1000) << e;
            h.record(v);
            exact.push(v);
        }
        exact.sort_unstable();
        for &p in &[0.5, 0.9, 0.99] {
            let approx = h.quantile(p) as f64;
            let idx = ((p * exact.len() as f64).ceil() as usize).clamp(1, exact.len()) - 1;
            let truth = exact[idx] as f64;
            let rel = (approx - truth).abs() / truth.max(1.0);
            assert!(rel <= 0.02, "p{p}: approx {approx} vs exact {truth} (rel {rel})");
        }
        assert_eq!(h.count() as usize, exact.len());
        assert_eq!(h.max(), *exact.last().unwrap());
        assert_eq!(h.min(), exact[0]);
    }

    #[test]
    fn merge_equals_concatenation() {
        let mut a = LogHistogram::new();
        let mut b = LogHistogram::new();
        let mut both = LogHistogram::new();
        for v in [3u64, 900, 1 << 20, 7, 7, 1 << 33] {
            a.record(v);
            both.record(v);
        }
        for v in [1u64, 1 << 40, 55] {
            b.record(v);
            both.record(v);
        }
        a.merge(&b);
        assert_eq!(a.count(), both.count());
        assert_eq!(a.sum(), both.sum());
        assert_eq!(a.min(), both.min());
        assert_eq!(a.max(), both.max());
        for &p in &[0.25, 0.5, 0.75, 0.99] {
            assert_eq!(a.quantile(p), both.quantile(p));
        }
    }

    #[test]
    fn count_le_is_monotone_and_total() {
        let mut h = LogHistogram::new();
        for v in [1u64, 10, 100, 1000, 10_000, 100_000, 1_000_000] {
            h.record(v);
        }
        let bounds = [0u64, 1, 64, 128, 1 << 10, 1 << 14, 1 << 20, u64::MAX];
        let mut prev = 0;
        for &b in &bounds {
            let c = h.count_le(b);
            assert!(c >= prev, "count_le not monotone at {b}");
            prev = c;
        }
        assert_eq!(h.count_le(u64::MAX), h.count());
        assert_eq!(h.count_le(0), 0);
        // Exact below the linear range.
        assert_eq!(h.count_le(1), 1);
        assert_eq!(h.count_le(100), 3);
    }

    #[test]
    fn empty_histogram_is_all_zeros() {
        let h = LogHistogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.quantile(0.5), 0);
        assert_eq!(h.min(), 0);
        assert_eq!(h.max(), 0);
        assert_eq!(h.mean(), 0.0);
    }
}
