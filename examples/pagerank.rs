//! PageRank and power iteration over the solver subsystem — the
//! "repeated application of one sparse operator" workload where encoding
//! the matrix once and decoding it on every multiply is at its best.
//!
//! Builds a scale-free web graph, derives its column-stochastic
//! transition matrix P (edge u→v contributes `P[v][u] = 1/outdeg(u)`),
//! and runs PageRank over both plain CSR and CSR-dtANS operators: same
//! `solver::pagerank` call, different format behind the trait. Each
//! PageRank step is a single fused `run_axpby` (`x' = d·P·x + (1−d)/n`
//! with the teleport pre-filled), so iterations allocate nothing.
//!
//! Run: `cargo run --release --example pagerank`

use dtans::format::csr_dtans::{CsrDtans, EncodeOptions};
use dtans::matrix::gen::{gen_graph_csr, GraphModel};
use dtans::matrix::{Coo, Csr};
use dtans::solver::{pagerank_with, power_iteration_with, SolverConfig};
use dtans::spmv::engine::SpmvEngine;
use dtans::spmv::operator::{DtansOperator, SpmvOperator};
use dtans::util::rng::Xoshiro256;

/// Column-stochastic transition matrix of a directed graph given as an
/// adjacency CSR (entry (u, v) = edge u→v): P[v][u] = 1 / outdeg(u).
/// Dangling nodes (no out-edges) keep an all-zero column — they leak
/// rank mass to the teleport term, as in the classic formulation.
fn transition_matrix(adj: &Csr) -> Csr {
    let n = adj.nrows;
    let mut coo = Coo::new(n, n);
    for u in 0..n {
        let lo = adj.row_ptr[u];
        let hi = adj.row_ptr[u + 1];
        let outdeg = (hi - lo) as f64;
        for k in lo..hi {
            coo.push(adj.cols[k], u as u32, 1.0 / outdeg);
        }
    }
    Csr::from_coo(&coo)
}

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let mut rng = Xoshiro256::seeded(11);
    let adj = gen_graph_csr(GraphModel::BarabasiAlbert, 20_000, 8.0, &mut rng);
    let p = transition_matrix(&adj);
    println!(
        "web graph: {} nodes, {} edges -> transition matrix {} nnz",
        adj.nrows,
        adj.nnz(),
        p.nnz()
    );

    let enc = CsrDtans::encode(&p, &EncodeOptions::default())?;
    println!(
        "transition matrix: CSR {} KB -> CSR-dtANS {} KB ({:.2}x)",
        p.size_bytes_f64() / 1024,
        enc.size_report().total / 1024,
        p.size_bytes_f64() as f64 / enc.size_report().total as f64
    );
    let dtans_op = DtansOperator::new(enc);

    let engine = SpmvEngine::auto();
    let cfg = SolverConfig { tol: 1e-10, max_iters: 500, ..Default::default() };
    let ops: [(&str, &dyn SpmvOperator); 2] = [("CSR", &p), ("CSR-dtANS", &dtans_op)];
    let mut ranks = Vec::new();
    for (name, op) in ops {
        let sol = pagerank_with(&engine, op, 0.85, &cfg)?;
        let r = &sol.report;
        println!(
            "pagerank/{name:<10} {} in {} iters in {:.3}s ({:.3} ms/iter, {:.0}% in SpMVM)",
            if r.converged() { "converged" } else { "stopped" },
            r.iterations,
            r.total_secs,
            r.total_secs / r.iterations.max(1) as f64 * 1e3,
            100.0 * r.spmv_secs / r.total_secs.max(1e-12),
        );
        ranks.push(sol.x);
    }
    // Both formats rank the same pages on top.
    let top = |x: &[f64]| {
        let mut idx: Vec<usize> = (0..x.len()).collect();
        idx.sort_by(|&a, &b| x[b].partial_cmp(&x[a]).unwrap());
        idx.truncate(5);
        idx
    };
    let (t_csr, t_dt) = (top(&ranks[0]), top(&ranks[1]));
    println!("top-5 pages (CSR):       {t_csr:?}");
    println!("top-5 pages (CSR-dtANS): {t_dt:?}");
    assert_eq!(t_csr, t_dt, "formats must agree on the ranking");

    // Bonus: the dominant eigenvalue of the symmetric adjacency structure
    // via power iteration on the same engine.
    let sym = gen_graph_csr(GraphModel::ErdosRenyi, 5_000, 10.0, &mut rng);
    let eig = power_iteration_with(
        &engine,
        &sym,
        None,
        &SolverConfig { tol: 1e-8, max_iters: 2000, ..Default::default() },
    )?;
    println!(
        "power iteration on a {}-node graph: dominant |eigenvalue| ~ {:.4} after {} iters",
        sym.nrows, eig.eigenvalue, eig.report.iterations
    );
    println!("OK");
    Ok(())
}
