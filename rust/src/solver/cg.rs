//! Conjugate gradient over any [`SpmvOperator`] — the classic Krylov
//! solver for symmetric positive-definite systems (Hestenes–Stiefel),
//! with one fused [`run_axpby`](crate::spmv::engine::SpmvEngine::run_axpby)
//! multiply per iteration.

use super::{check_square, dot, initial_x, norm2, Solution, SolveReport, SolverConfig, Termination};
use crate::spmv::engine::SpmvEngine;
use crate::spmv::operator::SpmvOperator;
use crate::util::error::Result;
use std::time::Instant;

/// Solve `A·x = b` by conjugate gradient, building a fresh engine from
/// [`SolverConfig::par`]. `A` must be symmetric positive-definite; a
/// violation surfaces as [`Termination::Breakdown`] (`p·Ap ≤ 0`).
///
/// Convergence is declared when `‖r‖₂ / ‖b‖₂ ≤ tol`; the report records
/// that relative residual after every iteration.
///
/// ```
/// use dtans::matrix::gen::structured::tridiagonal;
/// use dtans::solver::{cg, SolverConfig};
///
/// let a = tridiagonal(32); // SPD: 2 on the diagonal, -1 off it
/// let b = vec![1.0; 32];
/// let sol = cg(&a, &b, &SolverConfig::default()).unwrap();
/// assert!(sol.report.converged());
/// assert!(sol.report.final_residual() <= 1e-10);
/// // The iterate really solves the system.
/// let mut ax = vec![0.0; 32];
/// dtans::spmv::spmv_csr(&a, &sol.x, &mut ax).unwrap();
/// assert!(ax.iter().zip(&b).all(|(l, r)| (l - r).abs() < 1e-8));
/// ```
pub fn cg(op: &dyn SpmvOperator, b: &[f64], cfg: &SolverConfig) -> Result<Solution> {
    cg_with(&SpmvEngine::new(cfg.par), op, b, None, cfg)
}

/// [`cg`] on an existing engine, with an optional initial guess `x0`
/// (zeros when `None`). This is the entry point the service uses so every
/// solve shares one engine (and its thread pool) instead of spawning a
/// pool per solve.
///
/// ```
/// use dtans::matrix::gen::structured::tridiagonal;
/// use dtans::solver::{cg_with, SolverConfig};
/// use dtans::spmv::engine::SpmvEngine;
///
/// let a = tridiagonal(16);
/// let b: Vec<f64> = (0..16).map(|i| (i as f64 * 0.3).cos()).collect();
/// let engine = SpmvEngine::serial();
/// let cfg = SolverConfig::default();
/// let from_zero = cg_with(&engine, &a, &b, None, &cfg).unwrap();
/// // Warm-starting from a 1e-10 answer converges immediately at 1e-6
/// // (0 iterations: the true residual of the guess is already below tol).
/// let warm_cfg = SolverConfig { tol: 1e-6, ..cfg };
/// let warm = cg_with(&engine, &a, &b, Some(&from_zero.x), &warm_cfg).unwrap();
/// assert!(warm.report.converged());
/// assert_eq!(warm.report.iterations, 0);
/// ```
pub fn cg_with(
    engine: &SpmvEngine,
    op: &dyn SpmvOperator,
    b: &[f64],
    x0: Option<&[f64]>,
    cfg: &SolverConfig,
) -> Result<Solution> {
    let n = check_square(op, b.len())?;
    let t_total = Instant::now();
    let mut spmv_secs = 0.0;
    let mut vector_secs = 0.0;

    let mut x = initial_x(n, x0)?;
    let mut r = b.to_vec();
    if x0.is_some() {
        // r = b - A·x0, fused.
        let t = Instant::now();
        engine.run_axpby(op, &x, -1.0, 1.0, &mut r)?;
        spmv_secs += t.elapsed().as_secs_f64();
    }

    let bnorm = norm2(b);
    let mut residuals = Vec::new();
    let done = |termination, iterations, residuals: Vec<f64>, x, spmv_secs, vector_secs| {
        Ok(Solution {
            x,
            report: SolveReport {
                termination,
                iterations,
                residuals,
                spmv_secs,
                vector_secs,
                total_secs: t_total.elapsed().as_secs_f64(),
            },
        })
    };
    if bnorm == 0.0 {
        // b = 0: x = 0 is the exact answer.
        return done(Termination::Converged, 0, residuals, vec![0.0; n], spmv_secs, vector_secs);
    }
    let mut rs = dot(&r, &r);
    if rs.sqrt() <= cfg.tol * bnorm {
        // The initial guess already satisfies the tolerance.
        return done(Termination::Converged, 0, residuals, x, spmv_secs, vector_secs);
    }

    let mut p = r.clone();
    let mut ap = vec![0.0; n];
    let mut termination = Termination::MaxIters;
    let mut iterations = 0;
    for _ in 0..cfg.max_iters {
        let t = Instant::now();
        // ap = A·p: the only allocation-free multiply of the iteration.
        engine.run_axpby(op, &p, 1.0, 0.0, &mut ap)?;
        spmv_secs += t.elapsed().as_secs_f64();

        let t = Instant::now();
        let pap = dot(&p, &ap);
        if pap <= 0.0 {
            // Not SPD (or numerically indefinite): stop rather than step.
            termination = Termination::Breakdown;
            vector_secs += t.elapsed().as_secs_f64();
            break;
        }
        let alpha = rs / pap;
        for i in 0..n {
            x[i] += alpha * p[i];
            r[i] -= alpha * ap[i];
        }
        let rs_new = dot(&r, &r);
        iterations += 1;
        let rel = rs_new.sqrt() / bnorm;
        residuals.push(rel);
        if rel <= cfg.tol {
            termination = Termination::Converged;
            vector_secs += t.elapsed().as_secs_f64();
            break;
        }
        let beta = rs_new / rs;
        rs = rs_new;
        for i in 0..n {
            p[i] = r[i] + beta * p[i];
        }
        vector_secs += t.elapsed().as_secs_f64();
    }
    done(termination, iterations, residuals, x, spmv_secs, vector_secs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::gen::structured::{stencil2d5, tridiagonal};
    use crate::spmv::spmv_csr;

    #[test]
    fn solves_poisson_to_tight_tolerance() {
        let a = stencil2d5(16, 16);
        let b: Vec<f64> = (0..a.nrows).map(|i| ((i as f64) * 0.11).sin()).collect();
        let sol = cg(&a, &b, &SolverConfig::default()).unwrap();
        assert!(sol.report.converged(), "{:?}", sol.report.termination);
        assert!(sol.report.final_residual() <= 1e-10);
        assert_eq!(sol.report.residuals.len(), sol.report.iterations);
        let mut ax = vec![0.0; a.nrows];
        spmv_csr(&a, &sol.x, &mut ax).unwrap();
        for (l, r) in ax.iter().zip(&b) {
            assert!((l - r).abs() < 1e-8);
        }
    }

    #[test]
    fn residual_history_is_monotone_enough_and_recorded() {
        let a = tridiagonal(64);
        let b = vec![1.0; 64];
        let sol = cg(&a, &b, &SolverConfig::default()).unwrap();
        assert!(sol.report.iterations > 0);
        // CG's recurrence residual ends below tol.
        assert!(*sol.report.residuals.last().unwrap() <= 1e-10);
        assert!(sol.report.total_secs >= sol.report.spmv_secs);
    }

    #[test]
    fn non_spd_breaks_down_instead_of_lying() {
        // -A is negative definite: p·Ap < 0 on the very first step.
        let mut a = tridiagonal(8);
        for v in &mut a.vals {
            *v = -*v;
        }
        let sol = cg(&a, &[1.0; 8], &SolverConfig::default()).unwrap();
        assert_eq!(sol.report.termination, Termination::Breakdown);
    }

    #[test]
    fn zero_rhs_returns_zero_immediately() {
        let a = tridiagonal(6);
        let sol = cg(&a, &[0.0; 6], &SolverConfig::default()).unwrap();
        assert!(sol.report.converged());
        assert_eq!(sol.report.iterations, 0);
        assert_eq!(sol.x, vec![0.0; 6]);
    }

    #[test]
    fn max_iters_terminates_without_convergence() {
        let a = stencil2d5(16, 16);
        let b = vec![1.0; a.nrows];
        let cfg = SolverConfig { max_iters: 2, ..Default::default() };
        let sol = cg(&a, &b, &cfg).unwrap();
        assert_eq!(sol.report.termination, Termination::MaxIters);
        assert_eq!(sol.report.iterations, 2);
    }
}
