//! A small fixed-size thread pool with a shared work queue.
//!
//! Used by the SpMV engine ([`crate::spmv::engine`]), the coordinator's
//! worker pool and the evaluation harness to parallelize over corpus
//! matrices (tokio/rayon are not available offline).
//!
//! Two submission APIs exist:
//!
//! * [`ThreadPool::execute`] / [`ThreadPool::par_map`] take `'static` jobs
//!   (owned data only) — the classic fire-and-forget queue.
//! * [`ThreadPool::scope_run`] takes *borrowing* jobs and blocks until all
//!   of them have finished, so jobs may capture `&`/`&mut` references to
//!   the caller's stack (the same contract as `std::thread::scope`, but on
//!   pooled threads with no per-call spawn cost). This is what lets the
//!   SpMV engine hand each worker a disjoint `&mut` slice of the output
//!   vector without copying.

use std::cell::Cell;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Unique id per pool, so a worker can recognize its own pool (0 = not a
/// pool worker). Used by [`ThreadPool::scope_run`] to detect reentrancy.
static NEXT_POOL_ID: AtomicU64 = AtomicU64::new(1);

thread_local! {
    static CURRENT_POOL: Cell<u64> = const { Cell::new(0) };
}

/// A borrowing job for [`ThreadPool::scope_run`]: may capture non-`'static`
/// references; guaranteed to have finished when `scope_run` returns.
pub type ScopedJob<'env> = Box<dyn FnOnce() + Send + 'env>;

/// Fixed-size thread pool; jobs are `FnOnce()` closures.
///
/// `&ThreadPool` can be shared across threads (`ThreadPool: Sync`, via
/// `mpsc::Sender: Sync` on Rust >= 1.72) — the coordinator's workers all
/// submit through one shared engine pool.
pub struct ThreadPool {
    id: u64,
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
    pending: Arc<AtomicUsize>,
}

/// Completion latch for one `scope_run` call: counts jobs down and records
/// whether any of them panicked.
struct Latch {
    remaining: Mutex<usize>,
    done: Condvar,
    panicked: AtomicBool,
}

/// Decrements the latch when a scoped job finishes — including by panic
/// (`Drop` runs during unwinding), so `scope_run` can never deadlock on a
/// panicking job.
struct LatchGuard(Arc<Latch>);

impl Drop for LatchGuard {
    fn drop(&mut self) {
        if std::thread::panicking() {
            self.0.panicked.store(true, Ordering::SeqCst);
        }
        let mut remaining = self.0.remaining.lock().unwrap();
        *remaining -= 1;
        if *remaining == 0 {
            self.0.done.notify_all();
        }
    }
}

impl ThreadPool {
    /// Spawn `n` worker threads (at least 1).
    pub fn new(n: usize) -> Self {
        let n = n.max(1);
        let id = NEXT_POOL_ID.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let pending = Arc::new(AtomicUsize::new(0));
        let workers = (0..n)
            .map(|_| {
                let rx = Arc::clone(&rx);
                let pending = Arc::clone(&pending);
                std::thread::spawn(move || {
                    CURRENT_POOL.with(|c| c.set(id));
                    loop {
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match job {
                            Ok(job) => {
                                // Contain panicking jobs: the worker
                                // survives and the pending counter stays
                                // exact, so `wait_idle`/`par_map` cannot
                                // hang afterwards. `scope_run` re-raises
                                // via its latch; a bare `execute` panic
                                // surfaces through `par_map`'s
                                // missing-result check instead.
                                let _ = std::panic::catch_unwind(
                                    std::panic::AssertUnwindSafe(job),
                                );
                                pending.fetch_sub(1, Ordering::SeqCst);
                            }
                            Err(_) => break,
                        }
                    }
                })
            })
            .collect();
        ThreadPool {
            id,
            tx: Some(tx),
            workers,
            pending,
        }
    }

    /// Number of worker threads in this pool.
    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// Number of logical CPUs (fallback 4).
    pub fn default_parallelism() -> usize {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
    }

    /// Submit a job. A panicking job is contained in its worker (see
    /// [`ThreadPool::scope_run`] for the variant that re-raises).
    pub fn execute(&self, job: impl FnOnce() + Send + 'static) {
        self.pending.fetch_add(1, Ordering::SeqCst);
        self.tx.as_ref().unwrap().send(Box::new(job)).unwrap();
    }

    /// Busy-wait (with yields) until all submitted jobs completed.
    pub fn wait_idle(&self) {
        while self.pending.load(Ordering::SeqCst) != 0 {
            std::thread::yield_now();
        }
    }

    /// Run borrowed jobs to completion on the pool (a scoped fan-out).
    ///
    /// Blocks until every job has finished; only then do the `'env` borrows
    /// captured by the jobs go out of use, which is what makes the internal
    /// lifetime extension sound. Panics (after all jobs have settled) if
    /// any job panicked.
    ///
    /// Multiple threads may call `scope_run` on one shared pool
    /// concurrently; each call waits only for its own jobs. A *reentrant*
    /// call — from a job already running on this same pool — executes its
    /// jobs inline on the calling worker instead (queueing them would
    /// deadlock behind the blocked caller on a saturated pool).
    pub fn scope_run<'env>(&self, jobs: Vec<ScopedJob<'env>>) {
        if jobs.is_empty() {
            return;
        }
        if CURRENT_POOL.with(|c| c.get()) == self.id {
            for job in jobs {
                job();
            }
            return;
        }
        let latch = Arc::new(Latch {
            remaining: Mutex::new(jobs.len()),
            done: Condvar::new(),
            panicked: AtomicBool::new(false),
        });
        for job in jobs {
            // SAFETY: the loop below blocks until the latch reports every
            // job has finished executing (the guard decrements on normal
            // completion AND on panic), so no job — and therefore no `'env`
            // borrow it captured — outlives this call. The pool itself
            // cannot be dropped mid-call because `&self` is borrowed.
            let job: Box<dyn FnOnce() + Send + 'static> = unsafe { std::mem::transmute(job) };
            let latch = Arc::clone(&latch);
            self.execute(move || {
                let _guard = LatchGuard(latch);
                job();
            });
        }
        let mut remaining = latch.remaining.lock().unwrap();
        while *remaining != 0 {
            remaining = latch.done.wait(remaining).unwrap();
        }
        drop(remaining);
        assert!(
            !latch.panicked.load(Ordering::SeqCst),
            "a scoped thread-pool job panicked"
        );
    }

    /// Parallel map over an indexed range, preserving order.
    pub fn par_map<T, F>(&self, n: usize, f: F) -> Vec<T>
    where
        T: Send + 'static,
        F: Fn(usize) -> T + Send + Sync + 'static,
    {
        let f = Arc::new(f);
        let results: Arc<Mutex<Vec<Option<T>>>> =
            Arc::new(Mutex::new((0..n).map(|_| None).collect()));
        for i in 0..n {
            let f = Arc::clone(&f);
            let results = Arc::clone(&results);
            self.execute(move || {
                let v = f(i);
                results.lock().unwrap()[i] = Some(v);
            });
        }
        self.wait_idle();
        Arc::try_unwrap(results)
            .ok()
            .expect("results still shared")
            .into_inner()
            .unwrap()
            .into_iter()
            .map(|x| x.expect("job did not run"))
            .collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn par_map_order_preserved() {
        let pool = ThreadPool::new(4);
        let out = pool.par_map(100, |i| i * i);
        assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn wait_idle_completes() {
        let pool = ThreadPool::new(2);
        let ctr = Arc::new(AtomicUsize::new(0));
        for _ in 0..50 {
            let c = Arc::clone(&ctr);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(ctr.load(Ordering::SeqCst), 50);
    }

    #[test]
    fn scope_run_borrows_stack_data() {
        let pool = ThreadPool::new(4);
        let mut out = vec![0usize; 64];
        let input: Vec<usize> = (0..64).collect();
        {
            let mut jobs: Vec<ScopedJob<'_>> = Vec::new();
            let mut tail: &mut [usize] = &mut out;
            let mut chunk_start = 0usize;
            while !tail.is_empty() {
                let take = tail.len().min(10);
                let (seg, rest) = tail.split_at_mut(take);
                tail = rest;
                let src = &input[chunk_start..chunk_start + take];
                jobs.push(Box::new(move || {
                    for (o, &i) in seg.iter_mut().zip(src) {
                        *o = i * 3;
                    }
                }));
                chunk_start += take;
            }
            pool.scope_run(jobs);
        }
        assert_eq!(out, (0..64).map(|i| i * 3).collect::<Vec<_>>());
    }

    #[test]
    fn scope_run_reentrant_from_own_worker_runs_inline() {
        // A job on a 1-worker pool calling scope_run on that same pool
        // must complete (inline) rather than deadlock behind itself.
        let pool = Arc::new(ThreadPool::new(1));
        let (tx, rx) = mpsc::channel();
        let p2 = Arc::clone(&pool);
        pool.execute(move || {
            let mut vals = [0u32; 4];
            let jobs: Vec<ScopedJob<'_>> = vals
                .iter_mut()
                .enumerate()
                .map(|(i, v)| Box::new(move || *v = i as u32 + 1) as ScopedJob<'_>)
                .collect();
            p2.scope_run(jobs);
            tx.send(vals).unwrap();
        });
        let got = rx
            .recv_timeout(std::time::Duration::from_secs(30))
            .expect("reentrant scope_run deadlocked");
        assert_eq!(got, [1, 2, 3, 4]);
    }

    #[test]
    fn scope_run_panicking_job_reraises_and_pool_survives() {
        let pool = ThreadPool::new(1);
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.scope_run(vec![Box::new(|| panic!("boom")) as ScopedJob<'_>]);
        }));
        assert!(caught.is_err(), "scope_run must re-raise job panics");
        // The single worker must still be alive and the counter exact.
        let mut out = [0u8; 1];
        pool.scope_run(vec![Box::new(|| out[0] = 7) as ScopedJob<'_>]);
        assert_eq!(out[0], 7);
        pool.wait_idle(); // must not hang
    }

    #[test]
    fn scope_run_empty_is_noop() {
        let pool = ThreadPool::new(1);
        pool.scope_run(Vec::new());
    }

    #[test]
    fn scope_run_concurrent_callers() {
        let pool = Arc::new(ThreadPool::new(4));
        let handles: Vec<_> = (0..4)
            .map(|t| {
                let pool = Arc::clone(&pool);
                std::thread::spawn(move || {
                    let mut acc = vec![0u64; 8];
                    let jobs: Vec<ScopedJob<'_>> = acc
                        .iter_mut()
                        .enumerate()
                        .map(|(i, slot)| {
                            Box::new(move || *slot = (t * 100 + i) as u64) as ScopedJob<'_>
                        })
                        .collect();
                    pool.scope_run(jobs);
                    acc
                })
            })
            .collect();
        for (t, h) in handles.into_iter().enumerate() {
            let acc = h.join().unwrap();
            assert_eq!(acc, (0..8).map(|i| (t * 100 + i) as u64).collect::<Vec<_>>());
        }
    }
}
