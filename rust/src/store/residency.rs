//! Memory-budgeted residency tracking: which registered matrices are held
//! in RAM, at what byte cost, and which get evicted when the budget is
//! exceeded.
//!
//! [`ResidencyManager`] is a plain data structure (no interior locking —
//! the store wraps it in its own mutex) tracking one slot per registered
//! id with:
//!
//! * an optional **resident** payload (`Arc<T>`) plus its byte cost;
//! * a **pin count** — pinned slots are never evicted, which is how
//!   in-flight requests keep the matrix they are multiplying alive;
//! * an **evictable** flag — a slot only becomes evictable once its
//!   on-disk artifact exists, since eviction would otherwise lose data;
//! * a **last-use clock** for LRU victim selection.
//!
//! [`ResidencyManager::enforce`] evicts cold (unpinned, evictable)
//! residents in least-recently-used order until the total resident bytes
//! fit the budget. The budget is deliberately *soft* at the edges: a slot
//! that is pinned or not yet persisted is skipped, so a burst of pinned
//! working set can exceed the budget transiently and is trimmed back on
//! the next unpin.
//!
//! The manager is generic over the resident payload so its eviction logic
//! is unit-testable without building real matrices.

use std::collections::HashMap;
use std::sync::Arc;

/// One tracked id's residency state.
#[derive(Debug)]
struct Slot<T> {
    resident: Option<Arc<T>>,
    cost: u64,
    pins: u32,
    evictable: bool,
    last_use: u64,
}

/// Aggregate residency numbers (see [`ResidencyManager::stats`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ResidencyStats {
    /// Tracked ids (resident or cold).
    pub tracked: usize,
    /// Ids currently resident.
    pub resident: usize,
    /// Sum of resident byte costs.
    pub resident_bytes: u64,
    /// Configured budget, if any.
    pub budget_bytes: Option<u64>,
}

/// LRU residency manager under an optional byte budget.
#[derive(Debug)]
pub struct ResidencyManager<T> {
    budget: Option<u64>,
    clock: u64,
    resident_bytes: u64,
    slots: HashMap<u64, Slot<T>>,
}

impl<T> ResidencyManager<T> {
    /// New manager; `budget` of `None` means nothing is ever evicted.
    pub fn new(budget: Option<u64>) -> ResidencyManager<T> {
        ResidencyManager {
            budget,
            clock: 0,
            resident_bytes: 0,
            slots: HashMap::new(),
        }
    }

    /// Start tracking `id` (cold, unpinned, not yet evictable). No-op if
    /// already tracked.
    pub fn track(&mut self, id: u64) {
        self.slots.entry(id).or_insert(Slot {
            resident: None,
            cost: 0,
            pins: 0,
            evictable: false,
            last_use: 0,
        });
    }

    /// Is `id` tracked (registered) at all?
    pub fn is_tracked(&self, id: u64) -> bool {
        self.slots.contains_key(&id)
    }

    /// Is `id` currently resident?
    pub fn is_resident(&self, id: u64) -> bool {
        self.slots.get(&id).is_some_and(|s| s.resident.is_some())
    }

    /// Pin `id`: it cannot be evicted until the matching [`Self::unpin`].
    pub fn pin(&mut self, id: u64) {
        if let Some(s) = self.slots.get_mut(&id) {
            s.pins += 1;
        }
    }

    /// Release one pin on `id`.
    pub fn unpin(&mut self, id: u64) {
        if let Some(s) = self.slots.get_mut(&id) {
            s.pins = s.pins.saturating_sub(1);
        }
    }

    /// Current pin count of `id` (0 if untracked).
    pub fn pins(&self, id: u64) -> u32 {
        self.slots.get(&id).map_or(0, |s| s.pins)
    }

    /// Mark `id` as safe to evict (its on-disk artifact exists).
    pub fn mark_evictable(&mut self, id: u64) {
        if let Some(s) = self.slots.get_mut(&id) {
            s.evictable = true;
        }
    }

    /// Revoke `id`'s evictability. Used when a resident matrix gains
    /// RAM-only state its artifact does not capture — a delta overlay
    /// ([`crate::delta`]) lives only in memory until compaction persists a
    /// merged artifact, so evicting the entry would lose the appended
    /// updates.
    pub fn mark_unevictable(&mut self, id: u64) {
        if let Some(s) = self.slots.get_mut(&id) {
            s.evictable = false;
        }
    }

    /// Fetch `id`'s resident payload, bumping its LRU clock.
    pub fn get(&mut self, id: u64) -> Option<Arc<T>> {
        self.clock += 1;
        let clock = self.clock;
        let s = self.slots.get_mut(&id)?;
        s.last_use = clock;
        s.resident.clone()
    }

    /// Make `id` resident at `cost` bytes (tracking it first if needed),
    /// then enforce the budget. Returns the ids evicted to make room.
    pub fn insert(&mut self, id: u64, payload: Arc<T>, cost: u64) -> Vec<u64> {
        self.track(id);
        self.clock += 1;
        let clock = self.clock;
        let s = self.slots.get_mut(&id).expect("tracked above");
        if s.resident.is_some() {
            self.resident_bytes -= s.cost;
        }
        s.resident = Some(payload);
        s.cost = cost;
        s.last_use = clock;
        self.resident_bytes += cost;
        self.enforce()
    }

    /// Evict LRU (unpinned, evictable) residents until the budget fits or
    /// no victim remains. Returns the evicted ids.
    pub fn enforce(&mut self) -> Vec<u64> {
        let mut evicted = Vec::new();
        let Some(budget) = self.budget else {
            return evicted;
        };
        while self.resident_bytes > budget {
            let victim = self
                .slots
                .iter()
                .filter(|(_, s)| s.resident.is_some() && s.pins == 0 && s.evictable)
                .min_by_key(|(_, s)| s.last_use)
                .map(|(&id, _)| id);
            match victim {
                Some(id) => {
                    self.evict(id);
                    evicted.push(id);
                }
                None => break,
            }
        }
        evicted
    }

    /// Forcibly drop `id`'s resident payload regardless of budget (still
    /// refuses pinned or non-evictable slots). Returns whether it evicted.
    pub fn evict(&mut self, id: u64) -> bool {
        match self.slots.get_mut(&id) {
            Some(s) if s.resident.is_some() && s.pins == 0 && s.evictable => {
                s.resident = None;
                self.resident_bytes -= s.cost;
                s.cost = 0;
                true
            }
            _ => false,
        }
    }

    /// Aggregate numbers.
    pub fn stats(&self) -> ResidencyStats {
        ResidencyStats {
            tracked: self.slots.len(),
            resident: self.slots.values().filter(|s| s.resident.is_some()).count(),
            resident_bytes: self.resident_bytes,
            budget_bytes: self.budget,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mgr(budget: u64) -> ResidencyManager<&'static str> {
        ResidencyManager::new(Some(budget))
    }

    fn insert(m: &mut ResidencyManager<&'static str>, id: u64, cost: u64) -> Vec<u64> {
        m.track(id);
        m.mark_evictable(id);
        m.insert(id, Arc::new("payload"), cost)
    }

    #[test]
    fn evicts_lru_first_when_over_budget() {
        let mut m = mgr(250);
        assert!(insert(&mut m, 1, 100).is_empty());
        assert!(insert(&mut m, 2, 100).is_empty());
        // Touch 1 so 2 becomes the LRU.
        assert!(m.get(1).is_some());
        let evicted = insert(&mut m, 3, 100);
        assert_eq!(evicted, vec![2]);
        assert!(m.is_resident(1) && !m.is_resident(2) && m.is_resident(3));
        assert_eq!(m.stats().resident_bytes, 200);
    }

    #[test]
    fn pinned_entries_survive_any_pressure() {
        let mut m = mgr(50);
        m.track(1);
        m.mark_evictable(1);
        m.pin(1);
        assert!(m.insert(1, Arc::new("a"), 100).is_empty()); // over budget but pinned
        assert!(insert(&mut m, 2, 100).contains(&2) || !m.is_resident(2));
        assert!(m.is_resident(1));
        m.unpin(1);
        assert_eq!(m.enforce(), vec![1]);
        assert!(!m.is_resident(1));
        assert_eq!(m.stats().resident_bytes, 0);
    }

    #[test]
    fn non_evictable_entries_are_skipped() {
        let mut m = mgr(50);
        m.track(1);
        // Not marked evictable: no artifact on disk yet.
        assert!(m.insert(1, Arc::new("a"), 100).is_empty());
        assert!(m.is_resident(1));
        m.mark_evictable(1);
        assert_eq!(m.enforce(), vec![1]);
    }

    #[test]
    fn unevictable_mark_revokes_and_restores() {
        let mut m = mgr(50);
        m.track(1);
        m.mark_evictable(1);
        m.mark_unevictable(1);
        assert!(m.insert(1, Arc::new("a"), 100).is_empty());
        assert!(m.is_resident(1), "unevictable entries survive the budget");
        assert!(!m.evict(1), "manual evict must refuse too");
        m.mark_evictable(1);
        assert_eq!(m.enforce(), vec![1]);
        assert!(!m.is_resident(1));
    }

    #[test]
    fn unbudgeted_never_evicts() {
        let mut m: ResidencyManager<&'static str> = ResidencyManager::new(None);
        for id in 0..16 {
            m.track(id);
            m.mark_evictable(id);
            assert!(m.insert(id, Arc::new("x"), u64::MAX / 32).is_empty());
        }
        assert_eq!(m.stats().resident, 16);
    }

    #[test]
    fn reinsert_replaces_cost_without_double_count() {
        let mut m = mgr(1000);
        insert(&mut m, 1, 400);
        insert(&mut m, 1, 100);
        assert_eq!(m.stats().resident_bytes, 100);
        assert_eq!(m.stats().resident, 1);
    }

    #[test]
    fn manual_evict_respects_pins() {
        let mut m = mgr(1000);
        insert(&mut m, 1, 10);
        m.pin(1);
        assert!(!m.evict(1));
        m.unpin(1);
        assert!(m.evict(1));
        assert!(!m.evict(1)); // already cold
    }
}
