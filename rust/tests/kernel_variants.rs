//! Tier-1 suite for the vectorized range kernels: every
//! [`KernelVariant`] × every built-in format × partition counts 1..=16
//! must be **bit-identical** to the same variant's serial run, and every
//! variant's serial result must stay within the oracle's closeness bound
//! of the scalar CSR ground truth — including tail rows shorter than the
//! lane width, empty rows, and the 31/32/33 warp-slice-boundary fixtures.
//! Plus the reassociation negative control: a deliberately wrong combine
//! order must be caught by the per-format bit-identity oracle.

use dtans::format::csr_dtans::EncodeOptions;
use dtans::matrix::coo::Coo;
use dtans::matrix::csr::Csr;
use dtans::matrix::gen::structured::{banded, powerlaw_rows, stencil2d5};
use dtans::matrix::gen::{assign_values, gen_graph_csr, GraphModel, ValueDist};
use dtans::spmv::engine::{KernelVariant, ParStrategy, SpmvEngine};
use dtans::spmv::operator::FormatRegistry;
use dtans::testkit::oracle::{self, MismatchKind, MiscombinedOperator, OracleConfig};
use dtans::testkit::{seeded_vector, zoo};
use dtans::util::propcheck::{assert_close, check, Ctx};
use std::sync::Arc;

/// Random sparse matrix mixing graph and structured families — the same
/// palette the operator-dispatch suite uses, so empty rows (power-law,
/// Erdős–Rényi) and short rows (narrow bands) both occur naturally.
fn random_csr(ctx: &mut Ctx) -> Csr {
    let n = 1 + ctx.rng.below_usize(ctx.size.max(1));
    let mut m = match ctx.rng.below(4) {
        0 => gen_graph_csr(GraphModel::ErdosRenyi, n.max(4), 4.0, &mut ctx.rng),
        1 => powerlaw_rows(n.max(4), 5.0, 1.1, &mut ctx.rng),
        2 => banded(n.max(2), 1 + ctx.rng.below_usize(4)),
        _ => {
            let side = 2 + ctx.rng.below_usize((n as f64).sqrt() as usize + 2);
            stencil2d5(side, side)
        }
    };
    let dist = match ctx.rng.below(3) {
        0 => ValueDist::FewDistinct(6),
        1 => ValueDist::Gaussian,
        _ => ValueDist::Quantized(64),
    };
    assign_values(&mut m, dist, &mut ctx.rng);
    m
}

/// The central variant contract, property-tested: for every built-in
/// format and every kernel variant, each partition count in 1..=16 is
/// bit-identical to the *same variant's* serial run, and the variant's
/// serial run is close (oracle metric) to the scalar serial CSR kernel.
#[test]
fn prop_variants_bit_identical_across_partitions_and_close_to_scalar() {
    check("kernel-variants-bitident", 10, 90, |ctx: &mut Ctx| {
        let m = random_csr(ctx);
        let opts = EncodeOptions::default();
        let x: Vec<f64> = (0..m.ncols).map(|_| ctx.rng.next_f64() - 0.5).collect();

        // Scalar serial CSR ground truth for the closeness level.
        let mut want = vec![0.0; m.nrows];
        dtans::spmv::spmv_csr(&m, &x, &mut want).map_err(|e| e.to_string())?;

        for (tag, op) in FormatRegistry::builtin().build_all(&m, &opts) {
            let op = op.map_err(|e| format!("{tag}: build failed: {e}"))?;
            for variant in KernelVariant::ALL {
                let mut own = vec![0.0; m.nrows];
                SpmvEngine::serial()
                    .with_kernel_variant(variant)
                    .run(op.as_ref(), &x, &mut own)
                    .map_err(|e| format!("{tag}/{}: {e}", variant.label()))?;
                assert_close(&own, &want, 1e-9, 1e-12)
                    .map_err(|e| format!("{tag}/{}: not close to scalar CSR: {e}", variant.label()))?;
                for parts in 1..=16usize {
                    let engine =
                        SpmvEngine::new(ParStrategy::Fixed(parts)).with_kernel_variant(variant);
                    let mut got = vec![0.0; m.nrows];
                    engine
                        .run(op.as_ref(), &x, &mut got)
                        .map_err(|e| format!("{tag}/{}: {e}", variant.label()))?;
                    if got.iter().zip(&own).any(|(a, b)| a.to_bits() != b.to_bits()) {
                        return Err(format!(
                            "{tag}/{}: parts={parts} not bit-identical to serial",
                            variant.label()
                        ));
                    }
                }
            }
        }
        Ok(())
    });
}

/// The 31/32/33 warp-slice-boundary fixtures from the pathological zoo,
/// swept through the full format × variant × partition cross-product.
#[test]
fn slice_boundary_fixtures_conform_under_all_variants() {
    let cfg = OracleConfig { max_parts: 16, ..Default::default() };
    let registry = FormatRegistry::builtin();
    let fixtures: Vec<_> = zoo::pathological()
        .into_iter()
        .filter(|f| f.name.starts_with("slice-boundary-"))
        .collect();
    assert_eq!(fixtures.len(), 3, "expected the 31/32/33 trio");
    for f in fixtures {
        let report = oracle::cross_check_with(&f.csr, &cfg, &registry, &KernelVariant::ALL)
            .unwrap_or_else(|e| panic!("{}: oracle errored: {e}", f.name));
        assert!(report.is_conformant(), "{}: {report}", f.name);
        assert_eq!(report.strategies, 3 * 17, "{}", f.name); // 3 variants x (serial + 1..=16)
    }
}

/// Hand-built worst case for the unrolled tails: every row length from 0
/// (empty) through 9 — all shorter than, equal to, and one past both lane
/// widths (4 and 8) — must agree bitwise across partitions for every
/// variant, and stay close to scalar CSR.
#[test]
fn short_and_empty_rows_stay_exact_under_unrolled_variants() {
    let nrows = 10usize;
    let ncols = 16usize;
    let mut coo = Coo::new(nrows, ncols);
    for r in 0..nrows as u32 {
        for j in 0..r {
            // Row r has exactly r elements (row 0 is empty).
            coo.push(r, (j * 3 + r) % ncols as u32, (r as f64 + 1.0) / (j as f64 + 2.0));
        }
    }
    let m = Csr::from_coo(&coo);
    let x = seeded_vector(ncols, 0xBEEF);
    let mut want = vec![0.0; nrows];
    dtans::spmv::spmv_csr(&m, &x, &mut want).unwrap();

    for (tag, op) in FormatRegistry::builtin().build_all(&m, &EncodeOptions::default()) {
        let op = op.expect(tag);
        for variant in KernelVariant::ALL {
            let mut own = vec![0.0; nrows];
            SpmvEngine::serial().with_kernel_variant(variant).run(op.as_ref(), &x, &mut own).unwrap();
            assert_close(&own, &want, 1e-12, 1e-12)
                .unwrap_or_else(|e| panic!("{tag}/{}: {e}", variant.label()));
            for parts in 1..=16usize {
                let engine = SpmvEngine::new(ParStrategy::Fixed(parts)).with_kernel_variant(variant);
                let mut got = vec![0.0; nrows];
                engine.run(op.as_ref(), &x, &mut got).unwrap();
                assert_eq!(
                    got.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    own.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    "{tag}/{} parts={parts}",
                    variant.label()
                );
            }
        }
    }
}

/// Negative control: a kernel whose *partitioned* runs use a deliberately
/// wrong combine order (reverse-element sequential folds) must be flagged
/// by the level-2 bit-identity oracle as partition divergence — under the
/// scalar variant and under the unrolled variants alike.
#[test]
fn wrong_combine_order_is_caught_by_the_bit_identity_oracle() {
    let mut m = banded(200, 4);
    assign_values(&mut m, ValueDist::Gaussian, &mut dtans::util::rng::Xoshiro256::seeded(11));
    let cfg = OracleConfig::default();

    // Precondition (so the control can't silently go vacuous): under the
    // oracle's own input vector, at least one row's forward and reverse
    // sequential folds must differ bitwise.
    let x = seeded_vector(m.ncols, cfg.seed);
    let differs = (0..m.nrows).any(|r| {
        let (lo, hi) = (m.row_ptr[r], m.row_ptr[r + 1]);
        let fwd = (lo..hi).fold(0.0f64, |acc, k| acc + m.vals[k] * x[m.cols[k] as usize]);
        let rev = (lo..hi).rev().fold(0.0f64, |acc, k| acc + m.vals[k] * x[m.cols[k] as usize]);
        fwd.to_bits() != rev.to_bits()
    });
    assert!(differs, "fixture too tame: reverse fold never changes a bit");

    let bad = MiscombinedOperator::new(Arc::new(m.clone()));
    let report = oracle::check_operator_with(&bad, &m, &cfg, &KernelVariant::ALL).unwrap();
    assert!(!report.is_conformant(), "wrong combine order went undetected");
    // Every mismatch is a level-2 partition divergence on a genuinely
    // partitioned run; the serial/full-block runs stay clean.
    for mm in &report.mismatches {
        assert_eq!(mm.kind, MismatchKind::ParallelDivergence, "{mm}");
        assert!(mm.parts >= 2, "{mm}");
        assert!(mm.ulps >= 1, "{mm}");
    }
    // The scalar variant must be among the catches (the operator ignores
    // variant dispatch, so all three variants report the same drift).
    assert!(report.mismatches.iter().any(|mm| mm.variant == KernelVariant::Scalar));
    assert_eq!(report.mismatches.len(), 3 * 7); // 3 variants x parts 2..=8
}
