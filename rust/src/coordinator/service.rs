//! The SpMVM service: matrix registry + request batcher + worker pool,
//! executing over the parallel SpMV engine.
//!
//! Requests `(matrix_id, x)` are queued; a dispatcher groups consecutive
//! requests to the same matrix into batches (amortizing plan lookups and
//! keeping the decode tables hot, the same motivation as GPU batching).
//! Singleton batches run as jobs on a worker pool; multi-request batches
//! take the SpMM fast path — one multi-RHS engine call for the whole
//! batch, fanning the (request × row-block) grid across the engine's
//! threads. Either way the kernel work routes through a shared
//! [`SpmvEngine`] whose [`ParStrategy`] comes from [`ServiceConfig::par`]
//! (`ParStrategy::Serial` restores the old one-thread-per-request
//! behavior). Responses are delivered over per-request channels.
//! Everything is std-thread based.

use super::metrics::Metrics;
use super::router::{FormatChoice, RoutePolicy};
use crate::format::csr_dtans::{CsrDtans, EncodeOptions};
use crate::matrix::csr::Csr;
use crate::spmv::csr_dtans::DecodePlan;
use crate::spmv::engine::{ParStrategy, SpmvEngine};
use crate::util::error::{DtansError, Result};
use std::collections::HashMap;
use std::sync::atomic::Ordering;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex, RwLock};
use std::time::Instant;

/// A registered matrix with its routed execution state.
pub struct LoadedMatrix {
    /// Human-readable name.
    pub name: String,
    /// The CSR original (kept for the CSR route and for re-encoding).
    pub csr: Arc<Csr>,
    /// The encoded form.
    pub enc: Arc<CsrDtans>,
    /// Prebuilt decode plan (symbol lookup tables).
    pub plan: Arc<DecodePlan>,
    /// Routed format.
    pub choice: FormatChoice,
}

/// One SpMVM request.
struct Request {
    matrix: u64,
    x: Vec<f64>,
    submitted: Instant,
    resp: Sender<Result<Vec<f64>>>,
}

/// Service configuration.
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    /// Worker threads (request-level parallelism for singleton batches).
    pub workers: usize,
    /// Max requests fused into one batch.
    pub max_batch: usize,
    /// Encoding options for registered matrices.
    pub encode: EncodeOptions,
    /// Routing policy.
    pub policy: RoutePolicy,
    /// Kernel-level parallelism: the [`ParStrategy`] of the shared
    /// [`SpmvEngine`] every request executes on. `Auto` (default) splits
    /// large multiplies across all CPUs and runs small ones serially;
    /// `Serial` restores pre-engine behavior.
    pub par: ParStrategy,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            workers: 2,
            max_batch: 16,
            encode: EncodeOptions::default(),
            policy: RoutePolicy::default(),
            par: ParStrategy::Auto,
        }
    }
}

/// Handle for a pending response.
pub struct Pending {
    rx: Receiver<Result<Vec<f64>>>,
}

impl Pending {
    /// Block until the result arrives.
    pub fn wait(self) -> Result<Vec<f64>> {
        self.rx
            .recv()
            .map_err(|_| DtansError::Service("worker dropped response".into()))?
    }
}

/// The batching SpMVM service.
pub struct SpmvService {
    registry: Arc<RwLock<HashMap<u64, Arc<LoadedMatrix>>>>,
    queue_tx: Sender<Request>,
    /// Service metrics (shared with workers).
    pub metrics: Arc<Metrics>,
    dispatcher: Option<std::thread::JoinHandle<()>>,
    next_id: Mutex<u64>,
    config: ServiceConfig,
}

impl SpmvService {
    /// Start the service with `config`.
    pub fn start(config: ServiceConfig) -> SpmvService {
        let registry: Arc<RwLock<HashMap<u64, Arc<LoadedMatrix>>>> =
            Arc::new(RwLock::new(HashMap::new()));
        let metrics = Arc::new(Metrics::default());
        let (tx, rx) = channel::<Request>();

        let dispatcher = {
            let registry = Arc::clone(&registry);
            let metrics = Arc::clone(&metrics);
            let cfg = config.clone();
            std::thread::spawn(move || dispatcher_loop(rx, registry, metrics, cfg))
        };

        SpmvService {
            registry,
            queue_tx: tx,
            metrics,
            dispatcher: Some(dispatcher),
            next_id: Mutex::new(1),
            config,
        }
    }

    /// Register a matrix: encodes it, routes it, returns its id.
    pub fn register(&self, name: &str, csr: Csr) -> Result<u64> {
        let enc = CsrDtans::encode(&csr, &self.config.encode)?;
        let choice = self.config.policy.choose(&csr, &enc, &self.config.encode);
        let plan = DecodePlan::new(&enc);
        let id = {
            let mut g = self.next_id.lock().unwrap();
            let id = *g;
            *g += 1;
            id
        };
        self.registry.write().unwrap().insert(
            id,
            Arc::new(LoadedMatrix {
                name: name.to_string(),
                csr: Arc::new(csr),
                enc: Arc::new(enc),
                plan: Arc::new(plan),
                choice,
            }),
        );
        Ok(id)
    }

    /// Routed format of a registered matrix.
    pub fn format_of(&self, id: u64) -> Option<FormatChoice> {
        self.registry.read().unwrap().get(&id).map(|m| m.choice)
    }

    /// Submit a request; returns a [`Pending`] handle.
    pub fn submit(&self, matrix: u64, x: Vec<f64>) -> Pending {
        let (tx, rx) = channel();
        self.metrics.submitted.fetch_add(1, Ordering::Relaxed);
        let _ = self.queue_tx.send(Request {
            matrix,
            x,
            submitted: Instant::now(),
            resp: tx,
        });
        Pending { rx }
    }

    /// Convenience: submit and wait.
    pub fn spmv(&self, matrix: u64, x: Vec<f64>) -> Result<Vec<f64>> {
        self.submit(matrix, x).wait()
    }
}

impl Drop for SpmvService {
    fn drop(&mut self) {
        // Close the queue so the dispatcher drains and exits.
        let (tx, _rx) = channel();
        let old = std::mem::replace(&mut self.queue_tx, tx);
        drop(old);
        if let Some(h) = self.dispatcher.take() {
            let _ = h.join();
        }
    }
}

fn dispatcher_loop(
    rx: Receiver<Request>,
    registry: Arc<RwLock<HashMap<u64, Arc<LoadedMatrix>>>>,
    metrics: Arc<Metrics>,
    cfg: ServiceConfig,
) {
    let pool = crate::util::threadpool::ThreadPool::new(cfg.workers);
    // One engine shared by every request: the decode tables / plan stay
    // hot, and kernel-level parallelism is centralized in one place.
    let engine = Arc::new(SpmvEngine::new(cfg.par));
    let mut pending: Option<Request> = None;
    loop {
        // Collect a batch: all queued requests for the same matrix, up to
        // max_batch (vLLM-style continuous batching, simplified).
        let first = match pending.take() {
            Some(r) => r,
            None => match rx.recv() {
                Ok(r) => r,
                Err(_) => break, // queue closed
            },
        };
        let mut batch = vec![first];
        while batch.len() < cfg.max_batch {
            match rx.try_recv() {
                Ok(r) if r.matrix == batch[0].matrix => batch.push(r),
                Ok(r) => {
                    pending = Some(r);
                    break;
                }
                Err(_) => break,
            }
        }
        metrics.batches.fetch_add(1, Ordering::Relaxed);

        let mat = registry.read().unwrap().get(&batch[0].matrix).cloned();
        match mat {
            None => {
                for req in batch {
                    metrics.failed.fetch_add(1, Ordering::Relaxed);
                    let _ = req
                        .resp
                        .send(Err(DtansError::Service(format!("unknown matrix {}", req.matrix))));
                }
            }
            // SpMM fast path only when the engine would actually fan the
            // batch out; otherwise (Serial engine, or Auto below its cost
            // threshold) keep the old one-worker-per-request path so
            // request-level parallelism on the service pool is preserved.
            Some(mat)
                if batch.len() > 1
                    && engine.will_batch_parallel(mat.csr.nnz(), batch.len()) =>
            {
                run_spmm_batch(&mat, batch, &engine, &metrics);
            }
            Some(mat) => {
                for req in batch {
                    let mat = Arc::clone(&mat);
                    let metrics = Arc::clone(&metrics);
                    let engine = Arc::clone(&engine);
                    pool.execute(move || {
                        let result = run_one(&mat, &engine, &req.x);
                        match &result {
                            Ok(_) => metrics
                                .record_latency(req.submitted.elapsed().as_micros() as u64),
                            Err(_) => {
                                metrics.failed.fetch_add(1, Ordering::Relaxed);
                            }
                        }
                        let _ = req.resp.send(result);
                    });
                }
                pool.wait_idle();
            }
        }
    }
}

/// SpMM fast path for a multi-request batch: dimension-check each request
/// up front (so one malformed vector cannot poison the batch), then run
/// all remaining right-hand sides through a single batched engine call.
fn run_spmm_batch(
    mat: &LoadedMatrix,
    batch: Vec<Request>,
    engine: &SpmvEngine,
    metrics: &Metrics,
) {
    let (nrows, ncols) = (mat.csr.nrows, mat.csr.ncols);
    let mut xs = Vec::with_capacity(batch.len());
    let mut accepted = Vec::with_capacity(batch.len());
    for req in batch {
        if req.x.len() == ncols {
            xs.push(req.x);
            accepted.push((req.resp, req.submitted));
        } else {
            metrics.failed.fetch_add(1, Ordering::Relaxed);
            // Same message shape as the per-request path (check_dims with
            // the nrows-sized output the run would have used), so clients
            // see one error text regardless of how requests batched.
            let _ = req.resp.send(Err(DtansError::Dimension(format!(
                "matrix {nrows}x{ncols} with x[{}], y[{nrows}]",
                req.x.len()
            ))));
        }
    }
    if accepted.is_empty() {
        return;
    }
    let result = match mat.choice {
        FormatChoice::Csr => engine.spmm_csr(&mat.csr, &xs),
        FormatChoice::CsrDtans => engine.spmm_csr_dtans_with_plan(&mat.enc, &mat.plan, &xs),
    };
    match result {
        Ok(ys) => {
            for ((resp, submitted), y) in accepted.into_iter().zip(ys) {
                metrics.record_latency(submitted.elapsed().as_micros() as u64);
                let _ = resp.send(Ok(y));
            }
        }
        Err(e) => {
            // Decode-level failures are a property of the matrix, so every
            // request in the batch sees the same error — with its variant
            // preserved, exactly as the per-request path would report it.
            for (resp, _) in accepted {
                metrics.failed.fetch_add(1, Ordering::Relaxed);
                let _ = resp.send(Err(e.duplicate()));
            }
        }
    }
}

fn run_one(mat: &LoadedMatrix, engine: &SpmvEngine, x: &[f64]) -> Result<Vec<f64>> {
    let mut y = vec![0.0; mat.csr.nrows];
    match mat.choice {
        FormatChoice::Csr => engine.spmv_csr(&mat.csr, x, &mut y)?,
        FormatChoice::CsrDtans => engine.spmv_csr_dtans_with_plan(&mat.enc, &mat.plan, x, &mut y)?,
    }
    Ok(y)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::gen::structured::banded;
    use crate::matrix::gen::{assign_values, ValueDist};
    use crate::spmv::spmv_csr;
    use crate::util::rng::Xoshiro256;

    #[test]
    fn serves_requests_correctly() {
        let svc = SpmvService::start(ServiceConfig::default());
        let mut m = banded(200, 3);
        assign_values(&mut m, ValueDist::FewDistinct(4), &mut Xoshiro256::seeded(1));
        let id = svc.register("banded", m.clone()).unwrap();
        let x: Vec<f64> = (0..200).map(|i| (i as f64).cos()).collect();
        let mut want = vec![0.0; 200];
        spmv_csr(&m, &x, &mut want).unwrap();
        let got = svc.spmv(id, x).unwrap();
        crate::util::propcheck::assert_close(&got, &want, 1e-12, 1e-12).unwrap();
        assert!(svc.metrics.latency_summary().count >= 1);
    }

    #[test]
    fn batches_many_concurrent_requests() {
        let svc = SpmvService::start(ServiceConfig {
            workers: 4,
            max_batch: 8,
            ..Default::default()
        });
        let m = banded(128, 2);
        let id = svc.register("m", m.clone()).unwrap();
        let handles: Vec<Pending> = (0..40)
            .map(|i| {
                let x: Vec<f64> = (0..128).map(|j| ((i * j) as f64 * 0.01).sin()).collect();
                svc.submit(id, x)
            })
            .collect();
        for h in handles {
            let y = h.wait().unwrap();
            assert_eq!(y.len(), 128);
        }
        assert_eq!(svc.metrics.completed.load(Ordering::Relaxed), 40);
    }

    #[test]
    fn unknown_matrix_errors() {
        let svc = SpmvService::start(ServiceConfig::default());
        assert!(svc.spmv(999, vec![0.0; 4]).is_err());
        assert_eq!(svc.metrics.failed.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn parallel_engine_config_matches_serial_service() {
        // Same requests through a Serial-engine service and a Fixed(4)
        // engine service must produce bit-identical responses.
        let mut m = banded(3000, 3);
        assign_values(&mut m, ValueDist::FewDistinct(8), &mut Xoshiro256::seeded(7));
        let xs: Vec<Vec<f64>> = (0..6)
            .map(|i| (0..3000).map(|j| ((i * j) as f64 * 0.001).sin()).collect())
            .collect();
        let mut answers: Vec<Vec<Vec<f64>>> = Vec::new();
        for par in [ParStrategy::Serial, ParStrategy::Fixed(4)] {
            let svc = SpmvService::start(ServiceConfig {
                workers: 2,
                par,
                policy: RoutePolicy { min_nnz: 1 << 10, max_size_ratio: 0.95 },
                ..Default::default()
            });
            let id = svc.register("m", m.clone()).unwrap();
            // Submit all up front so the dispatcher can exercise the SpMM
            // batch fast path.
            let pendings: Vec<Pending> =
                xs.iter().map(|x| svc.submit(id, x.clone())).collect();
            answers.push(pendings.into_iter().map(|p| p.wait().unwrap()).collect());
        }
        assert_eq!(answers[0], answers[1]);
        // And both match the serial CSR ground truth.
        for (x, y) in xs.iter().zip(&answers[0]) {
            let mut want = vec![0.0; 3000];
            spmv_csr(&m, x, &mut want).unwrap();
            crate::util::propcheck::assert_close(y, &want, 1e-12, 1e-12).unwrap();
        }
    }

    #[test]
    fn spmm_batch_isolates_bad_dimensions() {
        // Fixed strategy keeps will_batch_parallel() true at any size, so
        // whenever these requests do coalesce they exercise the SpMM path.
        let svc = SpmvService::start(ServiceConfig {
            par: ParStrategy::Fixed(2),
            ..Default::default()
        });
        let m = banded(256, 2);
        let id = svc.register("m", m).unwrap();
        // One malformed request among good ones; submitted together so
        // they can batch.
        let good1 = svc.submit(id, vec![1.0; 256]);
        let bad = svc.submit(id, vec![1.0; 7]);
        let good2 = svc.submit(id, vec![2.0; 256]);
        assert_eq!(good1.wait().unwrap().len(), 256);
        assert!(bad.wait().is_err());
        assert_eq!(good2.wait().unwrap().len(), 256);
    }

    #[test]
    fn routes_large_structured_to_dtans() {
        let svc = SpmvService::start(ServiceConfig {
            policy: RoutePolicy {
                min_nnz: 1 << 10,
                max_size_ratio: 0.9,
            },
            ..Default::default()
        });
        let mut m = banded(4000, 2);
        assign_values(&mut m, ValueDist::Ones, &mut Xoshiro256::seeded(2));
        let id = svc.register("big", m.clone()).unwrap();
        assert_eq!(svc.format_of(id), Some(FormatChoice::CsrDtans));
        // And results still match CSR.
        let x = vec![1.0; 4000];
        let mut want = vec![0.0; 4000];
        spmv_csr(&m, &x, &mut want).unwrap();
        let got = svc.spmv(id, x).unwrap();
        crate::util::propcheck::assert_close(&got, &want, 1e-12, 1e-9).unwrap();
    }
}
