//! Parsing of `artifacts/manifest.txt` produced by `python/compile/aot.py`:
//! one line per compiled entry (`name|in=dtype:shape;...|out`) plus
//! `#bucket` metadata lines describing the static padding shapes.

use crate::util::error::{DtansError, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// Element type of an artifact parameter.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElemType {
    /// 32-bit int.
    I32,
    /// 64-bit int.
    I64,
    /// 32-bit float.
    F32,
    /// 64-bit float.
    F64,
}

/// One parameter (or result) spec.
#[derive(Debug, Clone, PartialEq)]
pub struct ArgSpec {
    /// Element type.
    pub dtype: ElemType,
    /// Dimensions.
    pub dims: Vec<usize>,
}

impl ArgSpec {
    fn parse(s: &str) -> Result<ArgSpec> {
        let (dt, dims) = s
            .split_once(':')
            .ok_or_else(|| DtansError::Runtime(format!("bad arg spec {s:?}")))?;
        let dtype = match dt {
            "i32" => ElemType::I32,
            "i64" => ElemType::I64,
            "f32" => ElemType::F32,
            "f64" => ElemType::F64,
            _ => return Err(DtansError::Runtime(format!("bad dtype {dt:?}"))),
        };
        let dims = dims
            .split('x')
            .map(|d| {
                d.parse::<usize>()
                    .map_err(|_| DtansError::Runtime(format!("bad dim {d:?}")))
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(ArgSpec { dtype, dims })
    }

    /// Number of elements.
    pub fn len(&self) -> usize {
        self.dims.iter().product()
    }

    /// True when zero-dimensional.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// A compiled artifact entry.
#[derive(Debug, Clone)]
pub struct Entry {
    /// Entry name (`<entry>_<bucket>` — also the file stem).
    pub name: String,
    /// Input parameter specs, in call order.
    pub inputs: Vec<ArgSpec>,
    /// Output spec (flattened single result).
    pub output: ArgSpec,
}

/// Static bucket shapes the Rust side pads matrices into.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Bucket {
    /// Rows (multiple of 32).
    pub nrows: usize,
    /// Columns.
    pub ncols: usize,
    /// Stream capacity in words.
    pub nw: usize,
    /// Escape side-stream capacity.
    pub ne: usize,
    /// CSR-entry nnz capacity.
    pub nnz: usize,
    /// Segment loop bound.
    pub max_seg: usize,
}

/// Parsed manifest.
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    /// Entries by name.
    pub entries: BTreeMap<String, Entry>,
    /// Buckets by name.
    pub buckets: BTreeMap<String, Bucket>,
}

impl Manifest {
    /// Parse manifest text.
    pub fn parse(text: &str) -> Result<Manifest> {
        let mut m = Manifest::default();
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix("#bucket ") {
                let mut name = String::new();
                let mut vals: BTreeMap<&str, usize> = BTreeMap::new();
                for (i, tok) in rest.split_whitespace().enumerate() {
                    if i == 0 {
                        name = tok.to_string();
                    } else if let Some((k, v)) = tok.split_once('=') {
                        vals.insert(
                            k,
                            v.parse().map_err(|_| {
                                DtansError::Runtime(format!("bad bucket value {tok:?}"))
                            })?,
                        );
                    }
                }
                let get = |k: &str| -> Result<usize> {
                    vals.get(k)
                        .copied()
                        .ok_or_else(|| DtansError::Runtime(format!("bucket {name} missing {k}")))
                };
                m.buckets.insert(
                    name.clone(),
                    Bucket {
                        nrows: get("nrows")?,
                        ncols: get("ncols")?,
                        nw: get("nw")?,
                        ne: get("ne")?,
                        nnz: get("nnz")?,
                        max_seg: get("max_seg")?,
                    },
                );
                continue;
            }
            if line.starts_with('#') {
                continue;
            }
            let parts: Vec<&str> = line.split('|').collect();
            if parts.len() != 3 {
                return Err(DtansError::Runtime(format!("bad manifest line {line:?}")));
            }
            let inputs = parts[1]
                .split(';')
                .filter(|s| !s.is_empty())
                .map(ArgSpec::parse)
                .collect::<Result<Vec<_>>>()?;
            let output = ArgSpec::parse(parts[2])?;
            m.entries.insert(
                parts[0].to_string(),
                Entry {
                    name: parts[0].to_string(),
                    inputs,
                    output,
                },
            );
        }
        Ok(m)
    }

    /// Load `manifest.txt` from an artifact directory.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.txt"))?;
        Manifest::parse(&text)
    }

    /// Bucket name for an entry name (`<entry>_<bucket>`).
    pub fn bucket_of(&self, entry: &str) -> Option<(&str, &Bucket)> {
        self.buckets
            .iter()
            .find(|(b, _)| entry.ends_with(b.as_str()))
            .map(|(b, v)| (b.as_str(), v))
    }

    /// Smallest bucket (by nrows) satisfying the given requirements.
    pub fn pick_bucket(
        &self,
        nrows: usize,
        ncols: usize,
        nw: usize,
        ne: usize,
        max_seg: usize,
    ) -> Option<(&str, &Bucket)> {
        self.buckets
            .iter()
            .filter(|(_, b)| {
                b.nrows >= nrows
                    && b.ncols >= ncols
                    && b.nw >= nw
                    && b.ne >= ne
                    && b.max_seg >= max_seg
            })
            .min_by_key(|(_, b)| b.nrows)
            .map(|(n, b)| (n.as_str(), b))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
dense_matvec_r64c64|f32:64x64;f32:64;f32:64|f32:64
spmv_dtans_r64c64|i32:4096;i32:4096;f32:64|f32:64
#bucket r64c64 nrows=64 ncols=64 nw=4096 ne=512 nnz=1024 max_seg=32
#bucket r256c256 nrows=256 ncols=256 nw=32768 ne=4096 nnz=8192 max_seg=64
";

    #[test]
    fn parses_entries_and_buckets() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.entries.len(), 2);
        assert_eq!(m.buckets.len(), 2);
        let e = &m.entries["dense_matvec_r64c64"];
        assert_eq!(e.inputs[0].dims, vec![64, 64]);
        assert_eq!(e.inputs[0].dtype, ElemType::F32);
        assert_eq!(m.buckets["r64c64"].nw, 4096);
    }

    #[test]
    fn bucket_of_matches_suffix() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let (b, _) = m.bucket_of("spmv_dtans_r64c64").unwrap();
        assert_eq!(b, "r64c64");
    }

    #[test]
    fn pick_bucket_smallest_fit() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let (name, _) = m.pick_bucket(50, 64, 1000, 100, 10).unwrap();
        assert_eq!(name, "r64c64");
        let (name, _) = m.pick_bucket(65, 64, 1000, 100, 10).unwrap();
        assert_eq!(name, "r256c256");
        assert!(m.pick_bucket(10_000, 64, 1000, 100, 10).is_none());
    }

    #[test]
    fn rejects_malformed() {
        assert!(Manifest::parse("just|two").is_err());
        assert!(Manifest::parse("a|q32:3|f32:3").is_err());
    }
}
